"""Figs. 5/16: time to send one message vs size — max-rate (Eq. 10) inter-node
model vs intra-node (Eq. 12) model, Blue Waters constants (Tables 3-4)."""
from __future__ import annotations

from benchmarks.common import Table
from repro.core.cost_model import BLUE_WATERS, inter_node_time, intra_node_time


def run() -> Table:
    t = Table("Fig 5 — single message time (s), Blue Waters model",
              ["bytes", "protocol", "inter-node (ppn=16)", "inter-node (ppn=1)",
               "intra-node", "inter/intra"])
    for nbytes in (8, 64, 512, 4096, 32768, 262144, 2097152):
        inter16 = inter_node_time(nbytes, 16, BLUE_WATERS)
        inter1 = inter_node_time(nbytes, 1, BLUE_WATERS)
        intra = intra_node_time(nbytes, BLUE_WATERS)
        t.add(nbytes, BLUE_WATERS.protocol(nbytes), inter16, inter1, intra,
              inter16 / intra)
    return t


if __name__ == "__main__":
    print(run().render())
