"""Seed (pre-vectorisation) plan compiler, kept verbatim as the benchmark
baseline for BENCH_spmv.json's plan-compile speedup measurement.

This is the dict/per-element-loop implementation of ``split_local_blocks``
and ``compile_nap`` exactly as shipped in the seed commit; the library path
(``repro.core.spmv`` / ``repro.core.spmv_jax``) replaced it with bulk
``np.searchsorted`` indexing.  Do not "fix" or speed this file up — its
slowness is the datum.  (The fused-BSR arrays did not exist in the seed,
so the legacy compile measures strictly LESS work than the new one.)
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.comm_graph import Message, NAPPlan, build_nap_plan
from repro.core.partition import RowPartition
from repro.core.spmv import LocalBlocks
from repro.core.spmv_jax import CompiledNAP, _pad_to
from repro.core.topology import Topology
from repro.sparse.csr import CSR


def _pos_in(idx: np.ndarray, j: int) -> int:
    p = int(np.searchsorted(idx, j))
    assert p < idx.size and idx[p] == j
    return p


def legacy_split_local_blocks(a: CSR, part: RowPartition, topo: Topology, rank: int) -> LocalBlocks:
    rows = part.rows_of(rank)
    local = a.select_rows(rows)
    g_rows, g_cols, vals = local.to_coo()  # g_rows are positions within `rows`
    col_owner = part.owner[g_cols]
    col_node = topo.node_of_array(col_owner)
    me_node = topo.node_of(rank)

    on_proc_m = col_owner == rank
    on_node_m = (col_owner != rank) & (col_node == me_node)
    off_node_m = col_node != me_node

    # on-process: remap columns to local index within R(r)
    glob_to_loc = {int(g): i for i, g in enumerate(rows)}
    op_cols = np.array([glob_to_loc[int(c)] for c in g_cols[on_proc_m]], dtype=np.int64)
    on_proc = CSR.from_coo(g_rows[on_proc_m], op_cols, vals[on_proc_m],
                           (rows.size, rows.size), sum_duplicates=False)

    def buffer_block(mask: np.ndarray) -> Tuple[CSR, np.ndarray]:
        cols = np.unique(g_cols[mask])
        slot = {int(c): i for i, c in enumerate(cols)}
        bc = np.array([slot[int(c)] for c in g_cols[mask]], dtype=np.int64)
        blk = CSR.from_coo(g_rows[mask], bc, vals[mask],
                           (rows.size, max(int(cols.size), 1)), sum_duplicates=False)
        return blk, cols

    on_node, on_node_cols = buffer_block(on_node_m)
    off_node, off_node_cols = buffer_block(off_node_m)
    return LocalBlocks(rank=rank, rows=rows, on_proc=on_proc, on_node=on_node,
                       off_node=off_node, on_node_cols=on_node_cols,
                       off_node_cols=off_node_cols)


def legacy_split_all_blocks(a: CSR, part: RowPartition, topo: Topology) -> List[LocalBlocks]:
    return [legacy_split_local_blocks(a, part, topo, r) for r in range(topo.n_procs)]


def legacy_compile_nap(a: CSR, part: RowPartition, topo: Topology,
                plan: Optional[NAPPlan] = None) -> CompiledNAP:
    if plan is None:
        plan = build_nap_plan(a.indptr, a.indices, part, topo, pairing="aligned")
    n_procs, ppn, n_nodes = topo.n_procs, topo.ppn, topo.n_nodes
    blocks = legacy_split_all_blocks(a, part, topo)
    local_index = part.local_index()
    rows_pad = max(1, int(part.counts().max()))

    def msg_pad(phase: List[List[Message]]) -> int:
        sizes = [m.size for msgs in phase for m in msgs]
        return max(1, max(sizes, default=1))

    full_pad = msg_pad(plan.local_full_sends)
    init_pad = msg_pad(plan.local_init_sends)
    inter_pad = msg_pad(plan.inter_sends)
    final_pad = msg_pad(plan.local_final_sends)
    bnode_pad = max(1, max(b.on_node_cols.size for b in blocks))
    boff_pad = max(1, max(b.off_node_cols.size for b in blocks))
    nnz_pads = {
        "on_proc": max(1, max(b.on_proc.nnz for b in blocks)),
        "on_node": max(1, max(b.on_node.nnz for b in blocks)),
        "off_node": max(1, max(b.off_node.nnz for b in blocks)),
    }

    A: Dict[str, List[np.ndarray]] = {k: [] for k in (
        "v_loc_init",  # not an index array; filled by caller
    )}
    arrays: Dict[str, np.ndarray] = {}

    def stack_int(name: str, per_rank: List[np.ndarray], shape: Tuple[int, ...]) -> None:
        out = np.zeros((n_procs,) + shape, dtype=np.int32)
        for r, arr in enumerate(per_rank):
            out[r] = arr
        arrays[name] = out

    full_send, init_send, final_send = [], [], []
    inter_gather, bnode_gather, boff_gather = [], [], []
    coo = {k: {"rows": [], "cols": [], "vals": []} for k in nnz_pads}

    for r in range(n_procs):
        p_r, n_r = topo.proc_node(r)
        blk = blocks[r]

        # -- full-local sends: [ppn, full_pad] source local-row positions ----
        fs = np.zeros((ppn, full_pad), dtype=np.int32)
        for m in plan.local_full_sends[r]:
            q = topo.local_of(m.dst)
            fs[q, : m.size] = local_index[m.idx]
        full_send.append(fs)

        # -- init sends -------------------------------------------------------
        isnd = np.zeros((ppn, init_pad), dtype=np.int32)
        for m in plan.local_init_sends[r]:
            q = topo.local_of(m.dst)
            isnd[q, : m.size] = local_index[m.idx]
        init_send.append(isnd)

        # -- inter gather: positions into concat(v_loc, init_recv_flat) -------
        init_recv_by_src = {topo.local_of(m.src): m for m in plan.local_init_recvs[r]}
        ig = np.zeros((n_nodes, inter_pad), dtype=np.int32)
        for m in plan.inter_sends[r]:
            dst_node = topo.node_of(m.dst)
            for k, j in enumerate(m.idx):
                if part.owner[j] == r:
                    ig[dst_node, k] = local_index[j]
                else:
                    src_p = topo.local_of(int(part.owner[j]))
                    msg = init_recv_by_src[src_p]
                    ig[dst_node, k] = rows_pad + src_p * init_pad + _pos_in(msg.idx, int(j))
        inter_gather.append(ig)

        # -- final sends: positions into inter_recv_flat ----------------------
        inter_recv_by_node = {topo.node_of(m.src): m for m in plan.inter_recvs[r]}
        fsnd = np.zeros((ppn, final_pad), dtype=np.int32)
        for m in plan.local_final_sends[r]:
            q = topo.local_of(m.dst)
            for k, j in enumerate(m.idx):
                src_n = None
                for nn, rmsg in inter_recv_by_node.items():
                    hit = np.searchsorted(rmsg.idx, j)
                    if hit < rmsg.idx.size and rmsg.idx[hit] == j:
                        src_n = nn
                        fsnd[q, k] = nn * inter_pad + hit
                        break
                assert src_n is not None, "final-send value must have arrived inter-node"
        final_send.append(fsnd)

        # -- on-node buffer gather: positions into full_recv_flat -------------
        full_recv_by_src = {topo.local_of(m.src): m for m in plan.local_full_recvs[r]}
        bg = np.zeros((bnode_pad,), dtype=np.int32)
        for slot, j in enumerate(blk.on_node_cols):
            src_p = topo.local_of(int(part.owner[j]))
            msg = full_recv_by_src[src_p]
            bg[slot] = src_p * full_pad + _pos_in(msg.idx, int(j))
        bnode_gather.append(bg)

        # -- off-node buffer gather: concat(inter_recv_flat, final_recv_flat) -
        final_recv_by_src = {topo.local_of(m.src): m for m in plan.local_final_recvs[r]}
        og = np.zeros((boff_pad,), dtype=np.int32)
        for slot, j in enumerate(blk.off_node_cols):
            placed = False
            for nn, rmsg in inter_recv_by_node.items():
                hit = np.searchsorted(rmsg.idx, j)
                if hit < rmsg.idx.size and rmsg.idx[hit] == j:
                    og[slot] = nn * inter_pad + hit
                    placed = True
                    break
            if not placed:
                for src_p, rmsg in final_recv_by_src.items():
                    hit = np.searchsorted(rmsg.idx, j)
                    if hit < rmsg.idx.size and rmsg.idx[hit] == j:
                        og[slot] = n_nodes * inter_pad + src_p * final_pad + hit
                        placed = True
                        break
            assert placed, f"rank {r} off-node col {j} unreachable"
        boff_gather.append(og)

        # -- COO blocks --------------------------------------------------------
        for key, block in (("on_proc", blk.on_proc), ("on_node", blk.on_node),
                           ("off_node", blk.off_node)):
            rows_i, cols_i, vals_i = block.to_coo()
            coo[key]["rows"].append(rows_i.astype(np.int32))
            coo[key]["cols"].append(cols_i.astype(np.int32))
            coo[key]["vals"].append(vals_i)

    stack_int("full_send", full_send, (ppn, full_pad))
    stack_int("init_send", init_send, (ppn, init_pad))
    stack_int("final_send", final_send, (ppn, final_pad))
    stack_int("inter_gather", inter_gather, (n_nodes, inter_pad))
    stack_int("bnode_gather", bnode_gather, (bnode_pad,))
    stack_int("boff_gather", boff_gather, (boff_pad,))
    for key in coo:
        arrays[f"{key}_rows"] = _pad_to(coo[key]["rows"], nnz_pads[key]).astype(np.int32)
        arrays[f"{key}_cols"] = _pad_to(coo[key]["cols"], nnz_pads[key]).astype(np.int32)
        arrays[f"{key}_vals"] = _pad_to(
            [v.astype(np.float32) for v in coo[key]["vals"]], nnz_pads[key], fill=0.0)

    pads = dict(full=full_pad, init=init_pad, inter=inter_pad, final=final_pad,
                bnode=bnode_pad, boff=boff_pad, **{f"nnz_{k}": v for k, v in nnz_pads.items()})
    return CompiledNAP(topo=topo, part=part, rows_pad=rows_pad, pads=pads, arrays=arrays)
