"""Figs. 13/14/15: SuiteSparse(-like) speedups and the partition crossover.

Fig 13: NAP speedup over standard SpMV with STRIDED partitions (row r on
process r mod np) at several nnz/core scales.  Fig 14: same with BALANCED
(graph-partitioned) rows.  Fig 15: how many NAPSpMVs amortise the one-time
graph-partitioning cost (crossover count).

Those three tables are Blue Waters cost-model numbers at paper-like
process counts; :func:`run_measured` adds MEASURED walls through the
real ``repro.api`` shardmap stack (``repro.mesh.scaling``) for a subset
of surrogates at the shape this host can address — standard vs nap vs
multistep, strided vs balanced.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Table, measured_sweep, spmv_times
from repro.configs.paper_spmv import CONFIG
from repro.core.partition import make_partition
from repro.core.topology import Topology
from repro.sparse import suitesparse_like

MATRICES = ["nlpkkt240", "ML_Geer", "Flan_1565", "audikw_1", "Serena",
            "StocF-1465"]


def _topo_for(a, nnz_per_core: int) -> Topology:
    n_procs = max(CONFIG.ppn * 2, min(512, a.nnz // max(nnz_per_core, 1)))
    n_nodes = max(2, n_procs // CONFIG.ppn)
    return Topology(n_nodes=n_nodes, ppn=CONFIG.ppn)


def run_fig13_14():
    t13 = Table("Fig 13 — NAP speedup, STRIDED partitions (x-like surrogates)",
                ["matrix", "nnz/core", "standard (s)", "nap (s)", "speedup"])
    t14 = Table("Fig 14 — NAP speedup, BALANCED partitions",
                ["matrix", "nnz/core", "standard (s)", "nap (s)", "speedup"])
    for name in MATRICES:
        a = suitesparse_like.build(name, scale=4096)
        for nnz_per_core in (50_000, 100_000):
            topo = _topo_for(a, nnz_per_core)
            if a.shape[0] < topo.n_procs:
                continue
            strided = make_partition("strided", a.shape[0], topo.n_procs)
            r = spmv_times(a, strided, topo)
            t13.add(f"{name}-like", nnz_per_core, r["standard"], r["nap"],
                    r["speedup"])
            balanced = make_partition("balanced", a.shape[0], topo.n_procs,
                                      a.indptr, a.indices)
            r = spmv_times(a, balanced, topo)
            t14.add(f"{name}-like", nnz_per_core, r["standard"], r["nap"],
                    r["speedup"])
    return t13, t14


def run_fig15():
    t = Table("Fig 15 — NAPSpMV count to amortise graph partitioning",
              ["matrix", "t_nap strided (s)", "t_nap balanced (s)",
               "t_partition (s)", "crossover #spmvs"])
    for name in MATRICES[:4]:
        a = suitesparse_like.build(name, scale=4096)
        topo = _topo_for(a, 50_000)
        if a.shape[0] < topo.n_procs:
            continue
        strided = make_partition("strided", a.shape[0], topo.n_procs)
        t0 = time.time()
        balanced = make_partition("balanced", a.shape[0], topo.n_procs,
                                  a.indptr, a.indices)
        t_part = time.time() - t0   # stand-in for the PT-Scotch setup cost
        rs = spmv_times(a, strided, topo)["nap"]
        rb = spmv_times(a, balanced, topo)["nap"]
        gain = rs - rb
        crossover = int(np.ceil(t_part / gain)) if gain > 1e-12 else float("inf")
        t.add(f"{name}-like", rs, rb, t_part, crossover)
    return t


def run_measured() -> Table:
    t = Table("Fig 13/14 (measured) — NAP vs standard, shardmap stack (2x2)",
              ["matrix", "partition", "standard (s)", "nap (s)",
               "multistep (s)", "speedup (std/nap)"])
    for name in MATRICES[:2]:
        for kind in ("strided", "balanced"):
            sweep = measured_sweep({
                "mode": "strong",
                "matrix": {"kind": "suitesparse_like", "name": name,
                           "scale": 8192},
                "partition": kind,
                "ladder": [[2, 2]],
                "methods": ["standard", "nap", "multistep"],
                "repeats": 3,
            })
            for p in sweep["points"]:
                m = p["methods"]
                t.add(f"{name}-like", kind,
                      m["standard"]["wall_s"], m["nap"]["wall_s"],
                      m["multistep"]["wall_s"],
                      m["standard"]["wall_s"] / max(m["nap"]["wall_s"],
                                                    1e-12))
    return t


if __name__ == "__main__":
    a, b = run_fig13_14()
    print(a.render())
    print()
    print(b.render())
    print()
    print(run_fig15().render())
    print()
    print(run_measured().render())
