"""``scaling`` block of BENCH_spmv.json: MEASURED weak/strong walls.

Runs :func:`repro.mesh.scaling.scaling_sweep` over a small
(n_nodes, ppn) ladder — standard vs nap vs multistep through the real
``repro.api`` shardmap stack — plus the per-phase exchange walls and the
:meth:`PostalParams.calibrated` fit of the postal constants from those
walls.  The result is MERGED into an existing BENCH_spmv.json under the
``"scaling"`` key (other sections untouched) so benchmarks/run.py's
1.5x regression gate covers the flattened ``scaling.walls`` dict like
every other wall entry.

    PYTHONPATH=src python -m benchmarks.bench_scaling [--quick] [--out PATH]

Must run as its own process: it forces the device count before jax loads.
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse
import dataclasses
import json

FULL_LADDER = [[1, 2], [2, 2], [2, 4]]
QUICK_LADDER = [[1, 2], [2, 2]]


def flatten_walls(sweep: dict) -> dict:
    """``{"<nn>x<ppn>.<method>.<wall>": seconds}`` — the flat dict the
    regression gate walks (point/method identity in the key, so baseline
    and fresh runs compare like with like)."""
    walls = {}
    for point in sweep["points"]:
        shape = f"{point['n_nodes']}x{point['ppn']}"
        for method, m in point["methods"].items():
            walls[f"{shape}.{method}.wall_s"] = m["wall_s"]
            walls[f"{shape}.{method}.comm_wall_s"] = m["comm_wall_s"]
    return walls


def run(quick: bool = False) -> dict:
    from repro.core.cost_model import PostalParams
    from repro.mesh.scaling import calibration_records, scaling_sweep

    config = {
        "mode": "strong",
        "n_rows": 2048,
        "nnz_per_row": 8,
        "ladder": QUICK_LADDER if quick else FULL_LADDER,
        "methods": ["standard", "nap", "multistep"],
        "repeats": 3,
    }
    sweep = scaling_sweep(config)
    records = calibration_records(sweep)
    params = PostalParams.calibrated(records)
    sweep["walls"] = flatten_walls(sweep)
    sweep["calibration"] = dict(dataclasses.asdict(params),
                                n_records=len(records))
    return sweep


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_spmv.json")
    args = ap.parse_args()

    sweep = run(quick=args.quick)

    payload = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            payload = json.load(f)
    payload["scaling"] = sweep
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)

    cal = sweep["calibration"]
    print(f"scaling: {len(sweep['points'])} points, "
          f"{len(sweep['skipped'])} skipped, "
          f"{cal['n_records']} calibration records")
    for key, wall in sorted(sweep["walls"].items()):
        print(f"  {key}: {wall * 1e3:.3f} ms")
    print(f"  calibrated postal: alpha_inter={cal['alpha_inter']:.3e}s "
          f"beta_inter={cal['beta_inter']:.3e}B/s "
          f"alpha_intra={cal['alpha_intra']:.3e}s "
          f"beta_intra={cal['beta_intra']:.3e}B/s")


if __name__ == "__main__":
    main()
