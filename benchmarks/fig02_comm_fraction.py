"""Fig. 2: % of SpMV time spent communicating vs nnz/process (nlpkkt240-like).

The paper shows communication dominating as the strong-scaling limit is
approached (500k -> 50k nnz/process).  Two tables reproduce the trend
with the nlpkkt240 surrogate:

* :func:`run` — the Blue Waters cost model (Eqs. 10-12), at paper-like
  process counts.
* :func:`run_measured` — MEASURED walls through the real ``repro.api``
  shardmap stack (``repro.mesh.scaling``), at the ladder this host can
  actually address; the comm fraction comes from per-phase exchange
  walls timed in isolation, not from a model.
"""
from __future__ import annotations

from benchmarks.common import (Table, default_topology, measured_sweep,
                               spmv_times)
from repro.core.partition import contiguous_partition
from repro.core.topology import Topology
from repro.sparse import suitesparse_like


def run() -> Table:
    t = Table("Fig 2 — communication fraction of SpMV time (nlpkkt240-like)",
              ["nnz/process", "n_procs", "comm frac (standard)",
               "comm frac (NAP)"])
    a = suitesparse_like.build("nlpkkt240", scale=2048)
    base_topo = default_topology()
    for n_nodes in (2, 4, 8, 16, 32):
        topo = Topology(n_nodes=n_nodes, ppn=base_topo.ppn)
        part = contiguous_partition(a.shape[0], topo.n_procs)
        r = spmv_times(a, part, topo)
        nnz_pp = a.nnz // topo.n_procs
        t.add(nnz_pp, topo.n_procs,
              r["standard_comm"] / max(r["standard"], 1e-30),
              r["nap_comm"] / max(r["nap"], 1e-30))
    return t


def run_measured() -> Table:
    t = Table("Fig 2 (measured) — comm fraction, shardmap stack "
              "(nlpkkt240-like, strong scaling)",
              ["shape", "n_procs", "wall std (s)", "wall NAP (s)",
               "comm frac (standard)", "comm frac (NAP)"])
    sweep = measured_sweep({
        "mode": "strong",
        "matrix": {"kind": "suitesparse_like", "name": "nlpkkt240",
                   "scale": 8192},
        "ladder": [[1, 2], [2, 2], [2, 4]],
        "methods": ["standard", "nap"],
        "repeats": 3,
    })
    for p in sweep["points"]:
        std, nap = p["methods"]["standard"], p["methods"]["nap"]
        t.add(f"{p['n_nodes']}x{p['ppn']}", p["n_nodes"] * p["ppn"],
              std["wall_s"], nap["wall_s"],
              std["comm_fraction"], nap["comm_fraction"])
    return t


if __name__ == "__main__":
    print(run().render())
    print()
    print(run_measured().render())
