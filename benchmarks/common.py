"""Shared helpers for the paper-figure benchmarks.

All Blue Waters numbers are MODEL-derived (Eqs. 10-12 with the paper's
Tables 3-4 constants): this container has no Gemini interconnect to measure.
The experiments reproduce the *structure* of each figure — which algorithm
wins, where, and by how much — at simulation scale (32 nodes x 16 ppn).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.configs.paper_spmv import CONFIG
from repro.core.comm_graph import (build_nap_plan, build_standard_plan,
                                   nap_stats, standard_stats)
from repro.core.cost_model import (BLUE_WATERS, compute_time, nap_cost,
                                   standard_cost)
from repro.core.partition import make_partition
from repro.core.topology import Topology
from repro.sparse.csr import CSR


def default_topology() -> Topology:
    return Topology(n_nodes=CONFIG.n_nodes, ppn=CONFIG.ppn)


def spmv_times(a: CSR, part, topo: Topology, bytes_per_val: int = 8
               ) -> Dict[str, float]:
    """Modeled standard vs NAP SpMV times (comm + local compute)."""
    std = build_standard_plan(a.indptr, a.indices, part, topo)
    nap = build_nap_plan(a.indptr, a.indices, part, topo,
                         pairing=CONFIG.pairing)
    t_std = standard_cost(std, BLUE_WATERS, bytes_per_val)["total"]
    t_nap = nap_cost(nap, BLUE_WATERS, bytes_per_val)["total"]
    comp = compute_time(int(np.diff(a.indptr).max()) * 1)  # rough per-rank
    nnz_per_rank = a.nnz / topo.n_procs
    comp = compute_time(int(nnz_per_rank))
    return {
        "standard": t_std + comp,
        "nap": t_nap + comp,
        "standard_comm": t_std,
        "nap_comm": t_nap,
        "compute": comp,
        "speedup": (t_std + comp) / max(t_nap + comp, 1e-30),
    }


def measured_sweep(config: Dict) -> Dict:
    """Run :mod:`repro.mesh.scaling` in its own process and return the
    sweep payload.

    A subprocess is mandatory, not a convenience: the harness must force
    the XLA host device count for the ladder's largest shape before jax
    initialises, and the figure driver's jax is already up on one
    device.  Any inherited forced count is dropped so the child sizes
    its own platform.
    """
    import json
    import os
    import subprocess
    import sys
    import tempfile

    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    with tempfile.TemporaryDirectory() as td:
        cfg_path = os.path.join(td, "config.json")
        out_path = os.path.join(td, "out.json")
        with open(cfg_path, "w") as f:
            json.dump(config, f)
        proc = subprocess.run(
            [sys.executable, "-m", "repro.mesh.scaling", cfg_path, out_path],
            env=env, capture_output=True, text=True)
        if proc.returncode != 0:
            raise RuntimeError(
                f"repro.mesh.scaling failed:\n{proc.stderr[-4000:]}")
        with open(out_path) as f:
            return json.load(f)


def message_stats(a: CSR, part, topo: Topology) -> Dict[str, Dict]:
    std = build_standard_plan(a.indptr, a.indices, part, topo)
    nap = build_nap_plan(a.indptr, a.indices, part, topo,
                         pairing=CONFIG.pairing)
    return {"standard": standard_stats(std), "nap": nap_stats(nap)}


class Table:
    def __init__(self, title: str, columns: List[str]):
        self.title = title
        self.columns = columns
        self.rows: List[List] = []

    def add(self, *row) -> None:
        self.rows.append(list(row))

    def render(self) -> str:
        widths = [max(len(str(c)), *(len(_fmt(r[i])) for r in self.rows))
                  if self.rows else len(str(c))
                  for i, c in enumerate(self.columns)]
        out = [f"== {self.title} =="]
        out.append(" | ".join(str(c).ljust(w) for c, w in zip(self.columns, widths)))
        out.append("-+-".join("-" * w for w in widths))
        for r in self.rows:
            out.append(" | ".join(_fmt(v).ljust(w) for v, w in zip(r, widths)))
        return "\n".join(out)


def _fmt(v) -> str:
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) < 1e-3 or abs(v) >= 1e5:
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)
