"""§Roofline table: render results/dryrun.json as the per-cell roofline."""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import Table

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results" / "dryrun.json"


def run(mesh_filter: str = ""):
    t = Table("Roofline terms per (arch x shape x mesh) — from the dry-run",
              ["arch", "shape", "mesh", "compute s", "memory s", "coll s",
               "dominant", "MFU %", "useful", "peak GB", "analytic GB"])
    if not RESULTS.exists():
        t.add("(run `python -m repro.launch.dryrun --all` first)",
              *[""] * 10)
        return t
    cells = json.loads(RESULTS.read_text())["cells"]
    for key in sorted(cells):
        r = cells[key]
        arch, shape, mesh = key.split("|")
        if mesh_filter and mesh != mesh_filter:
            continue
        if r.get("skipped"):
            t.add(arch, shape, mesh, "-", "-", "-", "SKIP", "-", "-", "-", "-")
            continue
        if not r.get("ok"):
            t.add(arch, shape, mesh, "-", "-", "-", "FAIL", "-", "-", "-", "-")
            continue
        roof = r["roofline"]
        t.add(arch, shape, mesh, roof["t_compute"], roof["t_memory"],
              roof["t_collective"], roof["dominant"],
              round(roof["mfu"] * 100, 2), round(roof["useful_ratio"], 2),
              round(r["memory"]["peak_gb"], 1),
              round(r["memory"].get("analytic", {}).get("total", 0), 1))
    return t


if __name__ == "__main__":
    print(run().render())
