"""Figs. 11/12: weak & strong scaling on random fixed-nnz matrices.

Fig 11: 5 seeds x densities {25, 50, 100} nnz/row at one scale (costs are
seed-insensitive, matching the paper's observation).  Fig 12: weak scaling
(1000 rows/process) and strong scaling (fixed global rows) over node counts.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Table, spmv_times
from repro.configs.paper_spmv import CONFIG
from repro.core.partition import contiguous_partition
from repro.core.topology import Topology
from repro.sparse import random_fixed_nnz


def run_fig11():
    # seed/density insensitivity (the paper's point here) is scale-free;
    # 250 rows/process keeps 15 plan builds tractable on one host.
    topo = Topology(n_nodes=8, ppn=CONFIG.ppn)
    n_rows = 250 * topo.n_procs
    t = Table("Fig 11 — random matrices: 5 seeds x 3 densities (8 nodes)",
              ["nnz/row", "seed", "standard (s)", "nap (s)", "speedup"])
    for nnz in CONFIG.random_nnz_per_row:
        for seed in range(5):
            a = random_fixed_nnz(n_rows, nnz, seed=seed)
            part = contiguous_partition(n_rows, topo.n_procs)
            r = spmv_times(a, part, topo)
            t.add(nnz, seed, r["standard"], r["nap"], r["speedup"])
    return t


def run_fig12():
    t = Table("Fig 12 — weak & strong scaling, random (100 nnz/row)",
              ["mode", "nodes", "procs", "rows", "standard (s)", "nap (s)",
               "speedup"])
    for n_nodes in (2, 4, 8, 16):
        topo = Topology(n_nodes=n_nodes, ppn=CONFIG.ppn)
        rows = 500 * topo.n_procs
        a = random_fixed_nnz(rows, 100, seed=0)
        part = contiguous_partition(rows, topo.n_procs)
        r = spmv_times(a, part, topo)
        t.add("weak", n_nodes, topo.n_procs, rows, r["standard"], r["nap"],
              r["speedup"])
    rows = CONFIG.strong_scale_rows
    a = random_fixed_nnz(rows, 100, seed=0)
    for n_nodes in (2, 4, 8, 16):
        topo = Topology(n_nodes=n_nodes, ppn=CONFIG.ppn)
        part = contiguous_partition(rows, topo.n_procs)
        r = spmv_times(a, part, topo)
        t.add("strong", n_nodes, topo.n_procs, rows, r["standard"], r["nap"],
              r["speedup"])
    return t


if __name__ == "__main__":
    print(run_fig11().render())
    print()
    print(run_fig12().render())
