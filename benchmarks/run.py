"""Benchmark driver: one experiment per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slowest experiments (fig13-15)")
    args = ap.parse_args()
    t_start = time.time()

    from benchmarks import (fig02_comm_fraction, fig05_message_model,
                            fig08_10_amg_levels, fig11_12_random,
                            roofline_cells)

    print("#" * 72)
    print("# NAPSpMV benchmark suite — all Blue Waters numbers are")
    print("# cost-model-derived (Eqs. 10-12, Tables 3-4); roofline numbers")
    print("# come from the compiled multi-pod dry-run (results/dryrun.json).")
    print("#" * 72, flush=True)

    print(fig02_comm_fraction.run().render())
    print()
    print(fig05_message_model.run().render())
    print()
    for prob in ("anisotropic", "elasticity"):
        for t in fig08_10_amg_levels.run(prob):
            print(t.render())
            print()
    print(fig11_12_random.run_fig11().render())
    print()
    print(fig11_12_random.run_fig12().render())
    print()
    if not args.quick:
        from benchmarks import fig13_15_suitesparse
        t13, t14 = fig13_15_suitesparse.run_fig13_14()
        print(t13.render())
        print()
        print(t14.render())
        print()
        print(fig13_15_suitesparse.run_fig15().render())
        print()
    print(roofline_cells.run().render())

    # machine-readable SpMV perf trajectory (own process: it forces the
    # host device count before jax initialises)
    cmd = [sys.executable, "-m", "benchmarks.bench_spmv",
           "--out", "BENCH_spmv.json"] + (["--quick"] if args.quick else [])
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
    print(proc.stdout, end="")
    if proc.returncode != 0:
        print(f"bench_spmv FAILED:\n{proc.stderr}", flush=True)
        raise SystemExit(proc.returncode)

    print(f"\nall benchmarks done in {time.time() - t_start:.1f}s")


if __name__ == "__main__":
    main()
