"""Benchmark driver: one experiment per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--quick]

The run fails (non-zero exit) if the freshly measured BENCH_spmv.json
regresses plan-compile or local-compute wall time by more than
``REGRESSION_FACTOR`` versus the committed baseline — keep it green
across PRs.  The gate walks EVERY key shared by the two ``spmv_wall.wall``
dicts, which includes the operator-level end-to-end walls
(``operator_forward_nv*_s`` / ``operator_transpose_nv*_s`` — the
`repro.api` pack->run->unpack path) alongside the shard-level executor
walls.  The MEASURED scaling block (benchmarks/bench_scaling.py over
repro.mesh.scaling) rides the same gate: every ``scaling.walls`` entry
shared by baseline and fresh payloads is compared whenever the sweep
configs match, and so do the MoE dispatch island walls
(``moe_dispatch.walls``) whenever that block's config matches.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

REGRESSION_FACTOR = 1.5
# interpret-mode walls in the low-ms range jitter well past 1.5x on a
# shared CPU even with best-of-iters timing; a regression must also
# clear an absolute floor so scheduler noise can't fail the gate while
# a real slowdown (ms -> tens of ms) still does.
REGRESSION_MIN_DELTA_S = 0.005


def check_regressions(baseline: dict, fresh: dict,
                      factor: float = REGRESSION_FACTOR,
                      min_delta: float = REGRESSION_MIN_DELTA_S) -> list:
    """Compare the perf fields shared by two BENCH_spmv.json payloads.

    Sections whose problem size differs between the payloads (e.g. a
    --quick baseline vs a full run) are skipped — same keys, different
    workloads, not comparable.
    """
    regs = []

    def compare(label: str, old, new) -> None:
        if old and new and new > factor * old and new - old > min_delta:
            regs.append(f"{label}: {old}s -> {new}s (> {factor}x)")

    old_pc, new_pc = baseline.get("plan_compile", {}), fresh.get("plan_compile", {})
    if old_pc.get("n_rows") == new_pc.get("n_rows"):
        compare("plan_compile.vectorized_s",
                old_pc.get("vectorized_s"), new_pc.get("vectorized_s"))
    old_sw, new_sw = baseline.get("spmv_wall", {}), fresh.get("spmv_wall", {})
    if old_sw.get("n_rows") == new_sw.get("n_rows"):
        old_wall = old_sw.get("wall", {})
        new_wall = new_sw.get("wall", {})
        for k in sorted(set(old_wall) & set(new_wall)):
            compare(f"spmv_wall.wall.{k}", old_wall[k], new_wall[k])
    old_sc, new_sc = baseline.get("scaling", {}), fresh.get("scaling", {})
    if old_sc.get("config") and old_sc.get("config") == new_sc.get("config"):
        old_walls = old_sc.get("walls", {})
        new_walls = new_sc.get("walls", {})
        for k in sorted(set(old_walls) & set(new_walls)):
            compare(f"scaling.walls.{k}", old_walls[k], new_walls[k])
    # MoE dispatch island walls: same config (geometry + token count) ->
    # same workload, comparable
    old_md, new_md = baseline.get("moe_dispatch", {}), fresh.get("moe_dispatch", {})
    if old_md.get("config") and old_md.get("config") == new_md.get("config"):
        old_walls = old_md.get("walls", {})
        new_walls = new_md.get("walls", {})
        for k in sorted(set(old_walls) & set(new_walls)):
            compare(f"moe_dispatch.walls.{k}", old_walls[k], new_walls[k])
    return regs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slowest experiments (fig13-15)")
    args = ap.parse_args()
    t_start = time.time()

    from benchmarks import (fig02_comm_fraction, fig05_message_model,
                            fig08_10_amg_levels, fig11_12_random,
                            roofline_cells)

    print("#" * 72)
    print("# NAPSpMV benchmark suite — all Blue Waters numbers are")
    print("# cost-model-derived (Eqs. 10-12, Tables 3-4); roofline numbers")
    print("# come from the compiled multi-pod dry-run (results/dryrun.json).")
    print("#" * 72, flush=True)

    print(fig02_comm_fraction.run().render())
    print()
    print(fig02_comm_fraction.run_measured().render())
    print()
    print(fig05_message_model.run().render())
    print()
    for prob in ("anisotropic", "elasticity"):
        for t in fig08_10_amg_levels.run(prob):
            print(t.render())
            print()
    print(fig11_12_random.run_fig11().render())
    print()
    print(fig11_12_random.run_fig12().render())
    print()
    if not args.quick:
        from benchmarks import fig13_15_suitesparse
        t13, t14 = fig13_15_suitesparse.run_fig13_14()
        print(t13.render())
        print()
        print(t14.render())
        print()
        print(fig13_15_suitesparse.run_fig15().render())
        print()
        print(fig13_15_suitesparse.run_measured().render())
        print()
    print(roofline_cells.run().render())

    # machine-readable SpMV perf trajectory (own process: it forces the
    # host device count before jax initialises)
    baseline = None
    if os.path.exists("BENCH_spmv.json"):
        with open("BENCH_spmv.json") as f:
            baseline = json.load(f)
    cmd = [sys.executable, "-m", "benchmarks.bench_spmv",
           "--out", "BENCH_spmv.json"] + (["--quick"] if args.quick else [])
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
    print(proc.stdout, end="")
    if proc.returncode != 0:
        print(f"bench_spmv FAILED:\n{proc.stderr}", flush=True)
        raise SystemExit(proc.returncode)

    # measured scaling walls merge into the same payload (own process:
    # it too forces the host device count before jax initialises)
    cmd = [sys.executable, "-m", "benchmarks.bench_scaling",
           "--out", "BENCH_spmv.json"] + (["--quick"] if args.quick else [])
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
    print(proc.stdout, end="")
    if proc.returncode != 0:
        print(f"bench_scaling FAILED:\n{proc.stderr}", flush=True)
        raise SystemExit(proc.returncode)

    if baseline is not None:
        with open("BENCH_spmv.json") as f:
            fresh = json.load(f)
        regs = check_regressions(baseline, fresh)
        if regs:
            # keep the baseline in place so a rerun can't silently pass by
            # comparing the regressed numbers against themselves; park the
            # failing measurement next to it for inspection
            with open("BENCH_spmv.rejected.json", "w") as f:
                json.dump(fresh, f, indent=2)
            with open("BENCH_spmv.json", "w") as f:
                json.dump(baseline, f, indent=2)
            print("PERF REGRESSION vs committed BENCH_spmv.json baseline "
                  "(fresh numbers parked in BENCH_spmv.rejected.json):")
            for r in regs:
                print(f"  {r}")
            raise SystemExit(1)
        print("no perf regressions vs committed baseline "
              f"(threshold {REGRESSION_FACTOR}x)")

    print(f"\nall benchmarks done in {time.time() - t_start:.1f}s")


if __name__ == "__main__":
    main()
