"""BENCH_spmv.json: machine-readable perf trajectory of the distributed SpMV.

Measures, on a forced 8-device host platform (2 nodes x 4 ppn):

* ``plan_compile`` — wall time of plan compilation (split_all_blocks +
  compile_nap) on a 20k-row random matrix: the seed dict/per-element
  implementation (``benchmarks/_legacy_plan.py``, kept verbatim) vs the
  vectorised one, plus the cached-recompile time.  The acceptance bar is
  speedup >= 5x.
* ``spmv_wall`` — steady-state wall time per SpMV application for the
  standard (Alg. 1) executor and the NAP executor with COO (segment_sum)
  and fused Pallas BSR local compute, at nv in {1, 8}.  Pallas runs in
  interpret mode on CPU, so absolute numbers are NOT hardware numbers —
  they track relative regressions across PRs.
* ``modeled_bytes`` — padded vs effective bytes per phase (the quantity the
  paper's T/U balancing minimises) and plan-level message stats.

    PYTHONPATH=src python -m benchmarks.bench_spmv [--quick] [--out PATH]

Must run as its own process: it forces the device count before jax loads.
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse
import json
import time

import numpy as np


def _best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_plan_compile(n_rows: int, nnz_per_row: int) -> dict:
    from benchmarks._legacy_plan import legacy_compile_nap
    from repro.core.comm_graph import build_nap_plan
    from repro.core.partition import contiguous_partition
    from repro.core.spmv_jax import clear_compile_cache, compile_nap
    from repro.core.topology import Topology
    from repro.sparse import random_fixed_nnz

    topo = Topology(n_nodes=2, ppn=4)
    a = random_fixed_nnz(n_rows, nnz_per_row, seed=0)
    part = contiguous_partition(n_rows, topo.n_procs)
    # share one plan build: the comm-graph build was always vectorised, the
    # measured quantity is the *compile* step (split + gather maps + arrays)
    plan = build_nap_plan(a.indptr, a.indices, part, topo, pairing="aligned")

    t_legacy = _best_of(lambda: legacy_compile_nap(a, part, topo, plan=plan), 2)
    t_new = _best_of(lambda: compile_nap(a, part, topo, plan=plan), 3)
    clear_compile_cache()
    compile_nap(a, part, topo)                      # populate cache
    t_cached = _best_of(lambda: compile_nap(a, part, topo), 3)
    clear_compile_cache()
    return {
        "n_rows": n_rows, "nnz": a.nnz, "n_procs": topo.n_procs,
        "legacy_s": round(t_legacy, 4),
        "vectorized_s": round(t_new, 4),
        "cached_s": round(t_cached, 6),
        "speedup": round(t_legacy / t_new, 2),
    }


def bench_fused_emit(n_rows: int, nnz_per_row: int) -> dict:
    """One-off cost of materialising the fused Pallas BSR arrays (lazy;
    amortised by the compile cache across repeated SpMVs)."""
    from repro.core.partition import contiguous_partition
    from repro.core.spmv_jax import compile_nap
    from repro.core.topology import Topology
    from repro.sparse import random_fixed_nnz

    topo = Topology(n_nodes=2, ppn=4)
    a = random_fixed_nnz(n_rows, nnz_per_row, seed=0)
    part = contiguous_partition(n_rows, topo.n_procs)
    compiled = compile_nap(a, part, topo, cache=False)
    t0 = time.perf_counter()
    compiled.ensure_fused()
    t_emit = time.perf_counter() - t0
    return {"n_rows": n_rows, "nnz": a.nnz,
            "block_shape": list(compiled.block_shape),
            "emit_s": round(t_emit, 4),
            "blocks_mb": round(compiled.arrays["fused_blocks"].nbytes / 2**20, 1)}


def bench_spmv_wall(n_rows: int, nnz_per_row: int, quick: bool) -> dict:
    import jax
    from repro.compat import make_mesh
    from repro.core.comm_graph import build_standard_plan, nap_stats, standard_stats, build_nap_plan
    from repro.core.partition import contiguous_partition
    from repro.core.spmv_jax import (compile_nap, nap_spmv_shardmap,
                                     pack_vector, padded_traffic,
                                     standard_spmv_shardmap)
    from repro.core.topology import Topology
    from repro.sparse import random_fixed_nnz

    topo = Topology(n_nodes=2, ppn=4)
    mesh = make_mesh((topo.n_nodes, topo.ppn), ("node", "proc"))
    a = random_fixed_nnz(n_rows, nnz_per_row, seed=0)
    part = contiguous_partition(n_rows, topo.n_procs)
    compiled = compile_nap(a, part, topo, cache=False)
    rng = np.random.default_rng(0)

    iters = 3 if quick else 10
    walls = {}
    for nv in ((8,) if quick else (1, 8)):
        v = rng.standard_normal((n_rows, nv))
        shards = pack_vector(v, part, topo, compiled.rows_pad)
        paths = {
            "standard_bsr": standard_spmv_shardmap(a, part, topo, mesh,
                                                   local_compute="bsr")[0],
            "nap_coo": nap_spmv_shardmap(compiled, mesh, local_compute="coo"),
            "nap_fused_bsr": nap_spmv_shardmap(compiled, mesh,
                                               local_compute="bsr"),
        }
        for name, run in paths.items():
            out = run(shards)
            jax.block_until_ready(out)              # compile + warmup
            t0 = time.perf_counter()
            for _ in range(iters):
                out = run(shards)
            jax.block_until_ready(out)
            walls[f"{name}_nv{nv}_s"] = round(
                (time.perf_counter() - t0) / iters, 5)

    std_plan = build_standard_plan(a.indptr, a.indices, part, topo)
    nap_plan = compiled.plan or build_nap_plan(
        a.indptr, a.indices, part, topo, pairing="aligned")
    s, n = standard_stats(std_plan, 4), nap_stats(nap_plan, 4)
    modeled = {
        "standard_inter_bytes": s["inter"].total_bytes,
        "standard_intra_bytes": s["intra"].total_bytes,
        "nap_inter_bytes": n["inter"].total_bytes,
        "nap_intra_bytes": n["intra"].total_bytes,
        **padded_traffic(compiled),
    }
    return {"n_rows": n_rows, "nnz": a.nnz, "topo": [topo.n_nodes, topo.ppn],
            "interpret_mode": True, "iters": iters,
            "wall": walls, "modeled_bytes": modeled}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_spmv.json")
    args = ap.parse_args()

    t0 = time.time()
    result = {
        "bench": "spmv",
        "plan_compile": bench_plan_compile(
            4000 if args.quick else 20000, 12),
        "fused_emit": bench_fused_emit(1024 if args.quick else 2048, 8),
        "spmv_wall": bench_spmv_wall(1024 if args.quick else 2048, 8,
                                     args.quick),
    }
    result["total_s"] = round(time.time() - t0, 1)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    pc = result["plan_compile"]
    print(f"plan compile ({pc['n_rows']} rows, {pc['n_procs']} ranks): "
          f"legacy {pc['legacy_s']}s -> vectorized {pc['vectorized_s']}s "
          f"({pc['speedup']}x, cached {pc['cached_s']}s)")
    for k, v in result["spmv_wall"]["wall"].items():
        print(f"  {k}: {v}")
    print(f"wrote {args.out} in {result['total_s']}s")


if __name__ == "__main__":
    main()
