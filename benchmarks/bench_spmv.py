"""BENCH_spmv.json: machine-readable perf trajectory of the distributed SpMV.

Measures, on a forced 8-device host platform (2 nodes x 4 ppn):

* ``plan_compile`` — wall time of plan compilation (split_all_blocks +
  compile_nap) on a 20k-row random matrix: the seed dict/per-element
  implementation (``benchmarks/_legacy_plan.py``, kept verbatim) vs the
  vectorised one, plus the cached-recompile time.  ``speedup`` is THE
  claim source for any plan-compile speedup quoted in docs (ROADMAP /
  CHANGES quote this field, not a rounded slogan).
* ``local_emit`` — one-off cost + size of materialising each lazy local
  format (fused BSR tiles vs packed ELL) on the block-hostile matrix, and
  the autotuner's verdict.
* ``spmv_wall`` — steady-state wall time per SpMV application for the
  standard (Alg. 1) executor and the NAP executor across every local
  format (coo / ell / fused bsr) plus the autotuned "auto" path, at nv in
  {1, 8}.  Fairness: every variant gets the same explicit warmup
  iterations and ``jax.block_until_ready`` around every timed call.
  Pallas runs in interpret mode on CPU, so absolute numbers are NOT
  hardware numbers — they track relative regressions across PRs.
  Additionally ``operator_forward_nv*_s`` / ``operator_transpose_nv*_s``
  record the END-TO-END `repro.api` operator wall (pack -> SPMD run ->
  unpack, and the reversed-plan transpose), ``operator_rect_*`` the same
  for a RECTANGULAR [m, m/2] operator with independent row/col
  partitions, and ``galerkin_vcycle_s`` / ``galerkin_triple_product_s``
  a full AMG V-cycle whose every P/R is a rectangular shardmap operator
  plus the lazily composed ``(R @ A @ P) @ x`` chain — all share the
  wall dict, so benchmarks/run.py's >1.5x regression gate covers them
  like every other wall entry.
  The ``integrity_detect_overhead_s`` / ``integrity_recover_s`` walls
  time the same operator apply with wire checksums + ABFT verification
  armed, and with a scripted bitflip fired + recovered (detection plus
  the clean retry) — the overhead numbers the README threat model
  quotes, under the same regression gate.
* ``modeled_bytes`` — padded vs effective bytes per phase (the quantity
  the paper's T/U balancing minimises) and plan-level message stats.
* ``comm_autotune`` + the ``comm_multistep_forward_s`` /
  ``comm_autotune_hierarchy_s`` walls — the comm-strategy chooser on a
  skewed near-dense matrix: modeled injected inter-node bytes for
  nap vs nap-multistep (``comm_autotune.forward.reduction`` is THE
  claim source for any multi-step traffic number), the ``comm="auto"``
  resolution, and the per-level verdicts over a 3-level hierarchy whose
  coarse level leaves the nap path.  The walls share run.py's gate.
* ``rap_assemble`` + the ``spgemm_rap_*`` / ``hierarchy_assemble_*``
  walls — the distributed-SpGEMM Galerkin assembly: one fine-level RAP
  through host csr_matmul vs the float64 simulator vs the steady-state
  shard_map program, and the whole hierarchy setup host vs distributed.
  ``rap_assemble.speedup`` (distributed/host ratio) is THE claim source
  for any RAP-assembly number quoted in docs; the walls sit under
  run.py's 1.5x regression gate like every other entry.
* ``moe_dispatch`` — the MoE NAP-dispatch subsystem: measured
  pod-crossing bytes of the compiled shard_map island per dispatch mode
  and wire dtype (nap < flat and fp8 <= 0.55x f32 are asserted),
  plan-layer modeled inter-pod bytes, the executor f32 bit-identity
  flag, and island-apply walls under the same regression gate (keyed on
  the block's ``config``).

    PYTHONPATH=src python -m benchmarks.bench_spmv [--quick] [--out PATH]

Must run as its own process: it forces the device count before jax loads.
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse
import json
import time

import numpy as np

WARMUP_ITERS = 2


def _best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_plan_compile(n_rows: int, nnz_per_row: int) -> dict:
    from benchmarks._legacy_plan import legacy_compile_nap
    from repro.core.comm_graph import build_nap_plan
    from repro.core.partition import contiguous_partition
    from repro.core.spmv_jax import clear_compile_cache, compile_nap
    from repro.core.topology import Topology
    from repro.sparse import random_fixed_nnz

    topo = Topology(n_nodes=2, ppn=4)
    a = random_fixed_nnz(n_rows, nnz_per_row, seed=0)
    part = contiguous_partition(n_rows, topo.n_procs)
    # share one plan build: the comm-graph build was always vectorised, the
    # measured quantity is the *compile* step (split + gather maps + arrays)
    plan = build_nap_plan(a.indptr, a.indices, part, topo, pairing="aligned")

    t_legacy = _best_of(lambda: legacy_compile_nap(a, part, topo, plan=plan), 2)
    t_new = _best_of(lambda: compile_nap(a, part, topo, plan=plan), 5)
    clear_compile_cache()
    compile_nap(a, part, topo)                      # populate cache
    t_cached = _best_of(lambda: compile_nap(a, part, topo), 3)
    clear_compile_cache()
    speedup = round(t_legacy / t_new, 2)
    return {
        "n_rows": n_rows, "nnz": a.nnz, "n_procs": topo.n_procs,
        "legacy_s": round(t_legacy, 4),
        "vectorized_s": round(t_new, 4),
        "cached_s": round(t_cached, 6),
        "speedup": speedup,
        # the quotable claim, derived from the measured field above
        "speedup_claim": f"{speedup}x (BENCH_spmv.json plan_compile.speedup)",
    }


def bench_local_emit(n_rows: int, nnz_per_row: int) -> dict:
    """One-off cost + bytes of materialising each lazy local format, and
    what the autotuner chose (all lazy; the compile cache amortises the
    chosen format's emission across repeated SpMVs)."""
    from repro.core.partition import contiguous_partition
    from repro.core.spmv_jax import compile_nap
    from repro.core.topology import Topology
    from repro.sparse import random_fixed_nnz

    topo = Topology(n_nodes=2, ppn=4)
    a = random_fixed_nnz(n_rows, nnz_per_row, seed=0)
    part = contiguous_partition(n_rows, topo.n_procs)
    compiled = compile_nap(a, part, topo, cache=False)
    t0 = time.perf_counter()
    compiled.ensure_fused()
    t_bsr = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled.ensure_ell()
    t_ell = time.perf_counter() - t0
    ell_mb = (compiled.arrays["ell_cols"].nbytes
              + compiled.arrays["ell_vals"].nbytes) / 2**20
    chosen = compiled.chosen_local_compute
    auto_mb = {"bsr": round(compiled.arrays["fused_blocks"].nbytes / 2**20, 3),
               "ell": round(ell_mb, 3), "coo": 0.0}[chosen]
    return {"n_rows": n_rows, "nnz": a.nnz,
            "block_shape": list(compiled.block_shape),
            "bsr_emit_s": round(t_bsr, 4),
            "bsr_blocks_mb": round(compiled.arrays["fused_blocks"].nbytes / 2**20, 3),
            "ell_emit_s": round(t_ell, 4),
            "ell_mb": round(ell_mb, 3),
            "autotune_chosen": chosen,
            "auto_emitted_mb": auto_mb}


def bench_spmv_wall(n_rows: int, nnz_per_row: int, quick: bool) -> dict:
    import jax
    import repro.api as nap_api
    from repro.compat import make_mesh
    from repro.core.comm_graph import build_standard_plan, nap_stats, standard_stats, build_nap_plan
    from repro.core.partition import contiguous_partition
    from repro.core.spmv_jax import (compile_nap, compile_standard,
                                     nap_forward_shardmap, pack_vector,
                                     padded_traffic,
                                     standard_forward_shardmap)
    from repro.core.topology import Topology
    from repro.sparse import random_fixed_nnz

    topo = Topology(n_nodes=2, ppn=4)
    mesh = make_mesh((topo.n_nodes, topo.ppn), ("node", "proc"))
    a = random_fixed_nnz(n_rows, nnz_per_row, seed=0)
    part = contiguous_partition(n_rows, topo.n_procs)
    compiled = compile_nap(a, part, topo, cache=False)
    compiled_std = compile_standard(a, part, topo, cache=False)
    rng = np.random.default_rng(0)

    def timed(fn, *args):
        # fairness: identical explicit warmup + a block_until_ready
        # fence around every timed application for every variant;
        # best-of-iters so shared-CPU load spikes don't masquerade as
        # regressions under run.py's 1.5x gate
        for _ in range(WARMUP_ITERS):
            jax.block_until_ready(fn(*args))
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best = min(best, time.perf_counter() - t0)
        return best

    iters = 3 if quick else 10
    walls = {}
    auto_vs_best = {}
    # one operator reused across nv (jit retraces per shape; the plan
    # compile + format emission happen once, like the shard-level paths)
    op = nap_api.operator(a, part=part, topo=topo, method="nap",
                          backend="shardmap", mesh=mesh, cache=False)
    for nv in ((8,) if quick else (1, 8)):
        v = rng.standard_normal((n_rows, nv))
        shards = pack_vector(v, part, topo, compiled.rows_pad)
        run_auto = nap_forward_shardmap(compiled, mesh, local_compute="auto")
        # auto is timed adjacent to the cheap fixed formats it resolves
        # against, not in the heap-churn shadow of the 11 MB BSR variant
        paths = {
            "standard_bsr": standard_forward_shardmap(compiled_std, mesh,
                                                      local_compute="bsr"),
            "nap_coo": nap_forward_shardmap(compiled, mesh, local_compute="coo"),
            "nap_ell": nap_forward_shardmap(compiled, mesh, local_compute="ell"),
            "nap_auto": run_auto,
            "nap_fused_bsr": nap_forward_shardmap(compiled, mesh,
                                                  local_compute="bsr"),
        }
        for name, run in paths.items():
            walls[f"{name}_nv{nv}_s"] = round(timed(run, shards), 5)
        best_fixed = min(walls[f"nap_{f}_nv{nv}_s"]
                         for f in ("coo", "ell", "fused_bsr"))
        auto_vs_best[f"nv{nv}"] = round(
            walls[f"nap_auto_nv{nv}_s"] / best_fixed, 3)

        # operator-level end-to-end walls (pack -> run -> unpack), forward
        # and reversed-plan transpose, through the repro.api front-end
        walls[f"operator_forward_nv{nv}_s"] = round(
            timed(lambda: op @ v), 5)
        walls[f"operator_transpose_nv{nv}_s"] = round(
            timed(lambda: op.T @ v), 5)

    # -- integrity walls ----------------------------------------------------
    # integrity_detect_overhead_s: the same end-to-end operator apply
    # with the wire checksums + ABFT verification armed ("detect") — the
    # relative overhead vs operator_forward_nv1_s is the number the
    # README threat-model section quotes.  integrity_recover_s: one
    # apply with a scripted inter-phase bitflip fired, so the wall
    # includes detection + the clean retry from the retained packed
    # shards.  Both sit in the shared wall dict under run.py's 1.5x
    # gate; the integrity="off" program is unchanged (it IS the
    # operator_forward walls above).
    v1 = rng.standard_normal(n_rows)
    op_det = nap_api.operator(a, part=part, topo=topo, method="nap",
                              backend="shardmap", mesh=mesh, cache=False,
                              integrity="detect")
    walls["integrity_detect_overhead_s"] = round(
        timed(lambda: op_det @ v1), 5)
    op_rec = nap_api.operator(a, part=part, topo=topo, method="nap",
                              backend="shardmap", mesh=mesh, cache=False,
                              integrity="recover")

    def recover_apply():
        op_rec.inject_fault("inter", "bitflip", node=1, proc=0, slot=0,
                            element=1, bit=20)
        return op_rec @ v1

    walls["integrity_recover_s"] = round(timed(recover_apply), 5)
    assert op_rec.integrity_report()["recovered"] > 0

    # -- rectangular operator walls (independent row/col partitions) -------
    # forward packs by the column partition, transpose by the row
    # partition; the transpose runs the autotuned ell/coo transposed
    # local compute.  Shares the regression gate with every other wall.
    from repro.core.partition import contiguous_partition
    from repro.sparse import CSR
    m_r, n_r = n_rows, n_rows // 2
    rng_r = np.random.default_rng(1)
    rows_r = np.repeat(np.arange(m_r), nnz_per_row)
    a_rect = CSR.from_coo(rows_r,
                          rng_r.integers(0, n_r, size=rows_r.size),
                          rng_r.standard_normal(rows_r.size), (m_r, n_r))
    op_rect = nap_api.operator(a_rect, topo=topo, mesh=mesh,
                               row_part=contiguous_partition(m_r, topo.n_procs),
                               col_part=contiguous_partition(n_r, topo.n_procs),
                               backend="shardmap", cache=False)
    v_r = rng.standard_normal(n_r)
    u_r = rng.standard_normal(m_r)
    walls["operator_rect_forward_nv1_s"] = round(timed(lambda: op_rect @ v_r), 5)
    walls["operator_rect_transpose_nv1_s"] = round(
        timed(lambda: op_rect.T @ u_r), 5)

    # -- distributed AMG: composed Galerkin + full V-cycle ------------------
    # every restriction/prolongation is a rectangular shardmap operator
    # (restriction through the node-aware transpose executor); the lazy
    # (R @ A @ P) chain is timed separately.
    from repro.amg import (amg_vcycle, level_operators,
                           smoothed_aggregation_hierarchy)
    from repro.sparse import rotated_anisotropic_2d
    a_amg = rotated_anisotropic_2d(16 if quick else 24, eps=0.1)
    levels = smoothed_aggregation_hierarchy(a_amg, theta=0.1, coarse_size=32)
    ops = level_operators(levels, topo, backend="shardmap", mesh=mesh)
    b_amg = rng.standard_normal(a_amg.shape[0])
    walls["galerkin_vcycle_s"] = round(
        timed(lambda: amg_vcycle(levels, b_amg, operators=ops)), 5)
    gal = ops[0].galerkin()
    if gal is not None:
        xc = rng.standard_normal(gal.shape[1])
        walls["galerkin_triple_product_s"] = round(timed(lambda: gal @ xc), 5)

    # -- distributed SpGEMM: RAP + hierarchy assembly walls -----------------
    # spgemm_rap_* times ONE Galerkin triple product A_c = R (A P) on the
    # fine level: host csr_matmul, the float64 message-passing simulator,
    # and the steady-state shard_map program (compile + trace cached, so
    # the wall is pack -> 2x SPMD product -> unpack); hierarchy_assemble_*
    # times the WHOLE setup (every level's RAP) host vs distributed.  All
    # share run.py's 1.5x regression gate; rap_assemble.speedup (the
    # distributed-vs-host ratio on the shardmap path) is the claim source
    # for any RAP-assembly number quoted in docs.
    from repro.amg.matmul import csr_matmul
    from repro.spgemm import distributed_rap, galerkin_rap
    lvl0 = levels[0]
    fine = contiguous_partition(lvl0.a.shape[0], topo.n_procs)
    coarse = contiguous_partition(lvl0.p.shape[1], topo.n_procs)
    walls["spgemm_rap_host_s"] = round(timed(
        lambda: csr_matmul(lvl0.r, csr_matmul(lvl0.a, lvl0.p))), 5)
    walls["spgemm_rap_simulate_s"] = round(timed(
        lambda: galerkin_rap(lvl0.r, lvl0.a, lvl0.p, fine, coarse, topo,
                             backend="simulate")), 5)
    walls["spgemm_rap_shardmap_s"] = round(timed(
        lambda: galerkin_rap(lvl0.r, lvl0.a, lvl0.p, fine, coarse, topo,
                             backend="shardmap", mesh=mesh)), 5)
    theta_amg, cs_amg = 0.1, 32
    walls["hierarchy_assemble_host_s"] = round(timed(
        lambda: smoothed_aggregation_hierarchy(a_amg, theta=theta_amg,
                                               coarse_size=cs_amg)), 5)
    dist_rap = distributed_rap(topo, backend="simulate")
    walls["hierarchy_assemble_distributed_s"] = round(timed(
        lambda: smoothed_aggregation_hierarchy(a_amg, theta=theta_amg,
                                               coarse_size=cs_amg,
                                               rap=dist_rap)), 5)
    rap_assemble = {
        "n_fine_rows": lvl0.a.shape[0],
        "host_s": walls["spgemm_rap_host_s"],
        "simulate_s": walls["spgemm_rap_simulate_s"],
        "shardmap_s": walls["spgemm_rap_shardmap_s"],
        "speedup": round(walls["spgemm_rap_host_s"]
                         / walls["spgemm_rap_shardmap_s"], 3),
        "note": "distributed (steady-state shard_map, interpret-mode CPU) "
                "vs host csr_matmul wall for one fine-level RAP; quote "
                "rap_assemble.speedup, not a rounded slogan",
    }

    # -- solver-service walls ----------------------------------------------
    # serve_submit_p50_s: median submit -> done wall for a 1-RHS SpMV
    # request through the full service path (admission, EDF batching, plan
    # cache, accounting) on the simulate backend — the service overhead
    # number, dominated by the oracle SpMV itself.  serve_recover_rebuild_s:
    # the elastic-recovery wall after a scripted node death (survivor
    # repartition + plan-cache rebuild + eager recompile + checkpoint
    # probe), as measured by the service's own stats.  Both sit in the
    # shared wall dict, so run.py's 1.5x gate covers them.
    from repro.serve import FaultPlan, SolverService, dead_node
    svc = SolverService(topo, backend="simulate", queue_limit=64)
    svc.register_matrix("A", a)
    submit_walls = []
    for i in range(3 if quick else 9):
        b_req = rng.standard_normal(n_rows)
        t0 = time.perf_counter()
        t = svc.submit("bench", "A", b_req, kind="spmv")
        svc.run()
        submit_walls.append(time.perf_counter() - t0)
        assert t.status == "done"
    walls["serve_submit_p50_s"] = round(
        float(np.median(submit_walls)), 5)
    svc_f = SolverService(topo, backend="simulate",
                          fault_plan=FaultPlan.of(dead_node(1, "node1")),
                          heartbeat_timeout=2.5)
    svc_f.register_matrix("A", a)
    t = svc_f.submit("bench", "A", rng.standard_normal(n_rows), kind="spmv")
    svc_f.run(max_steps=40)
    assert t.status == "done" and svc_f.stats["recoveries"] == 1
    walls["serve_recover_rebuild_s"] = round(
        svc_f.stats["last_recover_rebuild_s"], 5)

    std_plan = build_standard_plan(a.indptr, a.indices, part, topo)
    nap_plan = compiled.plan or build_nap_plan(
        a.indptr, a.indices, part, topo, pairing="aligned")
    s, n = standard_stats(std_plan, 4), nap_stats(nap_plan, 4)
    modeled = {
        "standard_inter_bytes": s["inter"].total_bytes,
        "standard_intra_bytes": s["intra"].total_bytes,
        "nap_inter_bytes": n["inter"].total_bytes,
        "nap_intra_bytes": n["intra"].total_bytes,
        **padded_traffic(compiled),
    }
    at = compiled.autotune
    autotune = {
        "chosen": at["chosen"],
        "modeled_times_s": {k: float(f"{v:.3e}") for k, v in at["times"].items()},
        "per_rank_choice": [e["choice"] for e in at["per_rank"]],
        "per_rank_bsr_fill": [round(e["bsr_fill"], 5) for e in at["per_rank"]],
        "per_rank_ell_kmax": [e["ell_kmax"] for e in at["per_rank"]],
        "auto_vs_best_fixed": auto_vs_best,
    }
    return {"n_rows": n_rows, "nnz": a.nnz, "topo": [topo.n_nodes, topo.ppn],
            "interpret_mode": True, "iters": iters, "warmup": WARMUP_ITERS,
            "timing": "best_of_iters",
            "wall": walls, "autotune": autotune, "modeled_bytes": modeled,
            "rap_assemble": rap_assemble}


def _skewed_matrix(topo, rows_per_rank: int, bulk: int, seed: int = 0):
    """Near-dense coarse-level structure: shared d=ppn background columns
    plus a d=1 bulk pulled by one node only — the pattern where peeling
    low-duplication columns out of the aggregated inter exchange shrinks
    the pad every inter message pays (see src/repro/comm/README.md)."""
    from repro.core.partition import contiguous_partition
    from repro.sparse import CSR

    n = rows_per_rank * topo.n_procs
    part = contiguous_partition(n, topo.n_procs)
    rng = np.random.default_rng(seed)
    rows = [[] for _ in range(n)]
    lo = lambda r: r * rows_per_rank
    for r in range(topo.n_procs):
        node, lr = topo.node_of(r), topo.local_of(r)
        remote = [q for q in range(topo.n_procs) if topo.node_of(q) != node]
        base = lo(r)
        for i in range(rows_per_rank):
            rows[base + i].append(base + i)
        for src in remote:
            for i in range(rows_per_rank):
                rows[base + i].append(lo(src))
        if node == 0:
            src = remote[lr]
            for k in range(bulk):
                gi = base + int(rng.integers(rows_per_rank))
                rows[gi].append(lo(src) + 1 + k)
    indptr = [0]
    indices = []
    for rr in rows:
        cols = sorted(set(rr))
        indices.extend(cols)
        indptr.append(len(indices))
    data = rng.standard_normal(len(indices))
    return CSR(np.array(indptr, np.int64), np.array(indices, np.int64),
               data, (n, n)), part


def bench_comm_autotune(quick: bool) -> dict:
    """Comm-strategy walls + the machine-readable ``comm_autotune`` block.

    ``comm_multistep_forward_s``: steady-state end-to-end operator apply
    through the five-phase multi-step shardmap program on the skewed
    near-dense matrix.  ``comm_autotune_hierarchy_s``: building the
    3-level operator stack with ``comm="auto"`` — one candidate-plan
    build + per-direction verdict per level operator.  Both walls merge
    into the shared ``spmv_wall.wall`` dict, so run.py's 1.5x gate
    covers them.  The block quotes the chooser's verdict on the skewed
    matrix (nap vs multistep modeled injected inter-node bytes and the
    reduction — THE claim source for any multi-step traffic number in
    docs) plus the per-level resolutions over the hierarchy.
    """
    import jax
    import repro.api as nap_api
    from repro.amg import Level, level_operators
    from repro.comm import choose_comm
    from repro.compat import make_mesh
    from repro.core.topology import Topology
    from repro.sparse import random_fixed_nnz

    topo = Topology(n_nodes=2, ppn=4)
    mesh = make_mesh((topo.n_nodes, topo.ppn), ("node", "proc"))
    rows_per_rank = 32 if quick else 64
    a, part = _skewed_matrix(topo, rows_per_rank, bulk=3 * rows_per_rank // 4)
    n2 = a.shape[0]
    iters = 3 if quick else 10
    rng = np.random.default_rng(0)
    walls = {}

    def timed(fn):
        for _ in range(WARMUP_ITERS):
            jax.block_until_ready(fn())
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best

    op_ms = nap_api.operator(a, part=part, topo=topo, backend="shardmap",
                             mesh=mesh, cache=False, comm="multistep")
    v = rng.standard_normal(n2)
    walls["comm_multistep_forward_s"] = round(timed(lambda: op_ms @ v), 5)

    # chooser verdict on the skewed matrix (what comm="auto" sees)
    verdict = choose_comm(a.indptr, a.indices, part, topo)
    op_auto = nap_api.operator(a, part=part, topo=topo, backend="simulate",
                               comm="auto")

    def quote(d):
        c = verdict[d]["candidates"]
        nap_b = c["nap"]["injected_inter_bytes"]
        ms_b = c["multistep"]["injected_inter_bytes"]
        return {
            "chosen": verdict[d]["chosen"],
            "nap_injected_inter_bytes": nap_b,
            "multistep_injected_inter_bytes": ms_b,
            "reduction": round(1.0 - ms_b / nap_b, 3) if nap_b else 0.0,
            "standard_injected_inter_bytes":
                c["standard"]["injected_inter_bytes"],
        }

    # 3-level hierarchy: uniform fine/mid, skewed near-dense coarse
    from repro.sparse import CSR
    n1, n0 = n2 * 2, n2 * 4
    fine_a = random_fixed_nnz(n0, 4, seed=13)
    mid_a = random_fixed_nnz(n1, 6, seed=14)

    def injection_p(nf, nc):
        k = nf // nc
        indptr = np.arange(nf + 1, dtype=np.int64)
        indices = (np.arange(nf) // k).astype(np.int64)
        return CSR(indptr, indices, np.ones(nf), (nf, nc))

    levels = [Level(a=fine_a, p=injection_p(n0, n1)),
              Level(a=mid_a, p=injection_p(n1, n2)),
              Level(a=a)]
    walls["comm_autotune_hierarchy_s"] = round(timed(
        lambda: level_operators(levels, topo, backend="simulate",
                                comm="auto")), 5)
    ops = level_operators(levels, topo, backend="simulate", comm="auto")
    per_level = []
    for i, entry in enumerate(ops):
        rep = entry.a.autotune_report()["comm"]
        row = {"level": i, "n_rows": levels[i].a.shape[0],
               "a_forward": rep["resolved"],
               "a_transpose": rep["transpose_resolved"]}
        if entry.p is not None:
            prep = entry.p.autotune_report()["comm"]
            row["p_forward"] = prep["resolved"]
            row["p_transpose"] = prep["transpose_resolved"]
        per_level.append(row)

    block = {
        "n_rows": n2,
        "topo": [topo.n_nodes, topo.ppn],
        "threshold": verdict["threshold"],
        "forward": quote("forward"),
        "transpose": quote("transpose"),
        "auto_resolved": op_auto.method,
        "per_level": per_level,
        "note": "modeled injected inter-node bytes (slot-granular, pad-"
                "charged) on the skewed near-dense matrix; quote "
                "comm_autotune.forward.reduction, not a rounded slogan",
    }
    return {"wall": walls, "comm_autotune": block}


def bench_moe_dispatch(quick: bool) -> dict:
    """The MoE NAP-dispatch block: measured + modeled traffic and walls.

    ``measured_dci_bytes``: pod-crossing bytes of the compiled shard_map
    island (``analyze_hlo`` with ``pod_boundary=4`` on the 2x4 mesh) for
    flat/f32, nap/f32 and the quantized nap wires — the claim source for
    the dispatch traffic numbers in docs (nap < flat, fp8 <= 0.55x f32
    are ASSERTED here, so a regression breaks the bench).
    ``modeled_inter_bytes``: the plan layer's slot-granular injected
    inter-pod bytes for the same geometry.  ``walls``: steady-state
    island applies per mode/wire, gated by run.py's 1.5x rule whenever
    the ``config`` matches the committed baseline.
    """
    import jax
    import jax.numpy as jnp
    import repro.api as nap_api
    from repro.compat import make_mesh, set_mesh
    from repro.configs import get_reduced
    from repro.core.hlo_analysis import analyze_hlo
    from repro.models.moe import EPInfo, moe_apply_sharded, moe_init
    from repro.moe.dispatch import topology_of_mesh
    from repro.moe.plan import (dispatch_partitions, dispatch_traffic,
                                build_dispatch_plans, representative_routing,
                                routing_matrix)
    from repro.moe.wire import wire_bytes

    d = 32 if quick else 64
    cfg0 = get_reduced("qwen3-moe-235b-a22b").replace(
        n_experts=8, top_k=4, moe_dff=d, d_model=d, capacity_factor=8.0)
    mesh = make_mesh((2, 4), ("pod", "model"))
    ep = EPInfo(inner_axis="model", pod_axis="pod")
    params = moe_init(jax.random.key(0), cfg0, jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 16, d)) * 0.3, jnp.float32)
    iters = 3 if quick else 10

    walls, measured = {}, {}
    with set_mesh(mesh):
        for mode, wd in (("flat", "f32"), ("nap", "f32"),
                         ("nap", "bf16"), ("nap", "fp8_e4m3")):
            cfg = cfg0.replace(moe_dispatch=mode, wire_dtype=wd)
            fn = jax.jit(lambda p, xx, c=cfg: moe_apply_sharded(p, c, xx,
                                                                ep, mesh))
            compiled = fn.lower(params, x).compile()
            # pod_boundary=4: devices 0-3 are pod 0, 4-7 pod 1
            measured[f"{mode}_{wd}"] = analyze_hlo(
                compiled.as_text(), pod_boundary=4).dci_bytes
            for _ in range(WARMUP_ITERS):
                jax.block_until_ready(fn(params, x))
            best = float("inf")
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(params, x))
                best = min(best, time.perf_counter() - t0)
            walls[f"island_{mode}_{wd}_s"] = round(best, 5)

    # the acceptance inequalities are load-bearing: fail the bench loudly
    # rather than record a payload that contradicts the claims
    assert measured["nap_f32"] < measured["flat_f32"], measured
    assert measured["nap_fp8_e4m3"] <= 0.55 * measured["nap_f32"], measured

    # plan-layer modeled traffic for the same geometry (per token batch:
    # nv = d_model values per routed copy)
    topo = topology_of_mesh(mesh)
    T, E = 64, cfg0.n_experts
    ids, w = representative_routing(T, E, cfg0.top_k, seed=0)
    r = routing_matrix(ids, w, E)
    ep_, tp_ = dispatch_partitions(E, T, topo)
    plans = build_dispatch_plans(r, ep_, tp_, topo)
    modeled = {}
    for name, wd in (("flat_f32", "f32"), ("nap_f32", "f32"),
                     ("nap_fp8_e4m3", "fp8_e4m3")):
        plan = plans[name.split("_", 1)[0]]
        modeled[name] = dispatch_traffic(plan, wire_dtype=wd,
                                         nv=d)["injected_inter_bytes"]
    assert modeled["nap_f32"] < modeled["flat_f32"], modeled
    assert modeled["nap_fp8_e4m3"] * 4 == modeled["nap_f32"], modeled

    # executor f32 path must be bitwise the simulate oracle
    xv = rng.standard_normal((T, d))
    sim = nap_api.operator(r, topo=topo, row_part=ep_, col_part=tp_,
                           backend="simulate", method="nap")
    moe = nap_api.operator(r, topo=topo, row_part=ep_, col_part=tp_,
                           backend="moe", method="nap")
    f32_bit_identical = bool(
        np.array_equal(moe @ xv, sim @ xv)
        and np.array_equal(moe.T @ (sim @ xv), sim.T @ (sim @ xv)))
    assert f32_bit_identical

    return {
        "config": {"n_experts": E, "top_k": cfg0.top_k, "d_model": d,
                   "capacity_factor": cfg0.capacity_factor,
                   "mesh": [2, 4], "n_tokens_modeled": T},
        "measured_dci_bytes": measured,
        "dci_reduction_nap_vs_flat": round(
            measured["flat_f32"] / measured["nap_f32"], 2),
        "fp8_vs_f32_wire_ratio": round(
            measured["nap_fp8_e4m3"] / measured["nap_f32"], 3),
        "modeled_inter_bytes": modeled,
        "wire_bytes_per_val": {wd: wire_bytes(wd)
                               for wd in ("f32", "bf16", "fp8_e4m3")},
        "f32_bit_identical": f32_bit_identical,
        "walls": walls,
        "note": "measured_dci_bytes comes from analyze_hlo(pod_boundary=4) "
                "over the compiled island; quote dci_reduction_nap_vs_flat "
                "and fp8_vs_f32_wire_ratio, not rounded slogans",
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_spmv.json")
    args = ap.parse_args()

    t0 = time.time()
    result = {
        "bench": "spmv",
        "plan_compile": bench_plan_compile(
            4000 if args.quick else 20000, 12),
        "local_emit": bench_local_emit(1024 if args.quick else 2048, 8),
        "spmv_wall": bench_spmv_wall(1024 if args.quick else 2048, 8,
                                     args.quick),
    }
    # hoist the RAP-assembly claim source next to plan_compile
    result["rap_assemble"] = result["spmv_wall"].pop("rap_assemble")
    # comm-strategy walls ride the shared wall dict (run.py 1.5x gate);
    # the chooser verdict is hoisted like rap_assemble
    comm = bench_comm_autotune(args.quick)
    result["spmv_wall"]["wall"].update(comm["wall"])
    result["comm_autotune"] = comm["comm_autotune"]
    # MoE dispatch block: own walls subdict (gated by run.py whenever the
    # committed baseline's config matches), measured + modeled traffic
    result["moe_dispatch"] = bench_moe_dispatch(args.quick)
    result["total_s"] = round(time.time() - t0, 1)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    pc = result["plan_compile"]
    print(f"plan compile ({pc['n_rows']} rows, {pc['n_procs']} ranks): "
          f"legacy {pc['legacy_s']}s -> vectorized {pc['vectorized_s']}s "
          f"({pc['speedup']}x, cached {pc['cached_s']}s)")
    at = result["spmv_wall"]["autotune"]
    print(f"autotune: chose {at['chosen']} "
          f"(auto/best {at['auto_vs_best_fixed']}), "
          f"emitted {result['local_emit']['auto_emitted_mb']} MB")
    ra = result["rap_assemble"]
    print(f"rap assemble ({ra['n_fine_rows']} fine rows): host {ra['host_s']}s, "
          f"simulate {ra['simulate_s']}s, shardmap {ra['shardmap_s']}s "
          f"(speedup {ra['speedup']}x)")
    ca = result["comm_autotune"]
    print(f"comm autotune ({ca['n_rows']} rows): forward chose "
          f"{ca['forward']['chosen']} "
          f"(nap {ca['forward']['nap_injected_inter_bytes']} B -> multistep "
          f"{ca['forward']['multistep_injected_inter_bytes']} B, "
          f"reduction {ca['forward']['reduction']}); per-level "
          f"{[r['a_forward'] for r in ca['per_level']]}")
    md = result["moe_dispatch"]
    print(f"moe dispatch (E={md['config']['n_experts']} "
          f"top_k={md['config']['top_k']} on 2x4): measured DCI "
          f"flat {md['measured_dci_bytes']['flat_f32']:.0f} B -> "
          f"nap {md['measured_dci_bytes']['nap_f32']:.0f} B "
          f"({md['dci_reduction_nap_vs_flat']}x), fp8 wire "
          f"{md['fp8_vs_f32_wire_ratio']}x of f32, "
          f"f32_bit_identical={md['f32_bit_identical']}")
    for k, v in result["spmv_wall"]["wall"].items():
        print(f"  {k}: {v}")
    for k, v in md["walls"].items():
        print(f"  moe_dispatch.{k}: {v}")
    print(f"wrote {args.out} in {result['total_s']}s")


if __name__ == "__main__":
    main()
