"""Figs. 8, 9, 10: per-AMG-level message counts/sizes and SpMV times.

Builds smoothed-aggregation hierarchies for the rotated anisotropic and
linear elasticity problems, then measures — per level — the max inter- and
intra-node message count/volume of a single process (Figs. 8/9) and the
modeled standard vs NAP SpMV time (Fig. 10).  Coarse levels are the paper's
high-message-count regime where NAP wins most.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Table, default_topology, message_stats, spmv_times
from repro.amg import smoothed_aggregation_hierarchy
from repro.configs.paper_spmv import CONFIG
from repro.core.partition import contiguous_partition
from repro.sparse import linear_elasticity_2d, rotated_anisotropic_2d


def _problem(name: str):
    if name == "anisotropic":
        a = rotated_anisotropic_2d(CONFIG.anisotropic_grid, eps=0.001,
                                   theta=np.pi / 6)
        ns = np.ones((a.shape[0], 1))
        return a, ns, 0.1
    n = CONFIG.elasticity_grid
    a = linear_elasticity_2d(n)
    xy = np.stack(np.meshgrid(np.arange(n), np.arange(n), indexing="ij"),
                  -1).reshape(-1, 2).astype(float)
    ns = np.zeros((a.shape[0], 3))
    ns[0::2, 0] = 1.0
    ns[1::2, 1] = 1.0
    ns[0::2, 2] = -xy[:, 1]
    ns[1::2, 2] = xy[:, 0]
    return a, ns, 0.05


def run(problem: str = "elasticity"):
    topo = default_topology()
    a, ns, theta = _problem(problem)
    levels = smoothed_aggregation_hierarchy(a, nullspace=ns, theta=theta,
                                            coarse_size=2 * topo.n_procs)
    t8 = Table(f"Fig 8 — max INTER-node msgs per process, {problem} AMG",
               ["level", "rows", "nnz", "std #msg", "nap #msg",
                "std bytes", "nap bytes"])
    t9 = Table(f"Fig 9 — max INTRA-node msgs per process, {problem} AMG",
               ["level", "std #msg", "nap #msg", "std bytes", "nap bytes"])
    t10 = Table(f"Fig 10 — modeled SpMV time per level, {problem} AMG",
                ["level", "standard (s)", "nap (s)", "speedup"])
    for lvl, level in enumerate(levels):
        al = level.a
        if al.shape[0] < topo.n_procs:
            break
        part = contiguous_partition(al.shape[0], topo.n_procs)
        ms = message_stats(al, part, topo)
        t8.add(lvl, al.shape[0], al.nnz,
               ms["standard"]["inter"].max_msgs, ms["nap"]["inter"].max_msgs,
               ms["standard"]["inter"].max_bytes, ms["nap"]["inter"].max_bytes)
        t9.add(lvl, ms["standard"]["intra"].max_msgs,
               ms["nap"]["intra"].max_msgs,
               ms["standard"]["intra"].max_bytes, ms["nap"]["intra"].max_bytes)
        times = spmv_times(al, part, topo)
        t10.add(lvl, times["standard"], times["nap"], times["speedup"])
    return t8, t9, t10


if __name__ == "__main__":
    for prob in ("anisotropic", "elasticity"):
        for t in run(prob):
            print(t.render())
            print()
