"""NAPSpMV applied to Mixture-of-Experts dispatch (the paper -> LMs bridge).

Runs the SAME MoE layer through its three dispatch modes on a simulated
2-pod x 4-chip mesh and shows:
  * all three agree numerically (vs the dense-masked oracle), and
  * the NAP (3-step, pod-deduplicated) dispatch injects FEWER bytes across
    the inter-pod boundary than the flat all-to-all — the paper's E(n, m)
    dedup, applied to tokens routed to multiple experts on one remote pod.

    PYTHONPATH=src python examples/moe_nap_dispatch.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import make_mesh, set_mesh
from repro.configs import get_reduced
from repro.core.hlo_analysis import analyze_hlo
from repro.models.moe import EPInfo, moe_apply_local, moe_apply_sharded, moe_init


def main() -> None:
    cfg = get_reduced("qwen3-moe-235b-a22b").replace(
        n_experts=8, top_k=4, moe_dff=64, d_model=64, capacity_factor=8.0)
    mesh = make_mesh((2, 4), ("pod", "model"))
    params = moe_init(jax.random.key(0), cfg, jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 16, cfg.d_model)) * 0.3,
                    jnp.float32)

    want = moe_apply_local(params, cfg, x)

    results = {}
    for mode in ("flat", "nap"):
        mcfg = cfg.replace(moe_dispatch=mode)
        ep = EPInfo(inner_axis="model", pod_axis="pod")
        fn = jax.jit(lambda p, xx: moe_apply_sharded(p, mcfg, xx, ep, mesh))
        with set_mesh(mesh):
            lowered = fn.lower(params, x)
            compiled = lowered.compile()
            got = np.asarray(fn(params, x))
        # pod_boundary=4: devices 0-3 are pod 0, 4-7 pod 1 on the (2,4) mesh
        cost = analyze_hlo(compiled.as_text(), pod_boundary=4)
        results[mode] = (cost.dci_bytes, cost.total_collective_bytes)
        err = np.abs(got - np.asarray(want)).max() / np.abs(np.asarray(want)).max()
        print(f"{mode:4s} dispatch: max rel err vs dense oracle = {err:.2e}, "
              f"pod-crossing (DCI) bytes = {cost.dci_bytes:,.0f}, "
              f"total = {cost.total_collective_bytes:,.0f}")
        assert err < 1e-4, f"{mode} dispatch diverged from the oracle"

    (flat_dci, flat_tot), (nap_dci, nap_tot) = results["flat"], results["nap"]
    print(f"\nEXPENSIVE-axis (inter-pod) bytes: flat {flat_dci:,.0f} -> "
          f"nap {nap_dci:,.0f}  ({flat_dci / max(nap_dci, 1):.2f}x less)")
    print(f"cheap intra-pod bytes grow: {flat_tot - flat_dci:,.0f} -> "
          f"{nap_tot - nap_dci:,.0f} — the paper's Figs. 8-vs-9 trade.")
    assert nap_dci < flat_dci, "NAP must reduce pod-crossing traffic"


if __name__ == "__main__":
    main()
