"""NAPSpMV applied to Mixture-of-Experts dispatch (the paper -> LMs bridge).

Exercises the first-class MoE dispatch subsystem (:mod:`repro.moe`) on a
simulated 2-pod x 4-chip mesh, from both of its faces:

1. **In-graph** (the training/serving path): the SAME MoE layer through
   its dispatch modes and wire dtypes via ``moe_apply_sharded``, showing
   * all modes agree numerically with the dense-masked oracle,
   * the NAP (3-step, pod-deduplicated) dispatch injects FEWER bytes
     across the inter-pod boundary than the flat all-to-all — the
     paper's E(n, m) dedup, applied to tokens routed to multiple
     experts on one remote pod, measured from the compiled HLO, and
   * quantized wire payloads (``wire_dtype="bf16" | "fp8_e4m3"``) cut
     the measured pod-crossing bytes again while staying inside the
     modeled error budget.
2. **Registered-operator** (the plan/analysis path):
   ``dispatch_operator`` compiles a concrete routing into the NAP plan
   machinery — per-direction flat-vs-nap verdicts and slot-granular
   quantized byte accounting, no devices required.

    PYTHONPATH=src python examples/moe_nap_dispatch.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax
import jax.numpy as jnp

from repro.compat import make_mesh, set_mesh
from repro.configs import get_reduced
from repro.core.hlo_analysis import analyze_hlo
from repro.models.moe import EPInfo, moe_apply_local, moe_apply_sharded, moe_init
from repro.moe import wire_error_bound
from repro.moe.dispatch import dispatch_operator


def main() -> None:
    cfg = get_reduced("qwen3-moe-235b-a22b").replace(
        n_experts=8, top_k=4, moe_dff=64, d_model=64, capacity_factor=8.0)
    mesh = make_mesh((2, 4), ("pod", "model"))
    params = moe_init(jax.random.key(0), cfg, jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 16, cfg.d_model)) * 0.3,
                    jnp.float32)

    want = moe_apply_local(params, cfg, x)
    ep = EPInfo(inner_axis="model", pod_axis="pod")

    def run(mcfg):
        fn = jax.jit(lambda p, xx: moe_apply_sharded(p, mcfg, xx, ep, mesh))
        with set_mesh(mesh):
            compiled = fn.lower(params, x).compile()
            got = np.asarray(fn(params, x))
        # pod_boundary=4: devices 0-3 are pod 0, 4-7 pod 1 on the (2,4) mesh
        return got, analyze_hlo(compiled.as_text(), pod_boundary=4)

    # ---- in-graph: flat vs nap at f32 (measured from the compiled HLO) ----
    results = {}
    for mode in ("flat", "nap"):
        got, cost = run(cfg.replace(moe_dispatch=mode))
        results[mode] = (cost.dci_bytes, cost.total_collective_bytes, got)
        err = np.abs(got - np.asarray(want)).max() / np.abs(np.asarray(want)).max()
        print(f"{mode:4s} dispatch: max rel err vs dense oracle = {err:.2e}, "
              f"pod-crossing (DCI) bytes = {cost.dci_bytes:,.0f}, "
              f"total = {cost.total_collective_bytes:,.0f}")
        assert err < 1e-4, f"{mode} dispatch diverged from the oracle"

    (flat_dci, flat_tot, _), (nap_dci, nap_tot, nap_out) = \
        results["flat"], results["nap"]
    print(f"\nEXPENSIVE-axis (inter-pod) bytes: flat {flat_dci:,.0f} -> "
          f"nap {nap_dci:,.0f}  ({flat_dci / max(nap_dci, 1):.2f}x less)")
    print(f"cheap intra-pod bytes grow: {flat_tot - flat_dci:,.0f} -> "
          f"{nap_tot - nap_dci:,.0f} — the paper's Figs. 8-vs-9 trade.")
    assert nap_dci < flat_dci, "NAP must reduce pod-crossing traffic"

    # ---- in-graph: quantized wire payloads on the nap exchange ------------
    print("\nquantized wire (nap dispatch):")
    scale = np.abs(np.asarray(want)).max()
    for wd in ("bf16", "fp8_e4m3"):
        wcfg = cfg.replace(moe_dispatch="nap", wire_dtype=wd)
        got, cost = run(wcfg)
        err = np.abs(got - nap_out).max() / scale
        bound = wire_error_bound(wcfg)
        print(f"  {wd:8s}: DCI bytes = {cost.dci_bytes:,.0f} "
              f"({nap_dci / max(cost.dci_bytes, 1):.2f}x less than f32), "
              f"rel err vs f32 = {err:.2e} (budget {bound:.2e})")
        assert cost.dci_bytes < nap_dci, f"{wd} must shrink the DCI bytes"

    # ---- registered operator: routing compiled into the plan machinery ----
    print("\ndispatch_operator (plan layer, auto mode):")
    acfg = cfg.replace(moe_dispatch="auto", wire_dtype="fp8_e4m3")
    op = dispatch_operator(acfg, mesh, n_tokens=256)
    rep = op.autotune_report()
    st = op.stats()
    print(f"  per-direction verdicts: dispatch={rep['dispatch_resolved']} "
          f"combine={rep['combine_resolved']}")
    print(f"  modeled injected inter-pod bytes/RHS: "
          f"dispatch {st['dispatch_injected_inter_bytes']:,} "
          f"combine {st['combine_injected_inter_bytes']:,} "
          f"at {st['bytes_per_val']} B/value on the wire")


if __name__ == "__main__":
    main()
