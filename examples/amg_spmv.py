"""AMG-preconditioned CG where EVERY SpMV — including restriction and
prolongation — is a NapOperator.

This is the paper's driving application: algebraic multigrid solves spend
their time in per-level SpMVs whose communication patterns degrade on
coarse levels.  Here a rotated-anisotropic system is solved by AMG-PCG
with a FULLY DISTRIBUTED hierarchy: `level_operators` emits one square
operator per level's A and one RECTANGULAR operator per prolongation P
(`row_part` = fine partition, `col_part` = coarse partition); the
restriction is `P.T` — the node-aware transpose executor over the same
compiled plan — so the V-cycle's `P.T @ r` never falls back to a
host-side gather.  The lazily composed Galerkin operator `(R @ A @ P)`
is cross-checked against the scipy triple product and then MATERIALISED
through the node-aware distributed SpGEMM (`repro.spgemm`) into a
concrete coarse operator, and a BiCG solve on a nonsymmetric
perturbation additionally exercises `op.T` on a square system.

    PYTHONPATH=src python examples/amg_spmv.py
"""
import numpy as np

from repro.amg import (amg_vcycle, bicgstab_solve, cg_solve, level_operators,
                       smoothed_aggregation_hierarchy)
from repro.core.cost_model import BLUE_WATERS
from repro.core.topology import Topology
from repro.sparse import CSR, random_fixed_nnz, rotated_anisotropic_2d


def main() -> None:
    a = rotated_anisotropic_2d(48, eps=0.01, theta=np.pi / 6)
    a = CSR.from_dense(a.to_dense() + np.eye(a.shape[0]) * 1e-3)
    topo = Topology(n_nodes=8, ppn=4)
    levels = smoothed_aggregation_hierarchy(a, theta=0.1, coarse_size=64)
    print(f"AMG hierarchy: {[lvl.a.shape[0] for lvl in levels]} rows/level")

    # one LevelOperators per level: square A + rectangular P, R = P.T
    # (exact simulator backend) + modeled times, grid transfers included
    ops = level_operators(levels, topo, method="nap", backend="simulate")
    std_ops = level_operators(levels, topo, method="standard",
                              backend="simulate")
    for i, (lvl, op, op_std) in enumerate(zip(levels, ops, std_ops)):
        if op.a is None:
            continue
        ts = op_std.a.cost(BLUE_WATERS)["total"]
        tn = op.a.cost(BLUE_WATERS)["total"]
        line = (f"  level {i}: rows {lvl.a.shape[0]:6d}  modeled comm "
                f"std {ts:.2e}s  nap {tn:.2e}s  ({ts/tn:4.1f}x)")
        if op.p is not None:
            line += (f"  P {op.p.shape[0]}x{op.p.shape[1]} comm "
                     f"{op.p.cost(BLUE_WATERS)['total']:.2e}s")
        print(line)

    # -- the Galerkin operator as lazy composition ---------------------------
    # (R @ A @ P) chains three node-aware SpMVs (restriction through the
    # transpose executor); cross-check against the scipy triple product.
    import scipy.sparse as sp
    gal = ops[0].galerkin()
    assert gal is not None and gal.shape == levels[1].a.shape
    rng = np.random.default_rng(0)
    xc = rng.standard_normal(gal.shape[1])
    p_sp = sp.csr_matrix(levels[0].p.to_dense())
    a_sp = sp.csr_matrix(levels[0].a.to_dense())
    want = (p_sp.T @ a_sp @ p_sp) @ xc
    np.testing.assert_allclose(gal @ xc, want, rtol=1e-5, atol=1e-6)
    print(f"Galerkin (R @ A @ P) @ x matches the scipy triple product "
          f"({gal.shape[0]}x{gal.shape[1]}, 3 chained node-aware SpMVs)")

    # -- materialised Galerkin: the node-aware distributed SpGEMM ------------
    # two distributed products (A@P then R@(AP)) carrying B-row blocks
    # through the three-step exchange; the float64 simulate path is
    # bit-for-bit the host csr_matmul assembly of the hierarchy.
    conc = gal.materialize(cross_check=True)
    np.testing.assert_allclose(conc @ xc, want, rtol=1e-9, atol=1e-9)
    assert np.array_equal(conc.a.data, levels[1].a.data)
    print(f"materialize(): concrete coarse NapOperator "
          f"({conc.shape[0]}x{conc.shape[1]}, nnz {conc.a.nnz}) via the "
          f"distributed SpGEMM — bit-for-bit the host RAP, 1 SpMV/apply")

    # every grid transfer in the V-cycle is a rectangular NapOperator
    n_rect = sum(1 for e in ops if e.p is not None)
    assert all(e.r.transposed and e.r.shape == e.p.shape[::-1]
               for e in ops if e.p is not None)
    print(f"{n_rect} rectangular P/R operator pairs in the V-cycle "
          f"(restriction = P.T through the node-aware transpose path)")

    b = rng.standard_normal(a.shape[0])
    x, iters, rel = cg_solve(
        a, b, tol=1e-8, maxiter=100,
        precond=lambda r: amg_vcycle(levels, r, operators=ops),
        spmv=ops[0].a)
    print(f"AMG-PCG with fully distributed V-cycle converged in {iters} "
          f"iters (relres {rel:.1e})")
    assert rel < 1e-8

    # -- transpose SpMV in anger: BiCG on a nonsymmetric system --------------
    # plain BiCG needs A.T @ v every iteration; op.T serves it from the
    # same compiled NAP plan with the send/recv roles reversed.
    import repro.api as nap
    an = random_fixed_nnz(1024, 9, seed=3)
    an = CSR.from_dense(an.to_dense() + np.eye(1024) * 12.0)  # diag-dominant
    op_n = nap.operator(an, topo=topo, method="nap", backend="simulate")
    bn = rng.standard_normal(1024)
    xb, itb, relb = bicgstab_solve(an, bn, tol=1e-8, maxiter=200,
                                   spmv=op_n, spmv_t=op_n.T)
    print(f"BiCG with forward+transpose NAPSpMV converged in {itb} iters "
          f"(relres {relb:.1e})")
    assert relb < 1e-8


if __name__ == "__main__":
    main()
