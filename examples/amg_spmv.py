"""AMG-preconditioned CG where every SpMV is the distributed NAPSpMV.

This is the paper's driving application: algebraic multigrid solves spend
their time in per-level SpMVs whose communication patterns degrade on coarse
levels.  Here a rotated-anisotropic system is solved by AMG-PCG with the
level-0 (and optionally every level's) SpMV executed through the exact
NAPSpMV message-passing simulator, and the per-level communication savings
are printed.

    PYTHONPATH=src python examples/amg_spmv.py
"""
import numpy as np

from repro.amg import amg_vcycle, cg_solve, smoothed_aggregation_hierarchy
from repro.configs.paper_spmv import CONFIG
from repro.core.cost_model import BLUE_WATERS, nap_cost, standard_cost
from repro.core.partition import contiguous_partition
from repro.core.spmv import DistSpMV
from repro.core.topology import Topology
from repro.sparse import CSR, rotated_anisotropic_2d


def main() -> None:
    a = rotated_anisotropic_2d(48, eps=0.01, theta=np.pi / 6)
    a = CSR.from_dense(a.to_dense() + np.eye(a.shape[0]) * 1e-3)
    topo = Topology(n_nodes=8, ppn=4)
    levels = smoothed_aggregation_hierarchy(a, theta=0.1, coarse_size=64)
    print(f"AMG hierarchy: {[lvl.a.shape[0] for lvl in levels]} rows/level")

    # distributed SpMV per level (exact simulator) + modeled times
    dists = []
    for i, lvl in enumerate(levels):
        if lvl.a.shape[0] < topo.n_procs:
            dists.append(None)
            continue
        part = contiguous_partition(lvl.a.shape[0], topo.n_procs)
        d = DistSpMV.build(lvl.a, part, topo)
        dists.append(d)
        ts = standard_cost(d.standard, BLUE_WATERS)["total"]
        tn = nap_cost(d.nap, BLUE_WATERS)["total"]
        print(f"  level {i}: rows {lvl.a.shape[0]:6d}  modeled comm "
              f"std {ts:.2e}s  nap {tn:.2e}s  ({ts/tn:4.1f}x)")

    def spmv_at(lvl_idx: int, vec: np.ndarray) -> np.ndarray:
        d = dists[lvl_idx]
        return d.run(vec, "nap") if d is not None else levels[lvl_idx].a.matvec(vec)

    rng = np.random.default_rng(0)
    b = rng.standard_normal(a.shape[0])
    x, iters, rel = cg_solve(
        a, b, tol=1e-8, maxiter=100,
        precond=lambda r: amg_vcycle(levels, r, spmv_at=spmv_at),
        spmv=lambda vec: dists[0].run(vec, "nap"))
    print(f"AMG-PCG with NAPSpMV converged in {iters} iters (relres {rel:.1e})")
    assert rel < 1e-8


if __name__ == "__main__":
    main()
