"""AMG-preconditioned CG where every SpMV is a NapOperator.

This is the paper's driving application: algebraic multigrid solves spend
their time in per-level SpMVs whose communication patterns degrade on
coarse levels.  Here a rotated-anisotropic system is solved by AMG-PCG
with EVERY level's SpMV executed through `repro.api.operator` (exact
NAPSpMV message-passing backend), and the per-level communication savings
are printed.  A BiCG solve on a nonsymmetric perturbation additionally
exercises `op.T` — the transpose SpMV that AMG restriction and BiCG-type
solvers need, compiled from the same communication plan.

    PYTHONPATH=src python examples/amg_spmv.py
"""
import numpy as np

from repro.amg import (amg_vcycle, bicgstab_solve, cg_solve, level_operators,
                       smoothed_aggregation_hierarchy)
from repro.core.cost_model import BLUE_WATERS
from repro.core.topology import Topology
from repro.sparse import CSR, random_fixed_nnz, rotated_anisotropic_2d


def main() -> None:
    a = rotated_anisotropic_2d(48, eps=0.01, theta=np.pi / 6)
    a = CSR.from_dense(a.to_dense() + np.eye(a.shape[0]) * 1e-3)
    topo = Topology(n_nodes=8, ppn=4)
    levels = smoothed_aggregation_hierarchy(a, theta=0.1, coarse_size=64)
    print(f"AMG hierarchy: {[lvl.a.shape[0] for lvl in levels]} rows/level")

    # one NapOperator per level (exact simulator backend) + modeled times
    ops = level_operators(levels, topo, method="nap", backend="simulate")
    std_ops = level_operators(levels, topo, method="standard",
                              backend="simulate")
    for i, (lvl, op, op_std) in enumerate(zip(levels, ops, std_ops)):
        if op is None:
            continue
        ts = op_std.cost(BLUE_WATERS)["total"]
        tn = op.cost(BLUE_WATERS)["total"]
        print(f"  level {i}: rows {lvl.a.shape[0]:6d}  modeled comm "
              f"std {ts:.2e}s  nap {tn:.2e}s  ({ts/tn:4.1f}x)")

    rng = np.random.default_rng(0)
    b = rng.standard_normal(a.shape[0])
    x, iters, rel = cg_solve(
        a, b, tol=1e-8, maxiter=100,
        precond=lambda r: amg_vcycle(levels, r, operators=ops),
        spmv=ops[0])
    print(f"AMG-PCG with NAPSpMV converged in {iters} iters (relres {rel:.1e})")
    assert rel < 1e-8

    # -- transpose SpMV in anger: BiCG on a nonsymmetric system --------------
    # plain BiCG needs A.T @ v every iteration; op.T serves it from the
    # same compiled NAP plan with the send/recv roles reversed.
    import repro.api as nap
    an = random_fixed_nnz(1024, 9, seed=3)
    an = CSR.from_dense(an.to_dense() + np.eye(1024) * 12.0)  # diag-dominant
    op_n = nap.operator(an, topo=topo, method="nap", backend="simulate")
    bn = rng.standard_normal(1024)
    xb, itb, relb = bicgstab_solve(an, bn, tol=1e-8, maxiter=200,
                                   spmv=op_n, spmv_t=op_n.T)
    print(f"BiCG with forward+transpose NAPSpMV converged in {itb} iters "
          f"(relres {relb:.1e})")
    assert relb < 1e-8


if __name__ == "__main__":
    main()
