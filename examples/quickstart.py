"""Quickstart: node-aware SpMV through the unified operator API.

Builds a 2D anisotropic diffusion matrix, distributes it over a simulated
(4 nodes x 4 processes) machine, and runs forward AND transpose SpMV
through one `NapOperator` on both backends — the exact message-passing
simulator and the JAX shard_map SPMD executor — then prints the
communication win.  The whole flow is five lines:

    import repro.api as nap
    op = nap.operator(a, topo=Topology(n_nodes=4, ppn=4))
    w  = op @ v        # forward SpMV (multi-RHS: v of shape [n, nv])
    z  = op.T @ v      # transpose SpMV, same compiled plan reversed
    op.stats(); op.cost(BLUE_WATERS); op.autotune_report()

    PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")

import numpy as np
import jax

import repro.api as nap
from repro.core.cost_model import BLUE_WATERS
from repro.core.topology import Topology
from repro.sparse import random_fixed_nnz, rotated_anisotropic_2d


def main() -> None:
    # -- problem + machine ----------------------------------------------------
    a = rotated_anisotropic_2d(32, eps=0.01, theta=np.pi / 6)
    topo = Topology(n_nodes=4, ppn=4)
    rng = np.random.default_rng(0)
    v = rng.standard_normal(a.shape[0])
    want = a.matvec(v)
    want_t = a.transpose().matvec(v)

    # -- exact message-passing simulation through the operator ----------------
    for method in ("standard", "nap"):
        op = nap.operator(a, topo=topo, method=method, backend="simulate")
        np.testing.assert_allclose(op @ v, want, rtol=1e-12)
        np.testing.assert_allclose(op.T @ v, want_t, rtol=1e-12)
    print("exactness: standard & NAP simulators match A@v and A.T@v")

    # -- communication statistics (the paper's Figs. 11/12 in miniature) ------
    # unstructured matrices are where the node-level dedup wins: many ranks
    # of one node need the same remote value, and NAP injects it once.
    ar = random_fixed_nnz(4096, 50, seed=0)
    op_std = nap.operator(ar, topo=topo, method="standard", backend="simulate")
    op_nap = nap.operator(ar, topo=topo, method="nap", backend="simulate")
    v0 = rng.standard_normal(4096)
    np.testing.assert_allclose(op_nap @ v0, ar.matvec(v0), rtol=1e-9, atol=1e-12)
    s, n = op_std.stats(), op_nap.stats()
    print("\nrandom 4096x4096, 50 nnz/row (the paper's unstructured case):")
    print(f"inter-node messages: standard {s['messages_inter'].total_msgs:4d}  "
          f"nap {n['messages_inter'].total_msgs:4d}")
    print(f"inter-node bytes:    standard {s['messages_inter'].total_bytes:6d}  "
          f"nap {n['messages_inter'].total_bytes:6d}")
    print(f"intra-node bytes:    standard {s['messages_intra'].total_bytes:6d}  "
          f"nap {n['messages_intra'].total_bytes:6d}   (cheap traffic grows)")
    ts = op_std.cost(BLUE_WATERS)["total"]
    tn = op_nap.cost(BLUE_WATERS)["total"]
    print(f"modeled comm time:   standard {ts:.2e}s  nap {tn:.2e}s  "
          f"({ts / tn:.2f}x)")

    # -- the same plan compiled to shard_map SPMD ------------------------------
    if jax.device_count() >= topo.n_procs:
        op = nap.operator(a, topo=topo, method="nap", backend="shardmap")
        np.testing.assert_allclose(op @ v, want, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(op.T @ v, want_t, rtol=1e-4, atol=1e-5)
        print(f"\nautotuned local compute: {op.local_compute} "
              f"(see op.autotune_report())")
        print("SPMD shard_map NAPSpMV matches on a 16-device host mesh")


if __name__ == "__main__":
    main()
