"""Quickstart: node-aware SpMV on a small problem, end to end.

Builds a 2D anisotropic diffusion matrix, distributes it over a simulated
(4 nodes x 4 processes) machine, runs the standard and node-aware SpMV
through (a) the exact message-passing simulator and (b) the JAX shard_map
SPMD executor, checks exactness, and prints the communication win.

    PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")

import numpy as np
import jax

from repro.compat import make_mesh
from repro.core.comm_graph import build_nap_plan, build_standard_plan, nap_stats, standard_stats
from repro.core.cost_model import BLUE_WATERS, nap_cost, standard_cost
from repro.core.partition import contiguous_partition
from repro.core.spmv import DistSpMV
from repro.core.spmv_jax import (compile_nap, nap_spmv_shardmap, pack_vector,
                                 unpack_vector)
from repro.core.topology import Topology
from repro.sparse import rotated_anisotropic_2d


def main() -> None:
    # -- problem + machine ----------------------------------------------------
    a = rotated_anisotropic_2d(32, eps=0.01, theta=np.pi / 6)
    topo = Topology(n_nodes=4, ppn=4)
    part = contiguous_partition(a.shape[0], topo.n_procs)
    rng = np.random.default_rng(0)
    v = rng.standard_normal(a.shape[0])
    want = a.matvec(v)

    # -- exact message-passing simulation ------------------------------------
    dist = DistSpMV.build(a, part, topo)
    w_std = dist.run(v, "standard")
    w_nap = dist.run(v, "nap")
    np.testing.assert_allclose(w_std, want, rtol=1e-12)
    np.testing.assert_allclose(w_nap, want, rtol=1e-12)
    print("exactness: standard & NAP simulators match A@v")

    # -- communication statistics (the paper's Figs. 11/12 in miniature) ------
    # unstructured matrices are where the node-level dedup wins: many ranks
    # of one node need the same remote value, and NAP injects it once.
    from repro.sparse import random_fixed_nnz
    ar = random_fixed_nnz(4096, 50, seed=0)
    partr = contiguous_partition(ar.shape[0], topo.n_procs)
    distr = DistSpMV.build(ar, partr, topo)
    np.testing.assert_allclose(distr.run(v0 := rng.standard_normal(4096), "nap"),
                               ar.matvec(v0), rtol=1e-9, atol=1e-12)
    s = standard_stats(distr.standard)
    n = nap_stats(distr.nap)
    print("\nrandom 4096x4096, 50 nnz/row (the paper's unstructured case):")
    print(f"inter-node messages: standard {s['inter'].total_msgs:4d}  "
          f"nap {n['inter'].total_msgs:4d}")
    print(f"inter-node bytes:    standard {s['inter'].total_bytes:6d}  "
          f"nap {n['inter'].total_bytes:6d}")
    print(f"intra-node bytes:    standard {s['intra'].total_bytes:6d}  "
          f"nap {n['intra'].total_bytes:6d}   (cheap traffic grows)")
    ts = standard_cost(distr.standard, BLUE_WATERS)["total"]
    tn = nap_cost(distr.nap, BLUE_WATERS)["total"]
    print(f"modeled comm time:   standard {ts:.2e}s  nap {tn:.2e}s  "
          f"({ts / tn:.2f}x)")

    # -- the same plan compiled to shard_map SPMD ------------------------------
    if jax.device_count() >= topo.n_procs:
        mesh = make_mesh((topo.n_nodes, topo.ppn), ("node", "proc"))
        compiled = compile_nap(a, part, topo)
        run = nap_spmv_shardmap(compiled, mesh)
        shards = pack_vector(v, part, topo, compiled.rows_pad)
        w_spmd = unpack_vector(np.asarray(run(shards)), part, topo)
        np.testing.assert_allclose(w_spmd, want, rtol=1e-4, atol=1e-5)
        print("SPMD shard_map NAPSpMV matches on a 16-device host mesh")


if __name__ == "__main__":
    main()
