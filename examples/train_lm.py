"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Exercises the full substrate stack — synthetic bigram data pipeline, the
gemma2-family model at a ~100M width, AdamW, async checkpoints, restart —
and asserts the loss drops toward the generating process's entropy floor.

Default is a quicker ~20M config; pass --full-100m for the 100M run.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    base = get_config("gemma2-2b")
    if args.full_100m:
        cfg = base.replace(n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
                           d_head=64, d_ff=2048, vocab=32_768,
                           sliding_window=64, attn_block_q=64,
                           attn_block_kv=64, xent_chunk=128,
                           dtype="float32", remat=False, grad_accum=1)
    else:
        cfg = base.replace(n_layers=6, d_model=256, n_heads=8, n_kv_heads=4,
                           d_head=32, d_ff=1024, vocab=8_192,
                           sliding_window=64, attn_block_q=64,
                           attn_block_kv=64, xent_chunk=128,
                           dtype="float32", remat=False, grad_accum=1)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab} "
          f"-> {n_params/1e6:.1f}M params")

    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    opt_state = adamw_init(params, opt_cfg)
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, seed=0)
    floor = ds.bigram_entropy()
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    losses = []
    t0 = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in
                 ds.batch(step, args.batch).items()}
        loss, params, opt_state = step_fn(params, opt_state, batch)
        losses.append(float(loss))
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {losses[-1]:.4f}  "
                  f"(floor {floor:.3f}, {time.time()-t0:.0f}s)")
        if mgr and (step + 1) % 100 == 0:
            mgr.save(step + 1, (params, opt_state), extra={"step": step + 1})
    if mgr:
        mgr.wait()

    first = float(np.mean(losses[:10]))
    last = float(np.mean(losses[-10:]))
    print(f"\nloss {first:.3f} -> {last:.3f}; bigram-entropy floor {floor:.3f}")
    assert last < first - 0.5, "training failed to learn the bigram structure"
    print("OK: the model learned the synthetic structure")


if __name__ == "__main__":
    main()
