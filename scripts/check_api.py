"""API smoke: the unified NapOperator surface + the post-deprecation contract.

Run as its own process (it forces the XLA host device count before jax
initialises); wired into the tier-1 pytest run via tests/test_api.py.

Checks, on a (2, 2) machine on CPU:
  * `repro.api` imports and `operator(...)` builds on both backends;
  * forward AND transpose match the dense oracle (1e-9 on simulate,
    f32 tolerance on shardmap), 1-RHS and multi-RHS, on a 64-row square
    operator AND a 64x40 RECTANGULAR operator (row_part != col_part);
  * `(R @ A @ P)` composes lazily and matches the scipy triple product;
  * the distributed-SpGEMM surface exists and works:
    `repro.spgemm.build_spgemm_plan` + `simulate_nap_spgemm` produce the
    host `csr_matmul` product bit-for-bit, and
    `ComposedOperator.materialize()` collapses `(R @ A @ P)` into a
    concrete NapOperator on the coarse partitions;
  * the integrity surface works end to end: `integrity="detect"` raises
    an attributed `IntegrityError` on a scripted wire fault (clean
    applies stay bit-identical to `integrity="off"`), `"recover"`
    reproduces the fault-free result bit-for-bit, and
    `op.integrity_report()` carries the retry/strike counters;
  * the one-release deprecation shims are GONE: `nap_spmv_shardmap`,
    `standard_spmv_shardmap` and `DistSpMV.run` no longer exist (their
    release has passed — migration table: src/repro/kernels/README.md)
    and no removed shim has resurfaced.

    PYTHONPATH=src python scripts/check_api.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import numpy as np


def main() -> None:
    import repro.api as nap
    import repro.core.spmv_jax as spmv_jax_mod
    from repro.core.partition import contiguous_partition
    from repro.core.spmv import DistSpMV
    from repro.core.topology import Topology
    from repro.sparse import CSR, random_fixed_nnz

    n = 64
    topo = Topology(n_nodes=2, ppn=2)
    a = random_fixed_nnz(n, 6, seed=0)
    rng = np.random.default_rng(0)
    v = rng.standard_normal(n)
    v4 = rng.standard_normal((n, 4))
    at = a.transpose()

    # -- operator forward + transpose on both backends ----------------------
    for backend, rtol, atol in [("simulate", 1e-9, 1e-12),
                                ("shardmap", 1e-4, 1e-5)]:
        for method in ("nap", "standard"):
            op = nap.operator(a, topo=topo, method=method, backend=backend)
            np.testing.assert_allclose(op @ v, a.matvec(v), rtol=rtol, atol=atol)
            np.testing.assert_allclose(op.T @ v, at.matvec(v), rtol=rtol, atol=atol)
            w4, z4 = op @ v4, op.T @ v4
            for i in range(4):
                np.testing.assert_allclose(w4[:, i], a.matvec(v4[:, i]),
                                           rtol=rtol, atol=atol)
                np.testing.assert_allclose(z4[:, i], at.matvec(v4[:, i]),
                                           rtol=rtol, atol=atol)
            assert op.T.T is op
    print("operator forward+transpose OK on simulate + shardmap "
          "(nap & standard, 1-RHS & multi-RHS)")

    # -- rectangular operator + lazy composition ----------------------------
    nc = 40
    pm = (rng.random((n, nc)) < 0.2) * rng.standard_normal((n, nc))
    p = CSR.from_dense(pm)
    fine = contiguous_partition(n, topo.n_procs)
    coarse = contiguous_partition(nc, topo.n_procs)
    xc = rng.standard_normal(nc)
    u = rng.standard_normal(n)
    for backend, rtol, atol in [("simulate", 1e-9, 1e-12),
                                ("shardmap", 1e-3, 1e-4)]:
        a_op = nap.operator(a, topo=topo, part=fine, backend=backend)
        p_op = nap.operator(p, topo=topo, row_part=fine, col_part=coarse,
                            backend=backend)
        assert p_op.shape == (n, nc) and p_op.T.shape == (nc, n)
        np.testing.assert_allclose(p_op @ xc, pm @ xc, rtol=rtol, atol=atol)
        np.testing.assert_allclose(p_op.T @ u, pm.T @ u, rtol=rtol, atol=atol)
        gal = p_op.T @ a_op @ p_op
        want = pm.T @ (a.to_dense() @ (pm @ xc))
        np.testing.assert_allclose(gal @ xc, want, rtol=5e-3, atol=5e-4)
        rep = p_op.autotune_report()
        if backend == "shardmap":
            assert rep["transpose_resolved"] in ("ell", "coo"), rep
            assert "transpose" in rep, "compile must record the transpose verdict"
    print("rectangular operator + (R @ A @ P) composition OK on both backends")

    # -- distributed SpGEMM surface + materialize ---------------------------
    from repro.amg.matmul import csr_matmul
    from repro.spgemm import build_spgemm_plan, simulate_nap_spgemm

    plan = build_spgemm_plan(a, p, fine, fine, topo, method="nap")
    c = simulate_nap_spgemm(a, p, plan)
    host = csr_matmul(a, p)
    assert np.array_equal(c.indptr, host.indptr) and \
        np.array_equal(c.indices, host.indices) and \
        np.array_equal(c.data, host.data), \
        "simulate_nap_spgemm must equal host csr_matmul bit-for-bit"
    assert hasattr(nap.ComposedOperator, "materialize"), \
        "ComposedOperator.materialize is part of the public surface"
    a_op = nap.operator(a, topo=topo, part=fine, backend="simulate")
    p_op = nap.operator(p, topo=topo, row_part=fine, col_part=coarse,
                        backend="simulate")
    conc = (p_op.T @ a_op @ p_op).materialize(cross_check=True)
    assert isinstance(conc, nap.NapOperator) and conc.shape == (nc, nc)
    np.testing.assert_allclose(conc @ xc, pm.T @ (a.to_dense() @ (pm @ xc)),
                               rtol=1e-9, atol=1e-10)
    print("spgemm surface OK (build_spgemm_plan + simulate_nap_spgemm "
          "bit-for-bit, ComposedOperator.materialize concrete on coarse "
          "partitions)")

    # -- the serve surface: service round-trip + hot swap, no retrace -------
    from repro.serve import (FaultPlan, PlanCache, SolverService, dead_node)
    from repro.sparse.csr import CSR as _CSR

    svc = SolverService(topo, backend="simulate",
                        fault_plan=FaultPlan.of(dead_node(2, "node1")),
                        heartbeat_timeout=2.5)
    m_int = np.rint(a.to_dense() * 4)
    ai = CSR.from_dense(m_int + m_int.T + np.eye(n) * 80.0)   # integer SPD
    svc.register_matrix("A", ai)
    bi = rng.integers(-8, 9, size=n).astype(np.float64)
    t_spmv = svc.submit("tenant", "A", bi, kind="spmv")
    t_solve = svc.submit("tenant", "A", bi, kind="solve", tol=1e-10)
    svc.run(max_steps=40)
    assert t_spmv.status == "done" and t_solve.status == "done", \
        (t_spmv.status, t_solve.status)
    assert svc.stats["recoveries"] == 1 and svc.topo.n_nodes == 1, \
        "node1's scripted death must drive one elastic recovery"
    np.testing.assert_array_equal(t_spmv.result(), ai.matvec(bi))
    np.testing.assert_allclose(ai.matvec(t_solve.result()), bi,
                               rtol=1e-8, atol=1e-8)
    # hot value swap reuses the compiled shardmap program: zero retraces
    op = nap.operator(a, topo=topo, backend="shardmap")
    _ = op @ v
    before = dict(op.trace_counts())
    op.swap_values(_CSR(indptr=a.indptr.copy(), indices=a.indices.copy(),
                        data=a.data * 2.0, shape=a.shape))
    w_sw = op @ v
    assert op.trace_counts() == before, \
        f"hot swap retraced: {before} -> {op.trace_counts()}"
    np.testing.assert_allclose(w_sw, 2.0 * a.matvec(v), rtol=1e-4, atol=1e-4)
    cache = PlanCache(topo, backend="simulate")
    op_c = cache.operator_for(a, fine)
    assert cache.operator_for(a, fine) is op_c and cache.stats["hits"] == 1
    print("serve surface OK (service solve + elastic recovery; hot swap "
          "with zero retraces; structure-keyed plan cache)")

    # -- the integrity surface ----------------------------------------------
    # detect raises an attributed IntegrityError on a scripted wire
    # fault; recover returns the fault-free result bit-for-bit; the
    # report carries the counters the serve quarantine path reads.
    assert nap.IntegrityError is not None and nap.MessageFault is not None
    y_clean = nap.operator(a, topo=topo, backend="shardmap") @ v
    op_det = nap.operator(a, topo=topo, backend="shardmap",
                          integrity="detect")
    assert np.array_equal(op_det @ v, y_clean), \
        "clean detect must be bit-identical to integrity='off'"
    op_det.inject_fault("inter", "bitflip", node=1, proc=0, slot=0,
                        element=1, bit=20)
    try:
        op_det @ v
        raise AssertionError("scripted bitflip must raise under detect")
    except nap.IntegrityError as e:
        assert e.mismatches and e.mismatches[0].phase == "inter", \
            [str(m) for m in e.mismatches]
    op_rec = nap.operator(a, topo=topo, backend="shardmap",
                          integrity="recover")
    op_rec.inject_fault("inter", "bitflip", node=1, proc=0, slot=0,
                        element=1, bit=20)
    assert np.array_equal(op_rec @ v, y_clean), \
        "recover must reproduce the fault-free apply bit-for-bit"
    rep = op_rec.integrity_report()
    assert rep["recovered"] == 1 and rep["retries"] == 1, rep
    assert rep["strikes"].get("node1") == 1, rep
    print("integrity surface OK (detect raises attributed, recover "
          "bit-identical, report counters populated)")

    # -- the deprecation shims are GONE -------------------------------------
    for mod, name in [(spmv_jax_mod, "nap_spmv_shardmap"),
                      (spmv_jax_mod, "standard_spmv_shardmap"),
                      (DistSpMV, "run")]:
        assert not hasattr(mod, name), \
            f"{name} must be removed (its deprecation release has passed)"
    try:
        import repro.deprecation  # noqa: F401
        raise AssertionError("repro.deprecation should be gone with the shims")
    except ImportError:
        pass
    print("deprecation shims removed (DistSpMV.run, nap_spmv_shardmap, "
          "standard_spmv_shardmap)")

    # -- comm-strategy surface ----------------------------------------------
    # comm="multistep" matches the oracle on both backends; comm="nap" is
    # bit-identical to the pre-existing operator; comm="auto" records the
    # per-direction verdict on autotune_report().
    for backend, rtol, atol in [("simulate", 1e-9, 1e-12),
                                ("shardmap", 1e-4, 1e-5)]:
        op = nap.operator(a, topo=topo, backend=backend, comm="multistep")
        np.testing.assert_allclose(op @ v, a.matvec(v), rtol=rtol, atol=atol)
        np.testing.assert_allclose(op.T @ v, at.matvec(v),
                                   rtol=rtol, atol=atol)
        base = nap.operator(a, topo=topo, backend=backend)
        pinned = nap.operator(a, topo=topo, backend=backend, comm="nap")
        np.testing.assert_array_equal(np.asarray(base @ v),
                                      np.asarray(pinned @ v))
    op = nap.operator(a, topo=topo, backend="simulate", comm="auto")
    rep = op.autotune_report()
    assert rep["comm"]["requested"] == "auto"
    assert rep["comm_resolved"] in ("standard", "nap", "multistep")
    assert rep["comm_transpose_resolved"] in ("standard", "nap", "multistep")
    cand = rep["comm"]["forward"]["candidates"]
    assert set(cand) == {"standard", "nap", "multistep"}
    for c in cand.values():
        assert c["injected_inter_bytes"] >= c["effective_inter_bytes"] >= 0
    np.testing.assert_allclose(op @ v, a.matvec(v), rtol=1e-9, atol=1e-12)
    print("comm surface OK (multistep both backends, comm='nap' "
          "bit-identical, comm='auto' verdict on autotune_report)")

    # -- the mesh runtime surface -------------------------------------------
    # topology autodiscovery (operator(a) with no topo), the persistent
    # buffer registry behind every compiled plan, and the launcher's env
    # contract — all single-process here; the 2-process path is
    # tests/multidev/mesh_prog.py.
    from repro.mesh import (default_registry, discover_topology, launch,
                            mesh_env, pick_coordinator)
    from repro.mesh.buffers import is_multiprocess
    from repro.mesh.launcher import ENV_COORDINATOR

    assert not is_multiprocess()
    disc = discover_topology()
    assert disc.n_nodes == 1 and disc.ppn == 4, disc   # forced 4-device host
    op_auto = nap.operator(a, backend="shardmap")       # topo autodiscovered
    assert op_auto.topo == disc
    oracle = nap.operator(a, topo=disc, backend="shardmap")
    assert np.array_equal(np.asarray(op_auto @ v), np.asarray(oracle @ v)), \
        "autodiscovered topo must be bit-identical to the declared one"
    reg = default_registry()
    rep = reg.report()
    assert rep["staged"] > 0, rep                       # plans stage through it
    assert rep["resident_bytes"] > 0, rep
    env = mesh_env(pick_coordinator(), 2, 1, local_devices=3)
    assert env[ENV_COORDINATOR].startswith("127.0.0.1:")
    assert callable(launch)
    print("mesh surface OK (autodiscovered topo bit-identical, buffer "
          "registry live, launcher env contract)")

    # -- the MoE dispatch subsystem -----------------------------------------
    # backend="moe" executors registered; f32 wire is the identity codec
    # (bitwise vs the matching simulator); quantized byte accounting on
    # stats(); dispatch_operator resolves "auto" per direction.
    from repro.models.config import ModelConfig
    from repro.moe import (dispatch_partitions, representative_routing,
                           routing_matrix, wire_bytes)
    from repro.moe.dispatch import dispatch_operator

    for m in ("flat", "nap", "auto"):
        assert ("moe", m) in nap.available_executors(), \
            f"moe/{m} executor must be registered"
    tt = Topology(n_nodes=2, ppn=2)
    ids, w = representative_routing(64, 4, 2, seed=1)
    r = routing_matrix(ids, w, 4)
    ep_, tp_ = dispatch_partitions(4, 64, tt)
    xt = rng.standard_normal((64, 3))
    ref = nap.operator(r, topo=tt, row_part=ep_, col_part=tp_,
                       backend="simulate", method="nap")
    moe_op = nap.operator(r, topo=tt, row_part=ep_, col_part=tp_,
                          backend="moe", method="nap")
    assert np.array_equal(moe_op @ xt, ref @ xt), \
        "f32 wire must be bit-identical to the simulate oracle"
    assert np.array_equal(moe_op.T @ (ref @ xt), ref.T @ (ref @ xt))
    st = {wd: nap.operator(r, topo=tt, row_part=ep_, col_part=tp_,
                           backend="moe", method="nap",
                           wire_dtype=wd).stats()
          for wd in ("f32", "bf16", "fp8_e4m3")}
    for wd, s in st.items():
        assert s["bytes_per_val"] == wire_bytes(wd), (wd, s["bytes_per_val"])
    assert st["fp8_e4m3"]["dispatch_injected_inter_bytes"] * 4 == \
        st["f32"]["dispatch_injected_inter_bytes"], \
        "quantized byte accounting must scale with the wire width"
    cfg_moe = ModelConfig(name="t", family="moe", n_layers=1, d_model=3,
                          n_heads=1, n_kv_heads=1, d_ff=8, vocab=8,
                          n_experts=4, top_k=2, moe_dff=8,
                          moe_dispatch="auto", wire_dtype="bf16")
    rep = dispatch_operator(cfg_moe, topo=tt, routing=(ids, w)).autotune_report()
    assert rep["dispatch_resolved"] in ("flat", "nap") and \
        rep["combine_resolved"] in ("flat", "nap") and \
        rep["wire_dtype"] == "bf16", rep
    print("moe dispatch surface OK (moe/flat|nap|auto registered, f32 "
          "bit-identical, wire-width byte accounting, auto per-direction "
          "verdicts)")
    print("API OK")


if __name__ == "__main__":
    main()
