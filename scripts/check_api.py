"""API smoke: the unified NapOperator surface + the deprecation contract.

Run as its own process (it forces the XLA host device count before jax
initialises); wired into the tier-1 pytest run via tests/test_api.py.

Checks, on a 64-row operator over a (2, 2) machine on CPU:
  * `repro.api` imports and `operator(...)` builds on both backends;
  * forward AND transpose match the dense oracle (1e-9 on simulate,
    f32 tolerance on shardmap), 1-RHS and multi-RHS;
  * each deprecation shim (`nap_spmv_shardmap`, `standard_spmv_shardmap`,
    `DistSpMV.run`) emits DeprecationWarning EXACTLY once per process
    while remaining fully functional.

    PYTHONPATH=src python scripts/check_api.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import warnings

import numpy as np


def main() -> None:
    import repro.api as nap
    from repro.compat import make_mesh
    from repro.core.partition import contiguous_partition
    from repro.core.spmv import DistSpMV
    from repro.core.spmv_jax import (compile_nap, nap_spmv_shardmap,
                                     pack_vector, standard_spmv_shardmap)
    from repro.core.topology import Topology
    from repro.sparse import random_fixed_nnz

    n = 64
    topo = Topology(n_nodes=2, ppn=2)
    a = random_fixed_nnz(n, 6, seed=0)
    rng = np.random.default_rng(0)
    v = rng.standard_normal(n)
    v4 = rng.standard_normal((n, 4))
    at = a.transpose()

    # -- operator forward + transpose on both backends ----------------------
    for backend, rtol, atol in [("simulate", 1e-9, 1e-12),
                                ("shardmap", 1e-4, 1e-5)]:
        for method in ("nap", "standard"):
            op = nap.operator(a, topo=topo, method=method, backend=backend)
            np.testing.assert_allclose(op @ v, a.matvec(v), rtol=rtol, atol=atol)
            np.testing.assert_allclose(op.T @ v, at.matvec(v), rtol=rtol, atol=atol)
            w4, z4 = op @ v4, op.T @ v4
            for i in range(4):
                np.testing.assert_allclose(w4[:, i], a.matvec(v4[:, i]),
                                           rtol=rtol, atol=atol)
                np.testing.assert_allclose(z4[:, i], at.matvec(v4[:, i]),
                                           rtol=rtol, atol=atol)
            assert op.T.T is op
    print("operator forward+transpose OK on simulate + shardmap "
          "(nap & standard, 1-RHS & multi-RHS)")

    # -- deprecation shims: warn exactly once, still functional -------------
    part = contiguous_partition(n, topo.n_procs)
    mesh = make_mesh((topo.n_nodes, topo.ppn), ("node", "proc"))
    compiled = compile_nap(a, part, topo)
    shards = pack_vector(v, part, topo, compiled.rows_pad)
    dist = DistSpMV.build(a, part, topo)
    shims = {
        "nap_spmv_shardmap": lambda: nap_spmv_shardmap(compiled, mesh)(shards),
        "standard_spmv_shardmap": lambda: standard_spmv_shardmap(
            a, part, topo, mesh)[0](shards),
        "DistSpMV.run": lambda: dist.run(v),
    }
    for name, call in shims.items():
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            call()
            call()
        got = [w for w in caught if issubclass(w.category, DeprecationWarning)]
        assert len(got) == 1, (
            f"{name}: expected exactly ONE DeprecationWarning over two "
            f"calls, saw {len(got)}")
        assert "repro.api" in str(got[0].message), got[0].message
    print("deprecation shims warn exactly once each and stay functional")
    print("API OK")


if __name__ == "__main__":
    main()
