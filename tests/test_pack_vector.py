"""pack_vector / unpack_vector edge cases.

The packed-shards layout ([n_nodes, ppn, rows_pad(, nv)]) is the one
contract every shardmap executor and the operator front-end share, so the
edges get explicit coverage: uneven ``contiguous_partition`` tails
(remainder rows on the leading ranks), EMPTY ranks (more ranks than
rows), non-contiguous partitions, and round-trips under the bn-aligned
rows_pad the compiled plans use.
"""
import numpy as np
import pytest

from repro.core.partition import (contiguous_partition, make_partition,
                                  strided_partition)
from repro.core.spmv_jax import pack_vector, unpack_vector
from repro.core.topology import Topology


def _roundtrip(v, part, topo, rows_pad):
    shards = pack_vector(v, part, topo, rows_pad)
    assert shards.shape[:3] == (topo.n_nodes, topo.ppn, rows_pad)
    return shards, unpack_vector(shards, part, topo)


@pytest.mark.parametrize("n,nn,ppn", [(37, 2, 3), (41, 4, 2), (65, 4, 4)])
def test_uneven_contiguous_tail_roundtrip(n, nn, ppn):
    """n not divisible by n_procs: remainder rows sit on leading ranks."""
    topo = Topology(n_nodes=nn, ppn=ppn)
    part = contiguous_partition(n, topo.n_procs)
    assert int(part.counts().max()) != int(part.counts().min())  # truly uneven
    v = np.random.default_rng(0).standard_normal(n)
    rows_pad = int(part.counts().max())
    shards, back = _roundtrip(v, part, topo, rows_pad)
    np.testing.assert_array_equal(back, v.astype(np.float32))


def test_empty_ranks():
    """More ranks than rows: trailing ranks own zero rows; their shard
    slots must stay zero and unpack must ignore them."""
    topo = Topology(n_nodes=2, ppn=4)
    n = 5  # < 8 ranks
    part = contiguous_partition(n, topo.n_procs)
    assert (part.counts() == 0).any()
    v = np.arange(1.0, n + 1.0)
    shards, back = _roundtrip(v, part, topo, rows_pad=3)
    np.testing.assert_array_equal(back, v.astype(np.float32))
    flat = shards.reshape(topo.n_procs, 3)
    for r in range(topo.n_procs):
        cnt = int(part.counts()[r])
        assert (flat[r, cnt:] == 0).all()


@pytest.mark.parametrize("kind", ["strided", "balanced"])
def test_non_contiguous_partitions_roundtrip(kind):
    topo = Topology(n_nodes=2, ppn=2)
    n = 23
    rng = np.random.default_rng(1)
    indptr = np.arange(n + 1) * 2
    indices = rng.integers(0, n, size=2 * n)
    part = make_partition(kind, n, topo.n_procs, indptr=indptr,
                          indices=indices, seed=3)
    v = rng.standard_normal(n)
    _, back = _roundtrip(v, part, topo, int(part.counts().max()))
    np.testing.assert_array_equal(back, v.astype(np.float32))


@pytest.mark.parametrize("bn", [8, 16, 128])
def test_bn_aligned_padding_roundtrip(bn):
    """rows_pad rounded up to the kernel lane width (what compile_nap
    does): padding slots never leak values and unpack still recovers v."""
    topo = Topology(n_nodes=2, ppn=2)
    n = 30
    part = strided_partition(n, topo.n_procs)
    rows_pad = -(-int(part.counts().max()) // bn) * bn
    v = np.random.default_rng(2).standard_normal(n)
    shards, back = _roundtrip(v, part, topo, rows_pad)
    np.testing.assert_array_equal(back, v.astype(np.float32))
    flat = shards.reshape(topo.n_procs, rows_pad)
    for r in range(topo.n_procs):
        assert (flat[r, int(part.counts()[r]):] == 0).all()


def test_rectangular_row_and_col_partitions_roundtrip():
    """m != n: the forward pack uses the COLUMN partition (n entries,
    cols_pad) while the output unpacks by the ROW partition (m entries,
    rows_pad) — both sides must round-trip bit-for-bit with their own
    partition, including the uneven tails two different sizes produce."""
    topo = Topology(n_nodes=2, ppn=3)
    m, n = 41, 100                      # both leave uneven tails over 6 ranks
    row_part = contiguous_partition(m, topo.n_procs)
    col_part = contiguous_partition(n, topo.n_procs)
    assert int(row_part.counts().max()) != int(row_part.counts().min())
    assert int(col_part.counts().max()) != int(col_part.counts().min())
    rng = np.random.default_rng(4)
    u, v = rng.standard_normal(m), rng.standard_normal(n)
    rows_pad = -(-int(row_part.counts().max()) // 8) * 8
    cols_pad = -(-int(col_part.counts().max()) // 8) * 8
    assert rows_pad != cols_pad         # genuinely two pads in flight
    _, back_u = _roundtrip(u, row_part, topo, rows_pad)
    _, back_v = _roundtrip(v, col_part, topo, cols_pad)
    np.testing.assert_array_equal(back_u, u.astype(np.float32))
    np.testing.assert_array_equal(back_v, v.astype(np.float32))


def test_empty_column_partition_ranks():
    """A coarse AMG col partition can own FEWER entries than there are
    ranks: the empty ranks' shards stay all-zero, unpack ignores them,
    and the round-trip is bit-for-bit — for 1-RHS and multi-RHS."""
    topo = Topology(n_nodes=4, ppn=2)
    n = 3                               # 3 entries over 8 ranks
    part = contiguous_partition(n, topo.n_procs)
    assert int((part.counts() == 0).sum()) == 5
    rng = np.random.default_rng(5)
    for v in (rng.standard_normal(n), rng.standard_normal((n, 4))):
        shards, back = _roundtrip(v, part, topo, rows_pad=8)
        np.testing.assert_array_equal(back, v.astype(np.float32))
        flat = shards.reshape((topo.n_procs, 8) + shards.shape[3:])
        for r in range(topo.n_procs):
            cnt = int(part.counts()[r])
            assert (flat[r, cnt:] == 0).all()


def test_multirhs_roundtrip_and_order():
    """[n, nv] multivectors: packing is column-independent."""
    topo = Topology(n_nodes=2, ppn=2)
    n, nv = 19, 5
    part = contiguous_partition(n, topo.n_procs)
    rng = np.random.default_rng(3)
    v = rng.standard_normal((n, nv))
    shards, back = _roundtrip(v, part, topo, rows_pad=8)
    assert shards.shape == (2, 2, 8, nv)
    np.testing.assert_array_equal(back, v.astype(np.float32))
    for i in range(nv):
        col = pack_vector(v[:, i], part, topo, 8)
        np.testing.assert_array_equal(col, shards[..., i])
