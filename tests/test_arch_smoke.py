"""Per-architecture smoke tests: REDUCED configs, one forward/train step +
one decode step on CPU; asserts output shapes and finiteness.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import all_arch_ids, get_config, get_reduced
from repro.models import build_model


def make_batch(cfg, rng, batch=2, seq=32):
    tokens = rng.integers(0, cfg.vocab, size=(batch, seq)).astype(np.int32)
    labels = rng.integers(0, cfg.vocab, size=(batch, seq)).astype(np.int32)
    b = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    if cfg.is_encoder_decoder:
        b["frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
    return b


@pytest.mark.parametrize("arch", all_arch_ids())
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    assert cfg.name == arch
    # spot checks against the assignment table
    table = {
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
    }
    L, d, H, KV, ff, V = table[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.vocab) == (L, d, H, KV, V)
    if arch == "qwen3-moe-235b-a22b":
        assert cfg.moe_dff == ff and cfg.n_experts == 128 and cfg.top_k == 8
    elif arch == "deepseek-v2-236b":
        assert cfg.n_experts == 160 and cfg.top_k == 6
        assert cfg.mla_kv_lora == 512 and cfg.n_shared_experts == 2
    else:
        assert cfg.d_ff == ff
    if arch == "zamba2-2.7b":
        assert cfg.ssm_state == 64


@pytest.mark.parametrize("arch", all_arch_ids())
def test_smoke_train_step(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    rng = np.random.default_rng(hash(arch) % 2**31)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, rng)

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss = {loss}"
    assert float(loss) > 0.0
    gnorm = float(jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                               for g in jax.tree.leaves(grads))))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: grad norm {gnorm}"


@pytest.mark.parametrize("arch", all_arch_ids())
def test_smoke_decode_step(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.key(1))
    B, S = 2, 16
    cache = model.init_cache(B, S)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
    logits, cache = jax.jit(model.decode_step)(params, cache, tokens)
    assert logits.shape[-1] == cfg.vocab
    assert logits.shape[0] == B
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite decode logits"
    # a second step advances length
    logits2, cache2 = jax.jit(model.decode_step)(params, cache, tokens)
    assert int(cache2["length"][0]) == 2
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ["gemma2-2b", "deepseek-v2-236b",
                                  "zamba2-2.7b", "rwkv6-3b"])
def test_decode_matches_prefill(arch):
    """Teacher-forced decode must reproduce the training-mode logits."""
    cfg = get_reduced(arch)
    model = build_model(cfg)
    rng = np.random.default_rng(3)
    params = model.init(jax.random.key(2))
    B, S = 1, 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    # full-sequence hidden -> logits at each position
    if cfg.is_encoder_decoder:
        pytest.skip("covered via whisper-specific test")
    h = model.hidden(params, tokens)
    from repro.models.common import head_logits
    want = head_logits(h, model.head_matrix(params), cfg.final_softcap)

    cache = model.init_cache(B, S)
    got = []
    step = jax.jit(model.decode_step)
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t:t + 1])
        got.append(np.asarray(logits[:, 0]))
    got = np.stack(got, axis=1)
    np.testing.assert_allclose(got, np.asarray(want), rtol=2e-2, atol=2e-3)
