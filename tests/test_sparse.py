"""Generators + BSR container invariants."""
import numpy as np
import pytest

from repro.sparse import (BSR, CSR, linear_elasticity_2d, poisson_2d,
                          random_fixed_nnz, rotated_anisotropic_2d)
from repro.sparse import suitesparse_like


def test_poisson_2d_is_laplacian():
    a = poisson_2d(8)
    d = a.to_dense()
    np.testing.assert_allclose(d, d.T)
    # interior row sums are zero (constant in the null space of the stencil)
    interior = np.arange(8 * 8).reshape(8, 8)[2:-2, 2:-2].reshape(-1)
    np.testing.assert_allclose(d[interior].sum(axis=1), 0.0, atol=1e-12)
    assert (np.diag(d) > 0).all()


def test_rotated_anisotropic_symmetric_spd_ish():
    a = rotated_anisotropic_2d(10, eps=0.01, theta=np.pi / 3)
    d = a.to_dense()
    np.testing.assert_allclose(d, d.T, atol=1e-12)
    w = np.linalg.eigvalsh(d)
    assert w.min() > -1e-8  # PSD up to roundoff (pure Neumann -> singular ok)


def test_linear_elasticity_spd():
    a = linear_elasticity_2d(6)
    d = a.to_dense()
    np.testing.assert_allclose(d, d.T, atol=1e-8 * np.abs(d).max())
    w = np.linalg.eigvalsh(d)
    assert w.min() > 0, "Dirichlet-pinned elasticity must be SPD"


def test_random_fixed_nnz_row_counts():
    a = random_fixed_nnz(100, 7, seed=1)
    counts = np.diff(a.indptr)
    assert counts.max() <= 7
    assert counts.min() >= 1
    assert a.shape == (100, 100)


@pytest.mark.parametrize("name", ["nlpkkt240", "audikw_1", "StocF-1465"])
def test_suitesparse_like_builds(name):
    a = suitesparse_like.build(name, scale=8192)
    assert a.shape[0] >= 256
    assert a.nnz > a.shape[0]
    d = a.to_dense()
    np.testing.assert_allclose(d, d.T, atol=1e-12)  # surrogates are symmetric


@pytest.mark.parametrize("bm,bn", [(2, 2), (4, 8), (8, 4)])
def test_bsr_roundtrip_matvec(bm, bn):
    rng = np.random.default_rng(0)
    dense = rng.standard_normal((32, 24)) * (rng.random((32, 24)) < 0.15)
    a = CSR.from_dense(dense)
    b = BSR.from_csr(a, bm=bm, bn=bn)
    pad = b.to_dense()
    np.testing.assert_allclose(pad[:32, :24], dense, rtol=1e-6)
    v = rng.standard_normal(b.shape[1])
    want = pad @ v
    np.testing.assert_allclose(b.matvec(v), want, rtol=1e-5)


def test_bsr_padded_uniform_consistent():
    rng = np.random.default_rng(1)
    dense = rng.standard_normal((16, 16)) * (rng.random((16, 16)) < 0.3)
    b = BSR.from_csr(CSR.from_dense(dense), bm=4, bn=4)
    cols, blocks, kmax = b.padded_uniform()
    assert cols.shape == (4, kmax) and blocks.shape == (4, kmax, 4, 4)
    # rebuild dense from the padded layout
    out = np.zeros(b.shape)
    for i in range(4):
        for k in range(kmax):
            if cols[i, k] >= 0:
                out[i * 4:(i + 1) * 4, cols[i, k] * 4:(cols[i, k] + 1) * 4] = blocks[i, k]
    np.testing.assert_allclose(out, b.to_dense(), rtol=0)
