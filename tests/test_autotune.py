"""Density-driven local-compute format autotuner (single-process).

Covers the cost-model chooser's three regimes (dense blocks -> bsr,
flat low-density rows -> ell, skewed rows / VMEM-hostile -> coo), the
stats + verdict compile_nap records on CompiledNAP, the packed ELL
emission's layout invariant, and the cache-key extensions that keep
``local_compute`` / tuner switches from returning stale plans.
"""
import numpy as np
import pytest

from repro.core.cost_model import (LOCAL_FORMATS, LocalComputeParams,
                                   TPU_V5E_LOCAL, choose_local_format,
                                   local_format_times)
from repro.core.partition import contiguous_partition, make_partition
from repro.core.spmv import split_all_blocks
from repro.core.spmv_jax import (clear_compile_cache, compile_nap)
from repro.core.topology import Topology
from repro.sparse import CSR, ELL, random_fixed_nnz

TOPOS = [(1, 4), (2, 2), (4, 2)]


# ---------------------------------------------------------------------------
# chooser regimes
# ---------------------------------------------------------------------------

def test_chooser_prefers_bsr_on_dense_blocks():
    stats = {"rows_pad": 256, "n_x": 320, "nnz_pad": 2048,
             "bsr_blocks": 36, "bm": 8, "bn": 8, "ell_kmax": 8}
    times = local_format_times(stats)
    assert choose_local_format(stats) == "bsr"
    assert times["bsr"] < times["ell"] < times["coo"]


def test_chooser_prefers_ell_on_flat_low_density():
    # the BENCH block-hostile regime: ~8 nnz/row, (8, 128) tiles at <1% fill
    stats = {"rows_pad": 256, "n_x": 1408, "nnz_pad": 2111,
             "bsr_blocks": 352, "bm": 8, "bn": 128, "ell_kmax": 8}
    assert choose_local_format(stats) == "ell"


def test_chooser_prefers_coo_on_skewed_rows():
    # one super-dense row blows up ELL's kmax padding
    stats = {"rows_pad": 256, "n_x": 1408, "nnz_pad": 2300,
             "bsr_blocks": 352, "bm": 8, "bn": 128, "ell_kmax": 2000}
    assert choose_local_format(stats) == "coo"


def test_chooser_rejects_ell_when_x_exceeds_vmem():
    stats = {"rows_pad": 4096, "n_x": 6_000_000, "nnz_pad": 40_000,
             "bsr_blocks": 5000, "bm": 8, "bn": 128, "ell_kmax": 12}
    assert local_format_times(stats)["ell"] == float("inf")
    assert choose_local_format(stats) != "ell"


# ---------------------------------------------------------------------------
# compile-time recording
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nn,ppn", TOPOS)
def test_compile_records_autotune_verdict(nn, ppn):
    topo = Topology(n_nodes=nn, ppn=ppn)
    a = random_fixed_nnz(64, 5, seed=1)
    part = make_partition("contiguous", 64, topo.n_procs)
    compiled = compile_nap(a, part, topo, block_shape=(8, 16), cache=False)
    at = compiled.autotune
    assert at["chosen"] in LOCAL_FORMATS
    assert set(at["times"]) == set(LOCAL_FORMATS)
    assert len(at["per_rank"]) == topo.n_procs
    for entry in at["per_rank"]:
        assert entry["choice"] in LOCAL_FORMATS
        assert 0.0 <= entry["bsr_fill"] <= 1.0
        assert entry["ell_kmax"] >= 1
    assert compiled.chosen_local_compute == at["chosen"]
    assert compiled.resolve_local_compute("auto") == at["chosen"]
    assert compiled.resolve_local_compute("coo") == "coo"
    with pytest.raises(ValueError):
        compiled.resolve_local_compute("csr")


def test_block_hostile_low_density_selects_non_bsr():
    """<= 12 nnz/row at (8, 128) tiles densifies ~1/fill: never pick bsr."""
    topo = Topology(n_nodes=2, ppn=4)
    for seed, nnz_row in ((0, 8), (1, 12), (2, 4)):
        a = random_fixed_nnz(2048, nnz_row, seed=seed)
        part = contiguous_partition(2048, topo.n_procs)
        compiled = compile_nap(a, part, topo, cache=False)
        assert compiled.chosen_local_compute in ("ell", "coo")
        assert all(e["choice"] in ("ell", "coo")
                   for e in compiled.autotune["per_rank"])


def test_dense_block_diagonal_selects_bsr():
    """Dense (8, 8) diagonal blocks are the MXU's home turf."""
    n, b = 128, 8
    rng = np.random.default_rng(3)
    dense = np.zeros((n, n))
    for i in range(0, n, b):
        dense[i:i + b, i:i + b] = rng.standard_normal((b, b))
    a = CSR.from_dense(dense)
    topo = Topology(n_nodes=2, ppn=2)
    part = contiguous_partition(n, topo.n_procs)
    compiled = compile_nap(a, part, topo, block_shape=(8, 8), cache=False)
    assert compiled.chosen_local_compute == "bsr"


# ---------------------------------------------------------------------------
# packed ELL emission
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nn,ppn", TOPOS)
def test_packed_ell_layout_equals_local_blocks(nn, ppn):
    """The ELL arrays, viewed densely per rank, reproduce the three column
    blocks at their packed-domain offsets (v_loc | on-node | off-node)."""
    topo = Topology(n_nodes=nn, ppn=ppn)
    a = random_fixed_nnz(60, 6, seed=11)
    part = make_partition("contiguous", 60, topo.n_procs)
    compiled = compile_nap(a, part, topo, block_shape=(8, 16), cache=False)
    compiled.ensure_ell()
    rows_pad, pads = compiled.rows_pad, compiled.pads
    for r, blk in enumerate(split_all_blocks(a, part, topo)):
        ell = ELL(cols=compiled.arrays["ell_cols"][r],
                  vals=compiled.arrays["ell_vals"][r],
                  shape=(rows_pad, compiled.packed_x_len))
        dense = ell.to_dense()
        nr = blk.rows.size
        np.testing.assert_allclose(dense[:nr, :nr], blk.on_proc.to_dense(),
                                   atol=1e-6)
        o = rows_pad
        np.testing.assert_allclose(dense[:nr, o:o + blk.on_node.shape[1]],
                                   blk.on_node.to_dense(), atol=1e-6)
        o = rows_pad + pads["bnode"]
        np.testing.assert_allclose(dense[:nr, o:o + blk.off_node.shape[1]],
                                   blk.off_node.to_dense(), atol=1e-6)
        assert not dense[nr:].any()


def test_packed_segments_are_lane_aligned():
    """Every packed-x segment length is rounded to the bn lane width, so the
    kernels can view v_loc / b_on_node / b_off_node zero-copy."""
    topo = Topology(n_nodes=2, ppn=2)
    a = random_fixed_nnz(50, 5, seed=2)      # 50 rows -> ragged per-rank counts
    part = make_partition("contiguous", 50, topo.n_procs)
    for bn in (8, 16, 128):
        compiled = compile_nap(a, part, topo, block_shape=(8, bn), cache=False)
        assert compiled.rows_pad % bn == 0
        assert compiled.pads["bnode"] % bn == 0
        assert compiled.pads["boff"] % bn == 0


# ---------------------------------------------------------------------------
# cache keying
# ---------------------------------------------------------------------------

def test_cache_distinguishes_local_compute_and_tuner():
    clear_compile_cache()
    topo = Topology(n_nodes=2, ppn=2)
    a = random_fixed_nnz(60, 6, seed=9)
    part = make_partition("contiguous", 60, topo.n_procs)
    c_auto = compile_nap(a, part, topo)
    assert compile_nap(a, part, topo) is c_auto
    c_ell = compile_nap(a, part, topo, local_compute="ell")
    assert c_ell is not c_auto
    assert compile_nap(a, part, topo, local_compute="ell") is c_ell
    # a compile-time format request is an override that "auto" executors
    # resolve to (explicit executor requests still win)
    assert c_ell.resolve_local_compute("auto") == "ell"
    assert c_ell.resolve_local_compute("coo") == "coo"
    assert c_auto.resolve_local_compute("auto") == c_auto.autotune["chosen"]
    # autotuner inputs (rate model) are part of the key too
    slow_scatter = LocalComputeParams(scatter_flops=1.0)
    c_tuned = compile_nap(a, part, topo, tuner=slow_scatter)
    assert c_tuned is not c_auto
    assert c_tuned.autotune["times"]["coo"] > c_auto.autotune["times"]["coo"]
    with pytest.raises(ValueError):
        compile_nap(a, part, topo, local_compute="csr")
    clear_compile_cache()
