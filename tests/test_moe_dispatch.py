"""The MoE NAP-dispatch subsystem (repro.moe): plan layer + executors.

Single-process tier-1 sweep — the simulate-backed moe executors, the
routing-matrix plan layer, the quantized wire codecs and their error
budgets, and the integrity threading over QUANTIZED messages.  The
in-graph shard_map face is tests/multidev/moe_dispatch_prog.py.
"""
import numpy as np
import pytest

import repro.api as nap
from repro.core.topology import Topology
from repro.models.config import ModelConfig
from repro.moe.dispatch import dispatch_operator
from repro.moe.plan import (DISPATCH_MODES, choose_dispatch,
                            dispatch_partitions, dispatch_traffic,
                            representative_routing, routing_matrix)
from repro.moe.wire import (WIRE_DTYPES, check_wire_dtype, decode_np,
                            dispatch_error_budget, encode_np, quantize_np,
                            wire_bytes, wire_error_bound)

TOPO = Topology(n_nodes=2, ppn=4)
T, E, K, NV = 128, 8, 4, 8


@pytest.fixture(scope="module")
def routing():
    ids, w = representative_routing(T, E, K, seed=3)
    return ids, w, routing_matrix(ids, w, E)


@pytest.fixture(scope="module")
def parts():
    return dispatch_partitions(E, T, TOPO)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(1)
    return rng.standard_normal((T, NV)) * 0.5, rng.standard_normal((E, NV))


def _moe_op(r, parts, **kw):
    ep, tp = parts
    return nap.operator(r, topo=TOPO, row_part=ep, col_part=tp,
                        backend="moe", **kw)


def _sim_op(r, parts, method):
    ep, tp = parts
    return nap.operator(r, topo=TOPO, row_part=ep, col_part=tp,
                        backend="simulate", method=method)


# ---------------------------------------------------------------------------
# plan layer
# ---------------------------------------------------------------------------

def test_routing_matrix_shape_and_weights(routing):
    ids, w, r = routing
    assert r.shape == (E, T)
    dense = r.to_dense()
    # column t holds token t's router weights at its expert rows
    for t in (0, 17, T - 1):
        for k in range(K):
            assert dense[ids[t, k], t] == pytest.approx(w[t, k])
    # normalized top-k weights sum to 1 per token
    np.testing.assert_allclose(dense.sum(axis=0), 1.0, rtol=1e-12)


def test_routing_matrix_rejects_out_of_range():
    ids = np.array([[0, E]], np.int32)     # E is out of range
    w = np.array([[0.5, 0.5]])
    with pytest.raises(ValueError):
        routing_matrix(ids, w, E)


def test_routing_matrix_drops_negative_ids():
    # a dropped (capacity-overflowed) token copy is encoded as id -1:
    # it must simply vanish from the matrix, not raise
    ids = np.array([[0, -1], [1, 2]], np.int32)
    w = np.array([[1.0, 0.25], [0.5, 0.5]])
    r = routing_matrix(ids, w, E)
    assert r.nnz == 3
    assert r.to_dense()[0, 0] == 1.0


def test_dispatch_partitions_divisibility():
    with pytest.raises(ValueError):
        dispatch_partitions(E + 1, T, TOPO)   # 9 experts over 8 chips


def test_choose_dispatch_prefers_fewer_inter_bytes(routing):
    _, _, r = routing
    ep, tp = dispatch_partitions(E, T, TOPO)
    verdict = choose_dispatch(r, ep, tp, TOPO, nv=NV)
    for d in ("dispatch", "combine"):
        v = verdict[d]
        assert v["chosen"] in ("flat", "nap")
        chosen = v["candidates"][v["chosen"]]["injected_inter_bytes"]
        for s in v["candidates"].values():
            assert chosen <= s["injected_inter_bytes"]


def test_dispatch_traffic_scales_with_wire_dtype(routing, parts):
    _, _, r = routing
    ep, tp = parts
    from repro.moe.plan import build_dispatch_plans
    plan = build_dispatch_plans(r, ep, tp, TOPO)["nap"]
    t32 = dispatch_traffic(plan, wire_dtype="f32", nv=NV)
    t8 = dispatch_traffic(plan, wire_dtype="fp8_e4m3", nv=NV)
    assert t8["injected_inter_bytes"] * 4 == t32["injected_inter_bytes"]
    assert t8["injected_intra_bytes"] * 4 == t32["injected_intra_bytes"]


# ---------------------------------------------------------------------------
# wire codecs
# ---------------------------------------------------------------------------

def test_f32_codec_is_identity():
    x = np.random.default_rng(0).standard_normal(64).astype(np.float32)
    assert encode_np(x, "f32") is x or np.array_equal(encode_np(x, "f32"), x)
    assert np.array_equal(quantize_np(x, "f32"), x)
    assert wire_bytes("f32") == 4


def test_codec_roundtrip_error_bounds():
    rng = np.random.default_rng(0)
    x = rng.standard_normal(4096) * 3.0
    for wd, u in (("bf16", 2.0 ** -8), ("fp8_e4m3", 2.0 ** -4)):
        q = decode_np(encode_np(x, wd), wd)
        d = 2.0 ** -10 if wd == "fp8_e4m3" else 0.0
        assert np.all(np.abs(q - x) <= u * np.abs(x) + d + 1e-12), wd
        assert not np.array_equal(q, x)


def test_fp8_saturates():
    x = np.array([1e6, -1e6, 500.0], np.float64)
    q = quantize_np(x, "fp8_e4m3")
    assert np.all(np.isfinite(q)) and np.abs(q).max() <= 448.0


def test_check_wire_dtype_rejects_unknown():
    with pytest.raises(ValueError, match="f32|bf16|fp8_e4m3"):
        check_wire_dtype("int4")


# ---------------------------------------------------------------------------
# executors: f32 bitwise vs the MATCHING float64 simulator
# ---------------------------------------------------------------------------

def test_f32_bitwise_vs_matching_simulator(routing, parts, data):
    _, _, r = routing
    x, y = data
    oracle = {"flat": _sim_op(r, parts, "standard"),
              "nap": _sim_op(r, parts, "nap")}
    oracle["auto"] = oracle["nap"]          # nap wins both directions here
    for method in DISPATCH_MODES:
        op = _moe_op(r, parts, method=method)
        ref = oracle[method]
        assert np.array_equal(op @ x, ref @ x), (method, "forward")
        assert np.array_equal(op.T @ y, ref.T @ y), (method, "combine")


def test_flat_and_nap_agree_within_roundoff(routing, parts, data):
    _, _, r = routing
    x, _ = data
    flat = _moe_op(r, parts, method="flat") @ x
    napd = _moe_op(r, parts, method="nap") @ x
    np.testing.assert_allclose(flat, napd, rtol=1e-12, atol=1e-13)


def test_wire_none_matches_forced_f32_wire(routing, parts, data):
    # f32 with no faults uses wire=None (no SimWire in the loop); arming
    # integrity forces a checksummed f32 wire — results must be bitwise equal
    _, _, r = routing
    x, _ = data
    plain = _moe_op(r, parts, method="nap") @ x
    forced = _moe_op(r, parts, method="nap", integrity="detect") @ x
    assert np.array_equal(plain, forced)


def test_quantized_within_error_budget(routing, parts, data):
    _, _, r = routing
    x, _ = data
    ref = {"flat": _sim_op(r, parts, "standard") @ x,
           "nap": _sim_op(r, parts, "nap") @ x}
    for wd in ("bf16", "fp8_e4m3"):
        budget = dispatch_error_budget(r, x, wd, hops=1)
        for method in ("flat", "nap"):
            out = _moe_op(r, parts, method=method, wire_dtype=wd) @ x
            assert np.all(np.abs(out - ref[method]) <= budget), (method, wd)
            assert not np.array_equal(out, ref[method]), \
                f"{method}/{wd} must actually quantize"


def test_byte_accounting_tracks_wire_dtype(routing, parts):
    _, _, r = routing
    stats = {wd: _moe_op(r, parts, method="nap", wire_dtype=wd).stats()
             for wd in WIRE_DTYPES}
    for wd in WIRE_DTYPES:
        assert stats[wd]["bytes_per_val"] == wire_bytes(wd)
        assert stats[wd]["wire_dtype"] == wd
    # the acceptance inequality: fp8 wire <= 0.55x the f32 wire
    ratio = (stats["fp8_e4m3"]["dispatch_injected_inter_bytes"]
             / stats["f32"]["dispatch_injected_inter_bytes"])
    assert ratio <= 0.55


def test_wire_error_bound_scales_with_hops():
    cfg_flat = _cfg(moe_dispatch="flat", wire_dtype="bf16")
    cfg_nap = _cfg(moe_dispatch="nap", wire_dtype="bf16")
    assert wire_error_bound(cfg_nap) == 2 * wire_error_bound(cfg_flat)
    assert wire_error_bound(wire_dtype="fp8_e4m3", hops=1) > \
        wire_error_bound(wire_dtype="bf16", hops=1)


# ---------------------------------------------------------------------------
# edges: empty experts and dropped tokens
# ---------------------------------------------------------------------------

def test_empty_expert_rows(parts, data):
    # all tokens route to experts {0, 1}: six expert rows are EMPTY and the
    # plan layer must not choke on zero-traffic destinations
    rng = np.random.default_rng(5)
    ids = rng.integers(0, 2, size=(T, 2)).astype(np.int32)
    ids[:, 1] = 1 - ids[:, 0]               # distinct experts per token
    w = np.full((T, 2), 0.5)
    r = routing_matrix(ids, w, E)
    x, _ = data
    out = _moe_op(r, parts, method="nap") @ x
    ref = _sim_op(r, parts, "nap") @ x
    assert np.array_equal(out, ref)
    assert np.array_equal(out[2:], np.zeros_like(out[2:]))  # empty experts


def test_dropped_tokens(parts, data):
    # capacity-dropped copies (-1 ids) vanish: the matching columns are
    # empty and the combine still matches the simulator bitwise
    ids, w = representative_routing(T, E, K, seed=3)
    ids[::7] = -1                           # drop every 7th token entirely
    r = routing_matrix(ids, w, E)
    x, y = data
    op = _moe_op(r, parts, method="nap")
    ref = _sim_op(r, parts, "nap")
    assert np.array_equal(op @ x, ref @ x)
    back = op.T @ y
    assert np.array_equal(back[::7], np.zeros_like(back[::7]))


# ---------------------------------------------------------------------------
# integrity over QUANTIZED messages
# ---------------------------------------------------------------------------

FAULT = dict(node=1, proc=0, slot=0, element=2, bit=6)


def test_detect_attributes_quantized_fault(routing, parts, data):
    _, _, r = routing
    x, _ = data
    op = _moe_op(r, parts, method="nap", wire_dtype="fp8_e4m3",
                 integrity="detect")
    _ = op @ x                              # clean apply passes
    op.inject_fault("inter", kind="bitflip", **FAULT)
    with pytest.raises(nap.IntegrityError) as ei:
        op @ x
    assert ei.value.mismatches and ei.value.mismatches[0].phase == "inter"
    rep = op.integrity_report()
    assert rep["faults_injected"] == 1      # the fault actually fired
    assert rep["wire_mismatches"] == 1 and rep["by_scope"]["off_node"] == 1


def test_recover_bit_identical_quantized(routing, parts, data):
    _, _, r = routing
    x, _ = data
    op = _moe_op(r, parts, method="nap", wire_dtype="fp8_e4m3",
                 integrity="recover")
    base = op @ x                           # fault-free quantized result
    op.inject_fault("inter", kind="bitflip", **FAULT)
    assert np.array_equal(op @ x, base), \
        "recover must retry through a clean quantizing wire"
    rep = op.integrity_report()
    assert rep["faults_injected"] == 1 and rep["retries"] == 1 \
        and rep["recovered"] == 1


# ---------------------------------------------------------------------------
# config + api validation
# ---------------------------------------------------------------------------

def _cfg(**kw):
    return ModelConfig(name="t", family="moe", n_layers=1, d_model=NV,
                       n_heads=1, n_kv_heads=1, d_ff=8, vocab=8, n_experts=E,
                       top_k=K, moe_dff=8, **kw)


def test_model_config_validates_dispatch_fields():
    with pytest.raises(ValueError, match="flat|nap|auto"):
        _cfg(moe_dispatch="bogus")
    with pytest.raises(ValueError, match="f32|bf16|fp8_e4m3"):
        _cfg(wire_dtype="int4")
    _cfg(moe_dispatch="auto", wire_dtype="fp8_e4m3")   # valid combos pass


def test_wire_dtype_is_moe_only(routing, parts):
    _, _, r = routing
    ep, tp = parts
    with pytest.raises(ValueError, match="moe"):
        nap.operator(r, topo=TOPO, row_part=ep, col_part=tp,
                     backend="simulate", method="standard", wire_dtype="bf16")


def test_dispatch_operator_front_door(routing, parts, data):
    ids, w, r = routing
    x, _ = data
    op = dispatch_operator(_cfg(moe_dispatch="auto"), topo=TOPO,
                           routing=(ids, w))
    ref = _sim_op(r, parts, "nap") @ x      # auto resolves to nap here
    assert np.array_equal(op @ x, ref)
    rep = op.autotune_report()
    assert rep["dispatch_resolved"] in ("flat", "nap")
    assert rep["combine_resolved"] in ("flat", "nap")
    assert rep["wire_dtype"] == "f32"
