"""Example 2.1 of the paper, tested against its worked tables.

The 6x6 matrix of Fig. 4 is reconstructed from the paper's Tables 5, 6, 9,
13 and 15 (the figure itself is an image):

    row 0: {0, 1, 3, 4, 5}
    row 1: {1}
    row 2: {2, 3}
    row 3: {0, 3}
    row 4: {1, 2, 4}
    row 5: {0, 1, 5}

(A[5,1] is implied by the dedup motivation of Sec. 4.1: v1 reaches node 2
once under NAP — Table 9 routes E(0,2) = {0,1} to (1,2), and Table 13 has
(1,2) forward only {1} to (0,2), so (1,2) itself consumes v0 and v1.)

Six processes across three nodes (ppn = 2); rank r owns row r (Fig. 3).

Exact-match tests cover the unambiguous tables (1, 2, 5, 6, 14, 15).  The
T/U process assignment of Tables 7-13 depends on an ordering rule that the
paper's own worked example does not apply consistently (see comm_graph.py
docstring), so those are verified through *invariants*: one aggregated
message per communicating node pair, network-injection only in the inter
phase, and exact delivery of every needed value.
"""
import numpy as np
import pytest

from repro.core.comm_graph import build_nap_plan, build_standard_plan, nap_stats, standard_stats
from repro.core.partition import contiguous_partition
from repro.core.spmv import DistSpMV, simulate_nap_spmv, simulate_standard_spmv
from repro.core.topology import Topology, paper_example_topology
from repro.sparse.csr import CSR


def example_matrix() -> CSR:
    rows_cols = {0: [0, 1, 3, 4, 5], 1: [1], 2: [2, 3], 3: [0, 3], 4: [1, 2, 4], 5: [0, 1, 5]}
    rows, cols = [], []
    for i, js in rows_cols.items():
        for j in js:
            rows.append(i)
            cols.append(j)
    vals = 1.0 + np.arange(len(rows)) * 0.25  # distinct values catch routing bugs
    return CSR.from_coo(np.array(rows), np.array(cols), vals, (6, 6))


@pytest.fixture
def setup():
    a = example_matrix()
    topo = paper_example_topology()
    part = contiguous_partition(6, topo.n_procs)  # rank r owns row r
    return a, topo, part


def test_topology_tuples():
    topo = paper_example_topology()
    assert topo.n_procs == 6
    assert [topo.proc_node(r) for r in range(6)] == [
        (0, 0), (1, 0), (0, 1), (1, 1), (0, 2), (1, 2)]
    assert topo.rank(1, 2) == 5


def test_standard_plan_P_and_D(setup):
    """Tables 1-2 ground truth (derived from the reconstructed Fig. 4)."""
    a, topo, part = setup
    plan = build_standard_plan(a.indptr, a.indices, part, topo)
    assert plan.P(0) == [3, 5]
    assert plan.P(1) == [0, 4, 5]
    assert plan.D(1, 5).tolist() == [1]
    assert plan.P(2) == [4]
    assert plan.P(3) == [0, 2]
    assert plan.P(4) == [0]
    assert plan.P(5) == [0]
    assert plan.D(0, 3).tolist() == [0]
    assert plan.D(0, 5).tolist() == [0]
    assert plan.D(1, 0).tolist() == [1]
    assert plan.D(1, 4).tolist() == [1]
    assert plan.D(3, 0).tolist() == [3]
    assert plan.D(3, 2).tolist() == [3]
    assert plan.D(4, 0).tolist() == [4]
    assert plan.D(5, 0).tolist() == [5]
    assert plan.D(2, 4).tolist() == [2]
    assert plan.D(0, 1).size == 0  # no such message


def test_node_sets_table5_table6(setup):
    """Exact match with paper Tables 5 and 6."""
    a, topo, part = setup
    plan = build_nap_plan(a.indptr, a.indices, part, topo)
    assert plan.N(0) == [1, 2]
    assert plan.N(1) == [0, 2]
    assert plan.N(2) == [0]
    assert plan.E(0, 1).tolist() == [0]
    assert plan.E(0, 2).tolist() == [0, 1]
    assert plan.E(1, 0).tolist() == [3]
    assert plan.E(1, 2).tolist() == [2]
    assert plan.E(2, 0).tolist() == [4, 5]
    assert plan.E(2, 1).size == 0


def test_fully_local_table15(setup):
    """Table 15: (1,0) sends {1} to (0,0); (1,1) sends {3} to (0,1)."""
    a, topo, part = setup
    plan = build_nap_plan(a.indptr, a.indices, part, topo)
    sends = {(m.src, m.dst): m.idx.tolist()
             for msgs in plan.local_full_sends for m in msgs}
    assert sends == {(1, 0): [1], (3, 2): [3]}


@pytest.mark.parametrize("pairing", ["balanced", "aligned"])
def test_inter_node_invariants(setup, pairing):
    a, topo, part = setup
    plan = build_nap_plan(a.indptr, a.indices, part, topo, pairing=pairing)
    # 1. every inter-node message really crosses nodes, locals stay local
    for msgs in plan.inter_sends:
        for m in msgs:
            assert topo.node_of(m.src) != topo.node_of(m.dst)
    for phase in (plan.local_init_sends, plan.local_final_sends, plan.local_full_sends):
        for msgs in phase:
            for m in msgs:
                assert topo.node_of(m.src) == topo.node_of(m.dst)
    # 2. the union of inter-node payloads for a node pair equals E(n, m):
    per_pair = {}
    for msgs in plan.inter_sends:
        for m in msgs:
            key = (topo.node_of(m.src), topo.node_of(m.dst))
            per_pair.setdefault(key, []).append(m.idx)
    for (n, mm), chunks in per_pair.items():
        got = np.sort(np.concatenate(chunks))
        assert got.tolist() == plan.E(n, mm).tolist()
        # 3. deduplicated: no index crosses the network twice for one pair
        assert len(np.unique(got)) == len(got)
    assert set(per_pair) == set(plan.node_idx)
    # 4. if aligned: sender local id == receiver local id (TPU all-to-all form)
    if pairing == "aligned":
        for msgs in plan.inter_sends:
            for m in msgs:
                assert topo.local_of(m.src) == topo.local_of(m.dst)


def test_paper_example_message_reduction(setup):
    """The headline claim, on the worked example: NAP injects fewer (and no
    duplicated) values into the network than the standard SpMV."""
    a, topo, part = setup
    std = build_standard_plan(a.indptr, a.indices, part, topo)
    nap = build_nap_plan(a.indptr, a.indices, part, topo)
    s = standard_stats(std)
    n = nap_stats(nap)
    assert n["inter"].total_bytes <= s["inter"].total_bytes
    assert n["inter"].total_msgs <= s["inter"].total_msgs
    # the example has a duplicated value (v0 -> node 2 twice in standard):
    assert n["inter"].total_bytes < s["inter"].total_bytes


@pytest.mark.parametrize("algorithm", ["standard", "nap"])
@pytest.mark.parametrize("pairing", ["balanced", "aligned"])
def test_spmv_exactness(setup, algorithm, pairing):
    a, topo, part = setup
    dist = DistSpMV.build(a, part, topo, pairing=pairing)
    rng = np.random.default_rng(0)
    v = rng.standard_normal(6)
    sim = (simulate_standard_spmv(a, v, dist.standard)
           if algorithm == "standard" else simulate_nap_spmv(a, v, dist.nap))
    np.testing.assert_allclose(sim, a.matvec(v), rtol=1e-13)
