"""Plan compilation: vectorised split/compile, slot maps, cache, traffic.

Single-process tests of everything compile-side (no device mesh needed):
the block splitter's reconstruction invariant, the slot-map lookup tables,
the compile cache, effective-vs-padded traffic accounting, the fused BSR
layout, and the mailbox's duplicate-post guard.
"""
import numpy as np
import pytest

from repro.core.comm_graph import (build_nap_plan, flat_slot_map, lookup_slots,
                                   Message)
from repro.core.partition import make_partition
from repro.core.spmv import _MailBox, split_all_blocks
from repro.core.spmv_jax import (CompiledNAP, clear_compile_cache, compile_nap,
                                 padded_traffic)
from repro.core.topology import Topology
from repro.sparse import random_fixed_nnz
from repro.sparse.bsr import BSR

TOPOS = [(1, 4), (2, 2), (4, 2)]


def problem(nn, ppn, n=60, nnz=6, kind="contiguous", seed=0):
    topo = Topology(n_nodes=nn, ppn=ppn)
    a = random_fixed_nnz(n, nnz, seed=seed)
    part = make_partition(kind, n, topo.n_procs,
                          indptr=a.indptr, indices=a.indices, seed=seed)
    return topo, a, part


@pytest.mark.parametrize("nn,ppn", TOPOS)
@pytest.mark.parametrize("kind", ["contiguous", "strided", "balanced"])
def test_split_blocks_reconstruct(nn, ppn, kind):
    """on_proc + on_node + off_node (mapped back to global cols) == A rows."""
    topo, a, part = problem(nn, ppn, kind=kind, seed=3)
    dense = a.to_dense()
    for blk in split_all_blocks(a, part, topo):
        got = np.zeros((blk.rows.size, a.shape[1]))
        got[:, blk.rows] += blk.on_proc.to_dense()
        if blk.on_node_cols.size:
            got[:, blk.on_node_cols] += blk.on_node.to_dense()
        if blk.off_node_cols.size:
            got[:, blk.off_node_cols] += blk.off_node.to_dense()
        np.testing.assert_allclose(got, dense[blk.rows])


def test_flat_slot_map_roundtrip():
    msgs = [Message(src=0, dst=2, idx=np.array([3, 7, 11])),
            Message(src=1, dst=2, idx=np.array([1, 5]))]
    idx, pos = flat_slot_map(msgs, [0, 1], pad=4)
    assert idx.tolist() == [1, 3, 5, 7, 11]
    # slot * pad + position-in-message
    assert lookup_slots((idx, pos), np.array([7, 1, 11])).tolist() == [1, 4, 2]
    with pytest.raises(AssertionError):
        lookup_slots((idx, pos), np.array([2]))  # never delivered


def test_flat_slot_map_rejects_duplicate_delivery():
    msgs = [Message(src=0, dst=2, idx=np.array([3, 7])),
            Message(src=1, dst=2, idx=np.array([7]))]
    with pytest.raises(AssertionError):
        flat_slot_map(msgs, [0, 1], pad=4)


@pytest.mark.parametrize("nn,ppn", TOPOS)
def test_recv_slot_map_matches_messages(nn, ppn):
    topo, a, part = problem(nn, ppn, seed=5)
    plan = build_nap_plan(a.indptr, a.indices, part, topo, pairing="aligned")
    for r in range(topo.n_procs):
        idx, pos = plan.recv_slot_map(r, "inter", pad=100)
        for m in plan.inter_recvs[r]:
            want = topo.node_of(m.src) * 100 + np.arange(m.size)
            got = lookup_slots((idx, pos), m.idx)
            np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("nn,ppn", TOPOS)
def test_padded_traffic_effective_le_padded(nn, ppn):
    topo, a, part = problem(nn, ppn, seed=7)
    t = padded_traffic(compile_nap(a, part, topo, cache=False))
    for phase in ("inter", "full", "init", "final"):
        assert t[f"{phase}_effective"] <= t[f"{phase}_padded"], (phase, t)
    # effective inter bytes must equal the plan's true payload
    plan = build_nap_plan(a.indptr, a.indices, part, topo, pairing="aligned")
    want = 4 * sum(m.size for msgs in plan.inter_sends for m in msgs)
    assert t["inter_effective"] == want


def test_compile_cache_hits_and_distinguishes():
    clear_compile_cache()
    topo, a, part = problem(2, 2, seed=9)
    c1 = compile_nap(a, part, topo)
    assert compile_nap(a, part, topo) is c1                     # pure cache hit
    assert compile_nap(a, part, topo, block_shape=(8, 8)) is not c1
    a2 = random_fixed_nnz(60, 6, seed=10)                        # new structure
    assert compile_nap(a2, part, topo) is not c1
    a3 = random_fixed_nnz(60, 6, seed=9)
    a3.data = a3.data * 2.0                                      # same structure, new values
    assert compile_nap(a3, part, topo) is not c1
    assert compile_nap(a, part, topo, cache=False) is not c1
    clear_compile_cache()


@pytest.mark.parametrize("nn,ppn", TOPOS)
def test_fused_bsr_layout_equals_local_blocks(nn, ppn):
    """The fused blocks, viewed densely per rank, reproduce the three
    column blocks at their layout offsets."""
    topo, a, part = problem(nn, ppn, seed=11)
    compiled = compile_nap(a, part, topo, block_shape=(8, 16), cache=False)
    compiled.ensure_fused()
    lay = compiled.bsr_layout
    bm, bn = compiled.block_shape
    blocks = split_all_blocks(a, part, topo)
    for r, blk in enumerate(blocks):
        cols = compiled.arrays["fused_cols"][r]
        data = compiled.arrays["fused_blocks"][r]
        n_bcols = (lay["vblk"] + lay["nblk"] + lay["oblk"]) // bn
        dense = np.zeros((cols.shape[0] * bm, n_bcols * bn))
        for i in range(cols.shape[0]):
            for k in range(cols.shape[1]):
                c = cols[i, k]
                if c >= 0:
                    dense[i * bm:(i + 1) * bm, c * bn:(c + 1) * bn] += data[i, k]
        nr = blk.rows.size
        np.testing.assert_allclose(
            dense[:nr, :nr], blk.on_proc.to_dense(), atol=1e-6)
        o = lay["vblk"]
        np.testing.assert_allclose(
            dense[:nr, o:o + blk.on_node.shape[1]], blk.on_node.to_dense(),
            atol=1e-6)
        o += lay["nblk"]
        np.testing.assert_allclose(
            dense[:nr, o:o + blk.off_node.shape[1]], blk.off_node.to_dense(),
            atol=1e-6)


def test_mailbox_duplicate_post_fails_loudly():
    box = _MailBox()
    m1 = Message(src=0, dst=1, idx=np.array([2, 4]))
    m2 = Message(src=0, dst=1, idx=np.array([6]))  # same pair, different idx
    box.post(m1, np.array([1.0, 2.0]))
    np.testing.assert_array_equal(box.fetch(m1), [1.0, 2.0])
    with pytest.raises(AssertionError, match="duplicate message"):
        box.post(m2, np.array([3.0]))


def test_bsr_from_coo_matches_from_csr():
    a = random_fixed_nnz(40, 5, seed=1)
    rows, cols, vals = a.to_coo()
    b1 = BSR.from_csr(a, bm=8, bn=8)
    b2 = BSR.from_coo(rows, cols, vals, a.shape, bm=8, bn=8)
    np.testing.assert_array_equal(b1.indptr, b2.indptr)
    np.testing.assert_array_equal(b1.indices, b2.indices)
    np.testing.assert_allclose(b1.data, b2.data)
    np.testing.assert_allclose(b2.to_dense()[:40, :40], a.to_dense(), atol=1e-6)
