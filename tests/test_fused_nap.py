"""Fused Pallas BSR NAPSpMV vs simulator/dense oracles (multi-device subprocess).

The sweep itself lives in tests/multidev/fused_nap_prog.py — it needs a
forced 8-device host platform, which must be set before jax initialises.
"""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


@pytest.mark.multidev
def test_fused_nap_matches_oracles_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)  # the program sets its own device count
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "multidev" / "fused_nap_prog.py")],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL OK" in proc.stdout
