"""hier_collectives correctness on an 8-device host mesh.

Multi-device programs run in a subprocess so the main pytest session keeps a
single CPU device (XLA locks the device count at first init; see launch/dryrun
for the same pattern at 512 devices).
"""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


def run_prog(name: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)  # the program sets its own device count
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "multidev" / name)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


@pytest.mark.multidev
def test_collectives_8dev():
    out = run_prog("collectives_prog.py")
    assert "ALL OK" in out


@pytest.mark.multidev
def test_moe_dispatch_8dev():
    """flat + nap sharded MoE dispatch vs dense oracle, incl. gradients."""
    out = run_prog("moe_dispatch_prog.py")
    assert "ALL OK" in out
