"""Pallas kernel sweeps (interpret mode) vs pure-jnp / numpy oracles."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.bsr_spmv import (bsr_spmm, bsr_spmv, fused_bsr_spmm,
                                    fused_bsr_spmm_packed, fused_bsr_spmm_ref)
from repro.kernels.bsr_spmv.kernel import bsr_spmm_padded
from repro.kernels.bsr_spmv.ref import bsr_spmm_padded_ref, bsr_spmv_ref
from repro.kernels.decode_attn import decode_attention, decode_attention_ref
from repro.kernels.decode_attn.kernel import decode_attention_grouped
from repro.kernels.ell_spmv import (ell_spmm_packed, ell_spmm_packed_ref,
                                    ell_spmv_ref)
from repro.sparse import BSR, CSR, ELL, poisson_2d, random_fixed_nnz


# ---------------------------------------------------------------------------
# BSR SpMV / SpMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bm,bn,nv", [(8, 8, 1), (8, 16, 4), (16, 8, 8),
                                      (32, 32, 16), (8, 128, 128)])
def test_bsr_kernel_vs_ref_shapes(bm, bn, nv):
    rng = np.random.default_rng(bm * 1000 + bn * 10 + nv)
    nbr, nbc, kmax = 3, 4, 3
    cols = rng.integers(-1, nbc, size=(nbr, kmax)).astype(np.int32)
    blocks = rng.standard_normal((nbr, kmax, bm, bn)).astype(np.float32)
    blocks[cols < 0] = 0.0
    x = rng.standard_normal((nbc, bn, nv)).astype(np.float32)
    got = bsr_spmm_padded(jnp.asarray(cols), jnp.asarray(blocks),
                          jnp.asarray(x), interpret=True)
    want = bsr_spmm_padded_ref(jnp.asarray(cols), jnp.asarray(blocks),
                               jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_bsr_spmv_matches_csr_matvec(dtype):
    a = poisson_2d(12)
    bsr = BSR.from_csr(a, bm=8, bn=8)
    rng = np.random.default_rng(0)
    v = rng.standard_normal(a.shape[1]).astype(dtype)
    vpad = np.zeros(bsr.shape[1])
    vpad[: v.size] = v
    got = np.asarray(bsr_spmv(bsr, vpad, interpret=True))[: a.shape[0]]
    want = a.matvec(v.astype(np.float64))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    # and the jnp oracle agrees
    np.testing.assert_allclose(np.asarray(bsr_spmv_ref(bsr, vpad))[: a.shape[0]],
                               want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("bm,bn,nv,nv_block", [
    (8, 8, 1, 128),     # single RHS, no tiling
    (8, 16, 8, 4),      # nv tiled into 2 blocks
    (16, 8, 128, 64),   # wide multi-RHS, 2 nv tiles
    (8, 128, 12, 8),    # nv not a multiple of nv_block (pad + slice)
])
def test_fused_bsr_kernel_vs_ref(bm, bn, nv, nv_block):
    """The fused (nv-tiled) kernel against its gather+einsum oracle."""
    rng = np.random.default_rng(bm * 1000 + bn * 10 + nv + nv_block)
    nbr, nbc, ktot = 3, 5, 4
    cols = rng.integers(-1, nbc, size=(nbr, ktot)).astype(np.int32)
    blocks = rng.standard_normal((nbr, ktot, bm, bn)).astype(np.float32)
    blocks[cols < 0] = 0.0
    x = rng.standard_normal((nbc, bn, nv)).astype(np.float32)
    got = fused_bsr_spmm(jnp.asarray(cols), jnp.asarray(blocks),
                         jnp.asarray(x), nv_block=nv_block, interpret=True)
    want = fused_bsr_spmm_ref(jnp.asarray(cols), jnp.asarray(blocks),
                              jnp.asarray(x))
    assert got.shape == (nbr, bm, nv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_bsr_spmm_multi_vector():
    a = random_fixed_nnz(64, 5, seed=3)
    bsr = BSR.from_csr(a, bm=16, bn=16)
    rng = np.random.default_rng(1)
    x = rng.standard_normal((bsr.shape[1], 8)).astype(np.float32)
    got = np.asarray(bsr_spmm(bsr, x, interpret=True))
    want = bsr.to_dense() @ x
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n_segments", [1, 2, 3])
@pytest.mark.parametrize("nv,nv_block", [(1, 128), (8, 4), (12, 8)])
def test_fused_bsr_packed_bitwise_equals_concat(n_segments, nv, nv_block):
    """The zero-copy segment-routed kernel must equal the materialised-
    concat kernel bit-for-bit (same dots, same accumulation order)."""
    rng = np.random.default_rng(n_segments * 100 + nv + nv_block)
    bm, bn, nbr, ktot = 8, 16, 4, 5
    seg_lens = [3, 2, 4][:n_segments]
    nbc = sum(seg_lens)
    cols = rng.integers(-1, nbc, size=(nbr, ktot)).astype(np.int32)
    blocks = rng.standard_normal((nbr, ktot, bm, bn)).astype(np.float32)
    blocks[cols < 0] = 0.0
    x = rng.standard_normal((nbc, bn, nv)).astype(np.float32)
    bounds = np.cumsum([0] + seg_lens)
    xs = tuple(x[bounds[i]:bounds[i + 1]] for i in range(n_segments))
    got = fused_bsr_spmm_packed(jnp.asarray(cols), jnp.asarray(blocks), xs,
                                nv_block=nv_block, interpret=True)
    want = fused_bsr_spmm(jnp.asarray(cols), jnp.asarray(blocks),
                          jnp.asarray(x), nv_block=nv_block, interpret=True)
    assert np.array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# ELL SpMV / SpMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_segments", [1, 3])
@pytest.mark.parametrize("nv,nv_block,rows_block", [
    (1, 128, 0),    # single RHS, auto row tile
    (8, 4, 0),      # nv tiled into 2 blocks
    (12, 8, 8),     # nv not a multiple of nv_block + forced 8-row tiles
    (128, 64, 16),  # wide multi-RHS
])
def test_ell_packed_kernel_vs_ref(n_segments, nv, nv_block, rows_block):
    rng = np.random.default_rng(n_segments * 10 + nv + rows_block)
    n_rows, kmax = 32, 5
    seg_lens = [16, 8, 24][:n_segments]
    n_x = sum(seg_lens)
    cols = rng.integers(-1, n_x, size=(n_rows, kmax)).astype(np.int32)
    vals = rng.standard_normal((n_rows, kmax)).astype(np.float32)
    vals[cols < 0] = 0.0
    bounds = np.cumsum([0] + seg_lens)
    x = rng.standard_normal((n_x, nv)).astype(np.float32)
    xs = tuple(x[bounds[i]:bounds[i + 1]] for i in range(n_segments))
    got = ell_spmm_packed(jnp.asarray(cols), jnp.asarray(vals), xs,
                          nv_block=nv_block, rows_block=rows_block,
                          interpret=True)
    want = ell_spmm_packed_ref(jnp.asarray(cols), jnp.asarray(vals), xs)
    assert got.shape == (n_rows, nv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    # zero-copy multi-segment == materialised single-segment, bit-for-bit
    got_cat = ell_spmm_packed(jnp.asarray(cols), jnp.asarray(vals), (x,),
                              nv_block=nv_block, rows_block=rows_block,
                              interpret=True)
    assert np.array_equal(np.asarray(got), np.asarray(got_cat))


def test_ell_spmv_matches_csr_matvec():
    a = poisson_2d(12)
    ell = ELL.from_csr(a)
    rng = np.random.default_rng(0)
    v = rng.standard_normal(a.shape[1])
    want = a.matvec(v)
    np.testing.assert_allclose(ell.matvec(v), want, rtol=1e-6)
    got = ell_spmm_packed(jnp.asarray(ell.cols), jnp.asarray(ell.vals),
                          (v.reshape(-1, 1).astype(np.float32),),
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got).ravel()[: a.shape[0]], want,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ell_spmv_ref(ell, v))[: a.shape[0]],
                               want, rtol=1e-4, atol=1e-5)


def test_ell_padding_slots_are_inert():
    """col == -1 slots must not contribute even against nonzero x rows."""
    cols = np.array([[0, -1], [1, 0]], np.int32)
    vals = np.array([[2.0, 0.0], [3.0, 1.0]], np.float32)
    x = np.array([[10.0], [100.0]], np.float32)
    got = ell_spmm_packed(jnp.asarray(np.tile(cols, (4, 1))),
                          jnp.asarray(np.tile(vals, (4, 1))), (x,),
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got)[:2].ravel(), [20.0, 310.0])


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Hkv,g,D,S,block_s", [
    (2, 2, 4, 32, 256, 64),
    (1, 4, 1, 64, 512, 128),
    (3, 1, 8, 16, 128, 128),
])
def test_decode_attn_vs_ref(B, Hkv, g, D, S, block_s):
    rng = np.random.default_rng(B * 100 + S)
    q = rng.standard_normal((B, Hkv, g, D)).astype(np.float32)
    k = rng.standard_normal((B, Hkv, S, D)).astype(np.float32)
    v = rng.standard_normal((B, Hkv, S, D)).astype(np.float32)
    lengths = rng.integers(1, S + 1, size=(B,)).astype(np.int32)
    scale = 1.0 / np.sqrt(D)
    got = decode_attention_grouped(jnp.asarray(q), jnp.asarray(k),
                                   jnp.asarray(v), jnp.asarray(lengths),
                                   scale=scale, block_s=block_s,
                                   interpret=True)
    want = decode_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                jnp.asarray(v), jnp.asarray(lengths),
                                scale=scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_decode_attn_flat_api_and_softcap(softcap):
    rng = np.random.default_rng(7)
    B, H, Hkv, D, S = 2, 8, 2, 32, 200     # S not a block multiple -> padding
    q = rng.standard_normal((B, H, D)).astype(np.float32)
    kc = rng.standard_normal((B, S, Hkv, D)).astype(np.float32)
    vc = rng.standard_normal((B, S, Hkv, D)).astype(np.float32)
    lengths = np.array([150, 200], np.int32)
    got = decode_attention(jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc),
                           jnp.asarray(lengths), softcap=softcap,
                           block_s=64, interpret=True)
    want = decode_attention_ref(
        jnp.asarray(q.reshape(B, Hkv, H // Hkv, D)),
        jnp.asarray(np.swapaxes(kc, 1, 2)), jnp.asarray(np.swapaxes(vc, 1, 2)),
        jnp.asarray(lengths), scale=1.0 / np.sqrt(D), softcap=softcap,
    ).reshape(B, H, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_decode_attn_length_zero_tail_is_ignored():
    """Values beyond `lengths` must not leak into the output."""
    rng = np.random.default_rng(9)
    B, Hkv, g, D, S = 1, 1, 2, 16, 128
    q = rng.standard_normal((B, Hkv, g, D)).astype(np.float32)
    k = rng.standard_normal((B, Hkv, S, D)).astype(np.float32)
    v = rng.standard_normal((B, Hkv, S, D)).astype(np.float32)
    lengths = np.array([40], np.int32)
    out1 = decode_attention_grouped(jnp.asarray(q), jnp.asarray(k),
                                    jnp.asarray(v), jnp.asarray(lengths),
                                    scale=0.25, block_s=32, interpret=True)
    k2, v2 = k.copy(), v.copy()
    k2[:, :, 40:] = 1e6
    v2[:, :, 40:] = -1e6
    out2 = decode_attention_grouped(jnp.asarray(q), jnp.asarray(k2),
                                    jnp.asarray(v2), jnp.asarray(lengths),
                                    scale=0.25, block_s=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)
