"""repro.mesh runtime: discovery, buffers, launcher plumbing, calibration.

Single-process tier-1 checks; the real 2-process jax.distributed run is
the @multidev test at the bottom (tests/multidev/mesh_prog.py).
"""
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

import jax

import repro.api as nap
from repro.core.cost_model import PostalParams, TPU_V5E_POSTAL
from repro.core.topology import Topology
from repro.mesh.buffers import (BufferRegistry, default_registry,
                                fetch_mesh_array, is_multiprocess,
                                stage_mesh_array)
from repro.mesh.discover import discover_topology, discovery_report
from repro.mesh.launcher import (ENV_COORDINATOR, ENV_LOCAL_DEVICES,
                                 ENV_NUM_PROCESSES, ENV_PROCESS_ID,
                                 attach, launch, mesh_env, pick_coordinator)
from repro.sparse import random_fixed_nnz

ROOT = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# discovery
# ---------------------------------------------------------------------------

def test_discover_topology_single_process_fallback():
    """One process, no jax.distributed: Topology(1, n_local_devices)."""
    topo = discover_topology()
    assert topo == Topology(n_nodes=1, ppn=jax.local_device_count())


def test_discovery_report_fields():
    rep = discovery_report()
    assert rep["jax"] and rep["n_nodes"] == 1
    assert rep["device_count"] == jax.device_count()


def test_operator_autodiscovers_topology_bit_identical():
    """operator(a) with topo omitted must equal the declared-topo build
    bit for bit (the single-process half of the mesh_prog oracle)."""
    a = random_fixed_nnz(48, 5, seed=3)
    v = np.random.default_rng(3).standard_normal(48)
    auto = nap.operator(a, backend="shardmap")
    assert auto.topo == discover_topology()
    declared = nap.operator(a, topo=auto.topo, backend="shardmap")
    assert np.array_equal(np.asarray(auto @ v), np.asarray(declared @ v))


# ---------------------------------------------------------------------------
# buffer registry
# ---------------------------------------------------------------------------

def test_buffer_namespace_lifecycle_and_stats():
    reg = BufferRegistry(name="t")
    ns = reg.namespace("plan-a")
    x = np.zeros(16, np.float32)
    assert "k" not in ns
    ns["k"] = x
    assert "k" in ns and ns["k"] is x
    assert reg.stats["staged"] == 1
    assert reg.stats["reused"] == 1          # the read above
    assert reg.resident_bytes() == x.nbytes
    ns.pop("k")
    assert reg.stats["evicted"] == 1 and reg.resident_bytes() == 0
    ns["k2"] = x
    freed = ns.release()
    assert freed == x.nbytes and len(ns) == 0
    assert ns.release() == 0                 # idempotent
    rep = reg.report()
    assert rep["namespaces_created"] == 1 and rep["namespaces_released"] == 1


def test_compiled_plan_buffers_live_in_default_registry():
    reg = default_registry()
    staged_before = reg.stats["staged"]
    a = random_fixed_nnz(48, 5, seed=1)
    op = nap.operator(a, topo=Topology(1, jax.local_device_count()),
                      backend="shardmap")
    _ = op @ np.ones(48)
    assert reg.stats["staged"] > staged_before
    assert reg.resident_bytes() > 0


def test_plancache_eviction_releases_buffers():
    from repro.serve.plancache import PlanCache, release_operator_buffers
    topo = Topology(1, jax.local_device_count())
    cache = PlanCache(topo, backend="shardmap", max_entries=1)
    a = random_fixed_nnz(48, 5, seed=1)
    b = random_fixed_nnz(48, 7, seed=2)
    from repro.core.partition import contiguous_partition
    part = contiguous_partition(48, topo.n_procs)
    op_a = cache.operator_for(a, part)
    _ = op_a @ np.ones(48)
    assert release_operator_buffers(op_a) >= 0   # callable on a live op
    _ = op_a @ np.ones(48)                       # restages on next apply
    cache.operator_for(b, part)                  # evicts op_a's entry
    assert cache.stats["evictions"] == 1
    assert "buffer_bytes_released" in cache.stats
    assert "resident_bytes" in cache.buffer_report()


def test_stage_and_fetch_single_process_bit_identical():
    topo = Topology(1, jax.local_device_count())
    g = np.random.default_rng(0).standard_normal(
        (1, topo.ppn, 6)).astype(np.float32)
    w = stage_mesh_array(g, topo)
    assert np.array_equal(fetch_mesh_array(w), g)
    assert not is_multiprocess()


# ---------------------------------------------------------------------------
# launcher plumbing (no jax.distributed in tier 1)
# ---------------------------------------------------------------------------

def test_mesh_env_and_pick_coordinator():
    coord = pick_coordinator()
    host, port = coord.rsplit(":", 1)
    assert host == "127.0.0.1" and 0 < int(port) < 65536
    env = mesh_env(coord, 4, 2, local_devices=3)
    assert env[ENV_COORDINATOR] == coord
    assert env[ENV_NUM_PROCESSES] == "4"
    assert env[ENV_PROCESS_ID] == "2"
    assert env[ENV_LOCAL_DEVICES] == "3"
    assert ENV_LOCAL_DEVICES not in mesh_env(coord, 4, 2)


def test_attach_is_noop_without_env(monkeypatch):
    monkeypatch.delenv(ENV_COORDINATOR, raising=False)
    info = attach()
    assert info == {"attached": False, "process_id": 0, "num_processes": 1}


def test_launch_fans_out_env(tmp_path):
    """launch() runs a plain script per process with the REPRO_MESH_*
    contract wired (no jax in the children — pure plumbing check)."""
    script = tmp_path / "child.py"
    script.write_text(
        "import os\n"
        "print('pid', os.environ['REPRO_MESH_PROCESS_ID'],\n"
        "      'of', os.environ['REPRO_MESH_NUM_PROCESSES'],\n"
        "      'xla', os.environ['XLA_FLAGS'])\n")
    res = launch(str(script), 2, local_devices=3, timeout_s=60)
    assert res.returncodes == [0, 0]
    for pid in (0, 1):
        assert f"pid {pid} of 2" in res.output(pid)
        assert "device_count=3" in res.output(pid)


def test_launch_surfaces_child_failure(tmp_path):
    from repro.mesh.launcher import LaunchError
    script = tmp_path / "boom.py"
    script.write_text("import sys; print('going down'); sys.exit(3)\n")
    with pytest.raises(LaunchError) as ei:
        launch(str(script), 2, timeout_s=60)
    assert "going down" in str(ei.value)


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

def test_postal_calibrated_recovers_planted_constants():
    alpha_i, beta_i = 2.0e-4, 1.0e8
    alpha_l, beta_l = 3.0e-6, 4.0e9
    rng = np.random.default_rng(0)
    walls = []
    for _ in range(12):
        n, b = int(rng.integers(1, 9)), int(rng.integers(1, 64)) * 4096
        walls.append({"inter": True, "n_msgs": n, "nbytes": b,
                      "seconds": n * alpha_i + b / beta_i})
        walls.append({"inter": False, "n_msgs": n, "nbytes": b,
                      "seconds": n * alpha_l + b / beta_l})
    p = PostalParams.calibrated(walls)
    assert p.alpha_inter == pytest.approx(alpha_i, rel=1e-6)
    assert p.beta_inter == pytest.approx(beta_i, rel=1e-6)
    assert p.alpha_intra == pytest.approx(alpha_l, rel=1e-6)
    assert p.beta_intra == pytest.approx(beta_l, rel=1e-6)
    assert p.name == "calibrated"


def test_postal_calibrated_degrades_to_defaults():
    # fewer than two records per level: every constant stays the default
    p = PostalParams.calibrated([{"inter": True, "n_msgs": 1,
                                  "nbytes": 4096, "seconds": 1e-4}])
    d = TPU_V5E_POSTAL
    assert (p.alpha_inter, p.beta_inter) == (d.alpha_inter, d.beta_inter)
    assert (p.alpha_intra, p.beta_intra) == (d.alpha_intra, d.beta_intra)


# ---------------------------------------------------------------------------
# the real thing: 2 jax.distributed processes vs the declared-topo oracle
# ---------------------------------------------------------------------------

@pytest.mark.multidev
def test_mesh_launcher_2proc_bit_identical():
    """tests/multidev/mesh_prog.py: launch() 2 coordinator-connected
    processes (2 devices each), run op @ x through the autodiscovered
    (2, 2) topology, and require the gathered result to be BIT-IDENTICAL
    to a single-process declared-topo shardmap oracle."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "multidev" / "mesh_prog.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL OK" in proc.stdout
