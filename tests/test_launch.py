"""launch-layer plumbing: shape grid, skip rules, analytic memory math."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import all_arch_ids
from repro.configs.shapes import SHAPES, SUBQUADRATIC, all_cells, cell_runnable
from repro.launch.steps import _sharded_gb
from repro.models.partitioning import _guard


def test_shape_grid_is_40_cells():
    cells = all_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if cell_runnable(*c)[0]]
    skipped = [c for c in cells if not cell_runnable(*c)[0]]
    assert len(skipped) == 8          # long_500k on full-attention archs
    assert all(s == "long_500k" for _, s in skipped)
    assert {"rwkv6-3b", "zamba2-2.7b"} == {
        a for a, s in runnable if s == "long_500k"}


def test_shapes_match_assignment():
    assert (SHAPES["train_4k"].seq_len, SHAPES["train_4k"].global_batch) == (4096, 256)
    assert (SHAPES["prefill_32k"].seq_len, SHAPES["prefill_32k"].global_batch) == (32768, 32)
    assert (SHAPES["decode_32k"].seq_len, SHAPES["decode_32k"].global_batch) == (32768, 128)
    assert (SHAPES["long_500k"].seq_len, SHAPES["long_500k"].global_batch) == (524288, 1)


def test_mesh_module_is_lazy():
    """Importing launch.mesh must not initialise jax devices."""
    import importlib
    import repro.launch.mesh as m
    importlib.reload(m)
    assert callable(m.make_production_mesh)


def test_production_mesh_shape_derives_from_device_count():
    from repro.launch.mesh import production_mesh_shape
    assert production_mesh_shape(256) == (16, 16)      # the classic pod
    assert production_mesh_shape(512) == (16, 32)
    assert production_mesh_shape(8) == (2, 4)
    assert production_mesh_shape(1) == (1, 1)
    assert production_mesh_shape(512, multi_pod=True) == (2, 16, 16)
    assert production_mesh_shape(512, multi_pod=True, n_pods=4) == (4, 8, 16)


def test_production_mesh_shape_errors_name_device_count():
    from repro.launch.mesh import production_mesh_shape
    with pytest.raises(ValueError, match="0 devices"):
        production_mesh_shape(0)
    with pytest.raises(ValueError, match="7 devices"):
        production_mesh_shape(7, multi_pod=True)
    with pytest.raises(ValueError, match="n_pods"):
        production_mesh_shape(8, multi_pod=True, n_pods=1)


def test_make_production_mesh_uses_live_devices():
    """On this single-device host the derived production mesh is (1, 1) —
    no hard-coded (16, 16) demanding 256 devices."""
    from repro.launch.mesh import dp_size, make_production_mesh
    mesh = make_production_mesh()
    assert mesh.shape == {"data": 1, "model": 1}
    assert dp_size(mesh) == 1


def test_sharded_gb_math():
    tree = {"a": jax.ShapeDtypeStruct((16, 32), jnp.float32)}
    spec = {"a": P("data", "model")}
    sizes = {"data": 4, "model": 8}
    got = _sharded_gb(tree, spec, sizes)
    assert got == pytest.approx(16 * 32 * 4 / 32 / 1e9)
    # tuple axes multiply
    spec2 = {"a": P(("pod", "data"), None)}
    got2 = _sharded_gb(tree, spec2, {"pod": 2, "data": 4})
    assert got2 == pytest.approx(16 * 32 * 4 / 8 / 1e9)


def test_divisibility_guard_drops_uneven_axes():
    sizes = {"model": 16, "data": 16}
    assert _guard(P("model", None), (51865, 768), sizes) == P(None, None)
    assert _guard(P("model", None), (256000, 768), sizes) == P("model", None)
    assert _guard(P(("pod", "data"),), (1,), {"pod": 2, "data": 16}) == P(None)


def test_every_arch_has_reduced_config():
    from repro.configs import get_reduced
    for arch in all_arch_ids():
        cfg = get_reduced(arch)
        assert cfg.d_model <= 128, arch   # genuinely reduced
        assert cfg.vocab <= 1024, arch
