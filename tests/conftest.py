def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multidev: runs a subprocess with a forced multi-device host platform")
    config.addinivalue_line(
        "markers",
        "tier1: fast single-process smoke tier (`pytest -m tier1`); "
        "everything not marked multidev")


def pytest_collection_modifyitems(config, items):
    # tier1 = the whole suite minus the slow multi-device subprocess sweeps,
    # so `pytest -m tier1` is the quick smoke alias documented in ROADMAP.
    for item in items:
        if "multidev" not in item.keywords:
            item.add_marker("tier1")
