def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multidev: runs a subprocess with a forced multi-device host platform")
