"""Property tests of the distributed SpMV invariants (seeded numpy sweep).

``hypothesis`` is not installed in the container, so the case generator is
a seeded-numpy parametrized sweep — the invariants actually run under
tier-1 instead of silently skipping.  System invariants, over arbitrary
sparsity / topology / partition / pairing:

  1. exactness — both executors reproduce the scipy matvec in float64 up
     to associativity tolerance, and the TRANSPOSE executors reproduce
     ``A.T @ u`` through the reversed message flow;
  2. NAP never injects more bytes into the network than the standard
     SpMV, and never injects a value twice toward one node;
  3. intra-node phases never cross node boundaries;
  4. every rank touches exactly the off-process values it received
     (checked implicitly by the simulator's access/routing assertions).
"""
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core.comm_graph import (build_nap_plan, build_standard_plan,
                                   nap_stats, standard_stats)
from repro.core.partition import make_partition
from repro.core.spmv import (DistSpMV, simulate_nap_spmv,
                             simulate_nap_spmv_transpose,
                             simulate_standard_spmv,
                             simulate_standard_spmv_transpose)
from repro.core.topology import Topology
from repro.sparse.csr import CSR

N_CASES = 40


def make_case(seed: int):
    """Deterministic analogue of the old hypothesis strategy: topology,
    dense matrix, partition kind and pairing all drawn from one rng."""
    rng = np.random.default_rng(1000 + seed)
    topo = Topology(n_nodes=int(rng.integers(1, 5)),
                    ppn=int(rng.integers(1, 5)))
    n = int(rng.integers(topo.n_procs, 41))
    density = float(rng.uniform(0.05, 0.5))
    mat = (rng.random((n, n)) < density).astype(np.float64)
    mat[np.arange(n), np.arange(n)] = 1.0  # keep a diagonal, like the paper
    mat *= rng.standard_normal((n, n))
    mat[np.arange(n), np.arange(n)] += 2.0
    kind = ["contiguous", "strided", "balanced"][int(rng.integers(3))]
    pairing = ["balanced", "aligned"][int(rng.integers(2))]
    a = CSR.from_dense(mat)
    part = make_partition(kind, n, topo.n_procs, indptr=a.indptr,
                          indices=a.indices, seed=seed)
    return topo, mat, a, part, pairing, rng


@pytest.mark.parametrize("seed", range(N_CASES))
def test_nap_and_standard_match_dense(seed):
    topo, mat, a, part, pairing, rng = make_case(seed)
    dist = DistSpMV.build(a, part, topo, pairing=pairing)
    v = rng.standard_normal(a.shape[0])
    expected = sp.csr_matrix(mat) @ v
    np.testing.assert_allclose(simulate_standard_spmv(a, v, dist.standard),
                               expected, rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(simulate_nap_spmv(a, v, dist.nap),
                               expected, rtol=1e-10, atol=1e-12)


@pytest.mark.parametrize("seed", range(N_CASES))
def test_transpose_matches_dense(seed):
    """z = A.T u through the reversed send/recv roles of BOTH plans."""
    topo, mat, a, part, pairing, rng = make_case(seed)
    dist = DistSpMV.build(a, part, topo, pairing=pairing)
    u = rng.standard_normal(a.shape[0])
    expected = sp.csr_matrix(mat).T @ u
    np.testing.assert_allclose(
        simulate_standard_spmv_transpose(a, u, dist.standard),
        expected, rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(
        simulate_nap_spmv_transpose(a, u, dist.nap),
        expected, rtol=1e-10, atol=1e-12)


@pytest.mark.parametrize("seed", range(N_CASES))
def test_nap_network_injection_never_worse(seed):
    topo, mat, a, part, pairing, _ = make_case(seed)
    std = build_standard_plan(a.indptr, a.indices, part, topo)
    nap = build_nap_plan(a.indptr, a.indices, part, topo, pairing=pairing)
    s, n = standard_stats(std), nap_stats(nap)
    assert n["inter"].total_bytes <= s["inter"].total_bytes
    # deduplication: each (node pair, index) crosses the network at most once
    seen = set()
    for msgs in nap.inter_sends:
        for m in msgs:
            key_base = (topo.node_of(m.src), topo.node_of(m.dst))
            for j in m.idx:
                key = (*key_base, int(j))
                assert key not in seen
                seen.add(key)


N_RECT_CASES = 24


def make_rect_case(seed: int):
    """Rectangular analogue of :func:`make_case`: independent [m, n]
    with tall / wide / empty-rank shapes and independent row/col
    partitions of matching kind."""
    rng = np.random.default_rng(5000 + seed)
    topo = Topology(n_nodes=int(rng.integers(1, 4)),
                    ppn=int(rng.integers(1, 4)))
    shape_kind = seed % 3
    if shape_kind == 0:    # tall
        m = int(rng.integers(topo.n_procs, 41))
        n = int(rng.integers(max(2, m // 3), m + 1))
    elif shape_kind == 1:  # wide
        n = int(rng.integers(topo.n_procs, 41))
        m = int(rng.integers(max(2, n // 3), n + 1))
    else:                  # empty-rank: fewer cols than ranks
        m = int(rng.integers(topo.n_procs * 2 + 1, 41))
        n = int(rng.integers(1, max(2, topo.n_procs)))
    density = float(rng.uniform(0.1, 0.5))
    mat = (rng.random((m, n)) < density) * rng.standard_normal((m, n))
    a = CSR.from_dense(mat)
    kind = ["contiguous", "strided"][int(rng.integers(2))]
    row_part = make_partition(kind, m, topo.n_procs)
    col_part = make_partition(kind, n, topo.n_procs)
    pairing = ["balanced", "aligned"][int(rng.integers(2))]
    return topo, mat, a, row_part, col_part, pairing, rng


@pytest.mark.parametrize("seed", range(N_RECT_CASES))
def test_rectangular_forward_transpose_match_scipy(seed):
    """op @ x and op.T @ y on genuine [m, n] operators with independent
    row/col partitions, against the scipy oracle (simulate backend)."""
    import repro.api as nap

    topo, mat, a, row_part, col_part, pairing, rng = make_rect_case(seed)
    s = sp.csr_matrix(mat)
    op = nap.operator(a, topo=topo, row_part=row_part, col_part=col_part,
                      backend="simulate", pairing=pairing)
    assert op.shape == mat.shape and op.T.shape == mat.shape[::-1]
    x = rng.standard_normal(mat.shape[1])
    y = rng.standard_normal(mat.shape[0])
    np.testing.assert_allclose(op @ x, s @ x, rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(op.T @ y, s.T @ y, rtol=1e-10, atol=1e-12)
    # the standard (Alg. 1) method agrees on the same layout
    op_std = nap.operator(a, topo=topo, row_part=row_part,
                          col_part=col_part, method="standard",
                          backend="simulate")
    np.testing.assert_allclose(op_std @ x, s @ x, rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(op_std.T @ y, s.T @ y, rtol=1e-10, atol=1e-12)


@pytest.mark.parametrize("seed", range(0, N_RECT_CASES, 2))
def test_rectangular_galerkin_composition_matches_scipy(seed):
    """(R @ A @ P) @ x — the lazily composed Galerkin operator over a
    square A and rectangular P with matching interface partitions —
    equals the scipy triple product."""
    import repro.api as nap

    topo, pmat, p, row_part, col_part, pairing, rng = make_rect_case(seed)
    m = pmat.shape[0]
    amat = (rng.random((m, m)) < 0.3) * rng.standard_normal((m, m))
    a = CSR.from_dense(amat)
    a_op = nap.operator(a, topo=topo, part=row_part, backend="simulate",
                        pairing=pairing)
    p_op = nap.operator(p, topo=topo, row_part=row_part, col_part=col_part,
                        backend="simulate", pairing=pairing)
    gal = p_op.T @ a_op @ p_op
    assert gal.shape == (pmat.shape[1], pmat.shape[1])
    x = rng.standard_normal(pmat.shape[1])
    want = (sp.csr_matrix(pmat).T @ sp.csr_matrix(amat)
            @ sp.csr_matrix(pmat)) @ x
    np.testing.assert_allclose(gal @ x, want, rtol=1e-9, atol=1e-11)
    # and the composed transpose distributes in reverse
    np.testing.assert_allclose(
        gal.T @ x,
        (sp.csr_matrix(pmat).T @ sp.csr_matrix(amat).T
         @ sp.csr_matrix(pmat)) @ x, rtol=1e-9, atol=1e-11)


@pytest.mark.parametrize("seed", range(0, N_CASES, 2))
def test_phase_locality(seed):
    topo, mat, a, part, pairing, _ = make_case(seed)
    nap = build_nap_plan(a.indptr, a.indices, part, topo, pairing=pairing)
    for phase in (nap.local_init_sends, nap.local_final_sends,
                  nap.local_full_sends):
        for msgs in phase:
            for m in msgs:
                assert topo.same_node(m.src, m.dst) and m.src != m.dst
    for msgs in nap.inter_sends:
        for m in msgs:
            assert not topo.same_node(m.src, m.dst)
