"""Property-based tests of the distributed SpMV invariants (hypothesis).

System invariants, over arbitrary sparsity / topology / partition:
  1. exactness — both executors reproduce the dense matvec bit-for-bit in
     float64 up to associativity tolerance;
  2. NAP never injects more bytes into the network than the standard SpMV,
     and never injects a value twice toward one node;
  3. intra-node phases never cross node boundaries;
  4. every rank receives exactly the off-process values its block needs
     (checked implicitly by the simulator's access assertions).
"""
import numpy as np
import pytest
import scipy.sparse as sp

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.comm_graph import build_nap_plan, build_standard_plan, nap_stats, standard_stats
from repro.core.partition import make_partition
from repro.core.spmv import DistSpMV
from repro.core.topology import Topology
from repro.sparse.csr import CSR


@st.composite
def spmv_case(draw):
    n_nodes = draw(st.integers(1, 4))
    ppn = draw(st.integers(1, 4))
    topo = Topology(n_nodes=n_nodes, ppn=ppn)
    n = draw(st.integers(topo.n_procs, 40))
    density = draw(st.floats(0.05, 0.5))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    mat = (rng.random((n, n)) < density).astype(np.float64)
    mat[np.arange(n), np.arange(n)] = 1.0  # keep a diagonal, like the paper's systems
    mat *= rng.standard_normal((n, n))
    mat[np.arange(n), np.arange(n)] += 2.0
    kind = draw(st.sampled_from(["contiguous", "strided", "balanced"]))
    pairing = draw(st.sampled_from(["balanced", "aligned"]))
    return topo, mat, kind, pairing, seed


@settings(max_examples=40, deadline=None)
@given(spmv_case())
def test_nap_and_standard_match_dense(case):
    topo, mat, kind, pairing, seed = case
    a = CSR.from_dense(mat)
    part = make_partition(kind, a.shape[0], topo.n_procs,
                          indptr=a.indptr, indices=a.indices, seed=seed)
    dist = DistSpMV.build(a, part, topo, pairing=pairing)
    rng = np.random.default_rng(seed + 1)
    v = rng.standard_normal(a.shape[0])
    expected = sp.csr_matrix(mat) @ v
    np.testing.assert_allclose(dist.run(v, "standard"), expected, rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(dist.run(v, "nap"), expected, rtol=1e-10, atol=1e-12)


@settings(max_examples=40, deadline=None)
@given(spmv_case())
def test_nap_network_injection_never_worse(case):
    topo, mat, kind, pairing, seed = case
    a = CSR.from_dense(mat)
    part = make_partition(kind, a.shape[0], topo.n_procs,
                          indptr=a.indptr, indices=a.indices, seed=seed)
    std = build_standard_plan(a.indptr, a.indices, part, topo)
    nap = build_nap_plan(a.indptr, a.indices, part, topo, pairing=pairing)
    s, n = standard_stats(std), nap_stats(nap)
    assert n["inter"].total_bytes <= s["inter"].total_bytes
    # deduplication: each (node pair, index) crosses the network at most once
    seen = set()
    for msgs in nap.inter_sends:
        for m in msgs:
            key_base = (topo.node_of(m.src), topo.node_of(m.dst))
            for j in m.idx:
                key = (*key_base, int(j))
                assert key not in seen
                seen.add(key)


@settings(max_examples=25, deadline=None)
@given(spmv_case())
def test_phase_locality(case):
    topo, mat, kind, pairing, seed = case
    a = CSR.from_dense(mat)
    part = make_partition(kind, a.shape[0], topo.n_procs,
                          indptr=a.indptr, indices=a.indices, seed=seed)
    nap = build_nap_plan(a.indptr, a.indices, part, topo, pairing=pairing)
    for phase in (nap.local_init_sends, nap.local_final_sends, nap.local_full_sends):
        for msgs in phase:
            for m in msgs:
                assert topo.same_node(m.src, m.dst) and m.src != m.dst
    for msgs in nap.inter_sends:
        for m in msgs:
            assert not topo.same_node(m.src, m.dst)
