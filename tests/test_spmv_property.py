"""Property tests of the distributed SpMV invariants (seeded numpy sweep).

``hypothesis`` is not installed in the container, so the case generator is
a seeded-numpy parametrized sweep — the invariants actually run under
tier-1 instead of silently skipping.  System invariants, over arbitrary
sparsity / topology / partition / pairing:

  1. exactness — both executors reproduce the scipy matvec in float64 up
     to associativity tolerance, and the TRANSPOSE executors reproduce
     ``A.T @ u`` through the reversed message flow;
  2. NAP never injects more bytes into the network than the standard
     SpMV, and never injects a value twice toward one node;
  3. intra-node phases never cross node boundaries;
  4. every rank touches exactly the off-process values it received
     (checked implicitly by the simulator's access/routing assertions).
"""
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core.comm_graph import (build_nap_plan, build_standard_plan,
                                   nap_stats, standard_stats)
from repro.core.partition import make_partition
from repro.core.spmv import (DistSpMV, simulate_nap_spmv,
                             simulate_nap_spmv_transpose,
                             simulate_standard_spmv,
                             simulate_standard_spmv_transpose)
from repro.core.topology import Topology
from repro.sparse.csr import CSR

N_CASES = 40


def make_case(seed: int):
    """Deterministic analogue of the old hypothesis strategy: topology,
    dense matrix, partition kind and pairing all drawn from one rng."""
    rng = np.random.default_rng(1000 + seed)
    topo = Topology(n_nodes=int(rng.integers(1, 5)),
                    ppn=int(rng.integers(1, 5)))
    n = int(rng.integers(topo.n_procs, 41))
    density = float(rng.uniform(0.05, 0.5))
    mat = (rng.random((n, n)) < density).astype(np.float64)
    mat[np.arange(n), np.arange(n)] = 1.0  # keep a diagonal, like the paper
    mat *= rng.standard_normal((n, n))
    mat[np.arange(n), np.arange(n)] += 2.0
    kind = ["contiguous", "strided", "balanced"][int(rng.integers(3))]
    pairing = ["balanced", "aligned"][int(rng.integers(2))]
    a = CSR.from_dense(mat)
    part = make_partition(kind, n, topo.n_procs, indptr=a.indptr,
                          indices=a.indices, seed=seed)
    return topo, mat, a, part, pairing, rng


@pytest.mark.parametrize("seed", range(N_CASES))
def test_nap_and_standard_match_dense(seed):
    topo, mat, a, part, pairing, rng = make_case(seed)
    dist = DistSpMV.build(a, part, topo, pairing=pairing)
    v = rng.standard_normal(a.shape[0])
    expected = sp.csr_matrix(mat) @ v
    np.testing.assert_allclose(simulate_standard_spmv(a, v, dist.standard),
                               expected, rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(simulate_nap_spmv(a, v, dist.nap),
                               expected, rtol=1e-10, atol=1e-12)


@pytest.mark.parametrize("seed", range(N_CASES))
def test_transpose_matches_dense(seed):
    """z = A.T u through the reversed send/recv roles of BOTH plans."""
    topo, mat, a, part, pairing, rng = make_case(seed)
    dist = DistSpMV.build(a, part, topo, pairing=pairing)
    u = rng.standard_normal(a.shape[0])
    expected = sp.csr_matrix(mat).T @ u
    np.testing.assert_allclose(
        simulate_standard_spmv_transpose(a, u, dist.standard),
        expected, rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(
        simulate_nap_spmv_transpose(a, u, dist.nap),
        expected, rtol=1e-10, atol=1e-12)


@pytest.mark.parametrize("seed", range(N_CASES))
def test_nap_network_injection_never_worse(seed):
    topo, mat, a, part, pairing, _ = make_case(seed)
    std = build_standard_plan(a.indptr, a.indices, part, topo)
    nap = build_nap_plan(a.indptr, a.indices, part, topo, pairing=pairing)
    s, n = standard_stats(std), nap_stats(nap)
    assert n["inter"].total_bytes <= s["inter"].total_bytes
    # deduplication: each (node pair, index) crosses the network at most once
    seen = set()
    for msgs in nap.inter_sends:
        for m in msgs:
            key_base = (topo.node_of(m.src), topo.node_of(m.dst))
            for j in m.idx:
                key = (*key_base, int(j))
                assert key not in seen
                seen.add(key)


@pytest.mark.parametrize("seed", range(0, N_CASES, 2))
def test_phase_locality(seed):
    topo, mat, a, part, pairing, _ = make_case(seed)
    nap = build_nap_plan(a.indptr, a.indices, part, topo, pairing=pairing)
    for phase in (nap.local_init_sends, nap.local_final_sends,
                  nap.local_full_sends):
        for msgs in phase:
            for m in msgs:
                assert topo.same_node(m.src, m.dst) and m.src != m.dst
    for msgs in nap.inter_sends:
        for m in msgs:
            assert not topo.same_node(m.src, m.dst)
