"""Mamba2 chunked-SSD vs sequential recurrence; RWKV6 scan vs decode."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod


@pytest.fixture(scope="module")
def zcfg():
    return get_reduced("zamba2-2.7b")


def test_mamba2_chunked_matches_sequential(zcfg):
    cfg = zcfg
    rng = np.random.default_rng(0)
    p = ssm_mod.mamba2_init(jax.random.key(0), cfg, jnp.float32)
    B, S = 2, cfg.ssm_chunk * 3
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)) * 0.3, jnp.float32)
    got = ssm_mod.mamba2_apply(p, cfg, x)
    want = ssm_mod.mamba2_scan_ref(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_mamba2_decode_continues_state(zcfg):
    """decode after a prefix == the tail of a longer sequence."""
    cfg = zcfg
    rng = np.random.default_rng(1)
    p = ssm_mod.mamba2_init(jax.random.key(1), cfg, jnp.float32)
    B, S = 1, cfg.ssm_chunk
    x = jnp.asarray(rng.standard_normal((B, S + 4, cfg.d_model)) * 0.3,
                    jnp.float32)
    full = ssm_mod.mamba2_scan_ref(p, cfg, x)
    state = ssm_mod.mamba2_init_state(cfg, B, jnp.float32)
    for t in range(S):
        _, state = ssm_mod.mamba2_decode(p, cfg, x[:, t:t + 1], state)
    outs = []
    for t in range(S, S + 4):
        y, state = ssm_mod.mamba2_decode(p, cfg, x[:, t:t + 1], state)
        outs.append(np.asarray(y))
    np.testing.assert_allclose(np.concatenate(outs, 1),
                               np.asarray(full[:, S:]), rtol=1e-4, atol=1e-5)


def test_rwkv6_scan_matches_stepwise():
    cfg = get_reduced("rwkv6-3b")
    rng = np.random.default_rng(2)
    p = rwkv_mod.rwkv6_init(jax.random.key(2), cfg, jnp.float32)
    B, S = 2, 12
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)) * 0.3, jnp.float32)
    st0 = rwkv_mod.rwkv6_init_state(cfg, B, jnp.float32)
    full, st_full = rwkv_mod.rwkv6_time_mix(p, cfg, x, st0)
    # stepwise
    st = st0
    outs = []
    for t in range(S):
        y, st = rwkv_mod.rwkv6_time_mix(p, cfg, x[:, t:t + 1], st)
        outs.append(np.asarray(y))
    np.testing.assert_allclose(np.concatenate(outs, 1), np.asarray(full),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st["S"]), np.asarray(st_full["S"]),
                               rtol=1e-4, atol=1e-5)


def test_rwkv6_decay_is_data_dependent():
    """The Finch feature: decay w must vary with the input."""
    cfg = get_reduced("rwkv6-3b")
    p = rwkv_mod.rwkv6_init(jax.random.key(3), cfg, jnp.float32)
    rng = np.random.default_rng(3)
    B, S = 1, 4
    x1 = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    x2 = x1 * 2.0
    last = jnp.zeros((B, cfg.d_model), jnp.float32)
    *_, w1 = rwkv_mod._time_mix_inputs(p, cfg, x1, last)
    *_, w2 = rwkv_mod._time_mix_inputs(p, cfg, x2, last)
    assert not np.allclose(np.asarray(w1), np.asarray(w2))
    assert (np.asarray(w1) > 0).all() and (np.asarray(w1) < 1).all()


def test_rwkv6_chunked_matches_scan():
    """The GLA-style chunked form must equal the stepwise recurrence."""
    cfg = get_reduced("rwkv6-3b")
    rng = np.random.default_rng(5)
    p = rwkv_mod.rwkv6_init(jax.random.key(5), cfg, jnp.float32)
    B, S = 2, 32
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)) * 0.3, jnp.float32)
    st0 = rwkv_mod.rwkv6_init_state(cfg, B, jnp.float32)
    want, st_w = rwkv_mod.rwkv6_time_mix(p, cfg, x, st0)
    got, st_g = rwkv_mod.rwkv6_time_mix_chunked(p, cfg, x, st0, chunk=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_g["S"]), np.asarray(st_w["S"]),
                               rtol=1e-4, atol=1e-5)
    # non-zero initial state path too
    want2, _ = rwkv_mod.rwkv6_time_mix(p, cfg, x, st_w)
    got2, _ = rwkv_mod.rwkv6_time_mix_chunked(p, cfg, x, st_g, chunk=8)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want2),
                               rtol=1e-4, atol=1e-5)
