"""Solver-service tier: admission, batching, hot swap, crash consistency,
and the E2E elastic-recovery path (mid-solve node loss, bit-identical
results on the survivor fleet)."""
import numpy as np
import pytest

import repro.api as nap
from repro.checkpoint import CheckpointManager, save_checkpoint, load_checkpoint
from repro.core.partition import contiguous_partition, survivor_partition
from repro.core.topology import Topology
from repro.runtime import ElasticPolicy, HeartbeatMonitor
from repro.serve import (FabricError, FaultEvent, FaultPlan, ManualClock,
                         PlanCache, Request, SolverService, Ticket,
                         batched_cg, dead_node, straggler, structure_key,
                         torn_checkpoint, values_fingerprint,
                         REJECT_BAD_OPERAND, REJECT_DEADLINE_UNMEETABLE,
                         REJECT_FLEET_DEGRADED, REJECT_QUEUE_FULL,
                         REJECT_UNKNOWN_MATRIX)
from repro.sparse.csr import CSR


def int_laplacian(m, diag=8.0):
    """Integer-valued SPD 5-point Laplacian (+diag*I).  Integer data and
    integer RHS make float64 SpMV EXACT, hence order-invariant, hence
    bit-identical across topologies — the E2E recovery oracle."""
    n = m * m
    rows, cols, vals = [], [], []
    for i in range(m):
        for j in range(m):
            k = i * m + j
            rows.append(k); cols.append(k); vals.append(diag)
            for di, dj in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                ii, jj = i + di, j + dj
                if 0 <= ii < m and 0 <= jj < m:
                    rows.append(k); cols.append(ii * m + jj); vals.append(-1.0)
    return CSR.from_coo(np.array(rows), np.array(cols), np.array(vals), (n, n))


def scaled(a, factor):
    return CSR(indptr=a.indptr.copy(), indices=a.indices.copy(),
               data=a.data * factor, shape=a.shape)


def make_service(topo=None, **kw):
    kw.setdefault("backend", "simulate")
    return SolverService(topo or Topology(2, 2), **kw)


# ------------------------- admission / batching ----------------------------

def test_submit_solve_roundtrip():
    a = int_laplacian(8)
    dense = a.to_dense()
    svc = make_service()
    svc.register_matrix("lap", a)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(a.shape[0])
    t1 = svc.submit("acme", "lap", b, kind="spmv")
    t2 = svc.submit("acme", "lap", b, kind="solve", tol=1e-11)
    assert t1.status == "queued" and t2.status == "queued"
    svc.run()
    np.testing.assert_allclose(t1.result(), dense @ b)
    x = t2.result()
    assert np.linalg.norm(dense @ x - b) / np.linalg.norm(b) < 1e-10
    rep = svc.report()
    assert rep["stats"]["completed"] == 2
    acct = rep["tenants"]["acme"]
    assert acct["completed"] == 2 and acct["cg_iters"] == t2.request.iters
    assert acct["plan"], "op.stats() rollup should be non-empty"


def test_admission_reject_reasons():
    a = int_laplacian(4)
    svc = make_service(queue_limit=2)
    svc.register_matrix("lap", a)
    b = np.ones(a.shape[0])
    assert svc.submit("t", "nope", b).reason == REJECT_UNKNOWN_MATRIX
    assert svc.submit("t", "lap", np.ones(7)).reason == REJECT_BAD_OPERAND
    assert svc.submit("t", "lap", b,
                      deadline=-1.0).reason == REJECT_DEADLINE_UNMEETABLE
    assert svc.submit("t", "lap", b).status == "queued"
    assert svc.submit("t", "lap", b).status == "queued"
    full = svc.submit("t", "lap", b)
    assert full.status == "rejected" and full.reason == REJECT_QUEUE_FULL
    with pytest.raises(ValueError):
        svc.submit("t", "lap", b, kind="invert")
    with pytest.raises(ValueError):
        full.result()   # rejected ticket has no result
    assert svc.report()["stats"]["rejected"] == 4


def test_batching_aggregates_concurrent_rhs():
    """Concurrent same-matrix requests execute as ONE multi-RHS batch
    (one pump step), not one step each."""
    a = int_laplacian(6)
    dense = a.to_dense()
    svc = make_service(batch_limit=8)
    svc.register_matrix("lap", a)
    rng = np.random.default_rng(1)
    B = rng.integers(-5, 6, size=(a.shape[0], 5)).astype(float)
    tickets = [svc.submit("t", "lap", B[:, i], kind="spmv") for i in range(5)]
    rep = svc.step()
    assert rep["executed"] == 5     # the whole group went in one batch
    for i, t in enumerate(tickets):
        np.testing.assert_array_equal(t.result(), dense @ B[:, i])


def test_deadline_expires_in_queue():
    a = int_laplacian(4)
    svc = make_service(batch_limit=1, dt=10.0)
    svc.register_matrix("lap", a)
    b = np.ones(a.shape[0])
    early = svc.submit("t", "lap", b, deadline=5.0)
    late = svc.submit("t", "lap", b, deadline=100.0)
    svc.step()   # clock jumps to 10: early expires before execution
    assert early.status == "expired"
    assert late.status == "done"
    assert svc.report()["stats"]["expired"] == 1


def test_run_is_bounded_never_deadlocks():
    """A permanently failing workload terminates at max_steps with the
    requests failed — the pump never spins forever."""
    a = int_laplacian(4)
    plan = FaultPlan.of(FaultEvent(step=1, kind="dead_node", node="node0"),
                        FaultEvent(step=1, kind="dead_node", node="node1"))
    svc = make_service(fault_plan=plan, max_attempts=2, backoff=0.1)
    svc.register_matrix("lap", a)
    t = svc.submit("t", "lap", np.ones(a.shape[0]))
    steps = svc.run(max_steps=30)
    assert steps <= 30
    assert t.status == "failed"
    for _ in range(4):   # idle ticks let the heartbeat timeout fire
        svc.step()
    assert svc.degraded
    assert svc.submit("t", "lap",
                      np.ones(a.shape[0])).reason == REJECT_FLEET_DEGRADED


# ------------------------- batched CG --------------------------------------

def test_batched_cg_matches_solo_columns():
    """Frozen-column batching: each column of a multi-RHS CG is
    bit-identical to its own 1-RHS solve under a COLUMNWISE mv — the
    executors' multi-RHS path applies per column, so this is the
    service-relevant contract (a blocked dense gemm would not be
    bit-stable per column; the backends are)."""
    a = int_laplacian(7)
    dense = a.to_dense()

    def mv(V):   # columnwise, like _SimulateExecutor._columnwise
        return np.stack([dense @ V[:, i] for i in range(V.shape[1])], axis=1)

    rng = np.random.default_rng(3)
    B = rng.standard_normal((a.shape[0], 4))
    # different conditioning per column so convergence staggers
    B[:, 1] *= 100.0
    X, iters, rel = batched_cg(mv, B, tol=1e-11, maxiter=200)
    assert (rel < 1e-11).all()
    assert len(set(iters.tolist())) > 1, "columns should converge at different its"
    for i in range(B.shape[1]):
        xi, _, _ = batched_cg(mv, B[:, i:i+1], tol=1e-11, maxiter=200)
        np.testing.assert_array_equal(X[:, i], xi[:, 0])


def test_batched_cg_warm_start():
    a = int_laplacian(6)
    dense = a.to_dense()
    b = np.random.default_rng(4).standard_normal((a.shape[0], 1))
    x_cold, it_cold, _ = batched_cg(lambda V: dense @ V, b, tol=1e-11)
    X0 = 0.9 * x_cold
    x_warm, it_warm, _ = batched_cg(lambda V: dense @ V, b, tol=1e-11, X0=X0)
    assert it_warm[0] < it_cold[0]
    np.testing.assert_allclose(dense @ x_warm[:, 0], b[:, 0], atol=1e-8)


# ------------------------- plan cache / hot swap ---------------------------

def test_plan_cache_hit_swap_miss_evict():
    topo = Topology(2, 2)
    a = int_laplacian(6)
    part = contiguous_partition(a.shape[0], topo.n_procs)
    cache = PlanCache(topo, backend="simulate", max_entries=2)
    op1 = cache.operator_for(a, part)
    assert cache.stats["misses"] == 1
    assert cache.operator_for(a, part) is op1
    assert cache.stats["hits"] == 1
    # same structure + new values -> hot swap, same operator object
    a2 = scaled(a, 3.0)
    assert cache.operator_for(a2, part) is op1
    assert cache.stats["hot_swaps"] == 1
    v = np.arange(a.shape[0], dtype=float)
    np.testing.assert_array_equal(op1 @ v, 3.0 * (a.to_dense() @ v))
    # two more structures -> LRU eviction
    cache.operator_for(int_laplacian(5), contiguous_partition(25, 4))
    cache.operator_for(int_laplacian(4), contiguous_partition(16, 4))
    assert len(cache) == 2 and cache.stats["evictions"] == 1
    # structure_key ignores values; fingerprint sees them
    p2 = contiguous_partition(a.shape[0], topo.n_procs)
    k1 = structure_key(a, part, part, topo, "nap", "simulate")
    k2 = structure_key(a2, p2, p2, topo, "nap", "simulate")
    assert k1 == k2
    assert values_fingerprint(a) != values_fingerprint(a2)
    # rebuild drops everything and retargets
    dropped = cache.rebuild(Topology(1, 2))
    assert dropped == 2 and len(cache) == 0
    assert cache.topo.n_nodes == 1 and cache.stats["rebuilds"] == 1


def test_service_hot_swap_zero_recompile():
    """update_values -> the SAME cached plan re-runs with new values: the
    plan cache reports a hot swap, not a miss (no recompile)."""
    a = int_laplacian(6)
    svc = make_service()
    svc.register_matrix("lap", a)
    b = np.ones(a.shape[0])
    t1 = svc.submit("t", "lap", b, kind="spmv")
    svc.run()
    svc.update_values("lap", scaled(a, 2.0))
    t2 = svc.submit("t", "lap", b, kind="spmv")
    svc.run()
    np.testing.assert_array_equal(t2.result(), 2.0 * t1.result())
    assert svc.plans.stats == {"hits": 0, "misses": 1, "hot_swaps": 1,
                               "evictions": 0, "rebuilds": 0}
    with pytest.raises(ValueError):
        svc.update_values("lap", int_laplacian(5))   # structure change


def test_shardmap_hot_swap_zero_retrace():
    """The compiled shardmap program is REUSED across a value swap: trace
    counts stay flat (value arrays are jit arguments, not closure
    constants), and results track the new values."""
    a = int_laplacian(5)
    dense = a.to_dense()
    op = nap.operator(a, topo=Topology(1, 1), backend="shardmap")
    v = np.random.default_rng(5).integers(-4, 5, a.shape[0]).astype(float)
    w1 = op @ v
    np.testing.assert_allclose(w1, dense @ v, atol=1e-4)
    assert op.trace_counts() == {"forward": 1}
    op.swap_values(scaled(a, 2.0))
    w2 = op @ v
    np.testing.assert_allclose(w2, 2.0 * (dense @ v), atol=1e-4)
    assert op.trace_counts() == {"forward": 1}, "hot swap must not retrace"
    with pytest.raises(ValueError):
        op.swap_values(int_laplacian(4))


# ------------------------- crash consistency -------------------------------

def test_torn_save_restores_previous_step(tmp_path):
    tree = {"x": np.arange(6.0)}
    save_checkpoint(str(tmp_path), 1, tree, extra={"it": 1})
    with pytest.raises(OSError):
        save_checkpoint(str(tmp_path), 2, {"x": np.arange(6.0) * 2},
                        extra={"it": 2},
                        on_before_commit=lambda: (_ for _ in ()).throw(
                            OSError("torn")))
    out, extra = load_checkpoint(str(tmp_path))   # falls back to step 1
    assert extra["it"] == 1
    np.testing.assert_array_equal(out["x"], np.arange(6.0))


def test_manager_reraises_background_failure(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": np.ones(3)}, block=True)
    def boom():
        raise OSError("disk full")
    mgr.save(2, {"x": np.ones(3)}, on_before_commit=boom)
    with pytest.raises(RuntimeError, match="last committed step is 1") as ei:
        mgr.wait()
    assert isinstance(ei.value.__cause__, OSError)
    mgr.save(3, {"x": np.ones(3)}, block=True)    # manager still usable
    assert mgr.last_saved == 3


def test_missing_shard_is_descriptive(tmp_path):
    save_checkpoint(str(tmp_path), 5, {"a": np.ones(4), "b": np.zeros(2)})
    shard = next((tmp_path / "step_00000005").glob("shard_*.npz"))
    shard.unlink()
    with pytest.raises(FileNotFoundError, match="it held 2 leaves"):
        load_checkpoint(str(tmp_path))


def test_service_survives_torn_checkpoint(tmp_path):
    """A scripted torn save mid-solve is absorbed: the save fails, the
    previous committed step stands, the solve completes anyway."""
    a = int_laplacian(8)
    plan = FaultPlan.of(torn_checkpoint(1))
    svc = make_service(Topology(2, 2), fault_plan=plan,
                       checkpoint_dir=str(tmp_path), checkpoint_every=3)
    svc.register_matrix("lap", a)
    b = np.random.default_rng(6).standard_normal(a.shape[0])
    t = svc.submit("t", "lap", b, kind="solve", tol=1e-11)
    svc.run()
    assert t.status == "done"
    assert svc.stats["torn_saves"] == 1
    # later (intact) saves committed: restore yields the LAST good step
    tree, extra = svc.ckpt.restore()
    assert extra["iteration"] > 3


# ------------------------- fault plans -------------------------------------

def test_fault_plan_validation_and_clock():
    with pytest.raises(ValueError):
        FaultEvent(step=1, kind="meteor")
    with pytest.raises(ValueError):
        FaultEvent(step=1, kind="dead_node")      # needs a node
    clk = ManualClock()
    with pytest.raises(ValueError):
        clk.advance(-1.0)
    clk.advance(2.5)
    assert clk() == 2.5
    plan = FaultPlan.of(straggler(5, "n1"), dead_node(2, "n0"))
    assert [e.step for e in plan.events] == [2, 5]
    assert len(plan.at(2)) == 1 and plan.at(3) == []


def test_fault_plan_random_is_deterministic():
    nodes = ["node0", "node1", "node2"]
    p1 = FaultPlan.random(seed=42, nodes=nodes, n_steps=10, n_events=3)
    p2 = FaultPlan.random(seed=42, nodes=nodes, n_steps=10, n_events=3)
    assert p1 == p2
    assert FaultPlan.random(seed=43, nodes=nodes, n_steps=10, n_events=3) != p1


def test_same_seed_same_eviction_step():
    """The crash-consistency determinism contract: the same seeded plan
    against the same workload evicts the same node at the same step."""
    a = int_laplacian(6)

    def run_once():
        plan = FaultPlan.random(seed=9, nodes=["node0", "node1", "node2"],
                                n_steps=3, n_events=1)
        svc = make_service(Topology(3, 2), fault_plan=plan,
                           heartbeat_timeout=2.5, max_attempts=6)
        svc.register_matrix("lap", a)
        tickets = [svc.submit("t", "lap", np.ones(a.shape[0]))
                   for _ in range(3)]
        svc.run(max_steps=40)
        evict_logs = [l for l in svc.log if "evicted" in l]
        return tuple(evict_logs), tuple(t.status for t in tickets)

    assert run_once() == run_once()


# ------------------------- elastic recovery (E2E) --------------------------

def test_e2e_midsolve_node_loss_bit_identical(tmp_path):
    """THE tentpole assertion: a node dies at CG iteration 4 mid-solve;
    the service detects it, repartitions onto the survivors, rebuilds the
    NAP plans, restores the checkpointed iterate, and re-executes — and
    the SpMV answer is BIT-identical to the uninterrupted run (integer
    data → exact arithmetic → order-invariant across topologies)."""
    a = int_laplacian(8)
    dense = a.to_dense()
    rng = np.random.default_rng(7)
    b_int = rng.integers(-8, 9, size=a.shape[0]).astype(np.float64)
    b_f = rng.standard_normal(a.shape[0])
    topo = Topology(3, 2)

    def build(**kw):
        svc = make_service(topo, queue_limit=16, heartbeat_timeout=2.5,
                           checkpoint_every=3, max_attempts=5, backoff=0.5,
                           **kw)
        svc.register_matrix("lap", a)
        return svc

    ref = build()
    r1 = ref.submit("t", "lap", b_int, kind="spmv")
    r2 = ref.submit("t", "lap", b_f, kind="solve", tol=1e-11, maxiter=300)
    ref.run()

    plan = FaultPlan.of(dead_node(1, "node1", at_iteration=4))
    svc = build(fault_plan=plan, checkpoint_dir=str(tmp_path))
    f1 = svc.submit("t", "lap", b_int, kind="spmv")
    f2 = svc.submit("t", "lap", b_f, kind="solve", tol=1e-11, maxiter=300)
    svc.run(max_steps=60)

    assert f1.status == "done" and f2.status == "done"
    assert svc.stats["recoveries"] == 1
    assert svc.topo == Topology(2, 2) and svc.nodes == ["node0", "node2"]
    assert svc.stats["last_recover_rebuild_s"] > 0
    assert any("died mid-solve at CG iteration 4" in l for l in svc.log)

    # bit-identical SpMV across the node loss
    assert np.array_equal(f1.result(), r1.result())
    # solve: converged on the survivor fleet, matching the clean run
    assert (np.linalg.norm(dense @ f2.result() - b_f)
            / np.linalg.norm(b_f) < 1e-10)
    np.testing.assert_allclose(f2.result(), r2.result(), atol=1e-9)
    # the checkpointed iterate warm-started the retry
    assert any("restored checkpointed iterates" in l for l in svc.log)
    assert f2.request.iters < r2.request.iters

    # survivors kept their rows: only node1's ranks (2, 3) moved
    part = svc.matrices["lap"]["row_part"]
    assert part.n_procs == 4 and part.kind == "elastic"


def test_e2e_recovery_matches_survivor_oracle(tmp_path):
    """The recovered solve equals an oracle run natively on the survivor
    topology with the same warm start — recovery is exactly 'resume on
    the new fleet', nothing more."""
    a = int_laplacian(8)
    b = np.random.default_rng(8).standard_normal(a.shape[0])
    plan = FaultPlan.of(dead_node(1, "node2", at_iteration=4))
    svc = make_service(Topology(3, 2), fault_plan=plan,
                       checkpoint_dir=str(tmp_path), checkpoint_every=2,
                       heartbeat_timeout=2.5, max_attempts=5, backoff=0.5)
    svc.register_matrix("lap", a)
    t = svc.submit("t", "lap", b, kind="solve", tol=1e-11, maxiter=300)
    svc.run(max_steps=60)
    assert t.status == "done" and svc.stats["recoveries"] == 1

    # oracle: same operator type on the survivor layout, same warm start
    tree, extra = svc.ckpt.restore()
    part = svc.matrices["lap"]["row_part"]
    op = nap.operator(a, topo=svc.topo, row_part=part, backend="simulate")
    X, _, _ = batched_cg(op, b[:, None], tol=1e-11, maxiter=300,
                         X0=np.asarray(tree["x"])[:, :1])
    np.testing.assert_array_equal(t.result(), X[:, 0])


def test_straggler_evicts_through_recovery():
    a = int_laplacian(6)
    plan = FaultPlan.of(straggler(2, "node2", slowdown=8.0))
    svc = make_service(Topology(3, 2), fault_plan=plan,
                       heartbeat_timeout=50.0)   # only the straggler path
    svc.register_matrix("lap", a)
    t = svc.submit("t", "lap", np.ones(a.shape[0]))
    for _ in range(12):
        svc.step()
    assert t.status == "done"
    assert svc.stats["recoveries"] == 1
    assert "node2" not in svc.nodes and svc.topo.n_nodes == 2


# ------------------------- runtime satellites ------------------------------

def test_heartbeat_unknown_node_raises():
    t = [0.0]
    mon = HeartbeatMonitor(["n0"], timeout=5.0, clock=lambda: t[0])
    with pytest.raises(KeyError, match="unregistered"):
        mon.beat("n0-typo")
    mon.beat("n1", register=True)     # explicit opt-in still works
    assert "n1" in mon.last


def test_global_batch_plan_exact():
    pol = ElasticPolicy()
    per_row, accum = pol.global_batch_plan(96, old_data=8, new_data=6)
    assert per_row * 6 * accum == 96
    assert per_row <= 96 // 8
    with pytest.raises(ValueError, match="not divisible"):
        pol.global_batch_plan(96, old_data=8, new_data=7)


def test_survivor_topology_rules():
    pol = ElasticPolicy()
    t = pol.survivor_topology(Topology(4, 2), [1, 3])
    assert t == Topology(2, 2)
    assert pol.survivor_topology(Topology(2, 2), [0, 1]) is None


def test_survivor_partition_properties():
    part = contiguous_partition(40, 4)
    new = survivor_partition(part, [1])
    assert new.n_procs == 3 and new.kind == "elastic"
    # survivors keep every row they had (ranks renumber 0,2,3 -> 0,1,2),
    # plus their waterfilled share of the orphans
    for old_r, new_r in [(0, 0), (2, 1), (3, 2)]:
        assert np.all(np.isin(part.rows_of(old_r), new.rows_of(new_r)))
    np.testing.assert_array_equal(np.sort(np.concatenate(
        [new.rows_of(r) for r in range(3)])), np.arange(40))
    # orphans waterfill: counts stay balanced within 1
    counts = new.counts()
    assert counts.max() - counts.min() <= 1
    # deterministic regardless of dead-rank ordering or duplicates
    again = survivor_partition(part, (1, 1))
    np.testing.assert_array_equal(new.owner, again.owner)
    with pytest.raises(ValueError):
        survivor_partition(part, [0, 1, 2, 3])
    with pytest.raises(ValueError):
        survivor_partition(part, [9])
