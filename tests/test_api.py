"""The unified NapOperator front-end (repro.api) + the executor registry.

Tier-1 tests run the simulate backend in-process (float64 oracles, no
device mesh needed) plus the scripts/check_api.py smoke as a subprocess
(it needs its own XLA device count for the shardmap backend).  The full
shardmap operator sweep lives in tests/multidev/operator_prog.py.
"""
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

import repro.api as nap
from repro.core.cost_model import BLUE_WATERS
from repro.core.partition import strided_partition
from repro.core.topology import Topology
from repro.sparse import random_fixed_nnz, rotated_anisotropic_2d

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _dense_cols(a, v):
    if v.ndim == 1:
        return a.matvec(v)
    return np.stack([a.matvec(v[:, i]) for i in range(v.shape[1])], axis=1)


@pytest.mark.parametrize("method", ["nap", "standard"])
@pytest.mark.parametrize("nv", [None, 3])
def test_simulate_forward_transpose_match_dense(method, nv):
    topo = Topology(n_nodes=2, ppn=3)
    n = 50
    a = random_fixed_nnz(n, 7, seed=1)  # nonsymmetric: A != A.T
    rng = np.random.default_rng(0)
    v = rng.standard_normal(n if nv is None else (n, nv))
    op = nap.operator(a, topo=topo, method=method, backend="simulate")
    np.testing.assert_allclose(op @ v, _dense_cols(a, v),
                               rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(op.T @ v, _dense_cols(a.transpose(), v),
                               rtol=1e-9, atol=1e-12)


def test_operator_structure():
    topo = Topology(n_nodes=2, ppn=2)
    a = rotated_anisotropic_2d(8)
    part = strided_partition(a.shape[0], topo.n_procs)
    op = nap.operator(a, topo=topo, part=part, backend="simulate")
    assert op.shape == a.shape and op.method == "nap"
    assert op.T.T is op and op.T.transposed and not op.transposed
    assert "NapOperator" in repr(op) and ".T" in repr(op.T)
    # square sugar: both partitions are the same object, swapped by .T
    assert op.row_part is part and op.col_part is part
    assert op.T.domain_part is op.range_part
    # stats/cost/autotune surfaces exist on every backend
    s = op.stats()
    assert s["messages_inter"].total_bytes >= 0
    assert op.cost(BLUE_WATERS)["total"] >= 0
    rep = op.autotune_report()
    assert "resolved" in rep and "transpose_resolved" in rep
    # the simulate backend computes both directions in exact numpy
    assert op.T.local_compute == op.local_compute == "numpy"
    # matvec alias and __call__ agree
    v = np.random.default_rng(1).standard_normal(a.shape[0])
    np.testing.assert_array_equal(op.matvec(v), op(v))


def test_operator_validation():
    topo = Topology(n_nodes=1, ppn=2)
    a = random_fixed_nnz(16, 3, seed=0)
    with pytest.raises(ValueError, match="available"):
        nap.operator(a, topo=topo, backend="no-such-backend")
    from repro.core.partition import contiguous_partition
    from repro.sparse.csr import CSR
    rect = CSR.from_dense(np.ones((4, 6)))
    # part= is square-only sugar; rectangular needs row_part/col_part
    with pytest.raises(ValueError, match="square"):
        nap.operator(rect, topo=topo, part=contiguous_partition(4, 2))
    with pytest.raises(ValueError, match="not both"):
        nap.operator(a, topo=topo, part=contiguous_partition(16, 2),
                     row_part=contiguous_partition(16, 2))
    with pytest.raises(ValueError, match="mismatch"):
        nap.operator(rect, topo=topo,
                     row_part=contiguous_partition(6, 2),
                     col_part=contiguous_partition(6, 2))
    # a rectangular matrix WITHOUT part= builds on default partitions
    op_r = nap.operator(rect, topo=topo, backend="simulate")
    assert op_r.shape == (4, 6) and op_r.T.shape == (6, 4)
    op = nap.operator(a, topo=topo, backend="simulate")
    with pytest.raises(ValueError, match="operand"):
        op @ np.ones(7)
    with pytest.raises(ValueError, match="operand"):
        op_r @ np.ones(4)       # forward operand is [n]=6, not [m]=4
    with pytest.raises(ValueError, match="precision"):
        op(np.ones(16), precision="bf16")
    with pytest.raises(ValueError, match="aligned"):
        nap.operator(a, topo=topo, backend="shardmap", pairing="balanced")
    assert op(np.ones(16), precision="float32").dtype == np.float32


def test_rectangular_and_composition_simulate():
    """[m, n] operators with independent partitions + lazy (R @ A @ P)."""
    topo = Topology(n_nodes=2, ppn=2)
    rng = np.random.default_rng(5)
    m, n = 48, 20
    from repro.core.partition import contiguous_partition
    from repro.sparse.csr import CSR
    am = (rng.random((m, m)) < 0.2) * rng.standard_normal((m, m))
    pm = (rng.random((m, n)) < 0.3) * rng.standard_normal((m, n))
    fine = contiguous_partition(m, topo.n_procs)
    coarse = contiguous_partition(n, topo.n_procs)
    a_op = nap.operator(CSR.from_dense(am), topo=topo, part=fine,
                        backend="simulate")
    p_op = nap.operator(CSR.from_dense(pm), topo=topo, row_part=fine,
                        col_part=coarse, backend="simulate")
    x, u = rng.standard_normal(n), rng.standard_normal(m)
    np.testing.assert_allclose(p_op @ x, pm @ x, rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(p_op.T @ u, pm.T @ u, rtol=1e-9, atol=1e-12)
    gal = p_op.T @ a_op @ p_op
    assert isinstance(gal, nap.ComposedOperator)
    assert gal.shape == (n, n) and len(gal.factors) == 3
    np.testing.assert_allclose(gal @ x, pm.T @ (am @ (pm @ x)),
                               rtol=1e-9, atol=1e-10)
    # transpose distributes in reverse; per-stage introspection rolls up
    np.testing.assert_allclose(gal.T @ x, (pm.T @ am.T @ pm) @ x,
                               rtol=1e-9, atol=1e-10)
    cost = gal.cost(BLUE_WATERS)
    assert len(cost["stages"]) == 3 and len(gal.stats()) == 3
    assert cost["total"] >= max(s["total"] for s in cost["stages"])
    # incompatible interface partitions are rejected at compose time
    from repro.core.partition import strided_partition
    p_bad = nap.operator(CSR.from_dense(pm), topo=topo,
                         row_part=strided_partition(m, topo.n_procs),
                         col_part=coarse, backend="simulate")
    with pytest.raises(ValueError, match="[Ii]ncompatible"):
        a_op @ p_bad
    with pytest.raises(ValueError, match="chain"):
        p_op @ a_op  # (m, n) @ (m, m) does not chain


def test_registry_pluggable():
    """A new backend registers once and becomes reachable through
    nap.operator without touching any call site."""
    from repro.core.executors import _REGISTRY, register_executor

    calls = {}

    @register_executor("dummy", "nap")
    class DummyExec:
        def __init__(self, a, row_part, col_part, topo, spec, mesh=None):
            self.a = a

        def forward(self, v, donate=False):
            calls["forward"] = True
            return np.asarray(v) * 2.0

        def transpose(self, u, donate=False):
            calls["transpose"] = True
            return np.asarray(u) * 3.0

    try:
        a = random_fixed_nnz(8, 2, seed=0)
        op = nap.operator(a, topo=Topology(1, 1), backend="dummy")
        assert ("dummy", "nap") in nap.available_executors()
        v = np.ones(8)
        np.testing.assert_array_equal(op @ v, v * 2.0)
        np.testing.assert_array_equal(op.T @ v, v * 3.0)
        assert calls == {"forward": True, "transpose": True}
    finally:
        _REGISTRY.pop(("dummy", "nap"), None)


def test_amg_vcycle_through_operators():
    """amg_vcycle(..., operators=...) runs every level — A AND the P/R
    grid transfers — through NapOperators (restriction = P.T)."""
    from repro.amg import (LevelOperators, amg_vcycle, cg_solve,
                           level_operators, smoothed_aggregation_hierarchy)

    a = rotated_anisotropic_2d(16, eps=0.1)
    topo = Topology(n_nodes=2, ppn=2)
    levels = smoothed_aggregation_hierarchy(a, theta=0.1, coarse_size=32)
    ops = level_operators(levels, topo, method="nap", backend="simulate")
    assert isinstance(ops[0], LevelOperators) and ops[0].a is not None
    # the hierarchy is distributed: P is rectangular, R its transpose view
    assert ops[0].p is not None and ops[0].p.shape == levels[0].p.shape
    assert ops[0].r.transposed and ops[0].r.shape == ops[0].p.shape[::-1]
    # Galerkin composition matches the host-side RAP coarse matrix
    gal = ops[0].galerkin()
    if gal is not None:
        xc = np.random.default_rng(7).standard_normal(gal.shape[1])
        np.testing.assert_allclose(gal @ xc, levels[1].a.matvec(xc),
                                   rtol=1e-8, atol=1e-9)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(a.shape[0])
    x, iters, rel = cg_solve(
        a, b, tol=1e-8, maxiter=200,
        precond=lambda r: amg_vcycle(levels, r, operators=ops),
        spmv=ops[0].a)
    assert rel < 1e-8, (iters, rel)


def test_bicg_uses_transpose_operator():
    from repro.amg import bicgstab_solve
    from repro.sparse.csr import CSR

    n = 96
    a = random_fixed_nnz(n, 5, seed=2)
    a = CSR.from_dense(a.to_dense() + np.eye(n) * 10.0)
    op = nap.operator(a, topo=Topology(2, 2), backend="simulate")
    b = np.random.default_rng(0).standard_normal(n)
    x, iters, rel = bicgstab_solve(a, b, tol=1e-9, maxiter=200,
                                   spmv=op, spmv_t=op.T)
    assert rel < 1e-9
    np.testing.assert_allclose(a.matvec(x), b, rtol=1e-6, atol=1e-7)


def test_check_api_smoke():
    """scripts/check_api.py — the operator + deprecation-contract smoke —
    must pass in its own process (it forces the XLA device count)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "check_api.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "API OK" in proc.stdout


@pytest.mark.multidev
def test_operator_shardmap_8dev():
    """Full shardmap operator sweep (forward+transpose, nap+standard,
    multi-RHS, donate) on a forced 8-device host platform."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable,
         str(ROOT / "tests" / "multidev" / "operator_prog.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL OK" in proc.stdout


@pytest.mark.multidev
def test_rect_operator_shardmap_8dev():
    """Rectangular operator + composed-AMG sweep on a forced 8-device host
    platform: tall/wide/empty-rank shapes, (R @ A @ P) vs scipy, and the
    V-cycle whose every restriction runs through the node-aware transpose
    executor (asserted inside the program)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable,
         str(ROOT / "tests" / "multidev" / "rect_operator_prog.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL OK" in proc.stdout
