"""The unified NapOperator front-end (repro.api) + the executor registry.

Tier-1 tests run the simulate backend in-process (float64 oracles, no
device mesh needed) plus the scripts/check_api.py smoke as a subprocess
(it needs its own XLA device count for the shardmap backend).  The full
shardmap operator sweep lives in tests/multidev/operator_prog.py.
"""
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

import repro.api as nap
from repro.core.cost_model import BLUE_WATERS
from repro.core.partition import strided_partition
from repro.core.topology import Topology
from repro.sparse import random_fixed_nnz, rotated_anisotropic_2d

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _dense_cols(a, v):
    if v.ndim == 1:
        return a.matvec(v)
    return np.stack([a.matvec(v[:, i]) for i in range(v.shape[1])], axis=1)


@pytest.mark.parametrize("method", ["nap", "standard"])
@pytest.mark.parametrize("nv", [None, 3])
def test_simulate_forward_transpose_match_dense(method, nv):
    topo = Topology(n_nodes=2, ppn=3)
    n = 50
    a = random_fixed_nnz(n, 7, seed=1)  # nonsymmetric: A != A.T
    rng = np.random.default_rng(0)
    v = rng.standard_normal(n if nv is None else (n, nv))
    op = nap.operator(a, topo=topo, method=method, backend="simulate")
    np.testing.assert_allclose(op @ v, _dense_cols(a, v),
                               rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(op.T @ v, _dense_cols(a.transpose(), v),
                               rtol=1e-9, atol=1e-12)


def test_operator_structure():
    topo = Topology(n_nodes=2, ppn=2)
    a = rotated_anisotropic_2d(8)
    part = strided_partition(a.shape[0], topo.n_procs)
    op = nap.operator(a, topo=topo, part=part, backend="simulate")
    assert op.shape == a.shape and op.method == "nap"
    assert op.T.T is op and op.T.transposed and not op.transposed
    assert "NapOperator" in repr(op) and ".T" in repr(op.T)
    # stats/cost/autotune surfaces exist on every backend
    s = op.stats()
    assert s["messages_inter"].total_bytes >= 0
    assert op.cost(BLUE_WATERS)["total"] >= 0
    assert "resolved" in op.autotune_report()
    # the simulate backend computes both directions in exact numpy
    assert op.T.local_compute == op.local_compute == "numpy"
    # matvec alias and __call__ agree
    v = np.random.default_rng(1).standard_normal(a.shape[0])
    np.testing.assert_array_equal(op.matvec(v), op(v))


def test_operator_validation():
    topo = Topology(n_nodes=1, ppn=2)
    a = random_fixed_nnz(16, 3, seed=0)
    with pytest.raises(ValueError, match="available"):
        nap.operator(a, topo=topo, backend="no-such-backend")
    with pytest.raises(ValueError, match="square"):
        from repro.sparse.csr import CSR
        nap.operator(CSR.from_dense(np.ones((4, 6))), topo=topo)
    op = nap.operator(a, topo=topo, backend="simulate")
    with pytest.raises(ValueError, match="operand"):
        op @ np.ones(7)
    with pytest.raises(ValueError, match="precision"):
        op(np.ones(16), precision="bf16")
    with pytest.raises(ValueError, match="aligned"):
        nap.operator(a, topo=topo, backend="shardmap", pairing="balanced")
    assert op(np.ones(16), precision="float32").dtype == np.float32


def test_registry_pluggable():
    """A new backend registers once and becomes reachable through
    nap.operator without touching any call site."""
    from repro.core.executors import _REGISTRY, register_executor

    calls = {}

    @register_executor("dummy", "nap")
    class DummyExec:
        def __init__(self, a, part, topo, spec, mesh=None):
            self.a = a

        def forward(self, v, donate=False):
            calls["forward"] = True
            return np.asarray(v) * 2.0

        def transpose(self, u, donate=False):
            calls["transpose"] = True
            return np.asarray(u) * 3.0

    try:
        a = random_fixed_nnz(8, 2, seed=0)
        op = nap.operator(a, topo=Topology(1, 1), backend="dummy")
        assert ("dummy", "nap") in nap.available_executors()
        v = np.ones(8)
        np.testing.assert_array_equal(op @ v, v * 2.0)
        np.testing.assert_array_equal(op.T @ v, v * 3.0)
        assert calls == {"forward": True, "transpose": True}
    finally:
        _REGISTRY.pop(("dummy", "nap"), None)


def test_amg_vcycle_through_operators():
    """amg_vcycle(..., operators=...) runs every level through NapOperator."""
    from repro.amg import (amg_vcycle, cg_solve, level_operators,
                          smoothed_aggregation_hierarchy)

    a = rotated_anisotropic_2d(16, eps=0.1)
    topo = Topology(n_nodes=2, ppn=2)
    levels = smoothed_aggregation_hierarchy(a, theta=0.1, coarse_size=32)
    ops = level_operators(levels, topo, method="nap", backend="simulate")
    assert ops[0] is not None
    rng = np.random.default_rng(0)
    b = rng.standard_normal(a.shape[0])
    x, iters, rel = cg_solve(
        a, b, tol=1e-8, maxiter=200,
        precond=lambda r: amg_vcycle(levels, r, operators=ops),
        spmv=ops[0])
    assert rel < 1e-8, (iters, rel)


def test_bicg_uses_transpose_operator():
    from repro.amg import bicgstab_solve
    from repro.sparse.csr import CSR

    n = 96
    a = random_fixed_nnz(n, 5, seed=2)
    a = CSR.from_dense(a.to_dense() + np.eye(n) * 10.0)
    op = nap.operator(a, topo=Topology(2, 2), backend="simulate")
    b = np.random.default_rng(0).standard_normal(n)
    x, iters, rel = bicgstab_solve(a, b, tol=1e-9, maxiter=200,
                                   spmv=op, spmv_t=op.T)
    assert rel < 1e-9
    np.testing.assert_allclose(a.matvec(x), b, rtol=1e-6, atol=1e-7)


def test_check_api_smoke():
    """scripts/check_api.py — the operator + deprecation-contract smoke —
    must pass in its own process (it forces the XLA device count)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "check_api.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "API OK" in proc.stdout


@pytest.mark.multidev
def test_operator_shardmap_8dev():
    """Full shardmap operator sweep (forward+transpose, nap+standard,
    multi-RHS, donate) on a forced 8-device host platform."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable,
         str(ROOT / "tests" / "multidev" / "operator_prog.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL OK" in proc.stdout
