"""Comm-strategy subsystem: the multi-step exchange, planned traffic,
the postal cost model, and the per-level comm autotuner.

Host-side (tier-1): plan-split invariants, the float64 multi-step
simulators against the dense oracle AND bit-for-bit against the nap
simulator, slot-granular traffic accounting, the chooser's preference
order, and ``comm="auto"`` resolving per level over a 3-level hierarchy
with a skewed near-dense coarse level.  The shardmap-vs-simulator
bitwise sweep lives in tests/multidev/comm_prog.py.
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import repro.api as nap
from repro.comm import (AUTO_THRESHOLD, COMM_CHOICES, COMM_STRATEGIES,
                        build_candidate_plans, build_multistep_plan,
                        choose_comm, comm_verdict, duplication_counts,
                        multistep_stats, planned_traffic,
                        simulate_multistep_spmv,
                        simulate_multistep_spmv_transpose)
from repro.core.comm_graph import build_nap_plan, build_standard_plan
from repro.core.cost_model import (TPU_V5E_POSTAL, postal_comm_time,
                                   postal_phase_time)
from repro.core.partition import contiguous_partition
from repro.core.spmv import simulate_nap_spmv, simulate_nap_spmv_transpose
from repro.core.topology import Topology
from repro.sparse import random_fixed_nnz
from repro.sparse.csr import CSR

ROOT = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# matrix builders
# ---------------------------------------------------------------------------

def dense_of(a: CSR) -> np.ndarray:
    d = np.zeros(a.shape)
    for i in range(a.shape[0]):
        for k in range(a.indptr[i], a.indptr[i + 1]):
            d[i, a.indices[k]] += a.data[k]
    return d


def skewed_matrix(topo, rows_per_rank=64, bulk=40, seed=0):
    """The near-dense-coarse-level pattern that favours the multi-step
    exchange: every rank needs one column of each remote rank that its
    whole node also needs (duplication d = ppn -> the node-aware dedup
    path), and each node-0 rank additionally pulls ``bulk`` columns of
    its node-1 peer that nobody else wants (d = 1 -> direct).  The d=1
    bulk inflates the nap inter phase's shared pad in one node-pair
    direction only; peeling it into direct messages shrinks the pad
    every inter message pays.
    """
    n = rows_per_rank * topo.n_procs
    part = contiguous_partition(n, topo.n_procs)
    rng = np.random.default_rng(seed)
    rows = [[] for _ in range(n)]
    lo = lambda r: r * rows_per_rank
    for r in range(topo.n_procs):
        node, lr = topo.node_of(r), topo.local_of(r)
        remote = [q for q in range(topo.n_procs) if topo.node_of(q) != node]
        base = lo(r)
        for i in range(rows_per_rank):
            rows[base + i].append(base + i)
        for src in remote:  # shared background: d = ppn
            for i in range(rows_per_rank):
                rows[base + i].append(lo(src))
        if node == 0:       # exclusive bulk, node 0 only: d = 1
            src = remote[lr]
            for k in range(bulk):
                gi = base + int(rng.integers(rows_per_rank))
                rows[gi].append(lo(src) + 1 + k)
    indptr = [0]
    indices = []
    for rr in rows:
        cols = sorted(set(rr))
        indices.extend(cols)
        indptr.append(len(indices))
    data = rng.standard_normal(len(indices))
    return CSR(np.array(indptr, np.int64), np.array(indices, np.int64),
               data, (n, n)), part


# ---------------------------------------------------------------------------
# split invariants
# ---------------------------------------------------------------------------

def test_duplication_counts_handmade():
    """d counts requesting processes per (requester node, column)."""
    topo = Topology(2, 2)
    # requesting ranks 0 and 1 live on node 0, rank 2 on node 1
    t = np.array([0, 1, 0, 2])
    j = np.array([4, 4, 6, 4])
    d = duplication_counts(t, j, topo, n_cols=8)
    # col 4: two node-0 requesters (d=2 each) + one node-1 (d=1)
    np.testing.assert_array_equal(d, [2, 2, 1, 1])
    assert duplication_counts(np.zeros(0, np.int64), np.zeros(0, np.int64),
                              topo, n_cols=8).size == 0


def test_multistep_split_partitions_offnode_triples():
    """Direct + nap sub-plans cover the off-proc structure exactly once:
    message volumes of (nap sub-plan init+full+direct sends) equal the
    plain nap plan's (init+full) plus nothing lost."""
    topo = Topology(2, 4)
    a, part = skewed_matrix(topo, rows_per_rank=16, bulk=12, seed=1)
    ms = build_multistep_plan(a.indptr, a.indices, part, topo)
    plain = build_nap_plan(a.indptr, a.indices, part, topo,
                           pairing="balanced")

    def vol(sends):
        return sum(m.size for msgs in sends for m in msgs)

    # the direct share is exactly the low-duplication off-node triples
    from repro.core.comm_graph import _offproc_pairs
    t, r, j = _offproc_pairs(a.indptr, a.indices, part, part)
    off = topo.node_of_array(t) != topo.node_of_array(r)
    d = duplication_counts(t[off], j[off], topo, a.shape[1])
    assert vol(ms.direct.sends) == int((d < AUTO_THRESHOLD).sum()) > 0
    # every direct message crosses nodes by construction
    for rr in range(topo.n_procs):
        for m in ms.direct.sends[rr]:
            assert not topo.same_node(m.src, m.dst)
    # the fully-local phase is untouched by the split
    assert vol(ms.nap.local_full_sends) == vol(plain.local_full_sends)
    st = multistep_stats(ms)
    assert st["direct"].total_msgs > 0
    assert ms.threshold == AUTO_THRESHOLD


def test_threshold_one_degenerates_to_nap():
    """d >= 1 always, so threshold=1 sends nothing direct and the
    multi-step simulator is bit-for-bit the nap simulator."""
    topo = Topology(2, 4)
    a, part = skewed_matrix(topo, rows_per_rank=16, bulk=12, seed=2)
    ms = build_multistep_plan(a.indptr, a.indices, part, topo, threshold=1)
    assert sum(len(m) for m in ms.direct.sends) == 0
    plain = build_nap_plan(a.indptr, a.indices, part, topo,
                           pairing="balanced")
    v = np.random.default_rng(0).standard_normal(a.shape[1])
    np.testing.assert_array_equal(simulate_multistep_spmv(a, v, ms),
                                  simulate_nap_spmv(a, v, plain))


# ---------------------------------------------------------------------------
# simulators
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo_shape", [(2, 2), (2, 4), (4, 2)])
def test_multistep_simulator_square(topo_shape):
    nn, ppn = topo_shape
    topo = Topology(nn, ppn)
    a, part = skewed_matrix(topo, rows_per_rank=12, bulk=8, seed=nn)
    dense = dense_of(a)
    rng = np.random.default_rng(5)
    v = rng.standard_normal(a.shape[1])
    u = rng.standard_normal(a.shape[0])
    ms = build_multistep_plan(a.indptr, a.indices, part, topo)
    plain = build_nap_plan(a.indptr, a.indices, part, topo,
                           pairing="balanced")
    w = simulate_multistep_spmv(a, v, ms)
    np.testing.assert_allclose(w, dense @ v, rtol=1e-12, atol=1e-13)
    # same arrival values, same local kernel order -> bitwise equal
    np.testing.assert_array_equal(w, simulate_nap_spmv(a, v, plain))
    z = simulate_multistep_spmv_transpose(a, u, ms)
    np.testing.assert_allclose(z, dense.T @ u, rtol=1e-12, atol=1e-13)


def test_multistep_simulator_rectangular_empty_ranks():
    """Rectangular operator whose column partition leaves ranks empty."""
    topo = Topology(2, 4)
    m, n = 96, 6  # 6 cols over 8 ranks -> at least two empty ranks
    row_part = contiguous_partition(m, topo.n_procs)
    col_part = contiguous_partition(n, topo.n_procs)
    assert min(np.bincount(col_part.owner, minlength=topo.n_procs)) == 0
    a = random_fixed_nnz(m, 3, seed=9)
    # rewrap onto n columns
    indices = a.indices % n
    indptr, idx2 = [0], []
    for i in range(m):
        cols = sorted(set(indices[a.indptr[i]:a.indptr[i + 1]].tolist()))
        idx2.extend(cols)
        indptr.append(len(idx2))
    rng = np.random.default_rng(3)
    a = CSR(np.array(indptr, np.int64), np.array(idx2, np.int64),
            rng.standard_normal(len(idx2)), (m, n))
    dense = dense_of(a)
    v, u = rng.standard_normal(n), rng.standard_normal(m)
    ms = build_multistep_plan(a.indptr, a.indices, row_part, topo,
                              col_part=col_part)
    np.testing.assert_allclose(simulate_multistep_spmv(a, v, ms), dense @ v,
                               rtol=1e-12, atol=1e-13)
    np.testing.assert_allclose(simulate_multistep_spmv_transpose(a, u, ms),
                               dense.T @ u, rtol=1e-12, atol=1e-13)


# ---------------------------------------------------------------------------
# operator front-end
# ---------------------------------------------------------------------------

def test_comm_pins_strategy_and_nap_is_bit_identical():
    topo = Topology(2, 4)
    a, part = skewed_matrix(topo, rows_per_rank=16, bulk=12, seed=4)
    rng = np.random.default_rng(1)
    v = rng.standard_normal(a.shape[1])
    base = nap.operator(a, topo=topo, part=part, backend="simulate")
    pinned = nap.operator(a, topo=topo, part=part, backend="simulate",
                          comm="nap")
    # comm="nap" routes through the exact pre-existing executor
    assert pinned.method == "nap"
    np.testing.assert_array_equal(pinned @ v, base @ v)
    np.testing.assert_array_equal(pinned.T @ v, base.T @ v)
    # comm takes precedence over method
    over = nap.operator(a, topo=topo, part=part, backend="simulate",
                        method="standard", comm="multistep")
    assert over.method == "multistep"
    rep = over.autotune_report()
    assert rep["comm_resolved"] == "multistep"
    assert rep["comm"]["requested"] == "multistep"
    with pytest.raises(ValueError):
        nap.operator(a, topo=topo, part=part, comm="telepathy")


def test_comm_choices_registry():
    assert COMM_CHOICES == ("standard", "nap", "multistep", "auto")
    assert set(COMM_STRATEGIES) == {"standard", "nap", "multistep"}
    for s in COMM_STRATEGIES.values():
        assert s.phases  # every strategy declares its exchange phases


def test_operator_all_strategies_match_oracle():
    topo = Topology(2, 4)
    a, part = skewed_matrix(topo, rows_per_rank=16, bulk=12, seed=6)
    dense = dense_of(a)
    rng = np.random.default_rng(2)
    v = rng.standard_normal(a.shape[1])
    for comm in ("standard", "nap", "multistep", "auto"):
        op = nap.operator(a, topo=topo, part=part, backend="simulate",
                          comm=comm)
        np.testing.assert_allclose(op @ v, dense @ v, rtol=1e-12, atol=1e-13)
        np.testing.assert_allclose(op.T @ v, dense.T @ v,
                                   rtol=1e-12, atol=1e-13)


# ---------------------------------------------------------------------------
# planned traffic + postal model
# ---------------------------------------------------------------------------

def test_planned_traffic_effective_le_injected():
    topo = Topology(2, 4)
    a, part = skewed_matrix(topo, rows_per_rank=16, bulk=12, seed=7)
    plans = build_candidate_plans(a.indptr, a.indices, part, topo)
    for name, plan in plans.items():
        for direction in ("forward", "transpose"):
            t = planned_traffic(plan, direction=direction)
            assert t["strategy"] == name and t["direction"] == direction
            assert t["effective_inter_bytes"] <= t["injected_inter_bytes"]
            assert t["effective_intra_bytes"] <= t["injected_intra_bytes"]
            for ph in t["phases"].values():
                assert ph["effective_bytes"] <= ph["padded_bytes"]
                assert ph["max_rank_padded_bytes"] <= ph["padded_bytes"]
                assert ph["checksum_bytes"] == 0  # integrity off


def test_planned_traffic_counts_integrity_side_channel():
    """integrity != off adds the PR 7 checksum exchange: one u32 per
    message slot per phase that has any traffic."""
    topo = Topology(2, 4)
    a, part = skewed_matrix(topo, rows_per_rank=16, bulk=12, seed=7)
    plan = build_multistep_plan(a.indptr, a.indices, part, topo)
    off = planned_traffic(plan, integrity="off")
    det = planned_traffic(plan, integrity="detect")
    grew = 0
    for name, ph in det["phases"].items():
        if ph["n_msgs"] > 0:
            assert ph["checksum_bytes"] > 0
            grew += 1
        else:
            assert ph["checksum_bytes"] == 0
    assert grew >= 2  # at least inter + direct carry traffic here
    assert det["injected_inter_bytes"] > off["injected_inter_bytes"]


def test_simulate_stats_report_direct_phase():
    topo = Topology(2, 4)
    a, part = skewed_matrix(topo, rows_per_rank=16, bulk=12, seed=7)
    op = nap.operator(a, topo=topo, part=part, backend="simulate",
                      comm="multistep")
    st = op.stats()
    assert st["messages_direct"].total_msgs > 0


def test_postal_phase_time_shape():
    p = TPU_V5E_POSTAL
    assert postal_phase_time(0, 0, True, p) == 0.0
    t1 = postal_phase_time(1, 1024, True, p)
    t2 = postal_phase_time(2, 2048, True, p)
    assert t2 > t1 > 0.0
    # intra beats inter for the same payload
    assert postal_phase_time(1, 1024, False, p) < t1
    topo = Topology(2, 4)
    a, part = skewed_matrix(topo, rows_per_rank=16, bulk=12, seed=7)
    plan = build_nap_plan(a.indptr, a.indices, part, topo,
                          pairing="balanced")
    times = postal_comm_time(planned_traffic(plan), p)
    assert times["total"] == pytest.approx(
        sum(v for k, v in times.items() if k != "total"))


# ---------------------------------------------------------------------------
# chooser
# ---------------------------------------------------------------------------

def test_chooser_prefers_nap_on_uniform_structure():
    """Uniform random structure: dedup wins, direct split saves nothing,
    and the empty-direct multistep ties nap -> preference keeps nap."""
    topo = Topology(2, 4)
    n = 256
    part = contiguous_partition(n, topo.n_procs)
    a = random_fixed_nnz(n, 12, seed=11)
    v = choose_comm(a.indptr, a.indices, part, topo)
    assert v["forward"]["chosen"] == "nap"
    assert v["transpose"]["chosen"] == "nap"


def test_chooser_picks_multistep_on_skewed_structure():
    """The acceptance matrix: the d=1 bulk inflates nap's shared inter
    pad, multistep strictly reduces modeled injected inter-node bytes
    and the chooser takes it in both directions."""
    topo = Topology(2, 4)
    a, part = skewed_matrix(topo)
    v = choose_comm(a.indptr, a.indices, part, topo)
    for d in ("forward", "transpose"):
        cand = v[d]["candidates"]
        assert v[d]["chosen"] == "multistep"
        assert cand["multistep"]["injected_inter_bytes"] < \
            cand["nap"]["injected_inter_bytes"]


def test_comm_auto_resolves_through_operator():
    topo = Topology(2, 4)
    a, part = skewed_matrix(topo)
    dense = dense_of(a)
    op = nap.operator(a, topo=topo, part=part, backend="simulate",
                      comm="auto")
    assert op.method == "multistep"
    rep = op.autotune_report()
    assert rep["comm"]["requested"] == "auto"
    assert rep["comm_resolved"] == "multistep"
    assert rep["comm_transpose_resolved"] in ("multistep", "nap", "standard")
    rng = np.random.default_rng(8)
    v = rng.standard_normal(a.shape[1])
    np.testing.assert_allclose(op @ v, dense @ v, rtol=1e-12, atol=1e-13)
    np.testing.assert_allclose(op.T @ v, dense.T @ v,
                               rtol=1e-12, atol=1e-13)


# ---------------------------------------------------------------------------
# per-level autotuning over a hierarchy (satellite 3)
# ---------------------------------------------------------------------------

def test_auto_hierarchy_fine_nap_coarse_multistep():
    """3-level hierarchy, skewed near-dense coarse level: comm="auto"
    keeps the uniform fine/mid levels on nap and moves the coarse level
    off it, with rectangular P operators riding along."""
    from repro.amg import Level, level_operators

    topo = Topology(2, 4)
    coarse_a, _ = skewed_matrix(topo, rows_per_rank=64, bulk=40, seed=12)
    n2 = coarse_a.shape[0]            # 512
    n1, n0 = n2 * 2, n2 * 4
    fine_a = random_fixed_nnz(n0, 4, seed=13)
    mid_a = random_fixed_nnz(n1, 6, seed=14)

    def injection_p(nf, nc):
        k = nf // nc
        indptr = np.arange(nf + 1, dtype=np.int64)
        indices = (np.arange(nf) // k).astype(np.int64)
        return CSR(indptr, indices, np.ones(nf), (nf, nc))

    levels = [Level(a=fine_a, p=injection_p(n0, n1)),
              Level(a=mid_a, p=injection_p(n1, n2)),
              Level(a=coarse_a)]
    ops = level_operators(levels, topo, backend="simulate", comm="auto")
    assert ops[0].a.method == "nap"
    assert ops[1].a.method == "nap"
    assert ops[2].a.method in ("multistep", "standard")
    assert ops[2].a.method == "multistep"  # the skew is multistep-shaped
    # every level's verdict is inspectable
    for entry in ops:
        rep = entry.a.autotune_report()
        assert rep["comm"]["requested"] == "auto"
    # the rectangular grid transfers resolved per direction and apply
    rng = np.random.default_rng(15)
    xc = rng.standard_normal(n1)
    np.testing.assert_allclose(ops[0].p @ xc,
                               dense_of(levels[0].p) @ xc,
                               rtol=1e-12, atol=1e-13)
    r = rng.standard_normal(n0)
    np.testing.assert_allclose(ops[0].r @ r,
                               dense_of(levels[0].p).T @ r,
                               rtol=1e-12, atol=1e-13)
    # coarse-level operator matches its oracle under the chosen strategy
    vc = rng.standard_normal(n2)
    np.testing.assert_allclose(ops[2].a @ vc, dense_of(coarse_a) @ vc,
                               rtol=1e-12, atol=1e-13)


# ---------------------------------------------------------------------------
# shardmap sweep (subprocess, forced 8-device host)
# ---------------------------------------------------------------------------

@pytest.mark.multidev
def test_comm_shardmap_8dev():
    """All three strategies' shard_map programs bit-for-bit against their
    float64 simulators (integer-valued data), empty ranks, rectangular
    operators, comm="auto" end-to-end, and comm="nap" bit-identical to
    the pre-existing program."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "multidev" / "comm_prog.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL OK" in proc.stdout
