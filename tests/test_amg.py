"""AMG hierarchy + solver correctness (scipy used as independent oracle)."""
import numpy as np
import pytest
import scipy.sparse as sp

from repro.amg import amg_vcycle, cg_solve, csr_matmul, smoothed_aggregation_hierarchy
from repro.amg.hierarchy import standard_aggregation, strength_graph, tentative_prolongator
from repro.sparse import CSR, linear_elasticity_2d, poisson_2d, rotated_anisotropic_2d


def to_scipy(a: CSR):
    return sp.csr_matrix((a.data, a.indices, a.indptr), shape=a.shape)


def test_csr_matmul_vs_scipy():
    rng = np.random.default_rng(0)
    for _ in range(5):
        a = (rng.random((23, 17)) < 0.2) * rng.standard_normal((23, 17))
        b = (rng.random((17, 31)) < 0.2) * rng.standard_normal((17, 31))
        got = csr_matmul(CSR.from_dense(a), CSR.from_dense(b)).to_dense()
        np.testing.assert_allclose(got, a @ b, atol=1e-12)


def test_aggregation_covers_all_nodes():
    a = poisson_2d(16)
    s = strength_graph(a, theta=0.1)
    agg = standard_aggregation(s)
    assert (agg >= 0).all()
    assert agg.max() + 1 < a.shape[0]  # actually coarsens


def test_tentative_prolongator_orthonormal_columns():
    a = poisson_2d(12)
    agg = standard_aggregation(strength_graph(a))
    t, bc = tentative_prolongator(agg, np.ones((a.shape[0], 1)))
    td = t.to_dense()
    gram = td.T @ td
    np.testing.assert_allclose(gram, np.eye(gram.shape[0]), atol=1e-12)


def test_hierarchy_shapes_and_galerkin():
    a = rotated_anisotropic_2d(20, eps=0.01)
    levels = smoothed_aggregation_hierarchy(a, coarse_size=30)
    assert len(levels) >= 2
    for lvl in range(len(levels) - 1):
        al, p, ac = levels[lvl].a, levels[lvl].p, levels[lvl + 1].a
        assert p.shape == (al.shape[0], ac.shape[0])
        # Galerkin: A_c == P^T A P (oracle via scipy)
        want = (to_scipy(p).T @ to_scipy(al) @ to_scipy(p)).toarray()
        np.testing.assert_allclose(ac.to_dense(), want, atol=1e-8 * np.abs(want).max())
        assert ac.shape[0] < al.shape[0]


@pytest.mark.parametrize("prob", ["poisson", "anis", "elasticity"])
def test_vcycle_converges(prob):
    theta = 0.0
    if prob == "poisson":
        n = 24
        a = poisson_2d(n)
        a = CSR.from_dense(a.to_dense() + np.eye(n * n) * 1e-3)  # regularize Neumann
        ns = np.ones((a.shape[0], 1))
    elif prob == "anis":
        a = rotated_anisotropic_2d(24, eps=0.01)
        a = CSR.from_dense(a.to_dense() + np.eye(a.shape[0]) * 1e-3)
        ns = np.ones((a.shape[0], 1))
        theta = 0.1
    else:
        n = 10
        a = linear_elasticity_2d(n)
        # 3 rigid-body modes (tx, ty, rotation) — the standard SA nullspace
        xy = np.stack(np.meshgrid(np.arange(n), np.arange(n), indexing="ij"),
                      -1).reshape(-1, 2).astype(float)
        ns = np.zeros((a.shape[0], 3))
        ns[0::2, 0] = 1.0
        ns[1::2, 1] = 1.0
        ns[0::2, 2] = -xy[:, 1]
        ns[1::2, 2] = xy[:, 0]
        theta = 0.05
    levels = smoothed_aggregation_hierarchy(a, nullspace=ns, theta=theta,
                                            coarse_size=40)
    rng = np.random.default_rng(0)
    x_true = rng.standard_normal(a.shape[0])
    b = a.matvec(x_true)
    x = np.zeros_like(b)
    res0 = np.linalg.norm(b)
    # plain SA + Jacobi converges at ~0.6/cycle on the hard cases (strong
    # rotated anisotropy, elasticity); 25 cycles must reach 1e-5 everywhere.
    for _ in range(25):
        x = amg_vcycle(levels, b, x)
    res = np.linalg.norm(b - a.matvec(x)) / res0
    assert res < 1e-5, f"V-cycle stalled at relres {res:.2e} for {prob}"


def test_cg_with_amg_preconditioner():
    a = poisson_2d(20)
    a = CSR.from_dense(a.to_dense() + np.eye(a.shape[0]) * 1e-3)
    levels = smoothed_aggregation_hierarchy(a, coarse_size=40)
    rng = np.random.default_rng(1)
    b = rng.standard_normal(a.shape[0])
    x_plain, it_plain, _ = cg_solve(a, b, tol=1e-8, maxiter=2000)
    x_amg, it_amg, rel = cg_solve(a, b, tol=1e-8, maxiter=200,
                                  precond=lambda r: amg_vcycle(levels, r))
    assert rel < 1e-8
    assert it_amg < it_plain / 2, (it_amg, it_plain)
    np.testing.assert_allclose(x_amg, x_plain, atol=1e-5)
