"""Silent-data-corruption defense: wire checksums, ABFT verification,
scripted message faults, self-verifying solvers, digest-checked
checkpoints, and the service-level detect/recover/quarantine flow.

Tier-1 runs the numpy/simulate layers in-process (the checksum twins, a
seeded clean-apply sweep over rectangular / empty-rank / uneven layouts,
fault detection with phase+message attribution on the simulate wire,
solver replay-rollback, checkpoint digests, deterministic retry jitter,
and the SolverService scenarios) plus the --quick 4-device shardmap
program as a subprocess.  The full 8-device kind x phase x direction
sweep is the ``multidev``-marked run of the same program.
"""
import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

import repro.api as nap
from repro.amg.solve import bicgstab_solve, cg_solve
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core.integrity import (IntegrityError, MessageFault,
                                  build_fault_spec, checksum_np,
                                  corrupt_payload_np, message_phases)
from repro.core.partition import contiguous_partition, make_partition
from repro.core.topology import Topology
from repro.serve import FaultEvent, FaultPlan, SolverService
from repro.serve.faultplan import (corrupt_message, drop_message,
                                   duplicate_message)
from repro.sparse import CSR, random_fixed_nnz
from repro.spgemm.shardmap import distributed_spgemm

ROOT = pathlib.Path(__file__).resolve().parents[1]


def band_spd(n, diag=4.0, bands=(1, 7)):
    m = np.eye(n) * diag
    for d in bands:
        idx = np.arange(n - d)
        m[idx, idx + d] = m[idx + d, idx] = -1.0
    return CSR.from_dense(m)


# ------------------------- checksum primitives -----------------------------

def test_checksum_np_matches_jnp_twin():
    """The host Fletcher checksum and the in-graph one are bit-identical
    twins over f32 AND f64 payloads — the wire comparison depends on it."""
    import jax
    import jax.numpy as jnp
    from repro.core.spmv_jax import _msg_checksums
    rng = np.random.default_rng(0)
    # f64 words need x64 enabled to survive jnp.asarray un-truncated
    # (the f64 SpGEMM wire runs under the same flag)
    with jax.experimental.enable_x64():
        for dtype in (np.float32, np.float64):
            for shape in [(3, 8), (1, 1), (4, 5)]:
                buf = rng.standard_normal(shape).astype(dtype)
                buf[0, -1] = 0.0      # padding-like zeros included
                got = np.asarray(_msg_checksums(jnp.asarray(buf)))
                want = [checksum_np(row) for row in buf]
                assert got.tolist() == want


def test_checksum_position_weighted():
    """Swapping two elements (same multiset of words) changes the
    checksum — what lets the wire catch stale/shifted payloads."""
    rng = np.random.default_rng(1)
    v = rng.standard_normal(16).astype(np.float32)
    w = v.copy()
    w[2], w[9] = v[9], v[2]
    assert checksum_np(v) != checksum_np(w)
    assert checksum_np(v) == checksum_np(v.copy())


def test_corrupt_payload_np_kinds():
    rng = np.random.default_rng(2)
    v = rng.standard_normal(8).astype(np.float32)
    prev = rng.standard_normal(8).astype(np.float32)
    c0 = checksum_np(v)
    for kind in ("zero", "drop"):
        assert not corrupt_payload_np(v, kind).any()
    assert checksum_np(corrupt_payload_np(v, "stale")) != c0
    assert np.array_equal(corrupt_payload_np(v, "stale"), np.roll(v, 1))
    assert np.array_equal(corrupt_payload_np(v, "duplicate", other=prev), prev)
    assert not corrupt_payload_np(v, "duplicate").any()  # no other message
    flipped = corrupt_payload_np(v, "bitflip", element=3, bit=20)
    assert checksum_np(flipped) != c0
    # flipping the same bit twice restores the payload exactly
    assert np.array_equal(
        corrupt_payload_np(flipped, "bitflip", element=3, bit=20), v)
    with pytest.raises(ValueError):
        corrupt_payload_np(v, "gamma-ray")


def test_message_fault_validation():
    with pytest.raises(ValueError):
        MessageFault(phase="warp")
    with pytest.raises(ValueError):
        MessageFault(phase="full", kind="gamma-ray")
    with pytest.raises(ValueError):
        MessageFault(phase="compute", kind="zero")   # ABFT models bitflips
    with pytest.raises(ValueError):
        MessageFault(phase="full", direction="sideways")


def test_build_fault_spec_pure_and_validated():
    topo = Topology(2, 2)
    faults = [MessageFault(phase="inter", node=1, proc=0, slot=0,
                           element=3, bit=20)]
    s1 = build_fault_spec(topo, faults, "nap")
    s2 = build_fault_spec(topo, faults, "nap")
    assert np.array_equal(s1, s2) and s1.dtype == np.int32
    assert not build_fault_spec(topo, [], "nap").any()
    with pytest.raises(ValueError):        # pair is standard-only
        build_fault_spec(topo, [MessageFault(phase="pair")], "nap")
    with pytest.raises(ValueError):        # sender outside the topology
        build_fault_spec(topo, [MessageFault(phase="full", node=5)], "nap")
    with pytest.raises(ValueError):        # two faults, same device+phase
        build_fault_spec(topo, [MessageFault(phase="full", slot=0),
                                MessageFault(phase="full", slot=1)], "nap")
    assert message_phases("nap") == ("full", "init", "inter", "final")
    assert message_phases("standard") == ("pair",)


# ------------------------- simulate-backend wire ---------------------------

def sim_op(a, topo, integrity, method="nap"):
    return nap.operator(a, topo=topo,
                        part=contiguous_partition(a.shape[0], topo.n_procs),
                        method=method, backend="simulate",
                        integrity=integrity)


def test_simulate_detect_attribution_and_recover():
    """Scripted faults on REAL message edges of the simulate wire: detect
    raises with phase + receiver + scope attribution, recover reruns
    clean bit-for-bit, strikes accumulate against the implicated node."""
    topo = Topology(2, 2)
    a = band_spd(64)
    rng = np.random.default_rng(3)
    v = rng.standard_normal(64)
    y0 = sim_op(a, topo, "off") @ v

    op = sim_op(a, topo, "detect")
    assert np.array_equal(op @ v, y0)      # clean detect adds no numerics
    rep = op.integrity_report()
    assert rep["wire_mismatches"] == 0 and rep["wire_checks"] > 0, rep

    # real edges for this band matrix on (2, 2): see the message list the
    # SimWire actually carries (intra-node neighbors + the node pair)
    edges = [("full", 0, 0, 1, "on_node"), ("init", 0, 1, 0, "off_node"),
             ("inter", 1, 0, 0, "off_node"), ("final", 1, 1, 0, "off_node")]
    for phase, node, proc, slot, scope in edges:
        op.inject_fault(phase, "bitflip", node=node, proc=proc, slot=slot,
                        element=0, bit=20)
        with pytest.raises(IntegrityError) as ei:
            op @ v
        m = ei.value.mismatches[0]
        assert (m.phase, m.scope, m.direction) == (phase, scope, "forward")

    rec = sim_op(a, topo, "recover")
    rec.inject_fault("inter", "bitflip", node=1, proc=0, slot=0,
                     element=0, bit=20)
    assert np.array_equal(rec @ v, y0)
    rep = rec.integrity_report()
    assert rep["retries"] == 1 and rep["recovered"] == 1, rep
    assert rep["strikes"].get("node1") == 1, rep

    # transpose fault injection is shardmap-only on this backend
    rec.T.inject_fault("inter", "bitflip", node=1, proc=0, slot=0)
    with pytest.raises(NotImplementedError):
        rec.T @ v

    with pytest.raises(ValueError):        # integrity="off" has no wire
        sim_op(a, topo, "off").queue_fault(MessageFault(phase="full"))
    with pytest.raises(ValueError):
        nap.operator(a, topo=topo, integrity="sometimes")


@pytest.mark.parametrize("seed", range(5))
def test_clean_apply_checksum_sweep(seed):
    """Seeded sweep over square / rectangular / empty-rank / uneven
    layouts, both methods: every pack -> exchange -> unpack round trip
    re-verifies its checksums with ZERO mismatches, and the instrumented
    apply is bit-identical to the uninstrumented one."""
    rng = np.random.default_rng(seed)
    topo = Topology(2, 2)
    m = int(rng.integers(9, 70))
    n = m if seed % 2 == 0 else int(rng.integers(3, 70))
    a = random_fixed_nnz(m, int(rng.integers(2, 7)), seed=seed) \
        if m == n else CSR.from_dense(
            (rng.random((m, n)) < 0.3) * rng.standard_normal((m, n)))
    kind = ["contiguous", "strided"][seed % 2]
    row_part = make_partition(kind, m, topo.n_procs, indptr=a.indptr,
                              indices=a.indices, seed=seed)
    col_part = row_part if m == n else contiguous_partition(n, topo.n_procs)
    method = ["nap", "standard"][seed % 2]
    v = rng.standard_normal(n)
    kw = dict(topo=topo, row_part=row_part, col_part=col_part,
              method=method, backend="simulate")
    y0 = nap.operator(a, **kw) @ v
    op = nap.operator(a, integrity="detect", **kw)
    assert np.array_equal(op @ v, y0)
    u = rng.standard_normal(m)
    assert np.array_equal(op.T @ u, nap.operator(a, **kw).T @ u)
    rep = op.integrity_report()
    assert rep["wire_mismatches"] == 0 and rep["abft_mismatches"] == 0, rep
    assert rep["wire_checks"] > 0


# ------------------------- self-verifying solvers --------------------------

def test_cg_replay_rollback_bit_identical():
    a = band_spd(64)
    rng = np.random.default_rng(1)
    b = rng.standard_normal(64)
    x_clean, it_clean, _ = cg_solve(a, b, tol=1e-10)

    calls = {"n": 0}

    def transient_mv(v):
        calls["n"] += 1
        w = a.matvec(v)
        if calls["n"] == 7:                # fires once, then clean
            w = w.copy()
            w[3] += 1.0
        return w

    x_v, it_v, _ = cg_solve(a, b, tol=1e-10, spmv=transient_mv,
                            verify_every=2)
    assert np.array_equal(x_v, x_clean)
    # the clean run is also bit-identical with verification enabled
    x_d, it_d, _ = cg_solve(a, b, tol=1e-10, verify_every=2)
    assert np.array_equal(x_d, x_clean) and it_d == it_clean


def test_cg_persistent_corruption_raises():
    a = band_spd(64)
    b = np.ones(64)

    def persistent_mv(v):
        w = a.matvec(v)
        w = w.copy()
        w[3] += 1.0
        return w

    with pytest.raises(IntegrityError, match="twice"):
        cg_solve(a, b, tol=1e-10, spmv=persistent_mv, verify_every=2)


def test_bicgstab_replay_rollback_bit_identical():
    a = band_spd(64)
    rng = np.random.default_rng(4)
    b = rng.standard_normal(64)
    x_clean, _, _ = bicgstab_solve(a, b, tol=1e-10)

    calls = {"n": 0}

    def transient_mv(v):
        calls["n"] += 1
        w = a.matvec(v)
        if calls["n"] == 5:
            w = w.copy()
            w[0] += 1.0
        return w

    x_v, _, _ = bicgstab_solve(a, b, tol=1e-10, spmv=transient_mv,
                               verify_every=2)
    assert np.array_equal(x_v, x_clean)
    # the BiCG branch (explicit transpose recurrence) verifies too
    spmv_t = lambda v: a.to_dense().T @ v
    x_t, _, _ = bicgstab_solve(a, b, tol=1e-10, spmv_t=spmv_t)
    x_tv, _, _ = bicgstab_solve(a, b, tol=1e-10, spmv_t=spmv_t,
                                verify_every=3)
    assert np.array_equal(x_tv, x_t)


# ------------------------- checkpoint digests ------------------------------

def test_checkpoint_digest_detects_shard_corruption(tmp_path):
    p = save_checkpoint(tmp_path, 1, {"x": np.arange(32.0)})
    load_checkpoint(tmp_path)              # clean load verifies quietly
    shard = pathlib.Path(p) / "shard_0.npz"
    raw = bytearray(shard.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    shard.write_bytes(bytes(raw))
    with pytest.raises(IntegrityError, match="shard_0.npz"):
        load_checkpoint(tmp_path)


def test_checkpoint_pre_digest_manifest_still_loads(tmp_path):
    p = pathlib.Path(save_checkpoint(tmp_path, 1, {"x": np.arange(8.0)}))
    mf = p / "manifest.json"
    manifest = json.loads(mf.read_text())
    del manifest["shard_digests"]          # a checkpoint from before ABFT
    mf.write_text(json.dumps(manifest))
    tree, _extra = load_checkpoint(tmp_path)
    assert np.array_equal(tree["x"], np.arange(8.0))


# ------------------------- service-level flow ------------------------------

def _service(plan=None, integrity="off", a=None, **kw):
    svc = SolverService(Topology(2, 2), fault_plan=plan,
                       integrity=integrity, **kw)
    svc.register_matrix("A", a if a is not None else band_spd(64))
    return svc


def _run_requests(svc):
    rng = np.random.default_rng(5)
    tickets = [svc.submit(t, "A", rng.standard_normal(64), kind=k, tol=1e-10)
               for t, k in (("t0", "spmv"), ("t1", "solve"))]
    svc.run()
    return [t.result() for t in tickets]


def test_backoff_jitter_deterministic():
    svc = _service()
    d1 = svc._backoff_delay(17, 2)
    d2 = _service()._backoff_delay(17, 2)
    assert d1 == d2                        # pure function of (id, attempt)
    assert 2.0 <= d1 <= 2.5                # base 2.0, jitter in [0, 25%]
    assert len({svc._backoff_delay(i, 1) for i in range(20)}) == 20


def test_service_recover_bit_identical_under_scripted_faults():
    base = _run_requests(_service())
    plan = FaultPlan.of(
        corrupt_message(1, ("inter", (1, 0), 0), kind="bitflip",
                        element=1, bit=20),
        drop_message(2, ("final", (1, 1), 0)))
    got = _run_requests(_service(plan=plan, integrity="recover"))
    for w0, w1 in zip(base, got):
        assert np.array_equal(w0, w1)


def test_service_detect_retries_then_completes_clean():
    base = _run_requests(_service())
    svc = _service(plan=FaultPlan.of(
        corrupt_message(1, ("full", (0, 1), 0), kind="zero", element=0)),
        integrity="detect")
    got = _run_requests(svc)
    assert svc.stats["integrity_detected"] >= 1, svc.stats
    assert svc.stats["retries"] >= 1
    for w0, w1 in zip(base, got):          # fault fires once; retry clean
        assert np.array_equal(w0, w1)


def test_service_off_drops_message_faults_logged():
    base = _run_requests(_service())
    plan = FaultPlan.of(corrupt_message(1, ("inter", (1, 0), 0)),
                        drop_message(2, ("final", (1, 1), 0)))
    svc = _service(plan=plan, integrity="off")
    got = _run_requests(svc)
    assert svc.stats["message_faults"] == 2
    assert any("dropped" in line for line in svc.log)
    for w0, w1 in zip(base, got):
        assert np.array_equal(w0, w1)


def test_service_quarantines_repeat_offender_node():
    events = [corrupt_message(s, ("inter", (1, 0), 0), kind="bitflip",
                              element=1, bit=20) for s in (1, 2, 3)]
    svc = _service(plan=FaultPlan.of(*events), integrity="recover",
                   quarantine_strikes=2, batch_limit=1)
    rng = np.random.default_rng(5)
    tickets = [svc.submit("t", "A", rng.standard_normal(64), kind="spmv")
               for _ in range(4)]
    svc.run()
    assert svc.stats["quarantines"] == 1, svc.stats
    assert "node1" not in svc.nodes and svc.topo.n_nodes == 1
    assert svc.stats["recoveries"] >= 1
    assert all(t.request.status == "done" for t in tickets)


# ------------------------- fault-plan determinism --------------------------

def test_faultplan_random_message_kinds_pure():
    nodes = ["node0", "node1"]
    p1 = FaultPlan.random(3, nodes, 10, n_events=8, ppn=2)
    p2 = FaultPlan.random(3, nodes, 10, n_events=8, ppn=2)
    assert p1.events == p2.events          # pure function of the seed
    kinds = {e.kind for s in range(16)
             for e in FaultPlan.random(s, nodes, 10, n_events=8,
                                       ppn=2).events}
    assert kinds & set(FaultEvent.MESSAGE_KINDS)
    # without the node width the draw stays on the legacy kinds
    legacy = {e.kind for s in range(16)
              for e in FaultPlan.random(s, nodes, 10, n_events=8).events}
    assert not (legacy & set(FaultEvent.MESSAGE_KINDS))
    ev = duplicate_message(4, ("full", (0, 1), 1))
    assert ev.kind == "duplicate_message" and ev.step == 4


def test_spgemm_integrity_argument_validation():
    rng = np.random.default_rng(0)
    a = CSR.from_dense(rng.standard_normal((8, 8)))
    topo = Topology(2, 2)
    part = contiguous_partition(8, topo.n_procs)
    f = MessageFault(phase="inter", node=0, proc=0, slot=1)
    with pytest.raises(ValueError):        # faults need integrity on
        distributed_spgemm(a, a, part, part, topo, faults=[f])
    with pytest.raises(ValueError):        # simulate has no spgemm wire
        distributed_spgemm(a, a, part, part, topo, backend="simulate",
                           integrity="detect")
    with pytest.raises(ValueError):
        distributed_spgemm(a, a, part, part, topo, integrity="sometimes")


# ------------------------- shardmap program (subprocess) -------------------

def _run_prog(args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)  # the program sets its own device count
    proc = subprocess.run(
        [sys.executable,
         str(ROOT / "tests" / "multidev" / "integrity_prog.py")] + args,
        capture_output=True, text=True, env=env, timeout=timeout)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL OK" in proc.stdout


def test_integrity_shardmap_quick():
    """Tier-1 shardmap integrity smoke (subprocess; 4-device subset):
    detect attribution, ABFT, recover bit-identity, zero retraces."""
    _run_prog(["--quick"])


@pytest.mark.multidev
def test_integrity_shardmap_8dev_full():
    """Full 8-device program: every fault kind x every phase x both
    directions detected with correct attribution, recover bit-identical,
    SpGEMM integrity on the (2, 4) mesh."""
    _run_prog([])
