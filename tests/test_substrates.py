"""data / optim / checkpoint / runtime unit tests."""
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.data import SyntheticLM, make_batch_iterator
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.optim.adamw import _q8_dequant, _q8_quant, global_norm
from repro.runtime import ElasticPolicy, HeartbeatMonitor, StragglerDetector


# --------------------------- data ------------------------------------------

def test_synthetic_data_deterministic_and_sharded():
    ds = SyntheticLM(vocab=512, seq_len=16, seed=7)
    b1 = ds.batch(step=3, batch_size=8, shard=0, n_shards=2)
    b2 = ds.batch(step=3, batch_size=8, shard=0, n_shards=2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch(step=3, batch_size=8, shard=1, n_shards=2)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].shape == (4, 16)
    # labels are next tokens
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    assert 0 < ds.bigram_entropy() < np.log(512)


def test_batch_iterator_prefetch():
    ds = SyntheticLM(vocab=128, seq_len=8, seed=1)
    it = make_batch_iterator(ds, batch_size=4, prefetch=2)
    first = next(it)
    want = ds.batch(0, 4)
    np.testing.assert_array_equal(first["tokens"], want["tokens"])
    second = next(it)
    np.testing.assert_array_equal(second["tokens"], ds.batch(1, 4)["tokens"])


# --------------------------- optim ------------------------------------------

def _quadratic_params():
    return {"w": jnp.asarray(np.random.default_rng(0).standard_normal(64),
                             jnp.float32),
            "b": jnp.zeros((8,), jnp.float32)}


@pytest.mark.parametrize("state_dtype", ["float32", "int8"])
def test_adamw_minimizes_quadratic(state_dtype):
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=5,
                      total_steps=300, state_dtype=state_dtype)
    params = _quadratic_params()
    target = jax.tree.map(lambda p: jnp.ones_like(p) * 0.5, params)
    state = adamw_init(params, cfg)

    def loss_fn(p):
        return sum(jnp.sum((a - t) ** 2)
                   for a, t in zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    @jax.jit
    def step(p, s):
        g = jax.grad(loss_fn)(p)
        return adamw_update(g, p, s, cfg)

    l0 = float(loss_fn(params))
    for _ in range(200):
        params, state = step(params, state)
    l1 = float(loss_fn(params))
    assert l1 < l0 * 1e-3, (l0, l1)


def test_q8_roundtrip_accuracy():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((513,)) * 0.01, jnp.float32)
    back = _q8_dequant(_q8_quant(x))
    err = float(jnp.abs(back - x).max() / jnp.abs(x).max())
    assert err < 0.02


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 55, 100, 200]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5, abs=0.02)
    assert lrs[2] == pytest.approx(1.0, abs=0.02)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(0.1, abs=0.02)
    assert lrs[5] == pytest.approx(0.1, abs=0.02)


# --------------------------- checkpoint ---------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "nested": {"b": jnp.ones((3, 4), jnp.bfloat16)},
            "step": jnp.asarray(7)}
    save_checkpoint(str(tmp_path), 7, tree, extra={"data_step": 123})
    out, extra = load_checkpoint(str(tmp_path), target=tree)
    assert extra["data_step"] == 123
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(10))
    assert np.asarray(out["nested"]["b"]).dtype == np.asarray(tree["nested"]["b"]).dtype


def test_checkpoint_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros((4,))}
    for s in (1, 2, 3):
        mgr.save(s, tree, block=True)
    import pathlib
    steps = sorted(p.name for p in pathlib.Path(tmp_path).glob("step_*"))
    assert steps == ["step_00000002", "step_00000003"]
    out, _ = mgr.restore(target=tree)
    assert out["w"].shape == (4,)


def test_checkpoint_uncommitted_is_ignored(tmp_path):
    tree = {"w": jnp.zeros((4,))}
    save_checkpoint(str(tmp_path), 1, tree)
    # fake a crashed save at step 2
    import pathlib
    p = pathlib.Path(tmp_path) / "step_00000002"
    p.mkdir()
    (p / "manifest.json").write_text("{}")
    out, _ = load_checkpoint(str(tmp_path), target=tree)  # falls back to 1
    assert out["w"].shape == (4,)


# --------------------------- runtime -------------------------------------------

def test_heartbeat_detects_dead_node():
    t = [0.0]
    mon = HeartbeatMonitor(["n0", "n1"], timeout=10.0, clock=lambda: t[0])
    t[0] = 5.0
    mon.beat("n0")
    t[0] = 12.0
    assert mon.dead_nodes() == ["n1"]
    mon.beat("n1")
    assert mon.healthy()


def test_straggler_zscore():
    det = StragglerDetector(window=8, z_thresh=2.0, rel_floor=1.3)
    for step in range(8):
        for n in range(6):
            det.record(f"n{n}", 1.0 + 0.01 * n)
        det.record("slow", 3.0)
    assert det.stragglers() == ["slow"]


def test_elastic_policy_shrinks_data_axis():
    pol = ElasticPolicy()
    out = pol.propose((16, 16), ("data", "model"), n_dead_nodes=2,
                      chips_per_node=4)
    assert out is not None
    (shape, names) = out
    assert names == ("data", "model")
    assert shape == (15, 16)  # 8 chips lost -> one data row dropped


def test_elastic_policy_drops_pod_when_needed():
    pol = ElasticPolicy(min_data=14)
    out = pol.propose((2, 16, 16), ("pod", "data", "model"), n_dead_nodes=16,
                      chips_per_node=4)
    assert out is not None
    shape, _ = out
    assert shape == (1, 16, 16)
