"""Multi-device verification program for hier_collectives (run via subprocess).

Asserts, on an 8-device host mesh (2 pods x 4 chips):
  * nap_psum / nap_psum_tree  ==  flat psum
  * nap_all_gather / nap_reduce_scatter  ==  flat equivalents
  * nap_all_to_all  ==  flat all_to_all (bitwise)
  * compressed psum: close to exact, error-feedback residual shrinks drift,
    result identical on every device (no replica divergence)
  * nap_moe_dispatch: every surviving (token, expert) pair is delivered to
    the owning chip, tokens bound for 2 experts on one remote pod cross once
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import hier_collectives as hc

PODS, INNER = 2, 4
from repro.compat import make_mesh, shard_map as compat_shard_map

mesh = make_mesh((PODS, INNER), ("pod", "inner"))
rng = np.random.default_rng(0)


def smap(fn, in_specs, out_specs):
    return jax.jit(compat_shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs))


def test_psum_family():
    x = rng.standard_normal((PODS * INNER, 6, 5)).astype(np.float32)
    spec = P(("pod", "inner"))

    got = smap(lambda v: hc.nap_psum(v[0], "inner", "pod")[None],
               (spec,), spec)(x)
    want = np.broadcast_to(x.sum(0, keepdims=True), x.shape)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)

    tree = {"a": x, "b": x[:, :2, :3] * 2.0}
    got_t = smap(lambda t: jax.tree.map(lambda l: l[None],
                                        hc.nap_psum_tree(jax.tree.map(lambda l: l[0], t),
                                                         "inner", "pod")),
                 ({"a": spec, "b": spec},), {"a": spec, "b": spec})(tree)
    np.testing.assert_allclose(np.asarray(got_t["a"]), want, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got_t["b"]),
                               np.broadcast_to(tree["b"].sum(0, keepdims=True),
                                               tree["b"].shape), rtol=1e-5)
    print("psum family ok")


def test_gather_scatter():
    x = rng.standard_normal((PODS * INNER, 4, 4)).astype(np.float32)
    spec = P(("pod", "inner"))
    # hierarchical all-gather reproduces the full array on every shard, but
    # gathered in (pod-major, inner) order == flat order for SMP layout.
    got = smap(lambda v: hc.nap_all_gather(v[0], "inner", "pod", axis=0)[None],
               (spec,), spec)(x)
    flat = x.reshape(-1, 4)
    # gather order: outer gather first -> [pods*4], then inner -> [inner, pods*4]
    # verify contents as a set of rows (order checked against flat gather below)
    got0 = np.asarray(got)[0]
    assert got0.shape == (PODS * INNER * 4, 4)
    # every original row must be present
    for r in flat:
        assert (np.abs(got0 - r).sum(1) < 1e-6).any()

    rs_nap = smap(lambda v: hc.nap_reduce_scatter(v[0].reshape(-1), "inner", "pod")[None],
                  (spec,), spec)(x)
    # flat reduce-scatter over ("inner","pod")? our nap RS scatters inner-major:
    # verify total content: concatenating all shards (in some order) == sum
    total = x.sum(0).reshape(-1)
    got_rs = np.asarray(rs_nap).reshape(-1)
    np.testing.assert_allclose(np.sort(got_rs), np.sort(total), rtol=1e-5)
    print("gather/scatter ok")


def test_all_to_all():
    n = PODS * INNER
    x = rng.standard_normal((n, n, 3)).astype(np.float32)  # [src, dst, D]
    spec = P(("pod", "inner"))
    nap = smap(lambda v: hc.nap_all_to_all(v[0], "inner", "pod")[None],
               (spec,), spec)(x)
    flat = smap(lambda v: hc.flat_all_to_all(v[0], "inner", "pod")[None],
                (spec,), spec)(x)
    np.testing.assert_array_equal(np.asarray(nap), np.asarray(flat))
    # semantic check: receiver d row s == x[s, d]
    out = np.asarray(nap)
    for d in range(n):
        for s in range(n):
            np.testing.assert_allclose(out[d, s], x[s, d], rtol=0)
    print("all_to_all ok")


def test_compressed_psum():
    x = rng.standard_normal((PODS * INNER, 4096)).astype(np.float32)
    spec = P(("pod", "inner"))

    def step(v):
        g = v[0]
        out, res = hc.nap_psum_compressed(g, "inner", "pod")
        return out[None], res[None]

    out, res = smap(step, (spec,), (spec, spec))(x)
    want = x.sum(0)
    got = np.asarray(out)
    # identical on every replica (no drift)
    for d in range(1, PODS * INNER):
        np.testing.assert_array_equal(got[d], got[0])
    err = np.abs(got[0] - want).max() / np.abs(want).max()
    assert err < 0.02, f"int8 psum too lossy: {err}"
    # residual carries the quantization error: second call with residual
    # compensates (mean error over 2 steps < single-step error)
    print(f"compressed psum ok (rel err {err:.2e})")


def test_moe_dispatch():
    T, D, K, CAP = 16, 8, 2, 64
    n_chips = PODS * INNER
    tokens = rng.standard_normal((n_chips, T, D)).astype(np.float32)
    dest = rng.integers(0, n_chips, size=(n_chips, T, K)).astype(np.int32)
    spec = P(("pod", "inner"))

    def run(tok, dst):
        r, s, v = hc.nap_moe_dispatch(tok[0], dst[0], "inner", "pod", CAP)
        return r[None], s[None], v[None]

    recv, src, valid = smap(run, (spec, spec), (spec, spec, spec))(tokens, dest)
    recv, src, valid = map(np.asarray, (recv, src, valid))
    # every (token, chip) pair that was routed must be present exactly once
    for chip in range(n_chips):
        got_ids = set(src[chip][valid[chip]].tolist())
        want_ids = set()
        for c in range(n_chips):
            for t in range(T):
                if chip in dest[c, t].tolist():
                    want_ids.add(c * T + t)
        assert want_ids == got_ids, (chip, want_ids - got_ids, got_ids - want_ids)
        # payload integrity
        for pos in np.nonzero(valid[chip])[0]:
            gid = src[chip, pos]
            np.testing.assert_allclose(recv[chip, pos], tokens[gid // T, gid % T],
                                       rtol=0)
    print("moe dispatch ok")


if __name__ == "__main__":
    assert jax.device_count() == 8, jax.devices()
    test_psum_family()
    test_gather_scatter()
    test_all_to_all()
    test_compressed_psum()
    test_moe_dispatch()
    print("ALL OK")
