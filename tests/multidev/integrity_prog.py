"""Multi-device integrity sweep of the shardmap backend (subprocess).

Exercises the ABFT-checksummed exchange end to end on real (forced-host)
devices: every scripted message-fault kind (bitflip / zero / stale /
drop / duplicate) on every exchange phase, forward AND transpose, must
be detected under ``integrity="detect"`` with correct phase + message
attribution; compute-phase bitflips must be caught by the ABFT column
check; ``integrity="recover"`` must reproduce the fault-free result
bit-for-bit; the instrumented programs must never retrace when faults
are armed (the fault spec is a per-call jit argument); and the
distributed SpGEMM surface must detect/recover the same way.

A dense operand matrix is used so every (sender, slot) edge of every
phase carries non-constant, nonzero payload in both directions — making
every fault kind deterministically detectable (zero/drop need a nonzero
payload, stale/duplicate a non-constant one).

``--quick`` runs a 4-device (2, 2) subset — the tier-1 subprocess smoke.
"""
import os
import sys

QUICK = "--quick" in sys.argv
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + \
    ("4" if QUICK else "8")

import numpy as np

import repro.api as nap
from repro.core.integrity import IntegrityError, MessageFault
from repro.core.partition import contiguous_partition
from repro.core.topology import Topology
from repro.sparse.csr import CSR
from repro.spgemm.shardmap import distributed_spgemm

NN, PPN = (2, 2) if QUICK else (2, 4)
TOPO = Topology(n_nodes=NN, ppn=PPN)
N = 16 * TOPO.n_procs
KINDS = ("bitflip", "zero", "stale", "drop", "duplicate")
NAP_PHASES = ("full", "init", "inter", "final")

rng = np.random.default_rng(0)
A = CSR.from_dense(rng.standard_normal((N, N)))
PART = contiguous_partition(N, TOPO.n_procs)
V = rng.standard_normal(N)


def build(integrity, method="nap"):
    return nap.operator(A, topo=TOPO, part=PART, method=method,
                        backend="shardmap", block_shape=(8, 16),
                        integrity=integrity)


def expect_detect(op, fault, direction):
    """Inject ``fault`` and assert the next apply raises with the right
    phase / receiver-device / slot / scope / direction attribution."""
    view = op.T if direction == "transpose" else op
    view.inject_fault(fault.phase, fault.kind, node=fault.node,
                      proc=fault.proc, slot=fault.slot,
                      element=fault.element, bit=fault.bit)
    try:
        view @ V
    except IntegrityError as e:
        ms = [m for m in e.mismatches if m.check == "wire"]
        assert ms, f"no wire mismatch for {fault}"
        m = ms[0]
        assert m.phase == fault.phase and m.direction == direction, m
        # the aux output is indexed by RECEIVER: an intra-node fault at
        # sender (n, p) slot s lands at device (n, s) slot p; an inter
        # fault at (n, p) slot d lands at (d, p) slot n; a pair fault
        # at (n, p) slot dst lands at the dst device, slot = sender rank
        if fault.phase == "inter":
            want = (fault.slot, fault.proc, fault.node, "off_node")
        elif fault.phase == "pair":
            src = fault.node * PPN + fault.proc
            want = (fault.slot // PPN, fault.slot % PPN, src,
                    "on_node" if fault.slot // PPN == fault.node
                    else "off_node")
        else:
            want = (fault.node, fault.slot, fault.proc,
                    {"full": "on_node", "init": "off_node",
                     "final": "off_node"}[fault.phase])
        got = (m.node, m.proc, m.slot, m.scope)
        assert got == want, (str(fault), got, want)
        return m
    raise AssertionError(f"{fault.kind} on {fault.phase} "
                         f"({direction}) NOT detected")


# --- clean parity: detect instrumentation adds no numerics --------------
op_off = build("off")
y0, z0 = op_off @ V, op_off.T @ V
assert np.allclose(y0, A.to_dense() @ V, atol=1e-3)
op_det = build("detect")
assert np.array_equal(op_det @ V, y0), "clean detect != off (forward)"
assert np.array_equal(op_det.T @ V, z0), "clean detect != off (transpose)"
rep = op_det.integrity_report()
assert rep["wire_mismatches"] == 0 and rep["abft_mismatches"] == 0, rep
assert rep["wire_checks"] > 0 and rep["abft_checks"] == 2, rep
print(f"clean detect bit-identical ({rep['wire_checks']} wire checks)")

# --- every kind x every phase x both directions -------------------------
for direction in ("forward", "transpose"):
    for i, phase in enumerate(NAP_PHASES):
        for j, kind in enumerate(KINDS):
            if kind == "duplicate" and phase != "inter":
                # the intra-node phases broadcast the same segment copy
                # to every local destination, so a duplicated slot can
                # be byte-identical to the real one — the documented
                # undetectable class; inter slots carry per-node
                # payloads that genuinely differ
                continue
            if phase == "init" and (
                    kind == "stale"
                    or (direction == "transpose" and kind != "bitflip")):
                # aligned-pairing init relays are single-element
                # (pad=1) messages — a stale (rolled) payload is
                # byte-identical — and the transpose-direction init
                # buffer is identically zero (its adjoint traffic rides
                # the other phases), leaving only bitflip byte-visible:
                # the documented undetectable classes (see the
                # serve/README.md threat model)
                continue
            # vary the sender/slot edge across the sweep; intra-node
            # slots are destination local ranks, inter slots are
            # destination nodes; under aligned pairing the init relay's
            # only real traffic is the SELF slot, so target that there
            node, proc = (i + j) % NN, (i * 2 + j) % PPN
            if phase == "inter":
                slot = (node + 1) % NN
            elif phase == "init":
                slot = proc
            else:
                slot = (proc + 1) % PPN
            f = MessageFault(phase=phase, kind=kind, node=node, proc=proc,
                             slot=slot, element=1, bit=20,
                             direction=direction)
            expect_detect(op_det, f, direction)
    print(f"{direction}: all kinds detected on all "
          f"{len(NAP_PHASES)} phases with correct attribution")

# --- compute-phase corruption is ABFT's to catch ------------------------
for direction in ("forward", "transpose"):
    view = op_det.T if direction == "transpose" else op_det
    view.inject_fault("compute", "bitflip", node=NN - 1, proc=PPN - 1,
                      element=2, bit=25)
    try:
        view @ V
        raise AssertionError(f"compute fault ({direction}) NOT detected")
    except IntegrityError as e:
        m = e.mismatches[0]
        assert m.check == "abft" and m.scope == "on_proc", m
        assert (m.node, m.proc) == (NN - 1, PPN - 1), m
print("compute faults caught by ABFT on both directions")

# --- standard method: the pair phase ------------------------------------
std_off = build("off", method="standard")
std_det = build("detect", method="standard")
ys = std_off @ V
assert np.array_equal(std_det @ V, ys)
src = 1 * PPN + 0
for j, kind in enumerate(KINDS):
    if kind == "duplicate":
        # the standard method broadcasts the sender's own x segment to
        # every destination, so every pair slot is byte-identical and a
        # duplicated slot is indistinguishable — documented
        # undetectable class (see the serve/README.md threat model)
        continue
    # destination slot sweeps every rank EXCEPT the sender itself (the
    # self slot is pad-filled constant data — stale-invisible)
    slot = (src + 1 + j % (TOPO.n_procs - 1)) % TOPO.n_procs
    f = MessageFault(phase="pair", kind=kind, node=1, proc=0,
                     slot=slot, element=1, bit=20)
    expect_detect(std_det, f, "forward")
print("standard/pair: all kinds detected")

# --- recover: bit-identical to the fault-free run -----------------------
op_rec = build("recover")
for direction, phase, kind in [("forward", "inter", "bitflip"),
                               ("forward", "full", "stale"),
                               ("transpose", "final", "zero"),
                               ("forward", "compute", "bitflip")]:
    view = op_rec.T if direction == "transpose" else op_rec
    bit = 25 if phase == "compute" else 20
    view.inject_fault(phase, kind, node=1, proc=PPN - 1,
                      slot=0, element=1, bit=bit)
    got = view @ V
    want = z0 if direction == "transpose" else y0
    assert np.array_equal(got, want), \
        f"recover {phase}/{kind} ({direction}) not bit-identical"
rep = op_rec.integrity_report()
assert rep["retries"] == 4 and rep["recovered"] == 4, rep
assert rep["faults_injected"] == 4, rep
print(f"recover bit-identical through 4 faults "
      f"(retries={rep['retries']}, strikes={rep['strikes']})")

# --- zero retraces: the fault spec is a per-call jit argument -----------
tc = op_det.trace_counts()
assert tc == {"forward": 1, "transpose": 1}, tc
assert op_rec.trace_counts() == {"forward": 1, "transpose": 1}
print("zero retraces across all armed/clean applies:", tc)

# --- distributed SpGEMM integrity ---------------------------------------
m, k, n = 6 * TOPO.n_procs, 5 * TOPO.n_procs, 36
am = rng.standard_normal((m, k)) * (rng.random((m, k)) < 0.6)
bm = rng.standard_normal((k, n)) * (rng.random((k, n)) < 0.6)
a, b = CSR.from_dense(am), CSR.from_dense(bm)
rp = contiguous_partition(m, TOPO.n_procs)
mp = contiguous_partition(k, TOPO.n_procs)

c0 = distributed_spgemm(a, b, rp, mp, TOPO)
srep = {}
c1 = distributed_spgemm(a, b, rp, mp, TOPO, integrity="detect", report=srep)
assert np.array_equal(c0.data, c1.data), "spgemm clean detect != off"
assert srep["wire_mismatches"] == 0, srep
for phase in NAP_PHASES:
    f = MessageFault(phase=phase, kind="bitflip", node=1, proc=PPN - 1,
                     slot=0, element=0, bit=20)
    try:
        distributed_spgemm(a, b, rp, mp, TOPO, integrity="detect",
                           faults=[f])
        raise AssertionError(f"spgemm {phase} fault NOT detected")
    except IntegrityError as e:
        assert any(mm.phase == phase for mm in e.mismatches), \
            (phase, [str(mm) for mm in e.mismatches])
srep = {}
c2 = distributed_spgemm(
    a, b, rp, mp, TOPO, integrity="recover",
    faults=[MessageFault(phase="inter", kind="bitflip", node=0, proc=1,
                         slot=1 % NN, element=2, bit=20)], report=srep)
assert np.array_equal(c0.data, c2.data), "spgemm recover not bit-identical"
assert srep["recovered"] == 1 and srep["retries"] == 1, srep
print("spgemm: bitflips detected on all phases, recover bit-identical")

print("ALL OK")
