"""Multi-device MoE dispatch verification (subprocess; 2 pods x 4 chips).

flat and nap sharded dispatch must match the dense-masked oracle, and the
nap mode must put FEWER bytes on the inter-pod all-to-all when top_k spreads
a token over several experts of one remote pod.  The quantized wire must
SHRINK the measured pod-crossing bytes while staying inside the modeled
error budget, and the registered executor path (``backend="moe"``) must
agree with the island and carry the integrity surface over QUANTIZED
messages.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core.hlo_analysis import analyze_hlo
from repro.models.moe import EPInfo, moe_apply_local, moe_apply_sharded, moe_init

cfg0 = get_reduced("qwen3-moe-235b-a22b").replace(
    n_experts=8, top_k=4, moe_dff=32, d_model=32, capacity_factor=8.0)
from repro.compat import make_mesh, set_mesh

mesh = make_mesh((2, 4), ("pod", "model"))
params = moe_init(jax.random.key(0), cfg0, jnp.float32)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((4, 16, cfg0.d_model)) * 0.3, jnp.float32)
want = np.asarray(moe_apply_local(params, cfg0, x))

def run_island(cfg):
    ep = EPInfo(inner_axis="model", pod_axis="pod")
    fn = jax.jit(lambda p, xx: moe_apply_sharded(p, cfg, xx, ep, mesh))
    with set_mesh(mesh):
        compiled = fn.lower(params, x).compile()
        got = np.asarray(fn(params, x))
    # pod_boundary=4: devices 0-3 are pod 0, 4-7 pod 1 on the (2, 4) mesh
    return got, analyze_hlo(compiled.as_text(), pod_boundary=4)


a2a_bytes, dci_bytes, outs = {}, {}, {}
for mode in ("flat", "nap"):
    got, cost = run_island(cfg0.replace(moe_dispatch=mode))
    err = np.abs(got - want).max() / np.abs(want).max()
    assert err < 1e-4, (mode, err)
    a2a_bytes[mode] = cost.total_collective_bytes
    dci_bytes[mode] = cost.dci_bytes
    outs[mode] = got
    print(mode, "err", err, "coll bytes", a2a_bytes[mode],
          "dci bytes", dci_bytes[mode])
assert dci_bytes["nap"] < dci_bytes["flat"], \
    "nap must put fewer bytes on the inter-pod boundary"

# quantized wire: measured DCI bytes SHRINK, error stays inside the budget
from repro.moe import wire_error_bound

scale = np.abs(want).max()
for wd in ("bf16", "fp8_e4m3"):
    wcfg = cfg0.replace(moe_dispatch="nap", wire_dtype=wd)
    got, cost = run_island(wcfg)
    err = np.abs(got - outs["nap"]).max() / scale
    bound = wire_error_bound(wcfg)
    assert cost.dci_bytes < dci_bytes["nap"], (wd, cost.dci_bytes)
    assert err <= bound, (wd, err, bound)
    print(wd, "dci bytes", cost.dci_bytes, "err", err, "budget", bound)


# gradient path agrees with the oracle too
def loss(p, xx, m):
    c = cfg0.replace(moe_dispatch=m)
    ep = EPInfo(inner_axis="model", pod_axis="pod")
    return (moe_apply_sharded(p, c, xx, ep, mesh) ** 2).sum()

def loss_ref(p, xx):
    return (moe_apply_local(p, cfg0, xx) ** 2).sum()

g_ref = jax.grad(loss_ref)(params, x)
with set_mesh(mesh):
    g_nap = jax.jit(jax.grad(lambda p, xx: loss(p, xx, "nap")))(params, x)
for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_nap)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-3, atol=5e-4)
print("grads ok")

# ---------------------------------------------------------------------------
# registered executor path: dispatch_operator on this mesh + integrity
# over a corrupted QUANTIZED message
# ---------------------------------------------------------------------------
import repro.api as nap_api
from repro.moe.dispatch import dispatch_operator, topology_of_mesh
from repro.moe.plan import (dispatch_partitions, representative_routing,
                            routing_matrix)

topo = topology_of_mesh(mesh)
assert (topo.n_nodes, topo.ppn) == (2, 4), topo
acfg = cfg0.replace(moe_dispatch="auto", wire_dtype="fp8_e4m3")
op = dispatch_operator(acfg, mesh, n_tokens=128, integrity="detect")
ids, w = representative_routing(128, cfg0.n_experts, cfg0.top_k)
r = routing_matrix(ids, w, cfg0.n_experts)
ep_, tp_ = dispatch_partitions(cfg0.n_experts, 128, topo)
xv = np.random.default_rng(2).standard_normal((128, cfg0.d_model))
ref = nap_api.operator(r, topo=topo, row_part=ep_, col_part=tp_,
                       backend="simulate", method="nap") @ xv
out = op @ xv                                   # clean quantized apply
assert np.all(np.isfinite(out)) and not np.array_equal(out, ref)
rel = np.abs(out - ref).max() / np.abs(ref).max()
assert rel < 0.2, rel                           # fp8 ballpark, budget in tier-1
op.inject_fault("inter", kind="bitflip", node=1, proc=0, slot=0,
                element=2, bit=6)
try:
    op @ xv
    raise AssertionError("corrupted quantized message must raise")
except nap_api.IntegrityError as e:
    assert e.mismatches and e.mismatches[0].phase == "inter"
rep = op.integrity_report()
assert rep["faults_injected"] == 1 and rep["wire_mismatches"] == 1, rep
print("executor path ok (auto+fp8 on the mesh topology; quantized "
      "fault detected and attributed)")
print("ALL OK")
