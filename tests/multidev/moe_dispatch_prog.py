"""Multi-device MoE dispatch verification (subprocess; 2 pods x 4 chips).

flat and nap sharded dispatch must match the dense-masked oracle, and the
nap mode must put FEWER bytes on the inter-pod all-to-all when top_k spreads
a token over several experts of one remote pod.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core.hlo_analysis import analyze_hlo
from repro.models.moe import EPInfo, moe_apply_local, moe_apply_sharded, moe_init

cfg0 = get_reduced("qwen3-moe-235b-a22b").replace(
    n_experts=8, top_k=4, moe_dff=32, d_model=32, capacity_factor=8.0)
from repro.compat import make_mesh, set_mesh

mesh = make_mesh((2, 4), ("pod", "model"))
params = moe_init(jax.random.key(0), cfg0, jnp.float32)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((4, 16, cfg0.d_model)) * 0.3, jnp.float32)
want = np.asarray(moe_apply_local(params, cfg0, x))

a2a_bytes = {}
for mode in ("flat", "nap"):
    cfg = cfg0.replace(moe_dispatch=mode)
    ep = EPInfo(inner_axis="model", pod_axis="pod")
    fn = jax.jit(lambda p, xx: moe_apply_sharded(p, cfg, xx, ep, mesh))
    with set_mesh(mesh):
        compiled = fn.lower(params, x).compile()
        got = np.asarray(fn(params, x))
    err = np.abs(got - want).max() / np.abs(want).max()
    assert err < 1e-4, (mode, err)
    cost = analyze_hlo(compiled.as_text())
    a2a_bytes[mode] = cost.total_collective_bytes
    print(mode, "err", err, "coll bytes", a2a_bytes[mode])

# gradient path agrees with the oracle too
def loss(p, xx, m):
    c = cfg0.replace(moe_dispatch=m)
    ep = EPInfo(inner_axis="model", pod_axis="pod")
    return (moe_apply_sharded(p, c, xx, ep, mesh) ** 2).sum()

def loss_ref(p, xx):
    return (moe_apply_local(p, cfg0, xx) ** 2).sum()

g_ref = jax.grad(loss_ref)(params, x)
with set_mesh(mesh):
    g_nap = jax.jit(jax.grad(lambda p, xx: loss(p, xx, "nap")))(params, x)
for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_nap)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-3, atol=5e-4)
print("grads ok")
print("ALL OK")
