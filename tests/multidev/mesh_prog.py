"""Multi-process mesh runtime end to end (run as its own process).

Parent (no argv): forces 4 host devices, computes the single-process
declared-topo ``Topology(2, 2)`` SpMV oracle, then uses
``repro.mesh.launcher.launch`` to spawn TWO coordinator-connected
processes with 2 devices each running this same file in child mode.

Child (``child <out.json>``): attaches via the ``REPRO_MESH_*`` env,
asserts ``discover_topology()`` sees ``(n_nodes=2, ppn=2)``, builds the
operator with ``topo=None`` (autodiscovery) and runs a cross-process
``op @ x`` on the jitted shardmap stack; process 0 writes the result.

The parent asserts the 2-process result is BIT-IDENTICAL to its
single-process declared-topo oracle and within f32 tolerance of the
float64 message-passing simulator.  Prints "ALL OK" at the end —
tests/test_mesh.py greps for it.
"""
import json
import os
import sys
import tempfile

import numpy as np

N = 64
SEED = 0


def problem():
    from repro.sparse import random_fixed_nnz
    a = random_fixed_nnz(N, 6, seed=SEED)
    v = np.random.default_rng(SEED).standard_normal(N)
    return a, v


def child(out_path: str) -> None:
    from repro.mesh.launcher import attach
    info = attach(verbose=True)
    assert info["attached"], "child must find the REPRO_MESH_* env"
    from repro.mesh.discover import discover_topology
    topo = discover_topology()
    assert (topo.n_nodes, topo.ppn) == (2, 2), \
        f"discovered {topo}, wanted (2, 2)"

    import repro.api as nap
    a, v = problem()
    op = nap.operator(a)          # topo autodiscovered from the live mesh
    assert op.topo is not None and (op.topo.n_nodes, op.topo.ppn) == (2, 2)
    w = np.asarray(op @ v, np.float64)
    if info["process_id"] == 0:
        with open(out_path, "w") as f:
            json.dump({"topo": [topo.n_nodes, topo.ppn],
                       "w": w.tolist()}, f)
    print(f"CHILD {info['process_id']} OK", flush=True)


def parent() -> None:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import repro.api as nap
    from repro.core.comm_graph import build_nap_plan
    from repro.core.partition import contiguous_partition
    from repro.core.spmv import simulate_nap_spmv
    from repro.core.topology import Topology
    from repro.mesh.launcher import launch

    a, v = problem()
    topo = Topology(n_nodes=2, ppn=2)
    op = nap.operator(a, topo=topo, backend="shardmap")
    w_oracle = np.asarray(op @ v, np.float64)

    out_path = os.path.join(tempfile.mkdtemp(prefix="mesh_prog_"), "w.json")
    res = launch(os.path.abspath(__file__), 2, args=["child", out_path],
                 local_devices=2, timeout_s=560)
    for pid in range(2):
        assert f"CHILD {pid} OK" in "".join(res.outputs), res.outputs[pid]
    with open(out_path) as f:
        payload = json.load(f)
    assert payload["topo"] == [2, 2], payload["topo"]
    w_mesh = np.asarray(payload["w"], np.float64)

    assert np.array_equal(w_mesh, w_oracle), \
        "2-process launcher result must be BIT-IDENTICAL to the " \
        f"single-process declared-topo oracle (max delta " \
        f"{np.abs(w_mesh - w_oracle).max():.3e})"
    part = contiguous_partition(N, topo.n_procs)
    plan = build_nap_plan(a.indptr, a.indices, part, topo)
    want = simulate_nap_spmv(a, v, plan)
    err = np.abs(w_mesh - want).max()
    assert err < 1e-4, f"vs float64 simulator: {err:.3e}"
    print(f"2-process op @ x bit-identical to the declared-topo oracle; "
          f"max err vs float64 simulator = {err:.3e}", flush=True)
    print("ALL OK", flush=True)


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "child":
        child(sys.argv[2])
    else:
        parent()
