"""Multi-device sweep of the NapOperator shardmap backend (subprocess).

For topologies (1,4), (2,2), (4,2), both methods (nap / standard), and
nv in {1, 8}: the operator's forward must match the dense ``A @ x`` and
its ``.T`` the dense ``A.T @ x`` — the transpose compiled from the SAME
plan with reversed send/recv roles — plus the simulate backend as the
float64 cross-oracle.  Also checks: multi-RHS column consistency, the
``donate=True`` entry, per-format local_compute overrides, and that
operator results agree with the raw builder + pack/unpack path
bit-for-bit (the operator adds no numerics of its own).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np

import repro.api as nap
from repro.compat import make_mesh
from repro.core.partition import make_partition
from repro.core.spmv_jax import (compile_nap, nap_forward_shardmap,
                                 pack_vector, unpack_vector)
from repro.core.topology import Topology
from repro.sparse import random_fixed_nnz

TOPOS = [(1, 4), (2, 2), (4, 2)]


def dense_oracle(a, v):
    if v.ndim == 1:
        return a.matvec(v)
    return np.stack([a.matvec(v[:, i]) for i in range(v.shape[1])], axis=1)


def check(topo_shape, kind, nv, seed):
    nn, ppn = topo_shape
    topo = Topology(n_nodes=nn, ppn=ppn)
    rng = np.random.default_rng(seed)
    n = int(rng.integers(topo.n_procs * 3, 72))
    a = random_fixed_nnz(n, int(rng.integers(3, 9)), seed=seed)
    part = make_partition(kind, n, topo.n_procs,
                          indptr=a.indptr, indices=a.indices, seed=seed)
    at = a.transpose()
    v = rng.standard_normal(n) if nv == 1 else rng.standard_normal((n, nv))
    want_f, want_t = dense_oracle(a, v), dense_oracle(at, v)

    sim = nap.operator(a, topo=topo, part=part, method="nap",
                       backend="simulate")
    np.testing.assert_allclose(sim @ v, want_f, rtol=1e-9, atol=1e-11)
    np.testing.assert_allclose(sim.T @ v, want_t, rtol=1e-9, atol=1e-11)

    for method in ("nap", "standard"):
        op = nap.operator(a, topo=topo, part=part, method=method,
                          backend="shardmap", block_shape=(8, 16))
        got_f, got_t = op @ v, op.T @ v
        np.testing.assert_allclose(got_f, want_f, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(got_t, want_t, rtol=1e-4, atol=1e-5)
        # the transpose direction reports the format it actually runs —
        # now the transpose autotuner's ell/coo verdict, not a default
        rep = op.autotune_report()
        assert op.T.local_compute in ("ell", "coo")
        assert op.T.local_compute == rep["transpose_resolved"]
        # donate entry returns the same numbers
        np.testing.assert_allclose(op(v, donate=True), got_f,
                                   rtol=1e-6, atol=1e-7)

    # explicit local_compute overrides all agree (nv=8 only, cost)
    if nv == 8:
        for fmt in ("coo", "ell", "bsr"):
            op_f = nap.operator(a, topo=topo, part=part, method="nap",
                                backend="shardmap", block_shape=(8, 16),
                                local_compute=fmt)
            np.testing.assert_allclose(op_f @ v, want_f, rtol=1e-4, atol=1e-5)
            assert op_f.local_compute == fmt


def check_operator_equals_builder_path():
    """The operator is plumbing, not math: its forward must equal the raw
    compile_nap + nap_forward_shardmap + pack/unpack path bit-for-bit."""
    topo = Topology(n_nodes=2, ppn=4)
    mesh = make_mesh((2, 4), ("node", "proc"))
    n, nv = 256, 8
    a = random_fixed_nnz(n, 6, seed=11)
    part = make_partition("contiguous", n, topo.n_procs)
    v = np.random.default_rng(11).standard_normal((n, nv))

    compiled = compile_nap(a, part, topo)
    run = nap_forward_shardmap(compiled, mesh)
    raw = unpack_vector(
        np.asarray(run(pack_vector(v, part, topo, compiled.rows_pad))),
        part, topo)
    op = nap.operator(a, topo=topo, part=part, backend="shardmap", mesh=mesh)
    assert np.array_equal(np.asarray(op @ v), raw)
    print("operator == builder+pack/unpack path, bit-for-bit", flush=True)


def main():
    seed = 300
    for topo_shape in TOPOS:
        for nv in (1, 8):
            kind = ["contiguous", "strided", "balanced"][seed % 3]
            check(topo_shape, kind, nv, seed)
            print(f"topo={topo_shape} kind={kind} nv={nv} ok", flush=True)
            seed += 1
    check_operator_equals_builder_path()
    print("ALL OK")


if __name__ == "__main__":
    main()
