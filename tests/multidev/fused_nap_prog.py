"""Multi-device property check of the fused Pallas BSR NAPSpMV (subprocess).

Seeded-random sweep on an 8-device host platform: for every topology
``(n_nodes, ppn) ∈ {(1,4), (2,2), (4,2)}``, block sizes, partition kinds
and ``nv ∈ {1, 8, 128}``, the fused-BSR shard_map executor must agree with

  * the numpy message-passing simulator (exact MPI semantics oracle), and
  * the dense ``A @ x`` ground truth,

to 1e-5, in Pallas interpret mode.  The COO (segment_sum) executor and the
standard-algorithm executor are swept at nv=8 as cross-checks.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np

from repro.compat import make_mesh
from repro.core.partition import make_partition
from repro.core.spmv import DistSpMV
from repro.core.spmv_jax import (compile_nap, nap_spmv_shardmap, pack_vector,
                                 standard_spmv_shardmap, unpack_vector)
from repro.core.topology import Topology
from repro.sparse import random_fixed_nnz

TOPOS = [(1, 4), (2, 2), (4, 2)]
NVS = [1, 8, 128]


def dense_oracle(a, v):
    return np.stack([a.matvec(v[:, i]) for i in range(v.shape[1])], axis=1)


def check(topo_shape, kind, block_shape, nv, seed):
    nn, ppn = topo_shape
    topo = Topology(n_nodes=nn, ppn=ppn)
    rng = np.random.default_rng(seed)
    n = int(rng.integers(topo.n_procs * 3, 64))
    a = random_fixed_nnz(n, int(rng.integers(3, 9)), seed=seed)
    part = make_partition(kind, n, topo.n_procs,
                          indptr=a.indptr, indices=a.indices, seed=seed)
    mesh = make_mesh((nn, ppn), ("node", "proc"))
    compiled = compile_nap(a, part, topo, block_shape=block_shape, cache=False)
    v = rng.standard_normal((n, nv))
    want = dense_oracle(a, v)

    # oracle 1: the numpy message-passing simulator (column-wise)
    dist = DistSpMV.build(a, part, topo, pairing="aligned")
    sim = np.stack([dist.run(v[:, i], "nap") for i in range(nv)], axis=1)
    np.testing.assert_allclose(sim, want, rtol=1e-9, atol=1e-11)

    # fused Pallas BSR shard_map executor vs both oracles
    run = nap_spmv_shardmap(compiled, mesh, local_compute="bsr")
    shards = pack_vector(v, part, topo, compiled.rows_pad)
    got = unpack_vector(np.asarray(run(shards)), part, topo)
    np.testing.assert_allclose(got, sim, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    if nv == 8:
        run_coo = nap_spmv_shardmap(compiled, mesh, local_compute="coo")
        got_coo = unpack_vector(np.asarray(run_coo(shards)), part, topo)
        np.testing.assert_allclose(got_coo, want, rtol=1e-4, atol=1e-5)
        run_std, _ = standard_spmv_shardmap(a, part, topo, mesh,
                                            local_compute="bsr",
                                            block_shape=block_shape)
        got_std = unpack_vector(np.asarray(run_std(shards)), part, topo)
        np.testing.assert_allclose(got_std, want, rtol=1e-4, atol=1e-5)


def main():
    seed = 100
    for topo_shape in TOPOS:
        for nv in NVS:
            kind = ["contiguous", "strided", "balanced"][seed % 3]
            check(topo_shape, kind, (8, 16), nv, seed)
            print(f"topo={topo_shape} kind={kind} bs=(8,16) nv={nv} ok", flush=True)
            seed += 1
    # block-size sweep on one topology (incl. the MXU-native 128-lane tile)
    for block_shape in [(8, 8), (16, 16), (8, 128)]:
        check((2, 2), "contiguous", block_shape, 8, seed)
        print(f"topo=(2,2) bs={block_shape} nv=8 ok", flush=True)
        seed += 1
    print("ALL OK")


if __name__ == "__main__":
    main()
