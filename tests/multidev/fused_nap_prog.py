"""Multi-device property check of the adaptive NAPSpMV engine (subprocess).

Seeded-random sweep on an 8-device host platform: for every topology
``(n_nodes, ppn) ∈ {(1,4), (2,2), (4,2)}``, block sizes, partition kinds
and ``nv ∈ {1, 8, 128}``, the fused-BSR shard_map executor must agree with

  * the numpy message-passing simulator (exact MPI semantics oracle), and
  * the dense ``A @ x`` ground truth,

to 1e-5, in Pallas interpret mode.  The ELL, COO and autotuned executors
and the standard-algorithm executor are swept at nv=8 as cross-checks,
and the zero-copy packed-x path is checked bit-for-bit against the
materialised-concat path (``materialize_x=True``).  The TRANSPOSE
executors (reversed send/recv roles, same compiled plans) are checked at
nv=8 against both the reversed-flow simulator and dense ``A.T @ x``.

A block-hostile low-density problem additionally asserts the format
autotuner rejects BSR, and a jaxpr scan asserts the packed x operand is
NOT materialised as an HBM concat by the zero-copy executors (while the
materialize_x oracle path IS — a differential check, immune to shape
coincidences).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np

import jax

from repro.compat import make_mesh
from repro.core.comm_graph import build_nap_plan
from repro.core.partition import contiguous_partition, make_partition
from repro.core.spmv import simulate_nap_spmv, simulate_nap_spmv_transpose
from repro.core.spmv_jax import (compile_nap, compile_standard,
                                 nap_forward_shardmap, nap_transpose_shardmap,
                                 pack_vector, standard_forward_shardmap,
                                 standard_transpose_shardmap, unpack_vector)
from repro.core.topology import Topology
from repro.sparse import random_fixed_nnz

TOPOS = [(1, 4), (2, 2), (4, 2)]
NVS = [1, 8, 128]


def dense_oracle(a, v):
    return np.stack([a.matvec(v[:, i]) for i in range(v.shape[1])], axis=1)


def check(topo_shape, kind, block_shape, nv, seed):
    nn, ppn = topo_shape
    topo = Topology(n_nodes=nn, ppn=ppn)
    rng = np.random.default_rng(seed)
    n = int(rng.integers(topo.n_procs * 3, 64))
    a = random_fixed_nnz(n, int(rng.integers(3, 9)), seed=seed)
    part = make_partition(kind, n, topo.n_procs,
                          indptr=a.indptr, indices=a.indices, seed=seed)
    mesh = make_mesh((nn, ppn), ("node", "proc"))
    compiled = compile_nap(a, part, topo, block_shape=block_shape, cache=False)
    v = rng.standard_normal((n, nv))
    want = dense_oracle(a, v)

    # oracle 1: the numpy message-passing simulator (column-wise)
    nap_plan = build_nap_plan(a.indptr, a.indices, part, topo,
                              pairing="aligned")
    sim = np.stack([simulate_nap_spmv(a, v[:, i], nap_plan)
                    for i in range(nv)], axis=1)
    np.testing.assert_allclose(sim, want, rtol=1e-9, atol=1e-11)

    # fused Pallas BSR shard_map executor (zero-copy) vs both oracles
    run = nap_forward_shardmap(compiled, mesh, local_compute="bsr")
    shards = pack_vector(v, part, topo, compiled.rows_pad)
    got_raw = np.asarray(run(shards))
    got = unpack_vector(got_raw, part, topo)
    np.testing.assert_allclose(got, sim, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    # zero-copy in-kernel gather == materialised HBM concat, bit-for-bit
    run_mat = nap_forward_shardmap(compiled, mesh, local_compute="bsr",
                                   materialize_x=True)
    assert np.array_equal(np.asarray(run_mat(shards)), got_raw)

    if nv == 8:
        for fmt in ("coo", "ell", "auto"):
            run_f = nap_forward_shardmap(compiled, mesh, local_compute=fmt)
            got_f = unpack_vector(np.asarray(run_f(shards)), part, topo)
            np.testing.assert_allclose(got_f, want, rtol=1e-4, atol=1e-5)
        assert run_f.local_compute == compiled.chosen_local_compute
        run_ell_mat = nap_forward_shardmap(compiled, mesh, local_compute="ell",
                                           materialize_x=True)
        run_ell = nap_forward_shardmap(compiled, mesh, local_compute="ell")
        assert np.array_equal(np.asarray(run_ell(shards)),
                              np.asarray(run_ell_mat(shards)))
        cstd = compile_standard(a, part, topo, block_shape=block_shape,
                                cache=False)
        for fmt in ("bsr", "auto"):
            run_std = standard_forward_shardmap(cstd, mesh, local_compute=fmt)
            got_std = unpack_vector(np.asarray(run_std(shards)), part, topo)
            np.testing.assert_allclose(got_std, want, rtol=1e-4, atol=1e-5)

        # transpose executors vs the reversed-flow simulator AND dense A.T
        at = a.transpose()
        want_t = dense_oracle(at, v)
        sim_t = np.stack([simulate_nap_spmv_transpose(a, v[:, i], nap_plan)
                          for i in range(nv)], axis=1)
        np.testing.assert_allclose(sim_t, want_t, rtol=1e-9, atol=1e-11)
        run_t = nap_transpose_shardmap(compiled, mesh)
        got_t = unpack_vector(np.asarray(run_t(shards)), part, topo)
        np.testing.assert_allclose(got_t, sim_t, rtol=1e-4, atol=1e-5)
        run_ts = standard_transpose_shardmap(cstd, mesh)
        got_ts = unpack_vector(np.asarray(run_ts(shards)), part, topo)
        np.testing.assert_allclose(got_ts, want_t, rtol=1e-4, atol=1e-5)


def _count_packed_x_concats(fn, shards, n_x, nv) -> int:
    """Occurrences of a concatenate producing the packed x operand
    ([n_x, nv] elementwise or [n_x/bn, bn, nv] block form) in the
    executor's jaxpr.  The walk does NOT descend into pallas_call bodies:
    interpret mode traces kernel internals as jax eqns, and a concat of
    VMEM refs inside the kernel is not an HBM materialisation — the
    assertion targets the per-call executor graph."""
    jaxpr = jax.make_jaxpr(fn)(shards)

    def walk(jx):
        hits = 0
        for eqn in jx.eqns:
            if eqn.primitive.name == "concatenate":
                shape = eqn.outvars[0].aval.shape
                if (len(shape) >= 2 and shape[0] == n_x
                        and shape[-1] == nv):
                    hits += 1
            if "pallas" in eqn.primitive.name:
                continue
            for val in eqn.params.values():
                leaves = val if isinstance(val, (list, tuple)) else [val]
                for leaf in leaves:
                    if isinstance(leaf, jax.core.ClosedJaxpr):
                        hits += walk(leaf.jaxpr)
                    elif isinstance(leaf, jax.core.Jaxpr):
                        hits += walk(leaf)
        return hits

    return walk(jaxpr.jaxpr)


def check_block_hostile_autotune():
    """Low-density (<= 12 nnz/row) matrix: auto must reject BSR, match the
    dense oracle, and never materialise the packed x concat."""
    topo = Topology(n_nodes=2, ppn=4)
    mesh = make_mesh((2, 4), ("node", "proc"))
    n, nv = 1024, 8
    a = random_fixed_nnz(n, 8, seed=7)
    part = contiguous_partition(n, topo.n_procs)
    compiled = compile_nap(a, part, topo, cache=False)
    assert compiled.chosen_local_compute in ("ell", "coo"), compiled.autotune
    assert all(e["choice"] != "bsr" for e in compiled.autotune["per_rank"])

    rng = np.random.default_rng(1)
    v = rng.standard_normal((n, nv))
    shards = pack_vector(v, part, topo, compiled.rows_pad)
    want = dense_oracle(a, v)
    n_x = compiled.packed_x_len

    for fmt in ("auto", "ell", "bsr"):
        run = nap_forward_shardmap(compiled, mesh, local_compute=fmt)
        got = unpack_vector(np.asarray(run(shards)), part, topo)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
        # the zero-copy executor must NOT materialise the packed x concat...
        assert _count_packed_x_concats(run.run4, shards, n_x, nv) == 0, fmt
    # ...while the materialize_x oracle path DOES (differential: proves the
    # scan actually sees the concat when it exists)
    run_mat = nap_forward_shardmap(compiled, mesh, local_compute="ell",
                                materialize_x=True)
    assert _count_packed_x_concats(run_mat.run4, shards, n_x, nv) >= 1
    print(f"block-hostile autotune ok: chose {compiled.chosen_local_compute}, "
          f"no packed-x concat in zero-copy jaxpr", flush=True)


def main():
    seed = 100
    for topo_shape in TOPOS:
        for nv in NVS:
            kind = ["contiguous", "strided", "balanced"][seed % 3]
            check(topo_shape, kind, (8, 16), nv, seed)
            print(f"topo={topo_shape} kind={kind} bs=(8,16) nv={nv} ok", flush=True)
            seed += 1
    # block-size sweep on one topology (incl. the MXU-native 128-lane tile)
    for block_shape in [(8, 8), (16, 16), (8, 128)]:
        check((2, 2), "contiguous", block_shape, 8, seed)
        print(f"topo=(2,2) bs={block_shape} nv=8 ok", flush=True)
        seed += 1
    check_block_hostile_autotune()
    print("ALL OK")


if __name__ == "__main__":
    main()
