"""Multi-device sweep of the comm-strategy subsystem (subprocess).

On a forced 8-device host platform: every strategy's shard_map program
(standard / nap / multistep) must match its own float64 message-passing
simulator BIT-FOR-BIT on integer-valued data, forward and transpose;
``comm="nap"`` must be bit-identical to the pre-existing nap operator
(same compiled plan family, no direct phase); ``comm="auto"`` resolves
to multistep on the skewed near-dense structure and still matches the
oracle; rectangular operators with empty ranks ride the multistep
program end-to-end.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np

import repro.api as nap
from repro.comm import (build_multistep_plan, simulate_multistep_spmv,
                        simulate_multistep_spmv_transpose)
from repro.core.comm_graph import build_nap_plan, build_standard_plan
from repro.core.partition import contiguous_partition
from repro.core.spmv import (simulate_nap_spmv, simulate_nap_spmv_transpose,
                             simulate_standard_spmv,
                             simulate_standard_spmv_transpose)
from repro.core.topology import Topology
from repro.sparse import random_fixed_nnz
from repro.sparse.csr import CSR

TOPO = Topology(2, 4)


def intify(a: CSR, scale: int = 8) -> CSR:
    a.data[:] = np.round(a.data * scale)
    return a


def skewed_matrix(topo, rows_per_rank=32, bulk=24, seed=0):
    """Same shape as tests/test_comm.py: shared d=ppn background plus a
    d=1 bulk in one node-pair direction only."""
    n = rows_per_rank * topo.n_procs
    part = contiguous_partition(n, topo.n_procs)
    rng = np.random.default_rng(seed)
    rows = [[] for _ in range(n)]
    lo = lambda r: r * rows_per_rank
    for r in range(topo.n_procs):
        node, lr = topo.node_of(r), topo.local_of(r)
        remote = [q for q in range(topo.n_procs) if topo.node_of(q) != node]
        base = lo(r)
        for i in range(rows_per_rank):
            rows[base + i].append(base + i)
        for src in remote:
            for i in range(rows_per_rank):
                rows[base + i].append(lo(src))
        if node == 0:
            src = remote[lr]
            for k in range(bulk):
                gi = base + int(rng.integers(rows_per_rank))
                rows[gi].append(lo(src) + 1 + k)
    indptr = [0]
    indices = []
    for rr in rows:
        cols = sorted(set(rr))
        indices.extend(cols)
        indptr.append(len(indices))
    data = rng.standard_normal(len(indices))
    return intify(CSR(np.array(indptr, np.int64),
                      np.array(indices, np.int64), data, (n, n))), part


SIMULATORS = {
    "standard": (build_standard_plan, simulate_standard_spmv,
                 simulate_standard_spmv_transpose),
    "nap": (build_nap_plan, simulate_nap_spmv, simulate_nap_spmv_transpose),
    "multistep": (build_multistep_plan, simulate_multistep_spmv,
                  simulate_multistep_spmv_transpose),
}


def check_strategies_bitwise(a: CSR, part, label: str) -> None:
    """Each strategy's shardmap program == its float64 simulator, bitwise."""
    rng = np.random.default_rng(42)
    n, m = a.shape[1], a.shape[0]
    v = np.round(rng.standard_normal(n) * 4)
    u = np.round(rng.standard_normal(m) * 4)
    for comm, (builder, sim_f, sim_t) in SIMULATORS.items():
        kw = {"pairing": "aligned"} if comm == "nap" else {}
        plan = builder(a.indptr, a.indices, part, TOPO, **kw)
        want_f, want_t = sim_f(a, v, plan), sim_t(a, u, plan)
        op = nap.operator(a, topo=TOPO, part=part, backend="shardmap",
                          comm=comm)
        got_f = np.asarray(op @ v, dtype=np.float64)
        got_t = np.asarray(op.T @ u, dtype=np.float64)
        np.testing.assert_array_equal(got_f, want_f,
                                      err_msg=f"{label}:{comm}:forward")
        np.testing.assert_array_equal(got_t, want_t,
                                      err_msg=f"{label}:{comm}:transpose")
    print(f"  {label}: all strategies bitwise vs simulators")


def check_nap_bit_identical() -> None:
    """comm="nap" runs the exact pre-existing program: same executor
    class, same compiled-plan family (no direct phase), bitwise outputs."""
    a, part = skewed_matrix(TOPO, seed=1)
    rng = np.random.default_rng(7)
    v = np.round(rng.standard_normal(a.shape[1]) * 4)
    base = nap.operator(a, topo=TOPO, part=part, backend="shardmap")
    pinned = nap.operator(a, topo=TOPO, part=part, backend="shardmap",
                          comm="nap")
    assert type(pinned.executor) is type(base.executor)
    np.testing.assert_array_equal(np.asarray(base @ v),
                                  np.asarray(pinned @ v))
    np.testing.assert_array_equal(np.asarray(base.T @ v),
                                  np.asarray(pinned.T @ v))
    cb, cp = base.executor.compiled, pinned.executor.compiled
    assert cb.comm == cp.comm == "nap"
    assert "direct" not in cb.pads and "direct" not in cp.pads
    assert cb.pads == cp.pads
    print("  comm='nap' bit-identical to the pre-existing program")


def check_auto_end_to_end() -> None:
    a, part = skewed_matrix(TOPO, seed=2)
    rng = np.random.default_rng(8)
    v = np.round(rng.standard_normal(a.shape[1]) * 4)
    op = nap.operator(a, topo=TOPO, part=part, backend="shardmap",
                      comm="auto")
    rep = op.autotune_report()
    assert rep["comm_resolved"] == "multistep", rep["comm_resolved"]
    cand = rep["comm"]["forward"]["candidates"]
    assert cand["multistep"]["injected_inter_bytes"] < \
        cand["nap"]["injected_inter_bytes"]
    plan = build_multistep_plan(a.indptr, a.indices, part, TOPO)
    np.testing.assert_array_equal(np.asarray(op @ v, dtype=np.float64),
                                  simulate_multistep_spmv(a, v, plan))
    np.testing.assert_array_equal(np.asarray(op.T @ v, dtype=np.float64),
                                  simulate_multistep_spmv_transpose(a, v,
                                                                    plan))
    # multi-RHS through the same program
    vv = np.round(rng.standard_normal((a.shape[1], 4)) * 4)
    want = np.stack([simulate_multistep_spmv(a, vv[:, i], plan)
                     for i in range(4)], axis=1)
    np.testing.assert_array_equal(np.asarray(op @ vv, dtype=np.float64),
                                  want)
    print("  comm='auto' resolves to multistep and matches bitwise")


def check_rectangular_empty_ranks() -> None:
    """Wide operator whose column partition leaves ranks empty, run
    through the multistep shardmap program."""
    m, n = 96, 6
    row_part = contiguous_partition(m, TOPO.n_procs)
    col_part = contiguous_partition(n, TOPO.n_procs)
    assert min(np.bincount(col_part.owner, minlength=TOPO.n_procs)) == 0
    base = random_fixed_nnz(m, 3, seed=5)
    indptr, idx2 = [0], []
    for i in range(m):
        cols = sorted(set((base.indices[base.indptr[i]:base.indptr[i + 1]]
                           % n).tolist()))
        idx2.extend(cols)
        indptr.append(len(idx2))
    rng = np.random.default_rng(6)
    a = intify(CSR(np.array(indptr, np.int64), np.array(idx2, np.int64),
                   rng.standard_normal(len(idx2)), (m, n)))
    v = np.round(rng.standard_normal(n) * 4)
    u = np.round(rng.standard_normal(m) * 4)
    plan = build_multistep_plan(a.indptr, a.indices, row_part, TOPO,
                                col_part=col_part)
    op = nap.operator(a, topo=TOPO, row_part=row_part, col_part=col_part,
                      backend="shardmap", comm="multistep")
    np.testing.assert_array_equal(np.asarray(op @ v, dtype=np.float64),
                                  simulate_multistep_spmv(a, v, plan))
    np.testing.assert_array_equal(np.asarray(op.T @ u, dtype=np.float64),
                                  simulate_multistep_spmv_transpose(a, u,
                                                                    plan))
    print("  rectangular + empty ranks bitwise vs simulator")


def main() -> None:
    a, part = skewed_matrix(TOPO, seed=0)
    check_strategies_bitwise(a, part, "skewed")
    u = intify(random_fixed_nnz(256, 9, seed=3))
    check_strategies_bitwise(u, contiguous_partition(256, TOPO.n_procs),
                             "uniform")
    check_nap_bit_identical()
    check_auto_end_to_end()
    check_rectangular_empty_ranks()
    print("ALL OK")


if __name__ == "__main__":
    main()
