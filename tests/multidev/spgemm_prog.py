"""Multi-device distributed-SpGEMM sweep (subprocess, forced 8 devices).

Checks, with jax x64 enabled (the SpGEMM program supports float64
payloads; the SpMV operators keep their own float32 convention):

* square / tall / wide / empty-rank ``C = A @ B`` on independent
  row/mid partitions, both methods (nap / standard), both partition
  kinds: the shard_map program matches the scipy float64 oracle at f32
  tolerance, and at ~1-ulp with float64 payloads; the simulate path is
  bit-for-bit equal to the host ``csr_matmul``;
* the smoothed-aggregation hierarchy assembled with
  ``rap=distributed_rap(backend="shardmap", dtype=float64)`` matches the
  host hierarchy exactly in structure and to round-off in values (the
  simulate-backend hierarchy matches BIT-FOR-BIT), with every Galerkin
  product counted through the device program;
* ``level_operators(..., materialize=True, spgemm_backend="shardmap")``
  builds coarse operators from on-device products (asserted against the
  host assembly inside ``level_operators``) and the resulting V-cycle
  matches the host-operator V-cycle.

``--quick`` runs a 4-device subset (shard_map sweep only) — the tier-1
subprocess smoke.
"""
import os
import sys

QUICK = "--quick" in sys.argv
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + \
    ("4" if QUICK else "8")

import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from repro.amg.matmul import csr_matmul
from repro.core.partition import contiguous_partition, strided_partition
from repro.core.topology import Topology
from repro.spgemm import (build_spgemm_plan, distributed_rap,
                          distributed_spgemm, shardmap_spgemm_runs,
                          simulate_spgemm)
from repro.sparse import CSR, rotated_anisotropic_2d

TOPO = Topology(n_nodes=2, ppn=2) if QUICK else Topology(n_nodes=2, ppn=4)
# square / tall / wide / empty-rank (mid dim below the machine size)
SHAPES = [(64, 48, 40), (40, 64, 72), (48, 6, 40)]


def rand_csr(rng, m, n, density=0.25):
    mat = (rng.random((m, n)) < density) * rng.standard_normal((m, n))
    return mat, CSR.from_dense(mat)


def check_spgemm_sweep(seed: int) -> None:
    rng = np.random.default_rng(seed)
    for (m, k, n) in SHAPES:
        am, a = rand_csr(rng, m, k)
        bm, b = rand_csr(rng, k, n)
        want = (sp.csr_matrix(am) @ sp.csr_matrix(bm)).toarray()
        host = csr_matmul(a, b)
        for mk in (contiguous_partition, strided_partition):
            rp, mp = mk(m, TOPO.n_procs), mk(k, TOPO.n_procs)
            for method in ("nap", "standard"):
                # float64 simulate: bit-for-bit vs host csr_matmul
                plan = build_spgemm_plan(a, b, rp, mp, TOPO, method=method)
                c_sim = simulate_spgemm(a, b, plan)
                assert np.array_equal(c_sim.indptr, host.indptr)
                assert np.array_equal(c_sim.indices, host.indices)
                assert np.array_equal(c_sim.data, host.data)
                # f32 on-device program vs scipy
                c32 = distributed_spgemm(a, b, rp, mp, TOPO, method=method,
                                         backend="shardmap")
                np.testing.assert_allclose(c32.to_dense(), want,
                                           rtol=1e-4, atol=1e-4)
                if not QUICK:
                    # float64 payloads: round-off-level parity
                    c64 = distributed_spgemm(a, b, rp, mp, TOPO,
                                             method=method,
                                             backend="shardmap",
                                             dtype=jnp.float64)
                    assert np.array_equal(c64.indices, host.indices)
                    np.testing.assert_allclose(c64.data, host.data,
                                               rtol=1e-12, atol=1e-13)
        print(f"spgemm {m}x{k}x{n} ok", flush=True)


def check_distributed_hierarchy() -> None:
    from repro.amg import smoothed_aggregation_hierarchy

    a = rotated_anisotropic_2d(16, eps=0.1)
    host = smoothed_aggregation_hierarchy(a, theta=0.1, coarse_size=16)
    runs0 = shardmap_spgemm_runs()
    dev = smoothed_aggregation_hierarchy(
        a, theta=0.1, coarse_size=16,
        rap=distributed_rap(TOPO, backend="shardmap", dtype=jnp.float64))
    n_products = 2 * (len(host) - 1)  # A@P then R@(AP) per coarse level
    assert shardmap_spgemm_runs() - runs0 == n_products, \
        "hierarchy assembly did not run through the device SpGEMM program"
    for lh, ld in zip(host, dev):
        assert np.array_equal(lh.a.indptr, ld.a.indptr)
        assert np.array_equal(lh.a.indices, ld.a.indices)
        np.testing.assert_allclose(ld.a.data, lh.a.data,
                                   rtol=1e-12, atol=1e-13)
    # the float64 simulate path IS bit-for-bit
    sim = smoothed_aggregation_hierarchy(
        a, theta=0.1, coarse_size=16, rap=distributed_rap(TOPO))
    for lh, ls in zip(host, sim):
        assert np.array_equal(lh.a.data, ls.a.data)
    print(f"distributed hierarchy ok ({len(host)} levels, "
          f"{n_products} on-device Galerkin products)", flush=True)


def check_materialized_level_operators() -> None:
    from repro.amg import (amg_vcycle, level_operators,
                           smoothed_aggregation_hierarchy)

    a = rotated_anisotropic_2d(16, eps=0.1)
    a = CSR.from_dense(a.to_dense() + np.eye(a.shape[0]) * 1e-3)
    levels = smoothed_aggregation_hierarchy(a, theta=0.1, coarse_size=16)
    runs0 = shardmap_spgemm_runs()
    # every coarse A assembled on-device (float64 payloads), asserted
    # against the host csr_matmul assembly inside level_operators
    ops = level_operators(levels, TOPO, backend="shardmap",
                          block_shape=(8, 16), materialize=True,
                          spgemm_backend="shardmap",
                          spgemm_dtype=jnp.float64)
    n_products = 2 * (len(levels) - 1)
    assert shardmap_spgemm_runs() - runs0 == n_products, \
        "materialize=True did not route every Galerkin product through " \
        "the device SpGEMM program"
    rng = np.random.default_rng(0)
    b = rng.standard_normal(a.shape[0])
    x = amg_vcycle(levels, b, operators=ops)
    x_ref = amg_vcycle(levels, b, operators=None)
    np.testing.assert_allclose(x, x_ref, rtol=5e-3, atol=5e-4)
    # a concrete coarse operator straight from the front-end
    conc = ops[0].galerkin(materialize=True, spgemm_backend="shardmap",
                           dtype=jnp.float64, cross_check=True)
    assert conc.shape == (levels[1].a.shape[0],) * 2
    np.testing.assert_allclose(conc.a.data, levels[1].a.data,
                               rtol=1e-12, atol=1e-13)
    print(f"materialize=True level operators ok ({n_products} on-device "
          f"products, V-cycle matches host)", flush=True)


def main() -> None:
    check_spgemm_sweep(seed=42)
    if not QUICK:
        check_distributed_hierarchy()
        check_materialized_level_operators()
    print("ALL OK")


if __name__ == "__main__":
    main()
