"""Multi-device sweep of RECTANGULAR NapOperators (subprocess).

On a forced 8-device host platform:

* tall / wide / empty-rank [m, n] operators with independent row/col
  partitions, both methods (nap / standard), nv in {1, 4}: forward must
  match the dense ``A @ x`` and ``.T`` the dense ``A.T @ y`` — transpose
  packed by the ROW partition, unpacked by the COLUMN partition;
* the transpose direction's local compute resolves through the
  compile-time transpose autotuner (ell/coo) and BOTH formats agree;
* ``(R @ A @ P) @ x`` — the lazily composed Galerkin operator — matches
  the scipy triple product;
* a full AMG V-cycle through ``level_operators(backend="shardmap")`` in
  which EVERY restriction/prolongation is a rectangular NapOperator:
  asserted by checking each level's ``r`` is a transposed view whose
  executor has actually built (and run) its "transpose" program — the
  node-aware transpose executor, not a host-side gather.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import scipy.sparse as sp

import repro.api as nap
from repro.core.partition import contiguous_partition, strided_partition
from repro.core.topology import Topology
from repro.sparse import CSR, rotated_anisotropic_2d

TOPOS = [(2, 4), (4, 2)]


def dense_oracle(mat, v):
    return mat @ v if v.ndim == 1 else mat @ v


def rect_case(m, n, density, seed):
    rng = np.random.default_rng(seed)
    mat = (rng.random((m, n)) < density) * rng.standard_normal((m, n))
    return mat, CSR.from_dense(mat)


def check_rect(topo_shape, m, n, nv, seed):
    nn, ppn = topo_shape
    topo = Topology(n_nodes=nn, ppn=ppn)
    rng = np.random.default_rng(seed)
    mat, a = rect_case(m, n, 0.25, seed)
    mk = strided_partition if seed % 2 else contiguous_partition
    rp, cp = mk(m, topo.n_procs), mk(n, topo.n_procs)
    v = rng.standard_normal(n) if nv == 1 else rng.standard_normal((n, nv))
    u = rng.standard_normal(m) if nv == 1 else rng.standard_normal((m, nv))
    want_f, want_t = mat @ v, mat.T @ u

    sim = nap.operator(a, topo=topo, row_part=rp, col_part=cp,
                       backend="simulate")
    np.testing.assert_allclose(sim @ v, want_f, rtol=1e-9, atol=1e-11)
    np.testing.assert_allclose(sim.T @ u, want_t, rtol=1e-9, atol=1e-11)

    for method in ("nap", "standard"):
        op = nap.operator(a, topo=topo, row_part=rp, col_part=cp,
                          method=method, backend="shardmap",
                          block_shape=(8, 16))
        assert op.shape == (m, n) and op.T.shape == (n, m)
        np.testing.assert_allclose(op @ v, want_f, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(op.T @ u, want_t, rtol=1e-4, atol=1e-5)
        # the transpose autotuner recorded a verdict and op.T reports it
        rep = op.autotune_report()
        assert rep["transpose_resolved"] in ("ell", "coo")
        assert rep["transpose"]["chosen"] == rep["transpose_resolved"] or \
            op.spec.local_compute != "auto"
        assert op.T.local_compute == rep["transpose_resolved"]
        # both transpose formats compute the same numbers
        for fmt in ("ell", "coo"):
            op_f = nap.operator(a, topo=topo, row_part=rp, col_part=cp,
                                method=method, backend="shardmap",
                                block_shape=(8, 16), local_compute=fmt)
            np.testing.assert_allclose(op_f.T @ u, want_t,
                                       rtol=1e-4, atol=1e-5)
            assert op_f.T.local_compute == fmt


def check_galerkin(topo_shape, seed):
    """(R @ A @ P) @ x on shardmap == scipy triple product."""
    nn, ppn = topo_shape
    topo = Topology(n_nodes=nn, ppn=ppn)
    rng = np.random.default_rng(seed)
    m, nc = 96, 40
    amat, a = rect_case(m, m, 0.15, seed)
    pmat, p = rect_case(m, nc, 0.2, seed + 1)
    fine = contiguous_partition(m, topo.n_procs)
    coarse = contiguous_partition(nc, topo.n_procs)
    a_op = nap.operator(a, topo=topo, part=fine, backend="shardmap",
                        block_shape=(8, 16))
    p_op = nap.operator(p, topo=topo, row_part=fine, col_part=coarse,
                        backend="shardmap", block_shape=(8, 16))
    gal = p_op.T @ a_op @ p_op
    x = rng.standard_normal(nc)
    want = (sp.csr_matrix(pmat).T @ sp.csr_matrix(amat) @ sp.csr_matrix(pmat)) @ x
    np.testing.assert_allclose(gal @ x, want, rtol=1e-3, atol=1e-4)
    assert len(gal.factors) == 3 and gal.shape == (nc, nc)


def check_distributed_vcycle():
    """The V-cycle's every grid transfer is a rectangular shardmap
    NapOperator and restriction executes the transpose program."""
    from repro.amg import (amg_vcycle, level_operators,
                           smoothed_aggregation_hierarchy)

    topo = Topology(n_nodes=2, ppn=4)
    a = rotated_anisotropic_2d(16, eps=0.1)
    a = CSR.from_dense(a.to_dense() + np.eye(a.shape[0]) * 1e-3)
    levels = smoothed_aggregation_hierarchy(a, theta=0.1, coarse_size=16)
    ops = level_operators(levels, topo, backend="shardmap",
                          block_shape=(8, 16))
    rect_levels = [e for e in ops if e.p is not None]
    assert rect_levels, "hierarchy produced no distributed P/R"
    for e in rect_levels:
        assert e.r.transposed and e.r.shape == e.p.shape[::-1]
        assert e.p.row_part is not e.p.col_part
    rng = np.random.default_rng(0)
    b = rng.standard_normal(a.shape[0])
    x = amg_vcycle(levels, b, operators=ops)
    # oracle: the identical cycle through host-side matvecs
    x_ref = amg_vcycle(levels, b, operators=None)
    np.testing.assert_allclose(x, x_ref, rtol=5e-3, atol=5e-4)
    # every rectangular level BUILT AND RAN its transpose program — the
    # node-aware transpose executor served P.T @ r (no host gather)
    for e in rect_levels:
        runs = e.p.executor._runs
        assert "transpose" in runs, \
            "restriction did not go through the transpose executor"
        assert runs["transpose"].local_compute in ("ell", "coo")
    print(f"distributed V-cycle ok: {len(rect_levels)} rectangular P/R "
          f"levels, all restrictions through the transpose executor",
          flush=True)


def main():
    seed = 700
    for topo_shape in TOPOS:
        for (m, n) in [(72, 40), (40, 72), (80, 6)]:  # tall / wide / empty-rank
            for nv in (1, 4):
                check_rect(topo_shape, m, n, nv, seed)
                seed += 1
            print(f"topo={topo_shape} rect {m}x{n} ok", flush=True)
        check_galerkin(topo_shape, seed)
        print(f"topo={topo_shape} galerkin triple product ok", flush=True)
    check_distributed_vcycle()
    print("ALL OK")


if __name__ == "__main__":
    main()
