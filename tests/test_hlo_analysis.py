"""hlo_analysis: trip-count-aware costing on real compiled modules +
synthetic HLO snippets for the parsers."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.hlo_analysis import (_comp_header_name, _crosses_pod,
                                     _first_group, _shape_bytes, analyze_hlo)


def test_scan_trip_count_flops():
    """XLA's cost_analysis counts a while body once; ours multiplies."""
    def g(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    c = jax.jit(g).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    r = analyze_hlo(c.as_text())
    assert r.dot_flops == 10 * 2 * 64**3
    xla = compat.cost_analysis(c)["flops"]
    assert xla == pytest.approx(2 * 64**3, rel=0.01)  # one body only


def test_nested_scan_flops():
    def h(x):
        def outer(c, _):
            def inner(cc, _):
                return cc @ cc, None
            y, _ = jax.lax.scan(inner, c, None, length=5)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    c = jax.jit(h).lower(jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    r = analyze_hlo(c.as_text())
    assert r.dot_flops == 15 * 2 * 32**3


def test_header_parse_nested_tuple():
    assert _comp_header_name(
        "%region_0.2 (arg: (s32[], f32[256,256])) -> (s32[], f32[256,256]) {"
    ) == "region_0.2"
    assert _comp_header_name("ENTRY %main.4 (x: f32[2]) -> f32[2] {") == "main.4"
    assert _comp_header_name("not a header") is None


def test_shape_bytes_tuple_with_comments():
    s = ("(s32[], f32[2,16,2048,16000]{3,2,1,0}, /*index=5*/pred[2,16,2048]"
         "{2,1,0})")
    want = 4 + 2 * 16 * 2048 * 16000 * 4 + 2 * 16 * 2048 * 1
    assert _shape_bytes(s) == want


def test_replica_group_iota_reconstruction():
    # [4,2]<=[2,4]T(1,0): transpose(reshape(iota(8),[2,4]),[1,0]) ->
    # [[0,4],[1,5],[2,6],[3,7]] — groups PAIR ACROSS the pod boundary 4
    attrs = "replica_groups=[4,2]<=[2,4]T(1,0), use_global_device_ids=true"
    assert _first_group(attrs) == [0, 4]
    assert _crosses_pod(attrs, pod_boundary=4)
    # [2,4]<=[8]: [[0,1,2,3],[4,5,6,7]] — within-pod groups
    attrs2 = "replica_groups=[2,4]<=[8]"
    assert _first_group(attrs2) == [0, 1, 2, 3]
    assert not _crosses_pod(attrs2, pod_boundary=4)


def test_explicit_groups_and_permute_pairs():
    assert _crosses_pod("replica_groups={{0,4},{1,5}}", 4)
    assert not _crosses_pod("replica_groups={{0,1},{2,3}}", 4)
    assert _crosses_pod("source_target_pairs={{0,4},{4,0}}", 4)
    assert not _crosses_pod("source_target_pairs={{0,1},{1,0}}", 4)
