"""Distributed SpGEMM correctness: plan simulator, shard_map program,
chunked host matmul, materialize, and the distributed AMG setup.

Tier-1 runs the float64 simulators in-process (square / tall / wide /
empty-rank partition sweep vs the scipy oracle, bit-for-bit vs the host
``csr_matmul``) plus a --quick shard_map sweep as a subprocess (it needs
its own forced device count).  The full 8-device program — float64
on-device products, the distributed hierarchy, ``materialize=True``
level operators — is the ``multidev``-marked run of the same program.
"""
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest
import scipy.sparse as sp

from repro.amg.matmul import csr_matmul
from repro.core.partition import contiguous_partition, strided_partition
from repro.core.topology import Topology
from repro.spgemm import (build_spgemm_plan, galerkin_rap, distributed_rap,
                          simulate_nap_spgemm, simulate_spgemm,
                          simulate_standard_spgemm)
from repro.sparse import CSR, rotated_anisotropic_2d

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _rand_csr(rng, m, n, density=0.2):
    mat = (rng.random((m, n)) < density) * rng.standard_normal((m, n))
    return mat, CSR.from_dense(mat)


# square / tall / wide / empty-rank (mid dim smaller than the machine)
SHAPES = [(48, 48, 48), (72, 40, 56), (40, 72, 64), (48, 5, 40)]


@pytest.mark.parametrize("method", ["nap", "standard"])
@pytest.mark.parametrize("part_kind", ["contiguous", "strided"])
def test_simulator_matches_scipy_and_host(method, part_kind):
    """Seeded sweep: the float64 message-passing SpGEMM equals scipy's
    ``A @ B`` numerically and the host ``csr_matmul`` BIT-FOR-BIT."""
    import zlib
    rng = np.random.default_rng(zlib.crc32(f"{method}/{part_kind}".encode()))
    topo = Topology(n_nodes=2, ppn=3)
    mk = {"contiguous": contiguous_partition,
          "strided": strided_partition}[part_kind]
    for (m, k, n) in SHAPES:
        am, a = _rand_csr(rng, m, k)
        bm, b = _rand_csr(rng, k, n, density=0.25)
        plan = build_spgemm_plan(a, b, mk(m, topo.n_procs),
                                 mk(k, topo.n_procs), topo, method=method)
        c = simulate_spgemm(a, b, plan)
        want = (sp.csr_matrix(am) @ sp.csr_matrix(bm)).toarray()
        np.testing.assert_allclose(c.to_dense(), want, atol=1e-12)
        host = csr_matmul(a, b)
        assert np.array_equal(c.indptr, host.indptr)
        assert np.array_equal(c.indices, host.indices)
        assert np.array_equal(c.data, host.data), \
            "simulate SpGEMM must be bit-for-bit equal to host csr_matmul"


def test_named_simulators_dispatch():
    rng = np.random.default_rng(0)
    topo = Topology(n_nodes=2, ppn=2)
    _, a = _rand_csr(rng, 32, 24)
    _, b = _rand_csr(rng, 24, 16)
    rp, mp = contiguous_partition(32, 4), contiguous_partition(24, 4)
    pn = build_spgemm_plan(a, b, rp, mp, topo, method="nap")
    ps = build_spgemm_plan(a, b, rp, mp, topo, method="standard")
    host = csr_matmul(a, b)
    for c in (simulate_nap_spgemm(a, b, pn), simulate_standard_spgemm(a, b, ps)):
        assert np.array_equal(c.data, host.data)
    with pytest.raises(AssertionError):
        simulate_nap_spgemm(a, b, ps)  # wrong plan family


def test_plan_validation_and_stats():
    rng = np.random.default_rng(1)
    topo = Topology(n_nodes=2, ppn=2)
    _, a = _rand_csr(rng, 32, 24)
    _, b = _rand_csr(rng, 24, 16)
    with pytest.raises(ValueError, match="chain"):
        build_spgemm_plan(a, a, contiguous_partition(32, 4),
                          contiguous_partition(32, 4), topo)
    with pytest.raises(ValueError, match="mismatch"):
        build_spgemm_plan(a, b, contiguous_partition(16, 4),
                          contiguous_partition(24, 4), topo)
    with pytest.raises(ValueError, match="method"):
        build_spgemm_plan(a, b, contiguous_partition(32, 4),
                          contiguous_partition(24, 4), topo, method="x")
    # value-weighted stats: every needed remote B row's nnz is accounted
    plan = build_spgemm_plan(a, b, contiguous_partition(32, 4),
                             contiguous_partition(24, 4), topo)
    st = plan.stats(bytes_per_val=8)
    assert st["inter"].total_bytes >= 0 and st["intra"].total_bytes >= 0
    vpads = plan.value_pads()
    assert set(vpads) == {"full", "init", "inter", "final"}
    assert all(v >= 1 for v in vpads.values())


def test_csr_matmul_chunking_bitwise_invariant():
    """The chunked row expansion (peak-memory fix) is bit-for-bit equal
    for ANY chunk budget, including one row at a time."""
    rng = np.random.default_rng(2)
    am, a = _rand_csr(rng, 37, 23, density=0.4)
    bm, b = _rand_csr(rng, 23, 29, density=0.4)
    ref = csr_matmul(a, b)
    np.testing.assert_allclose(
        ref.to_dense(), (sp.csr_matrix(am) @ sp.csr_matrix(bm)).toarray(),
        atol=1e-12)
    for budget in (1, 5, 64, 1 << 12):
        c = csr_matmul(a, b, chunk_products=budget)
        assert np.array_equal(c.indptr, ref.indptr)
        assert np.array_equal(c.indices, ref.indices)
        assert np.array_equal(c.data, ref.data), budget


def test_galerkin_rap_and_distributed_hierarchy():
    """The distributed RAP (simulate backend) assembles every coarse
    level bit-for-bit equal to the host hierarchy."""
    topo = Topology(n_nodes=2, ppn=2)
    a = rotated_anisotropic_2d(12, eps=0.1)
    from repro.amg import smoothed_aggregation_hierarchy
    host = smoothed_aggregation_hierarchy(a, theta=0.1, coarse_size=16)
    dist = smoothed_aggregation_hierarchy(
        a, theta=0.1, coarse_size=16,
        rap=distributed_rap(topo, cross_check=True))
    assert len(dist) == len(host) >= 2
    for lh, ld in zip(host, dist):
        assert np.array_equal(lh.a.indptr, ld.a.indptr)
        assert np.array_equal(lh.a.indices, ld.a.indices)
        assert np.array_equal(lh.a.data, ld.a.data)
    # one explicit triple product through galerkin_rap
    lvl = host[0]
    fine = contiguous_partition(lvl.a.shape[0], topo.n_procs)
    coarse = contiguous_partition(lvl.p.shape[1], topo.n_procs)
    a_c = galerkin_rap(lvl.r, lvl.a, lvl.p, fine, coarse, topo,
                       backend="simulate", cross_check=True)
    assert np.array_equal(a_c.data, host[1].a.data)
    with pytest.raises(ValueError, match="fine"):
        galerkin_rap(lvl.r, lvl.a, lvl.p, coarse, coarse, topo)


def test_materialize_simulate_and_level_operators():
    """ComposedOperator.materialize + level_operators(materialize=True)
    on the simulate backend: the concrete coarse operator equals the
    host Galerkin product bit-for-bit and the V-cycle is unchanged."""
    import repro.api as nap
    from repro.amg import (amg_vcycle, level_operators,
                           smoothed_aggregation_hierarchy)

    topo = Topology(n_nodes=2, ppn=2)
    rng = np.random.default_rng(3)
    m, nc = 48, 20
    am, a = _rand_csr(rng, m, m)
    pm, p = _rand_csr(rng, m, nc, density=0.3)
    fine = contiguous_partition(m, topo.n_procs)
    coarse = contiguous_partition(nc, topo.n_procs)
    a_op = nap.operator(a, topo=topo, part=fine, backend="simulate")
    p_op = nap.operator(p, topo=topo, row_part=fine, col_part=coarse,
                        backend="simulate")
    gal = p_op.T @ a_op @ p_op
    conc = gal.materialize(cross_check=True)
    assert isinstance(conc, nap.NapOperator) and conc.shape == (nc, nc)
    assert conc.row_part is coarse and conc.col_part is coarse
    host = csr_matmul(p.transpose(), csr_matmul(a, p))
    assert np.array_equal(conc.a.data, host.data)
    x = rng.standard_normal(nc)
    np.testing.assert_allclose(conc @ x, gal @ x, rtol=1e-12, atol=1e-12)

    # materialized hierarchy: coarse operators built FROM the distributed
    # product, asserted bit-for-bit against the host assembly inside
    a2 = rotated_anisotropic_2d(12, eps=0.1)
    levels = smoothed_aggregation_hierarchy(a2, theta=0.1, coarse_size=16)
    ops = level_operators(levels, topo, materialize=True)
    gal2 = ops[0].galerkin(materialize=True)
    assert isinstance(gal2, nap.NapOperator)
    assert np.array_equal(gal2.a.data, levels[1].a.data)
    b = rng.standard_normal(a2.shape[0])
    np.testing.assert_allclose(
        amg_vcycle(levels, b, operators=ops),
        amg_vcycle(levels, b, operators=None), rtol=1e-9, atol=1e-11)


def _run_prog(args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)  # the program sets its own device count
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "multidev" / "spgemm_prog.py")]
        + args,
        capture_output=True, text=True, env=env, timeout=timeout)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL OK" in proc.stdout


def test_spgemm_shardmap_quick():
    """Tier-1 shard_map sweep (subprocess; quick subset of the 8-device
    program): on-device SpGEMM vs the scipy float64 oracle."""
    _run_prog(["--quick"])


@pytest.mark.multidev
def test_spgemm_shardmap_8dev_full():
    """Full 8-device program: shard_map SpGEMM sweep, float64 on-device
    products, the distributed hierarchy matching the host bit-for-bit,
    and materialize=True level operators whose every Galerkin product
    runs through the device program."""
    _run_prog([])
