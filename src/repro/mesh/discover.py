"""Topology autodiscovery: derive ``Topology`` from the live jax mesh.

The paper's premise is exploiting the *actual* node-processor layout;
the seed declared it by hand everywhere.  Discovery reads the runtime
instead:

* ``n_nodes``  = ``jax.process_count()`` — one "node" per jax process
  (each process addresses its own devices; crossing processes is the
  expensive hop, exactly the paper's node boundary).
* ``ppn``      = ``jax.local_device_count()`` — devices this process
  addresses.

Rules:

* jax-free install (simulate backend only) → ``Topology(1, 1)``, the
  seed default.
* single process → ``Topology(1, local_device_count)``; with one device
  that is ``Topology(1, 1)`` — bit-identical to the declared default.
* multi-process (after :func:`repro.mesh.launcher.attach`) →
  ``Topology(process_count, local_device_count)``.  The device layout
  must be uniform (``device_count == process_count * local_device_count``)
  because the SMP rank order assumes equal ppn — a ragged job raises
  :class:`DiscoveryError` rather than silently mislaying ranks.
"""
from __future__ import annotations

from typing import Dict

from repro.core.topology import Topology

__all__ = ["DiscoveryError", "discover_topology", "discovery_report"]


class DiscoveryError(RuntimeError):
    """The live device layout cannot be expressed as Topology(n, ppn)."""


def discover_topology(*, strict: bool = True) -> Topology:
    """The ``Topology`` of the running job (see module docstring).

    ``strict=False`` skips the uniform-layout check and trusts the local
    counts (useful when probing a partially-initialised job).
    """
    try:
        import jax
    except Exception:        # jax-free numpy install: the seed default
        return Topology(n_nodes=1, ppn=1)
    n_proc = int(jax.process_count())
    ppn = int(jax.local_device_count())
    if strict:
        total = int(jax.device_count())
        if total != n_proc * ppn:
            raise DiscoveryError(
                f"non-uniform device layout: {total} global devices across "
                f"{n_proc} processes with {ppn} local — Topology(n_nodes, "
                f"ppn) needs every process to address the same device count")
    return Topology(n_nodes=n_proc, ppn=ppn)


def discovery_report() -> Dict[str, object]:
    """Machine-readable view of what discovery saw (benchmarks embed it)."""
    try:
        import jax
    except Exception:
        return {"source": "fallback", "jax": False,
                "n_nodes": 1, "ppn": 1, "platform": "none"}
    topo = discover_topology(strict=False)
    return {
        "source": "jax",
        "jax": True,
        "n_nodes": topo.n_nodes,
        "ppn": topo.ppn,
        "process_index": int(jax.process_index()),
        "device_count": int(jax.device_count()),
        "platform": str(jax.devices()[0].platform),
    }
