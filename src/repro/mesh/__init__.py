"""Multi-host mesh runtime: launcher, topology discovery, buffers, scaling.

The distributed runtime under the operator stack:

* :mod:`repro.mesh.launcher` — ``jax.distributed`` multi-process
  launcher (subprocess fan-out for CI, ``REPRO_MESH_*`` env attach for
  clusters).
* :mod:`repro.mesh.discover` — ``discover_topology()`` derives
  ``Topology(n_nodes, ppn)`` from the live mesh; ``repro.api.operator``
  autodiscovers when ``topo`` is omitted.
* :mod:`repro.mesh.buffers` — persistent device-buffer registry +
  single/multi-process array placement (the one seam that knows about
  global ``jax.Array`` layout).
* :mod:`repro.mesh.scaling` — measured weak/strong-scaling harness over
  the real operator stack (per-phase exchange walls, standard vs nap vs
  multistep).

Submodules import lazily where it matters: ``repro.mesh`` itself never
touches jax.
"""
from repro.mesh.buffers import (BufferNamespace, BufferRegistry,
                                default_registry, fetch_mesh_array,
                                is_multiprocess, stage_mesh_array)
from repro.mesh.discover import (DiscoveryError, discover_topology,
                                 discovery_report)
from repro.mesh.launcher import (LaunchError, LaunchResult, attach, launch,
                                 mesh_env, pick_coordinator)

__all__ = [
    "BufferNamespace", "BufferRegistry", "default_registry",
    "fetch_mesh_array", "is_multiprocess", "stage_mesh_array",
    "DiscoveryError", "discover_topology", "discovery_report",
    "LaunchError", "LaunchResult", "attach", "launch",
    "mesh_env", "pick_coordinator",
]
