"""Persistent device-buffer registry + global mesh placement helpers.

Two jobs, one seam:

* :class:`BufferRegistry` / :class:`BufferNamespace` — an alpa-style
  persistent buffer store (named CSR/value/plan arrays pinned on device
  across solves, explicit lifecycle + eviction stats).  A namespace
  speaks the dict protocol so it plugs straight into a compiled plan's
  ``_dev_cache`` (:func:`repro.core.spmv_jax._memo_device_arrays`): the
  first bind stages each host array once, every later bind — and every
  hot value swap — reuses the resident device buffer.  Evicting a plan
  (``serve.PlanCache`` LRU / elastic ``rebuild``) releases its namespace
  so the device memory is accounted, not leaked.

* Placement — the ONE place that knows whether this process is part of a
  multi-process ``jax.distributed`` mesh.  Single-process staging is a
  plain ``jnp.asarray`` (bit-identical to the declared-topo seed path);
  multi-process staging builds a GLOBAL ``jax.Array`` laid out
  ``P("node", "proc")`` over the process mesh, where each process
  materialises only its addressable shards.  ``fetch_mesh_array``
  inverts it: fully-addressable results fetch with ``np.asarray``,
  global results gather their shards across processes (mask-select per
  owner, so the round trip is bitwise exact — no zero+sum, which can
  turn ``-0.0`` into ``+0.0``).

Importing this module never touches jax — everything jax lives behind
function calls (the simulate backend stays usable on a jax-free numpy
install).
"""
from __future__ import annotations

import weakref
from typing import Dict, Optional

import numpy as np

from repro.core.topology import Topology

__all__ = ["BufferNamespace", "BufferRegistry", "default_registry",
           "process_count", "is_multiprocess", "mesh_for",
           "stage_mesh_array", "input_stager", "fetch_mesh_array"]


# ---------------------------------------------------------------------------
# Placement: single-process vs jax.distributed global arrays
# ---------------------------------------------------------------------------

def process_count() -> int:
    """Processes in the jax.distributed job (1 when unattached/jax-free)."""
    try:
        import jax
        return int(jax.process_count())
    except Exception:
        return 1


def is_multiprocess() -> bool:
    return process_count() > 1


_MESH_CACHE: Dict[tuple, object] = {}


def mesh_for(topo: Topology):
    """The shared ``(node, proc)`` device mesh for a topology, memoized —
    every executor/stager bound to the same layout reuses one mesh object
    (jax caches sharding/layout decisions per mesh instance)."""
    key = (topo.n_nodes, topo.ppn)
    if key not in _MESH_CACHE:
        from repro.compat import make_mesh
        _MESH_CACHE[key] = make_mesh((topo.n_nodes, topo.ppn),
                                     ("node", "proc"))
    return _MESH_CACHE[key]


def _global_sharding(topo: Topology):
    from jax.sharding import NamedSharding, PartitionSpec as P
    return NamedSharding(mesh_for(topo), P("node", "proc"))


def stage_mesh_array(g: np.ndarray, topo: Topology, dtype=None):
    """Device-stage one mesh-shaped ``[n_nodes, ppn, ...]`` host array.

    Single-process: plain ``jnp.asarray`` — bit-identical to the
    declared-topo path.  Multi-process: a global ``jax.Array`` sharded
    ``P("node", "proc")``; each process materialises only the shards it
    can address (its own node rows), never the full job's buffers.
    """
    import jax.numpy as jnp
    if dtype is not None:
        g = np.asarray(g, dtype)
    if not is_multiprocess():
        return jnp.asarray(g)
    import jax
    g = np.asarray(g)
    return jax.make_array_from_callback(g.shape, _global_sharding(topo),
                                        lambda idx: g[idx])


def input_stager(topo: Topology):
    """Per-call operand stager for the jitted run path.

    ``None`` in a single process — the seed's ``jnp.asarray(v, f32)``
    stays untouched (bit-identity).  Multi-process, returns
    ``stage(shards, dtype=f32)`` placing the packed ``[n_nodes, ppn,
    pad(, nv)]`` operand globally so the shard_map program can consume
    it.
    """
    if not is_multiprocess():
        return None

    def stage(shards, dtype=np.float32):
        return stage_mesh_array(np.asarray(shards, dtype), topo)

    return stage


def fetch_mesh_array(w) -> np.ndarray:
    """Host copy of a (possibly global) device array, bitwise exact.

    Fully-addressable arrays (every single-process result) go through
    ``np.asarray`` — the seed path.  Global arrays fill the local shards,
    ``process_allgather`` the per-process views, and mask-select each
    element from its owning process.
    """
    if getattr(w, "is_fully_addressable", True):
        return np.asarray(w)
    from jax.experimental import multihost_utils
    shards = list(w.addressable_shards)
    full = np.zeros(w.shape, np.asarray(shards[0].data).dtype)
    have = np.zeros(w.shape, bool)
    for s in shards:
        full[s.index] = np.asarray(s.data)
        have[s.index] = True
    all_vals = np.asarray(multihost_utils.process_allgather(full))
    all_have = np.asarray(multihost_utils.process_allgather(have))
    out = full
    for p in range(all_vals.shape[0]):
        out = np.where(all_have[p], all_vals[p], out)
    return np.asarray(out, full.dtype)


# ---------------------------------------------------------------------------
# The persistent buffer registry
# ---------------------------------------------------------------------------

class BufferNamespace:
    """One plan's named device buffers (dict protocol; a ``_dev_cache``).

    Lifecycle: arrays enter via ``__setitem__`` (counted as ``staged``),
    are read back by every executor bind via ``__getitem__`` (``reused``),
    leave individually via ``pop`` (hot value swaps retire exactly the
    swapped names) or wholesale via ``release()`` (plan eviction /
    elastic rebuild).  Byte counts use the logical array size — the
    registry's ``resident_bytes`` is the job-wide figure, not per-host.
    """

    def __init__(self, registry: "BufferRegistry", label: str):
        self._registry = registry
        self.label = label
        self._bufs: Dict[str, object] = {}
        self._nbytes: Dict[str, int] = {}
        self.released = False

    def __contains__(self, name: str) -> bool:
        return name in self._bufs

    def __getitem__(self, name: str):
        self._registry.stats["reused"] += 1
        return self._bufs[name]

    def __setitem__(self, name: str, arr) -> None:
        if name in self._bufs:
            self.pop(name)
        nb = int(getattr(arr, "nbytes", 0))
        self._bufs[name] = arr
        self._nbytes[name] = nb
        st = self._registry.stats
        st["staged"] += 1
        st["staged_bytes"] += nb

    def pop(self, name: str, default=None):
        if name not in self._bufs:
            return default
        arr = self._bufs.pop(name)
        nb = self._nbytes.pop(name)
        st = self._registry.stats
        st["evicted"] += 1
        st["evicted_bytes"] += nb
        return arr

    def __len__(self) -> int:
        return len(self._bufs)

    def keys(self):
        return self._bufs.keys()

    def resident_bytes(self) -> int:
        return sum(self._nbytes.values())

    def release(self) -> int:
        """Drop every buffer in the namespace; returns bytes released.
        Idempotent — the serve cache may release through several paths."""
        nb = self.resident_bytes()
        for name in list(self._bufs):
            self.pop(name)
        if not self.released:
            self.released = True
            self._registry.stats["namespaces_released"] += 1
        return nb


class BufferRegistry:
    """Job-wide accounting over every live :class:`BufferNamespace`.

    The registry never holds strong references to buffers — namespaces
    own them, the registry tracks them weakly, so a garbage-collected
    plan frees its device memory exactly as before the registry existed.
    """

    def __init__(self, name: str = "default"):
        self.name = name
        self._namespaces: "weakref.WeakSet[BufferNamespace]" = weakref.WeakSet()
        self.stats: Dict[str, int] = {
            "staged": 0, "staged_bytes": 0,
            "reused": 0,
            "evicted": 0, "evicted_bytes": 0,
            "namespaces_created": 0, "namespaces_released": 0,
        }

    def namespace(self, label: str = "plan") -> BufferNamespace:
        ns = BufferNamespace(self, label)
        self._namespaces.add(ns)
        self.stats["namespaces_created"] += 1
        return ns

    def live_namespaces(self) -> int:
        return sum(1 for ns in self._namespaces if not ns.released)

    def resident_bytes(self) -> int:
        return sum(ns.resident_bytes() for ns in self._namespaces)

    def report(self) -> Dict[str, object]:
        return dict(self.stats, name=self.name,
                    live_namespaces=self.live_namespaces(),
                    resident_bytes=self.resident_bytes())


_DEFAULT: Optional[BufferRegistry] = None


def default_registry() -> BufferRegistry:
    """The process-wide registry every compiled plan's ``_dev_cache``
    hangs off (tests may construct private registries)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = BufferRegistry()
    return _DEFAULT
