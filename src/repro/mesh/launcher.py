"""``jax.distributed`` multi-process launcher + env-var attach.

Two halves of one contract:

* :func:`launch` — subprocess fan-out for tests/CI: spawn N python
  processes on localhost, each wired to a fresh coordinator through the
  ``REPRO_MESH_*`` environment variables, run a target per process and
  collect its output.  The target is either a ``"pkg.mod:fn"`` spec
  (re-entered via ``python -m repro.mesh.launcher``) or a script path
  (run as ``python script.py args...`` — the script calls
  :func:`attach` itself).

* :func:`attach` — env-var attach for children AND real clusters: read
  the ``REPRO_MESH_*`` variables (a scheduler can set the same ones),
  force the per-process XLA host device count *before* jax loads, pick
  the gloo CPU collectives backend, and ``jax.distributed.initialize``.
  With no variables set it is a no-op returning the single-process view
  — safe to call unconditionally at program start.

Environment variables::

    REPRO_MESH_COORDINATOR    host:port of process 0's coordinator
    REPRO_MESH_NUM_PROCESSES  total process count N
    REPRO_MESH_PROCESS_ID     this process's id in [0, N)
    REPRO_MESH_LOCAL_DEVICES  devices per process (CPU: forces the XLA
                              host device count; unset = platform default)

Importing this module never touches jax (children must set XLA flags
before jax loads — that is the point).
"""
from __future__ import annotations

import dataclasses
import importlib
import json
import os
import socket
import subprocess
import sys
from typing import Dict, List, Optional, Sequence

ENV_COORDINATOR = "REPRO_MESH_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_MESH_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_MESH_PROCESS_ID"
ENV_LOCAL_DEVICES = "REPRO_MESH_LOCAL_DEVICES"

__all__ = ["attach", "launch", "pick_coordinator", "mesh_env",
           "LaunchError", "LaunchResult",
           "ENV_COORDINATOR", "ENV_NUM_PROCESSES", "ENV_PROCESS_ID",
           "ENV_LOCAL_DEVICES"]


class LaunchError(RuntimeError):
    """A launched process died; carries every process's output tail."""


def pick_coordinator(host: str = "127.0.0.1") -> str:
    """A free ``host:port`` for a fresh coordinator (bind-and-release)."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind((host, 0))
        return f"{host}:{s.getsockname()[1]}"


def mesh_env(coordinator: str, num_processes: int, process_id: int,
             local_devices: Optional[int] = None) -> Dict[str, str]:
    """The ``REPRO_MESH_*`` variables for one process of a job."""
    env = {
        ENV_COORDINATOR: coordinator,
        ENV_NUM_PROCESSES: str(int(num_processes)),
        ENV_PROCESS_ID: str(int(process_id)),
    }
    if local_devices is not None:
        env[ENV_LOCAL_DEVICES] = str(int(local_devices))
    return env


def attach(verbose: bool = False) -> Dict[str, object]:
    """Join the mesh described by the ``REPRO_MESH_*`` environment.

    Must run before anything initialises jax's backends.  Returns a
    summary dict; ``attached`` is False when no coordinator is set (the
    plain single-process path — nothing is touched).
    """
    coordinator = os.environ.get(ENV_COORDINATOR)
    if not coordinator:
        return {"attached": False, "process_id": 0, "num_processes": 1}
    num_processes = int(os.environ[ENV_NUM_PROCESSES])
    process_id = int(os.environ[ENV_PROCESS_ID])
    local = os.environ.get(ENV_LOCAL_DEVICES)
    if local and "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        flags = " ".join(f for f in flags.split()
                         if not f.startswith("--xla_force_host_platform_"
                                             "device_count"))
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={local}".strip())
    import jax
    # TCP collectives for cross-process all_to_all on CPU hosts; a pure
    # config flag, ignored by non-CPU platforms (and probing the backend
    # here would initialise it, which initialize() forbids)
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)
    info = {"attached": True, "coordinator": coordinator,
            "process_id": process_id, "num_processes": num_processes,
            "local_devices": int(jax.local_device_count())}
    if verbose:
        print(f"[mesh.attach] p{process_id}/{num_processes} -> {coordinator} "
              f"({info['local_devices']} local devices)", flush=True)
    return info


@dataclasses.dataclass
class LaunchResult:
    coordinator: str
    returncodes: List[int]
    outputs: List[str]          # combined stdout+stderr per process

    def output(self, process_id: int = 0) -> str:
        return self.outputs[process_id]


def _child_cmd(target: str, args: Sequence[str], python: str) -> List[str]:
    if target.endswith(".py") or os.path.sep in target:
        return [python, target, *map(str, args)]
    return [python, "-m", "repro.mesh.launcher", target,
            json.dumps(list(map(str, args)))]


def launch(target: str, n_processes: int, *, args: Sequence[str] = (),
           local_devices: int = 1, env: Optional[Dict[str, str]] = None,
           timeout_s: float = 600.0, python: str = sys.executable
           ) -> LaunchResult:
    """Run ``target`` in ``n_processes`` coordinator-connected processes.

    Every child gets the ``REPRO_MESH_*`` variables plus an XLA flag
    forcing ``local_devices`` host devices (overriding any inherited
    forced count — the parent's device fan-out must not leak into
    children).  Raises :class:`LaunchError` if any process exits
    non-zero or exceeds ``timeout_s``.
    """
    coordinator = pick_coordinator()
    procs = []
    for pid in range(int(n_processes)):
        child_env = dict(os.environ)
        child_env.update(env or {})
        child_env.update(mesh_env(coordinator, n_processes, pid,
                                  local_devices))
        child_env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={local_devices}")
        procs.append(subprocess.Popen(
            _child_cmd(target, args, python), env=child_env,
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True))
    outputs: List[str] = []
    returncodes: List[int] = []
    try:
        for p in procs:
            out, _ = p.communicate(timeout=timeout_s)
            outputs.append(out or "")
            returncodes.append(p.returncode)
    except subprocess.TimeoutExpired:
        for p in procs:
            p.kill()
        while len(outputs) < len(procs):
            p = procs[len(outputs)]
            try:
                out, _ = p.communicate()
            except Exception:
                out = ""
            outputs.append(out or "")
            returncodes.append(p.returncode if p.returncode is not None
                               else -1)
        raise LaunchError(
            f"launch({target!r}, n={n_processes}) timed out after "
            f"{timeout_s}s; tails:\n" + _tails(outputs))
    if any(rc != 0 for rc in returncodes):
        raise LaunchError(
            f"launch({target!r}, n={n_processes}) failed "
            f"(returncodes={returncodes}); tails:\n" + _tails(outputs))
    return LaunchResult(coordinator, returncodes, outputs)


def _tails(outputs: List[str], lines: int = 25) -> str:
    parts = []
    for pid, out in enumerate(outputs):
        tail = "\n".join(out.splitlines()[-lines:])
        parts.append(f"--- process {pid} ---\n{tail}")
    return "\n".join(parts)


def _child_main(argv: List[str]) -> int:
    """``python -m repro.mesh.launcher pkg.mod:fn '[json args]'`` — the
    module:function child entry: attach, import, call."""
    if not argv:
        print("usage: python -m repro.mesh.launcher pkg.mod:fn '[args...]'",
              file=sys.stderr)
        return 2
    target = argv[0]
    call_args = json.loads(argv[1]) if len(argv) > 1 else []
    attach(verbose=True)
    mod_name, _, fn_name = target.partition(":")
    fn = getattr(importlib.import_module(mod_name), fn_name)
    fn(*call_args)
    return 0


if __name__ == "__main__":
    raise SystemExit(_child_main(sys.argv[1:]))
