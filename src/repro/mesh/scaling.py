"""Measured weak/strong-scaling harness over the REAL operator stack.

The seed's paper-figure benchmarks modeled every wall with the Blue
Waters constants.  This module measures instead:

* :func:`measure_spmv` — end-to-end ``op @ x`` walls through
  ``repro.api.operator`` (pack → jitted shard_map exchange+compute →
  unpack), best-of-``repeats`` after a warm-up apply.
* :func:`measure_phase_walls` — per-phase EXCHANGE walls: each phase of
  the plan's :func:`repro.comm.cost.planned_traffic` is reproduced as a
  standalone jitted shard_map ``all_to_all`` with the plan's actual slot
  count and pad, timed in isolation.  These are the records
  :meth:`repro.core.cost_model.PostalParams.calibrated` fits.
* :func:`scaling_sweep` — a weak/strong ladder over (n_nodes, ppn)
  shapes × comm methods (standard vs nap vs multistep), emitting
  machine-readable walls + comm fractions + calibration records.

Run as its own process (it must force the XLA host device count before
jax initialises)::

    PYTHONPATH=src python -m repro.mesh.scaling config.json out.json

``config.json`` may override any :data:`DEFAULT_CONFIG` key.  Importing
this module never touches jax; every jax import lives inside a function.
"""
from __future__ import annotations

import json
import sys
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.topology import Topology

__all__ = ["DEFAULT_CONFIG", "measure_phase_walls", "measure_spmv",
           "scaling_sweep", "calibration_records"]

DEFAULT_CONFIG: Dict[str, object] = {
    "mode": "strong",            # "strong" (fixed n) | "weak" (n per rank)
    "n_rows": 1024,              # strong: global rows; weak: rows PER RANK
    "nnz_per_row": 8,
    "seed": 0,
    "matrix": {"kind": "random"},  # or {"kind": "suitesparse_like",
                                   #     "name": ..., "scale": ...}
    "partition": "contiguous",   # contiguous | strided | balanced
    "ladder": [[1, 2], [2, 2], [2, 4]],   # (n_nodes, ppn) shapes
    "methods": ["standard", "nap", "multistep"],
    "repeats": 3,
}


def _best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(max(1, int(repeats))):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _axis_slots(phase: str, topo: Topology):
    """(mesh axis, slot count) the lowering uses for one exchange phase."""
    if phase == "inter":
        return "node", topo.n_nodes
    if phase in ("direct", "pair"):
        return ("node", "proc"), topo.n_procs
    return "proc", topo.ppn           # full / init / final — intra-node


def measure_phase_walls(plan, topo: Topology, bytes_per_val: int = 4,
                        repeats: int = 3) -> List[Dict[str, object]]:
    """Measured wall per exchange phase of ``plan`` (standalone timers).

    Each non-empty phase of :func:`repro.comm.cost.planned_traffic` runs
    as a bare jitted shard_map ``all_to_all`` over the SAME mesh axis
    with the plan's slot count and pad — the exchange the full program
    issues, minus local compute.  The standard plan's flat pair exchange
    (accounted as ``pair_inter`` + ``pair_intra``) is one collective and
    is timed once, as ``pair``.

    Records carry ``n_msgs``/``nbytes`` per BOTTLENECK RANK (matching
    the postal model's charging) plus the measured ``seconds`` — the
    exact shape :meth:`PostalParams.calibrated` consumes.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.comm.cost import planned_traffic
    from repro.compat import shard_map
    from repro.mesh.buffers import fetch_mesh_array, input_stager, mesh_for

    traffic = planned_traffic(plan, bytes_per_val=bytes_per_val)
    phases: Dict[str, Dict] = {}
    for name, ph in traffic["phases"].items():
        if ph["n_msgs"] == 0:
            continue
        if name.startswith("pair_"):   # one flat collective, two entries
            merged = phases.setdefault("pair", dict(ph, inter=True))
            merged["max_rank_msgs"] = max(merged["max_rank_msgs"],
                                          ph["max_rank_msgs"])
            continue
        phases[name] = ph

    mesh = mesh_for(topo)
    stage = input_stager(topo)
    spec = P("node", "proc")
    walls: List[Dict[str, object]] = []
    for name, ph in phases.items():
        axis, n_slots = _axis_slots(name, topo)
        pad = int(ph["pad"])

        def per_device(x, axis=axis):
            return jax.lax.all_to_all(x.reshape(-1), axis, 0, 0,
                                      tiled=True).reshape(x.shape)

        smapped = shard_map(per_device, mesh=mesh, in_specs=(spec,),
                            out_specs=spec, check_vma=False)
        f = jax.jit(smapped)
        host = np.random.default_rng(0).standard_normal(
            (topo.n_nodes, topo.ppn, n_slots * pad)).astype(np.float32)
        x = jnp.asarray(host) if stage is None else stage(host)
        fetch_mesh_array(f(x))            # warm-up: trace + compile
        wall = _best_of(lambda: fetch_mesh_array(f(x)), repeats)
        walls.append({
            "phase": name,
            "inter": bool(ph["inter"]),
            "axis": "x".join(axis) if isinstance(axis, tuple) else axis,
            "n_slots": int(n_slots),
            "pad": pad,
            # bottleneck-rank charging, matching postal_phase_time
            "n_msgs": int(ph["max_rank_msgs"]),
            "nbytes": int(ph["max_rank_msgs"]) * pad * bytes_per_val,
            "seconds": float(wall),
        })
    return walls


def calibration_records(sweep: Dict[str, object]) -> List[Dict[str, object]]:
    """Flatten a :func:`scaling_sweep` payload into the wall records
    :meth:`PostalParams.calibrated` fits (one per measured phase)."""
    recs: List[Dict[str, object]] = []
    for point in sweep["points"]:
        for m in point["methods"].values():
            recs.extend(m["phase_walls"])
    return recs


def _build_matrix(cfg: Dict[str, object], n_rows: int, seed: int):
    mcfg = dict(cfg.get("matrix") or {"kind": "random"})
    if mcfg.get("kind") == "suitesparse_like":
        from repro.sparse import suitesparse_like
        return suitesparse_like.build(mcfg["name"], scale=int(mcfg["scale"]))
    from repro.sparse import random_fixed_nnz
    return random_fixed_nnz(n_rows, int(cfg.get("nnz_per_row", 8)), seed=seed)


def _build_partition(kind: str, a, n_procs: int):
    from repro.core.partition import make_partition
    if kind == "balanced":
        return make_partition("balanced", a.shape[0], n_procs,
                              a.indptr, a.indices)
    return make_partition(kind, a.shape[0], n_procs)


def measure_spmv(a, part, topo: Topology, method: str,
                 repeats: int = 3) -> Dict[str, object]:
    """Measured ``op @ x`` wall + per-phase exchange walls for one
    (matrix, partition, topology, method) point on the shardmap stack."""
    import repro.api as nap

    op = nap.operator(a, topo=topo, part=part, method=method,
                      backend="shardmap", cache=False)
    rng = np.random.default_rng(1)
    v = rng.standard_normal(a.shape[1])
    op @ v                                  # warm-up: compile + trace
    wall = _best_of(lambda: op @ v, repeats)
    compiled = op.executor.compiled
    plan = compiled.ms_plan if method == "multistep" else compiled.plan
    phase_walls = measure_phase_walls(plan, topo, repeats=repeats)
    comm_wall = sum(w["seconds"] for w in phase_walls)
    return {
        "wall_s": float(wall),
        "comm_wall_s": float(comm_wall),
        "comm_fraction": float(min(1.0, comm_wall / wall)) if wall else 0.0,
        "phase_walls": phase_walls,
    }


def scaling_sweep(config: Optional[Dict[str, object]] = None
                  ) -> Dict[str, object]:
    """Run the ladder described by ``config`` (see :data:`DEFAULT_CONFIG`).

    Ladder shapes needing more devices than the process addresses are
    skipped (recorded under ``"skipped"`` — no silent truncation).
    """
    import jax

    from repro.mesh.discover import discovery_report

    cfg = dict(DEFAULT_CONFIG)
    cfg.update(config or {})
    n_devices = int(jax.device_count())
    points: List[Dict[str, object]] = []
    skipped: List[Dict[str, object]] = []
    for nn, ppn in cfg["ladder"]:
        topo = Topology(n_nodes=int(nn), ppn=int(ppn))
        if topo.n_procs > n_devices:
            skipped.append({"n_nodes": nn, "ppn": ppn,
                            "reason": f"needs {topo.n_procs} devices, "
                                      f"have {n_devices}"})
            continue
        n_rows = (int(cfg["n_rows"]) * topo.n_procs
                  if cfg["mode"] == "weak" else int(cfg["n_rows"]))
        a = _build_matrix(cfg, n_rows, int(cfg["seed"]))
        if a.shape[0] < topo.n_procs:
            skipped.append({"n_nodes": nn, "ppn": ppn,
                            "reason": f"{a.shape[0]} rows < "
                                      f"{topo.n_procs} ranks"})
            continue
        part = _build_partition(str(cfg["partition"]), a, topo.n_procs)
        methods = {}
        for method in cfg["methods"]:
            methods[str(method)] = measure_spmv(a, part, topo, str(method),
                                                repeats=int(cfg["repeats"]))
        points.append({
            "n_nodes": topo.n_nodes, "ppn": topo.ppn,
            "n_rows": int(a.shape[0]), "nnz": int(a.nnz),
            "mode": cfg["mode"], "methods": methods,
        })
    return {"config": cfg, "discovery": discovery_report(),
            "points": points, "skipped": skipped}


def main(argv: List[str]) -> int:
    """Subprocess entry: force the device count for the LARGEST ladder
    shape before jax loads, sweep, write JSON."""
    if not argv or len(argv) > 2:
        print("usage: python -m repro.mesh.scaling config.json [out.json]",
              file=sys.stderr)
        return 2
    with open(argv[0]) as f:
        cfg = json.load(f)
    ladder = cfg.get("ladder", DEFAULT_CONFIG["ladder"])
    need = max(int(nn) * int(ppn) for nn, ppn in ladder)
    import os
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={need}"
    out = scaling_sweep(cfg)
    payload = json.dumps(out, indent=2)
    if len(argv) == 2:
        with open(argv[1], "w") as f:
            f.write(payload)
    else:
        print(payload)
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
