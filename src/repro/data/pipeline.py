"""Deterministic synthetic token pipeline (host-shardable, prefetching).

Sequences are sampled from a fixed random *bigram* process, so the stream has
learnable structure: a model that trains correctly drives its loss from
~log(V) down toward the bigram entropy.  Every batch is a pure function of
``(seed, step, shard)`` — restart/elastic-rescale resume bit-exactly from the
data cursor in the checkpoint, with no data service to re-synchronise.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    seed: int = 0
    branch: int = 16      # candidate successors per token (entropy knob)

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab, 4096)         # bigram table over a vocab prefix
        self._v = v
        self.successors = rng.integers(0, v, size=(v, self.branch))

    def batch(self, step: int, batch_size: int, shard: int = 0,
              n_shards: int = 1) -> Dict[str, np.ndarray]:
        """Batch for ``step`` restricted to this host shard (deterministic)."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + shard)
        b = batch_size // n_shards
        toks = np.empty((b, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self._v, size=b)
        choices = rng.integers(0, self.branch, size=(b, self.seq_len))
        for t in range(self.seq_len):
            toks[:, t + 1] = self.successors[toks[:, t], choices[:, t]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def bigram_entropy(self) -> float:
        """Per-token entropy of the generating process (loss floor), nats."""
        ent = 0.0
        for row in self.successors:
            _, counts = np.unique(row, return_counts=True)
            p = counts / counts.sum()
            ent += -(p * np.log(p)).sum()
        return float(ent / len(self.successors))


def make_batch_iterator(ds: SyntheticLM, batch_size: int, *, start_step: int = 0,
                        shard: int = 0, n_shards: int = 1,
                        prefetch: int = 2) -> Iterator[Dict[str, np.ndarray]]:
    """Background-thread prefetching iterator (the host-side input pipeline)."""
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(ds.batch(step, batch_size, shard, n_shards), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
