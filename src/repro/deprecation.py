"""Warn-once deprecation helper for the pre-``NapOperator`` entry points.

The PR that introduced :mod:`repro.api` kept the old SpMV entry points
(``nap_spmv_shardmap``, ``standard_spmv_shardmap``, ``DistSpMV.run``) as
thin shims for one release.  Each shim warns exactly once per process,
so AMG loops calling a shim thousands of times are not flooded.  Note
Python's default filters hide ``DeprecationWarning`` outside ``__main__``
— run with ``-W default`` (or under pytest, which surfaces them) to see
the nudge from library code.  The migration table lives in
``src/repro/kernels/README.md``.
"""
from __future__ import annotations

import warnings

_WARNED: set = set()


def warn_once(old: str, new: str) -> None:
    """Emit one DeprecationWarning per process for entry point ``old``."""
    if old in _WARNED:
        return
    _WARNED.add(old)
    warnings.warn(
        f"{old} is deprecated and will be removed next release; use {new} "
        f"(migration table: src/repro/kernels/README.md)",
        DeprecationWarning, stacklevel=3)


def reset_warned() -> None:
    """Forget which shims already warned (test isolation only)."""
    _WARNED.clear()
