"""Version-compatibility shims over the installed jax (pinned 0.4.x here).

The repo targets the current jax API surface (``jax.shard_map``,
``jax.sharding.AxisType``, ``pltpu.CompilerParams``, dict-valued
``Compiled.cost_analysis``); the container pins jax 0.4.37 where those
spell differently.  Every call site routes through this module so the
difference lives in exactly one place.
"""
from __future__ import annotations

from typing import Any, Dict, Sequence

import jax

# -- shard_map: jax.shard_map (>=0.5) vs jax.experimental.shard_map ----------

def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check_vma=None):
    """``jax.shard_map`` with the new kwarg spelling, on either API.

    ``axis_names`` (manual axes) maps to the old ``auto`` complement;
    ``check_vma`` maps to the old ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm
    kwargs = {}
    if axis_names is not None:
        kwargs["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    if check_vma is not None:
        kwargs["check_rep"] = check_vma
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)

# -- Pallas TPU compiler params: CompilerParams vs TPUCompilerParams ---------
from jax.experimental.pallas import tpu as _pltpu

if hasattr(_pltpu, "CompilerParams"):
    tpu_compiler_params = _pltpu.CompilerParams
else:
    tpu_compiler_params = _pltpu.TPUCompilerParams


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              explicit: bool = False) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with ``axis_types`` only where the API has it."""
    if hasattr(jax.sharding, "AxisType"):
        kind = (jax.sharding.AxisType.Explicit if explicit
                else jax.sharding.AxisType.Auto)
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=(kind,) * len(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def axis_size(name) -> int:
    """``lax.axis_size`` (new) or the classic ``psum(1, name)`` spelling."""
    from jax import lax
    if hasattr(lax, "axis_size"):
        return lax.axis_size(name)
    return lax.psum(1, name)


def set_mesh(mesh: jax.sharding.Mesh):
    """``jax.set_mesh`` context where available, else the Mesh context."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def cost_analysis(compiled) -> Dict[str, Any]:
    """``Compiled.cost_analysis()`` normalised to a flat dict.

    jax 0.4.x returns a one-element list of dicts (per partition); newer
    versions return the dict directly, and some backends return None.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}
