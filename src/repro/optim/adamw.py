"""AdamW with cosine schedule, global-norm clipping, and optional 8-bit
moment states (block-quantized, error-free dequant-update-requant).

The 8-bit states cut optimizer memory from 8 to 2 bytes/param (+ one f32
scale per 256-block) — this is what lets llama3-405b train on a SINGLE
256-chip pod (see EXPERIMENTS.md §Dry-run memory table); fp32 states need
the 2-pod mesh.  Master weights stay fp32 whenever the model dtype is lower.

Pure functions over pytrees; shard-agnostic (specs are applied by launch/).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any
BLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    state_dtype: str = "float32"     # "float32" | "int8"
    master_fp32: bool = True         # keep fp32 master copies of bf16 params


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree: Pytree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


# ---------------------------------------------------------------------------
# 8-bit block quantization for moment states
#
# Blocks run along the LAST axis only and the array shape is preserved:
# flattening a sharded [L, d, ff] tensor to 1-D forces GSPMD to all-gather
# the whole thing (observed as full fp32 copies of llama's 405B stacked
# weights — 4.8 TiB of temps per device).  Shape-preserving last-axis blocks
# keep every reshape sharding-compatible; scales get the same leading-dim
# sharding as the state itself.
# ---------------------------------------------------------------------------

SHARD_HINT = 16   # mesh axes are 16-wide; blocks should tile 1/16 shards


def _block_of(n: int) -> int:
    """Largest block <= 4096 dividing n whose block COUNT is a multiple of
    SHARD_HINT — then the blocked reshape tiles each 1/16 shard exactly and
    stays sharding-compatible (e.g. llama head 128256 -> b=501, nb=256)."""
    best = 0
    for b in range(1, min(n, 4096) + 1):
        if n % b == 0 and (n // b) % SHARD_HINT == 0:
            best = b
    if best:
        return best
    for b in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if n % b == 0:
            return b
    return 1


def _scale_shape(shape) -> tuple:
    if not shape:
        return (1,)
    b = _block_of(shape[-1])
    return tuple(shape[:-1]) + (shape[-1] // b,)


def _q8_zeros(shape) -> Dict:
    return {"q": jnp.zeros(shape, jnp.int8),
            "s": jnp.zeros(_scale_shape(shape), jnp.float32)}


def _q8_dequant(st: Dict) -> jnp.ndarray:
    shape = st["q"].shape
    if not shape:
        return st["q"].astype(jnp.float32) * st["s"][0]
    nb = st["s"].shape[-1]
    b = shape[-1] // nb
    blocks = st["q"].astype(jnp.float32).reshape(*shape[:-1], nb, b)
    return (blocks * st["s"][..., None]).reshape(shape)


def _q8_quant(x: jnp.ndarray) -> Dict:
    shape = x.shape
    xf = x.astype(jnp.float32)
    if not shape:
        s = jnp.maximum(jnp.abs(xf), 1e-30) / 127.0
        return {"q": jnp.round(xf / s).astype(jnp.int8), "s": s[None]}
    b = _block_of(shape[-1])
    nb = shape[-1] // b
    blocks = xf.reshape(*shape[:-1], nb, b)
    scale = jnp.maximum(jnp.abs(blocks).max(axis=-1), 1e-30) / 127.0
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127)
    return {"q": q.reshape(shape).astype(jnp.int8), "s": scale}


# v (second moment) needs ~10 orders of dynamic range: linear absmax
# quantization collapses small entries to 0 and m/(sqrt(0)+eps) explodes.
# Quantize v in the LOG domain (per-block min/step), the int8-Adam trick.
_LOG_FLOOR = -46.0   # log(1e-20)


def _q8l_zeros(shape) -> Dict:
    ss = _scale_shape(shape)
    return {"q": jnp.full(shape, -127, jnp.int8),
            "lo": jnp.full(ss, _LOG_FLOOR, jnp.float32),
            "st": jnp.zeros(ss, jnp.float32)}


def _q8l_dequant(st: Dict) -> jnp.ndarray:
    shape = st["q"].shape
    if not shape:
        lv = st["lo"][0] + (st["q"].astype(jnp.float32) + 127.0) * st["st"][0]
        v = jnp.exp(lv)
        return jnp.where(v <= jnp.exp(_LOG_FLOOR) * 1.5, 0.0, v)
    nb = st["lo"].shape[-1]
    b = shape[-1] // nb
    qf = st["q"].astype(jnp.float32).reshape(*shape[:-1], nb, b) + 127.0
    lv = st["lo"][..., None] + qf * st["st"][..., None]
    v = jnp.exp(lv).reshape(shape)
    return jnp.where(v <= jnp.exp(_LOG_FLOOR) * 1.5, 0.0, v)


def _q8l_quant(x: jnp.ndarray) -> Dict:
    shape = x.shape
    xl = jnp.log(jnp.maximum(x.astype(jnp.float32), jnp.exp(_LOG_FLOOR)))
    if not shape:
        return {"q": jnp.zeros((), jnp.int8) - 127, "lo": xl[None],
                "st": jnp.zeros((1,), jnp.float32)}
    b = _block_of(shape[-1])
    nb = shape[-1] // b
    blocks = xl.reshape(*shape[:-1], nb, b)
    lo = blocks.min(axis=-1)
    stp = jnp.maximum((blocks.max(axis=-1) - lo) / 254.0, 1e-12)
    q = jnp.clip(jnp.round((blocks - lo[..., None]) / stp[..., None]) - 127,
                 -127, 127)
    return {"q": q.reshape(shape).astype(jnp.int8), "lo": lo, "st": stp}


# ---------------------------------------------------------------------------
# init / update
# ---------------------------------------------------------------------------

def adamw_init(params: Pytree, cfg: AdamWConfig) -> Dict:
    if cfg.state_dtype == "int8":
        m_zeros = lambda p: _q8_zeros(p.shape)
        v_zeros = lambda p: _q8l_zeros(p.shape)
    else:
        m_zeros = v_zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(m_zeros, params),
        "v": jax.tree.map(v_zeros, params),
    }
    # master copies only help when params are lower precision; for fp32
    # params `astype` would ALIAS the same buffers (and donating params +
    # opt_state together then double-donates).
    if cfg.master_fp32 and any(l.dtype != jnp.float32
                               for l in jax.tree.leaves(params)):
        state["master"] = jax.tree.map(
            lambda p: jnp.asarray(p, jnp.float32) + 0.0
            if p.dtype == jnp.float32 else p.astype(jnp.float32), params)
    return state


def adamw_update(grads: Pytree, params: Pytree, state: Dict,
                 cfg: AdamWConfig) -> Tuple[Pytree, Dict]:
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    q8 = cfg.state_dtype == "int8"

    def leaf_update(g, p, m, v, master):
        g = g.astype(jnp.float32) * scale
        m_f = _q8_dequant(m) if q8 else m
        v_f = _q8l_dequant(v) if q8 else v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        mhat = m_f / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v_f / (1 - cfg.b2 ** step.astype(jnp.float32))
        base = master if master is not None else p.astype(jnp.float32)
        new = base - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                           + cfg.weight_decay * base)
        m_out = _q8_quant(m_f) if q8 else m_f
        v_out = _q8l_quant(v_f) if q8 else v_f
        return new.astype(p.dtype), m_out, v_out, (new if master is not None
                                                   else None)

    masters = state.get("master")
    flat_g, treedef = jax.tree.flatten(grads)
    flat_p = jax.tree.leaves(params)
    is_q8_leaf = (lambda x: isinstance(x, dict) and set(x) == {"q", "s"}) \
        if q8 else None
    flat_m = treedef.flatten_up_to(state["m"]) if q8 else jax.tree.leaves(state["m"])
    flat_v = treedef.flatten_up_to(state["v"]) if q8 else jax.tree.leaves(state["v"])
    flat_master = (jax.tree.leaves(masters) if masters is not None
                   else [None] * len(flat_g))

    outs = [leaf_update(g, p, m, v, mm) for g, p, m, v, mm in
            zip(flat_g, flat_p, flat_m, flat_v, flat_master)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_state = {
        "step": step,
        "m": jax.tree.unflatten(treedef, [o[1] for o in outs]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in outs]),
    }
    if masters is not None:
        new_state["master"] = jax.tree.unflatten(treedef, [o[3] for o in outs])
    return new_params, new_state
