from repro.sparse.csr import CSR
from repro.sparse.bsr import BSR
from repro.sparse.ell import ELL, stack_ell
from repro.sparse.generators import (linear_elasticity_2d, poisson_2d,
                                     random_fixed_nnz, rotated_anisotropic_2d)

__all__ = ["CSR", "BSR", "ELL", "stack_ell", "linear_elasticity_2d",
           "poisson_2d", "random_fixed_nnz", "rotated_anisotropic_2d"]
