"""Offline surrogates for the SuiteSparse matrices of Figs. 13-15.

The collection is not downloadable in this container, so each of the 13
matrices used by the paper is replaced by a synthetic matrix matching its
published row count, nnz, and *structure class* (banded stencil / power-law
graph / nearly-dense row blocks).  Benchmarks label them ``<name>-like``.
Statistics from the SuiteSparse collection index (public metadata).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.sparse.csr import CSR
from repro.sparse.generators import random_fixed_nnz


@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    name: str
    n: int            # rows (scaled-down honest surrogate, see scale())
    nnz_per_row: int  # average
    family: str       # "stencil" | "graph" | "rowblock"


# The 13 largest real SuiteSparse matrices the paper uses (metadata from the
# public collection index; row counts here are divided by `scale` at build
# time so laptop-scale tests stay tractable — the *shape* of the
# communication pattern is what the experiments exercise).
SPECS: List[MatrixSpec] = [
    MatrixSpec("nlpkkt240", 27_993_600, 28, "stencil"),
    MatrixSpec("nlpkkt200", 16_240_000, 27, "stencil"),
    MatrixSpec("nlpkkt160", 8_345_600, 27, "stencil"),
    MatrixSpec("ML_Geer", 1_504_002, 73, "rowblock"),
    MatrixSpec("Flan_1565", 1_564_794, 75, "stencil"),
    MatrixSpec("Cube_Coup_dt0", 2_164_760, 59, "stencil"),
    MatrixSpec("CurlCurl_4", 2_380_515, 11, "stencil"),
    MatrixSpec("dielFilterV3real", 1_102_824, 81, "rowblock"),
    MatrixSpec("StocF-1465", 1_465_137, 14, "stencil"),
    MatrixSpec("audikw_1", 943_695, 82, "rowblock"),
    MatrixSpec("Serena", 1_391_349, 46, "stencil"),
    MatrixSpec("Geo_1438", 1_437_960, 44, "stencil"),
    MatrixSpec("Hook_1498", 1_498_023, 41, "stencil"),
]

BY_NAME: Dict[str, MatrixSpec] = {s.name: s for s in SPECS}


def _banded(n: int, nnz_per_row: int, seed: int) -> CSR:
    """Symmetric banded pattern: diagonal + random offsets within a band
    ~ 3D-stencil reordered (what nlpkkt/Flan/Serena look like)."""
    rng = np.random.default_rng(seed)
    band = max(8, int(np.sqrt(n)))
    k = nnz_per_row
    offs = np.unique(np.concatenate([
        [0], rng.integers(1, band, size=2 * k)]))[: k // 2 + 1]
    rows, cols, vals = [], [], []
    idx = np.arange(n)
    for o in offs:
        r = idx[: n - o]
        rows += [r, r + o]
        cols += [r + o, r]
        v = rng.uniform(-1, 1, size=r.size)
        vals += [v, v]
    return CSR.from_coo(np.concatenate(rows), np.concatenate(cols),
                        np.concatenate(vals), (n, n))


def _rowblock(n: int, nnz_per_row: int, seed: int) -> CSR:
    """A few nearly-dense row blocks + banded background (audikw/dielFilter
    style; this is the pattern that motivates the paper's strided partition)."""
    rng = np.random.default_rng(seed)
    base = _banded(n, max(4, nnz_per_row // 2), seed)
    rows, cols, vals = base.to_coo()
    n_dense = max(1, n // 1000)
    dense_rows = rng.choice(n, size=n_dense, replace=False)
    width = min(n, nnz_per_row * 50)
    extra_r, extra_c = [], []
    for dr in dense_rows:
        c = rng.choice(n, size=width, replace=False)
        extra_r.append(np.full(width, dr))
        extra_c.append(c)
    er = np.concatenate(extra_r)
    ec = np.concatenate(extra_c)
    ev = rng.uniform(-1, 1, size=er.size)
    return CSR.from_coo(np.concatenate([rows, er, ec]),
                        np.concatenate([cols, ec, er]),
                        np.concatenate([vals, ev, ev]), (n, n))


def build(name: str, scale: int = 1024, seed: int = 0) -> CSR:
    """Construct the ``name``-like surrogate at ``n = spec.n // scale`` rows."""
    spec = BY_NAME[name]
    n = max(256, spec.n // scale)
    if spec.family == "rowblock":
        return _rowblock(n, spec.nnz_per_row, seed)
    if spec.family == "graph":
        return random_fixed_nnz(n, spec.nnz_per_row, seed, symmetric_pattern=True)
    return _banded(n, spec.nnz_per_row, seed)
