"""Block-sparse-row (BSR) container — the MXU-friendly local SpMV format.

The paper's ``local_spmv`` uses MKL/Eigen scalar CSR kernels; scalar row
kernels are hostile to the TPU's 128x128 MXU and (8, 128) VREG tiling
(DESIGN.md §2).  The TPU adaptation stores dense (bm x bn) blocks so each
block multiply is one MXU-shaped matmul; the Pallas kernel in
``kernels/bsr_spmv`` consumes exactly this layout.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.sparse.csr import CSR


@dataclasses.dataclass
class BSR:
    """Blocks of shape (bm, bn); block row i holds blocks
    ``data[indptr[i]:indptr[i+1]]`` at block columns ``indices[...]``."""

    indptr: np.ndarray    # int32 [n_brows + 1]
    indices: np.ndarray   # int32 [n_blocks]
    data: np.ndarray      # float32 [n_blocks, bm, bn]
    shape: Tuple[int, int]  # logical (padded) element shape

    @property
    def block_shape(self) -> Tuple[int, int]:
        return self.data.shape[1], self.data.shape[2]

    @property
    def n_brows(self) -> int:
        return len(self.indptr) - 1

    @property
    def n_blocks(self) -> int:
        return int(self.indices.size)

    @property
    def density(self) -> float:
        bm, bn = self.block_shape
        total = (self.shape[0] // bm) * (self.shape[1] // bn)
        return self.n_blocks / max(total, 1)

    def matvec(self, v: np.ndarray) -> np.ndarray:
        """Dense-block oracle (numpy)."""
        bm, bn = self.block_shape
        out = np.zeros(self.shape[0], dtype=np.result_type(self.data, v))
        vb = v.reshape(-1, bn)
        for i in range(self.n_brows):
            acc = np.zeros(bm, dtype=out.dtype)
            for k in range(self.indptr[i], self.indptr[i + 1]):
                acc += self.data[k] @ vb[self.indices[k]]
            out[i * bm:(i + 1) * bm] = acc
        return out

    def to_dense(self) -> np.ndarray:
        bm, bn = self.block_shape
        out = np.zeros(self.shape, dtype=self.data.dtype)
        for i in range(self.n_brows):
            for k in range(self.indptr[i], self.indptr[i + 1]):
                j = self.indices[k]
                out[i * bm:(i + 1) * bm, j * bn:(j + 1) * bn] = self.data[k]
        return out

    @staticmethod
    def from_csr(a: CSR, bm: int = 128, bn: int = 128,
                 dtype=np.float32) -> "BSR":
        """Convert CSR -> BSR, zero-padding the element shape up to the block
        grid.  Only blocks containing at least one nonzero are stored."""
        n_rows, n_cols = a.shape
        nbr = -(-n_rows // bm)
        nbc = -(-n_cols // bn)
        rows, cols, vals = a.to_coo()
        return _bsr_from_coo(rows, cols, vals, nbr, nbc, bm, bn, dtype)

    @staticmethod
    def from_coo(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                 shape: Tuple[int, int], bm: int = 128, bn: int = 128,
                 dtype=np.float32) -> "BSR":
        """COO (element indices) -> BSR, zero-padding up to the block grid.
        Duplicate entries are summed.  Fully vectorised — this is the
        conversion path for every rank-local block of the distributed SpMV,
        so it must scale past 10^7 nnz without Python-level loops."""
        nbr = -(-shape[0] // bm)
        nbc = -(-shape[1] // bn)
        return _bsr_from_coo(np.asarray(rows, np.int64), np.asarray(cols, np.int64),
                             np.asarray(vals), nbr, nbc, bm, bn, dtype)

    def padded_uniform(self, kmax: int = 0) -> Tuple[np.ndarray, np.ndarray, int]:
        """Pad every block row to the max blocks/row: returns
        (block_cols [n_brows, kmax] int32 with -1 pad,
         blocks [n_brows, kmax, bm, bn], kmax).  This is the static layout
        the Pallas kernel consumes (grid = (n_brows, kmax)).  A larger
        ``kmax`` may be forced to align layouts across ranks."""
        counts = np.diff(self.indptr)
        kmax = max(kmax, 1, int(counts.max()) if counts.size else 0)
        bm, bn = self.block_shape
        brow = np.repeat(np.arange(self.n_brows), counts)
        slot = np.arange(self.n_blocks) - np.repeat(self.indptr[:-1], counts)
        cols = np.full((self.n_brows, kmax), -1, dtype=np.int32)
        blocks = np.zeros((self.n_brows, kmax, bm, bn), dtype=self.data.dtype)
        cols[brow, slot] = self.indices
        blocks[brow, slot] = self.data
        return cols, blocks, kmax


def _bsr_from_coo(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                  nbr: int, nbc: int, bm: int, bn: int, dtype) -> BSR:
    """Shared vectorised COO -> BSR assembly (block grid of nbr x nbc)."""
    br, bc = rows // bm, cols // bn
    key = br * nbc + bc
    order = np.argsort(key, kind="stable")
    rows, cols, vals, key = rows[order], cols[order], vals[order], key[order]
    ukey, start = np.unique(key, return_index=True)
    counts = np.diff(np.append(start, rows.size))
    block_id = np.repeat(np.arange(ukey.size), counts)
    data = np.zeros((ukey.size, bm, bn), dtype=dtype)
    np.add.at(data, (block_id, rows % bm, cols % bn), vals.astype(dtype))
    ubr = (ukey // nbc).astype(np.int32)
    ubc = (ukey % nbc).astype(np.int32)
    indptr = np.zeros(nbr + 1, dtype=np.int32)
    np.add.at(indptr, ubr + 1, 1)
    indptr = np.cumsum(indptr).astype(np.int32)
    return BSR(indptr=indptr, indices=ubc, data=data,
               shape=(nbr * bm, nbc * bn))
