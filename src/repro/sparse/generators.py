"""Sparse matrix generators for the paper's experiments (Sec. 5).

* :func:`rotated_anisotropic_2d` — the structured AMG test problem: 9-point
  FE discretization of  -div(Q diag(1, eps) Q^T grad u)  on a regular grid,
  Q a rotation by theta (the paper's "2D rotated anisotropic").
* :func:`linear_elasticity_2d` — Q1 plane-stress linear elasticity on a
  regular grid, 2 dofs per node (the paper's unstructured-flavoured problem).
* :func:`random_fixed_nnz` — random matrices with a constant number of
  non-zeros per row (Figs. 11-12).
* :mod:`suitesparse_like` generates the Fig. 13-15 surrogates.
"""
from __future__ import annotations

import numpy as np

from repro.sparse.csr import CSR


def poisson_2d(n: int) -> CSR:
    """Standard 5-point Laplacian on an n x n grid (helper/oracle)."""
    return rotated_anisotropic_2d(n, eps=1.0, theta=0.0, stencil="fd")


def _stencil_matrix(n: int, offsets, weights) -> CSR:
    """Assemble an n*n grid operator from a list of ((di, dj), w) entries."""
    rows, cols, vals = [], [], []
    idx = np.arange(n * n).reshape(n, n)
    for (di, dj), w in zip(offsets, weights):
        if w == 0.0:
            continue
        si = slice(max(0, -di), n - max(0, di))
        sj = slice(max(0, -dj), n - max(0, dj))
        ti = slice(max(0, di), n + min(0, di))
        tj = slice(max(0, dj), n + min(0, dj))
        r = idx[ti, tj].reshape(-1)
        c = idx[si, sj].reshape(-1)
        rows.append(r)
        cols.append(c)
        vals.append(np.full(r.size, w))
    return CSR.from_coo(np.concatenate(rows), np.concatenate(cols),
                        np.concatenate(vals), (n * n, n * n))


def rotated_anisotropic_2d(n: int, eps: float = 0.001,
                           theta: float = np.pi / 6.0,
                           stencil: str = "fe") -> CSR:
    """-div(Q diag(1, eps) Q^T grad u) on an n x n grid.

    ``stencil="fe"`` is the bilinear FE 9-point stencil (PyAMG's
    ``diffusion_stencil_2d`` convention); ``"fd"`` is the 5/9-point FD one.
    """
    c, s = np.cos(theta), np.sin(theta)
    cxx = c * c + eps * s * s
    cyy = eps * c * c + s * s
    cxy = (1.0 - eps) * c * s  # half the mixed coefficient

    if stencil == "fd":
        off = [(0, 0), (0, 1), (0, -1), (1, 0), (-1, 0), (1, 1), (-1, -1), (1, -1), (-1, 1)]
        w = [2 * cxx + 2 * cyy, -cxx, -cxx, -cyy, -cyy,
             -cxy / 2, -cxy / 2, cxy / 2, cxy / 2]
        return _stencil_matrix(n, off, w)

    # bilinear FE stencil (3x3), PyAMG form
    a = (2.0 / 3.0) * (cxx + cyy)        # NW/NE/SW/SE contributions build below
    st = np.empty((3, 3))
    st[0, 0] = -cxx / 6 - cyy / 6 - cxy / 2   # NW  (di=+1, dj=-1)
    st[0, 1] = cyy / 3 - 2 * cxx / 3          # N
    st[0, 2] = -cxx / 6 - cyy / 6 + cxy / 2   # NE
    st[1, 0] = cxx / 3 - 2 * cyy / 3          # W
    st[1, 1] = 4.0 / 3.0 * (cxx + cyy)        # C
    st[1, 2] = cxx / 3 - 2 * cyy / 3          # E
    st[2, 0] = -cxx / 6 - cyy / 6 + cxy / 2   # SW
    st[2, 1] = cyy / 3 - 2 * cxx / 3          # S
    st[2, 2] = -cxx / 6 - cyy / 6 - cxy / 2   # SE
    offsets, weights = [], []
    for di in (-1, 0, 1):
        for dj in (-1, 0, 1):
            offsets.append((di, dj))
            weights.append(st[di + 1, dj + 1])
    return _stencil_matrix(n, offsets, weights)


def linear_elasticity_2d(n: int, E: float = 1e5, nu: float = 0.3) -> CSR:
    """Q1 plane-stress linear elasticity on an n x n node grid (2 dofs/node).

    Element stiffness assembled exactly (4-node bilinear quad, unit square
    elements, 2x2 Gauss quadrature); global matrix is block 2x2 per node pair.
    """
    # --- element stiffness (8x8), plane stress ------------------------------
    D = (E / (1.0 - nu * nu)) * np.array([
        [1.0, nu, 0.0], [nu, 1.0, 0.0], [0.0, 0.0, (1.0 - nu) / 2.0]])
    gp = np.array([-1.0, 1.0]) / np.sqrt(3.0)
    ke = np.zeros((8, 8))
    for xi in gp:
        for eta in gp:
            dN = 0.25 * np.array([
                [-(1 - eta), (1 - eta), (1 + eta), -(1 + eta)],
                [-(1 - xi), -(1 + xi), (1 + xi), (1 - xi)]])
            J = dN @ np.array([[0, 0], [1, 0], [1, 1], [0, 1]], dtype=float)
            dNdx = np.linalg.solve(J, dN)
            B = np.zeros((3, 8))
            B[0, 0::2] = dNdx[0]
            B[1, 1::2] = dNdx[1]
            B[2, 0::2] = dNdx[1]
            B[2, 1::2] = dNdx[0]
            ke += B.T @ D @ B * np.linalg.det(J)

    # --- assembly ------------------------------------------------------------
    nodes = np.arange(n * n).reshape(n, n)
    ne = n - 1
    e00 = nodes[:-1, :-1].reshape(-1)
    elems = np.stack([e00, e00 + 1, e00 + n + 1, e00 + n], axis=1)  # ccw quad
    dof = np.empty((ne * ne, 8), dtype=np.int64)
    dof[:, 0::2] = 2 * elems
    dof[:, 1::2] = 2 * elems + 1
    rows = np.repeat(dof, 8, axis=1).reshape(-1)
    cols = np.tile(dof, (1, 8)).reshape(-1)
    vals = np.tile(ke.reshape(-1), ne * ne)
    a = CSR.from_coo(rows, cols, vals, (2 * n * n, 2 * n * n))
    # pin the boundary (x = 0 edge) to make it SPD-regular
    fixed = np.concatenate([2 * nodes[0], 2 * nodes[0] + 1])
    return _apply_dirichlet(a, fixed)


def _apply_dirichlet(a: CSR, fixed: np.ndarray) -> CSR:
    rows, cols, vals = a.to_coo()
    fixed_set = np.zeros(a.shape[0], dtype=bool)
    fixed_set[fixed] = True
    keep = ~(fixed_set[rows] | fixed_set[cols])
    rows, cols, vals = rows[keep], cols[keep], vals[keep]
    rows = np.concatenate([rows, fixed])
    cols = np.concatenate([cols, fixed])
    vals = np.concatenate([vals, np.ones(fixed.size)])
    return CSR.from_coo(rows, cols, vals, a.shape)


def random_fixed_nnz(n_rows: int, nnz_per_row: int, seed: int = 0,
                     symmetric_pattern: bool = False) -> CSR:
    """Random matrix, constant nnz/row, values U(-1, 1), diagonal included
    (the paper's Figs. 11-12 family)."""
    rng = np.random.default_rng(seed)
    cols = rng.integers(0, n_rows, size=(n_rows, nnz_per_row))
    cols[:, 0] = np.arange(n_rows)  # keep a diagonal
    rows = np.repeat(np.arange(n_rows), nnz_per_row)
    vals = rng.uniform(-1.0, 1.0, size=rows.size)
    a = CSR.from_coo(rows, cols.reshape(-1), vals, (n_rows, n_rows))
    if symmetric_pattern:
        at = a.transpose()
        rows1, cols1, vals1 = a.to_coo()
        rows2, cols2, vals2 = at.to_coo()
        a = CSR.from_coo(np.concatenate([rows1, rows2]),
                         np.concatenate([cols1, cols2]),
                         np.concatenate([vals1, vals2]) * 0.5,
                         a.shape)
    return a
