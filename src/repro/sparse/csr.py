"""Minimal CSR/COO containers used across the framework.

Kept dependency-light: numpy only in the container itself (scipy is used in
tests/benchmarks as an independent oracle, never in the library path).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


def expand_positions(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """``concat(starts[i] + arange(counts[i]))`` without a Python loop —
    the index-ramp kernel behind CSR row expansion (``csr_matmul``, the
    SpGEMM simulators and compile step, bulk row-value gathers)."""
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    ends = np.cumsum(counts)
    intra = np.arange(total) - np.repeat(ends - counts, counts)
    return np.repeat(starts, counts) + intra


@dataclasses.dataclass
class CSR:
    indptr: np.ndarray   # int64 [n_rows + 1]
    indices: np.ndarray  # int64 [nnz]
    data: np.ndarray     # float64 [nnz]
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.indices.size)

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    def row(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        sl = slice(self.indptr[i], self.indptr[i + 1])
        return self.indices[sl], self.data[sl]

    def matvec(self, v: np.ndarray) -> np.ndarray:
        out = np.zeros(self.shape[0], dtype=np.result_type(self.data, v))
        np.add.at(out, np.repeat(np.arange(self.shape[0]), np.diff(self.indptr)),
                  self.data * v[self.indices])
        return out

    def to_coo(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        rows = np.repeat(np.arange(self.shape[0]), np.diff(self.indptr))
        return rows, self.indices.copy(), self.data.copy()

    def transpose(self) -> "CSR":
        rows, cols, vals = self.to_coo()
        return CSR.from_coo(cols, rows, vals, (self.shape[1], self.shape[0]))

    def select_rows(self, rows: np.ndarray) -> "CSR":
        counts = np.diff(self.indptr)[rows]
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        # vectorised gather of each row's nnz range: start offsets repeated
        # per-element plus an intra-row ramp (no per-row Python loop)
        starts = self.indptr[rows]
        take = np.repeat(starts - indptr[:-1], counts) + np.arange(indptr[-1])
        return CSR(indptr=indptr, indices=self.indices[take], data=self.data[take],
                   shape=(int(rows.size), self.shape[1]))

    @staticmethod
    def from_coo(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                 shape: Tuple[int, int], sum_duplicates: bool = True,
                 assume_sorted: bool = False) -> "CSR":
        """``assume_sorted`` skips the row-major sort for input already in
        row-major order (only meaningful with sum_duplicates=False; asserted
        on the row grouping, which indptr construction relies on)."""
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        vals = np.asarray(vals, dtype=np.float64)
        if sum_duplicates and rows.size:
            key = rows * shape[1] + cols
            order = np.argsort(key, kind="stable")
            key, rows, cols, vals = key[order], rows[order], cols[order], vals[order]
            uniq, start = np.unique(key, return_index=True)
            summed = np.add.reduceat(vals, start) if vals.size else vals
            rows, cols, vals = rows[start], cols[start], summed
        elif assume_sorted:
            assert rows.size < 2 or (np.diff(rows) >= 0).all()
        else:
            order = np.lexsort((cols, rows))
            rows, cols, vals = rows[order], cols[order], vals[order]
        indptr = np.zeros(shape[0] + 1, dtype=np.int64)
        np.add.at(indptr, rows + 1, 1)
        indptr = np.cumsum(indptr)
        return CSR(indptr=indptr.astype(np.int64), indices=cols, data=vals, shape=shape)

    @staticmethod
    def from_dense(a: np.ndarray) -> "CSR":
        rows, cols = np.nonzero(a)
        return CSR.from_coo(rows, cols, a[rows, cols], a.shape, sum_duplicates=False)

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape)
        rows, cols, vals = self.to_coo()
        out[rows, cols] = vals
        return out
