"""ELLPACK (padded-row) container — the VPU-friendly local SpMV format.

BSR (``sparse/bsr.py``) wins when nonzeros cluster into dense (bm, bn)
tiles; on block-hostile structures (random matrices at <= 12 nnz/row,
graph Laplacians, AMG coarse levels) densifying blocks inflates both the
bytes moved and the padded FLOPs by 1/fill.  ELL pads every *row* to the
matrix's max nnz/row instead: two [n_rows, kmax] arrays (column ids and
values), a layout whose padding overhead is ``kmax / mean_nnz_row`` — tiny
whenever the row-length distribution is flat, which is exactly the regime
where blocks are hostile.

The Pallas kernel in ``kernels/ell_spmv`` consumes this layout directly:
each row-tile does a vectorised gather of x rows by ``cols`` and a
multiply-accumulate over the kmax axis on the VPU (no MXU, no scatter).

Padding slots use ``cols == -1`` with ``vals == 0``; consumers clamp the
column to 0, so padding is mathematically inert against any finite x.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.sparse.csr import CSR


@dataclasses.dataclass
class ELL:
    """Row-padded sparse matrix: row i holds ``cols[i, :]`` / ``vals[i, :]``."""

    cols: np.ndarray   # int32 [n_rows, kmax], -1 = padding slot
    vals: np.ndarray   # float32 [n_rows, kmax], 0 on padding slots
    shape: Tuple[int, int]  # logical element shape (n_rows may exceed shape[0])

    @property
    def kmax(self) -> int:
        return int(self.cols.shape[1])

    @property
    def n_rows(self) -> int:
        return int(self.cols.shape[0])

    @property
    def nnz(self) -> int:
        return int((self.cols >= 0).sum())

    @property
    def fill(self) -> float:
        """Fraction of ELL slots holding real nonzeros (1 = no padding)."""
        return self.nnz / max(self.cols.size, 1)

    def matvec(self, v: np.ndarray) -> np.ndarray:
        """Dense gather oracle (numpy); v indexed by the stored column ids."""
        gathered = np.asarray(v)[np.maximum(self.cols, 0)]
        return (self.vals * np.where(self.cols >= 0, gathered, 0.0)).sum(axis=1)

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n_rows, self.shape[1]))
        r, k = np.nonzero(self.cols >= 0)
        out[r, self.cols[r, k]] += self.vals[r, k]
        return out

    @staticmethod
    def from_coo(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                 shape: Tuple[int, int], n_rows_pad: int = 0,
                 kmax: int = 0) -> "ELL":
        """COO -> ELL, fully vectorised (no per-row Python loops).

        ``n_rows_pad`` pads the row axis (extra all-padding rows); ``kmax``
        forces a wider slot axis than the data needs — both are used to
        align per-rank layouts across an SPMD mesh.
        """
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int64)
        vals = np.asarray(vals)
        n_rows = max(shape[0], n_rows_pad)
        order = np.argsort(rows, kind="stable")
        rows, cols, vals = rows[order], cols[order], vals[order]
        counts = np.bincount(rows, minlength=n_rows)
        kmax = max(kmax, 1, int(counts.max(initial=0)))
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        slot = np.arange(rows.size) - starts[rows]
        out_cols = np.full((n_rows, kmax), -1, dtype=np.int32)
        out_vals = np.zeros((n_rows, kmax), dtype=np.float32)
        out_cols[rows, slot] = cols.astype(np.int32)
        out_vals[rows, slot] = vals.astype(np.float32)
        return ELL(cols=out_cols, vals=out_vals, shape=shape)

    @staticmethod
    def from_csr(a: CSR, n_rows_pad: int = 0, kmax: int = 0) -> "ELL":
        rows, cols, vals = a.to_coo()
        return ELL.from_coo(rows, cols, vals, a.shape,
                            n_rows_pad=n_rows_pad, kmax=kmax)


def stack_ell(per_rank: List["ELL"],
              kmax: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray, int]:
    """Align ranks to one shared kmax and stack into the
    [n_procs, n_rows, kmax] cols/vals arrays the SPMD executor shards."""
    kmax = max(kmax or 1, max(e.kmax for e in per_rank))
    n_rows = max(e.n_rows for e in per_rank)
    cols = np.full((len(per_rank), n_rows, kmax), -1, dtype=np.int32)
    vals = np.zeros((len(per_rank), n_rows, kmax), dtype=np.float32)
    for r, e in enumerate(per_rank):
        cols[r, : e.n_rows, : e.kmax] = e.cols
        vals[r, : e.n_rows, : e.kmax] = e.vals
    return cols, vals, kmax
