"""One LinearOperator-style front-end over every NAPSpMV backend.

The paper's NAPSpMV is one kernel inside larger solvers — AMG cycles need
``A @ x`` *and* the restriction ``P.T @ r`` against node-aware
communication plans on every level.  This module is the single entry
point over the executor registry::

    import repro.api as nap

    op = nap.operator(a, topo=Topology(n_nodes=4, ppn=4))
    w  = op @ v          # forward SpMV (1-RHS or [n, nv] multi-RHS)
    z  = op.T @ v        # transpose SpMV, same compiled plan reversed
    op.stats(), op.cost(BLUE_WATERS), op.autotune_report()

**Rectangular operators.**  An operator is a genuine ``[m, n]`` linear
map over TWO partitions: ``row_part`` lays out the m output rows,
``col_part`` the n input entries.  The communication plan derives its
send/recv/gather maps from ``col_part`` (who owns the x values a rank
needs) and its output layout from ``row_part`` (who computes each row);
``op.T`` swaps the two through the same compiled plan.  ``part=`` stays
as the square-case sugar that sets both::

    p_op = nap.operator(p, topo=topo, row_part=fine, col_part=coarse)
    r    = p_op.T @ residual      # node-aware AMG restriction

**Operator algebra.**  ``@`` between operators is LAZY composition:
``R @ A @ P`` returns a :class:`ComposedOperator` that chains the
executors right-to-left with compatible-partition checking at compose
time, and rolls up per-stage ``.stats()`` / ``.cost()`` — the Galerkin
triple product applied as three node-aware SpMVs, never materialised
implicitly.  When the product will be applied many times,
``composed.materialize()`` collapses the chain through the node-aware
distributed SpGEMM (:mod:`repro.spgemm` — the same three-step exchange
carrying variable-length B-row blocks) into ONE concrete operator on
the outer partitions.

Backends resolve through the pluggable registry in
:mod:`repro.core.executors` — ``backend="shardmap"`` is the jitted SPMD
executor (Pallas local compute, zero-copy packed x), ``"simulate"`` the
exact float64 message-passing oracle; new backends (true-TPU Mosaic,
collective-permute overlap) register themselves without touching any call
site.  Compilation is lazy per backend *and* per direction: building an
operator costs one plan build; the forward program JITs on first
``op @ x`` and the transpose program only on first ``op.T @ x``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.cost_model import (LocalComputeParams, MachineParams,
                                   TPU_V5E_LOCAL)
from repro.core.executors import (OperatorSpec, available_executors,
                                  bind_executor, register_executor)
from repro.core.integrity import IntegrityError, MessageFault
from repro.core.partition import RowPartition, contiguous_partition
from repro.core.topology import Topology

__all__ = ["operator", "NapOperator", "ComposedOperator",
           "available_executors", "register_executor",
           "IntegrityError", "MessageFault"]


def operator(a, topo: Optional[Topology] = None,
             part: Optional[RowPartition] = None, *,
             row_part: Optional[RowPartition] = None,
             col_part: Optional[RowPartition] = None,
             method: str = "nap", backend: str = "shardmap",
             comm: Optional[str] = None, threshold: object = "auto",
             local_compute: str = "auto", mesh=None,
             pairing: str = "aligned",
             block_shape: Tuple[int, int] = (8, 128), nv_block: int = 128,
             interpret: bool = True, cache: bool = True,
             tuner: LocalComputeParams = TPU_V5E_LOCAL,
             integrity: str = "off",
             wire_dtype: str = "f32") -> "NapOperator":
    """Build a :class:`NapOperator` for ``a`` on a (topo, partitions) layout.

    Parameters
    ----------
    a : CSR
        Sparse ``[m, n]`` matrix — square or rectangular.
    topo : Topology, optional
        Machine shape.  ``None`` AUTODISCOVERS from the live runtime
        (:func:`repro.mesh.discover.discover_topology`): one "node" per
        jax process, ``ppn`` local devices — a plain single-device
        process discovers ``Topology(1, 1)``, bit-identical to the old
        declared default; after :func:`repro.mesh.launcher.attach` the
        operator spans the whole multi-process mesh.  Pass an explicit
        (n_nodes, ppn) to pin a layout (e.g. simulating a larger
        machine than the one running).
    part : RowPartition, optional
        Square-case sugar: sets ``row_part`` AND ``col_part`` to the same
        partition (requires ``m == n``; mutually exclusive with passing
        either of the two explicitly).
    row_part : RowPartition, optional
        Ownership of the m output rows; defaults to
        ``contiguous_partition(m, topo.n_procs)``.
    col_part : RowPartition, optional
        Ownership of the n input/x entries; defaults to ``row_part``
        when the matrix is square (the single-partition case, whatever
        layout ``row_part`` has), else to
        ``contiguous_partition(n, topo.n_procs)``.  Ranks may own zero
        entries (coarse AMG levels smaller than the machine).
    method : ``"nap"`` (Algorithms 2+3), ``"standard"`` (Algorithm 1) or
        ``"multistep"`` (the duplication-split node-aware exchange —
        see :mod:`repro.comm`).
    backend : ``"shardmap"`` (jitted SPMD) | ``"simulate"`` (exact numpy
        oracle) | any backend later added to the executor registry.
    comm : optional exchange-strategy override — ``"standard"`` |
        ``"nap"`` | ``"multistep"`` pin the strategy (taking precedence
        over ``method``), ``"auto"`` lets the comm autotuner
        (:func:`repro.comm.choose_comm`) pick one PER DIRECTION from the
        modeled injected inter-node bytes + postal time; when forward
        and transpose disagree, the operator holds a second executor for
        the transpose direction.  The verdict is merged into
        ``op.autotune_report()`` under ``comm``/``comm_resolved``/
        ``comm_transpose_resolved``.  ``None`` (default) follows
        ``method`` unchanged.
    threshold : duplication threshold for the multistep strategy
        (``"auto"`` or an int >= 1; ``d < threshold`` columns go direct).
    local_compute : shardmap local kernel — ``"auto"`` | ``"bsr"`` |
        ``"ell"`` | ``"coo"`` (see kernels/README.md).  The transpose
        direction autotunes independently over ell/coo (no transposed
        Pallas BSR kernel yet); see ``op.autotune_report()``.
    mesh : optional pre-built jax mesh with axes ("node", "proc");
        shardmap builds one lazily otherwise.
    pairing : inter-node slot pairing for the nap plan ("aligned" is the
        TPU all-to-all-natural choice and the only one the shardmap
        backend lowers; "balanced" is the paper's text rule, available on
        the simulate backend).
    integrity : ``"off"`` (default — the program is bit-for-bit the
        uninstrumented one) | ``"detect"`` (wire checksums over every
        exchange message + ABFT result verification per apply; a mismatch
        raises :class:`IntegrityError` with phase/message attribution) |
        ``"recover"`` (same checks, but a mismatch retries the apply from
        the retained packed refs — bit-identical to the fault-free run —
        and only raises when the mismatch persists).  Inspect with
        ``op.integrity_report()``; script deterministic faults with
        ``op.inject_fault(...)``.
    wire_dtype : wire payload encoding — ``"f32"`` (default; identity
        codec, bit-for-bit today's path) | ``"bf16"`` | ``"fp8_e4m3"``.
        Consumed by the ``backend="moe"`` dispatch executors
        (:mod:`repro.moe`): payloads are quantized at every wire
        crossing and accumulated at full width on receive, the modeled
        traffic/verdicts charge the narrow width, and integrity
        checksums run over the quantized words.  Other backends accept
        only ``"f32"`` (their programs never quantize).
    """
    m, n = a.shape
    if part is not None:
        if row_part is not None or col_part is not None:
            raise ValueError("pass either part= (square sugar) or "
                             "row_part=/col_part=, not both")
        if m != n:
            raise ValueError(
                f"part= is the square-case sugar (sets row AND col "
                f"partition); a is {a.shape} — pass row_part=/col_part=")
        row_part = col_part = part
    if topo is None:
        from repro.mesh.discover import discover_topology
        topo = discover_topology()
    if row_part is None:
        row_part = contiguous_partition(m, topo.n_procs)
    if col_part is None:
        col_part = (row_part if n == row_part.n_rows
                    else contiguous_partition(n, topo.n_procs))
    if row_part.n_rows != m or col_part.n_rows != n:
        raise ValueError(
            f"partition/matrix mismatch: a is {a.shape}, row_part has "
            f"{row_part.n_rows} rows, col_part {col_part.n_rows}")
    if backend == "shardmap" and pairing != "aligned":
        raise ValueError("the shardmap backend lowers pairing='aligned' "
                         "only (the all-to-all slot contract)")
    if integrity not in ("off", "detect", "recover"):
        raise ValueError(f"integrity must be off|detect|recover, "
                         f"got {integrity!r}")
    from repro.moe.wire import check_wire_dtype
    check_wire_dtype(wire_dtype)
    if wire_dtype != "f32" and backend != "moe":
        raise ValueError(
            f"wire_dtype={wire_dtype!r} is a moe-backend feature (the "
            f"quantized dispatch wire); backend={backend!r} programs "
            f"never quantize — pass wire_dtype='f32'")
    comm_report = None
    t_method = None
    if comm is not None:
        from repro.comm import COMM_CHOICES, choose_comm
        if comm not in COMM_CHOICES:
            raise ValueError(f"comm must be one of {COMM_CHOICES}, "
                             f"got {comm!r}")
        if comm == "auto":
            verdict = choose_comm(a.indptr, a.indices, row_part, topo,
                                  pairing=pairing, col_part=col_part,
                                  threshold=threshold, integrity=integrity)
            method = verdict["forward"]["chosen"]
            t_method = verdict["transpose"]["chosen"]
            comm_report = {
                "requested": "auto",
                "resolved": method,
                "transpose_resolved": t_method,
                "threshold": verdict["threshold"],
                "forward": verdict["forward"],
                "transpose": verdict["transpose"],
            }
        else:
            method = t_method = comm
            comm_report = {"requested": comm, "resolved": comm,
                           "transpose_resolved": comm}
    spec = OperatorSpec(method=method, backend=backend,
                        local_compute=local_compute, pairing=pairing,
                        block_shape=tuple(block_shape), nv_block=nv_block,
                        interpret=interpret, cache=cache, tuner=tuner,
                        integrity=integrity, threshold=threshold,
                        wire_dtype=wire_dtype)
    exec_ = bind_executor(backend, method, a, row_part, col_part, topo, spec,
                         mesh=mesh)
    t_exec = None
    if t_method is not None and t_method != method:
        # forward and transpose verdicts disagree: a dedicated executor
        # (own plan + programs) serves the transpose direction.
        t_spec = dataclasses.replace(spec, method=t_method)
        t_exec = bind_executor(backend, t_method, a, row_part, col_part,
                               topo, t_spec, mesh=mesh)
    return NapOperator(a=a, row_part=row_part, col_part=col_part, topo=topo,
                       spec=spec, executor=exec_,
                       transpose_executor=t_exec, comm_report=comm_report)


def _is_operator(x) -> bool:
    return isinstance(x, (NapOperator, ComposedOperator))


@dataclasses.dataclass
class NapOperator:
    """Distributed SpMV as a composable linear operator.

    ``op @ x`` / ``op(x)`` apply ``A``; ``op.T @ x`` applies ``A.T``
    through the SAME compiled communication plan with send/recv roles
    reversed.  ``x`` is a global ``[n]`` vector or ``[n, nv]``
    multivector (numpy or jax); the result is ``[m(, nv)]``.  ``op @ other_op``
    composes lazily into a :class:`ComposedOperator` instead of applying.
    """

    a: object
    row_part: RowPartition
    col_part: RowPartition
    topo: Topology
    spec: OperatorSpec
    executor: object
    # set when comm="auto" resolves the two directions to DIFFERENT
    # strategies: the transpose direction runs through its own executor
    transpose_executor: Optional[object] = None
    comm_report: Optional[dict] = None
    transposed: bool = False
    _parent: Optional["NapOperator"] = dataclasses.field(
        default=None, repr=False)

    # -- application / composition ----------------------------------------
    def __call__(self, x, donate: bool = False,
                 precision: Optional[str] = None) -> np.ndarray:
        """Apply the operator.

        ``donate=True`` lets XLA reuse the packed input buffer (shardmap
        backend; ignored by simulate).  ``precision`` pins the result
        dtype: ``"float32"`` / ``"float64"`` / None (backend native —
        float32 for shardmap, float64 for simulate).  The shardmap
        backend computes in float32 regardless; asking it for float64
        raises rather than implying precision it cannot deliver.
        """
        if precision not in (None, "float32", "float64"):
            raise ValueError(f"precision must be float32|float64, "
                             f"got {precision!r}")
        if precision == "float64" and self.spec.backend == "shardmap":
            raise NotImplementedError(
                "the shardmap backend computes in float32; use "
                "backend='simulate' for float64 results")
        if self.transposed:
            ex = (self.transpose_executor
                  if self.transpose_executor is not None else self.executor)
            apply = ex.transpose
        else:
            apply = self.executor.forward
        out = apply(x, donate=donate)
        if precision is not None:
            out = np.asarray(out, dtype=precision)
        return out

    def __matmul__(self, x):
        if _is_operator(x):
            return ComposedOperator.of(self, x)
        return self(x)

    def matvec(self, x) -> np.ndarray:
        return self(x)

    # -- structure ---------------------------------------------------------
    @property
    def shape(self) -> Tuple[int, int]:
        m, n = self.a.shape
        return (n, m) if self.transposed else (m, n)

    @property
    def range_part(self) -> RowPartition:
        """Partition laying out THIS view's output (shape[0] entries)."""
        return self.col_part if self.transposed else self.row_part

    @property
    def domain_part(self) -> RowPartition:
        """Partition laying out THIS view's operand (shape[1] entries)."""
        return self.row_part if self.transposed else self.col_part

    @property
    def method(self) -> str:
        return self.spec.method

    @property
    def backend(self) -> str:
        return self.spec.backend

    @property
    def local_compute(self) -> str:
        """Resolved local-compute format for THIS direction (the transpose
        direction autotunes independently over ell/coo — see
        ``autotune_report()["transpose_resolved"]``)."""
        if self.transposed:
            return getattr(self.executor, "transpose_local_compute",
                           getattr(self.executor, "local_compute", "unknown"))
        return getattr(self.executor, "local_compute", "unknown")

    @property
    def T(self) -> "NapOperator":
        """Transpose view sharing this operator's executor and compiled
        plan (``op.T.T is op``)."""
        if self._parent is not None:
            return self._parent
        return dataclasses.replace(self, transposed=not self.transposed,
                                   _parent=self)

    # -- hot value swap ----------------------------------------------------
    def swap_values(self, a_new) -> None:
        """Swap the matrix VALUES behind this operator without recompiling.

        ``a_new`` must have the exact sparsity structure of the current
        matrix (same shape, indptr, indices — always the UNtransposed
        orientation, even when called on a ``.T`` view: the transpose
        shares the executor and picks the new values up automatically).
        On the shardmap backend the compiled communication plan and both
        jitted direction programs are reused with ZERO retraces — value
        arrays are per-call jit arguments; verify with
        :meth:`trace_counts`.  The serve layer's plan cache keys on
        structure alone and leans on this for multi-tenant value updates.
        """
        self.executor.swap_values(a_new)
        if self.transpose_executor is not None:
            self.transpose_executor.swap_values(a_new)
        self.a = a_new
        if self._parent is not None:
            self._parent.a = a_new

    def trace_counts(self):
        """Per-direction program (re)trace counts — ``{"forward": n,
        "transpose": m}`` on shardmap (a direction appears once built),
        empty for backends that never trace.  Flat counts across a
        :meth:`swap_values` prove the hot-swap reused the compiled
        program."""
        counts = dict(self.executor.trace_counts())
        if self.transpose_executor is not None:
            counts.pop("transpose", None)
            counts.update(
                {k: v for k, v
                 in self.transpose_executor.trace_counts().items()
                 if k == "transpose"})
        return counts

    # -- integrity ---------------------------------------------------------
    def integrity_report(self):
        """Check/mismatch counters, scope attribution, per-node strikes
        and quarantine candidates (``{"mode": "off"}`` when the operator
        was built without integrity)."""
        rep = self.executor.integrity_report()
        if self.transpose_executor is not None:
            rep = dict(rep)
            rep["transpose_executor"] = \
                self.transpose_executor.integrity_report()
        return rep

    def inject_fault(self, phase: str, kind: str = "bitflip", *,
                     node: int = 0, proc: int = 0, slot: int = 0,
                     element: int = 0, bit: int = 30,
                     direction: Optional[str] = None) -> MessageFault:
        """Script ONE deterministic message fault for the next matching
        apply (requires ``integrity != "off"``; the fault fires once and
        replays exactly — see :class:`repro.api.MessageFault`).
        ``direction`` defaults to this view's own direction, so
        ``op.T.inject_fault(...)`` targets the transpose apply."""
        if direction is None:
            direction = "transpose" if self.transposed else "forward"
        fault = MessageFault(phase=phase, kind=kind, node=node, proc=proc,
                             slot=slot, element=element, bit=bit,
                             direction=direction)
        self.queue_fault(fault)
        return fault

    def queue_fault(self, fault: MessageFault) -> None:
        """Script a pre-built :class:`MessageFault` (see
        :meth:`inject_fault` for the keyword convenience)."""
        if (fault.direction == "transpose"
                and self.transpose_executor is not None):
            self.transpose_executor.queue_fault(fault)
        else:
            self.executor.queue_fault(fault)

    # -- introspection -----------------------------------------------------
    def stats(self):
        """Plan-level message statistics (+ padded traffic on shardmap)."""
        return self.executor.stats()

    def cost(self, machine: MachineParams):
        """Modeled communication time under a machine model (Eqs. 10-12)."""
        return self.executor.cost(machine)

    def autotune_report(self):
        """Local-compute format decision (chosen format, modeled times,
        per-rank stats) where the backend runs the adaptive engine —
        forward verdict at the top level, transpose verdict under
        ``"transpose"`` / ``"transpose_resolved"``.  When the operator
        was built with ``comm=``, the exchange-strategy verdict rides
        along under ``"comm"`` / ``"comm_resolved"`` /
        ``"comm_transpose_resolved"``."""
        rep = self.executor.autotune_report()
        if self.comm_report is None:
            return rep
        rep = dict(rep or {})
        rep["comm"] = self.comm_report
        rep["comm_resolved"] = self.comm_report["resolved"]
        rep["comm_transpose_resolved"] = \
            self.comm_report["transpose_resolved"]
        return rep

    def __repr__(self) -> str:
        t = ".T" if self.transposed else ""
        m, n = self.shape
        return (f"NapOperator{t}(shape=({m}, {n}), "
                f"method={self.spec.method!r}, backend={self.spec.backend!r}, "
                f"topo=({self.topo.n_nodes}x{self.topo.ppn}))")


@dataclasses.dataclass(frozen=True)
class ComposedOperator:
    """Lazy right-to-left chain of operators: ``(R @ A @ P) @ x`` runs
    ``P @ x`` first, then ``A``, then ``R`` — three node-aware SpMVs, the
    Galerkin product never materialised.

    Compose-time checking: adjacent shapes must chain
    (``left.shape[1] == right.shape[0]``) and the interface partitions
    must MATCH (``left.domain_part`` lays out the same entries as
    ``right.range_part``), so values flow stage to stage without a hidden
    host-side repartition.  ``.stats()`` / ``.cost()`` /
    ``.autotune_report()`` report per stage, with ``cost()["total"]``
    summing the chain (stages are sequential by data dependence).
    """

    factors: Tuple  # application order: factors[0] @ (... @ (factors[-1] @ x))

    @staticmethod
    def of(left, right) -> "ComposedOperator":
        """Compose two operators (either may already be composed)."""
        lf = left.factors if isinstance(left, ComposedOperator) else (left,)
        rf = right.factors if isinstance(right, ComposedOperator) else (right,)
        factors = tuple(lf) + tuple(rf)
        for l, r in zip(factors[:-1], factors[1:]):
            if l.shape[1] != r.shape[0]:
                raise ValueError(
                    f"operator shapes do not chain: {l.shape} @ {r.shape}")
            lp, rp = l.domain_part, r.range_part
            if lp.n_procs != rp.n_procs or \
                    not np.array_equal(lp.owner, rp.owner):
                raise ValueError(
                    "incompatible partitions at a composition interface: "
                    f"{l!r} consumes a different layout than {r!r} "
                    "produces — rebuild one side so the interface "
                    "partitions match (no hidden repartition)")
        return ComposedOperator(factors=factors)

    # -- application / further composition ---------------------------------
    def __call__(self, x, donate: bool = False,
                 precision: Optional[str] = None) -> np.ndarray:
        for f in reversed(self.factors):
            x = f(x, donate=donate)
        if precision is not None:
            x = np.asarray(x, dtype=precision)
        return x

    def __matmul__(self, x):
        if _is_operator(x):
            return ComposedOperator.of(self, x)
        return self(x)

    def matvec(self, x) -> np.ndarray:
        return self(x)

    # -- structure ---------------------------------------------------------
    @property
    def shape(self) -> Tuple[int, int]:
        return (self.factors[0].shape[0], self.factors[-1].shape[1])

    @property
    def range_part(self) -> RowPartition:
        return self.factors[0].range_part

    @property
    def domain_part(self) -> RowPartition:
        return self.factors[-1].domain_part

    @property
    def T(self) -> "ComposedOperator":
        """(ABC).T = C.T B.T A.T — each stage's node-aware transpose."""
        return ComposedOperator(
            factors=tuple(f.T for f in reversed(self.factors)))

    # -- materialisation: the node-aware distributed SpGEMM ---------------
    def materialize(self, *, spgemm_backend: Optional[str] = None,
                    spgemm_method: Optional[str] = None, dtype=None,
                    cross_check: bool = False, mesh=None) -> "NapOperator":
        """Collapse the lazy chain into ONE concrete :class:`NapOperator`
        on the outer partitions, multiplying the factors right-to-left
        through the node-aware distributed SpGEMM
        (:mod:`repro.spgemm`) — remote B rows route through the same
        three-step exchange the SpMV plans use, carrying variable-length
        CSR row blocks.

        The lazy chain pays k SpMVs (plus interface traffic) per apply;
        the materialised operator pays the SpGEMM once and ONE SpMV per
        apply — it wins whenever the operator is applied more than a few
        times (the AMG solve's coarse operator: one V-cycle already
        applies it several times).  See ``src/repro/spgemm/README.md``
        for the break-even discussion.

        ``spgemm_backend``: ``"simulate"`` (exact float64 products,
        bit-for-bit equal to the host ``csr_matmul`` chain) or
        ``"shardmap"`` (the SPMD program; float32 payloads unless
        ``dtype`` overrides under x64).  Defaults to ``"simulate"`` when
        any factor runs the simulate backend, else ``"shardmap"``.
        ``spgemm_method`` defaults to the leftmost factor's method.
        ``cross_check=True`` asserts every intermediate against the host
        ``csr_matmul`` oracle.  The result reuses the leftmost factor's
        executor spec (backend, local compute, block shape, ...) AND its
        mesh — factors built over an explicit device mesh keep the
        SpGEMM products and the concrete operator on the same devices
        (``mesh=`` overrides).
        """
        from repro.spgemm import assert_matches_host, distributed_spgemm

        factors = self.factors
        topo = factors[0].topo
        for f in factors:
            if (f.topo.n_nodes, f.topo.ppn) != (topo.n_nodes, topo.ppn):
                raise ValueError("cannot materialize a chain spanning "
                                 "different topologies")
        backend = spgemm_backend or (
            "simulate" if any(f.spec.backend == "simulate" for f in factors)
            else "shardmap")
        method = spgemm_method or factors[0].spec.method
        if mesh is None:
            # first explicitly meshed factor wins (executors hold _mesh
            # only when one was passed in or lazily built)
            for f in factors:
                mesh = getattr(f.executor, "_mesh", None)
                if mesh is not None:
                    break

        def csr_of(f: "NapOperator"):
            return f.a.transpose() if f.transposed else f.a

        cur = csr_of(factors[-1])
        for f in reversed(factors[:-1]):
            cur = distributed_spgemm(csr_of(f), cur, f.range_part,
                                     f.domain_part, topo, method=method,
                                     backend=backend, dtype=dtype,
                                     mesh=mesh)
        if cross_check:
            from repro.amg.matmul import csr_matmul
            want = csr_of(factors[-1])
            for f in reversed(factors[:-1]):
                want = csr_matmul(csr_of(f), want)
            assert_matches_host(cur, want, backend, "materialize")
        spec = factors[0].spec
        return operator(cur, topo=topo, row_part=self.range_part,
                        col_part=self.domain_part, method=spec.method,
                        backend=spec.backend,
                        local_compute=spec.local_compute, mesh=mesh,
                        pairing=spec.pairing, block_shape=spec.block_shape,
                        nv_block=spec.nv_block, interpret=spec.interpret,
                        cache=spec.cache, tuner=spec.tuner,
                        integrity=spec.integrity, threshold=spec.threshold,
                        wire_dtype=spec.wire_dtype)

    # -- per-stage introspection, rolled up --------------------------------
    def stats(self) -> List[object]:
        """Per-stage plan statistics, in application (right-to-left) order
        reversed to match ``factors`` (left-to-right)."""
        return [f.stats() for f in self.factors]

    def cost(self, machine: MachineParams):
        """Per-stage modeled comm times + their sum (stages are data-
        dependent, so the chain is sequential)."""
        stages = [f.cost(machine) for f in self.factors]
        return {"stages": stages,
                "total": float(sum(s["total"] for s in stages))}

    def autotune_report(self) -> List[object]:
        return [f.autotune_report() for f in self.factors]

    def __repr__(self) -> str:
        inner = " @ ".join(repr(f) for f in self.factors)
        return f"ComposedOperator({inner})"
