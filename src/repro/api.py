"""One LinearOperator-style front-end over every NAPSpMV backend.

The paper's NAPSpMV is one kernel inside larger solvers — AMG cycles need
``A @ x`` *and* the restriction ``A.T @ x`` against the same communication
plan on every level.  This module collapses the four historical entry
points (``DistSpMV.run``, ``compile_nap`` + ``nap_spmv_shardmap``
closures, ``standard_spmv_shardmap``, manual ``pack_vector`` /
``unpack_vector``) into one object::

    import repro.api as nap

    op = nap.operator(a, topo=Topology(n_nodes=4, ppn=4))
    w  = op @ v          # forward SpMV (1-RHS or [n, nv] multi-RHS)
    z  = op.T @ v        # transpose SpMV, same compiled plan reversed
    op.stats(), op.cost(BLUE_WATERS), op.autotune_report()

Backends resolve through the pluggable registry in
:mod:`repro.core.executors` — ``backend="shardmap"`` is the jitted SPMD
executor (Pallas local compute, zero-copy packed x), ``"simulate"`` the
exact float64 message-passing oracle; new backends (true-TPU Mosaic,
collective-permute overlap) register themselves without touching any call
site.  Compilation is lazy per backend *and* per direction: building an
operator costs one plan build; the forward program JITs on first
``op @ x`` and the transpose program only on first ``op.T @ x``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.cost_model import (LocalComputeParams, MachineParams,
                                   TPU_V5E_LOCAL)
from repro.core.executors import (OperatorSpec, available_executors,
                                  bind_executor, register_executor)
from repro.core.partition import RowPartition, contiguous_partition
from repro.core.topology import Topology

__all__ = ["operator", "NapOperator", "available_executors",
           "register_executor"]


def operator(a, topo: Optional[Topology] = None,
             part: Optional[RowPartition] = None, *,
             method: str = "nap", backend: str = "shardmap",
             local_compute: str = "auto", mesh=None,
             pairing: str = "aligned",
             block_shape: Tuple[int, int] = (8, 128), nv_block: int = 128,
             interpret: bool = True, cache: bool = True,
             tuner: LocalComputeParams = TPU_V5E_LOCAL) -> "NapOperator":
    """Build a :class:`NapOperator` for ``a`` on a (topo, part) layout.

    Parameters
    ----------
    a : CSR
        Square sparse matrix (vector space and row space share ``part``).
    topo : Topology, optional
        Machine shape.  Defaults to a single node with one process —
        pass the real (n_nodes, ppn) for anything distributed.
    part : RowPartition, optional
        Row ownership; defaults to ``contiguous_partition``.
    method : ``"nap"`` (Algorithms 2+3) or ``"standard"`` (Algorithm 1).
    backend : ``"shardmap"`` (jitted SPMD) | ``"simulate"`` (exact numpy
        oracle) | any backend later added to the executor registry.
    local_compute : shardmap local kernel — ``"auto"`` | ``"bsr"`` |
        ``"ell"`` | ``"coo"`` (see kernels/README.md).
    mesh : optional pre-built jax mesh with axes ("node", "proc");
        shardmap builds one lazily otherwise.
    pairing : inter-node slot pairing for the nap plan ("aligned" is the
        TPU all-to-all-natural choice and the only one the shardmap
        backend lowers; "balanced" is the paper's text rule, available on
        the simulate backend).
    """
    if a.shape[0] != a.shape[1]:
        raise ValueError(
            f"operator requires a square matrix (row partition doubles as "
            f"the vector partition); got shape {a.shape}")
    if topo is None:
        topo = Topology(n_nodes=1, ppn=1)
    if part is None:
        part = contiguous_partition(a.shape[0], topo.n_procs)
    if backend == "shardmap" and pairing != "aligned":
        raise ValueError("the shardmap backend lowers pairing='aligned' "
                         "only (the all-to-all slot contract)")
    spec = OperatorSpec(method=method, backend=backend,
                        local_compute=local_compute, pairing=pairing,
                        block_shape=tuple(block_shape), nv_block=nv_block,
                        interpret=interpret, cache=cache, tuner=tuner)
    exec_ = bind_executor(backend, method, a, part, topo, spec, mesh=mesh)
    return NapOperator(a=a, part=part, topo=topo, spec=spec, executor=exec_)


@dataclasses.dataclass
class NapOperator:
    """Distributed SpMV as a composable linear operator.

    ``op @ x`` / ``op(x)`` apply ``A``; ``op.T @ x`` applies ``A.T``
    through the SAME compiled communication plan with send/recv roles
    reversed.  ``x`` is a global ``[n]`` vector or ``[n, nv]``
    multivector (numpy or jax); the result matches the input shape.
    """

    a: object
    part: RowPartition
    topo: Topology
    spec: OperatorSpec
    executor: object
    transposed: bool = False
    _parent: Optional["NapOperator"] = dataclasses.field(
        default=None, repr=False)

    # -- application -------------------------------------------------------
    def __call__(self, x, donate: bool = False,
                 precision: Optional[str] = None) -> np.ndarray:
        """Apply the operator.

        ``donate=True`` lets XLA reuse the packed input buffer (shardmap
        backend; ignored by simulate).  ``precision`` pins the result
        dtype: ``"float32"`` / ``"float64"`` / None (backend native —
        float32 for shardmap, float64 for simulate).  The shardmap
        backend computes in float32 regardless; asking it for float64
        raises rather than implying precision it cannot deliver.
        """
        if precision not in (None, "float32", "float64"):
            raise ValueError(f"precision must be float32|float64, "
                             f"got {precision!r}")
        if precision == "float64" and self.spec.backend == "shardmap":
            raise NotImplementedError(
                "the shardmap backend computes in float32; use "
                "backend='simulate' for float64 results")
        apply = (self.executor.transpose if self.transposed
                 else self.executor.forward)
        out = apply(x, donate=donate)
        if precision is not None:
            out = np.asarray(out, dtype=precision)
        return out

    def __matmul__(self, x) -> np.ndarray:
        return self(x)

    def matvec(self, x) -> np.ndarray:
        return self(x)

    # -- structure ---------------------------------------------------------
    @property
    def shape(self) -> Tuple[int, int]:
        n, m = self.a.shape
        return (m, n) if self.transposed else (n, m)

    @property
    def method(self) -> str:
        return self.spec.method

    @property
    def backend(self) -> str:
        return self.spec.backend

    @property
    def local_compute(self) -> str:
        """Resolved local-compute format for THIS direction (the transpose
        programs run the COO/segment_sum path until transposed Pallas
        kernels land — see the transpose builders in core/spmv_jax.py)."""
        if self.transposed:
            return getattr(self.executor, "transpose_local_compute",
                           getattr(self.executor, "local_compute", "unknown"))
        return getattr(self.executor, "local_compute", "unknown")

    @property
    def T(self) -> "NapOperator":
        """Transpose view sharing this operator's executor and compiled
        plan (``op.T.T is op``)."""
        if self._parent is not None:
            return self._parent
        return dataclasses.replace(self, transposed=not self.transposed,
                                   _parent=self)

    # -- introspection -----------------------------------------------------
    def stats(self):
        """Plan-level message statistics (+ padded traffic on shardmap)."""
        return self.executor.stats()

    def cost(self, machine: MachineParams):
        """Modeled communication time under a machine model (Eqs. 10-12)."""
        return self.executor.cost(machine)

    def autotune_report(self):
        """Local-compute format decision (chosen format, modeled times,
        per-rank stats) where the backend runs the adaptive engine."""
        return self.executor.autotune_report()

    def __repr__(self) -> str:
        t = ".T" if self.transposed else ""
        return (f"NapOperator{t}(n={self.a.shape[0]}, "
                f"method={self.spec.method!r}, backend={self.spec.backend!r}, "
                f"topo=({self.topo.n_nodes}x{self.topo.ppn}))")
