from repro.runtime.fault import (ElasticPolicy, HeartbeatMonitor,
                                 StragglerDetector)

__all__ = ["ElasticPolicy", "HeartbeatMonitor", "StragglerDetector"]
