"""Fault tolerance runtime: heartbeats, straggler detection, elastic rescale.

On a real fleet each host runs the HeartbeatMonitor against its peers (or a
coordination service); here the components are clock-injectable so the tests
simulate dead nodes and stragglers deterministically.  The recovery path is:

  detector fires -> ElasticPolicy proposes a surviving mesh ->
  launcher re-enters train loop -> checkpoint/store.py elastic restore
  (full-leaf arrays re-device_put onto the new mesh) -> data pipeline
  resumes from the checkpointed cursor (pure function of step).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


class HeartbeatMonitor:
    """Tracks per-node heartbeats; a node silent for ``timeout`` is dead."""

    def __init__(self, nodes: Sequence[str], timeout: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        self.last: Dict[str, float] = {n: clock() for n in nodes}

    def beat(self, node: str, register: bool = False) -> None:
        """Record a heartbeat.  Beating an UNKNOWN node raises ``KeyError``
        unless ``register=True`` — silently auto-registering meant a typo'd
        node name looked healthy forever while the real node timed out."""
        if node not in self.last and not register:
            raise KeyError(
                f"heartbeat from unregistered node {node!r} (known: "
                f"{sorted(self.last)}); pass register=True to add it")
        self.last[node] = self.clock()

    def dead_nodes(self) -> List[str]:
        now = self.clock()
        return [n for n, t in self.last.items() if now - t > self.timeout]

    def healthy(self) -> bool:
        return not self.dead_nodes()


class StragglerDetector:
    """Per-node step-time z-score detector over a sliding window.

    A node whose step time exceeds mean + z_thresh * std of the fleet (and a
    relative floor) is flagged; the launcher response is to checkpoint and
    rebalance (drop the node via ElasticPolicy) or re-route its shard.
    """

    def __init__(self, window: int = 32, z_thresh: float = 3.0,
                 rel_floor: float = 1.5):
        self.window = window
        self.z = z_thresh
        self.rel_floor = rel_floor
        self.times: Dict[str, deque] = {}

    def record(self, node: str, step_time: float) -> None:
        self.times.setdefault(node, deque(maxlen=self.window)).append(step_time)

    def stragglers(self) -> List[str]:
        means = {n: float(np.mean(t)) for n, t in self.times.items() if t}
        if len(means) < 2:
            return []
        vals = np.array(list(means.values()))
        mu, sd = float(vals.mean()), float(vals.std())
        out = []
        for n, m in means.items():
            if m > mu * self.rel_floor and (sd == 0 or (m - mu) / max(sd, 1e-9)
                                            > self.z):
                out.append(n)
        return out


@dataclasses.dataclass
class ElasticPolicy:
    """Given the production mesh and dead nodes, propose the survivor mesh.

    The data axis absorbs the loss (batch is re-sharded; global batch is
    preserved by increasing per-chip microbatches), the model axis is never
    shrunk (params are sharded over it), and a pod that loses too many nodes
    is dropped whole.  Checkpoint restore handles the re-shard (store.py).
    """
    min_data: int = 1

    def propose(self, mesh_shape: Tuple[int, ...], axis_names: Tuple[str, ...],
                n_dead_nodes: int, chips_per_node: int = 4
                ) -> Optional[Tuple[Tuple[int, ...], Tuple[str, ...]]]:
        shape = dict(zip(axis_names, mesh_shape))
        dead_chips = n_dead_nodes * chips_per_node
        data = shape.get("data", 1)
        model = shape.get("model", 1)
        pods = shape.get("pod", 1)
        chips_per_data_row = model
        rows_lost = -(-dead_chips // chips_per_data_row)
        new_data = data - rows_lost
        if new_data >= self.min_data:
            shape["data"] = new_data
            return tuple(shape[a] for a in axis_names), axis_names
        if pods > 1:  # drop a whole pod, restore data axis
            shape["pod"] = pods - 1
            shape["data"] = data
            return tuple(shape[a] for a in axis_names), axis_names
        return None  # fleet too degraded

    def global_batch_plan(self, global_batch: int, old_data: int,
                          new_data: int) -> Tuple[int, int]:
        """(per_row_batch, grad_accum_multiplier) preserving global batch
        EXACTLY: ``per_row_batch * new_data * accum == global_batch``.

        Contract: ``new_data`` must divide ``global_batch`` (the data axis
        re-shards whole examples; a non-divisible shrink would change the
        effective batch and thus the optimiser trajectory — callers that
        cannot satisfy it must change ``global_batch`` explicitly instead
        of silently training on a different batch).  ``accum`` is the
        smallest multiplier keeping the per-row microbatch at or below the
        pre-shrink ``global_batch // old_data``.
        """
        if global_batch % new_data != 0:
            raise ValueError(
                f"global batch {global_batch} is not divisible by the "
                f"surviving data-axis size {new_data}; pick a new global "
                f"batch explicitly rather than silently changing it")
        per_old = max(1, global_batch // old_data)
        total_per_row = global_batch // new_data  # = per_row_batch * accum
        accum = -(-total_per_row // per_old)      # smallest with per_row <= per_old
        while total_per_row % accum:              # bounded: accum <= total_per_row
            accum += 1
        per_row = total_per_row // accum
        assert per_row * new_data * accum == global_batch
        return per_row, accum

    def survivor_topology(self, topo, dead_nodes: Sequence) -> Optional[object]:
        """Node-drop rule for the SOLVER mesh (:class:`repro.core.topology.
        Topology`): dead nodes leave whole (their ppn ranks go with them),
        survivors keep the per-node process count.  Returns the survivor
        :class:`Topology`, or ``None`` when the fleet is too degraded
        (no node left) — the caller sheds load instead of deadlocking."""
        from repro.core.topology import Topology

        alive = topo.n_nodes - len(set(dead_nodes))
        if alive < 1:
            return None
        return Topology(n_nodes=alive, ppn=topo.ppn)
