"""Sharded checkpoints with manifest, async save, and ELASTIC restore.

Layout per step:  <dir>/step_<n>/
    manifest.json      tree structure, shapes, dtypes, shard digests
    shard_<k>.npz      leaf arrays (chunked so no single file balloons)
    _COMMITTED         written LAST — a crash mid-save never corrupts restore

The manifest records a sha256 content digest per shard file, verified on
every load: the ``_COMMITTED`` marker proves the save FINISHED, the
digests prove the bytes read back are the bytes written — bitrot or a
partial overwrite inside an intact shard set raises
:class:`repro.core.integrity.IntegrityError` naming the corrupt shard
instead of silently restoring garbage iterates.

Elastic restore: arrays are stored UNSHARDED per leaf (on a real multi-host
fleet each host writes its shard slice + index, same manifest), so restoring
onto a *different* mesh is just device_put with the new sharding — the
surviving-nodes restart path in runtime/elastic.py relies on this.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.integrity import IntegrityError

Pytree = Any


def _file_digest(path: pathlib.Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _flatten_with_names(tree: Pytree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((name, leaf))
    return out, treedef


def save_checkpoint(directory: str, step: int, tree: Pytree,
                    extra: Optional[Dict] = None,
                    shard_mb: int = 512,
                    on_before_commit: Optional[Callable[[], None]] = None) -> str:
    """Write one committed checkpoint step.

    ``on_before_commit`` runs after every shard and the manifest are on
    disk but BEFORE the ``_COMMITTED`` marker — the crash window the
    marker protects against.  Fault harnesses (``repro.serve.faultplan``)
    raise from it to produce a deterministic torn save; restore must then
    fall back to the previous committed step.
    """
    path = pathlib.Path(directory) / f"step_{step:08d}"
    path.mkdir(parents=True, exist_ok=True)
    named, _ = _flatten_with_names(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": [], "shards": 0}
    shard, shard_bytes, shard_id = {}, 0, 0
    limit = shard_mb * 1_000_000

    digests: Dict[str, str] = {}

    def flush():
        nonlocal shard, shard_bytes, shard_id
        if shard:
            fname = f"shard_{shard_id}.npz"
            np.savez(path / fname, **shard)
            digests[fname] = _file_digest(path / fname)
            shard, shard_bytes = {}, 0
            shard_id += 1

    for name, leaf in named:
        arr = np.asarray(leaf)
        key = name.replace("/", "__")
        logical_dtype = str(arr.dtype)
        if logical_dtype == "bfloat16":   # npz has no native bf16: bit-store
            arr = arr.view(np.uint16)
        manifest["leaves"].append({
            "name": name, "key": key, "shard": shard_id,
            "shape": list(arr.shape), "dtype": logical_dtype})
        shard[key] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= limit:
            flush()
    flush()
    manifest["shards"] = shard_id
    manifest["shard_digests"] = digests
    with open(path / "manifest.json", "w") as f:
        json.dump(manifest, f)
    if on_before_commit is not None:
        on_before_commit()
    (path / "_COMMITTED").touch()       # atomicity marker, written last
    return str(path)


def load_checkpoint(directory: str, step: Optional[int] = None,
                    target: Optional[Pytree] = None,
                    shardings: Optional[Pytree] = None) -> Tuple[Pytree, Dict]:
    """Restore (tree, extra).  ``target`` supplies the tree structure; with
    ``shardings`` the leaves are device_put to the (possibly NEW) mesh."""
    base = pathlib.Path(directory)
    if step is None:
        steps = sorted(int(p.name.split("_")[1]) for p in base.glob("step_*")
                       if (p / "_COMMITTED").exists())
        if not steps:
            raise FileNotFoundError(f"no committed checkpoints in {directory}")
        step = steps[-1]
    path = base / f"step_{step:08d}"
    if not (path / "_COMMITTED").exists():
        raise FileNotFoundError(f"checkpoint {path} not committed")
    with open(path / "manifest.json") as f:
        manifest = json.load(f)
    # pre-digest manifests (older checkpoints) skip verification
    digests = manifest.get("shard_digests", {})
    shards = {}
    for i in range(manifest["shards"]):   # manifest stores the exact count
        shard_path = path / f"shard_{i}.npz"
        if not shard_path.exists():
            held = [l["name"] for l in manifest["leaves"] if l["shard"] == i]
            raise FileNotFoundError(
                f"checkpoint {path} is committed but {shard_path.name} is "
                f"missing; it held {len(held)} leaves: {held}")
        want = digests.get(shard_path.name)
        if want is not None:
            got = _file_digest(shard_path)
            if got != want:
                held = [l["name"] for l in manifest["leaves"]
                        if l["shard"] == i]
                raise IntegrityError(
                    f"checkpoint shard {shard_path} is corrupt: sha256 "
                    f"{got[:16]}… != manifest {want[:16]}… — the shard set "
                    f"is intact but the bytes changed since the save "
                    f"(bitrot / partial overwrite); it held {len(held)} "
                    f"leaves: {held}")
        shards[i] = np.load(shard_path)
    import ml_dtypes
    by_name = {}
    for l in manifest["leaves"]:
        arr = shards[l["shard"]][l["key"]]
        if l["dtype"] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        by_name[l["name"]] = arr
    if target is None:
        return by_name, manifest["extra"]
    named, treedef = _flatten_with_names(target)
    leaves = []
    flat_shardings = (jax.tree.leaves(shardings)
                      if shardings is not None else [None] * len(named))
    for (name, tgt), sh in zip(named, flat_shardings):
        arr = by_name[name]
        if sh is not None:
            arr = jax.device_put(arr, sh)
        leaves.append(arr)
    return jax.tree.unflatten(treedef, leaves), manifest["extra"]


class CheckpointManager:
    """Async, rolling checkpoints: save() returns immediately; the writer
    thread serialises in the background (the train loop never stalls on I/O)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._lock = threading.Lock()    # guards last_saved across threads
        self.last_saved: Optional[int] = None

    def save(self, step: int, tree: Pytree, extra: Optional[Dict] = None,
             block: bool = False,
             on_before_commit: Optional[Callable[[], None]] = None) -> None:
        self.wait()                      # one in-flight save at a time
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot before async

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra,
                                on_before_commit=on_before_commit)
                with self._lock:
                    self.last_saved = step
                self._gc()
            except BaseException as e:   # surfaced on the next wait()/save()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                f"background checkpoint save failed; last committed step is "
                f"{self.last_saved}") from err

    def restore(self, target=None, shardings=None, step=None):
        return load_checkpoint(self.directory, step, target, shardings)

    def _gc(self) -> None:
        base = pathlib.Path(self.directory)
        steps = sorted(int(p.name.split("_")[1]) for p in base.glob("step_*")
                       if (p / "_COMMITTED").exists())
        for s in steps[:-self.keep]:
            p = base / f"step_{s:08d}"
            for f in p.iterdir():
                f.unlink()
            p.rmdir()
