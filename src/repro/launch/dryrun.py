import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init); 512 host devices back both the 16x16 single-pod mesh
and the 2x16x16 multi-pod mesh.

Per cell this records into an incremental JSON (safe to re-run; finished
cells are skipped unless --force):
  * compile + lower wall time
  * memory_analysis (argument/output/temp/generated-code bytes per device)
  * cost_analysis flops/bytes (XLA's view, NOT trip-count aware)
  * hlo_analysis flops/bytes/collective bytes (trip-count aware) and the
    three roofline terms (core/roofline.py)

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out out.json]
  python -m repro.launch.dryrun --all --both-meshes
"""

import argparse
import json
import pathlib
import time
import traceback

import jax

from repro import compat
from repro.configs import all_arch_ids, get_config
from repro.configs.shapes import SHAPES, cell_runnable
from repro.core.hlo_analysis import analyze_hlo
from repro.core.roofline import build_roofline, model_flops_for
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, lower_cell

DEFAULT_OUT = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun.json"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save_hlo: str = "", overrides=None) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "ok": False}
    if overrides:
        rec["overrides"] = overrides
    runnable, why = cell_runnable(arch, shape_name)
    if not runnable:
        rec.update(skipped=True, reason=why, ok=True)
        return rec
    try:
        chips = 512 if multi_pod else 256
        # pin the chip budget: the dry-run cells are defined at 256/512
        # regardless of how many host devices back them
        mesh = make_production_mesh(multi_pod=multi_pod, n_devices=chips,
                                    n_pods=2 if multi_pod else None)
        t0 = time.time()
        cell = build_cell(arch, shape_name, mesh, multi_pod,
                          overrides=overrides)
        lowered = lower_cell(cell, mesh)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        ma = compiled.memory_analysis()
        mem = {
            "argument_gb": ma.argument_size_in_bytes / 1e9,
            "output_gb": ma.output_size_in_bytes / 1e9,
            "temp_gb": ma.temp_size_in_bytes / 1e9,
            "code_gb": getattr(ma, "generated_code_size_in_bytes", 0) / 1e9,
            "alias_gb": getattr(ma, "alias_size_in_bytes", 0) / 1e9,
        }
        # peak per device: args + temps (aliased/donated buffers overlap args).
        # NB the CPU backend's float-normalization pass materialises f32
        # copies of every bf16 weight/cache (TPU runs bf16 natively), so this
        # OVERSTATES the TPU footprint; `analytic` is the TPU-native budget.
        mem["peak_gb"] = mem["argument_gb"] + mem["temp_gb"]
        mem["analytic"] = cell.analytic_gb
        ca = compat.cost_analysis(compiled)
        text = compiled.as_text()
        cost = analyze_hlo(text, pod_boundary=256 if multi_pod else 0)
        mf = model_flops_for(cell.kind, cell.n_active_params, cell.tokens)
        roof = build_roofline(arch, shape_name, mesh_name, chips, cost, mf)
        rec.update(
            ok=True, kind=cell.kind, chips=chips,
            t_lower_s=round(t_lower, 2), t_compile_s=round(t_compile, 2),
            memory=mem,
            xla_cost={"flops": ca.get("flops"),
                      "bytes": ca.get("bytes accessed")},
            hlo={"dot_flops": cost.dot_flops, "hbm_bytes": cost.hbm_bytes,
                 "collective_bytes": cost.collective_bytes,
                 "collective_counts": cost.collective_counts,
                 "dci_bytes": cost.dci_bytes},
            roofline={"t_compute": roof.t_compute, "t_memory": roof.t_memory,
                      "t_collective": roof.t_collective,
                      "t_collective_wire": roof.t_collective_wire,
                      "dominant": roof.dominant, "mfu": roof.mfu,
                      "model_flops": mf, "useful_ratio": roof.useful_ratio},
            tokens=cell.tokens, n_active_params=cell.n_active_params,
        )
        if save_hlo:
            import gzip
            with gzip.open(save_hlo, "wt") as f:
                f.write(text)
    except Exception as e:  # noqa: BLE001 — a failed cell is a result
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    return rec


def load_results(path: pathlib.Path) -> dict:
    if path.exists():
        return json.loads(path.read_text())
    return {"cells": {}}


def save_results(path: pathlib.Path, results: dict) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(results, indent=1))


def cell_key(arch, shape, multi_pod):
    return f"{arch}|{shape}|{'2x16x16' if multi_pod else '16x16'}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", default="")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (perf iterations); "
                         "results stored under a suffixed cell key")
    ap.add_argument("--tag", default="",
                    help="suffix for the cell key of an override run")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                v = {"true": True, "false": False}.get(v.lower(), v)
        overrides[k] = v

    out = pathlib.Path(args.out)
    results = load_results(out)

    if args.all:
        archs = all_arch_ids()
        shapes = list(SHAPES)
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        archs, shapes = [args.arch], [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                key = cell_key(arch, shape, multi_pod)
                if args.tag:
                    key += f"#{args.tag}"
                if not args.force and results["cells"].get(key, {}).get("ok"):
                    print(f"[skip] {key} (cached)")
                    continue
                print(f"[run ] {key} ...", flush=True)
                rec = run_cell(arch, shape, multi_pod, save_hlo=args.save_hlo,
                               overrides=overrides or None)
                results["cells"][key] = rec
                save_results(out, results)
                if rec["ok"]:
                    if rec.get("skipped"):
                        print(f"       SKIP: {rec['reason']}")
                    else:
                        r = rec["roofline"]
                        print(f"       ok compile={rec['t_compile_s']}s "
                              f"peak={rec['memory']['peak_gb']:.1f}GB "
                              f"dom={r['dominant']} mfu={r['mfu']*100:.1f}%")
                else:
                    failures += 1
                    print(f"       FAIL: {rec['error']}")
    print(f"done; {failures} failures -> {out}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
