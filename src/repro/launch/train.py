"""Training driver: data pipeline -> sharded train step -> checkpoints.

Runs for real on this host with reduced configs (--reduced, the default here)
and is the same code path the dry-run lowers at production scale.  Features:

* deterministic restart: data cursor + RNG live in the checkpoint
* async rolling checkpoints (checkpoint/store.py)
* straggler/heartbeat hooks (runtime/fault.py) — on a single host these
  monitor the local step loop; on a fleet each host reports its own
* elastic restart: --mesh data,model overrides let a resumed run use a
  smaller mesh; restore re-shards automatically

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --steps 50 \
      --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.compat import make_mesh
from repro.configs import get_config, get_reduced
from repro.data import SyntheticLM
from repro.launch.steps import (adamw_config_for, make_train_step,
                                opt_state_spec_tree, _sharding_tree)
from repro.models import build_model
from repro.models import partitioning as part
from repro.optim import adamw_init
from repro.runtime import StragglerDetector


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    cfg = cfg.replace(grad_accum=1)
    n_dev = jax.device_count()
    mesh = make_mesh((n_dev, 1), ("data", "model"))
    model = build_model(cfg, mesh=mesh)
    opt_cfg = adamw_config_for(cfg).__class__(
        lr=args.lr, total_steps=args.steps,
        warmup_steps=max(args.steps // 20, 1),
        state_dtype=cfg.opt_state_dtype, master_fp32=cfg.opt_master_fp32)

    params = model.init(jax.random.key(args.seed))
    opt_state = adamw_init(params, opt_cfg)
    start_step = 0
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, seed=args.seed)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr and args.resume:
        (params, opt_state), extra = mgr.restore(target=(params, opt_state))
        start_step = int(extra["step"])
        print(f"resumed at step {start_step}")

    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
    det = StragglerDetector()
    losses = []
    floor = ds.bigram_entropy()
    print(f"training {cfg.name} ({'reduced' if args.reduced else 'FULL'}) "
          f"on {n_dev} device(s); bigram-entropy loss floor ~ {floor:.3f}")
    for step in range(start_step, args.steps):
        batch = ds.batch(step, args.batch)
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.is_encoder_decoder:
            jb["frames"] = jnp.asarray(np.random.default_rng(step).standard_normal(
                (args.batch, cfg.encoder_seq, cfg.d_model)), jnp.float32)
        t0 = time.time()
        loss, params, opt_state = step_fn(params, opt_state, jb)
        loss = float(loss)
        det.record("local", time.time() - t0)
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {loss:.4f}  ({time.time()-t0:.2f}s)")
        if mgr and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, (params, opt_state), extra={"step": step + 1})
    if mgr:
        mgr.save(args.steps, (params, opt_state), extra={"step": args.steps},
                 block=True)
    first = np.mean(losses[: max(3, len(losses) // 10)])
    last = np.mean(losses[-max(3, len(losses) // 10):])
    print(f"loss {first:.4f} -> {last:.4f} (floor {floor:.3f})")
    if last >= first:
        raise SystemExit("loss did not decrease")


if __name__ == "__main__":
    main()
