"""Serving driver: batched prefill + decode loop against the KV cache.

Runs reduced configs for real on this host; the decode_32k / long_500k
dry-run cells lower exactly the ``decode_step`` used here.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    B, S = args.batch, args.prompt_len
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    decode = jax.jit(model.decode_step)
    # prefill by teacher-forcing through decode_step (cache shape fixed up
    # front); model.prefill is the fused-path alternative exercised by the
    # prefill_32k dry-run cells.
    cache = model.init_cache(B, args.max_seq)
    t0 = time.time()
    logits = None
    for t in range(S):
        logits, cache = decode(params, cache, prompts[:, t:t + 1])
    t_prefill = time.time() - t0

    out_tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t0 = time.time()
    for _ in range(args.gen):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t_gen = time.time() - t0
    gen = np.stack(out_tokens, axis=1)
    print(f"prefill {S} toks x {B} seqs: {t_prefill:.2f}s; "
          f"decode {args.gen} steps: {t_gen:.2f}s "
          f"({B*args.gen/max(t_gen,1e-9):.1f} tok/s)")
    print("generated ids [batch 0]:", gen[0].tolist())
    assert np.isfinite(np.asarray(logits)).all()


if __name__ == "__main__":
    main()
