"""Step functions + sharding assembly shared by dryrun/train/serve.

``make_train_step`` builds the jitted (donated, sharded) training step:
gradient accumulation over microbatches via ``lax.scan`` (activation memory
/ grad_accum), fp32 grad accumulators, AdamW update (optionally 8-bit
moments).  ``build_cell`` assembles the (arch x shape x mesh) programs the
dry-run lowers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.shapes import SHAPES, ShapeSpec
from repro.models import build_model
from repro.models import partitioning as part
from repro.models.registry import count_active_params, param_shapes
from repro.optim import AdamWConfig, adamw_init, adamw_update


def adamw_config_for(cfg) -> AdamWConfig:
    return AdamWConfig(state_dtype=cfg.opt_state_dtype,
                       master_fp32=cfg.opt_master_fp32)


def make_loss_with_accum(model, grad_shardings=None):
    """loss over the global batch with grad accumulation inside.

    grad_shardings (a pytree of NamedSharding matching params) pins the fp32
    accumulator to the PARAM layout: each microbatch's weight-grad reduction
    then lowers to a reduce-scatter onto the shards instead of a full-tensor
    all-reduce (llama3-405b: 34 TB -> ~2 TB of AR per step without it).
    """
    cfg = model.cfg
    A = cfg.grad_accum

    def pin(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def split(batch):
        return jax.tree.map(
            lambda x: x.reshape((A, x.shape[0] // A) + x.shape[1:]), batch)

    def loss_and_grad(params, batch):
        if A <= 1:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            return loss, pin(grads)
        micro = split(batch)

        def body(carry, mb):
            loss_acc, grad_acc = carry
            l, g = jax.value_and_grad(model.loss)(params, mb)
            grad_acc = pin(jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), grad_acc, g))
            return (loss_acc + l, grad_acc), None

        zeros = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                               zeros), micro)
        inv = 1.0 / A
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    return loss_and_grad


def make_train_step(model, opt_cfg: AdamWConfig, grad_shardings=None):
    loss_and_grad = make_loss_with_accum(model, grad_shardings)

    def train_step(params, opt_state, batch):
        loss, grads = loss_and_grad(params, batch)
        new_params, new_state = adamw_update(grads, params, opt_state, opt_cfg)
        return loss, new_params, new_state

    return train_step


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def _sharding_tree(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_spec_tree(cfg, params_shape, multi_pod: bool,
                        state_shape=None, axis_sizes=None):
    """Specs for the AdamW state: ZeRO-3 (all-DP) sharded moments/master.

    int8 states: the q tensors shard exactly like the param; the per-block
    scale tensors (same rank, last dim = n_blocks) inherit the same spec with
    the divisibility guard applied to their actual shapes — quantization
    blocks run along the last axis precisely so this stays sharding-stable.
    """
    z3 = part.param_specs(cfg, params_shape, multi_pod, zero3=True,
                          axis_sizes=axis_sizes)
    q8 = cfg.opt_state_dtype == "int8"

    def with_shape(spec, leaf):
        return part._guard(spec, leaf.shape, axis_sizes)

    if q8:
        def q8_spec(keys):
            def make(spec, st):
                return {k: with_shape(spec, st[k]) for k in keys}
            return make
        m_spec = (jax.tree.map(q8_spec(("q", "s")), z3, state_shape["m"],
                               is_leaf=lambda x: isinstance(x, P))
                  if state_shape is not None else
                  jax.tree.map(lambda s: {"q": s, "s": P()}, z3,
                               is_leaf=lambda x: isinstance(x, P)))
        v_spec = (jax.tree.map(q8_spec(("q", "lo", "st")), z3,
                               state_shape["v"],
                               is_leaf=lambda x: isinstance(x, P))
                  if state_shape is not None else
                  jax.tree.map(lambda s: {"q": s, "lo": P(), "st": P()}, z3,
                               is_leaf=lambda x: isinstance(x, P)))
    else:
        m_spec = v_spec = z3
    state = {"step": P(), "m": m_spec, "v": v_spec}
    has_master = (cfg.opt_master_fp32 if state_shape is None
                  else "master" in state_shape)
    if has_master:
        state["master"] = z3
    return state


def batch_specs_for(cfg, shape: ShapeSpec, multi_pod: bool, mesh) -> Dict:
    from repro.launch.mesh import dp_size
    dp = dp_size(mesh)
    bspec = part.batch_spec(multi_pod) if shape.global_batch >= dp else P()
    specs = {"tokens": bspec, "labels": bspec}
    if cfg.is_encoder_decoder:
        fspec = (part.frames_spec(multi_pod) if shape.global_batch >= dp
                 else P(None, None, None))
        specs["frames"] = fspec
    return specs


def input_specs(cfg, shape: ShapeSpec) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.is_encoder_decoder:
            out["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        return out
    # decode: one new token against an S-token cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


# ---------------------------------------------------------------------------
# cell assembly for the dry-run
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    """Everything needed to lower one (arch x shape x mesh) combination."""
    fn: Any                 # the function to jit
    args: Tuple             # ShapeDtypeStructs
    in_shardings: Tuple
    out_shardings: Any
    donate: Tuple[int, ...]
    kind: str
    tokens: int             # tokens processed per execution (for MODEL_FLOPS)
    n_active_params: int
    analytic_gb: Dict = dataclasses.field(default_factory=dict)


def _sharded_gb(shape_tree, spec_tree, axis_sizes) -> float:
    """Per-device bytes of a tree under its specs (TPU-native dtypes)."""
    total = 0.0
    for leaf, spec in zip(jax.tree.leaves(shape_tree),
                          jax.tree.leaves(spec_tree,
                                          is_leaf=lambda x: isinstance(x, P))):
        n = 1
        for s in leaf.shape:
            n *= s
        div = 1
        for entry in spec:
            if entry is None:
                continue
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                div *= axis_sizes.get(a, 1)
        total += n * leaf.dtype.itemsize / max(div, 1)
    return total / 1e9


def build_cell(arch: str, shape_name: str, mesh, multi_pod: bool,
               overrides: Optional[Dict] = None) -> Cell:
    from repro.configs import get_config
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    model = build_model(cfg, mesh=mesh, multi_pod=multi_pod)
    axis_sizes = dict(mesh.shape)
    pshape = param_shapes(model)
    pspec = part.param_specs(cfg, pshape, multi_pod, axis_sizes=axis_sizes)
    psh = _sharding_tree(mesh, pspec)
    n_active = count_active_params(model)

    if shape.kind == "train":
        opt_cfg = adamw_config_for(cfg)
        oshape = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), pshape)
        ospec = opt_state_spec_tree(cfg, pshape, multi_pod, oshape, axis_sizes)
        osh = _sharding_tree(mesh, ospec)
        bspec = batch_specs_for(cfg, shape, multi_pod, mesh)
        bsh = _sharding_tree(mesh, bspec)
        step = make_train_step(model, opt_cfg, grad_shardings=psh)
        args = (pshape, oshape, input_specs(cfg, shape))
        params_gb = _sharded_gb(pshape, pspec, axis_sizes)
        opt_gb = _sharded_gb(oshape, ospec, axis_sizes)
        # fp32 grads live at param sharding during the update
        grads_gb = params_gb * (4 / jnp.dtype(cfg.dtype).itemsize)
        # remat residuals: one hidden per layer per microbatch, seq-sharded
        from repro.launch.mesh import dp_size
        act = (cfg.n_layers * (shape.global_batch // max(cfg.grad_accum, 1))
               * shape.seq_len * cfg.d_model * 2
               / (dp_size(mesh) * mesh.shape.get("model", 1))) / 1e9
        return Cell(fn=step, args=args,
                    in_shardings=(psh, osh, bsh),
                    out_shardings=(NamedSharding(mesh, P()), psh, osh),
                    donate=(0, 1), kind="train",
                    tokens=shape.global_batch * shape.seq_len,
                    n_active_params=n_active,
                    analytic_gb={"params": params_gb, "opt": opt_gb,
                                 "grads": grads_gb, "residuals": act,
                                 "total": params_gb + opt_gb + grads_gb + act})

    if shape.kind == "prefill":
        bspec = batch_specs_for(cfg, shape, multi_pod, mesh)
        bsh = _sharding_tree(mesh, bspec)
        inputs = input_specs(cfg, shape)
        cshape = jax.eval_shape(
            lambda p, t, f=None: (model.prefill(p, t, f) if f is not None
                                  else model.prefill(p, t)),
            pshape, inputs["tokens"],
            *( [inputs["frames"]] if cfg.is_encoder_decoder else []))
        logits_shape, cache_shape = cshape
        cspec = part.cache_specs(cfg, cache_shape, multi_pod,
                                 axis_sizes=axis_sizes)
        csh = _sharding_tree(mesh, cspec)
        if cfg.is_encoder_decoder:
            fn = lambda p, t, f: model.prefill(p, t, f)
            args = (pshape, inputs["tokens"], inputs["frames"])
            in_sh = (psh, bsh["tokens"], bsh["frames"])
        else:
            fn = lambda p, t: model.prefill(p, t)
            args = (pshape, inputs["tokens"])
            in_sh = (psh, bsh["tokens"])
        params_gb = _sharded_gb(pshape, pspec, axis_sizes)
        cache_gb = _sharded_gb(cache_shape, cspec, axis_sizes)
        return Cell(fn=fn, args=args, in_shardings=in_sh,
                    out_shardings=(NamedSharding(mesh, P()), csh),
                    donate=(), kind="prefill",
                    tokens=shape.global_batch * shape.seq_len,
                    n_active_params=n_active,
                    analytic_gb={"params": params_gb, "cache": cache_gb,
                                 "total": params_gb + cache_gb})

    # decode
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    cspec = part.cache_specs(cfg, cache_shape, multi_pod,
                             axis_sizes=axis_sizes)
    csh = _sharding_tree(mesh, cspec)
    inputs = input_specs(cfg, shape)
    from repro.launch.mesh import dp_size
    tok_spec = (part.batch_spec(multi_pod)
                if shape.global_batch >= dp_size(mesh) else P())

    def fn(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    params_gb = _sharded_gb(pshape, pspec, axis_sizes)
    cache_gb = _sharded_gb(cache_shape, cspec, axis_sizes)
    return Cell(fn=fn, args=(pshape, cache_shape, inputs["tokens"]),
                in_shardings=(psh, csh, NamedSharding(mesh, tok_spec)),
                out_shardings=(NamedSharding(mesh, P()), csh),
                donate=(1,), kind="decode",
                tokens=shape.global_batch,
                n_active_params=n_active,
                analytic_gb={"params": params_gb, "cache": cache_gb,
                             "total": params_gb + cache_gb})


def lower_cell(cell: Cell, mesh):
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate)
    with jax.set_mesh(mesh):
        return jitted.lower(*cell.args)
