"""Production meshes.  A FUNCTION, not a module constant — importing this
module never touches jax device state (required so smoke tests keep their
single CPU device)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

from repro.compat import make_mesh


def _square_factor(n: int) -> Tuple[int, int]:
    """Most-square ``(data, model)`` factorization of ``n`` devices."""
    d = int(math.isqrt(n))
    while n % d:
        d -= 1
    return (d, n // d)


def production_mesh_shape(n_devices: int, *, multi_pod: bool = False,
                          n_pods: int = 2) -> Tuple[int, ...]:
    """Mesh shape for ``n_devices`` — pure, no jax.

    Single-pod: the most-square ``(data, model)`` factorization (256
    devices → the classic ``(16, 16)``).  Multi-pod: a leading ``pod``
    axis of ``n_pods`` over the per-pod factorization.  Raises a
    ``ValueError`` naming the device count when no layout exists.
    """
    if n_devices < 1:
        raise ValueError(
            f"cannot derive a production mesh from {n_devices} devices")
    if multi_pod:
        if n_pods < 2:
            raise ValueError(f"multi_pod needs n_pods >= 2, got {n_pods}")
        if n_devices % n_pods:
            raise ValueError(
                f"cannot derive a multi-pod mesh from {n_devices} devices: "
                f"not divisible by {n_pods} pods")
        return (n_pods,) + _square_factor(n_devices // n_pods)
    return _square_factor(n_devices)


def make_production_mesh(*, multi_pod: bool = False,
                         n_devices: Optional[int] = None,
                         n_pods: Optional[int] = None):
    """Build the production mesh over the devices actually present.

    The shape is DERIVED (:func:`production_mesh_shape`), not declared:
    ``n_devices`` defaults to ``len(jax.devices())`` and ``n_pods`` to
    the ``jax.distributed`` process count when the job is multi-process
    (else the classic dual-pod 2).  Pass either explicitly to pin a
    sub-fleet (the roofline dry-run pins its 256/512-chip cells).  jax is
    only touched here, at call time.
    """
    import jax
    if n_devices is None:
        n_devices = len(jax.devices())
    if n_pods is None:
        n_procs = int(jax.process_count())
        n_pods = n_procs if n_procs > 1 else 2
    shape = production_mesh_shape(n_devices, multi_pod=multi_pod,
                                  n_pods=n_pods)
    return make_mesh(shape, mesh_axes(multi_pod))


def mesh_axes(multi_pod: bool):
    return ("pod", "data", "model") if multi_pod else ("data", "model")


def dp_size(mesh) -> int:
    size = mesh.shape.get("data", 1)
    size *= mesh.shape.get("pod", 1)
    return size
