"""Production meshes.  A FUNCTION, not a module constant — importing this
module never touches jax device state (required so smoke tests keep their
single CPU device)."""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def mesh_axes(multi_pod: bool):
    return ("pod", "data", "model") if multi_pod else ("data", "model")


def dp_size(mesh) -> int:
    size = mesh.shape.get("data", 1)
    size *= mesh.shape.get("pod", 1)
    return size
