"""Shared building blocks: norms, RoPE, blocked attention, chunked loss.

Everything is a pure function over explicit param pytrees (no framework).
Initializers return nested dicts of jnp arrays; each ``init_*`` has a
matching ``spec_*`` in models/partitioning.py mapping the same tree to
PartitionSpecs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


def dtype_of(cfg) -> jnp.dtype:
    return DTYPES[cfg.dtype]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    """RMSNorm in f32 accumulation; gemma2 stores (w - 1) => scale (1 + w)."""
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(jnp.float32)
    return (xf * inv * scale).astype(x.dtype)


def l2_norm(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Parameter-free L2 normalization (chameleon qk-norm style, f32)."""
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float64) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, dim]; positions: [..., seq] (broadcastable)."""
    dim = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(dim, theta), jnp.float32)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, dim/2]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# blocked (flash-style) attention — pure jnp, remat & SPMD friendly
# ---------------------------------------------------------------------------

NEG = -1e30


def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      *, causal: bool = True, window: Optional[jax.Array] = None,
                      softcap: float = 0.0, block_q: int = 1024,
                      block_kv: int = 1024, q_offset: int = 0) -> jax.Array:
    """Online-softmax attention over KV blocks: O(S) memory instead of O(S^2).

    q: [B, Sq, Hkv, G, Dk]  (grouped query heads)
    k: [B, Skv, Hkv, Dk];  v: [B, Skv, Hkv, Dv]  (Dv may differ — MLA)
    window: scalar int32 (traced ok) — sliding window size; None/0 = full.
    q_offset: absolute position of q[0] (for decode/prefill continuation).
    Returns [B, Sq, Hkv, G, Dv].
    """
    B, Sq, Hkv, G, Dk = q.shape
    Dv = v.shape[-1]
    Skv = k.shape[1]
    scale = 1.0 / np.sqrt(Dk)
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    nq, nkv = -(-Sq // bq), -(-Skv // bkv)
    pad_q, pad_kv = nq * bq - Sq, nkv * bkv - Skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))

    qb = q.reshape(B, nq, bq, Hkv, G, Dk).astype(jnp.float32) * scale
    kb = k.reshape(B, nkv, bkv, Hkv, Dk)
    vb = v.reshape(B, nkv, bkv, Hkv, Dv)

    def q_block(iq, qi):
        q_pos = q_offset + iq * bq + jnp.arange(bq)

        def kv_block(carry, ikv):
            m_prev, l_prev, acc = carry
            kv_pos = ikv * bkv + jnp.arange(bkv)
            kk = jax.lax.dynamic_index_in_dim(kb, ikv, 1, keepdims=False)
            vv = jax.lax.dynamic_index_in_dim(vb, ikv, 1, keepdims=False)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kk.astype(jnp.float32))
            if softcap > 0.0:
                s = softcap * jnp.tanh(s / softcap)
            mask = kv_pos[None, :] <= q_pos[:, None] if causal else \
                jnp.ones((bq, bkv), bool)
            mask = mask & (kv_pos[None, :] < Skv) & (q_pos[:, None] < q_offset + Sq)
            if window is not None:
                mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG)
            m_new = jnp.maximum(m_prev, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vv.astype(jnp.float32))
            return (m_new, l_new, acc), None

        init = (jnp.full((B, Hkv, G, bq), NEG, jnp.float32),
                jnp.zeros((B, Hkv, G, bq), jnp.float32),
                jnp.zeros((B, Hkv, G, bq, Dv), jnp.float32))
        # causal: kv blocks after this q block contribute nothing; keeping the
        # scan bound static is required for SPMD, masking handles the rest.
        # checkpoint the block body: without it scan-backward STACKS the
        # [bq, bkv] score blocks across iterations (observed ~2.5 TB of HBM
        # traffic per step) — recompute-in-backward keeps flash-attention's
        # O(S) memory in the backward pass too.
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_block), init,
                                      jnp.arange(nkv))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.einsum("bhgqd->bqhgd", out)

    outs = jax.lax.map(lambda i: q_block(i, jax.lax.dynamic_index_in_dim(qb, i, 1, False)),
                       jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * bq, Hkv, G, Dv)
    return out[:, :Sq].astype(q.dtype)


def cache_decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                           length: jax.Array, *, softcap: float = 0.0,
                           window: Optional[jax.Array] = None) -> jax.Array:
    """One-token decode attention over a padded cache (jnp path).

    q: [B, 1, Hkv, G, Dh]; caches [B, S, Hkv, Dh]; length [B] current count
    (the new token is at index length-1).  The Pallas flash-decode kernel in
    kernels/decode_attn implements the same contract for the TPU target.
    """
    B, _, Hkv, G, Dh = q.shape
    S = k_cache.shape[1]
    scale = 1.0 / np.sqrt(Dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32) * scale,
                   k_cache.astype(jnp.float32))
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(S)[None]
    mask = pos < length[:, None]
    if window is not None:
        mask = mask & (pos > length[:, None] - 1 - window)
    s = jnp.where(mask[:, None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# chunked cross-entropy (never materialises [B, S, V] at once)
# ---------------------------------------------------------------------------

def chunked_xent(x: jax.Array, head: jax.Array, labels: jax.Array,
                 *, chunk: int = 2048, softcap: float = 0.0,
                 cs_logits=None) -> jax.Array:
    """x: [B, S, D]; head: [D, V]; labels: [B, S] -> mean token NLL (f32)."""
    B, S, D = x.shape
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    xc = x.reshape(B, n, chunk, D)
    lc = labels.reshape(B, n, chunk)

    def per_chunk(carry, inp):
        xi, li = inp
        logits = (xi @ head).astype(jnp.float32)
        if cs_logits is not None:
            logits = cs_logits(logits)
        if softcap > 0.0:
            logits = softcap * jnp.tanh(logits / softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # label gather as reduce-after-multiply: with a vocab-sharded V this
        # lowers to a tiny [B, chunk] psum instead of all-reducing the full
        # logits block (observed 200+ GB/device of all-reduce otherwise).
        V = logits.shape[-1]
        onehot = (jnp.arange(V)[None, None, :] == li[..., None])
        ll = jnp.where(onehot, logits, 0.0).sum(-1)
        valid = li >= 0
        nll = jnp.where(valid, lse - ll, 0.0)
        return carry + jnp.stack([nll.sum(), valid.sum().astype(jnp.float32)]), None

    # checkpoint the chunk body: scan-backward otherwise STACKS every chunk's
    # [B, chunk, V] logits as residuals (observed 40+ GB/device at V=152k),
    # defeating the chunking; recompute-in-backward keeps one chunk live.
    acc, _ = jax.lax.scan(jax.checkpoint(per_chunk), jnp.zeros(2, jnp.float32),
                          (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(lc, 1, 0)))
    return acc[0] / jnp.maximum(acc[1], 1.0)


def head_logits(x: jax.Array, head: jax.Array, softcap: float = 0.0) -> jax.Array:
    logits = (x @ head).astype(jnp.float32)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits
