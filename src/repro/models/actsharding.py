"""Activation sharding constraints (mixin for model objects).

Without explicit constraints GSPMD is free to pick activation layouts, and
on these programs it chooses batch-REPLICATED, d_model-sharded activations —
every chip then computes the whole global batch's loss (16x redundant flops
and ~150 GB of temps, observed on the first gemma2 dry-run).  Pinning the
residual stream to batch-over-DP at block boundaries (MaxText practice)
restores the intended data-parallel execution.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


class ActShard:
    """Mixin: model objects carry (mesh, multi_pod) and constrain hiddens."""
    mesh = None
    multi_pod: bool = False

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return ("pod", "data") if self.multi_pod else ("data",)

    def _dp_size(self) -> int:
        s = self.mesh.shape.get("data", 1)
        s *= self.mesh.shape.get("pod", 1)
        return s

    def _cs(self, x, spec: P):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def cs_hidden(self, x):
        """[B, S, d] -> batch over DP, SEQUENCE over model (sequence-parallel
        residual storage, Megatron-SP style): the per-layer remat residuals
        then occupy 1/|model| of the memory (llama3-405b: 31.5 GB -> ~2 GB per
        device), and GSPMD turns the TP output all-reduces into
        reduce-scatter + all-gather pairs of the same total bytes."""
        if self.mesh is None:
            return x
        dp = self.dp_axes if x.shape[0] % self._dp_size() == 0 else None
        tp = None
        if getattr(self.cfg, "sp_residuals", True) and \
                x.shape[1] % self.mesh.shape.get("model", 1) == 0:
            tp = "model"
        return self._cs(x, P(dp, tp, None))

    def cs_logits(self, x):
        """[..., V] -> vocab over model, batch over DP."""
        if self.mesh is None:
            return x
        dp = self.dp_axes if x.shape[0] % self._dp_size() == 0 else None
        rest = (None,) * (x.ndim - 2)
        return self._cs(x, P(dp, *rest, "model"))

    def cs_params(self, lp):
        """Pin per-layer (scan-sliced) params to their rule shardings INSIDE
        the scan body.  The transpose of this constraint pins the per-layer
        weight-GRAD contribution, turning the scan-transpose accumulation
        into sharded reduce-scatters instead of full-tensor all-reduces
        (llama3-405b: 16 TB/step of [16384,16384] f32 ARs otherwise)."""
        if self.mesh is None:
            return lp
        import jax
        from repro.models.partitioning import param_rules, tree_specs
        rules = param_rules(self.cfg, self.multi_pod)
        specs = tree_specs(lp, rules, dict(self.mesh.shape))
        return jax.tree.map(
            lambda a, s: jax.lax.with_sharding_constraint(
                a, NamedSharding(self.mesh, s)), lp, specs)

    def cs_full_hidden(self, x):
        """Megatron-SP "g": gather the seq-sharded residual to full sequence
        BEFORE the block's matmuls.  Weight gradients then reduce locally on
        each model shard; leaving the matmul inputs seq-sharded instead makes
        every weight grad an all-reduce over "model" (observed 37 TB/step on
        llama3-405b)."""
        if self.mesh is None:
            return x
        dp = self.dp_axes if x.shape[0] % self._dp_size() == 0 else None
        return self._cs(x, P(dp, None, None))

    def cs_qkv(self, q, k, v):
        """Pin attention layouts: q [B,S,Hkv,G,dh] heads over model (on Hkv
        if divisible, else on G), k/v [B,S,Hkv,dh] heads over model or
        replicated (GQA caches are small).  Without this, seq-sharded
        residuals + head-sharded weights make GSPMD reshard inside every
        kv-block scan iteration (observed 421k all-gathers on llama)."""
        if self.mesh is None:
            return q, k, v
        ms = self.mesh.shape.get("model", 1)
        dp = self.dp_axes if q.shape[0] % self._dp_size() == 0 else None
        Hkv, G = q.shape[2], q.shape[3]
        if Hkv % ms == 0:
            qspec = P(dp, None, "model", None, None)
        elif G % ms == 0:
            qspec = P(dp, None, None, "model", None)
        else:
            qspec = P(dp, None, None, None, None)
        kspec = P(dp, None, "model" if Hkv % ms == 0 else None, None)
        return (self._cs(q, qspec), self._cs(k, kspec), self._cs(v, kspec))

    def cs_kv(self, x):
        """Per-layer cache [B, S, Hkv, dh] (or [B, S, r]): seq over model."""
        if self.mesh is None:
            return x
        dp = self.dp_axes if x.shape[0] % self._dp_size() == 0 else None
        rest = (None,) * (x.ndim - 3)
        return self._cs(x, P(dp, "model", None, *rest))
