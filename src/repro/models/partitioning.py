"""Sharding rules: param/cache/batch PartitionSpecs for every architecture.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  Design (DESIGN.md §4):

* batch / activations over DP = ("pod", "data")
* tensor parallel over "model" (flattened head/ff dims, so unequal head
  counts never block divisibility)
* FSDP of params over "data" ONLY — params stay replicated across pods so
  every per-layer all-gather is intra-pod ICI; this is the paper's
  "aggregate before you inject" applied to parameter traffic.
* optimizer state over ("pod", "data") (+ model) — ZeRO-3 over the full
  fleet; one cross-pod gather per step (update), not per layer.
* experts over ("pod", "model") — expert parallelism crosses pods, which is
  exactly where the NAP dispatch (models/moe.py) pays off.
* decode KV caches over ("model" on the SEQUENCE dim) — sequence-parallel
  decode; works for any kv-head count, and XLA turns the softmax reductions
  into small cross-chip psums.

Rules are ordered regexes over "/"-joined param paths; first match wins.
"""
from __future__ import annotations

import re
from typing import Any, List, Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

Rules = List[Tuple[str, P]]


def _axes(multi_pod: bool):
    dp = ("pod", "data") if multi_pod else ("data",)
    fsdp = "data"
    tp = "model"
    ep = ("pod", "model") if multi_pod else ("model",)
    return dp, fsdp, tp, ep


def param_rules(cfg, multi_pod: bool, *, zero3: bool = False) -> Rules:
    """zero3=True returns the optimizer-state variant (fsdp over all DP)."""
    dp, fsdp, tp, ep = _axes(multi_pod)
    # experts already consume the pod axis (EP spans pods); their FSDP dim
    # can only take "data" — a mesh axis may appear once per spec.
    efsdp = "data"
    if zero3:
        fsdp = dp  # shard optimizer state over every data-parallel chip
    L = None  # leading stacked-layer dim is never sharded
    rules: Rules = [
        # --- embeddings / head: vocab-sharded over model -----------------
        (r"embed$", P(tp, None)),
        (r"head$", P(None, tp)),
        # --- MoE: experts over EP axes, FSDP over data on the d_model dim
        # (qwen3's 222B of expert weights would otherwise sit replicated
        # across the data axis: 27 GB/chip)
        (r"moe/router$", P(L, None, None)),
        (r"moe/w_(gate|up)$", P(L, ep, efsdp, None)),
        (r"moe/w_down$", P(L, ep, None, efsdp)),
        (r"moe/shared/w_(gate|up)$", P(L, fsdp, tp)),
        (r"moe/shared/w_down$", P(L, tp, fsdp)),
        # --- MLA ------------------------------------------------------------
        (r"attn/wq_a$", P(L, fsdp, None)),
        (r"attn/wq_b$", P(L, fsdp, tp)),
        (r"attn/wkv_a$", P(L, fsdp, None)),
        (r"attn/wkv_b$", P(L, None, tp)),
        (r"attn/(q_norm|k_norm|kv_norm)$", P(L, None)),
        # --- GQA attention ----------------------------------------------------
        (r"attn/w(q|k|v)$", P(L, fsdp, tp)),
        (r"attn/wo$", P(L, tp, fsdp)),
        (r"xattn/w(q|k|v)$", P(L, fsdp, tp)),
        (r"xattn/wo$", P(L, tp, fsdp)),
        # --- dense FFN -----------------------------------------------------------
        (r"ffn/w_(gate|up)$", P(L, fsdp, tp)),
        (r"ffn/w_down$", P(L, tp, fsdp)),
        # --- mamba2 -----------------------------------------------------------------
        (r"mamba/in_proj$", P(L, fsdp, tp)),
        (r"mamba/bc_proj$", P(L, fsdp, None)),
        (r"mamba/dt_proj$", P(L, fsdp, None)),
        (r"mamba/conv_w$", P(L, None, tp)),
        (r"mamba/out_proj$", P(L, tp, fsdp)),
        (r"mamba/(dt_bias|a_log|d_skip)$", P(L, None)),
        # --- rwkv6 ---------------------------------------------------------------------
        (r"block/w(r|k|v|g)$", P(L, fsdp, tp)),
        (r"block/wo$", P(L, tp, fsdp)),
        (r"block/w_lora_a$", P(L, fsdp, None)),
        (r"block/w_lora_b$", P(L, None, tp)),
        (r"block/c(k|r)$", P(L, fsdp, tp)),
        (r"block/cv$", P(L, tp, fsdp)),
        (r"block/(mix_.|cmix_.|w0|u|ln_x)$", P(L, None)),
        # --- norms & leftovers: replicated -------------------------------------------
        (r".*", P()),
    ]
    return rules


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _match(rules: Rules, path: str, shape, axis_sizes) -> P:
    for pat, spec in rules:
        if re.search(pat, path):
            return _guard(_fit(spec, path, len(shape)), shape, axis_sizes)
    return P()


def _guard(spec: P, shape, axis_sizes) -> P:
    """pjit ARGUMENT shardings must divide evenly: drop the sharding of any
    dim whose size is not a multiple of its mesh-axes product (whisper's
    51865 vocab, batch-1 long_500k caches, ...)."""
    if axis_sizes is None:
        return spec
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= axis_sizes.get(a, 1)
        out.append(entry if shape[i] % size == 0 else None)
    return P(*out)


def _fit(spec: P, path: str, ndim: int) -> P:
    """Adjust a rule spec to the actual rank: rules are written for the
    STACKED layout (leading layer dim).  Unstacked params (zamba shared
    block, whisper tails, single layers) drop the leading None; shorter
    params (norm vectors) are replicated."""
    entries = list(spec)
    if len(entries) == ndim:
        return P(*entries)
    if len(entries) - 1 == ndim and (entries[0] is None):
        return P(*entries[1:])
    if len(entries) + 1 == ndim:
        return P(None, *entries)
    if ndim <= 1:
        return P()
    # fall back: replicate
    return P()


def tree_specs(tree, rules: Rules, axis_sizes=None):
    """Map a pytree of arrays/ShapeDtypeStructs to a spec tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _match(rules, _path_str(path), leaf.shape,
                                  axis_sizes),
        tree)


def param_specs(cfg, params_shape, multi_pod: bool, zero3: bool = False,
                axis_sizes=None):
    return tree_specs(params_shape, param_rules(cfg, multi_pod, zero3=zero3),
                      axis_sizes)


# ---------------------------------------------------------------------------
# batch + cache specs
# ---------------------------------------------------------------------------

def batch_spec(multi_pod: bool) -> P:
    dp, _, _, _ = _axes(multi_pod)
    return P(dp, None)


def frames_spec(multi_pod: bool) -> P:
    dp, _, _, _ = _axes(multi_pod)
    return P(dp, None, None)


def cache_rules(cfg, multi_pod: bool) -> Rules:
    dp, _, tp, _ = _axes(multi_pod)
    return [
        # KV caches [L, B, S, Hkv, dh]: batch over DP, SEQUENCE over model
        (r"layers/(k|v)$", P(None, dp, tp, None, None)),
        (r"shared/(k|v)$", P(None, dp, tp, None, None)),
        (r"x(k|v)$", P(None, dp, tp, None, None)),
        # MLA latent cache [L, B, S, r]
        (r"layers/(c_kv|k_rope)$", P(None, dp, tp, None)),
        (r"dense_layers/(k|v)$", P(None, dp, tp, None, None)),
        (r"dense_layers/(c_kv|k_rope)$", P(None, dp, tp, None)),
        # SSM states: batch over DP, heads over model
        (r"mamba/h$", P(None, dp, tp, None, None)),
        (r"mamba/conv$", P(None, dp, None, tp)),
        (r"state/S$", P(None, dp, tp, None, None)),
        (r"state/last_x(_c)?$", P(None, dp, tp)),
        (r"length$", P(dp)),
        (r".*", P()),
    ]


def cache_specs(cfg, cache_shape, multi_pod: bool, axis_sizes=None):
    return tree_specs(cache_shape, cache_rules(cfg, multi_pod), axis_sizes)
