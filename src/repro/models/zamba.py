"""Zamba2-style hybrid: Mamba2 backbone + a SHARED attention block.

54 Mamba2 blocks; one parameter-shared (attention + MLP) block is applied
every ``shared_attn_every`` layers (9 applications for 54/6).  Zamba2's
per-invocation LoRA adapters and embedding-concat input are simplified to a
plain residual application of the shared block (recorded in DESIGN.md
§Arch-applicability).

Because the sequence mixer is a state-space scan, the ``long_500k`` decode
cell runs here: the Mamba2 state is O(1) in context, and the shared block's
KV cache (one per application) is the only context-length memory.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.actsharding import ActShard
from repro.models import ssm as ssm_mod
from repro.models.common import (chunked_xent, dtype_of, embed_init,
                                 head_logits, rms_norm)
from repro.models.config import ModelConfig
from repro.models.ffn import ffn_apply, ffn_init


@dataclasses.dataclass
class ZambaModel(ActShard):
    cfg: ModelConfig
    mesh: Any = None
    ep: Any = None
    multi_pod: bool = False

    @property
    def n_apps(self) -> int:
        return self.cfg.n_layers // self.cfg.shared_attn_every

    def init(self, key) -> Dict:
        cfg = self.cfg
        dtype = dtype_of(cfg)
        ks = jax.random.split(key, 5)

        def mamba_layer(k):
            return {"norm": jnp.ones((cfg.d_model,), dtype),
                    "mamba": ssm_mod.mamba2_init(k, cfg, dtype)}

        k1, k2 = jax.random.split(ks[2])
        return {
            "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
            "mamba_layers": jax.vmap(mamba_layer)(
                jax.random.split(ks[1], cfg.n_layers)),
            "shared": {"norm1": jnp.ones((cfg.d_model,), dtype),
                       "attn": attn.gqa_init(k1, cfg, dtype),
                       "norm2": jnp.ones((cfg.d_model,), dtype),
                       "ffn": ffn_init(k2, cfg.d_model, cfg.d_ff, dtype)},
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }

    def head_matrix(self, params):
        return params["embed"].T

    # ---- training -------------------------------------------------------------
    def hidden(self, params, tokens: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = params["embed"][tokens]
        per = cfg.shared_attn_every

        def mamba_body(x, lp):
            lp = self.cs_params(lp)
            x = self.cs_full_hidden(x)
            h = rms_norm(x, lp["norm"])
            return self.cs_hidden(x + ssm_mod.mamba2_apply(lp["mamba"], cfg, h)), None

        body_fn = jax.checkpoint(mamba_body) if cfg.remat else mamba_body

        def shared_apply(x):
            sp = params["shared"]
            h = rms_norm(x, sp["norm1"])
            x = x + attn.gqa_apply(sp["attn"], cfg, h, cs_qkv=self.cs_qkv)
            h = rms_norm(x, sp["norm2"])
            return x + ffn_apply(sp["ffn"], h)

        shared_fn = jax.checkpoint(shared_apply) if cfg.remat else shared_apply
        for seg in range(self.n_apps):
            seg_params = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, seg * per, per, 0),
                params["mamba_layers"])
            x, _ = jax.lax.scan(body_fn, x, seg_params)
            x = shared_fn(x)
        return rms_norm(x, params["final_norm"])

    def loss(self, params, batch: Dict) -> jax.Array:
        h = self.hidden(params, batch["tokens"])
        return chunked_xent(h, self.head_matrix(params), batch["labels"],
                            chunk=self.cfg.xent_chunk,
                            cs_logits=self.cs_logits)

    # ---- serving ----------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int) -> Dict:
        cfg = self.cfg
        dtype = dtype_of(cfg)
        state = ssm_mod.mamba2_init_state(cfg, batch, dtype)
        return {
            "mamba": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), state),
            "shared": {
                "k": jnp.zeros((self.n_apps, batch, max_seq, cfg.n_kv_heads,
                                cfg.head_dim), dtype),
                "v": jnp.zeros((self.n_apps, batch, max_seq, cfg.n_kv_heads,
                                cfg.head_dim), dtype),
            },
            "length": jnp.zeros((batch,), jnp.int32),
        }

    def decode_step(self, params, cache: Dict, tokens: jax.Array
                    ) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        length = cache["length"]
        x = params["embed"][tokens]
        per = cfg.shared_attn_every

        def mamba_body(x, inp):
            lp, st = inp
            h = rms_norm(x, lp["norm"])
            y, st = ssm_mod.mamba2_decode(lp["mamba"], cfg, h, st)
            return x + y, st

        new_states = []
        new_k, new_v = [], []
        for seg in range(self.n_apps):
            seg_params = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, seg * per, per, 0),
                params["mamba_layers"])
            seg_state = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, seg * per, per, 0),
                cache["mamba"])
            x, st = jax.lax.scan(mamba_body, x, (seg_params, seg_state))
            new_states.append(st)
            sp = params["shared"]
            h = rms_norm(x, sp["norm1"])
            cl = {"k": cache["shared"]["k"][seg], "v": cache["shared"]["v"][seg]}
            y, cl = attn.gqa_decode(sp["attn"], cfg, h, cl, length)
            x = x + y
            h = rms_norm(x, sp["norm2"])
            x = x + ffn_apply(sp["ffn"], h)
            new_k.append(cl["k"])
            new_v.append(cl["v"])
        x = rms_norm(x, params["final_norm"])
        logits = head_logits(x, self.head_matrix(params))
        new_cache = {
            "mamba": jax.tree.map(lambda *a: jnp.concatenate(a, 0), *new_states),
            "shared": {"k": jnp.stack(new_k), "v": jnp.stack(new_v)},
            "length": length + 1,
        }
        return logits, new_cache

    def prefill(self, params, tokens: jax.Array) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        B, S = tokens.shape
        x = params["embed"][tokens]
        per = cfg.shared_attn_every
        states, ks, vs = [], [], []

        def mamba_prefill(x, lp):
            h = rms_norm(x, lp["norm"])
            y = ssm_mod.mamba2_apply(lp["mamba"], cfg, h)
            # final state for decode continuation — recompute via chunked form
            # is cheap relative to the scan; use the sequential state builder.
            return x + y, None

        body_fn = jax.checkpoint(mamba_prefill) if cfg.remat else mamba_prefill
        for seg in range(self.n_apps):
            seg_params = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, seg * per, per, 0),
                params["mamba_layers"])
            x, _ = jax.lax.scan(body_fn, x, seg_params)
            sp = params["shared"]
            h = rms_norm(x, sp["norm1"])
            positions = jnp.arange(S)[None, :]
            q, k, v = attn._project_qkv(sp["attn"], cfg, h, positions)
            if self.mesh is not None:
                q, k, v = self.cs_qkv(q, k, v)
            from repro.models.common import blocked_attention
            y = blocked_attention(q, k, v, causal=True,
                                  block_q=cfg.attn_block_q,
                                  block_kv=cfg.attn_block_kv)
            x = x + y.reshape(B, S, -1) @ sp["attn"]["wo"]
            h = rms_norm(x, sp["norm2"])
            x = x + ffn_apply(sp["ffn"], h)
            ks.append(k)
            vs.append(v)
        x = rms_norm(x, params["final_norm"])
        logits = head_logits(x[:, -1], self.head_matrix(params))
        # mamba decode states are not rebuilt here (prefill->decode handoff
        # re-runs the tail chunk); serving keeps caches from decode_step.
        cache = {"shared": {"k": jnp.stack(ks), "v": jnp.stack(vs)},
                 "mamba": jax.tree.map(
                     lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape),
                     ssm_mod.mamba2_init_state(cfg, B, dtype_of(cfg))),
                 "length": jnp.full((B,), S, jnp.int32)}
        return logits, cache
