"""Model configuration shared by all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | audio | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                # 0 -> d_model // n_heads

    # --- attention variants --------------------------------------------------
    rope_theta: float = 10_000.0
    sliding_window: int = 0        # gemma2 local layers (0 = full)
    alt_local_global: bool = False # gemma2: even layers local, odd global
    attn_softcap: float = 0.0      # gemma2 attention logit soft-cap
    final_softcap: float = 0.0     # gemma2 output logit soft-cap
    qk_norm: bool = False          # qwen3 / chameleon
    post_norms: bool = False       # gemma2 sandwich norms

    # --- MLA (deepseek-v2) ----------------------------------------------------
    mla_kv_lora: int = 0           # kv compression rank (0 = standard GQA)
    mla_q_lora: int = 0
    mla_rope_dim: int = 64
    mla_v_head: int = 128
    mla_qk_nope: int = 128

    # --- MoE -------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_dff: int = 0               # per-expert hidden (d_ff used for dense FFN)
    n_shared_experts: int = 0      # deepseek shared experts (x moe_dff each)
    first_dense_layers: int = 0    # deepseek: leading dense layers
    capacity_factor: float = 1.25
    moe_dispatch: str = "flat"     # flat | nap | auto  (see repro/moe/README.md)
    wire_dtype: str = "f32"        # dispatch wire payload: f32 | bf16 | fp8_e4m3
                                   # ("f32" = identity codec, bit-identical)

    # --- SSM / hybrid -----------------------------------------------------------
    ssm_state: int = 0             # mamba2 N
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256           # SSD chunk length (TPU matmul form)
    shared_attn_every: int = 0     # zamba2: shared attn block period
    rwkv_head_size: int = 64
    rwkv_chunk: int = 0            # 0 = stepwise scan; >0 = chunked GLA form

    # --- encoder-decoder (whisper) ----------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 1500        # precomputed audio frame embeddings (stub)
    is_encoder_decoder: bool = False

    # --- embedding / head ---------------------------------------------------------
    tie_embeddings: bool = True
    embed_scale: bool = False      # gemma2 multiplies embeddings by sqrt(d)

    # --- numerics / execution ------------------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    attn_block_q: int = 1024       # blocked-attention tile sizes
    attn_block_kv: int = 1024
    xent_chunk: int = 2048         # chunked cross-entropy seq tile
    grad_accum: int = 1            # microbatches per train step
    use_pallas: bool = False       # opt-in Pallas decode kernel (TPU target)
    opt_state_dtype: str = "float32"   # "int8" -> 8-bit Adam moments
    opt_master_fp32: bool = True       # fp32 master copies of bf16 params
    sp_residuals: bool = True          # store residuals sequence-sharded (SP)

    # ------------------------------------------------------------------------
    def __post_init__(self) -> None:
        # fail at construction, not deep inside a traced dispatch
        dispatch_modes = ("flat", "nap", "auto")
        if self.moe_dispatch not in dispatch_modes:
            raise ValueError(
                f"moe_dispatch must be one of {'|'.join(dispatch_modes)}, "
                f"got {self.moe_dispatch!r}")
        wire_dtypes = ("f32", "bf16", "fp8_e4m3")
        if self.wire_dtype not in wire_dtypes:
            raise ValueError(
                f"wire_dtype must be one of {'|'.join(wire_dtypes)}, "
                f"got {self.wire_dtype!r}")

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Total parameter count (used for MODEL_FLOPS = 6 N D)."""
        return sum(_param_sizes(self))

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k + shared experts only)."""
        return sum(_param_sizes(self, active_only=True))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def _param_sizes(cfg: ModelConfig, active_only: bool = False):
    d, dh = cfg.d_model, cfg.head_dim
    yield cfg.vocab * d                                  # embedding
    if not cfg.tie_embeddings:
        yield cfg.vocab * d

    def attn_size() -> int:
        if cfg.mla_kv_lora:
            q_in = cfg.mla_q_lora or d
            size = 0
            if cfg.mla_q_lora:
                size += d * cfg.mla_q_lora
            size += q_in * cfg.n_heads * (cfg.mla_qk_nope + cfg.mla_rope_dim)
            size += d * (cfg.mla_kv_lora + cfg.mla_rope_dim)
            size += cfg.mla_kv_lora * cfg.n_heads * (cfg.mla_qk_nope + cfg.mla_v_head)
            size += cfg.n_heads * cfg.mla_v_head * d
            return size
        return (d * cfg.n_heads * dh + 2 * d * cfg.n_kv_heads * dh
                + cfg.n_heads * dh * d)

    def dense_ffn(ff: int) -> int:
        return 3 * d * ff

    def layer_size(moe: bool) -> int:
        size = 2 * d  # norms
        if cfg.family == "ssm":      # rwkv6 block
            return rwkv_block_size(cfg)
        size += attn_size()
        if moe:
            n_routed = cfg.top_k if active_only else cfg.n_experts
            size += d * cfg.n_experts  # router (always resident)
            size += n_routed * dense_ffn(cfg.moe_dff) // 1
            size += cfg.n_shared_experts * dense_ffn(cfg.moe_dff)
        else:
            size += dense_ffn(cfg.d_ff)
        return size

    if cfg.family == "hybrid":       # zamba2
        yield cfg.n_layers * mamba_block_size(cfg)
        yield layer_size(False)      # one shared attention block
        return
    if cfg.family == "ssm":
        yield cfg.n_layers * rwkv_block_size(cfg)
        return
    n_moe = max(cfg.n_layers - cfg.first_dense_layers, 0) if cfg.is_moe else 0
    n_dense = cfg.n_layers - n_moe
    yield n_dense * layer_size(False) if not cfg.is_moe else n_dense * (
        2 * d + attn_size() + dense_ffn(cfg.d_ff if not cfg.is_moe else 12288))
    if n_moe:
        yield n_moe * layer_size(True)
    if cfg.is_encoder_decoder:
        # encoder layers + decoder cross-attention
        yield cfg.encoder_layers * (2 * d + attn_size() + dense_ffn(cfg.d_ff))
        yield cfg.n_layers * (d + attn_size())


def mamba_block_size(cfg: ModelConfig) -> int:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n_heads = d_in // cfg.ssm_head_dim
    return (d * (2 * d_in + 2 * n_heads)          # in_proj (x, z) + dt, A bias
            + cfg.ssm_conv * d_in                 # conv
            + 2 * d_in * cfg.ssm_state            # B, C proj (grouped)
            + d_in * d                            # out proj
            + 2 * d)                              # norms


def rwkv_block_size(cfg: ModelConfig) -> int:
    d = cfg.d_model
    return (4 * d * d          # r, k, v, output of time mix
            + d * d            # gate
            + 6 * 32 * d * 2   # data-dependent decay LoRA (approx)
            + 2 * d * cfg.d_ff + d * cfg.d_ff  # channel mix (k, v, r)
            + 2 * d)
