"""Mamba2 (SSD) block — chunked matmul form for the TPU MXU.

The CUDA Mamba2 kernel is a fused scan; the TPU adaptation (DESIGN.md §2)
uses the SSD *block decomposition*: within a chunk of length L the recurrence
is materialised as an (L x L) decay-masked attention-like matmul (MXU work),
and only the chunk-to-chunk state is carried through a short ``lax.scan``
(S / L steps instead of S).  ``mamba2_scan_ref`` is the sequential oracle.

Recurrence (scalar-identity A per head, as in Mamba2):
    h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * x_t B_t^T        h: [P, N]
    y_t = h_t C_t + D_h x_t
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def mamba2_init(key, cfg, dtype) -> Dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in, dtype),     # x, z (gate)
        "bc_proj": dense_init(ks[1], d, 2 * N, dtype),        # B, C (1 group)
        "dt_proj": dense_init(ks[2], d, H, dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "a_log": jnp.zeros((H,), jnp.float32),                # A = -exp(a_log)
        "d_skip": jnp.ones((H,), jnp.float32),
        "conv_w": (jax.random.normal(ks[3], (cfg.ssm_conv, d_in), jnp.float32)
                   * 0.1).astype(dtype),
        "out_proj": dense_init(ks[4], d_in, d, dtype),
    }


def _project(p, cfg, x, conv_state=None):
    """Shared projections.  x: [B, S, d].  Returns (u, z, B, C, dt, new_conv).

    conv_state: [B, conv-1, d_in] tail of the previous tokens (decode)."""
    B, S, d = x.shape
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    xz = x @ p["in_proj"]
    xs, z = xz[..., :d_in], xz[..., d_in:]
    # causal depthwise conv over the sequence
    K = cfg.ssm_conv
    if conv_state is None:
        pad = jnp.zeros((B, K - 1, d_in), xs.dtype)
    else:
        pad = conv_state.astype(xs.dtype)
    xpad = jnp.concatenate([pad, xs], axis=1)
    new_conv = xpad[:, -(K - 1):] if K > 1 else jnp.zeros((B, 0, d_in), xs.dtype)
    conv = sum(xpad[:, i:i + S] * p["conv_w"][i][None, None] for i in range(K))
    u = jax.nn.silu(conv)
    bc = x @ p["bc_proj"]
    N = cfg.ssm_state
    B_mat, C_mat = bc[..., :N], bc[..., N:]
    dt = jax.nn.softplus((x @ p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"])                      # [B, S, H]
    return u, z, B_mat, C_mat, dt, new_conv


def mamba2_apply(p, cfg, x: jax.Array) -> jax.Array:
    """Chunked SSD over a full sequence.  x: [B, S, d] -> [B, S, d]."""
    B, S, d = x.shape
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    L = min(cfg.ssm_chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L
    u, z, Bm, Cm, dt, _ = _project(p, cfg, x)
    d_in = u.shape[-1]
    H = d_in // P
    uh = u.reshape(B, nc, L, H, P)
    dtc = dt.reshape(B, nc, L, H)
    Bc = Bm.reshape(B, nc, L, N).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, L, N).astype(jnp.float32)
    A = -jnp.exp(p["a_log"])                                  # [H]
    la = dtc * A[None, None, None]                            # log decay/step
    lcum = jnp.cumsum(la, axis=2)                             # [B,nc,L,H]

    # ---- intra-chunk: decay-masked (L x L) matmul ---------------------------
    # M[i, j] = (C_i . B_j) * exp(lcum_i - lcum_j) * dt_j   for j <= i
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                # [B,nc,L,L]
    ratio = jnp.exp(lcum[:, :, :, None] - lcum[:, :, None])   # [B,nc,L,L,H]
    tri = jnp.tril(jnp.ones((L, L), bool))
    m = jnp.where(tri[None, None, :, :, None], cb[..., None] * ratio, 0.0)
    m = m * dtc[:, :, None, :, :]                             # dt_j on source
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m.astype(uh.dtype), uh)

    # ---- chunk states + inter-chunk scan ------------------------------------
    # state contribution of chunk c: sum_j exp(lcum_L - lcum_j) dt_j u_j B_j^T
    tail = jnp.exp(lcum[:, :, -1:, :] - lcum)                 # [B,nc,L,H]
    su = (uh * (tail * dtc)[..., None]).astype(jnp.float32)
    s_chunk = jnp.einsum("bclhp,bcln->bchpn", su, Bc)         # [B,nc,H,P,N]
    decay_chunk = jnp.exp(lcum[:, :, -1])                     # [B,nc,H]

    def step(h, inp):
        s_c, dec = inp                                        # [B,H,P,N],[B,H]
        h_new = h * dec[..., None, None] + s_c
        return h_new, h                                       # emit PREVIOUS

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    _, h_prevs = jax.lax.scan(step, h0,
                              (jnp.moveaxis(s_chunk, 1, 0),
                               jnp.moveaxis(decay_chunk, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                     # [B,nc,H,P,N]

    # y_inter_i = C_i . (exp(lcum_i) * h_prev)
    dec_i = jnp.exp(lcum)                                     # [B,nc,L,H]
    y_inter = jnp.einsum("bcin,bchpn->bcihp", Cc, h_prevs) * dec_i[..., None]
    y = (y_intra.astype(jnp.float32) + y_inter)               # [B,nc,L,H,P]
    y = y + uh.astype(jnp.float32) * p["d_skip"][None, None, None, :, None]
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba2_init_state(cfg, batch: int, dtype) -> Dict:
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    return {
        "h": jnp.zeros((batch, H, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_in), dtype),
    }


def mamba2_decode(p, cfg, x: jax.Array, state: Dict) -> Tuple[jax.Array, Dict]:
    """One-token step.  x: [B, 1, d]."""
    B = x.shape[0]
    P, N = cfg.ssm_head_dim, cfg.ssm_state
    u, z, Bm, Cm, dt, new_conv = _project(p, cfg, x, state["conv"])
    d_in = u.shape[-1]
    H = d_in // P
    uh = u.reshape(B, H, P).astype(jnp.float32)
    A = -jnp.exp(p["a_log"])
    dec = jnp.exp(dt[:, 0] * A[None])                         # [B, H]
    h = state["h"] * dec[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", uh * dt[:, 0][..., None], Bm[:, 0].astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", h, Cm[:, 0].astype(jnp.float32))
    y = y + uh * p["d_skip"][None, :, None]
    y = y.reshape(B, 1, d_in).astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"], {"h": h, "conv": new_conv}


# ---------------------------------------------------------------------------
# sequential oracle
# ---------------------------------------------------------------------------

def mamba2_scan_ref(p, cfg, x: jax.Array) -> jax.Array:
    """Step-by-step recurrence (slow, exact) — the test oracle."""
    B, S, d = x.shape
    state = mamba2_init_state(cfg, B, x.dtype)
    outs = []
    for t in range(S):
        y, state = mamba2_decode(p, cfg, x[:, t:t + 1], state)
        outs.append(y)
    return jnp.concatenate(outs, axis=1)
