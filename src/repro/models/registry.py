"""Model registry: config -> model object (family dispatch) + exact counts."""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np

from repro.models.config import ModelConfig
from repro.models.moe import EPInfo
from repro.models.transformer import LM
from repro.models.whisper import WhisperModel
from repro.models.zamba import ZambaModel


def build_model(cfg: ModelConfig, mesh=None, multi_pod: bool = False):
    """mesh=None -> local mode (single device, MoE oracle path)."""
    ep = None
    if mesh is not None and cfg.is_moe:
        ep = EPInfo(inner_axis="model", pod_axis="pod" if multi_pod else None)
    if cfg.is_encoder_decoder:
        return WhisperModel(cfg, mesh=mesh, ep=ep, multi_pod=multi_pod)
    if cfg.family == "hybrid":
        return ZambaModel(cfg, mesh=mesh, ep=ep, multi_pod=multi_pod)
    return LM(cfg, mesh=mesh, ep=ep, multi_pod=multi_pod)


def param_shapes(model) -> Any:
    """Abstract parameter tree (no allocation)."""
    return jax.eval_shape(lambda k: model.init(k), jax.random.key(0))


def count_params(model) -> int:
    tree = param_shapes(model)
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))


def count_active_params(model) -> int:
    """Active params/token: MoE counts top_k (+shared) experts, not all."""
    cfg = model.cfg
    total = count_params(model)
    if not cfg.is_moe:
        return total
    expert_size = 3 * cfg.d_model * cfg.moe_dff
    n_moe_layers = cfg.n_layers - cfg.first_dense_layers
    inactive = n_moe_layers * (cfg.n_experts - cfg.top_k) * expert_size
    return total - inactive
