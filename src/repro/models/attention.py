"""Attention modules: GQA (+ sliding window / softcap / qk-norm) and MLA.

Each module provides ``init``, ``apply`` (train/prefill over a full sequence)
and ``decode`` (single token against a cache).  Caches are plain dicts of
arrays so they shard/checkpoint like any other pytree.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (apply_rope, blocked_attention,
                                 cache_decode_attention, dense_init, l2_norm,
                                 rms_norm)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, dtype) -> Dict:
    d, dh = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * dh, dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * dh, dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * dh, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def _project_qkv(p, cfg, x, positions):
    B, S, _ = x.shape
    dh, Hkv = cfg.head_dim, cfg.n_kv_heads
    G = cfg.n_heads // Hkv
    q = (x @ p["wq"]).reshape(B, S, Hkv, G, dh)
    k = (x @ p["wk"]).reshape(B, S, Hkv, dh)
    v = (x @ p["wv"]).reshape(B, S, Hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q.reshape(B, S, Hkv * G, dh), positions,
                   cfg.rope_theta).reshape(B, S, Hkv, G, dh)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_apply(p, cfg, x: jax.Array, *, window: Optional[jax.Array] = None,
              causal: bool = True, cs_qkv=None) -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    if cs_qkv is not None:
        q, k, v = cs_qkv(q, k, v)
    out = blocked_attention(q, k, v, causal=causal, window=window,
                            softcap=cfg.attn_softcap,
                            block_q=cfg.attn_block_q,
                            block_kv=cfg.attn_block_kv)
    return out.reshape(B, S, -1) @ p["wo"]


def gqa_init_cache(cfg, batch: int, max_seq: int, dtype) -> Dict:
    dh, Hkv = cfg.head_dim, cfg.n_kv_heads
    return {
        "k": jnp.zeros((batch, max_seq, Hkv, dh), dtype),
        "v": jnp.zeros((batch, max_seq, Hkv, dh), dtype),
    }


def gqa_decode(p, cfg, x: jax.Array, cache: Dict, length: jax.Array,
               *, window: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    """x: [B, 1, d]; cache k/v [B, S, Hkv, dh]; length [B] tokens already
    stored (the new token lands at index ``length``)."""
    B = x.shape[0]
    positions = length[:, None]
    q, k, v = _project_qkv(p, cfg, x, positions)
    # in-place-style single-slot update: decode steps are aligned across the
    # batch (length[0] == length[b]), so one dynamic_update_slice suffices —
    # the onehot-where alternative rewrites (and double-buffers) the whole
    # cache every step.  Ragged serving would scatter per sequence instead.
    pos = length[0]
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
    out = cache_decode_attention(q, k_cache, v_cache, length + 1,
                                 softcap=cfg.attn_softcap, window=window)
    return out.reshape(B, 1, -1) @ p["wo"], {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA (deepseek-v2): low-rank compressed KV with decoupled RoPE
# ---------------------------------------------------------------------------

def mla_init(key, cfg, dtype) -> Dict:
    d = cfg.d_model
    H = cfg.n_heads
    r_kv, r_q = cfg.mla_kv_lora, cfg.mla_q_lora
    nope, rope, dv = cfg.mla_qk_nope, cfg.mla_rope_dim, cfg.mla_v_head
    ks = jax.random.split(key, 8)
    p = {
        # queries (optionally low-rank)
        "wq_a": dense_init(ks[0], d, r_q, dtype) if r_q else None,
        "q_norm": jnp.ones((r_q,), dtype) if r_q else None,
        "wq_b": dense_init(ks[1], r_q or d, H * (nope + rope), dtype),
        # compressed kv + decoupled rope key
        "wkv_a": dense_init(ks[2], d, r_kv + rope, dtype),
        "kv_norm": jnp.ones((r_kv,), dtype),
        "wkv_b": dense_init(ks[3], r_kv, H * (nope + dv), dtype),
        "wo": dense_init(ks[4], H * dv, d, dtype),
    }
    return {k: v for k, v in p.items() if v is not None}


def _mla_qkv(p, cfg, x, positions):
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope, dv = cfg.mla_qk_nope, cfg.mla_rope_dim, cfg.mla_v_head
    if cfg.mla_q_lora:
        q_in = rms_norm(x @ p["wq_a"], p["q_norm"])
    else:
        q_in = x
    q = (q_in @ p["wq_b"]).reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = x @ p["wkv_a"]                          # [B, S, r_kv + rope]
    c_kv = rms_norm(kv[..., : cfg.mla_kv_lora], p["kv_norm"])
    k_rope = apply_rope(kv[..., cfg.mla_kv_lora:][:, :, None, :],
                        positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def _mla_expand(p, cfg, c_kv):
    """Decompress cached latent into per-head K_nope, V."""
    B, S, _ = c_kv.shape
    H, nope, dv = cfg.n_heads, cfg.mla_qk_nope, cfg.mla_v_head
    kv = (c_kv @ p["wkv_b"]).reshape(B, S, H, nope + dv)
    return kv[..., :nope], kv[..., nope:]


def mla_apply(p, cfg, x: jax.Array, cs_qkv=None) -> jax.Array:
    B, S, _ = x.shape
    H, nope, rope, dv = (cfg.n_heads, cfg.mla_qk_nope, cfg.mla_rope_dim,
                         cfg.mla_v_head)
    positions = jnp.arange(S)[None, :]
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    k_nope, v = _mla_expand(p, cfg, c_kv)
    # assemble full q/k with the shared rope part; one kv "head group" per head
    q = jnp.concatenate([q_nope, q_rope], -1)[:, :, :, None, :]  # [B,S,H,1,dh]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                                  (B, S, H, rope))], -1)
    if cs_qkv is not None:
        q, k, v = cs_qkv(q, k, v)
    # grouped layout: Hkv = H, G = 1
    out = blocked_attention(q, k, v, causal=True, softcap=0.0,
                            block_q=cfg.attn_block_q,
                            block_kv=cfg.attn_block_kv)
    return out.reshape(B, S, H * dv) @ p["wo"]


def mla_init_cache(cfg, batch: int, max_seq: int, dtype) -> Dict:
    """MLA caches the COMPRESSED latent + rope key: (r_kv + rope) per token
    instead of 2*H*dh — the 93% KV-cache shrink of the paper."""
    return {
        "c_kv": jnp.zeros((batch, max_seq, cfg.mla_kv_lora), dtype),
        "k_rope": jnp.zeros((batch, max_seq, cfg.mla_rope_dim), dtype),
    }


def mla_decode(p, cfg, x: jax.Array, cache: Dict, length: jax.Array
               ) -> Tuple[jax.Array, Dict]:
    B = x.shape[0]
    H, nope, rope, dv = (cfg.n_heads, cfg.mla_qk_nope, cfg.mla_rope_dim,
                         cfg.mla_v_head)
    positions = length[:, None]
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(p, cfg, x, positions)
    S = cache["c_kv"].shape[1]
    pos = length[0]   # aligned decode steps (see gqa_decode)
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), pos, axis=1)
    # ABSORBED attention (never decompresses the cache): fold W_b into the
    # query and the output so scores/values live in the r_kv-dim latent space.
    #   score(s) = (W_bk^T q_nope) . c_kv[s]  +  q_rope . k_rope[s]
    #   out      = W_bv^T ( sum_s p_s c_kv[s] )
    w_b = p["wkv_b"].reshape(cfg.mla_kv_lora, H, nope + dv)
    w_bk, w_bv = w_b[..., :nope], w_b[..., nope:]
    q_eff = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0].astype(jnp.float32),
                       w_bk.astype(jnp.float32))            # [B, H, r_kv]
    scale = 1.0 / jnp.sqrt(jnp.float32(nope + rope))
    s_lat = jnp.einsum("bhr,bsr->bhs", q_eff, c_kv.astype(jnp.float32))
    s_rope = jnp.einsum("bhp,bsp->bhs", q_rope[:, 0].astype(jnp.float32),
                        k_rope.astype(jnp.float32))
    scores = (s_lat + s_rope) * scale
    mask = jnp.arange(S)[None] < (length + 1)[:, None]
    scores = jnp.where(mask[:, None], scores, -1e30)
    prob = jax.nn.softmax(scores, axis=-1)
    lat = jnp.einsum("bhs,bsr->bhr", prob, c_kv.astype(jnp.float32))
    out = jnp.einsum("bhr,rhv->bhv", lat, w_bv.astype(jnp.float32))
    out = out.reshape(B, 1, H * dv).astype(x.dtype) @ p["wo"]
    return out, {"c_kv": c_kv, "k_rope": k_rope}
