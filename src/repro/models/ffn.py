"""Dense gated FFN (SwiGLU / GeGLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def ffn_init(key, d: int, ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": dense_init(k1, d, ff, dtype),
            "w_up": dense_init(k2, d, ff, dtype),
            "w_down": dense_init(k3, ff, d, dtype)}


def ffn_apply(p, x: jax.Array, act: str = "silu") -> jax.Array:
    gate = x @ p["w_gate"]
    gate = jax.nn.gelu(gate) if act == "gelu" else jax.nn.silu(gate)
    return (gate * (x @ p["w_up"])) @ p["w_down"]
