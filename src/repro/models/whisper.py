"""Whisper-style encoder-decoder backbone (audio family).

The conv audio frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings [B, encoder_seq, d] (what the two conv layers
would produce).  Positions are sinusoidal for both stacks (whisper uses
sinusoidal encoder positions; we use them for the decoder too instead of a
learned table so ``decode_32k`` scales past the original 448 — recorded in
DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models.actsharding import ActShard
from repro.models.common import (blocked_attention, cache_decode_attention,
                                 chunked_xent, dense_init, dtype_of,
                                 embed_init, head_logits, rms_norm)
from repro.models.config import ModelConfig
from repro.models.ffn import ffn_apply, ffn_init


def sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    """positions [...]-shaped int -> [..., d] float32 sinusoids."""
    half = d // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _xattn_init(key, cfg, dtype) -> Dict:
    d, dh = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], d, cfg.n_heads * dh, dtype),
            "wk": dense_init(ks[1], d, cfg.n_kv_heads * dh, dtype),
            "wv": dense_init(ks[2], d, cfg.n_kv_heads * dh, dtype),
            "wo": dense_init(ks[3], cfg.n_heads * dh, d, dtype)}


def _xattn_kv(p, cfg, enc: jax.Array):
    B, T, _ = enc.shape
    dh, Hkv = cfg.head_dim, cfg.n_kv_heads
    k = (enc @ p["wk"]).reshape(B, T, Hkv, dh)
    v = (enc @ p["wv"]).reshape(B, T, Hkv, dh)
    return k, v


def _xattn_apply(p, cfg, x: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    B, S, _ = x.shape
    dh, Hkv = cfg.head_dim, cfg.n_kv_heads
    G = cfg.n_heads // Hkv
    q = (x @ p["wq"]).reshape(B, S, Hkv, G, dh)
    out = blocked_attention(q, k, v, causal=False,
                            block_q=cfg.attn_block_q,
                            block_kv=cfg.attn_block_kv)
    return out.reshape(B, S, -1) @ p["wo"]


@dataclasses.dataclass
class WhisperModel(ActShard):
    cfg: ModelConfig
    mesh: Any = None
    ep: Any = None
    multi_pod: bool = False

    # ---- params ---------------------------------------------------------------
    def init(self, key) -> Dict:
        cfg = self.cfg
        dtype = dtype_of(cfg)
        ks = jax.random.split(key, 6)

        def enc_layer(k):
            k1, k2 = jax.random.split(k)
            return {"norm1": jnp.ones((cfg.d_model,), dtype),
                    "attn": attn.gqa_init(k1, cfg, dtype),
                    "norm2": jnp.ones((cfg.d_model,), dtype),
                    "ffn": ffn_init(k2, cfg.d_model, cfg.d_ff, dtype)}

        def dec_layer(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {"norm1": jnp.ones((cfg.d_model,), dtype),
                    "attn": attn.gqa_init(k1, cfg, dtype),
                    "norm_x": jnp.ones((cfg.d_model,), dtype),
                    "xattn": _xattn_init(k2, cfg, dtype),
                    "norm2": jnp.ones((cfg.d_model,), dtype),
                    "ffn": ffn_init(k3, cfg.d_model, cfg.d_ff, dtype)}

        return {
            "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
            "enc_layers": jax.vmap(enc_layer)(
                jax.random.split(ks[1], cfg.encoder_layers)),
            "enc_norm": jnp.ones((cfg.d_model,), dtype),
            "dec_layers": jax.vmap(dec_layer)(
                jax.random.split(ks[2], cfg.n_layers)),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }

    def head_matrix(self, params):
        return params["embed"].T

    # ---- encoder ---------------------------------------------------------------
    def encode(self, params, frames: jax.Array) -> jax.Array:
        """frames [B, T, d] (stubbed conv output) -> encoder hidden."""
        cfg = self.cfg
        x = frames.astype(dtype_of(cfg))
        x = x + sinusoidal(jnp.arange(x.shape[1]), cfg.d_model
                           ).astype(x.dtype)[None]

        def body(x, lp):
            lp = self.cs_params(lp)
            x = self.cs_full_hidden(x)
            h = rms_norm(x, lp["norm1"])
            h = attn.gqa_apply(lp["attn"], cfg, h, causal=False,
                               cs_qkv=self.cs_qkv)
            x = x + h
            h = rms_norm(x, lp["norm2"])
            return self.cs_hidden(x + ffn_apply(lp["ffn"], h, act="gelu")), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
        return rms_norm(x, params["enc_norm"])

    # ---- decoder (training) -----------------------------------------------------
    def hidden(self, params, tokens: jax.Array, enc: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = params["embed"][tokens]
        x = x + sinusoidal(jnp.arange(x.shape[1]), cfg.d_model
                           ).astype(x.dtype)[None]

        def body(x, lp):
            lp = self.cs_params(lp)
            x = self.cs_full_hidden(x)
            h = rms_norm(x, lp["norm1"])
            h = attn.gqa_apply(lp["attn"], cfg, h, causal=True,
                               cs_qkv=self.cs_qkv)
            x = x + h
            h = rms_norm(x, lp["norm_x"])
            k, v = _xattn_kv(lp["xattn"], cfg, enc)
            x = x + _xattn_apply(lp["xattn"], cfg, h, k, v)
            h = rms_norm(x, lp["norm2"])
            return self.cs_hidden(x + ffn_apply(lp["ffn"], h, act="gelu")), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
        return rms_norm(x, params["final_norm"])

    def loss(self, params, batch: Dict) -> jax.Array:
        enc = self.encode(params, batch["frames"])
        h = self.hidden(params, batch["tokens"], enc)
        return chunked_xent(h, self.head_matrix(params), batch["labels"],
                            chunk=self.cfg.xent_chunk,
                            cs_logits=self.cs_logits)

    # ---- serving ------------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int) -> Dict:
        cfg = self.cfg
        dtype = dtype_of(cfg)
        dh, Hkv, L = cfg.head_dim, cfg.n_kv_heads, cfg.n_layers
        return {
            "layers": {
                "k": jnp.zeros((L, batch, max_seq, Hkv, dh), dtype),
                "v": jnp.zeros((L, batch, max_seq, Hkv, dh), dtype),
            },
            # cross-attention K/V computed once from the encoder output
            "xk": jnp.zeros((L, batch, cfg.encoder_seq, Hkv, dh), dtype),
            "xv": jnp.zeros((L, batch, cfg.encoder_seq, Hkv, dh), dtype),
            "length": jnp.zeros((batch,), jnp.int32),
        }

    def prefill(self, params, tokens: jax.Array, frames: jax.Array
                ) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        B, S = tokens.shape
        enc = self.encode(params, frames)
        x = params["embed"][tokens]
        x = x + sinusoidal(jnp.arange(S), cfg.d_model).astype(x.dtype)[None]

        def body(x, lp):
            h = rms_norm(x, lp["norm1"])
            positions = jnp.arange(S)[None, :]
            q, k, v = attn._project_qkv(lp["attn"], cfg, h, positions)
            if self.mesh is not None:
                q, k, v = self.cs_qkv(q, k, v)
            y = blocked_attention(q, k, v, causal=True,
                                  block_q=cfg.attn_block_q,
                                  block_kv=cfg.attn_block_kv)
            x = x + y.reshape(B, S, -1) @ lp["attn"]["wo"]
            h = rms_norm(x, lp["norm_x"])
            xk, xv = _xattn_kv(lp["xattn"], cfg, enc)
            x = x + _xattn_apply(lp["xattn"], cfg, h, xk, xv)
            h = rms_norm(x, lp["norm2"])
            x = x + ffn_apply(lp["ffn"], h, act="gelu")
            cache = jax.tree.map(self.cs_kv, {"k": k, "v": v,
                                              "xk": xk, "xv": xv})
            return self.cs_hidden(x), cache

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, caches = jax.lax.scan(body_fn, x, params["dec_layers"])
        x = rms_norm(x, params["final_norm"])
        logits = head_logits(x[:, -1], self.head_matrix(params))
        cache = {"layers": {"k": caches["k"], "v": caches["v"]},
                 "xk": caches["xk"], "xv": caches["xv"],
                 "length": jnp.full((B,), S, jnp.int32)}
        return logits, cache

    def decode_step(self, params, cache: Dict, tokens: jax.Array
                    ) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        B = tokens.shape[0]
        length = cache["length"]
        x = params["embed"][tokens]
        x = x + sinusoidal(length[:, None], cfg.d_model).astype(x.dtype)

        def body(x, inp):
            lp, cl, xk, xv = inp
            h = rms_norm(x, lp["norm1"])
            y, cl = attn.gqa_decode(lp["attn"], cfg, h, cl, length)
            x = x + y
            h = rms_norm(x, lp["norm_x"])
            dh, Hkv = cfg.head_dim, cfg.n_kv_heads
            G = cfg.n_heads // Hkv
            q = (h @ lp["xattn"]["wq"]).reshape(B, 1, Hkv, G, dh)
            enc_len = jnp.full((B,), cfg.encoder_seq, jnp.int32)
            y = cache_decode_attention(q, xk, xv, enc_len)
            x = x + y.reshape(B, 1, -1) @ lp["xattn"]["wo"]
            h = rms_norm(x, lp["norm2"])
            x = x + ffn_apply(lp["ffn"], h, act="gelu")
            return x, cl

        x, new_cache = jax.lax.scan(
            body, x, (params["dec_layers"], cache["layers"],
                      cache["xk"], cache["xv"]))
        x = rms_norm(x, params["final_norm"])
        logits = head_logits(x, self.head_matrix(params))
        return logits, {**cache, "layers": new_cache, "length": length + 1}
