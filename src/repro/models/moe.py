"""Mixture-of-Experts layer: params + local oracle over the NAP
dispatch subsystem.

The distributed dispatch machinery that used to live here is now the
first-class subsystem :mod:`repro.moe` (see ``src/repro/moe/README.md``)
— the token -> expert routing matrix is a sparse matrix, so MoE dispatch
*is* a distributed SpMV gather (DESIGN.md §2): tokens are the vector
entries, experts the matrix rows.  This module keeps the model-facing
pieces:

* :func:`moe_init` — parameter init (router + expert FFNs + optional
  shared experts);
* :func:`moe_apply_local` — the single-device dense-masked reference,
  the correctness oracle for the distributed paths;
* re-exports of the distributed path (:class:`EPInfo`,
  :func:`moe_apply_sharded`, the island internals) from
  :mod:`repro.moe.dispatch`, so every existing caller — the
  transformer stack, the serve registry, the multidev programs — keeps
  importing from here unchanged.

Dispatch modes (``cfg.moe_dispatch``): ``flat`` (Algorithm-1 analogue,
every (token, expert-choice) copy crosses separately), ``nap``
(Algorithms 2+3 — per-destination-POD dedup, ONE aggregated inter-pod
all-to-all, transpose route for the combine), ``auto`` (per-geometry
resolution from modeled injected inter-pod bytes).  ``cfg.wire_dtype``
quantizes the dispatch payloads on the wire (``f32`` is the identity
codec — bit-for-bit the unquantized program).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
# Back-compat surface: the distributed dispatch path moved to the
# repro.moe subsystem; these names keep their historical import site.
from repro.moe.dispatch import (EPInfo, _expert_compute, _fifo_slots,  # noqa: F401
                                _moe_island, _router, _shared_ffn,
                                moe_apply_sharded)

__all__ = ["EPInfo", "moe_init", "moe_apply_local", "moe_apply_sharded"]


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def moe_init(key, cfg, dtype) -> Dict:
    d, ff, E = cfg.d_model, cfg.moe_dff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "w_gate": _expert_init(ks[1], E, d, ff, dtype),
        "w_up": _expert_init(ks[2], E, d, ff, dtype),
        "w_down": _expert_init(ks[3], E, ff, d, dtype),
    }
    if cfg.n_shared_experts:
        ffs = ff * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {"w_gate": dense_init(k1, d, ffs, dtype),
                       "w_up": dense_init(k2, d, ffs, dtype),
                       "w_down": dense_init(k3, ffs, d, dtype)}
    return p


def _expert_init(key, E, d_in, d_out, dtype):
    scale = 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (E, d_in, d_out), jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# local reference (oracle; also the smoke-test path on 1 device)
# ---------------------------------------------------------------------------

def moe_apply_local(p, cfg, x: jax.Array) -> jax.Array:
    """Dense-masked reference: computes every expert on every token and
    masks — O(E/topk) extra flops, only for small configs and tests."""
    B, S, d = x.shape
    x2 = x.reshape(-1, d)
    w, ids = _router(p, cfg, x2)                          # [T, K]
    E = cfg.n_experts
    gate = jnp.zeros((x2.shape[0], E), jnp.float32)
    gate = jax.vmap(lambda g, i, ww: g.at[i].add(ww))(gate, ids, w)
    h = jnp.einsum("td,edf->tef", x2, p["w_gate"])
    u = jnp.einsum("td,edf->tef", x2, p["w_up"])
    y = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u, p["w_down"])
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), gate).astype(x.dtype)
    if cfg.n_shared_experts:
        out = out + _shared_ffn(p, x2)
    return out.reshape(B, S, d)
