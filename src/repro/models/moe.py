"""Mixture-of-Experts with NAPSpMV-style hierarchical dispatch.

The token -> expert routing matrix is a sparse matrix, so MoE dispatch *is*
a distributed SpMV gather (DESIGN.md §2): tokens are the vector entries,
experts the matrix rows.  The three dispatch modes mirror the paper:

* ``local``  — single-device reference (dense-masked einsum over all experts);
               the correctness oracle for the distributed paths.
* ``flat``   — Algorithm 1 analogue: one capacity-padded all-to-all over the
               *flat* expert-parallel axis; every (token, expert-choice) pair
               crosses the network separately.
* ``nap``    — Algorithms 2+3 analogue: per-destination-POD deduplication
               (a token bound for several experts on one remote pod crosses
               DCI once, the paper's E(n, m)), one aggregated inter-pod
               all-to-all, then intra-pod fan-out + expert compute, with the
               transpose route for the weighted combine.

The distributed paths run inside a *partial-auto* shard_map: manual over the
expert-parallel axes, auto over the data axis, so they embed directly in the
pjit train/serve programs.

Static-shape realisation: all buffers are capacity-padded; FIFO slots are
assigned by cumsum and overflowing copies are dropped (standard MoE token
dropping; capacity_factor controls the padding the paper's T/U balancing
minimises).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.models.common import dense_init


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def moe_init(key, cfg, dtype) -> Dict:
    d, ff, E = cfg.d_model, cfg.moe_dff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "w_gate": _expert_init(ks[1], E, d, ff, dtype),
        "w_up": _expert_init(ks[2], E, d, ff, dtype),
        "w_down": _expert_init(ks[3], E, ff, d, dtype),
    }
    if cfg.n_shared_experts:
        ffs = ff * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {"w_gate": dense_init(k1, d, ffs, dtype),
                       "w_up": dense_init(k2, d, ffs, dtype),
                       "w_down": dense_init(k3, ffs, d, dtype)}
    return p


def _expert_init(key, E, d_in, d_out, dtype):
    scale = 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (E, d_in, d_out), jnp.float32) * scale).astype(dtype)


def _router(p, cfg, x2d: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Return (weights [T, K], expert ids [T, K]); normalized top-k softmax."""
    logits = (x2d.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = lax.top_k(probs, cfg.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, ids.astype(jnp.int32)


def _shared_ffn(p, x):
    s = p["shared"]
    return (jax.nn.silu(x @ s["w_gate"]) * (x @ s["w_up"])) @ s["w_down"]


# ---------------------------------------------------------------------------
# local reference (oracle; also the smoke-test path on 1 device)
# ---------------------------------------------------------------------------

def moe_apply_local(p, cfg, x: jax.Array) -> jax.Array:
    """Dense-masked reference: computes every expert on every token and
    masks — O(E/topk) extra flops, only for small configs and tests."""
    B, S, d = x.shape
    x2 = x.reshape(-1, d)
    w, ids = _router(p, cfg, x2)                          # [T, K]
    E = cfg.n_experts
    gate = jnp.zeros((x2.shape[0], E), jnp.float32)
    gate = jax.vmap(lambda g, i, ww: g.at[i].add(ww))(gate, ids, w)
    h = jnp.einsum("td,edf->tef", x2, p["w_gate"])
    u = jnp.einsum("td,edf->tef", x2, p["w_up"])
    y = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u, p["w_down"])
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), gate).astype(x.dtype)
    if cfg.n_shared_experts:
        out = out + _shared_ffn(p, x2)
    return out.reshape(B, S, d)


# ---------------------------------------------------------------------------
# distributed dispatch (shard_map; flat and nap modes)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EPInfo:
    """Expert-parallel geometry: which mesh axes hold experts.

    axes ordering is (outer, inner) = (pod, model); single-pod meshes pass
    pod_axis=None and the nap mode degenerates to flat over `inner`.
    """
    inner_axis: str = "model"
    pod_axis: Optional[str] = None

    @property
    def manual_axes(self) -> Tuple[str, ...]:
        return ((self.pod_axis,) if self.pod_axis else ()) + (self.inner_axis,)


def _fifo_slots(need: jax.Array, capacity: int) -> jax.Array:
    """need [T, n_dst] bool -> slot [T, n_dst] in [0, capacity) or `capacity`
    (dropped; scatter mode='drop' discards it)."""
    slots = jnp.cumsum(need.astype(jnp.int32), axis=0) - 1
    return jnp.where(need & (slots < capacity), slots, capacity)


def _expert_compute(p_loc, cfg, tokens: jax.Array, meta_e: jax.Array,
                    meta_w: jax.Array, e_base: jax.Array, E_loc: int,
                    capacity: int) -> jax.Array:
    """Run this chip's experts over arrived copies.

    tokens [R, d]; meta_e [R, K] global expert ids (-1 pad); meta_w [R, K]
    router weights; e_base scalar — first global expert id on this chip.
    p_loc: expert weights [E_loc, d, ff] etc.
    Returns per-copy outputs [R, d] = sum over my experts hit by the copy.
    """
    R, d = tokens.shape
    out = jnp.zeros((R, d), jnp.float32)
    for el in range(E_loc):                      # static small loop
        gid = e_base + el
        hit = (meta_e == gid)
        w = (meta_w * hit).sum(-1)               # [R] combined weight
        need = hit.any(-1)
        slot = _fifo_slots(need[:, None], capacity)[:, 0]
        buf = jnp.zeros((capacity + 1, d), tokens.dtype).at[slot].set(
            tokens, mode="drop")[:capacity]
        h = jax.nn.silu(buf @ p_loc["w_gate"][el]) * (buf @ p_loc["w_up"][el])
        y = (h @ p_loc["w_down"][el]).astype(jnp.float32)
        back = jnp.where(slot[:, None] < capacity, y[jnp.minimum(slot, capacity - 1)], 0.0)
        out = out + back * w[:, None]
    return out


def moe_apply_sharded(p, cfg, x: jax.Array, ep: EPInfo, mesh) -> jax.Array:
    """Distributed MoE: x [B, S, d] (batch sharded over dp axes, replicated
    over the EP axes); experts sharded over ep.manual_axes."""
    B, S, d = x.shape
    in_dtype = x.dtype

    def island(x_blk, router, w_gate, w_up, w_down):
        # f32 at the shard_map boundary: the transpose-of-replication psum
        # the autodiff inserts for x must be f32 — XLA:CPU's
        # all-reduce-promotion pass CHECK-fails on bf16 psums whose reduction
        # computation carries a trailing `copy` (backend bug, documented in
        # DESIGN.md); compute inside stays in the model dtype.
        y = _moe_island(cfg, ep, x_blk.astype(in_dtype), router,
                        w_gate, w_up, w_down)
        return y.astype(jnp.float32)

    from jax.sharding import PartitionSpec as P
    pod = ep.pod_axis
    x_spec = P(pod, None, None) if pod else P(None, None, None)
    e_spec = P(ep.manual_axes if pod else ep.inner_axis)
    out = compat.shard_map(
        island, mesh=mesh,
        in_specs=(x_spec, P(), e_spec, e_spec, e_spec),
        out_specs=x_spec,
        axis_names=set(ep.manual_axes),
        check_vma=False,
    )(x.astype(jnp.float32), p["router"], p["w_gate"], p["w_up"],
      p["w_down"]).astype(in_dtype)
    if cfg.n_shared_experts:
        out = out + _shared_ffn(p, x.reshape(-1, d)).reshape(B, S, d)
    return out


def _moe_island(cfg, ep, x, router, w_gate, w_up, w_down):
    """Manual-collective MoE over the EP axes; runs per (pod?, model) chip."""
    n_in = compat.axis_size(ep.inner_axis)
    n_out = compat.axis_size(ep.pod_axis) if ep.pod_axis else 1
    my_in = lax.axis_index(ep.inner_axis)
    my_out = lax.axis_index(ep.pod_axis) if ep.pod_axis else 0
    n_chips = n_in * n_out
    E, E_loc = cfg.n_experts, cfg.n_experts // n_chips
    B, S, d = x.shape
    T = B * S
    x2 = x.reshape(T, d)

    # every inner-axis instance holds the same tokens (activations are
    # replicated over TP); instance m becomes the *gateway* for chunk m —
    # the paper's T/U distribution of node-level sends over local processes.
    Tc = T // n_in
    chunk = lax.dynamic_slice_in_dim(x2, my_in * Tc, Tc, 0)
    w, ids = _router({"router": router}, cfg, chunk)       # [Tc, K]
    K = cfg.top_k
    dst_chip = ids // E_loc                                # global EP chip
    # NB: global chip id c = pod * n_in + inner  (experts laid out pod-major)

    cap_factor = cfg.capacity_factor
    mode = cfg.moe_dispatch if (ep.pod_axis and n_out > 1) else "flat"

    if mode == "flat":
        # ---- Algorithm 1 analogue: per-(token, k) copies, flat a2a --------
        capacity = max(1, int(Tc * K * cap_factor / n_chips))
        need = jnp.zeros((Tc, n_chips), bool)
        send_slot = jnp.full((Tc, K), capacity, jnp.int32)
        # sequential-k FIFO so each (t, k) copy gets its own slot
        counts = jnp.zeros((n_chips,), jnp.int32)
        toks = jnp.zeros((n_chips, capacity, d), x.dtype)
        meta_e = jnp.full((n_chips, capacity, K), -1, jnp.int32)
        meta_w = jnp.zeros((n_chips, capacity, K), jnp.float32)
        for k in range(K):                                  # static loop
            c = dst_chip[:, k]
            onehot = jax.nn.one_hot(c, n_chips, dtype=jnp.int32)
            slot = counts[None, :] + jnp.cumsum(onehot, 0) - onehot
            slot_k = (slot * onehot).sum(-1)                # [Tc]
            slot_k = jnp.where(slot_k < capacity, slot_k, capacity)
            toks = toks.at[c, slot_k].set(chunk, mode="drop")
            me = jnp.full((Tc, K), -1, jnp.int32).at[:, 0].set(ids[:, k])
            mw = jnp.zeros((Tc, K), jnp.float32).at[:, 0].set(w[:, k])
            meta_e = meta_e.at[c, slot_k].set(me, mode="drop")
            meta_w = meta_w.at[c, slot_k].set(mw, mode="drop")
            send_slot = send_slot.at[:, k].set(slot_k)
            counts = counts + onehot.sum(0)
        axes = ep.manual_axes if ep.pod_axis else ep.inner_axis
        r_toks = lax.all_to_all(toks, axes, 0, 0, tiled=True)
        r_me = lax.all_to_all(meta_e, axes, 0, 0, tiled=True)
        r_mw = lax.all_to_all(meta_w, axes, 0, 0, tiled=True)
        e_base = (my_out * n_in + my_in) * E_loc
        cap_e = max(1, int(Tc * K * cap_factor / E_loc))
        y = _expert_compute({"w_gate": w_gate, "w_up": w_up, "w_down": w_down},
                            cfg, r_toks.reshape(-1, d),
                            r_me.reshape(-1, K), r_mw.reshape(-1, K),
                            e_base, E_loc, cap_e)
        # transpose route back: outputs in the same slots
        y = lax.all_to_all(y.reshape(n_chips, capacity, d), axes, 0, 0,
                           tiled=True)
        out_chunk = jnp.zeros((Tc, d), jnp.float32)
        for k in range(K):
            c, s = dst_chip[:, k], send_slot[:, k]
            val = jnp.where((s < capacity)[:, None],
                            y[c, jnp.minimum(s, capacity - 1)], 0.0)
            out_chunk = out_chunk + val
    else:
        # ---- NAPSpMV 3-step: pod-dedup -> one DCI a2a -> local fan-out -----
        # dedup bound: a token crosses to pod o at most ONCE, so cap_pod = Tc
        # is exact (no drops at the DCI stage) — vs Tc*K/n_out copies in flat.
        cap_pod = Tc
        dst_pod = dst_chip // n_in
        need_pod = jnp.zeros((Tc, n_out), bool)
        for k in range(K):
            need_pod = need_pod | (dst_pod[:, k:k + 1] == jnp.arange(n_out)[None])
        pod_slot = _fifo_slots(need_pod, cap_pod)           # [Tc, n_out]
        toks = jnp.zeros((n_out, cap_pod, d), x.dtype)
        meta_e = jnp.full((n_out, cap_pod, K), -1, jnp.int32)
        meta_w = jnp.zeros((n_out, cap_pod, K), jnp.float32)
        for o in range(n_out):                              # static tiny loop
            sel = pod_slot[:, o]
            toks = toks.at[o, sel].set(chunk, mode="drop")
            # ship only the expert choices that live on pod o (E(n,m) dedup)
            on_o = dst_pod == o
            meta_e = meta_e.at[o, sel].set(jnp.where(on_o, ids, -1), mode="drop")
            meta_w = meta_w.at[o, sel].set(jnp.where(on_o, w, 0.0), mode="drop")
        # step 2: ONE aggregated inter-pod exchange (same inner slot pairing)
        toks = lax.all_to_all(toks, ep.pod_axis, 0, 0, tiled=True)
        meta_e = lax.all_to_all(meta_e, ep.pod_axis, 0, 0, tiled=True)
        meta_w = lax.all_to_all(meta_w, ep.pod_axis, 0, 0, tiled=True)
        # step 3: fan out to owning chips within this pod
        R0 = n_out * cap_pod
        ft, fe, fw = (toks.reshape(R0, d), meta_e.reshape(R0, K),
                      meta_w.reshape(R0, K))
        cap_loc = max(1, int(Tc * K * cap_factor / n_in))
        loc_of = jnp.where(fe >= 0, (fe // E_loc) % n_in, -1)
        need_loc = jnp.zeros((R0, n_in), bool)
        for k in range(K):
            need_loc = need_loc | (loc_of[:, k:k + 1] == jnp.arange(n_in)[None])
        loc_slot = _fifo_slots(need_loc, cap_loc)
        lt = jnp.zeros((n_in, cap_loc, d), x.dtype)
        le = jnp.full((n_in, cap_loc, K), -1, jnp.int32)
        lw = jnp.zeros((n_in, cap_loc, K), jnp.float32)
        for i in range(n_in):
            sel = loc_slot[:, i]
            on_i = loc_of == i
            lt = lt.at[i, sel].set(ft, mode="drop")
            le = le.at[i, sel].set(jnp.where(on_i, fe, -1), mode="drop")
            lw = lw.at[i, sel].set(jnp.where(on_i, fw, 0.0), mode="drop")
        lt = lax.all_to_all(lt, ep.inner_axis, 0, 0, tiled=True)
        le = lax.all_to_all(le, ep.inner_axis, 0, 0, tiled=True)
        lw = lax.all_to_all(lw, ep.inner_axis, 0, 0, tiled=True)
        e_base = (my_out * n_in + my_in) * E_loc
        cap_e = max(1, int(Tc * K * cap_factor / E_loc))
        y = _expert_compute({"w_gate": w_gate, "w_up": w_up, "w_down": w_down},
                            cfg, lt.reshape(-1, d), le.reshape(-1, K),
                            lw.reshape(-1, K), e_base, E_loc, cap_e)
        # ---- transpose route: local gather-back, pod a2a back, combine ----
        y = lax.all_to_all(y.reshape(n_in, cap_loc, d), ep.inner_axis, 0, 0,
                           tiled=True).reshape(n_in * cap_loc, d)
        # each original pod-copy slot sums its local fan-out returns
        pod_back = jnp.zeros((R0, d), jnp.float32)
        for i in range(n_in):
            sel = loc_slot[:, i]
            val = jnp.where((sel < cap_loc)[:, None],
                            y[i * cap_loc + jnp.minimum(sel, cap_loc - 1)], 0.0)
            pod_back = pod_back + val
        pod_back = lax.all_to_all(pod_back.reshape(n_out, cap_pod, d),
                                  ep.pod_axis, 0, 0, tiled=True)
        out_chunk = jnp.zeros((Tc, d), jnp.float32)
        for o in range(n_out):
            sel = pod_slot[:, o]
            val = jnp.where((sel < cap_pod)[:, None],
                            pod_back[o, jnp.minimum(sel, cap_pod - 1)], 0.0)
            out_chunk = out_chunk + val

    # reassemble this pod's token set across its gateways (chunks were split
    # over the inner axis; pods hold different batch shards, no pod gather).
    # NB stays f32: a bf16 all_gather here transposes to a bf16 reduce-scatter
    # whose copy-rooted reduction trips the XLA:CPU promotion bug (see
    # moe_apply_sharded).
    full = lax.all_gather(out_chunk, ep.inner_axis, axis=0, tiled=True)
    return full.reshape(B, S, d)
