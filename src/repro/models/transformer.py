"""Decoder-only LM assembly (dense, MoE, VLM, SSM, hybrid families).

Layers are stacked on a leading axis and driven by ``lax.scan`` (compile time
stays flat in depth); the layer body is wrapped in ``jax.checkpoint`` when
``cfg.remat``.  The same stacked layout is what the FSDP sharding rules and
the checkpoint format address.

The public surface is the :class:`LM` protocol used by launch/ and tests:
    init(key) -> params
    loss(params, batch) -> scalar
    prefill(params, tokens) -> (last logits, cache)
    decode_step(params, cache, tokens, lengths) -> (logits, cache)
    init_cache(batch, max_seq) -> cache pytree
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.actsharding import ActShard
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (chunked_xent, dense_init, dtype_of,
                                 embed_init, head_logits, rms_norm)
from repro.models.config import ModelConfig
from repro.models.ffn import ffn_apply, ffn_init


# ---------------------------------------------------------------------------
# single transformer block (dense or moe)
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, dtype, *, moe: bool, d_ff: int) -> Dict:
    k1, k2 = jax.random.split(key)
    p = {"norm1": jnp.zeros((cfg.d_model,), dtype) if cfg.post_norms
         else jnp.ones((cfg.d_model,), dtype),
         "norm2": jnp.zeros((cfg.d_model,), dtype) if cfg.post_norms
         else jnp.ones((cfg.d_model,), dtype)}
    if cfg.post_norms:  # gemma2 sandwich norms (stored as w-1 -> zeros)
        p["norm1_post"] = jnp.zeros((cfg.d_model,), dtype)
        p["norm2_post"] = jnp.zeros((cfg.d_model,), dtype)
    if cfg.mla_kv_lora:
        p["attn"] = attn.mla_init(k1, cfg, dtype)
    else:
        p["attn"] = attn.gqa_init(k1, cfg, dtype)
    if moe:
        p["moe"] = moe_mod.moe_init(k2, cfg, dtype)
    else:
        p["ffn"] = ffn_init(k2, cfg.d_model, d_ff, dtype)
    return p


def _norm(cfg, x, w):
    return rms_norm(x, w, plus_one=cfg.post_norms)


def block_apply(p, cfg: ModelConfig, x: jax.Array, *,
                window: Optional[jax.Array], mesh=None,
                ep: Optional[moe_mod.EPInfo] = None, cs_qkv=None) -> jax.Array:
    h = _norm(cfg, x, p["norm1"])
    if cfg.mla_kv_lora:
        h = attn.mla_apply(p["attn"], cfg, h, cs_qkv=cs_qkv)
    else:
        h = attn.gqa_apply(p["attn"], cfg, h, window=window, cs_qkv=cs_qkv)
    if cfg.post_norms:
        h = _norm(cfg, h, p["norm1_post"])
    x = x + h
    h = _norm(cfg, x, p["norm2"])
    if "moe" in p:
        if mesh is not None:
            h = moe_mod.moe_apply_sharded(p["moe"], cfg, h, ep, mesh)
        else:
            h = moe_mod.moe_apply_local(p["moe"], cfg, h)
    else:
        act = "gelu" if cfg.family == "audio" else "silu"
        h = ffn_apply(p["ffn"], h, act=act)
    if cfg.post_norms:
        h = _norm(cfg, h, p["norm2_post"])
    return x + h


def block_decode(p, cfg: ModelConfig, x: jax.Array, cache: Dict,
                 length: jax.Array, *, window: Optional[jax.Array] = None,
                 mesh=None, ep=None) -> Tuple[jax.Array, Dict]:
    h = _norm(cfg, x, p["norm1"])
    if cfg.mla_kv_lora:
        h, cache = attn.mla_decode(p["attn"], cfg, h, cache, length)
    else:
        h, cache = attn.gqa_decode(p["attn"], cfg, h, cache, length,
                                   window=window)
    if cfg.post_norms:
        h = _norm(cfg, h, p["norm1_post"])
    x = x + h
    h = _norm(cfg, x, p["norm2"])
    if "moe" in p:
        if mesh is not None:
            h = moe_mod.moe_apply_sharded(p["moe"], cfg, h, ep, mesh)
        else:
            h = moe_mod.moe_apply_local(p["moe"], cfg, h)
    else:
        act = "gelu" if cfg.family == "audio" else "silu"
        h = ffn_apply(p["ffn"], h, act=act)
    if cfg.post_norms:
        h = _norm(cfg, h, p["norm2_post"])
    return x + h, cache


def _layer_windows(cfg: ModelConfig, n_layers: int, max_seq: int) -> jnp.ndarray:
    """Per-layer attention window (gemma2: even layers local)."""
    if cfg.alt_local_global and cfg.sliding_window:
        w = [cfg.sliding_window if i % 2 == 0 else max_seq
             for i in range(n_layers)]
    else:
        w = [max_seq] * n_layers
    return jnp.asarray(w, jnp.int32)


# ---------------------------------------------------------------------------
# LM model object
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LM(ActShard):
    cfg: ModelConfig
    mesh: Any = None                      # None -> local (smoke/test) mode
    ep: Optional[moe_mod.EPInfo] = None
    multi_pod: bool = False

    # ---- params -------------------------------------------------------------
    def init(self, key) -> Dict:
        cfg = self.cfg
        dtype = dtype_of(cfg)
        keys = jax.random.split(key, 4)
        p: Dict[str, Any] = {
            "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
            "final_norm": (jnp.zeros if cfg.post_norms else jnp.ones)(
                (cfg.d_model,), dtype),
        }
        if not cfg.tie_embeddings:
            p["head"] = dense_init(keys[1], cfg.d_model, cfg.vocab, dtype)
        if cfg.family == "ssm":
            layer_keys = jax.random.split(keys[2], cfg.n_layers)
            p["layers"] = jax.vmap(
                lambda k: {"block": rwkv_mod.rwkv6_init(k, cfg, dtype),
                           "norm1": jnp.ones((cfg.d_model,), dtype),
                           "norm2": jnp.ones((cfg.d_model,), dtype)})(layer_keys)
            return p
        n_dense = cfg.first_dense_layers if cfg.is_moe else 0
        n_stack = cfg.n_layers - n_dense
        if n_dense:
            dk = jax.random.split(keys[1], n_dense)
            p["dense_layers"] = jax.vmap(
                lambda k: block_init(k, cfg, dtype, moe=False, d_ff=cfg.d_ff)
            )(dk)
        layer_keys = jax.random.split(keys[2], n_stack)
        p["layers"] = jax.vmap(
            lambda k: block_init(k, cfg, dtype, moe=cfg.is_moe,
                                 d_ff=cfg.d_ff))(layer_keys)
        return p

    def head_matrix(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["head"]

    # ---- forward ------------------------------------------------------------
    def hidden(self, params, tokens: jax.Array) -> jax.Array:
        """tokens [B, S] -> hidden [B, S, d]."""
        cfg = self.cfg
        x = params["embed"][tokens]
        if cfg.embed_scale:
            x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
        x = self.cs_hidden(x)
        if cfg.family == "ssm":
            return self._rwkv_hidden(params, x)
        S = tokens.shape[1]
        n_dense = cfg.first_dense_layers if cfg.is_moe else 0
        if n_dense:
            for i in range(n_dense):
                lp = jax.tree.map(lambda a: a[i], params["dense_layers"])
                x = block_apply(lp, cfg, x, window=None, mesh=self.mesh,
                                ep=self.ep, cs_qkv=self.cs_qkv)
        windows = _layer_windows(cfg, cfg.n_layers - n_dense, S)
        has_window = bool(cfg.alt_local_global and cfg.sliding_window)

        def body(x, inp):
            lp, w = inp
            lp = self.cs_params(lp)      # pins per-layer weight-grad sharding
            x = self.cs_full_hidden(x)   # SP "g": gather seq before matmuls
            y = block_apply(lp, cfg, x, window=w if has_window else None,
                            mesh=self.mesh, ep=self.ep, cs_qkv=self.cs_qkv)
            return self.cs_hidden(y), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, (params["layers"], windows))
        return _norm(cfg, x, params["final_norm"])

    def _rwkv_hidden(self, params, x):
        cfg = self.cfg
        B = x.shape[0]
        state0 = rwkv_mod.rwkv6_init_state(cfg, B, x.dtype)

        def body(x, lp):
            lp = self.cs_params(lp)
            x = self.cs_full_hidden(x)
            y, _ = rwkv_mod.rwkv6_block_apply(lp["block"], cfg, x, state0,
                                              lp["norm1"], lp["norm2"])
            return self.cs_hidden(y), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, params["layers"])
        return _norm(cfg, x, params["final_norm"])

    def loss(self, params, batch: Dict) -> jax.Array:
        h = self.hidden(params, batch["tokens"])
        return chunked_xent(h, self.head_matrix(params), batch["labels"],
                            chunk=self.cfg.xent_chunk,
                            softcap=self.cfg.final_softcap,
                            cs_logits=self.cs_logits)

    # ---- serving ------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int) -> Dict:
        cfg = self.cfg
        dtype = dtype_of(cfg)
        if cfg.family == "ssm":
            state = rwkv_mod.rwkv6_init_state(cfg, batch, dtype)
            return {"state": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape),
                state), "length": jnp.zeros((batch,), jnp.int32)}
        n_dense = cfg.first_dense_layers if cfg.is_moe else 0
        mk = (attn.mla_init_cache if cfg.mla_kv_lora else attn.gqa_init_cache)
        one = mk(cfg, batch, max_seq, dtype)
        cache = {"layers": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers - n_dense,) + a.shape),
            one), "length": jnp.zeros((batch,), jnp.int32)}
        if n_dense:
            cache["dense_layers"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_dense,) + a.shape), one)
        return cache

    def decode_step(self, params, cache: Dict, tokens: jax.Array
                    ) -> Tuple[jax.Array, Dict]:
        """tokens [B, 1] -> (logits [B, 1, V], cache)."""
        cfg = self.cfg
        length = cache["length"]
        x = params["embed"][tokens]
        if cfg.embed_scale:
            x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
        if cfg.family == "ssm":
            x, new_states = self._rwkv_decode(params, x, cache)
            out_cache = {"state": new_states, "length": length + 1}
        else:
            n_dense = cfg.first_dense_layers if cfg.is_moe else 0
            out_cache = {"length": length + 1}
            if n_dense:
                new = []
                for i in range(n_dense):
                    lp = jax.tree.map(lambda a: a[i], params["dense_layers"])
                    cl = jax.tree.map(lambda a: a[i], cache["dense_layers"])
                    x, cl = block_decode(lp, cfg, x, cl, length,
                                         window=None, mesh=self.mesh,
                                         ep=self.ep)
                    new.append(cl)
                out_cache["dense_layers"] = jax.tree.map(
                    lambda *a: jnp.stack(a), *new)
            max_seq = jax.tree.leaves(cache["layers"])[0].shape[2]
            windows = _layer_windows(cfg, cfg.n_layers - n_dense, max_seq)
            has_window = bool(cfg.alt_local_global and cfg.sliding_window)

            def body(x, inp):
                lp, cl, w = inp
                y, cl = block_decode(lp, cfg, x, cl, length,
                                     window=w if has_window else None,
                                     mesh=self.mesh, ep=self.ep)
                return self.cs_hidden(y), cl

            x, new_cache = jax.lax.scan(body, x,
                                        (params["layers"], cache["layers"],
                                         windows))
            out_cache["layers"] = new_cache
        x = _norm(cfg, x, params["final_norm"])
        logits = head_logits(x, self.head_matrix(params), cfg.final_softcap)
        return logits, out_cache

    def _rwkv_decode(self, params, x, cache):
        cfg = self.cfg

        def body(x, inp):
            lp, st = inp
            y, st = rwkv_mod.rwkv6_block_apply(lp["block"], cfg, x, st,
                                               lp["norm1"], lp["norm2"])
            return y, st

        x, states = jax.lax.scan(body, x, (params["layers"], cache["state"]))
        return x, states

    def prefill(self, params, tokens: jax.Array) -> Tuple[jax.Array, Dict]:
        """Compute hidden over the prompt and build the cache in one pass.

        Returns (logits for the last position [B, V], cache filled to S).
        For attention families the per-layer K/V come out of the scan; for
        SSM the final state does.
        """
        cfg = self.cfg
        B, S = tokens.shape
        x = params["embed"][tokens]
        if cfg.embed_scale:
            x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
        length = jnp.full((B,), S, jnp.int32)
        if cfg.family == "ssm":
            state0 = rwkv_mod.rwkv6_init_state(cfg, B, x.dtype)

            def body(x, lp):
                y, st = rwkv_mod.rwkv6_block_apply(lp["block"], cfg, x, state0,
                                                   lp["norm1"], lp["norm2"])
                return y, st

            body_fn = jax.checkpoint(body) if cfg.remat else body
            x, states = jax.lax.scan(body_fn, x, params["layers"])
            cache = {"state": states, "length": length}
        else:
            n_dense = cfg.first_dense_layers if cfg.is_moe else 0
            cache = {"length": length}
            dtype = dtype_of(cfg)
            if n_dense:
                new = []
                for i in range(n_dense):
                    lp = jax.tree.map(lambda a: a[i], params["dense_layers"])
                    x, c = self._prefill_block(lp, x)
                    new.append(c)
                cache["dense_layers"] = jax.tree.map(
                    lambda *a: jnp.stack(a), *new)
            windows = _layer_windows(cfg, cfg.n_layers - n_dense, S)
            has_window = bool(cfg.alt_local_global and cfg.sliding_window)

            def body(x, inp):
                lp, w = inp
                x = self.cs_full_hidden(x)
                x, c = self._prefill_block(lp, x,
                                           window=w if has_window else None)
                return self.cs_hidden(x), jax.tree.map(self.cs_kv, c)

            body_fn = jax.checkpoint(body) if cfg.remat else body
            x, caches = jax.lax.scan(body_fn, x, (params["layers"], windows))
            cache["layers"] = caches
        x = _norm(cfg, x, params["final_norm"])
        logits = head_logits(x[:, -1], self.head_matrix(params),
                             cfg.final_softcap)
        return logits, cache

    def _prefill_block(self, lp, x, window=None):
        """Like block_apply but also returns the layer cache."""
        cfg = self.cfg
        h = _norm(cfg, x, lp["norm1"])
        if cfg.mla_kv_lora:
            B, S, _ = h.shape
            positions = jnp.arange(S)[None, :]
            q_nope, q_rope, c_kv, k_rope = attn._mla_qkv(lp["attn"], cfg, h,
                                                         positions)
            cache = {"c_kv": c_kv, "k_rope": k_rope}
            y = attn.mla_apply(lp["attn"], cfg, h, cs_qkv=self.cs_qkv)
        else:
            B, S, _ = h.shape
            positions = jnp.arange(S)[None, :]
            q, k, v = attn._project_qkv(lp["attn"], cfg, h, positions)
            q, k, v = self.cs_qkv(q, k, v) if self.mesh is not None else (q, k, v)
            cache = {"k": k, "v": v}
            from repro.models.common import blocked_attention
            y = blocked_attention(q, k, v, causal=True, window=window,
                                  softcap=cfg.attn_softcap,
                                  block_q=cfg.attn_block_q,
                                  block_kv=cfg.attn_block_kv)
            y = y.reshape(B, S, -1) @ lp["attn"]["wo"]
        if cfg.post_norms:
            y = _norm(cfg, y, lp["norm1_post"])
        x = x + y
        h = _norm(cfg, x, lp["norm2"])
        if "moe" in lp:
            if self.mesh is not None:
                h = moe_mod.moe_apply_sharded(lp["moe"], cfg, h, self.ep,
                                              self.mesh)
            else:
                h = moe_mod.moe_apply_local(lp["moe"], cfg, h)
        else:
            act = "gelu" if cfg.family == "audio" else "silu"
            h = ffn_apply(lp["ffn"], h, act=act)
        if cfg.post_norms:
            h = _norm(cfg, h, lp["norm2_post"])
        return x + h, cache
