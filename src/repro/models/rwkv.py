"""RWKV6 (Finch) block: attention-free time mixing with data-dependent decay.

Per head of size N (=64): state S in R^{N x N} evolves as
    y_t = r_t . (diag(u) k_t v_t^T + S_{t-1})
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
with the *data-dependent* per-channel decay  w_t = exp(-exp(w0 + LoRA(x_t)))
— the headline RWKV6 feature.  Token shift uses the learned-mix (v5-style)
form; the decay LoRA keeps the data dependence (simplification recorded in
DESIGN.md §Arch-applicability).

Training runs a ``lax.scan`` over time (the chunked GLA form is the recorded
perf iteration); decode is the O(1) recurrent step — which is why rwkv6 is
one of the two architectures that run the ``long_500k`` cell.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm

LORA_R = 32


def rwkv6_init(key, cfg, dtype) -> Dict:
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    H = d // cfg.rwkv_head_size
    return {
        # time mix
        "mix_r": jnp.full((d,), 0.5, dtype), "mix_k": jnp.full((d,), 0.5, dtype),
        "mix_v": jnp.full((d,), 0.5, dtype), "mix_w": jnp.full((d,), 0.5, dtype),
        "mix_g": jnp.full((d,), 0.5, dtype),
        "wr": dense_init(ks[0], d, d, dtype), "wk": dense_init(ks[1], d, d, dtype),
        "wv": dense_init(ks[2], d, d, dtype), "wg": dense_init(ks[3], d, d, dtype),
        "wo": dense_init(ks[4], d, d, dtype),
        "w0": jnp.full((d,), -2.0, jnp.float32),         # base decay
        "w_lora_a": dense_init(ks[5], d, LORA_R, dtype),
        "w_lora_b": (jax.random.normal(ks[6], (LORA_R, d), jnp.float32)
                     * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[7], (d,), jnp.float32) * 0.1).astype(jnp.float32),
        "ln_x": jnp.ones((d,), dtype),
        # channel mix
        "cmix_k": jnp.full((d,), 0.5, dtype), "cmix_r": jnp.full((d,), 0.5, dtype),
        "ck": dense_init(ks[8], d, cfg.d_ff, dtype),
        "cv": dense_init(ks[9], cfg.d_ff, d, dtype),
        "cr": dense_init(ks[10], d, d, dtype),
    }


def _shift(x: jax.Array, last: jax.Array) -> jax.Array:
    """x_{t-1} with ``last`` as the t=-1 element.  x: [B, S, d], last [B, d]."""
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def _time_mix_inputs(p, cfg, x, last_x):
    xs = _shift(x, last_x)
    mix = lambda m: x * m + xs * (1.0 - m)
    r = mix(p["mix_r"]) @ p["wr"]
    k = mix(p["mix_k"]) @ p["wk"]
    v = mix(p["mix_v"]) @ p["wv"]
    g = jax.nn.silu(mix(p["mix_g"]) @ p["wg"])
    xw = mix(p["mix_w"])
    w = jnp.exp(-jnp.exp(p["w0"] + (jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
                                    ).astype(jnp.float32)))   # [B,S,d] in (0,1)
    return r, k, v, g, w


def _wkv(r, k, v, w, u, state, head_size):
    """One step.  r,k,v,w: [B, d]; state: [B, H, N, N] -> (y [B, d], state)."""
    B, d = r.shape
    H, N = d // head_size, head_size
    rh = r.reshape(B, H, N).astype(jnp.float32)
    kh = k.reshape(B, H, N).astype(jnp.float32)
    vh = v.reshape(B, H, N).astype(jnp.float32)
    wh = w.reshape(B, H, N)
    uh = u.reshape(H, N)
    kv = jnp.einsum("bhk,bhv->bhkv", kh, vh)
    y = jnp.einsum("bhk,bhkv->bhv", rh, uh[None, :, :, None] * kv + state)
    state = wh[..., None] * state + kv
    return y.reshape(B, d), state


def rwkv6_time_mix(p, cfg, x: jax.Array, state: Dict) -> Tuple[jax.Array, Dict]:
    """Full-sequence time mixing via scan over time.  x: [B, S, d]."""
    B, S, d = x.shape
    r, k, v, g, w = _time_mix_inputs(p, cfg, x, state["last_x"])

    def step(s, inp):
        rt, kt, vt, wt = inp
        y, s = _wkv(rt, kt, vt, wt, p["u"], s, cfg.rwkv_head_size)
        return s, y

    s_new, ys = jax.lax.scan(step, state["S"],
                             (jnp.moveaxis(r, 1, 0), jnp.moveaxis(k, 1, 0),
                              jnp.moveaxis(v, 1, 0), jnp.moveaxis(w, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    y = rms_norm(y, p["ln_x"]) * g
    out = y @ p["wo"]
    return out, {"S": s_new, "last_x": x[:, -1]}


def rwkv6_time_mix_chunked(p, cfg, x: jax.Array, state: Dict,
                           chunk: int = 16) -> Tuple[jax.Array, Dict]:
    """Chunked (GLA-style) time mixing — the TPU perf iteration.

    The stepwise scan issues O(S) tiny VPU ops and per-step HBM round-trips
    (the rwkv6 train_4k cell's 2666 s memory term).  Within a chunk of L
    steps the recurrence is a decay-masked (L x L) matmul; only the
    chunk-to-chunk state is carried (S/L scan steps).  All decay ratios are
    exp(lw_a - lw_b) with a >= b, so every factor is <= 1 — no overflow.
    Exactly equal to rwkv6_time_mix up to float round-off.
    """
    B, S, d = x.shape
    H = d // cfg.rwkv_head_size
    N = cfg.rwkv_head_size
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L
    r, k, v, g, w = _time_mix_inputs(p, cfg, x, state["last_x"])
    rh = r.reshape(B, nc, L, H, N).astype(jnp.float32)
    kh = k.reshape(B, nc, L, H, N).astype(jnp.float32)
    vh = v.reshape(B, nc, L, H, N).astype(jnp.float32)
    lw = jnp.log(w.reshape(B, nc, L, H, N))          # negative
    lcum = jnp.cumsum(lw, axis=2)                    # [B,nc,L,H,N]
    lprev = jnp.concatenate([jnp.zeros_like(lcum[:, :, :1]),
                             lcum[:, :, :-1]], axis=2)   # lw cum through t-1
    uh = p["u"].reshape(H, N)

    # intra-chunk: a[t, j] = sum_n r_t exp(lprev_t - lcum_j) k_j   (j < t)
    ratio = jnp.exp(lprev[:, :, :, None] - lcum[:, :, None])  # [B,nc,L,L,H,N]
    a = jnp.einsum("bcthn,bcjhn,bctjhn->bchtj", rh, kh, ratio)
    tri = jnp.tril(jnp.ones((L, L), bool), k=-1)
    a = jnp.where(tri[None, None, None], a, 0.0)
    y = jnp.einsum("bchtj,bcjhn->bcthn", a, vh)
    # diagonal bonus term: r_t . (u o k_t) v_t
    diag = jnp.einsum("bcthn,bcthn->bcth", rh, uh[None, None, None] * kh)
    y = y + diag[..., None] * vh

    # inter-chunk: y_t += (r_t o exp(lprev_t)) S_prev ; scan over chunks
    k_tail = kh * jnp.exp(lcum[:, :, -1:] - lcum)    # decay k_j to chunk end

    def step(S0, inp):
        r_dec, kt, vt, dec_all = inp                 # per-chunk tensors
        y_in = jnp.einsum("bthn,bhnv->bthv", r_dec, S0)
        S1 = S0 * dec_all[..., None] + jnp.einsum("bthn,bthv->bhnv", kt, vt)
        return S1, y_in

    r_dec = (rh * jnp.exp(lprev)).transpose(1, 0, 2, 3, 4)   # [nc,B,L,H,N]
    k_t = k_tail.transpose(1, 0, 2, 3, 4)
    v_t = vh.transpose(1, 0, 2, 3, 4)
    dec_all = jnp.exp(lcum[:, :, -1]).transpose(1, 0, 2, 3)  # [nc,B,H,N]
    S_new, y_inter = jax.lax.scan(step, state["S"], (r_dec, k_t, v_t, dec_all))
    y = y + y_inter.transpose(1, 0, 2, 3, 4)
    y = y.reshape(B, S, d).astype(x.dtype)
    y = rms_norm(y, p["ln_x"]) * g
    return y @ p["wo"], {"S": S_new, "last_x": x[:, -1]}


def rwkv6_channel_mix(p, cfg, x: jax.Array, state: Dict) -> Tuple[jax.Array, Dict]:
    xs = _shift(x, state["last_x_c"])
    xk = x * p["cmix_k"] + xs * (1.0 - p["cmix_k"])
    xr = x * p["cmix_r"] + xs * (1.0 - p["cmix_r"])
    k = jnp.square(jax.nn.relu(xk @ p["ck"]))
    return jax.nn.sigmoid(xr @ p["cr"]) * (k @ p["cv"]), {"last_x_c": x[:, -1]}


def rwkv6_init_state(cfg, batch: int, dtype) -> Dict:
    d = cfg.d_model
    H = d // cfg.rwkv_head_size
    N = cfg.rwkv_head_size
    return {"S": jnp.zeros((batch, H, N, N), jnp.float32),
            "last_x": jnp.zeros((batch, d), dtype),
            "last_x_c": jnp.zeros((batch, d), dtype)}


def rwkv6_block_apply(p, cfg, x, state, norm1, norm2):
    """Pre-norm residual block: time mix then channel mix."""
    chunked = getattr(cfg, "rwkv_chunk", 0)
    tm_state = {k: state[k] for k in ("S", "last_x")}
    if chunked and x.shape[1] % chunked == 0 and x.shape[1] > 1:
        y, st_t = rwkv6_time_mix_chunked(p, cfg, rms_norm(x, norm1), tm_state,
                                         chunk=chunked)
    else:
        y, st_t = rwkv6_time_mix(p, cfg, rms_norm(x, norm1), tm_state)
    x = x + y
    y, st_c = rwkv6_channel_mix(p, cfg, rms_norm(x, norm2),
                                {"last_x_c": state["last_x_c"]})
    x = x + y
    return x, {**st_t, **st_c}
