"""Node-aware (hierarchical) collectives — the paper's 3-step pattern on a pod mesh.

The NAPSpMV insight (Sec. 4): traffic that must cross the *expensive* network
level should first be aggregated at the *cheap* level, cross once per
(node, node) pair deduplicated, and then be redistributed cheaply on the
receiving side.  On a TPU fleet the two levels are intra-pod ICI
(~50 GB/s/link) and inter-pod DCI (scarce).  Mesh convention throughout:
``outer_axis`` = "pod" (expensive, crosses DCI), ``inner_axis`` = intra-pod
axis (cheap ICI).

All functions here are *manual-collective* primitives: they must be called
inside :func:`jax.shard_map` with the named axes in scope.  Each has a flat
(topology-oblivious) counterpart so benchmarks can compare like-for-like:

====================  =========================================
flat                   node-aware
====================  =========================================
``psum(x, (i, o))``    ``nap_psum`` : RS(inner) -> psum(outer) -> AG(inner)
``all_gather(flat)``   ``nap_all_gather`` : AG(outer on 1/inner bytes) -> AG(inner)
``psum_scatter(flat)`` ``nap_reduce_scatter``
``all_to_all(flat)``   ``nap_all_to_all`` : 3-step (gather, inject once, scatter)
====================  =========================================

DCI byte count: a flat psum over ``(inner, outer)`` moves the *full* buffer
across DCI; ``nap_psum`` moves ``1/|inner|`` of it — the same factor the paper
gets by deduplicating node-pair messages (Fig. 8).

``compressed_psum_outer`` additionally quantizes the DCI stage to int8 with
error feedback (residual carried in optimizer state), a beyond-paper
distributed-optimization trick: ICI stays full precision, only the scarce
DCI link carries compressed payloads.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat

Pytree = Any


# ---------------------------------------------------------------------------
# Shape plumbing
# ---------------------------------------------------------------------------

def _flatten_concat(tree: Pytree) -> Tuple[jnp.ndarray, Any, list]:
    """Concatenate all leaves into one flat f32 vector (for fused collectives)."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [(l.shape, l.dtype) for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves]) \
        if leaves else jnp.zeros((0,), jnp.float32)
    return flat, treedef, shapes


def _split_restore(flat: jnp.ndarray, treedef, shapes) -> Pytree:
    out, off = [], 0
    for shape, dtype in shapes:
        n = 1
        for s in shape:
            n *= s
        out.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def _pad_to_multiple(x: jnp.ndarray, k: int) -> jnp.ndarray:
    pad = (-x.shape[0]) % k
    return jnp.pad(x, ((0, pad),)) if pad else x


# ---------------------------------------------------------------------------
# Hierarchical all-reduce (gradient synchronisation)
# ---------------------------------------------------------------------------

def nap_psum(x: jnp.ndarray, inner_axis: str, outer_axis: str) -> jnp.ndarray:
    """all-reduce over (inner x outer) with 1/|inner| of the bytes on DCI.

    reduce-scatter over ``inner_axis`` (ICI), psum over ``outer_axis`` (DCI,
    on the scattered shard), all-gather over ``inner_axis`` (ICI).
    Equivalent to ``lax.psum(x, (inner_axis, outer_axis))``.
    """
    inner = compat.axis_size(inner_axis)
    orig_shape = x.shape
    flat = _pad_to_multiple(x.reshape(-1), inner)
    shard = lax.psum_scatter(flat, inner_axis, scatter_dimension=0, tiled=True)
    shard = lax.psum(shard, outer_axis)
    full = lax.all_gather(shard, inner_axis, axis=0, tiled=True)
    n = 1
    for s in orig_shape:
        n *= s
    return full[:n].reshape(orig_shape)


def nap_psum_tree(tree: Pytree, inner_axis: str, outer_axis: str) -> Pytree:
    """Fused hierarchical all-reduce of a whole gradient pytree.

    One RS/AG pair for the entire flattened gradient — fewer collective
    launches (the paper's message-count reduction) *and* minimal DCI bytes.
    """
    flat, treedef, shapes = _flatten_concat(tree)
    red = nap_psum(flat, inner_axis, outer_axis)
    return _split_restore(red, treedef, shapes)


def flat_psum_tree(tree: Pytree, axes: Sequence[str]) -> Pytree:
    """Reference topology-oblivious gradient sync."""
    return jax.tree.map(lambda g: lax.psum(g, tuple(axes)), tree)


def nap_all_gather(x: jnp.ndarray, inner_axis: str, outer_axis: str,
                   axis: int = 0) -> jnp.ndarray:
    """all-gather over (outer x inner): cross DCI first on small shards, then
    replicate over ICI.  Equivalent to gathering over both axes flat, with
    1/|inner| of the bytes injected per DCI hop."""
    pod = lax.all_gather(x, outer_axis, axis=axis, tiled=True)
    return lax.all_gather(pod, inner_axis, axis=axis, tiled=True)


def nap_reduce_scatter(x: jnp.ndarray, inner_axis: str, outer_axis: str) -> jnp.ndarray:
    """reduce-scatter over (inner x outer): ICI RS shrinks the buffer |inner|x
    before the DCI RS touches it."""
    shard = lax.psum_scatter(x, inner_axis, scatter_dimension=0, tiled=True)
    return lax.psum_scatter(shard, outer_axis, scatter_dimension=0, tiled=True)


# ---------------------------------------------------------------------------
# Hierarchical (3-step) all-to-all — the literal NAPSpMV pattern
# ---------------------------------------------------------------------------

def nap_all_to_all(x: jnp.ndarray, inner_axis: str, outer_axis: str) -> jnp.ndarray:
    """All-to-all over the flat (outer*inner) grid via the paper's 3 steps.

    ``x`` has leading dim ``n_out*n_in`` (destination rank, SMP order: rank
    ``d = o*n_in + i``).  Step 1 (local gather): intra-pod all-to-all so that
    slot ``p`` of each pod holds everything the pod must send to remote slot
    ``p`` — the T/U "aligned" pairing of comm_graph.  Step 2: ONE aggregated
    inter-pod all-to-all.  Step 3 (local scatter): intra-pod all-to-all
    delivering to final destinations.  Bitwise-equal to the flat all-to-all
    over ``(outer, inner)``.
    """
    n_in = compat.axis_size(inner_axis)
    n_out = compat.axis_size(outer_axis)
    rest = x.shape[1:]
    # [n_out*n_in, ...] -> [n_out, n_in, ...]: row o = payload for pod o.
    y = x.reshape((n_out, n_in) + rest)
    # Step 1: bring "everything this pod sends to pod o" onto local slot o%?
    # aligned pairing: local slot p keeps destination-slot p payloads.
    # all_to_all over inner on the *destination-slot* dim (axis 1).
    y = lax.all_to_all(y, inner_axis, split_axis=1, concat_axis=1, tiled=True)
    # now y[o] on local slot p = payloads from every local slot s to (o, p):
    # shape [n_out, n_in, ...] where axis-1 index s = source slot.
    # Step 2: one aggregated DCI all-to-all over the pod axis (axis 0).
    y = lax.all_to_all(y, outer_axis, split_axis=0, concat_axis=0, tiled=True)
    # y[o'] = payload from pod o' destined to (this pod, this slot), per src slot.
    # Step 3: local scatter — deliver source-slot payloads home: the data is
    # already at the right (pod, slot); flatten source grid back to rank order.
    return y.reshape((n_out * n_in,) + rest)


def flat_all_to_all(x: jnp.ndarray, inner_axis: str, outer_axis: str) -> jnp.ndarray:
    """Topology-oblivious all-to-all over the combined (outer, inner) axis."""
    return lax.all_to_all(x, (outer_axis, inner_axis), split_axis=0,
                          concat_axis=0, tiled=True)


# ---------------------------------------------------------------------------
# int8 error-feedback compression for the DCI stage (beyond paper)
# ---------------------------------------------------------------------------

def _quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_outer(x: jnp.ndarray, outer_axis: str,
                          residual: Optional[jnp.ndarray] = None
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """psum over the pod axis with int8-on-the-wire + error feedback.

    Ring reduce-scatter then ring all-gather over ``outer_axis`` using
    ``ppermute``; every hop carries int8 payload + one f32 scale per chunk.
    ``residual`` (same shape as x) carries quantization error to the next
    step (error feedback keeps SGD/Adam convergence unbiased in practice).

    Returns (sum, new_residual).
    """
    n = compat.axis_size(outer_axis)
    if residual is None:
        residual = jnp.zeros_like(x)
    xc = x + residual
    if n == 1:
        return xc, jnp.zeros_like(x)

    orig = xc.shape
    flat = _pad_to_multiple(xc.reshape(-1), n)
    chunks = flat.reshape(n, -1)  # chunk c belongs to rank c after RS
    idx = lax.axis_index(outer_axis)

    sent_err = jnp.zeros_like(chunks)

    # ring reduce-scatter: step s, send chunk (idx - s - 1) to right neighbour;
    # receive the chunk our left neighbour sent, (idx - s - 2), and accumulate.
    perm = [(i, (i + 1) % n) for i in range(n)]
    acc = chunks
    for s in range(n - 1):
        send_c = (idx - s - 1) % n
        payload = acc[send_c]
        q, scale = _quantize_int8(payload)
        deq = q.astype(jnp.float32) * scale
        # record what we failed to transmit for the chunk we just sent
        sent_err = sent_err.at[send_c].add(payload - deq)
        acc = acc.at[send_c].set(0.0)  # sent away; zero to avoid double count
        q_in = lax.ppermute(q, outer_axis, perm)
        scale_in = lax.ppermute(scale, outer_axis, perm)
        rc = (idx - s - 2) % n
        acc = acc.at[rc].add(q_in.astype(jnp.float32) * scale_in)
    # after n-1 steps rank holds the full sum of chunk ``idx`` (mod quant error)
    mine = acc[idx]

    # ring all-gather of the reduced chunks, int8 on the wire again.  Every
    # rank applies the *dequantized* value (including the chunk owner) so the
    # result is bitwise identical on all replicas — parameters cannot drift.
    q, scale = _quantize_int8(mine)
    mine_deq = q.astype(jnp.float32) * scale
    out = jnp.zeros_like(chunks)
    out = out.at[idx].set(mine_deq)
    ag_err = jnp.zeros_like(chunks)
    ag_err = ag_err.at[idx].add(mine - mine_deq)
    cur_q, cur_s, cur_c = q, scale, idx
    for s in range(n - 1):
        cur_q = lax.ppermute(cur_q, outer_axis, perm)
        cur_s = lax.ppermute(cur_s, outer_axis, perm)
        cur_c = lax.ppermute(cur_c, outer_axis, perm)
        out = out.at[cur_c].add(cur_q.astype(jnp.float32) * cur_s)

    total = out.reshape(-1)[: xc.size].reshape(orig)
    # error feedback: local quantization error of chunks this rank transmitted
    new_residual = (sent_err + ag_err).reshape(-1)[: xc.size].reshape(orig)
    return total, new_residual


def nap_psum_compressed(x: jnp.ndarray, inner_axis: str, outer_axis: str,
                        residual: Optional[jnp.ndarray] = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Hierarchical all-reduce with int8 DCI stage: RS(ICI, fp32) ->
    compressed psum(DCI, int8+EF) -> AG(ICI, fp32)."""
    inner = compat.axis_size(inner_axis)
    orig = x.shape
    flat = _pad_to_multiple(x.reshape(-1), inner)
    shard = lax.psum_scatter(flat, inner_axis, scatter_dimension=0, tiled=True)
    if residual is None:
        res_in = jnp.zeros_like(shard)
    else:
        res_in = residual
    shard, res_out = compressed_psum_outer(shard, outer_axis, res_in)
    full = lax.all_gather(shard, inner_axis, axis=0, tiled=True)
    n = 1
    for s in orig:
        n *= s
    return full[:n].reshape(orig), res_out


def residual_shape_for(x_shape: Tuple[int, ...], inner: int) -> Tuple[int, ...]:
    """Shape of the error-feedback residual for nap_psum_compressed."""
    n = 1
    for s in x_shape:
        n *= s
    padded = n + ((-n) % inner)
    return (padded // inner,)


# ---------------------------------------------------------------------------
# NAP MoE dispatch: the paper's technique applied to expert parallelism
# ---------------------------------------------------------------------------

def nap_moe_dispatch(tokens: jnp.ndarray, dest_chip: jnp.ndarray,
                     inner_axis: str, outer_axis: str,
                     capacity: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Send each token to the expert-parallel chip(s) in ``dest_chip``.

    The token->expert routing matrix is literally a sparse matrix, so MoE
    dispatch *is* an SpMV gather: NAPSpMV applies verbatim.  A token bound
    for two experts hosted on the *same remote pod* crosses DCI **once**
    (the paper's E(n, m) dedup) and is fanned out on the receiving pod.

    tokens:    [T, D]      local token shard
    dest_chip: [T, K]      global EP-chip id per (token, expert-choice),
                           -1 for dropped.
    capacity:  per-(src chip, dst chip) buffer slots.

    Returns (recv_tokens [n_chips*capacity_in, D], recv_src_slot, recv_valid)
    where the receive buffer is ordered by source chip.  This primitive is
    exercised by the MoE layer; see models/moe.py for the full layer.
    """
    n_in = compat.axis_size(inner_axis)
    n_out = compat.axis_size(outer_axis)
    T, D = tokens.shape
    K = dest_chip.shape[1]
    my_pod = lax.axis_index(outer_axis)
    my_loc = lax.axis_index(inner_axis)
    my_chip = my_pod * n_in + my_loc

    dest_pod = jnp.where(dest_chip >= 0, dest_chip // n_in, -1)

    # --- dedup: does token t need pod o at all? (E(n,m) membership) ---------
    need_pod = jnp.zeros((T, n_out), dtype=bool)
    for k in range(K):
        need_pod = need_pod | (dest_pod[:, k:k + 1] == jnp.arange(n_out)[None, :])

    # slot of token t in the pod-o buffer (capacity-dropped FIFO); slots past
    # capacity go out-of-bounds and are dropped by scatter mode="drop".
    pod_slot = jnp.cumsum(need_pod.astype(jnp.int32), axis=0) - 1  # [T, n_out]
    pod_slot = jnp.where(need_pod & (pod_slot < capacity), pod_slot, capacity)

    # pack [n_out, capacity, D] + the token's chip list so the remote pod can
    # fan out: we ship dest_chip along with the payload.  Source provenance is
    # a global id (chip * T + token) so the combine path can route back.
    buf = jnp.zeros((n_out, capacity, D), tokens.dtype)
    meta = jnp.full((n_out, capacity, K), -1, jnp.int32)       # dest chips
    srcs = jnp.full((n_out, capacity), -1, jnp.int32)          # global src id
    src_gid = my_chip * T + jnp.arange(T, dtype=jnp.int32)
    for o in range(n_out):  # static tiny loop over pods
        sel = pod_slot[:, o]
        buf = buf.at[o, sel].set(tokens, mode="drop")
        meta = meta.at[o, sel].set(dest_chip, mode="drop")
        srcs = srcs.at[o, sel].set(src_gid, mode="drop")

    # --- step 1+2: aggregate intra-pod is implicit (tokens start sharded);
    # ONE aggregated inter-pod exchange ---------------------------------------
    buf = lax.all_to_all(buf, outer_axis, 0, 0, tiled=True)    # [n_out, cap, D]
    meta = lax.all_to_all(meta, outer_axis, 0, 0, tiled=True)
    srcs = lax.all_to_all(srcs, outer_axis, 0, 0, tiled=True)

    # --- step 3: local scatter to the owning chips within this pod ----------
    flat_tok = buf.reshape(n_out * capacity, D)
    flat_meta = meta.reshape(n_out * capacity, K)
    flat_src = srcs.reshape(n_out * capacity)
    # which local chip(s) need each arrived token?
    local_of = jnp.where(flat_meta >= 0, flat_meta % n_in, -1)
    pod_of = jnp.where(flat_meta >= 0, flat_meta // n_in, -1)
    need_local = jnp.zeros((n_out * capacity, n_in), bool)
    for k in range(K):
        need_local = need_local | ((pod_of[:, k:k + 1] == my_pod) &
                                   (local_of[:, k:k + 1] == jnp.arange(n_in)[None, :]))
    loc_slot = jnp.cumsum(need_local.astype(jnp.int32), axis=0) - 1
    loc_slot = jnp.where(need_local & (loc_slot < capacity), loc_slot, capacity)
    lbuf = jnp.zeros((n_in, capacity, D), tokens.dtype)
    lsrc = jnp.full((n_in, capacity), -1, jnp.int32)
    for i in range(n_in):
        sel = loc_slot[:, i]
        lbuf = lbuf.at[i, sel].set(flat_tok, mode="drop")
        lsrc = lsrc.at[i, sel].set(flat_src, mode="drop")
    lbuf = lax.all_to_all(lbuf, inner_axis, 0, 0, tiled=True)
    lsrc = lax.all_to_all(lsrc, inner_axis, 0, 0, tiled=True)
    recv = lbuf.reshape(n_in * capacity, D)
    recv_src = lsrc.reshape(n_in * capacity)
    return recv, recv_src, recv_src >= 0
