"""Communication performance models (paper Sec. 3) and plan cost evaluation.

* Eq. (10): **max-rate** model for inter-node messages
      T = alpha + ppn*s / min(B_N, B_max + (ppn-1) * B_inj)
  (with the paper's Blue Waters measurements, Table 3)
* Eq. (11): postal model (ppn = 1 special case)
* Eq. (12): **intra-node** model  T_l = alpha_l + s_l / B_max_l  (Table 4)

Protocol selection (short / eager / rendezvous) follows MPI size thresholds;
the paper does not state Blue Waters' cutoffs, so we use MPICH-on-Gemini's
conventional 512 B (short) and 8 KiB (eager->rendezvous) — the benchmarks
expose them as parameters.

A TPU parameter set expresses the same two-level asymmetry for a v5e fleet
(ICI intra-pod vs DCI inter-pod); it feeds the NAP-vs-flat collective
choice and the §Roofline collective term.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.core.comm_graph import Message, NAPPlan, StandardPlan

SHORT_CUTOFF = 512        # bytes
EAGER_CUTOFF = 8 * 1024   # bytes


@dataclasses.dataclass(frozen=True)
class ProtocolParams:
    alpha: float   # start-up latency (s)
    b_inj: float   # per-node injection rate (B/s)
    b_max: float   # per-process achievable rate (B/s)
    b_n: float     # NIC peak (B/s)


@dataclasses.dataclass(frozen=True)
class LocalParams:
    alpha: float
    b_max: float


@dataclasses.dataclass(frozen=True)
class MachineParams:
    """Two-level machine: inter-node (max-rate) + intra-node (postal)."""

    name: str
    inter: Dict[str, ProtocolParams]  # keyed by protocol
    intra: Dict[str, LocalParams]
    short_cutoff: int = SHORT_CUTOFF
    eager_cutoff: int = EAGER_CUTOFF

    def protocol(self, nbytes: int) -> str:
        if nbytes <= self.short_cutoff:
            return "short"
        if nbytes <= self.eager_cutoff:
            return "eager"
        return "rend"


# Paper Table 3 (inter) and Table 4 (intra) — Blue Waters Cray XE / Gemini.
BLUE_WATERS = MachineParams(
    name="blue_waters",
    inter={
        "short": ProtocolParams(alpha=4.0e-6, b_inj=6.3e8, b_max=1.8e7, b_n=float("inf")),
        "eager": ProtocolParams(alpha=1.1e-5, b_inj=1.7e9, b_max=6.2e7, b_n=float("inf")),
        "rend": ProtocolParams(alpha=2.0e-5, b_inj=3.6e9, b_max=6.1e8, b_n=5.5e9),
    },
    intra={
        "short": LocalParams(alpha=1.3e-6, b_max=4.2e8),
        "eager": LocalParams(alpha=1.6e-6, b_max=7.4e8),
        "rend": LocalParams(alpha=4.2e-6, b_max=3.1e9),
    },
)

# TPU v5e-fleet analogue: "node" = pod slice (ICI), "network" = inter-pod DCI.
# ICI: ~5e10 B/s per link; DCI modelled at ~6.25e9 B/s per chip with ~10 us
# collective start-up; intra-pod start-up ~1 us.  Single protocol (bulk DMA).
TPU_V5E = MachineParams(
    name="tpu_v5e",
    inter={k: ProtocolParams(alpha=1.0e-5, b_inj=2.5e10, b_max=6.25e9, b_n=1.0e11)
           for k in ("short", "eager", "rend")},
    intra={k: LocalParams(alpha=1.0e-6, b_max=5.0e10) for k in ("short", "eager", "rend")},
)


def inter_node_time(nbytes: int, ppn: int, machine: MachineParams) -> float:
    """Eq. (10) max-rate model for one inter-node message of ``nbytes``."""
    p = machine.inter[machine.protocol(nbytes)]
    rate = min(p.b_n, p.b_max + (ppn - 1) * p.b_inj) if ppn > 1 else p.b_max
    if ppn == 1:
        return p.alpha + nbytes / p.b_max  # Eq. (11), postal model
    return p.alpha + (ppn * nbytes) / rate


def intra_node_time(nbytes: int, machine: MachineParams) -> float:
    """Eq. (12) intra-node postal model."""
    p = machine.intra[machine.protocol(nbytes)]
    return p.alpha + nbytes / p.b_max


# ---------------------------------------------------------------------------
# Plan costing: per-rank sum of message times, max over ranks per phase.
# Phases within an algorithm are sequential (Alg. 3 dependencies), messages
# of one rank within a phase are pipelined (Isend/Irecv): we charge
# max(sum of per-message alpha, per-rank serialisation) per the postal custom:
# each rank pays alpha per message plus bytes at the phase rate.
# ---------------------------------------------------------------------------

def _rank_phase_time(msgs: List[Message], machine: MachineParams, ppn: int,
                     inter: bool, bytes_per_val: int = 8) -> float:
    t = 0.0
    for m in msgs:
        nbytes = m.size * bytes_per_val
        t += inter_node_time(nbytes, ppn, machine) if inter else intra_node_time(nbytes, machine)
    return t


def standard_cost(plan: StandardPlan, machine: MachineParams,
                  bytes_per_val: int = 8) -> Dict[str, float]:
    topo = plan.topology
    inter_t, intra_t = [], []
    for r in range(topo.n_procs):
        inter_msgs = [m for m in plan.sends[r] if not topo.same_node(m.src, m.dst)]
        intra_msgs = [m for m in plan.sends[r] if topo.same_node(m.src, m.dst)]
        inter_t.append(_rank_phase_time(inter_msgs, machine, topo.ppn, True, bytes_per_val))
        intra_t.append(_rank_phase_time(intra_msgs, machine, topo.ppn, False, bytes_per_val))
    # standard SpMV sends everything at once: phases overlap fully.
    return {
        "inter": max(inter_t, default=0.0),
        "intra": max(intra_t, default=0.0),
        "total": max((a + b) for a, b in zip(inter_t, intra_t)) if inter_t else 0.0,
    }


def nap_cost(plan: NAPPlan, machine: MachineParams,
             bytes_per_val: int = 8) -> Dict[str, float]:
    topo = plan.topology
    phases = {
        "intra_init": (plan.local_init_sends, False),
        "inter": (plan.inter_sends, True),
        "intra_final": (plan.local_final_sends, False),
        "intra_full": (plan.local_full_sends, False),
    }
    out: Dict[str, float] = {}
    for name, (sends, is_inter) in phases.items():
        per_rank = [_rank_phase_time(sends[r], machine, topo.ppn, is_inter, bytes_per_val)
                    for r in range(topo.n_procs)]
        out[name] = max(per_rank, default=0.0)
    # Alg. 3 dependencies: init -> inter -> final are sequential; the fully
    # local exchange overlaps the inter-node phase (it has no dependencies).
    out["intra"] = out["intra_init"] + out["intra_final"] + out["intra_full"]
    out["total"] = (out["intra_init"] + max(out["inter"], out["intra_full"])
                    + out["intra_final"])
    return out


def multistep_cost(plan, machine: MachineParams,
                   bytes_per_val: int = 8) -> Dict[str, float]:
    """Cost of a :class:`repro.comm.multistep.MultistepPlan`: the NAP
    sub-plan's phase chain plus the direct exchange, which shares the
    network with (and so serialises against) the aggregated inter
    phase; the fully-local exchange still overlaps both."""
    out = nap_cost(plan.nap, machine, bytes_per_val)
    direct = standard_cost(plan.direct, machine, bytes_per_val)
    # every direct message crosses nodes, and the shared network
    # serialises it with the aggregated inter phase
    out["direct"] = direct["inter"]
    out["inter"] = out["inter"] + direct["inter"]
    out["total"] = (out["intra_init"] + max(out["inter"], out["intra_full"])
                    + out["intra_final"])
    return out


def compute_time(nnz: int, flop_rate: float = 2.0e9) -> float:
    """Local SpMV compute estimate: 2 flops per nonzero at an effective rate
    (memory-bound; ~2 GF/s/core is representative of Interlagos SpMV)."""
    return 2.0 * nnz / flop_rate


# ---------------------------------------------------------------------------
# Postal comm term for the comm-strategy autotuner (repro.comm)
# ---------------------------------------------------------------------------
#
# The models above cost individual MPI-style messages at their EFFECTIVE
# size.  The SPMD lowerings ship PADDED slots (every message in an
# all_to_all stretches to the phase's max message), so the comm-strategy
# chooser needs an alpha-beta term over the slot-granular padded bytes
# that ``repro.comm.cost.planned_traffic`` reports — effective bytes say
# what must move, padded bytes say what the program actually injects.

@dataclasses.dataclass(frozen=True)
class PostalParams:
    """Flat two-level postal model: per-message start-up alpha plus
    padded bytes at rate beta, separately for network (inter-node) and
    intra-node hops.  TPU v5e-ish defaults (DCI vs ICI)."""

    name: str = "tpu_v5e_postal"
    alpha_inter: float = 1.0e-5
    beta_inter: float = 6.25e9
    alpha_intra: float = 1.0e-6
    beta_intra: float = 5.0e10

    def signature(self) -> tuple:
        return dataclasses.astuple(self)

    @classmethod
    def calibrated(cls, walls: List[Dict],
                   name: str = "calibrated") -> "PostalParams":
        """Fit the postal constants from MEASURED per-phase exchange walls.

        ``walls`` — records with ``n_msgs`` (bottleneck-rank messages),
        ``nbytes`` (bottleneck-rank padded bytes), ``inter`` (bool level
        flag) and ``seconds``, exactly what
        :func:`repro.mesh.scaling.measure_phase_walls` emits.  Each level
        solves the least-squares system ``seconds ≈ alpha*n_msgs +
        nbytes/beta`` over its records; a level with fewer than two
        usable records — or a fit with a non-positive coefficient (noise
        at micro-benchmark scale) — keeps that constant's TPU_V5E
        default, so a partial calibration degrades gracefully instead of
        producing a nonsense machine model.
        """
        import numpy as np
        d = cls()
        fitted = {"inter": (d.alpha_inter, d.beta_inter),
                  "intra": (d.alpha_intra, d.beta_intra)}
        for level in ("inter", "intra"):
            recs = [w for w in walls
                    if bool(w["inter"]) == (level == "inter")
                    and w["n_msgs"] > 0 and w["seconds"] > 0]
            if len(recs) < 2:
                continue
            design = np.array([[r["n_msgs"], r["nbytes"]] for r in recs],
                              dtype=np.float64)
            t = np.array([r["seconds"] for r in recs], dtype=np.float64)
            coef, *_ = np.linalg.lstsq(design, t, rcond=None)
            alpha, inv_beta = (float(coef[0]), float(coef[1]))
            da, db = fitted[level]
            fitted[level] = (alpha if alpha > 0 else da,
                             1.0 / inv_beta if inv_beta > 0 else db)
        return cls(name=name,
                   alpha_inter=fitted["inter"][0],
                   beta_inter=fitted["inter"][1],
                   alpha_intra=fitted["intra"][0],
                   beta_intra=fitted["intra"][1])


TPU_V5E_POSTAL = PostalParams()


def postal_phase_time(n_msgs: int, nbytes: float, inter: bool,
                      params: PostalParams = TPU_V5E_POSTAL) -> float:
    """alpha-beta time for one exchange phase at one rank: ``n_msgs``
    start-ups plus ``nbytes`` (padded) at the level's rate."""
    if n_msgs == 0:
        return 0.0
    alpha, beta = (params.alpha_inter, params.beta_inter) if inter \
        else (params.alpha_intra, params.beta_intra)
    return n_msgs * alpha + nbytes / beta


def postal_comm_time(traffic: Dict, params: PostalParams = TPU_V5E_POSTAL
                     ) -> Dict[str, float]:
    """Modeled seconds for one exchange schedule.

    ``traffic`` is a :func:`repro.comm.cost.planned_traffic` payload.
    Phases run sequentially (the lowerings are bulk-synchronous); each
    phase is charged at its bottleneck rank using the slot-granular
    padded bytes plus the integrity side-channel when armed.
    """
    out: Dict[str, float] = {}
    total = 0.0
    for name, ph in traffic["phases"].items():
        t = postal_phase_time(
            ph["max_rank_msgs"],
            ph["max_rank_padded_bytes"] + ph["checksum_bytes"],
            ph["inter"], params)
        out[name] = t
        total += t
    out["total"] = total
    return out


# ---------------------------------------------------------------------------
# Local-compute format autotuner (BSR vs ELL vs COO)
# ---------------------------------------------------------------------------
#
# The shared-memory SpMV literature's core lesson — no single sparse format
# wins across structures — applied to the rank-local compute of the
# distributed SpMV.  Each candidate is scored with a two-term roofline
#
#     t = max(padded_flops / unit_rate, bytes_moved / hbm_bw)
#
# where "padded" counts the work the static layout actually issues (dense
# (bm, bn) tiles for BSR, kmax-padded rows for ELL, nnz-padded triples for
# COO), and the unit rate reflects which hardware unit executes it: BSR
# feeds the MXU, ELL the VPU (vector gather + FMA), COO an effective
# scatter/segment-sum rate that is brutally low on TPU.  The SPMD program
# is bulk-synchronous, so the per-call decision uses stats maxed over
# ranks; per-rank estimates are still recorded for diagnostics.


@dataclasses.dataclass(frozen=True)
class LocalComputeParams:
    """Effective unit rates for the local-compute roofline (f32, TPU-ish).

    Absolute values matter less than ratios: MXU >> VPU >> scatter, and
    everything can be HBM-bound.  ``vmem_x_budget`` bounds the packed x
    operand the ELL kernel holds resident per nv tile.
    """

    name: str = "tpu_v5e_local"
    mxu_flops: float = 5.0e13     # dense-block matmul rate
    vpu_flops: float = 2.0e12     # vectorised gather+FMA rate
    scatter_flops: float = 4.0e9  # segment_sum / scalar scatter-add rate
    hbm_bw: float = 8.1e11        # HBM bandwidth
    vmem_x_budget: int = 8 * 2**20  # max packed-x bytes per ELL nv tile

    def signature(self) -> tuple:
        return dataclasses.astuple(self)


TPU_V5E_LOCAL = LocalComputeParams()

LOCAL_FORMATS = ("bsr", "ell", "coo")


def local_format_times(stats: Dict[str, float],
                       params: LocalComputeParams = TPU_V5E_LOCAL,
                       nv: int = 1) -> Dict[str, float]:
    """Per-format modeled seconds for one local SpMV application.

    ``stats`` (all padded to the SPMD max over ranks, per-rank element
    counts — see ``spmv_jax._autotune_stats``):
      rows_pad   output rows
      n_x        packed x length (v_loc + on-node + off-node buffers)
      nnz_pad    COO triples incl. cross-rank padding
      bsr_blocks padded (bm, bn) tiles incl. cross-rank kmax alignment
      bm, bn     block shape
      ell_kmax   padded ELL slots per row (cross-rank max)
    """
    bm, bn = int(stats["bm"]), int(stats["bn"])
    rows, n_x = stats["rows_pad"], stats["n_x"]
    out_b = 4 * rows * nv

    blocks = stats["bsr_blocks"]
    bsr_flops = 2.0 * blocks * bm * bn * nv
    bsr_bytes = blocks * (bm * bn * 4 + bn * 4 * nv) + out_b
    times = {"bsr": max(bsr_flops / params.mxu_flops,
                        bsr_bytes / params.hbm_bw)}

    kmax = stats["ell_kmax"]
    ell_flops = 2.0 * rows * kmax * nv
    ell_bytes = rows * kmax * 8 + n_x * 4 * nv + out_b
    ell_x_resident = n_x * 4 * min(nv, 128)
    if ell_x_resident > params.vmem_x_budget:
        times["ell"] = float("inf")  # packed x cannot stay VMEM-resident
    else:
        times["ell"] = max(ell_flops / params.vpu_flops,
                           ell_bytes / params.hbm_bw)

    nnz = stats["nnz_pad"]
    coo_flops = 2.0 * nnz * nv
    coo_bytes = nnz * 12 + nnz * 4 * nv + out_b
    times["coo"] = max(coo_flops / params.scatter_flops,
                       coo_bytes / params.hbm_bw)
    return times


def choose_local_format(stats: Dict[str, float],
                        params: LocalComputeParams = TPU_V5E_LOCAL,
                        nv: int = 1) -> str:
    """argmin-time format for the given layout stats."""
    times = local_format_times(stats, params, nv=nv)
    return min(LOCAL_FORMATS, key=lambda f: times[f])
