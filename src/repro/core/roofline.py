"""Three-term roofline from the compiled dry-run artifact (TPU v5e target).

    compute term    = HLO_FLOPs_per_chip / peak_FLOPs
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = collective_bytes_per_chip / link_bw

The per-chip numbers come straight from the SPMD per-device module via
:mod:`hlo_analysis` (trip-count aware — ``cost_analysis`` is not).  The
dominant term is the bottleneck; its value is the modeled step time, and
MODEL_FLOPS / (chips * peak * step_time) is the modeled MFU.

Hardware constants (assignment): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.  ``collective term`` follows the assignment formula
(operand bytes over one link's bandwidth); the ``wire`` refinement scales
ring collectives by 2(g-1)/g (all-reduce) or (g-1)/g (gather/scatter) over
the per-chip aggregate ICI bandwidth (v5e: 4 links usable per chip on a 2D
torus axis pair).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.hlo_analysis import HLOCost

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes / s / chip
LINK_BW = 50e9               # bytes / s / ICI link
LINKS_PER_CHIP = 4           # usable concurrently on a v5e 2D torus


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    # raw per-chip quantities
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_by_kind: Dict[str, float]
    # terms (seconds)
    t_compute: float
    t_memory: float
    t_collective: float
    t_collective_wire: float
    model_flops: float          # 6 * N(_active) * D tokens, GLOBAL
    useful_ratio: float         # MODEL_FLOPS / (flops * chips)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """No-overlap upper bound is sum; perfectly-overlapped bound is max.
        We report max (the roofline) — iteration drives the max down."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu(self) -> float:
        """Modeled model-FLOPs utilisation at the roofline step time."""
        t = self.step_time
        if t == 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    @property
    def hardware_util(self) -> float:
        """Fraction of peak the dominant resource reaches if all three terms
        ran at their roofline speed (1.0 = dominant term saturates)."""
        t = self.step_time
        return self.t_compute / t if t else 0.0

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} | "
                f"{self.t_compute*1e3:9.2f} | {self.t_memory*1e3:9.2f} | "
                f"{self.t_collective*1e3:9.2f} | {self.dominant:10s} | "
                f"{self.model_flops:.3e} | {self.useful_ratio:5.2f} | "
                f"{self.mfu*100:5.1f}% |")


HEADER = ("| arch | shape | mesh | compute ms | memory ms | collective ms | "
          "dominant | MODEL_FLOPS | useful | MFU |\n"
          "|---|---|---|---|---|---|---|---|---|---|")


def _wire_factor(kind: str, group: float) -> float:
    if group <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (group - 1) / group
    if kind in ("all-gather", "reduce-scatter", "all-to-all"):
        return (group - 1) / group
    return 1.0  # collective-permute


def build_roofline(arch: str, shape: str, mesh_name: str, chips: int,
                   cost: HLOCost, model_flops: float) -> Roofline:
    coll = cost.total_collective_bytes
    wire = 0.0
    for kind, b in cost.collective_bytes.items():
        sizes = cost.group_sizes.get(kind, [])
        g = (sum(sizes) / len(sizes)) if sizes else chips
        wire += b * _wire_factor(kind, g)
    flops = cost.dot_flops
    global_flops = flops * chips
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops=flops, hbm_bytes=cost.hbm_bytes, collective_bytes=coll,
        collective_by_kind=dict(cost.collective_bytes),
        t_compute=flops / PEAK_FLOPS,
        t_memory=cost.hbm_bytes / HBM_BW,
        t_collective=coll / LINK_BW,
        t_collective_wire=wire / (LINK_BW * LINKS_PER_CHIP),
        model_flops=model_flops,
        useful_ratio=(model_flops / global_flops) if global_flops else 0.0,
    )


def model_flops_for(kind: str, n_active_params: int, tokens: int) -> float:
    """MODEL_FLOPS: 6*N*D for training; 2*N*D for inference (fwd only)."""
    factor = 6.0 if kind == "train" else 2.0
    return factor * n_active_params * tokens
