"""Communication graphs for standard and node-aware SpMV (paper Secs. 2.1, 4.1, 4.2).

Implements, verbatim, the paper's set machinery:

* standard:    ``P(r)`` (Eq. 8), ``D(r, t)`` (Eq. 9)
* node level:  ``N(n)`` (Eq. 13), ``E(n, m)`` (Eq. 14)
* distribution:``T((p,n))`` (Eq. 15), ``U((p,n))`` (Eq. 16)
* inter-node:  ``G((p,n))`` (Eq. 17), ``I((p,n),(q,m))`` (Eq. 18)
* intra-node:  ``L(·, locality)`` and ``J(·, ·, locality)`` for the three
  localities (on→off Eq. 19/20, off→on Eq. 21/22, on→on Eq. 23/24).

Note on index semantics: Eqs. (9), (14), (18)… write ``{i | A_ij ≠ 0 …}`` but
the worked Example 2.1 (Tables 2, 6, 9) clearly communicates the *vector*
indices ``j`` owned by the sender — the row index ``i`` merely witnesses the
need.  We implement the ``j`` semantics, which is what the algorithm consumes.

Note on the T/U orderings: the text maps the destination node with the most
data to ``(0, n)`` for sends and to ``(ppn-1, n)`` for receives; the paper's
hand-worked Table 9 does not follow any single consistent ordering (e.g. node
0's sends are in *ascending* data order).  We follow the text's rule with
node-id tie-breaking, and additionally support the TPU-natural pairing
``q = p`` (sender slot = receiver slot) used by the SPMD all-to-all lowering
— the paper itself notes the mapping is a free choice affecting only
intra-node traffic (Sec. 4.1).

All sets are computed once, "as the matrix is formed" (Sec. 2.1), in numpy;
the SPMD executor bakes them in as static gather/scatter maps.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Literal, Optional, Sequence, Tuple

import numpy as np

from repro.core.partition import RowPartition
from repro.core.topology import Topology

Locality = Literal["on_on", "on_off", "off_on"]


@dataclasses.dataclass(frozen=True)
class Message:
    """One point-to-point message: global vector indices ``idx`` from src to dst."""

    src: int
    dst: int
    idx: np.ndarray  # global vector (column) indices, ascending

    @property
    def size(self) -> int:
        return int(self.idx.size)


def flat_slot_map(msgs: Sequence[Message], slots: Sequence[int],
                  pad: int) -> Tuple[np.ndarray, np.ndarray]:
    """Sorted lookup table from global index -> flat padded-buffer position.

    ``msgs[i]`` lands in buffer slot ``slots[i]``; element k of a message
    sits at flat position ``slots[i] * pad + k``.  Returns parallel arrays
    ``(idx, pos)`` with ``idx`` ascending, so consumers resolve whole index
    arrays with one ``np.searchsorted`` instead of per-element probing.
    Indices must be disjoint across the phase's messages (asserted).
    """
    if not msgs:
        z = np.empty(0, dtype=np.int64)
        return z, z.copy()
    idx = np.concatenate([m.idx for m in msgs])
    pos = np.concatenate([s * pad + np.arange(m.size, dtype=np.int64)
                          for s, m in zip(slots, msgs)])
    order = np.argsort(idx, kind="stable")
    idx, pos = idx[order], pos[order]
    assert idx.size < 2 or (np.diff(idx) > 0).all(), \
        "phase delivers one index through two messages"
    return idx, pos


def lookup_slots(table: Tuple[np.ndarray, np.ndarray],
                 query: np.ndarray) -> np.ndarray:
    """Resolve ``query`` indices against a :func:`flat_slot_map` table."""
    idx, pos = table
    query = np.asarray(query, dtype=np.int64)
    p = np.searchsorted(idx, query)
    ok = (p < idx.size) & (idx[np.minimum(p, max(idx.size - 1, 0))] == query) \
        if idx.size else np.zeros(query.shape, bool)
    assert bool(np.all(ok)), \
        f"indices never delivered to this rank: {query[~ok][:8]}"
    return pos[p]


def _group_sorted(keys: np.ndarray, vals: np.ndarray) -> Dict[int, np.ndarray]:
    """{key: sorted unique vals with that key} for parallel arrays."""
    out: Dict[int, np.ndarray] = {}
    if keys.size == 0:
        return out
    order = np.argsort(keys, kind="stable")
    keys, vals = keys[order], vals[order]
    bounds = np.flatnonzero(np.diff(keys)) + 1
    for chunk_keys, chunk_vals in zip(np.split(keys, bounds), np.split(vals, bounds)):
        out[int(chunk_keys[0])] = np.unique(chunk_vals)
    return out


# ---------------------------------------------------------------------------
# Structure extraction: which (row-owner, col) pairs need communication
# ---------------------------------------------------------------------------

def _offproc_pairs(indptr: np.ndarray, indices: np.ndarray,
                   row_part: RowPartition, col_part: RowPartition
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(row_owner t, col_owner r, col j) for every off-process nonzero, deduped.

    The communication structure of an SpMV is a function of TWO
    partitions: ``row_part`` says which rank computes row i (and hence
    *needs* x_j for every nonzero A_ij), ``col_part`` says which rank
    owns x_j.  For the paper's square systems the two coincide; a
    rectangular operator (AMG P / R) separates them.
    """
    n_rows = len(indptr) - 1
    rows = np.repeat(np.arange(n_rows), np.diff(indptr))
    cols = indices
    t = row_part.owner[rows]
    r = col_part.owner[cols]
    off = t != r
    t, r, j = t[off], r[off], cols[off]
    # dedupe (t, r, j); j indexes the x/column space of size col_part.n_rows
    key = (t.astype(np.int64) * row_part.n_procs + r) * col_part.n_rows + j
    _, uniq = np.unique(key, return_index=True)
    return t[uniq], r[uniq], j[uniq]


# ---------------------------------------------------------------------------
# Standard plan (Sec. 2.1, Algorithm 1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StandardPlan:
    """P(r) and D(r, t) realised as message lists per rank.

    ``partition`` is the ROW partition (who computes/owns output rows);
    ``col_partition`` the COLUMN partition (who owns x entries — the
    values the messages carry).  ``None`` means square single-partition
    (col == row), the paper's setting.
    """

    topology: Topology
    partition: RowPartition
    sends: List[List[Message]]  # sends[r] = messages rank r sends
    recvs: List[List[Message]]  # recvs[t] = messages rank t receives
    col_partition: Optional[RowPartition] = None

    @property
    def col_part(self) -> RowPartition:
        return self.col_partition if self.col_partition is not None \
            else self.partition

    def P(self, r: int) -> List[int]:
        return [m.dst for m in self.sends[r]]

    def D(self, r: int, t: int) -> np.ndarray:
        for m in self.sends[r]:
            if m.dst == t:
                return m.idx
        return np.empty(0, dtype=np.int64)

    def recv_slot_map(self, rank: int, pad: int) -> Tuple[np.ndarray, np.ndarray]:
        """Slot map into rank's flat recv buffer ([n_procs, pad] by src)."""
        msgs = self.recvs[rank]
        return flat_slot_map(msgs, [m.src for m in msgs], pad)


def build_standard_plan(indptr: np.ndarray, indices: np.ndarray,
                        part: RowPartition, topo: Topology,
                        col_part: Optional[RowPartition] = None,
                        pairs: Optional[Tuple[np.ndarray, np.ndarray,
                                              np.ndarray]] = None) -> StandardPlan:
    """``part`` is the row partition; ``col_part`` the column/x partition
    (defaults to ``part`` — the square single-partition case).

    ``pairs`` optionally supplies precomputed deduped off-process triples
    ``(t, r, j)`` (row owner, col owner, col) in place of extracting them
    from the matrix structure — the multi-step strategy splits one
    extraction between two sub-plans.  The default path is unchanged.
    """
    cpart = part if col_part is None else col_part
    t, r, j = pairs if pairs is not None else \
        _offproc_pairs(indptr, indices, part, cpart)
    sends: List[List[Message]] = [[] for _ in range(topo.n_procs)]
    recvs: List[List[Message]] = [[] for _ in range(topo.n_procs)]
    # group by sender r then receiver t
    for src in np.unique(r):
        mask = r == src
        for dst, idx in sorted(_group_sorted(t[mask], j[mask]).items()):
            msg = Message(src=int(src), dst=int(dst), idx=idx)
            sends[int(src)].append(msg)
            recvs[int(dst)].append(msg)
    return StandardPlan(topology=topo, partition=part, sends=sends,
                        recvs=recvs, col_partition=col_part)


# ---------------------------------------------------------------------------
# Node-aware plan (Sec. 4)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class NAPPlan:
    """Node-aware plan.  ``partition`` is the ROW partition,
    ``col_partition`` the COLUMN/x partition (``None`` = square,
    col == row) — see :class:`StandardPlan`."""

    topology: Topology
    partition: RowPartition
    # node-level sets
    node_dests: List[List[int]]                     # N(n)
    node_idx: Dict[Tuple[int, int], np.ndarray]     # E(n, m)
    # per-rank slot assignment (node ids, possibly repeated for chunk splits)
    T: List[List[int]]                              # T((p, n)) — dest nodes of rank
    U: List[List[int]]                              # U((p, n)) — src nodes of rank
    # realised message lists
    inter_sends: List[List[Message]]                # G/I — crosses the network
    inter_recvs: List[List[Message]]
    local_init_sends: List[List[Message]]           # L/J (on_node → off_node)
    local_init_recvs: List[List[Message]]
    local_final_sends: List[List[Message]]          # L/J (off_node → on_node)
    local_final_recvs: List[List[Message]]
    local_full_sends: List[List[Message]]           # L/J (on_node → on_node)
    local_full_recvs: List[List[Message]]
    col_partition: Optional[RowPartition] = None

    @property
    def col_part(self) -> RowPartition:
        return self.col_partition if self.col_partition is not None \
            else self.partition

    def N(self, n: int) -> List[int]:
        return self.node_dests[n]

    def E(self, n: int, m: int) -> np.ndarray:
        return self.node_idx.get((n, m), np.empty(0, dtype=np.int64))

    def G(self, rank: int) -> List[int]:
        return [m.dst for m in self.inter_sends[rank]]

    def I(self, rank: int, dst: int) -> np.ndarray:
        out = [m.idx for m in self.inter_sends[rank] if m.dst == dst]
        return np.unique(np.concatenate(out)) if out else np.empty(0, dtype=np.int64)

    def recv_slot_map(self, rank: int, phase: str,
                      pad: int) -> Tuple[np.ndarray, np.ndarray]:
        """Slot map into rank's flat padded recv buffer for one phase.

        The SPMD executor lays out received values as ``[n_slots, pad]`` per
        phase — slot = sender's local id for the intra-node phases ("full",
        "init", "final") and sender's *node* id for "inter" (the buffer the
        aggregated inter-node all-to-all produces).  This is the block-layout
        contract the fused BSR compile step builds its gather maps against.
        """
        topo = self.topology
        msgs = {"full": self.local_full_recvs, "init": self.local_init_recvs,
                "final": self.local_final_recvs, "inter": self.inter_recvs}[phase][rank]
        slot_of = topo.node_of if phase == "inter" else topo.local_of
        return flat_slot_map(msgs, [slot_of(m.src) for m in msgs], pad)


def _distribute_slots(items: Sequence[Tuple[int, int]], ppn: int) -> List[List[Tuple[int, int]]]:
    """Distribute (node, weight) items over ppn slots, balancing count & volume.

    Returns per-slot list of (node, n_chunks_for_this_pair-index) placeholders:
    concretely, a list per slot of (node, chunk_id) where chunk_id enumerates
    the contiguous chunk of E to use.  When there are fewer items than slots,
    heavy items are split across several slots so all processes communicate
    (Sec. 4.1); when more, items are dealt round-robin in descending-weight
    order (largest → slot 0, per the text).
    """
    slots: List[List[Tuple[int, int]]] = [[] for _ in range(ppn)]
    if not items:
        return slots
    ordered = sorted(items, key=lambda kv: (-kv[1], kv[0]))
    if len(ordered) >= ppn:
        for i, (node, _w) in enumerate(ordered):
            slots[i % ppn].append((node, 0))
        return slots
    # fewer destinations than processes: split the heavy ones.
    n_items = len(ordered)
    extra = ppn - n_items
    weights = np.array([w for _, w in ordered], dtype=np.float64)
    shares = np.ones(n_items, dtype=np.int64)
    if weights.sum() > 0:
        frac = weights / weights.sum() * extra
        add = np.floor(frac).astype(np.int64)
        rem = extra - add.sum()
        order = np.argsort(-(frac - add), kind="stable")
        add[order[:rem]] += 1
        shares += add
    else:
        shares[:extra] += 1
    slot = 0
    for (node, _w), k in zip(ordered, shares):
        for c in range(int(k)):
            slots[slot].append((node, c))
            slot += 1
    return slots


def _chunk(arr: np.ndarray, k: int, c: int) -> np.ndarray:
    """c-th of k near-equal contiguous chunks of arr."""
    bounds = np.linspace(0, arr.size, k + 1).astype(np.int64)
    return arr[bounds[c] : bounds[c + 1]]


def build_nap_plan(indptr: np.ndarray, indices: np.ndarray, part: RowPartition,
                   topo: Topology, pairing: str = "balanced",
                   col_part: Optional[RowPartition] = None,
                   pairs: Optional[Tuple[np.ndarray, np.ndarray,
                                         np.ndarray]] = None) -> NAPPlan:
    """Build the full node-aware plan.

    ``part`` is the row partition, ``col_part`` the column/x partition
    (defaults to ``part``: the paper's square single-partition case).

    pairing:
      * ``"balanced"`` — the paper's rule: send slots in descending-data order
        from p=0; receive slots in descending-data order from p=ppn-1.
      * ``"aligned"``  — TPU adaptation: receiver local id q equals sender
        local id p, so the inter-node phase is an all-to-all over the node
        mesh axis (documented in DESIGN.md §2).

    ``pairs`` optionally supplies precomputed deduped off-process triples
    ``(t, r, j)`` instead of extracting them from the structure — the
    multi-step strategy routes only its low-duplication share elsewhere
    and hands the rest here.  The default path is unchanged.
    """
    if pairing not in ("balanced", "aligned"):
        raise ValueError(pairing)
    cpart = part if col_part is None else col_part
    ppn, n_nodes, n_procs = topo.ppn, topo.n_nodes, topo.n_procs
    t, r, j = pairs if pairs is not None else \
        _offproc_pairs(indptr, indices, part, cpart)
    tn = topo.node_of_array(t)  # receiver node m
    rn = topo.node_of_array(r)  # sender node n
    off_node = tn != rn

    # ---- N(n), E(n, m) ----------------------------------------------------
    node_idx: Dict[Tuple[int, int], np.ndarray] = {}
    node_dests: List[List[int]] = [[] for _ in range(n_nodes)]
    on_t, on_r, on_j = t[off_node], r[off_node], j[off_node]
    on_tn, on_rn = tn[off_node], rn[off_node]
    for n in np.unique(on_rn):
        mask = on_rn == n
        grouped = _group_sorted(on_tn[mask], on_j[mask])
        node_dests[int(n)] = sorted(grouped)
        for m, idx in grouped.items():
            node_idx[(int(n), int(m))] = idx

    # ---- T/U slot assignment ----------------------------------------------
    send_slots: List[List[List[Tuple[int, int]]]] = []  # [n][p] -> [(m, chunk)]
    recv_slots: List[List[List[Tuple[int, int]]]] = []  # [m][q] -> [(n, chunk)]
    for n in range(n_nodes):
        items = [(m, int(node_idx[(n, m)].size)) for m in node_dests[n]]
        send_slots.append(_distribute_slots(items, ppn))
    node_srcs: List[List[int]] = [[] for _ in range(n_nodes)]
    for (n, m) in node_idx:
        node_srcs[m].append(n)
    for m in range(n_nodes):
        items = [(n, int(node_idx[(n, m)].size)) for n in sorted(node_srcs[m])]
        dist = _distribute_slots(items, ppn)
        if pairing == "balanced":
            dist = dist[::-1]  # largest fills from p = ppn-1 downward (text rule)
        recv_slots.append(dist)

    # chunk counts per (n, m) pair must agree on both sides
    send_count: Dict[Tuple[int, int], int] = {}
    recv_count: Dict[Tuple[int, int], int] = {}
    for n in range(n_nodes):
        for p in range(ppn):
            for (m, _c) in send_slots[n][p]:
                send_count[(n, m)] = send_count.get((n, m), 0) + 1
    for m in range(n_nodes):
        for q in range(ppn):
            for (n, _c) in recv_slots[m][q]:
                recv_count[(n, m)] = recv_count.get((n, m), 0) + 1

    # enumerate concrete chunk endpoints
    send_eps: Dict[Tuple[int, int], List[int]] = {k: [] for k in node_idx}  # ranks
    recv_eps: Dict[Tuple[int, int], List[int]] = {k: [] for k in node_idx}
    T: List[List[int]] = [[] for _ in range(n_procs)]
    U: List[List[int]] = [[] for _ in range(n_procs)]
    for n in range(n_nodes):
        for p in range(ppn):
            for (m, _c) in send_slots[n][p]:
                send_eps[(n, m)].append(topo.rank(p, n))
                T[topo.rank(p, n)].append(m)
    if pairing == "aligned":
        for (n, m), senders in send_eps.items():
            for s in senders:
                q = topo.local_of(s)
                recv_eps[(n, m)].append(topo.rank(q, m))
                U[topo.rank(q, m)].append(n)
    else:
        for m in range(n_nodes):
            for q in range(ppn):
                for (n, _c) in recv_slots[m][q]:
                    recv_eps[(n, m)].append(topo.rank(q, m))
                    U[topo.rank(q, m)].append(n)

    # ---- realise inter-node messages (G / I) -------------------------------
    # (vectorized: plan setup runs "as the matrix is formed" — its cost is
    # part of the paper's crossover story, so it must scale to 10^7+ nnz)
    inter_sends: List[List[Message]] = [[] for _ in range(n_procs)]
    inter_recvs: List[List[Message]] = [[] for _ in range(n_procs)]
    # (m, j) -> rank holding j after the inter phase, as parallel arrays
    rh_keys: List[np.ndarray] = []
    rh_home: List[np.ndarray] = []
    for (n, m), idx in node_idx.items():
        senders = send_eps[(n, m)]
        receivers = recv_eps[(n, m)]
        # k = max(...) with cycling keeps *both* sides as busy as they can be
        # (Sec. 4.1: all processes local to a node send and receive a similar
        # number and size of messages).  Empty chunks are skipped.
        k = max(len(senders), len(receivers), 1)
        for c in range(k):
            chunk = _chunk(idx, k, c)
            if chunk.size == 0:
                continue
            src = senders[c % len(senders)] if senders else topo.rank(0, n)
            dst = receivers[c % len(receivers)] if receivers else topo.rank(0, m)
            msg = Message(src=src, dst=dst, idx=chunk)
            inter_sends[src].append(msg)
            inter_recvs[dst].append(msg)
            rh_keys.append(m * np.int64(cpart.n_rows) + chunk)
            rh_home.append(np.full(chunk.size, dst, dtype=np.int64))

    def _emit(per_pair: Dict[int, np.ndarray], sends, recvs) -> None:
        for key in sorted(per_pair):
            src, dst = divmod(int(key), n_procs)
            msg = Message(src=src, dst=dst, idx=per_pair[key])
            sends[src].append(msg)
            recvs[dst].append(msg)

    # ---- local init redistribution (on_node -> off_node), Eqs. 19/20 ------
    local_init_sends: List[List[Message]] = [[] for _ in range(n_procs)]
    local_init_recvs: List[List[Message]] = [[] for _ in range(n_procs)]
    init_src, init_dst, init_j = [], [], []
    for rank in range(n_procs):
        for msg in inter_sends[rank]:
            owners = cpart.owner[msg.idx]
            off = owners != rank
            if off.any():
                init_src.append(owners[off])
                init_dst.append(np.full(int(off.sum()), rank, dtype=np.int64))
                init_j.append(msg.idx[off])
    if init_src:
        keys = np.concatenate(init_src) * n_procs + np.concatenate(init_dst)
        _emit(_group_sorted(keys, np.concatenate(init_j)),
              local_init_sends, local_init_recvs)

    # ---- local final redistribution (off_node -> on_node), Eqs. 21/22 -----
    # join (receiver rank t, col j) pairs against the (m, j) -> home map
    local_final_sends: List[List[Message]] = [[] for _ in range(n_procs)]
    local_final_recvs: List[List[Message]] = [[] for _ in range(n_procs)]
    if rh_keys:
        rhk = np.concatenate(rh_keys)
        rhh = np.concatenate(rh_home)
        order = np.argsort(rhk, kind="stable")
        rhk, rhh = rhk[order], rhh[order]
        pair_keys = on_tn.astype(np.int64) * cpart.n_rows + on_j
        pos = np.searchsorted(rhk, pair_keys)
        home = rhh[pos]                       # every needed (m, j) has a home
        mask = on_t != home
        if mask.any():
            keys = home[mask] * n_procs + on_t[mask]
            _emit(_group_sorted(keys, on_j[mask]),
                  local_final_sends, local_final_recvs)

    # ---- fully local (on_node -> on_node), Eqs. 23/24 ----------------------
    local_full_sends: List[List[Message]] = [[] for _ in range(n_procs)]
    local_full_recvs: List[List[Message]] = [[] for _ in range(n_procs)]
    same_node = ~off_node
    sn_t, sn_r, sn_j = t[same_node], r[same_node], j[same_node]
    if sn_t.size:
        keys = sn_r.astype(np.int64) * n_procs + sn_t
        _emit(_group_sorted(keys, sn_j), local_full_sends, local_full_recvs)

    return NAPPlan(
        topology=topo, partition=part, node_dests=node_dests, node_idx=node_idx,
        T=T, U=U,
        inter_sends=inter_sends, inter_recvs=inter_recvs,
        local_init_sends=local_init_sends, local_init_recvs=local_init_recvs,
        local_final_sends=local_final_sends, local_final_recvs=local_final_recvs,
        local_full_sends=local_full_sends, local_full_recvs=local_full_recvs,
        col_partition=col_part,
    )


# ---------------------------------------------------------------------------
# Message statistics (drives Figs. 8 & 9 and the cost model)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PhaseStats:
    """max-over-ranks message count / bytes sent by a single process."""

    max_msgs: int
    max_bytes: int
    total_msgs: int
    total_bytes: int

    @staticmethod
    def of(msg_lists: List[List[Message]], bytes_per_val: int = 8) -> "PhaseStats":
        counts = [len(msgs) for msgs in msg_lists]
        sizes = [sum(m.size for m in msgs) * bytes_per_val for msgs in msg_lists]
        return PhaseStats(
            max_msgs=max(counts, default=0), max_bytes=max(sizes, default=0),
            total_msgs=sum(counts), total_bytes=sum(sizes),
        )


def standard_stats(plan: StandardPlan, bytes_per_val: int = 8) -> Dict[str, PhaseStats]:
    topo = plan.topology
    inter = [[m for m in msgs if not topo.same_node(m.src, m.dst)] for msgs in plan.sends]
    intra = [[m for m in msgs if topo.same_node(m.src, m.dst)] for msgs in plan.sends]
    return {
        "inter": PhaseStats.of(inter, bytes_per_val),
        "intra": PhaseStats.of(intra, bytes_per_val),
    }


def nap_stats(plan: NAPPlan, bytes_per_val: int = 8) -> Dict[str, PhaseStats]:
    intra = [a + b + c for a, b, c in zip(
        plan.local_init_sends, plan.local_full_sends, plan.local_final_sends)]
    return {
        "inter": PhaseStats.of(plan.inter_sends, bytes_per_val),
        "intra": PhaseStats.of(intra, bytes_per_val),
        "intra_init": PhaseStats.of(plan.local_init_sends, bytes_per_val),
        "intra_full": PhaseStats.of(plan.local_full_sends, bytes_per_val),
        "intra_final": PhaseStats.of(plan.local_final_sends, bytes_per_val),
    }
