"""Silent-data-corruption defense for the distributed SpMV stack (ABFT).

The paper's whole point — fewer, larger inter-node messages — also makes
every message a bigger blast radius when the fabric flips a bit, delivers
a stale buffer, or drops a payload; the three-step NAP exchange amplifies
this by *relaying* values through intermediate ranks.  This module is the
host side of an end-to-end integrity layer with two complementary checks:

* **Wire checksums** — a position-weighted Fletcher-style fold over the
  raw f32/f64 bit patterns of every message payload, computed by the
  SENDER before the fault-injection boundary and re-computed by the
  RECEIVER after delivery (the checksum words travel through the same
  collective, one u32 per message).  Any transport corruption — bitflip,
  zeroed/dropped payload, stale (shifted) buffer, duplicated message —
  mismatches, and the failure is attributed to (exchange phase, message
  slot, receiving device).  Checksums see every bit but cannot see
  *compute* corruption: they verify what arrived equals what was sent.
* **ABFT result verification** — each rank carries the column-checksum
  vector ``c_p = 1^T A_p`` over the packed x domain (and its transpose
  twin, the row-sum vector ``A_p 1``), precomputed at plan-compile time,
  so ``sum(y_p)`` is checked against ``c_p · x_packed`` with a
  dtype-aware tolerance.  ABFT sees corruption *inside* the local
  compute (a flipped accumulator, bad kernel output) that the wire
  checksums can't — the two checks are disjoint by construction, since
  the ABFT dot is evaluated over the SAME received buffers the compute
  consumed.

Phase attribution maps the exchange phases onto the paper's data
classes: ``full`` carries on_node data, ``init``/``inter``/``final``
relay off_node data, and a compute/ABFT failure is on_proc.  The
``pair`` phase (standard Algorithm 1) attributes per message slot from
the sender/receiver ranks.

Fault injection is DETERMINISTIC and replayable: a scripted
:class:`MessageFault` is encoded into a small int32 spec array passed to
the jitted program as an ARGUMENT (zero retraces; the ``integrity="off"``
program takes no such argument and is bit-for-bit the pre-integrity
program), applied as a pure transform on the post-gather message buffer
— the pack boundary — and consumed exactly once.  ``integrity="recover"``
retries the apply from the retained packed refs with the fault consumed,
which reproduces the fault-free result bit-for-bit (the retry runs the
identical program on identical inputs).

Limits, stated honestly: a ``zero``/``drop`` fault on an all-zero
payload and a ``stale`` roll of a constant payload are undetectable
(the corrupted payload is bit-identical to the clean one); a mantissa
low-bit compute flip hides below the ABFT tolerance.  ``bitflip`` wire
faults are always detected.  In a static-SPMD program a dropped message
cannot simply *not arrive*; ``drop`` models it as a zeroed payload,
which is exactly what the receiver's buffer holds when a real drop is
papered over by the runtime.

This module is numpy-only (the simulate backend stays importable on a
jax-free installation); the in-graph twins of the checksum/fault
transforms live in :mod:`repro.core.spmv_jax`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "FAULT_KINDS", "KIND_CODE", "MessageFault", "Mismatch", "IntegrityError",
    "checksum_np", "corrupt_payload_np", "message_phases", "phase_index",
    "build_fault_spec", "scope_for", "verify_wire", "verify_abft",
    "IntegrityState", "SimWire", "MULTISTEP_MESSAGE_PHASES",
]

_MASK32 = 0xFFFFFFFF

#: Scripted message-fault kinds (plus the compute-side "bitflip" applied
#: through the ``"compute"`` pseudo-phase).  Codes are the spec-array
#: encoding; 0 means "no fault".
FAULT_KINDS = ("bitflip", "zero", "stale", "drop", "duplicate")
KIND_CODE: Dict[str, int] = {k: i + 1 for i, k in enumerate(FAULT_KINDS)}

#: Exchange phases that carry messages, per plan family, in the canonical
#: order the instrumented programs stack their checksum rows.
NAP_MESSAGE_PHASES: Tuple[str, ...] = ("full", "init", "inter", "final")
STD_MESSAGE_PHASES: Tuple[str, ...] = ("pair",)
#: Multi-step NAP = the four NAP phases plus the "direct" exchange that
#: carries the low-duplication columns owner -> requester in one hop.
MULTISTEP_MESSAGE_PHASES: Tuple[str, ...] = NAP_MESSAGE_PHASES + ("direct",)
COMPUTE_PHASE = "compute"


def message_phases(method: str) -> Tuple[str, ...]:
    if method == "nap":
        return NAP_MESSAGE_PHASES
    if method == "multistep":
        return MULTISTEP_MESSAGE_PHASES
    return STD_MESSAGE_PHASES


def phase_index(method: str) -> Dict[str, int]:
    """Phase name -> row index in the fault-spec array (compute last)."""
    phases = message_phases(method) + (COMPUTE_PHASE,)
    return {p: i for i, p in enumerate(phases)}


# ---------------------------------------------------------------------------
# Checksums (host twin of the in-graph fold)
# ---------------------------------------------------------------------------

def checksum_np(x: np.ndarray) -> int:
    """Position-weighted Fletcher-style fold over the raw bit pattern.

    ``s1`` is the wrapping u32 sum of the 32-bit words, ``s2`` the
    wrapping sum weighted by 1-based word position; the digest is
    ``s1 ^ rotl32(s2, 7)``.  The position weighting is what catches a
    ``stale`` (shifted) payload — a pure XOR fold is order-invariant and
    would pass any permutation of the same words.  Matches the in-graph
    fold in :mod:`repro.core.spmv_jax` bit-for-bit on float32 input.
    """
    b = np.ascontiguousarray(x).view(np.uint8).reshape(-1)
    pad = (-b.size) % 4
    if pad:
        b = np.concatenate([b, np.zeros(pad, np.uint8)])
    w = b.view("<u4").astype(np.uint64)
    idx = np.arange(1, w.size + 1, dtype=np.uint64)
    s1 = int(w.sum()) & _MASK32
    s2 = int((w * (idx & _MASK32)).sum()) & _MASK32
    rot = ((s2 << 7) & _MASK32) | (s2 >> 25)
    return (s1 ^ rot) & _MASK32


def corrupt_payload_np(values: np.ndarray, kind: str, element: int = 0,
                       bit: int = 30,
                       other: Optional[np.ndarray] = None) -> np.ndarray:
    """Numpy twin of the in-graph fault transform (simulate-backend wire).

    ``other`` is the candidate payload for ``duplicate`` (another message
    from the same sender); ``duplicate`` degrades to zeros when the
    sender has no other message to confuse with.
    """
    v = np.array(values, copy=True)
    if kind in ("zero", "drop"):
        return np.zeros_like(v)
    if kind == "stale":
        return np.roll(v, 1)
    if kind == "duplicate":
        if other is None:
            return np.zeros_like(v)
        out = np.zeros_like(v).reshape(-1)
        src = np.asarray(other).reshape(-1)
        n = min(out.size, src.size)
        out[:n] = src[:n]
        return out.reshape(v.shape)
    if kind == "bitflip":
        flat = v.reshape(-1)
        e = int(element) % max(flat.size, 1)
        if flat.dtype == np.float64:
            word = flat[e: e + 1].view(np.uint64)
            word ^= np.uint64(1) << np.uint64(int(bit) % 64)
        else:
            word = flat[e: e + 1].view(np.uint32)
            word ^= np.uint32(1) << np.uint32(int(bit) % 32)
        return v
    raise ValueError(f"unknown fault kind {kind!r}")


# ---------------------------------------------------------------------------
# Scripted faults
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MessageFault:
    """One deterministic fault at the pack boundary of one exchange phase.

    ``(node, proc)`` are the SENDER device coordinates; ``slot`` the
    destination message slot within the phase — the destination's local
    rank for the intra-node phases (``full``/``init``/``final``), the
    destination NODE for ``inter``, the destination flat rank for the
    standard ``pair`` phase, and ignored for ``compute`` (which perturbs
    the sender's own local result; only ``kind="bitflip"`` is
    meaningful there, targeting ``element``/``bit`` of the flattened
    output — the corruption ABFT exists to catch).
    """

    phase: str
    kind: str = "bitflip"
    node: int = 0
    proc: int = 0
    slot: int = 0
    element: int = 0
    bit: int = 30
    direction: str = "forward"   # "forward" | "transpose" | "any"

    def __post_init__(self) -> None:
        known = MULTISTEP_MESSAGE_PHASES + STD_MESSAGE_PHASES \
            + (COMPUTE_PHASE,)
        if self.phase not in known:
            raise ValueError(f"unknown phase {self.phase!r}; one of {known}")
        if self.phase != COMPUTE_PHASE and self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.phase == COMPUTE_PHASE and self.kind != "bitflip":
            raise ValueError("compute faults model a corrupted local "
                             "result: kind must be 'bitflip'")
        if self.direction not in ("forward", "transpose", "any"):
            raise ValueError(f"direction must be forward|transpose|any, "
                             f"got {self.direction!r}")


N_SPEC_FIELDS = 4   # (kind_code, slot, element, bit)


def build_fault_spec(topo, faults: Sequence[MessageFault],
                     method: str) -> np.ndarray:
    """Encode scripted faults into the [n_nodes, ppn, n_phases, 4] int32
    spec array the instrumented shard program consumes as a jit ARGUMENT
    (constant shape/dtype: arming or clearing faults never retraces).
    At most one fault per (sender device, phase) per apply."""
    idx = phase_index(method)
    spec = np.zeros((topo.n_nodes, topo.ppn, len(idx), N_SPEC_FIELDS),
                    dtype=np.int32)
    for f in faults:
        if f.phase not in idx:
            raise ValueError(
                f"phase {f.phase!r} does not exist on method {method!r}")
        if not (0 <= f.node < topo.n_nodes and 0 <= f.proc < topo.ppn):
            raise ValueError(f"sender ({f.node}, {f.proc}) outside the "
                             f"({topo.n_nodes}, {topo.ppn}) topology")
        row = spec[f.node, f.proc, idx[f.phase]]
        if row[0] != 0:
            raise ValueError(
                f"two faults scripted for device ({f.node}, {f.proc}) "
                f"phase {f.phase!r} in one apply; queue them on separate "
                f"applies")
        code = KIND_CODE["bitflip"] if f.phase == COMPUTE_PHASE \
            else KIND_CODE[f.kind]
        row[:] = (code, f.slot, f.element, f.bit)
    return spec


# ---------------------------------------------------------------------------
# Verification (host side, over the instrumented program's aux outputs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Mismatch:
    """One detected integrity failure, attributed."""

    check: str          # "wire" | "abft"
    phase: str          # exchange phase ("compute" for ABFT)
    scope: str          # "on_proc" | "on_node" | "off_node"
    node: int           # receiving / computing device coordinates
    proc: int
    slot: int           # message index within the phase (column for abft)
    direction: str = "forward"

    def __str__(self) -> str:
        return (f"{self.check} mismatch: phase={self.phase} ({self.scope}) "
                f"device=({self.node},{self.proc}) slot={self.slot} "
                f"direction={self.direction}")


class IntegrityError(RuntimeError):
    """A checksum / ABFT / stored-digest verification failed.

    ``mismatches`` carries the attributed failures (empty for
    checkpoint-digest errors, which name the corrupt shard in the
    message instead)."""

    def __init__(self, message: str,
                 mismatches: Sequence[Mismatch] = ()) -> None:
        super().__init__(message)
        self.mismatches: List[Mismatch] = list(mismatches)


#: Data-class attribution of the NAP phases (Eqs. 4-7 column classes):
#: the full-local phase moves on_node data; init/inter/final relay
#: off_node data; compute/ABFT failures are the rank's own (on_proc).
_NAP_PHASE_SCOPE = {"full": "on_node", "init": "off_node",
                    "inter": "off_node", "final": "off_node"}


def scope_for(phase: str, node: int, proc: int, slot: int, ppn: int) -> str:
    if phase == COMPUTE_PHASE:
        return "on_proc"
    if phase in _NAP_PHASE_SCOPE:
        return _NAP_PHASE_SCOPE[phase]
    # standard "pair": the slot is the sender's flat rank.
    me = node * ppn + proc
    if slot == me:
        return "on_proc"
    return "on_node" if slot // ppn == node else "off_node"


def verify_wire(chk: np.ndarray, phases: Sequence[str], ppn: int,
                direction: str) -> List[Mismatch]:
    """Compare sender-vs-receiver checksums.

    ``chk`` is the instrumented program's aux output
    ``[n_nodes, ppn, n_msg_phases, 2, max_slots]`` uint32 — row 0 the
    sender checksums as delivered through the collective, row 1 the
    receiver's recomputation.  Padded slots are zero on both rows.
    """
    chk = np.asarray(chk)
    bad = np.argwhere(chk[..., 0, :] != chk[..., 1, :])
    out = []
    for ni, pj, ph, slot in bad:
        phase = phases[int(ph)]
        out.append(Mismatch(check="wire", phase=phase,
                            scope=scope_for(phase, int(ni), int(pj),
                                            int(slot), ppn),
                            node=int(ni), proc=int(pj), slot=int(slot),
                            direction=direction))
    return out


def abft_tolerance(scale: np.ndarray, y: np.ndarray, d: np.ndarray,
                   n_terms: int) -> np.ndarray:
    """Dtype-aware ABFT tolerance: f32 rounding of two independently
    ordered ~n_terms-term sums, scaled by the |A||x| mass."""
    eps = float(np.finfo(np.float32).eps)
    return (64.0 * eps * np.sqrt(max(float(n_terms), 2.0))
            * (np.abs(scale) + np.abs(y) + np.abs(d)) + 1e-30)


def verify_abft(abft: np.ndarray, n_terms: int,
                direction: str) -> List[Mismatch]:
    """Check ``sum(y_p)`` against ``c_p · x_packed`` per device and RHS.

    ``abft`` is the aux output ``[n_nodes, ppn, 3, nv]`` float32:
    (result sum, checksum dot, |A||x| tolerance scale).
    """
    abft = np.asarray(abft, dtype=np.float64)
    y, d, scale = abft[..., 0, :], abft[..., 1, :], abft[..., 2, :]
    tol = abft_tolerance(scale, y, d, n_terms)
    bad = np.argwhere(~(np.abs(y - d) <= tol))   # NaN-safe: NaN fails
    out = []
    for ni, pj, col in bad:
        out.append(Mismatch(check="abft", phase=COMPUTE_PHASE,
                            scope="on_proc", node=int(ni), proc=int(pj),
                            slot=int(col), direction=direction))
    return out


# ---------------------------------------------------------------------------
# Per-executor integrity state (mode, fault queue, counters, strikes)
# ---------------------------------------------------------------------------

class IntegrityState:
    """Mutable integrity bookkeeping an executor carries per operator.

    Holds the scripted-fault queue (consumed one apply at a time — a
    fault fires ONCE), the currently armed spec array the jitted
    program's ``fault_fetch`` reads, check/mismatch counters with scope
    attribution, and per-node strike counts feeding the quarantine
    policy (``k`` strikes against a sender node propose it to the
    elastic path).
    """

    def __init__(self, mode: str, topo, method: str,
                 strikes_to_quarantine: int = 3) -> None:
        if mode not in ("detect", "recover"):
            raise ValueError(f"integrity mode must be detect|recover, "
                             f"got {mode!r}")
        self.mode = mode
        self.topo = topo
        self.method = method
        self.phases = message_phases(method)
        self.k = int(strikes_to_quarantine)
        self.pending: List[MessageFault] = []
        self.counters: Dict[str, int] = {
            "applies": 0, "wire_checks": 0, "abft_checks": 0,
            "wire_mismatches": 0, "abft_mismatches": 0,
            "faults_injected": 0, "retries": 0, "recovered": 0,
        }
        self.by_scope: Dict[str, int] = {"on_proc": 0, "on_node": 0,
                                         "off_node": 0}
        self.strikes: Dict[str, int] = {}
        self.last_mismatches: List[Mismatch] = []
        self._zero_spec = build_fault_spec(topo, (), method)
        self._current_spec = self._zero_spec

    # -- fault queue -------------------------------------------------------
    def queue_fault(self, fault: MessageFault) -> None:
        self.pending.append(fault)

    def take_pending(self, direction: str) -> List[MessageFault]:
        """Remove and return every queued fault matching ``direction``
        (scripted faults fire once)."""
        take = [f for f in self.pending
                if f.direction in ("any", direction)]
        self.pending = [f for f in self.pending
                        if f.direction not in ("any", direction)]
        return take

    def arm(self, direction: str) -> List[MessageFault]:
        """Consume every queued fault matching ``direction`` into the
        armed spec (the recover retry and all later applies run clean
        unless re-queued)."""
        take = self.take_pending(direction)
        if take:
            self._current_spec = build_fault_spec(self.topo, take,
                                                  self.method)
            self.counters["faults_injected"] += len(take)
        else:
            self._current_spec = self._zero_spec
        return take

    def disarm(self) -> None:
        self._current_spec = self._zero_spec

    def fetch_spec(self) -> np.ndarray:
        """The armed spec array — the jitted program's per-call argument."""
        return self._current_spec

    # -- verification ------------------------------------------------------
    def verify(self, chk: np.ndarray, abft: np.ndarray, direction: str,
               n_terms: int) -> List[Mismatch]:
        chk = np.asarray(chk)
        mism = verify_wire(chk, self.phases, self.topo.ppn, direction)
        mism += verify_abft(abft, n_terms, direction)
        self.counters["wire_checks"] += int(np.prod(chk.shape[:-2])
                                            * chk.shape[-1])
        self.counters["abft_checks"] += 1
        self.record(mism)
        return mism

    def record(self, mismatches: Sequence[Mismatch]) -> None:
        self.last_mismatches = list(mismatches)
        for m in mismatches:
            self.counters[f"{m.check}_mismatches"] += 1
            self.by_scope[m.scope] = self.by_scope.get(m.scope, 0) + 1
            self.strikes[self._strike_node(m)] = \
                self.strikes.get(self._strike_node(m), 0) + 1

    def _strike_node(self, m: Mismatch) -> str:
        """Name of the node a mismatch implicates (the SENDER side for
        wire faults — the inter phase's slot is the sending node; the
        intra-node phases stay on the receiver's node)."""
        if m.check == "wire" and m.phase == "inter":
            return f"node{m.slot}"
        if m.check == "wire" and m.phase in ("pair", "direct"):
            return f"node{m.slot // self.topo.ppn}"
        return f"node{m.node}"

    def quarantine_candidates(self) -> List[str]:
        """Nodes with >= k strikes — hand these to the elastic path
        (``survivor_partition`` -> ``PlanCache.rebuild``)."""
        return sorted(n for n, s in self.strikes.items() if s >= self.k)

    # -- simulate-backend bridge -------------------------------------------
    def note_sim(self, wire: "SimWire") -> List[Mismatch]:
        self.counters["wire_checks"] += wire.checks
        self.counters["faults_injected"] += wire.injected
        self.record(wire.mismatches)
        return wire.mismatches

    def report(self) -> Dict[str, object]:
        return dict(self.counters, mode=self.mode, by_scope=dict(self.by_scope),
                    strikes=dict(self.strikes),
                    quarantine=self.quarantine_candidates(),
                    pending_faults=len(self.pending),
                    last_mismatches=[str(m) for m in self.last_mismatches])


# ---------------------------------------------------------------------------
# Simulate-backend wire (checksums + faults over the numpy mailboxes)
# ---------------------------------------------------------------------------

class SimWire:
    """Checksum/fault layer threaded through the numpy message simulators.

    :class:`repro.core.spmv._MailBox` calls ``send`` at post time (the
    sender checksums the CLEAN payload, then the scripted fault — if one
    targets this message — corrupts it) and ``recv`` at fetch time (the
    receiver recomputes and compares).  Mirrors the shardmap wire layer
    exactly, for the forward simulators; the float64 payloads are
    checksummed at full width.
    """

    def __init__(self, topo, faults: Sequence[MessageFault] = ()) -> None:
        self.topo = topo
        self.faults = list(faults)
        self.sent: Dict[Tuple[str, int, int], int] = {}
        self.last_payload: Dict[Tuple[str, int], np.ndarray] = {}
        self.checks = 0
        self.injected = 0
        self.mismatches: List[Mismatch] = []

    def _match(self, phase: str, src: int, dst: int) -> Optional[MessageFault]:
        for i, f in enumerate(self.faults):
            if f.phase != phase:
                continue
            if f.node * self.topo.ppn + f.proc != src:
                continue
            if phase == "inter":
                ok = self.topo.node_of(dst) == f.slot
            elif phase in ("pair", "direct"):
                ok = dst == f.slot
            else:
                ok = self.topo.local_of(dst) == f.slot
            if ok:
                return self.faults.pop(i)
        return None

    def send(self, phase: str, msg, values: np.ndarray) -> np.ndarray:
        self.sent[(phase, msg.src, msg.dst)] = checksum_np(values)
        fault = self._match(phase, msg.src, msg.dst)
        prev = self.last_payload.get((phase, msg.src))
        self.last_payload[(phase, msg.src)] = np.array(values, copy=True)
        if fault is None:
            return values
        self.injected += 1
        return corrupt_payload_np(values, fault.kind, fault.element,
                                  fault.bit, other=prev)

    def recv(self, phase: str, msg, values: np.ndarray) -> None:
        self.checks += 1
        if checksum_np(values) == self.sent[(phase, msg.src, msg.dst)]:
            return
        ppn = self.topo.ppn
        slot = (self.topo.node_of(msg.src) if phase == "inter"
                else msg.src if phase in ("pair", "direct")
                else self.topo.local_of(msg.src))
        self.mismatches.append(Mismatch(
            check="wire", phase=phase,
            scope=scope_for(phase, self.topo.node_of(msg.dst),
                            self.topo.local_of(msg.dst), slot, ppn),
            node=self.topo.node_of(msg.dst), proc=self.topo.local_of(msg.dst),
            slot=slot, direction="forward"))
