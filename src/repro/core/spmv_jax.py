"""SPMD (shard_map) executors for the distributed SpMV on a device mesh.

XLA programs are static-SPMD, so the comm plans of :mod:`comm_graph` are
*compiled* into padded gather maps + collectives, once, at plan-build time
(exactly where the paper's MPI implementation builds its send lists):

* ``standard``  — Algorithm 1: one padded all-to-all over the **flat** rank
  axis (every rank pair may exchange), i.e. topology-oblivious.
* ``allgather`` — the dense-JAX baseline: replicate v everywhere.
* ``nap``       — Algorithms 2+3 with ``pairing="aligned"``: intra-node
  all-to-all (proc axis) → **one aggregated inter-node all-to-all (node
  axis)** → intra-node all-to-all.  Only the middle step crosses pods.

Mesh convention: ``("node", "proc")`` with shape ``(n_nodes, ppn)`` — on a
real fleet "node" is the pod/DCI axis and "proc" the intra-pod ICI axis.

Padding note: all per-rank buffers are padded to the max over ranks; the
paper's T/U load balancing minimises exactly this padding.  Effective vs
padded bytes are both reported by :func:`padded_traffic`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.ops import segment_sum
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.comm_graph import Message, NAPPlan, StandardPlan, build_nap_plan, build_standard_plan
from repro.core.partition import RowPartition
from repro.core.spmv import LocalBlocks, split_all_blocks
from repro.core.topology import Topology
from repro.sparse.csr import CSR


def _pad_to(arrs: List[np.ndarray], pad: int, fill: float = 0) -> np.ndarray:
    out = np.full((len(arrs), pad), fill, dtype=arrs[0].dtype if arrs else np.int64)
    for i, a in enumerate(arrs):
        out[i, : a.size] = a
    return out


def _msg_by_dst(msgs: List[Message]) -> Dict[int, Message]:
    return {m.dst: m for m in msgs}


def _msg_by_src(msgs: List[Message]) -> Dict[int, Message]:
    return {m.src: m for m in msgs}


def _pos_in(idx: np.ndarray, j: int) -> int:
    p = int(np.searchsorted(idx, j))
    assert p < idx.size and idx[p] == j
    return p


@dataclasses.dataclass
class CompiledNAP:
    """Static arrays for the shard_map NAPSpMV, stacked over ranks."""

    topo: Topology
    part: RowPartition
    rows_pad: int
    pads: Dict[str, int]          # full/init/inter/final/bnode/boff/nnz pads
    arrays: Dict[str, np.ndarray]  # stacked [n_procs, ...] index/value arrays

    def device_arrays(self) -> Dict[str, np.ndarray]:
        """Reshape the leading rank dim to (n_nodes, ppn) for mesh sharding."""
        nn, ppn = self.topo.n_nodes, self.topo.ppn
        return {k: v.reshape((nn, ppn) + v.shape[1:]) for k, v in self.arrays.items()}


def compile_nap(a: CSR, part: RowPartition, topo: Topology,
                plan: Optional[NAPPlan] = None) -> CompiledNAP:
    if plan is None:
        plan = build_nap_plan(a.indptr, a.indices, part, topo, pairing="aligned")
    n_procs, ppn, n_nodes = topo.n_procs, topo.ppn, topo.n_nodes
    blocks = split_all_blocks(a, part, topo)
    local_index = part.local_index()
    rows_pad = max(1, int(part.counts().max()))

    def msg_pad(phase: List[List[Message]]) -> int:
        sizes = [m.size for msgs in phase for m in msgs]
        return max(1, max(sizes, default=1))

    full_pad = msg_pad(plan.local_full_sends)
    init_pad = msg_pad(plan.local_init_sends)
    inter_pad = msg_pad(plan.inter_sends)
    final_pad = msg_pad(plan.local_final_sends)
    bnode_pad = max(1, max(b.on_node_cols.size for b in blocks))
    boff_pad = max(1, max(b.off_node_cols.size for b in blocks))
    nnz_pads = {
        "on_proc": max(1, max(b.on_proc.nnz for b in blocks)),
        "on_node": max(1, max(b.on_node.nnz for b in blocks)),
        "off_node": max(1, max(b.off_node.nnz for b in blocks)),
    }

    A: Dict[str, List[np.ndarray]] = {k: [] for k in (
        "v_loc_init",  # not an index array; filled by caller
    )}
    arrays: Dict[str, np.ndarray] = {}

    def stack_int(name: str, per_rank: List[np.ndarray], shape: Tuple[int, ...]) -> None:
        out = np.zeros((n_procs,) + shape, dtype=np.int32)
        for r, arr in enumerate(per_rank):
            out[r] = arr
        arrays[name] = out

    full_send, init_send, final_send = [], [], []
    inter_gather, bnode_gather, boff_gather = [], [], []
    coo = {k: {"rows": [], "cols": [], "vals": []} for k in nnz_pads}

    for r in range(n_procs):
        p_r, n_r = topo.proc_node(r)
        blk = blocks[r]

        # -- full-local sends: [ppn, full_pad] source local-row positions ----
        fs = np.zeros((ppn, full_pad), dtype=np.int32)
        for m in plan.local_full_sends[r]:
            q = topo.local_of(m.dst)
            fs[q, : m.size] = local_index[m.idx]
        full_send.append(fs)

        # -- init sends -------------------------------------------------------
        isnd = np.zeros((ppn, init_pad), dtype=np.int32)
        for m in plan.local_init_sends[r]:
            q = topo.local_of(m.dst)
            isnd[q, : m.size] = local_index[m.idx]
        init_send.append(isnd)

        # -- inter gather: positions into concat(v_loc, init_recv_flat) -------
        init_recv_by_src = {topo.local_of(m.src): m for m in plan.local_init_recvs[r]}
        ig = np.zeros((n_nodes, inter_pad), dtype=np.int32)
        for m in plan.inter_sends[r]:
            dst_node = topo.node_of(m.dst)
            for k, j in enumerate(m.idx):
                if part.owner[j] == r:
                    ig[dst_node, k] = local_index[j]
                else:
                    src_p = topo.local_of(int(part.owner[j]))
                    msg = init_recv_by_src[src_p]
                    ig[dst_node, k] = rows_pad + src_p * init_pad + _pos_in(msg.idx, int(j))
        inter_gather.append(ig)

        # -- final sends: positions into inter_recv_flat ----------------------
        inter_recv_by_node = {topo.node_of(m.src): m for m in plan.inter_recvs[r]}
        fsnd = np.zeros((ppn, final_pad), dtype=np.int32)
        for m in plan.local_final_sends[r]:
            q = topo.local_of(m.dst)
            for k, j in enumerate(m.idx):
                src_n = None
                for nn, rmsg in inter_recv_by_node.items():
                    hit = np.searchsorted(rmsg.idx, j)
                    if hit < rmsg.idx.size and rmsg.idx[hit] == j:
                        src_n = nn
                        fsnd[q, k] = nn * inter_pad + hit
                        break
                assert src_n is not None, "final-send value must have arrived inter-node"
        final_send.append(fsnd)

        # -- on-node buffer gather: positions into full_recv_flat -------------
        full_recv_by_src = {topo.local_of(m.src): m for m in plan.local_full_recvs[r]}
        bg = np.zeros((bnode_pad,), dtype=np.int32)
        for slot, j in enumerate(blk.on_node_cols):
            src_p = topo.local_of(int(part.owner[j]))
            msg = full_recv_by_src[src_p]
            bg[slot] = src_p * full_pad + _pos_in(msg.idx, int(j))
        bnode_gather.append(bg)

        # -- off-node buffer gather: concat(inter_recv_flat, final_recv_flat) -
        final_recv_by_src = {topo.local_of(m.src): m for m in plan.local_final_recvs[r]}
        og = np.zeros((boff_pad,), dtype=np.int32)
        for slot, j in enumerate(blk.off_node_cols):
            placed = False
            for nn, rmsg in inter_recv_by_node.items():
                hit = np.searchsorted(rmsg.idx, j)
                if hit < rmsg.idx.size and rmsg.idx[hit] == j:
                    og[slot] = nn * inter_pad + hit
                    placed = True
                    break
            if not placed:
                for src_p, rmsg in final_recv_by_src.items():
                    hit = np.searchsorted(rmsg.idx, j)
                    if hit < rmsg.idx.size and rmsg.idx[hit] == j:
                        og[slot] = n_nodes * inter_pad + src_p * final_pad + hit
                        placed = True
                        break
            assert placed, f"rank {r} off-node col {j} unreachable"
        boff_gather.append(og)

        # -- COO blocks --------------------------------------------------------
        for key, block in (("on_proc", blk.on_proc), ("on_node", blk.on_node),
                           ("off_node", blk.off_node)):
            rows_i, cols_i, vals_i = block.to_coo()
            coo[key]["rows"].append(rows_i.astype(np.int32))
            coo[key]["cols"].append(cols_i.astype(np.int32))
            coo[key]["vals"].append(vals_i)

    stack_int("full_send", full_send, (ppn, full_pad))
    stack_int("init_send", init_send, (ppn, init_pad))
    stack_int("final_send", final_send, (ppn, final_pad))
    stack_int("inter_gather", inter_gather, (n_nodes, inter_pad))
    stack_int("bnode_gather", bnode_gather, (bnode_pad,))
    stack_int("boff_gather", boff_gather, (boff_pad,))
    for key in coo:
        arrays[f"{key}_rows"] = _pad_to(coo[key]["rows"], nnz_pads[key]).astype(np.int32)
        arrays[f"{key}_cols"] = _pad_to(coo[key]["cols"], nnz_pads[key]).astype(np.int32)
        arrays[f"{key}_vals"] = _pad_to(
            [v.astype(np.float32) for v in coo[key]["vals"]], nnz_pads[key], fill=0.0)

    pads = dict(full=full_pad, init=init_pad, inter=inter_pad, final=final_pad,
                bnode=bnode_pad, boff=boff_pad, **{f"nnz_{k}": v for k, v in nnz_pads.items()})
    return CompiledNAP(topo=topo, part=part, rows_pad=rows_pad, pads=pads, arrays=arrays)


def pack_vector(v: np.ndarray, part: RowPartition, topo: Topology, rows_pad: int) -> np.ndarray:
    """Global vector -> [n_nodes, ppn, rows_pad] padded shards."""
    out = np.zeros((topo.n_procs, rows_pad), dtype=np.float32)
    for r in range(topo.n_procs):
        rows = part.rows_of(r)
        out[r, : rows.size] = v[rows]
    return out.reshape(topo.n_nodes, topo.ppn, rows_pad)


def unpack_vector(w: np.ndarray, part: RowPartition, topo: Topology) -> np.ndarray:
    """[n_nodes, ppn, rows_pad] -> global vector."""
    w = np.asarray(w).reshape(topo.n_procs, -1)
    out = np.zeros(part.n_rows, dtype=w.dtype)
    for r in range(topo.n_procs):
        rows = part.rows_of(r)
        out[rows] = w[r, : rows.size]
    return out


def nap_spmv_shardmap(compiled: CompiledNAP, mesh: Mesh):
    """Build the jitted shard_map NAPSpMV: f(v_shards, **device_arrays) -> w."""
    topo = compiled.topo
    rows_pad = compiled.rows_pad

    def per_device(v_loc, full_send, init_send, final_send, inter_gather,
                   bnode_gather, boff_gather,
                   on_proc_rows, on_proc_cols, on_proc_vals,
                   on_node_rows, on_node_cols, on_node_vals,
                   off_node_rows, off_node_cols, off_node_vals):
        squeeze = lambda x: x.reshape(x.shape[2:])
        v_loc = squeeze(v_loc)
        (full_send, init_send, final_send, inter_gather, bnode_gather, boff_gather,
         on_proc_rows, on_proc_cols, on_proc_vals, on_node_rows, on_node_cols,
         on_node_vals, off_node_rows, off_node_cols, off_node_vals) = map(
            squeeze, (full_send, init_send, final_send, inter_gather, bnode_gather,
                      boff_gather, on_proc_rows, on_proc_cols, on_proc_vals,
                      on_node_rows, on_node_cols, on_node_vals, off_node_rows,
                      off_node_cols, off_node_vals))

        # Phase A+B (overlap in Alg. 3): intra-node exchanges over "proc".
        full_out = v_loc[full_send]                       # [ppn, full_pad]
        full_recv = jax.lax.all_to_all(full_out, "proc", 0, 0, tiled=True)
        init_out = v_loc[init_send]
        init_recv = jax.lax.all_to_all(init_out, "proc", 0, 0, tiled=True)

        # Phase C: ONE aggregated inter-node all-to-all over "node".
        staged = jnp.concatenate([v_loc, init_recv.reshape(-1)])
        inter_out = staged[inter_gather]                  # [n_nodes, inter_pad]
        inter_recv = jax.lax.all_to_all(inter_out, "node", 0, 0, tiled=True)

        # local_spmv(A_on_process, v) — no communication needed (Alg. 3).
        w = segment_sum(on_proc_vals * v_loc[on_proc_cols], on_proc_rows,
                        num_segments=rows_pad)
        # local_spmv(A_on_node, b_l->l)
        bnode = full_recv.reshape(-1)[bnode_gather]
        w = w + segment_sum(on_node_vals * bnode[on_node_cols], on_node_rows,
                            num_segments=rows_pad)

        # Phase D: intra-node scatter of received off-node data.
        inter_flat = inter_recv.reshape(-1)
        final_out = inter_flat[final_send]
        final_recv = jax.lax.all_to_all(final_out, "proc", 0, 0, tiled=True)
        boff = jnp.concatenate([inter_flat, final_recv.reshape(-1)])[boff_gather]
        # local_spmv(A_off_node, b_nl->l)
        w = w + segment_sum(off_node_vals * boff[off_node_cols], off_node_rows,
                            num_segments=rows_pad)
        return w.reshape(1, 1, rows_pad)

    dev = compiled.device_arrays()
    names = ["full_send", "init_send", "final_send", "inter_gather", "bnode_gather",
             "boff_gather", "on_proc_rows", "on_proc_cols", "on_proc_vals",
             "on_node_rows", "on_node_cols", "on_node_vals",
             "off_node_rows", "off_node_cols", "off_node_vals"]
    spec = P("node", "proc")
    smapped = shard_map(per_device, mesh=mesh,
                        in_specs=(spec,) * (1 + len(names)), out_specs=spec)

    @jax.jit
    def run(v_shards):
        return smapped(v_shards, *[dev[k] for k in names])

    return run


def standard_spmv_shardmap(a: CSR, part: RowPartition, topo: Topology, mesh: Mesh,
                           plan: Optional[StandardPlan] = None):
    """Algorithm 1 as a flat padded all-to-all over ("node","proc")."""
    if plan is None:
        plan = build_standard_plan(a.indptr, a.indices, part, topo)
    n_procs = topo.n_procs
    blocks = split_all_blocks(a, part, topo)
    local_index = part.local_index()
    rows_pad = max(1, int(part.counts().max()))
    pair_pad = max(1, max((m.size for msgs in plan.sends for m in msgs), default=1))

    send_idx = np.zeros((n_procs, n_procs, pair_pad), dtype=np.int32)
    for r in range(n_procs):
        for m in plan.sends[r]:
            send_idx[r, m.dst, : m.size] = local_index[m.idx]

    # off-process buffer = on_node ∪ off_node columns (standard has one buffer)
    buf_pad = max(1, max(b.on_node_cols.size + b.off_node_cols.size for b in blocks))
    buf_gather = np.zeros((n_procs, buf_pad), dtype=np.int32)
    nnz_pad = max(1, max(b.on_node.nnz + b.off_node.nnz + b.on_proc.nnz for b in blocks))
    rows_s, cols_s, vals_s = [], [], []
    for r in range(n_procs):
        blk = blocks[r]
        recv_by_src = _msg_by_src(plan.recvs[r])
        cols_all = np.concatenate([blk.on_node_cols, blk.off_node_cols])
        for slot, j in enumerate(cols_all):
            src = int(part.owner[j])
            buf_gather[r, slot] = src * pair_pad + _pos_in(recv_by_src[src].idx, int(j))
        rr0, cc0, vv0 = blk.on_proc.to_coo()
        rr1, cc1, vv1 = blk.on_node.to_coo()
        rr2, cc2, vv2 = blk.off_node.to_coo()
        # shift buffer columns: on_proc -> [0, rows_pad), buffer -> offset rows_pad
        rows_s.append(np.concatenate([rr0, rr1, rr2]).astype(np.int32))
        cols_s.append(np.concatenate([cc0, rows_pad + cc1,
                                      rows_pad + blk.on_node_cols.size + cc2]).astype(np.int32))
        vals_s.append(np.concatenate([vv0, vv1, vv2]).astype(np.float32))

    A_rows = _pad_to(rows_s, nnz_pad).astype(np.int32)
    A_cols = _pad_to(cols_s, nnz_pad).astype(np.int32)
    A_vals = _pad_to(vals_s, nnz_pad, fill=0.0)
    nn, ppn = topo.n_nodes, topo.ppn
    reshape = lambda x: x.reshape((nn, ppn) + x.shape[1:])
    dev = dict(send_idx=reshape(send_idx), buf_gather=reshape(buf_gather),
               A_rows=reshape(A_rows), A_cols=reshape(A_cols), A_vals=reshape(A_vals))

    def per_device(v_loc, send_idx, buf_gather, A_rows, A_cols, A_vals):
        squeeze = lambda x: x.reshape(x.shape[2:])
        v_loc, send_idx, buf_gather, A_rows, A_cols, A_vals = map(
            squeeze, (v_loc, send_idx, buf_gather, A_rows, A_cols, A_vals))
        out = v_loc[send_idx]                               # [n_procs, pair_pad]
        recv = jax.lax.all_to_all(out, ("node", "proc"), 0, 0, tiled=True)
        buf = jnp.concatenate([v_loc, recv.reshape(-1)[buf_gather]])
        w = segment_sum(A_vals * buf[A_cols], A_rows, num_segments=rows_pad)
        return w.reshape(1, 1, rows_pad)

    spec = P("node", "proc")
    smapped = shard_map(per_device, mesh=mesh, in_specs=(spec,) * 6, out_specs=spec)

    @jax.jit
    def run(v_shards):
        return smapped(v_shards, dev["send_idx"], dev["buf_gather"],
                       dev["A_rows"], dev["A_cols"], dev["A_vals"])

    return run, rows_pad


def padded_traffic(compiled: CompiledNAP) -> Dict[str, int]:
    """Padded (SPMD-actual) vs effective bytes per phase, float32 payloads."""
    topo, pads = compiled.topo, compiled.pads
    eff = {
        "inter": sum(m.size for r in range(topo.n_procs) for m in []),
    }
    n = topo.n_procs
    return {
        "inter_padded": n * topo.n_nodes * pads["inter"] * 4,
        "full_padded": n * topo.ppn * pads["full"] * 4,
        "init_padded": n * topo.ppn * pads["init"] * 4,
        "final_padded": n * topo.ppn * pads["final"] * 4,
    }
