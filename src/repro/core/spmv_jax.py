"""SPMD (shard_map) executors for the distributed SpMV on a device mesh.

Entry points: the canonical user-facing surface is
:func:`repro.api.operator` (one ``NapOperator`` over every backend); this
module holds the compiled-plan containers (:class:`CompiledNAP`,
:class:`CompiledStandard`) and the shard_map program builders the
``"shardmap"`` backend registers —

* :func:`nap_forward_shardmap` / :func:`nap_transpose_shardmap`
* :func:`standard_forward_shardmap` / :func:`standard_transpose_shardmap`

(The one-release deprecation shims ``nap_spmv_shardmap`` /
``standard_spmv_shardmap`` are GONE — the migration table survives in
``src/repro/kernels/README.md``.)

**Rectangular operators**: every compiled plan carries TWO partitions —
``part`` (rows: who owns the output) and ``col_part`` (columns: who owns
the x entries).  Send/recv/gather maps derive from ``col_part`` and the
output layout from ``part``; the transpose direction simply swaps the
two.  A square single-partition operator (``col_part=None``) behaves
exactly as before; AMG restriction/prolongation pass a genuine ``[m, n]``
matrix with independent partitions.

**Transpose SpMV**: ``A.T @ x`` against the SAME compiled plan, with the
send/recv roles reversed — every forward gather ``buf = recv[idx_map]``
becomes a scatter-add ``segment_sum(contrib, idx_map)`` and every tiled
``all_to_all`` is its own adjoint (it is a (device, slot) transposition),
so the reversed program is the exact adjoint of the forward one.  Padded
map slots all point at position 0 but carry exactly-zero contributions
(no nonzero references a padding slot), so the scatters stay inert where
the forward gathers were.  AMG restriction and BiCG-type solvers get the
transpose for free from the forward plan — no second plan build.

XLA programs are static-SPMD, so the comm plans of :mod:`comm_graph` are
*compiled* into padded gather maps + collectives, once, at plan-build time
(exactly where the paper's MPI implementation builds its send lists):

* ``standard``  — Algorithm 1: one padded all-to-all over the **flat** rank
  axis (every rank pair may exchange), i.e. topology-oblivious.
* ``allgather`` — the dense-JAX baseline: replicate v everywhere.
* ``nap``       — Algorithms 2+3 with ``pairing="aligned"``: intra-node
  all-to-all (proc axis) → **one aggregated inter-node all-to-all (node
  axis)** → intra-node all-to-all.  Only the middle step crosses pods.

Mesh convention: ``("node", "proc")`` with shape ``(n_nodes, ppn)`` — on a
real fleet "node" is the pod/DCI axis and "proc" the intra-pod ICI axis.

Local compute (``local_compute=``) — the **adaptive engine**:

* ``"auto"`` (default) — a density-driven format autotuner: plan
  compilation records per-rank layout stats (block fill density, padded
  FLOPs, bytes moved — see :func:`repro.core.cost_model.local_format_times`)
  and picks the cheapest of bsr/ell/coo under a two-term roofline.  The
  decision is recorded on :class:`CompiledNAP` (``.autotune``).
* ``"bsr"`` — the **fused Pallas BSR path**: the three ``local_spmv``
  blocks of Algorithm 3 are compiled into one MXU-aligned block-sparse
  matmul over the packed ``[v_loc | b_on_node | b_off_node]`` x domain
  (:mod:`repro.kernels.bsr_spmv.fused`), with multi-RHS (nv-wide SpMM)
  support.  Slots are ordered on-process → on-node → off-node, so the
  Pallas pipeline streams the blocks that depend on inter-node data
  last — the paper's Isend/compute overlap, expressed as pipeline stages.
* ``"ell"`` — the **Pallas ELL path** (:mod:`repro.kernels.ell_spmv`) for
  low-density / block-hostile ranks where padded BSR tiles densify:
  kmax-padded rows, vectorised in-kernel row gather, same slot ordering.
* ``"coo"`` — scalar ``segment_sum`` gathers (the pre-fusion reference
  path, kept as an in-graph oracle and for nv on hardware without Pallas).

**Zero-copy x**: every per-rank buffer length is rounded up to the block
lane width bn at compile time, so the BSR/ELL kernels read ``v_loc``,
``b_on_node`` and ``b_off_node`` as separate refs via slot-indexed
index_maps — the packed x operand is never materialised as an HBM
pad/concat (``materialize_x=True`` re-enables the old concat path as a
bit-for-bit A/B oracle).

Plan compilation is fully vectorised (bulk ``np.searchsorted`` against the
slot maps :meth:`NAPPlan.recv_slot_map` exposes — no per-element Python
loops) and cached keyed on (matrix structure+values, partition, topology,
block shape, requested local_compute, autotuner params), so repeated
SpMVs (AMG V-cycles, training steps) pay the plan-build cost once.

Padding note: all per-rank buffers are padded to the max over ranks; the
paper's T/U load balancing minimises exactly this padding.  Effective vs
padded bytes are both reported by :func:`padded_traffic`.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.ops import segment_sum
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.comm_graph import (Message, NAPPlan, StandardPlan,
                                   build_nap_plan, build_standard_plan,
                                   lookup_slots)
from repro.core.integrity import (MULTISTEP_MESSAGE_PHASES,
                                  NAP_MESSAGE_PHASES, STD_MESSAGE_PHASES,
                                  phase_index)
from repro.core.cost_model import (LOCAL_FORMATS, LocalComputeParams,
                                   TPU_V5E_LOCAL, choose_local_format,
                                   local_format_times)
from repro.core.partition import RowPartition
from repro.core.spmv import LocalBlocks, split_all_blocks
from repro.core.topology import Topology
from repro.kernels.bsr_spmv.fused import fused_bsr_spmm, fused_bsr_spmm_packed
from repro.kernels.ell_spmv.kernel import ell_spmm_packed
from repro.sparse.bsr import BSR
from repro.sparse.csr import CSR
from repro.sparse.ell import ELL, stack_ell


def _pad_to(arrs: List[np.ndarray], pad: int, fill: float = 0) -> np.ndarray:
    out = np.full((len(arrs), pad), fill, dtype=arrs[0].dtype if arrs else np.int64)
    for i, a in enumerate(arrs):
        out[i, : a.size] = a
    return out


def _ceil_to(x: int, b: int) -> int:
    return -(-x // b) * b


def _resolve_local_compute(requested: str, compile_requested: str,
                           chosen: str) -> str:
    """Executor request -> concrete format (shared by both compiled plans).

    Precedence: an explicit executor request wins; an executor ``"auto"``
    defers to a concrete format requested at compile time, and only then
    to the autotuner's verdict.
    """
    if requested == "auto":
        if compile_requested != "auto":
            return compile_requested
        return chosen
    if requested not in LOCAL_FORMATS:
        raise ValueError(requested)
    return requested


def _resolve_transpose_local_compute(requested: str, compile_requested: str,
                                     autotune: Dict[str, object]) -> str:
    """Transpose-direction analogue of :func:`_resolve_local_compute`.

    Only ``ell`` and ``coo`` have transposed programs (transposed Pallas
    BSR is a roadmap item), so an explicit ``ell``/``coo`` request wins,
    while ``auto`` — and ``bsr``, which cannot be honoured — defer to the
    transpose autotuner verdict recorded under ``autotune["transpose"]``.
    """
    if requested not in ("auto",) + LOCAL_FORMATS:
        raise ValueError(requested)
    for cand in (requested, compile_requested):
        if cand in ("ell", "coo"):
            return cand
    t = autotune.get("transpose", {})
    return str(t.get("chosen", "coo")) if isinstance(t, dict) else "coo"


def _memo_device_arrays(topo: Topology, arrays: Dict[str, np.ndarray],
                        cache: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Mesh-shaped ((n_nodes, ppn, ...)) device copies of the host arrays.

    Memoized per array name: repeated executor binds against one compiled
    plan reuse the device buffers instead of re-staging every host array
    on every bind (lazy format arrays appear later, so the cache fills
    incrementally — existing entries are never re-copied).

    ``cache`` is normally a :class:`repro.mesh.buffers.BufferNamespace`
    (dict protocol) so the persistent-buffer registry accounts staging,
    reuse and eviction; placement goes through
    :func:`repro.mesh.buffers.stage_mesh_array` — a plain ``jnp.asarray``
    in a single process, a global ``jax.Array`` under a multi-process
    ``jax.distributed`` mesh.
    """
    from repro.mesh.buffers import stage_mesh_array
    nn, ppn = topo.n_nodes, topo.ppn
    for k, v in arrays.items():
        if k not in cache:
            cache[k] = stage_mesh_array(v.reshape((nn, ppn) + v.shape[1:]),
                                        topo)
    return {k: cache[k] for k in arrays}


def _plan_namespace():
    """Fresh buffer namespace for one compiled plan's ``_dev_cache``."""
    from repro.mesh.buffers import default_registry
    return default_registry().namespace("spmv-plan")


@dataclasses.dataclass
class CompiledNAP:
    """Static arrays for the shard_map NAPSpMV, stacked over ranks.

    Rectangular contract: ``part`` is the ROW partition (output layout,
    ``rows_pad`` rows per shard) and ``col_part`` the COLUMN partition
    (input x layout, ``cols_pad`` entries per shard).  They coincide for
    square single-partition operators; an AMG P / R separates them.  The
    packed x domain is ``[v_loc(cols_pad) | b_on_node | b_off_node]``.
    """

    topo: Topology
    part: RowPartition
    rows_pad: int
    pads: Dict[str, int]          # full/init/inter/final/bnode/boff/nnz pads
    arrays: Dict[str, np.ndarray]  # stacked [n_procs, ...] index/value arrays
    col_part: Optional[RowPartition] = None  # None = square (col == row)
    cols_pad: int = 0                        # 0 = square (== rows_pad)
    plan: Optional[NAPPlan] = None          # kept for traffic accounting
    block_shape: Tuple[int, int] = (8, 128)  # fused BSR (bm, bn)
    # element column offsets of the packed fused x operand, all multiples
    # of bn: [0, vblk) = v_loc, [vblk, vblk+nblk) = on-node buffer,
    # [vblk+nblk, vblk+nblk+oblk) = off-node buffer.
    bsr_layout: Dict[str, int] = dataclasses.field(default_factory=dict)
    # rank-local blocks retained for lazy fused-BSR / ELL emission
    local_blocks: Optional[List[LocalBlocks]] = None
    # format autotuner verdict + inputs (chosen format, per-rank stats,
    # modeled per-format times) — filled by compile_nap for BOTH
    # directions (the transpose verdict lives under autotune["transpose"])
    autotune: Dict[str, object] = dataclasses.field(default_factory=dict)
    requested_local_compute: str = "auto"
    ell_kmax: int = 0
    ell_t_kmax: int = 0
    # exchange strategy this plan lowers: "nap" (single aggregated
    # inter-node all_to_all) or "multistep" (adds the fifth "direct"
    # exchange for low-duplication columns; pads["direct"] + the
    # direct_send array exist, and ms_plan holds the full
    # repro.comm.multistep.MultistepPlan — ``plan`` stays the NAP
    # sub-plan so every nap-shaped consumer keeps working).
    comm: str = "nap"
    ms_plan: Optional[object] = None
    # per-name device-array memo (see _memo_device_arrays) — a registry
    # namespace, so resident plan buffers are accounted and releasable
    _dev_cache: Dict[str, jnp.ndarray] = dataclasses.field(
        default_factory=_plan_namespace, repr=False, compare=False)
    # matrix whose VALUES this plan currently carries (swap_values target)
    a_ref: Optional[CSR] = dataclasses.field(
        default=None, repr=False, compare=False)
    # compile-cache key to retire on a value swap (the global cache keys on
    # the ORIGINAL data hash — a swapped plan must not satisfy it)
    _cache_token: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False)

    def __post_init__(self) -> None:
        if self.col_part is None:
            self.col_part = self.part
        if not self.cols_pad:
            self.cols_pad = self.rows_pad

    @property
    def chosen_local_compute(self) -> str:
        return str(self.autotune.get("chosen", "coo"))

    def resolve_local_compute(self, requested: str) -> str:
        """Map an executor's ``local_compute`` request to a concrete format."""
        return _resolve_local_compute(requested, self.requested_local_compute,
                                      self.chosen_local_compute)

    def resolve_transpose_local_compute(self, requested: str) -> str:
        """Transpose-direction format: honours an explicit ``ell``/``coo``
        request; ``auto`` (and ``bsr``, which has no transposed Pallas
        kernel) defer to the transpose autotuner verdict recorded at
        compile time under ``autotune["transpose"]``."""
        return _resolve_transpose_local_compute(
            requested, self.requested_local_compute, self.autotune)

    @property
    def packed_x_len(self) -> int:
        """Element length of the packed [v_loc | b_on_node | b_off_node] x."""
        return self.cols_pad + self.pads["bnode"] + self.pads["boff"]

    def ensure_ell(self) -> None:
        """Materialise the packed ELL arrays (lazily, once) — the
        block-hostile branch of the adaptive engine."""
        if "ell_cols" in self.arrays:
            return
        assert self.local_blocks is not None, "compiled plan lost its blocks"
        cols, vals, kmax = _fused_ell_arrays(
            self.local_blocks, self.rows_pad, self.cols_pad,
            self.pads["bnode"], self.pads["boff"])
        self.arrays["ell_cols"] = cols
        self.arrays["ell_vals"] = vals
        self.ell_kmax = kmax

    def ensure_ell_t(self) -> None:
        """Materialise the TRANSPOSED packed ELL arrays (lazily, once):
        A_r^T over the packed contribution domain
        ``[z(cols_pad) | c_on_node | c_off_node]`` with x = u_loc — the
        vectorised alternative to the transpose COO scatter path."""
        if "ell_t_cols" in self.arrays:
            return
        assert self.local_blocks is not None, "compiled plan lost its blocks"
        cols_pad, bnode_pad = self.cols_pad, self.pads["bnode"]
        out_len = self.packed_x_len
        per_rank: List[ELL] = []
        for blk in self.local_blocks:
            op_r, op_c, op_v = blk.on_proc.to_coo()
            on_r, on_c, on_v = blk.on_node.to_coo()
            off_r, off_c, off_v = blk.off_node.to_coo()
            rows_t = np.concatenate([op_c, cols_pad + on_c,
                                     cols_pad + bnode_pad + off_c])
            cols_t = np.concatenate([op_r, on_r, off_r])
            vals = np.concatenate([op_v, on_v, off_v])
            per_rank.append(ELL.from_coo(rows_t, cols_t, vals,
                                         (out_len, self.rows_pad),
                                         n_rows_pad=out_len))
        cols, vals, kmax = stack_ell(per_rank)
        self.arrays["ell_t_cols"] = cols
        self.arrays["ell_t_vals"] = vals
        self.ell_t_kmax = kmax

    def ensure_fused(self) -> None:
        """Materialise the fused Pallas BSR arrays (lazily, once).

        The fused layout densifies (bm, bn) tiles, which on block-hostile
        structures costs far more memory/time than the gather maps — so it
        is built only when a "bsr" executor is requested, and cached on the
        compiled plan (the compile cache then amortises it across SpMVs).
        """
        if "fused_cols" in self.arrays:
            return
        assert self.local_blocks is not None, "compiled plan lost its blocks"
        bm, bn = self.block_shape
        fc, fb, layout = _fused_bsr_arrays(
            self.local_blocks, self.rows_pad, self.cols_pad,
            self.pads["bnode"], self.pads["boff"], bm, bn)
        self.arrays["fused_cols"] = fc
        self.arrays["fused_blocks"] = fb
        self.bsr_layout.update(layout)

    def ensure_abft(self) -> None:
        """Materialise the ABFT checksum vectors (lazily, once): the
        per-rank COLUMN sums ``c_p = 1^T A_p`` over the packed x domain
        (forward check: ``sum(y_p) == c_p · x_packed``) and ROW sums
        ``A_p 1`` over the output rows (transpose check), plus their
        absolute-value twins feeding the dtype-aware tolerance scale.
        Accumulated in float64 from the f32-rounded values the kernels
        actually multiply, then stored f32 — value arrays, so a hot swap
        refreshes them with zero retraces."""
        if "abft_col" in self.arrays:
            return
        assert self.local_blocks is not None, "compiled plan lost its blocks"
        n, n_x, rows_pad = self.topo.n_procs, self.packed_x_len, self.rows_pad
        col = np.zeros((n, n_x), np.float64)
        cola = np.zeros((n, n_x), np.float64)
        row = np.zeros((n, rows_pad), np.float64)
        rowa = np.zeros((n, rows_pad), np.float64)
        offs = (("on_proc", 0), ("on_node", self.cols_pad),
                ("off_node", self.cols_pad + self.pads["bnode"]))
        for r, blk in enumerate(self.local_blocks):
            for key_c, off in offs:
                rr, cc, vv = getattr(blk, key_c).to_coo()
                v32 = vv.astype(np.float32).astype(np.float64)
                np.add.at(col[r], cc + off, v32)
                np.add.at(cola[r], cc + off, np.abs(v32))
                np.add.at(row[r], rr, v32)
                np.add.at(rowa[r], rr, np.abs(v32))
        self.arrays["abft_col"] = col.astype(np.float32)
        self.arrays["abft_col_abs"] = cola.astype(np.float32)
        self.arrays["abft_row"] = row.astype(np.float32)
        self.arrays["abft_row_abs"] = rowa.astype(np.float32)

    def device_arrays(self) -> Dict[str, jnp.ndarray]:
        """Mesh-shaped (n_nodes, ppn, ...) device arrays, memoized per name."""
        return _memo_device_arrays(self.topo, self.arrays, self._dev_cache)

    def swap_values(self, a_new: CSR) -> List[str]:
        """Hot-swap matrix VALUES in place; sparsity must be identical.

        Rebuilds every value array (eager COO blocks plus any materialised
        lazy format) against the SAME pads and gather maps, evicts only
        those names from the device memo, and retires the plan from the
        global compile cache (which keys on the old data hash).  Executors
        bound to this plan pick the new values up on their next call with
        zero retraces — value arrays are jit arguments, and the
        replacements have identical shapes/dtypes.  Returns the changed
        array names.
        """
        _swap_check_structure(self, a_new)
        blocks = split_all_blocks(a_new, self.part, self.topo,
                                  col_part=self.col_part)
        self.local_blocks = blocks
        changed = []
        for key_c in ("on_proc", "on_node", "off_node"):
            self.arrays[f"{key_c}_vals"] = _pad_to(
                [getattr(b, key_c).to_coo()[2].astype(np.float32)
                 for b in blocks],
                self.pads[f"nnz_{key_c}"], fill=0.0)
            changed.append(f"{key_c}_vals")
        changed += _swap_refresh_lazy(self, [
            ("ell_cols", "ell_vals", self.ensure_ell),
            ("ell_t_cols", "ell_t_vals", self.ensure_ell_t),
            ("fused_cols", "fused_blocks", self.ensure_fused)])
        changed += _swap_refresh_abft(self)
        _swap_finish(self, a_new, changed)
        return changed


def _swap_check_structure(compiled, a_new: CSR) -> None:
    old = compiled.a_ref
    if old is None:
        raise ValueError("compiled plan lost its matrix reference; "
                         "recompile instead of swapping values")
    if (tuple(a_new.shape) != tuple(old.shape)
            or not np.array_equal(a_new.indptr, old.indptr)
            or not np.array_equal(a_new.indices, old.indices)):
        raise ValueError(
            "swap_values requires an identical sparsity structure (same "
            "shape, indptr, indices); a structural change needs a recompile")


def _swap_refresh_lazy(compiled, formats) -> List[str]:
    """Re-emit each MATERIALISED lazy format from the refreshed blocks.

    Structural companions (cols) regenerate to identical values, so their
    device-memo entries stay valid; only the value names report changed.
    """
    changed = []
    for cols_name, vals_name, ensure in formats:
        if cols_name in compiled.arrays:
            del compiled.arrays[cols_name], compiled.arrays[vals_name]
            ensure()
            changed.append(vals_name)
    return changed


#: ABFT checksum-vector names — value arrays derived from the matrix
#: values, so a hot swap refreshes them like the format value arrays.
_ABFT_NAMES = ("abft_col", "abft_col_abs", "abft_row", "abft_row_abs")


def _swap_refresh_abft(compiled) -> List[str]:
    """Re-emit the ABFT checksum vectors if they were materialised."""
    if "abft_col" not in compiled.arrays:
        return []
    for k in _ABFT_NAMES:
        del compiled.arrays[k]
    compiled.ensure_abft()
    return list(_ABFT_NAMES)


def _swap_finish(compiled, a_new: CSR, changed: List[str]) -> None:
    for name in changed:
        compiled._dev_cache.pop(name, None)
    compiled.a_ref = a_new
    if compiled._cache_token is not None:
        _COMPILE_CACHE.pop(compiled._cache_token, None)
        compiled._cache_token = None


# ---------------------------------------------------------------------------
# Plan compilation (vectorised + cached)
# ---------------------------------------------------------------------------

_COMPILE_CACHE: Dict[tuple, CompiledNAP] = {}
_COMPILE_CACHE_MAX = 16  # LRU bound: entries retain plans + dense fused blocks


def clear_compile_cache() -> None:
    _COMPILE_CACHE.clear()


def _cache_put(key: tuple, compiled: CompiledNAP) -> None:
    while len(_COMPILE_CACHE) >= _COMPILE_CACHE_MAX:
        _COMPILE_CACHE.pop(next(iter(_COMPILE_CACHE)))
    _COMPILE_CACHE[key] = compiled


def _cache_get(key: tuple) -> Optional[CompiledNAP]:
    hit = _COMPILE_CACHE.pop(key, None)
    if hit is not None:
        _COMPILE_CACHE[key] = hit  # re-insert: dict order is the LRU order
    return hit


def _cache_key(a: CSR, part: RowPartition, topo: Topology,
               block_shape: Tuple[int, int], local_compute: str,
               tuner: LocalComputeParams, tag: str,
               col_part: Optional[RowPartition] = None) -> tuple:
    h = hashlib.sha1()
    arrs = [a.indptr, a.indices, a.data, part.owner]
    if col_part is not None:
        arrs.append(col_part.owner)
    for arr in arrs:
        h.update(np.ascontiguousarray(arr).tobytes())
    # block_shape and the tuner signature cover every autotuner input that
    # is not a function of the hashed matrix (fill density etc. derive from
    # structure + block shape); local_compute covers the requested mode and
    # tag the plan family (nap vs standard) — switching any of them can
    # never return a stale compiled plan.
    return (tag, h.hexdigest(), a.shape, topo.n_nodes, topo.ppn,
            tuple(block_shape), str(local_compute), tuner.signature())


def _fused_bsr_arrays(blocks: List[LocalBlocks], rows_pad: int, cols_pad: int,
                      bnode_pad: int, boff_pad: int,
                      bm: int, bn: int) -> Tuple[np.ndarray, np.ndarray, Dict[str, int]]:
    """Fuse each rank's three column blocks into one padded-uniform BSR.

    The element column domain is the concatenated x operand
    ``[v_loc(cols_pad) | b_on_node | b_off_node]`` with every segment
    padded to a multiple of bn, so segment boundaries land on block
    boundaries and a block column never straddles two buffers.  Block
    columns sort ascending within each block row, which orders slots
    on-process → on-node → off-node — the overlap-friendly streaming
    order.  ``rows_pad`` (the row-partition output pad) and ``cols_pad``
    (the column-partition v_loc pad) coincide only in the square case.
    """
    vblk = _ceil_to(max(cols_pad, 1), bn)
    nblk = _ceil_to(max(bnode_pad, 1), bn)
    oblk = _ceil_to(max(boff_pad, 1), bn)
    n_cols = vblk + nblk + oblk
    per_rank: List[BSR] = []
    for blk in blocks:
        op_r, op_c, op_v = blk.on_proc.to_coo()
        on_r, on_c, on_v = blk.on_node.to_coo()
        off_r, off_c, off_v = blk.off_node.to_coo()
        rows = np.concatenate([op_r, on_r, off_r])
        cols = np.concatenate([op_c, vblk + on_c, vblk + nblk + off_c])
        vals = np.concatenate([op_v, on_v, off_v])
        per_rank.append(BSR.from_coo(rows, cols, vals, (rows_pad, n_cols),
                                     bm=bm, bn=bn))
    cols, data, kmax = _stack_padded_bsr(per_rank)
    layout = dict(vblk=vblk, nblk=nblk, oblk=oblk,
                  n_brows=per_rank[0].n_brows, kmax=kmax)
    return cols, data, layout


def _fused_ell_arrays(blocks: List[LocalBlocks], rows_pad: int, cols_pad: int,
                      bnode_pad: int, boff_pad: int
                      ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Emit each rank's three column blocks as one ELL over the packed x
    domain ``[v_loc(cols_pad) | b_on_node | b_off_node]`` (offsets
    cols_pad and cols_pad + bnode_pad), stacked to a shared kmax."""
    n_x = cols_pad + bnode_pad + boff_pad
    per_rank: List[ELL] = []
    for blk in blocks:
        op_r, op_c, op_v = blk.on_proc.to_coo()
        on_r, on_c, on_v = blk.on_node.to_coo()
        off_r, off_c, off_v = blk.off_node.to_coo()
        rows = np.concatenate([op_r, on_r, off_r])
        cols = np.concatenate([op_c, cols_pad + on_c,
                               cols_pad + bnode_pad + off_c])
        vals = np.concatenate([op_v, on_v, off_v])
        per_rank.append(ELL.from_coo(rows, cols, vals, (rows_pad, n_x),
                                     n_rows_pad=rows_pad))
    return stack_ell(per_rank)


def _format_stats_from_coo(per_rank_rc: List[Tuple[np.ndarray, np.ndarray]],
                           rows_pad: int, n_x: int, nnz_pad_total: int,
                           block_shape: Tuple[int, int],
                           tuner: LocalComputeParams) -> Dict[str, object]:
    """Layout stats + format decision from per-rank packed-domain COOs,
    without materialising any format.

    BSR tile counts come from unique (block row, block col) keys over the
    packed column domain; ELL kmax from per-row counts — both pure bulk
    numpy.  The SPMD program is bulk-synchronous, so the global decision
    uses stats maxed over ranks; per-rank verdicts are recorded for
    diagnostics/benchmarks.  Shared by compile_nap (three-segment packed
    domain) and standard_spmv_shardmap (two-segment).
    """
    bm, bn = block_shape
    nbc = n_x // bn
    n_brows = -(-rows_pad // bm)
    per_rank = []
    kb_global = 1
    ke_global = 1
    for rank, (rows, cols) in enumerate(per_rank_rc):
        keys = np.unique((rows // bm) * nbc + cols // bn)
        kb = int(np.bincount((keys // nbc).astype(np.int64),
                             minlength=n_brows).max(initial=0))
        ke = max(1, int(np.bincount(rows.astype(np.int64),
                                    minlength=rows_pad).max(initial=0)))
        nnz = int(rows.size)
        per_rank.append({
            "rank": rank, "nnz": nnz, "bsr_tiles": int(keys.size),
            "bsr_fill": nnz / max(int(keys.size) * bm * bn, 1),
            "ell_kmax": ke,
        })
        kb_global = max(kb_global, kb)
        ke_global = max(ke_global, ke)
    stats = {
        "rows_pad": rows_pad, "n_x": n_x, "nnz_pad": nnz_pad_total,
        "bsr_blocks": n_brows * kb_global, "bm": bm, "bn": bn,
        "ell_kmax": ke_global,
    }
    times = local_format_times(stats, tuner)
    for entry in per_rank:
        rank_stats = dict(stats, bsr_blocks=entry["bsr_tiles"],
                          ell_kmax=entry["ell_kmax"], nnz_pad=entry["nnz"])
        entry["choice"] = choose_local_format(rank_stats, tuner)
    return {
        "chosen": min(LOCAL_FORMATS, key=lambda f: times[f]),
        "times": times,
        "stats": stats,
        "per_rank": per_rank,
        "tuner": tuner.name,
    }


def _autotune_stats(blocks: List[LocalBlocks], rows_pad: int, cols_pad: int,
                    bnode_pad: int, boff_pad: int, nnz_pad_total: int,
                    block_shape: Tuple[int, int],
                    tuner: LocalComputeParams) -> Dict[str, object]:
    """NAP three-segment packed domain -> format stats + decision,
    for BOTH directions: the forward verdict at the top level and the
    transpose verdict (over the reversed domain) under ``"transpose"``."""
    per_rank_rc = []
    for blk in blocks:
        parts = [blk.on_proc.to_coo(), blk.on_node.to_coo(),
                 blk.off_node.to_coo()]
        offs = [0, cols_pad, cols_pad + bnode_pad]
        rows = np.concatenate([p[0] for p in parts])
        cols = np.concatenate([p[1] + o for p, o in zip(parts, offs)])
        per_rank_rc.append((rows, cols))
    n_x = cols_pad + bnode_pad + boff_pad
    out = _format_stats_from_coo(per_rank_rc, rows_pad, n_x,
                                 nnz_pad_total, block_shape, tuner)
    out["transpose"] = _transpose_format_stats(
        [(c, r) for r, c in per_rank_rc], n_x, rows_pad, nnz_pad_total,
        block_shape, tuner)
    return out


def _transpose_format_stats(per_rank_rc_t: List[Tuple[np.ndarray, np.ndarray]],
                            out_len: int, n_x: int, nnz_pad_total: int,
                            block_shape: Tuple[int, int],
                            tuner: LocalComputeParams) -> Dict[str, object]:
    """Format stats + verdict for the TRANSPOSED local compute.

    The transpose program multiplies A_r^T (shape [packed contribution
    domain, rows_pad]) against u_loc, so the roofline runs with the roles
    swapped: output rows = the packed domain, x = the row-partition
    shard.  Only ``ell`` and ``coo`` are candidates — there is no
    transposed Pallas BSR kernel — so the verdict is the argmin of those
    two (this is what ``op.T`` resolves ``local_compute="auto"`` to).
    """
    at = _format_stats_from_coo(per_rank_rc_t, out_len, n_x, nnz_pad_total,
                                block_shape, tuner)
    times = {f: at["times"][f] for f in ("ell", "coo")}
    return {"chosen": min(times, key=lambda f: times[f]), "times": times,
            "stats": at["stats"], "per_rank": at["per_rank"],
            "tuner": tuner.name}


def _stack_padded_bsr(per_rank: List[BSR]) -> Tuple[np.ndarray, np.ndarray, int]:
    """Align every rank's padded-uniform layout to one shared kmax and stack
    into the [n_procs, n_brows, kmax(, bm, bn)] arrays the kernel consumes."""
    kmax = max(1, max((int(np.diff(b.indptr).max(initial=0)) for b in per_rank),
                      default=1))
    cols_s, blocks_s = [], []
    for b in per_rank:
        c, d, _ = b.padded_uniform(kmax=kmax)
        cols_s.append(c)
        blocks_s.append(d)
    return np.stack(cols_s), np.stack(blocks_s), kmax


def compile_nap(a: CSR, part: RowPartition, topo: Topology,
                plan: Optional[NAPPlan] = None,
                block_shape: Tuple[int, int] = (8, 128),
                cache: bool = True, local_compute: str = "auto",
                tuner: LocalComputeParams = TPU_V5E_LOCAL,
                col_part: Optional[RowPartition] = None) -> CompiledNAP:
    """Compile the node-aware plan to static shard_map arrays.

    ``part`` is the ROW partition (output layout); ``col_part`` the
    COLUMN/x partition — defaults to ``part``, the square case.  A
    rectangular ``a`` REQUIRES ``col_part`` (shapes are validated).
    """
    if local_compute not in ("auto",) + LOCAL_FORMATS:
        raise ValueError(local_compute)
    cpart = part if col_part is None else col_part
    if part.n_rows != a.shape[0] or cpart.n_rows != a.shape[1]:
        raise ValueError(
            f"partition/matrix mismatch: a is {a.shape}, row partition has "
            f"{part.n_rows} rows, column partition {cpart.n_rows}")
    key = None
    if plan is None and cache:
        key = _cache_key(a, part, topo, block_shape, local_compute, tuner,
                         "nap", col_part=col_part)
        hit = _cache_get(key)
        if hit is not None:
            return hit
    if plan is None:
        plan = build_nap_plan(a.indptr, a.indices, part, topo,
                              pairing="aligned", col_part=col_part)
    n_procs, ppn, n_nodes = topo.n_procs, topo.ppn, topo.n_nodes
    blocks = split_all_blocks(a, part, topo, col_part=cpart)
    local_index = cpart.local_index()
    bn = block_shape[1]
    if bn % 8 != 0:
        raise ValueError(f"bn must be a multiple of the 8-wide sublane "
                         f"tile, got {bn}")
    # Segment lengths of the packed x operand are rounded up to the lane
    # width bn, so v_loc / b_on_node / b_off_node are bn-aligned views of
    # one packed domain and the Pallas kernels gather them zero-copy (no
    # HBM pad/concat per call).  Padding slots beyond the true sizes are
    # never referenced by a nonzero, so the rounding is mathematically
    # inert everywhere (incl. the COO path's segment_sum).  rows_pad is
    # the row-partition output pad, cols_pad the column-partition v_loc
    # pad (identical in the square single-partition case).
    rows_pad = _ceil_to(max(1, int(part.counts().max())), bn)
    cols_pad = _ceil_to(max(1, int(cpart.counts().max())), bn)
    bnode_pad = _ceil_to(max(1, max(b.on_node_cols.size for b in blocks)), bn)
    boff_pad = _ceil_to(max(1, max(b.off_node_cols.size for b in blocks)), bn)

    def msg_pad(phase: List[List[Message]]) -> int:
        sizes = [m.size for msgs in phase for m in msgs]
        return max(1, max(sizes, default=1))

    full_pad = msg_pad(plan.local_full_sends)
    init_pad = msg_pad(plan.local_init_sends)
    inter_pad = msg_pad(plan.inter_sends)
    final_pad = msg_pad(plan.local_final_sends)
    nnz_pads = {
        "on_proc": max(1, max(b.on_proc.nnz for b in blocks)),
        "on_node": max(1, max(b.on_node.nnz for b in blocks)),
        "off_node": max(1, max(b.off_node.nnz for b in blocks)),
    }

    arrays: Dict[str, np.ndarray] = {}

    def stack_int(name: str, per_rank: List[np.ndarray], shape: Tuple[int, ...]) -> None:
        out = np.zeros((n_procs,) + shape, dtype=np.int32)
        for r, arr in enumerate(per_rank):
            out[r] = arr
        arrays[name] = out

    full_send, init_send, final_send = [], [], []
    inter_gather, bnode_gather, boff_gather = [], [], []
    coo = {k: {"rows": [], "cols": [], "vals": []} for k in nnz_pads}

    for r in range(n_procs):
        blk = blocks[r]

        # -- full-local sends: [ppn, full_pad] source local-row positions ----
        fs = np.zeros((ppn, full_pad), dtype=np.int32)
        for m in plan.local_full_sends[r]:
            fs[topo.local_of(m.dst), : m.size] = local_index[m.idx]
        full_send.append(fs)

        # -- init sends -------------------------------------------------------
        isnd = np.zeros((ppn, init_pad), dtype=np.int32)
        for m in plan.local_init_sends[r]:
            isnd[topo.local_of(m.dst), : m.size] = local_index[m.idx]
        init_send.append(isnd)

        # -- inter gather: positions into concat(v_loc, init_recv_flat) -------
        # (bulk searchsorted against the init-phase slot map; no element loops)
        init_map = plan.recv_slot_map(r, "init", init_pad)
        ig = np.zeros((n_nodes, inter_pad), dtype=np.int32)
        for m in plan.inter_sends[r]:
            owners = cpart.owner[m.idx]
            own = owners == r
            pos = np.empty(m.size, dtype=np.int64)
            pos[own] = local_index[m.idx[own]]
            if not own.all():
                pos[~own] = cols_pad + lookup_slots(init_map, m.idx[~own])
            ig[topo.node_of(m.dst), : m.size] = pos
        inter_gather.append(ig)

        # -- final sends: positions into inter_recv_flat ----------------------
        inter_map = plan.recv_slot_map(r, "inter", inter_pad)
        fsnd = np.zeros((ppn, final_pad), dtype=np.int32)
        for m in plan.local_final_sends[r]:
            fsnd[topo.local_of(m.dst), : m.size] = lookup_slots(inter_map, m.idx)
        final_send.append(fsnd)

        # -- on-node buffer gather: positions into full_recv_flat -------------
        full_map = plan.recv_slot_map(r, "full", full_pad)
        bg = np.zeros((bnode_pad,), dtype=np.int32)
        bg[: blk.on_node_cols.size] = lookup_slots(full_map, blk.on_node_cols)
        bnode_gather.append(bg)

        # -- off-node buffer gather: concat(inter_recv_flat, final_recv_flat) -
        final_map = plan.recv_slot_map(r, "final", final_pad)
        comb_idx = np.concatenate([inter_map[0], final_map[0]])
        comb_pos = np.concatenate([inter_map[1],
                                   n_nodes * inter_pad + final_map[1]])
        order = np.argsort(comb_idx, kind="stable")
        og = np.zeros((boff_pad,), dtype=np.int32)
        og[: blk.off_node_cols.size] = lookup_slots(
            (comb_idx[order], comb_pos[order]), blk.off_node_cols)
        boff_gather.append(og)

        # -- COO blocks --------------------------------------------------------
        for key_c, block in (("on_proc", blk.on_proc), ("on_node", blk.on_node),
                             ("off_node", blk.off_node)):
            rows_i, cols_i, vals_i = block.to_coo()
            coo[key_c]["rows"].append(rows_i.astype(np.int32))
            coo[key_c]["cols"].append(cols_i.astype(np.int32))
            coo[key_c]["vals"].append(vals_i)

    stack_int("full_send", full_send, (ppn, full_pad))
    stack_int("init_send", init_send, (ppn, init_pad))
    stack_int("final_send", final_send, (ppn, final_pad))
    stack_int("inter_gather", inter_gather, (n_nodes, inter_pad))
    stack_int("bnode_gather", bnode_gather, (bnode_pad,))
    stack_int("boff_gather", boff_gather, (boff_pad,))
    for key_c in coo:
        arrays[f"{key_c}_rows"] = _pad_to(coo[key_c]["rows"], nnz_pads[key_c]).astype(np.int32)
        arrays[f"{key_c}_cols"] = _pad_to(coo[key_c]["cols"], nnz_pads[key_c]).astype(np.int32)
        arrays[f"{key_c}_vals"] = _pad_to(
            [v.astype(np.float32) for v in coo[key_c]["vals"]], nnz_pads[key_c], fill=0.0)

    pads = dict(full=full_pad, init=init_pad, inter=inter_pad, final=final_pad,
                bnode=bnode_pad, boff=boff_pad, **{f"nnz_{k}": v for k, v in nnz_pads.items()})
    autotune = _autotune_stats(blocks, rows_pad, cols_pad, bnode_pad, boff_pad,
                               sum(nnz_pads.values()), tuple(block_shape),
                               tuner)
    compiled = CompiledNAP(topo=topo, part=part, col_part=cpart,
                           rows_pad=rows_pad, cols_pad=cols_pad, pads=pads,
                           arrays=arrays, plan=plan,
                           block_shape=tuple(block_shape),
                           local_blocks=blocks, autotune=autotune,
                           requested_local_compute=local_compute,
                           a_ref=a, _cache_token=key)
    if key is not None:
        _cache_put(key, compiled)
    return compiled


def compile_multistep(a: CSR, part: RowPartition, topo: Topology,
                      plan=None, block_shape: Tuple[int, int] = (8, 128),
                      cache: bool = True, local_compute: str = "auto",
                      tuner: LocalComputeParams = TPU_V5E_LOCAL,
                      col_part: Optional[RowPartition] = None,
                      threshold="auto") -> CompiledNAP:
    """Compile the multi-step plan (``repro.comm.multistep``) to static
    shard_map arrays.

    Produces a :class:`CompiledNAP` with ``comm="multistep"``: the four
    NAP arrays are built from the high-duplication sub-plan exactly as
    :func:`compile_nap` builds them, plus a ``direct_send``
    ``[n_procs, direct_pad]`` gather for the fifth (flat, low-duplication)
    exchange, and ``boff_gather`` resolves off-node columns against the
    concatenation of all THREE recv buffers
    ``[inter | final | direct]``.  ``plan`` optionally supplies a
    prebuilt :class:`repro.comm.multistep.MultistepPlan`.
    """
    from repro.comm.multistep import build_multistep_plan, resolve_threshold
    if local_compute not in ("auto",) + LOCAL_FORMATS:
        raise ValueError(local_compute)
    cpart = part if col_part is None else col_part
    if part.n_rows != a.shape[0] or cpart.n_rows != a.shape[1]:
        raise ValueError(
            f"partition/matrix mismatch: a is {a.shape}, row partition has "
            f"{part.n_rows} rows, column partition {cpart.n_rows}")
    thr = resolve_threshold(threshold, topo)
    key = None
    if plan is None and cache:
        # the threshold changes the split, so it is part of the plan family
        key = _cache_key(a, part, topo, block_shape, local_compute, tuner,
                         f"multistep:{thr}", col_part=col_part)
        hit = _cache_get(key)
        if hit is not None:
            return hit
    if plan is None:
        plan = build_multistep_plan(a.indptr, a.indices, part, topo,
                                    pairing="aligned", col_part=col_part,
                                    threshold=thr)
    nap_plan, direct = plan.nap, plan.direct
    n_procs, ppn, n_nodes = topo.n_procs, topo.ppn, topo.n_nodes
    blocks = split_all_blocks(a, part, topo, col_part=cpart)
    local_index = cpart.local_index()
    bn = block_shape[1]
    if bn % 8 != 0:
        raise ValueError(f"bn must be a multiple of the 8-wide sublane "
                         f"tile, got {bn}")
    rows_pad = _ceil_to(max(1, int(part.counts().max())), bn)
    cols_pad = _ceil_to(max(1, int(cpart.counts().max())), bn)
    bnode_pad = _ceil_to(max(1, max(b.on_node_cols.size for b in blocks)), bn)
    boff_pad = _ceil_to(max(1, max(b.off_node_cols.size for b in blocks)), bn)

    def msg_pad(phase: List[List[Message]]) -> int:
        sizes = [m.size for msgs in phase for m in msgs]
        return max(1, max(sizes, default=1))

    full_pad = msg_pad(nap_plan.local_full_sends)
    init_pad = msg_pad(nap_plan.local_init_sends)
    inter_pad = msg_pad(nap_plan.inter_sends)
    final_pad = msg_pad(nap_plan.local_final_sends)
    direct_pad = msg_pad(direct.sends)
    nnz_pads = {
        "on_proc": max(1, max(b.on_proc.nnz for b in blocks)),
        "on_node": max(1, max(b.on_node.nnz for b in blocks)),
        "off_node": max(1, max(b.off_node.nnz for b in blocks)),
    }

    arrays: Dict[str, np.ndarray] = {}

    def stack_int(name: str, per_rank: List[np.ndarray], shape: Tuple[int, ...]) -> None:
        out = np.zeros((n_procs,) + shape, dtype=np.int32)
        for r, arr in enumerate(per_rank):
            out[r] = arr
        arrays[name] = out

    full_send, init_send, final_send, direct_send = [], [], [], []
    inter_gather, bnode_gather, boff_gather = [], [], []
    coo = {k: {"rows": [], "cols": [], "vals": []} for k in nnz_pads}

    for r in range(n_procs):
        blk = blocks[r]

        fs = np.zeros((ppn, full_pad), dtype=np.int32)
        for m in nap_plan.local_full_sends[r]:
            fs[topo.local_of(m.dst), : m.size] = local_index[m.idx]
        full_send.append(fs)

        isnd = np.zeros((ppn, init_pad), dtype=np.int32)
        for m in nap_plan.local_init_sends[r]:
            isnd[topo.local_of(m.dst), : m.size] = local_index[m.idx]
        init_send.append(isnd)

        init_map = nap_plan.recv_slot_map(r, "init", init_pad)
        ig = np.zeros((n_nodes, inter_pad), dtype=np.int32)
        for m in nap_plan.inter_sends[r]:
            owners = cpart.owner[m.idx]
            own = owners == r
            pos = np.empty(m.size, dtype=np.int64)
            pos[own] = local_index[m.idx[own]]
            if not own.all():
                pos[~own] = cols_pad + lookup_slots(init_map, m.idx[~own])
            ig[topo.node_of(m.dst), : m.size] = pos
        inter_gather.append(ig)

        inter_map = nap_plan.recv_slot_map(r, "inter", inter_pad)
        fsnd = np.zeros((ppn, final_pad), dtype=np.int32)
        for m in nap_plan.local_final_sends[r]:
            fsnd[topo.local_of(m.dst), : m.size] = lookup_slots(inter_map, m.idx)
        final_send.append(fsnd)

        # -- direct sends: [n_procs, direct_pad] source local-row positions,
        #    one slot per destination rank in the flat fifth exchange.
        ds = np.zeros((n_procs, direct_pad), dtype=np.int32)
        for m in direct.sends[r]:
            ds[m.dst, : m.size] = local_index[m.idx]
        direct_send.append(ds)

        full_map = nap_plan.recv_slot_map(r, "full", full_pad)
        bg = np.zeros((bnode_pad,), dtype=np.int32)
        bg[: blk.on_node_cols.size] = lookup_slots(full_map, blk.on_node_cols)
        bnode_gather.append(bg)

        # -- off-node gather over concat(inter | final | direct) recvs -------
        final_map = nap_plan.recv_slot_map(r, "final", final_pad)
        direct_map = direct.recv_slot_map(r, direct_pad)
        comb_idx = np.concatenate([inter_map[0], final_map[0], direct_map[0]])
        comb_pos = np.concatenate([
            inter_map[1],
            n_nodes * inter_pad + final_map[1],
            n_nodes * inter_pad + ppn * final_pad + direct_map[1]])
        order = np.argsort(comb_idx, kind="stable")
        og = np.zeros((boff_pad,), dtype=np.int32)
        og[: blk.off_node_cols.size] = lookup_slots(
            (comb_idx[order], comb_pos[order]), blk.off_node_cols)
        boff_gather.append(og)

        for key_c, block in (("on_proc", blk.on_proc), ("on_node", blk.on_node),
                             ("off_node", blk.off_node)):
            rows_i, cols_i, vals_i = block.to_coo()
            coo[key_c]["rows"].append(rows_i.astype(np.int32))
            coo[key_c]["cols"].append(cols_i.astype(np.int32))
            coo[key_c]["vals"].append(vals_i)

    stack_int("full_send", full_send, (ppn, full_pad))
    stack_int("init_send", init_send, (ppn, init_pad))
    stack_int("final_send", final_send, (ppn, final_pad))
    stack_int("direct_send", direct_send, (n_procs, direct_pad))
    stack_int("inter_gather", inter_gather, (n_nodes, inter_pad))
    stack_int("bnode_gather", bnode_gather, (bnode_pad,))
    stack_int("boff_gather", boff_gather, (boff_pad,))
    for key_c in coo:
        arrays[f"{key_c}_rows"] = _pad_to(coo[key_c]["rows"], nnz_pads[key_c]).astype(np.int32)
        arrays[f"{key_c}_cols"] = _pad_to(coo[key_c]["cols"], nnz_pads[key_c]).astype(np.int32)
        arrays[f"{key_c}_vals"] = _pad_to(
            [v.astype(np.float32) for v in coo[key_c]["vals"]], nnz_pads[key_c], fill=0.0)

    pads = dict(full=full_pad, init=init_pad, inter=inter_pad, final=final_pad,
                direct=direct_pad, bnode=bnode_pad, boff=boff_pad,
                **{f"nnz_{k}": v for k, v in nnz_pads.items()})
    autotune = _autotune_stats(blocks, rows_pad, cols_pad, bnode_pad, boff_pad,
                               sum(nnz_pads.values()), tuple(block_shape),
                               tuner)
    compiled = CompiledNAP(topo=topo, part=part, col_part=cpart,
                           rows_pad=rows_pad, cols_pad=cols_pad, pads=pads,
                           arrays=arrays, plan=nap_plan,
                           block_shape=tuple(block_shape),
                           local_blocks=blocks, autotune=autotune,
                           requested_local_compute=local_compute,
                           comm="multistep", ms_plan=plan,
                           a_ref=a, _cache_token=key)
    if key is not None:
        _cache_put(key, compiled)
    return compiled


# ---------------------------------------------------------------------------
# Vector packing
# ---------------------------------------------------------------------------

def pack_vector(v: np.ndarray, part: RowPartition, topo: Topology, rows_pad: int) -> np.ndarray:
    """Global vector/multivector -> [n_nodes, ppn, rows_pad(, nv)] shards.

    ``part`` is whichever partition owns ``v``: the COLUMN partition with
    ``rows_pad=compiled.cols_pad`` for a forward operand, the ROW
    partition with ``compiled.rows_pad`` for a transpose operand.  Empty
    ranks simply contribute all-zero shards.
    """
    v = np.asarray(v)
    out = np.zeros((topo.n_procs, rows_pad) + v.shape[1:], dtype=np.float32)
    for r in range(topo.n_procs):
        rows = part.rows_of(r)
        out[r, : rows.size] = v[rows]
    return out.reshape((topo.n_nodes, topo.ppn, rows_pad) + v.shape[1:])


def unpack_vector(w: np.ndarray, part: RowPartition, topo: Topology) -> np.ndarray:
    """[n_nodes, ppn, pad(, nv)] -> global vector/multivector.

    ``part`` is whichever partition owns the RESULT (row partition after
    a forward apply, column partition after a transpose); per-rank slots
    beyond the rank's count are padding and ignored.  Exact inverse of
    :func:`pack_vector` under the same partition, for any pad ≥ the max
    rank count — empty ranks and uneven m≠n tails round-trip bit-for-bit.
    """
    w = np.asarray(w)
    w = w.reshape((topo.n_procs, -1) + w.shape[3:] if w.ndim == 4
                  else (topo.n_procs, -1))
    out = np.zeros((part.n_rows,) + w.shape[2:], dtype=w.dtype)
    for r in range(topo.n_procs):
        rows = part.rows_of(r)
        out[rows] = w[r, : rows.size]
    return out


# ---------------------------------------------------------------------------
# Shared run wrapper
# ---------------------------------------------------------------------------

#: Device-array names that carry matrix VALUES rather than structure.
#: The shard_map builders pass these to the jitted program as ARGUMENTS
#: (re-fetched from the compiled plan on every call) instead of baking
#: them in as trace-time closure constants — so a hot value swap
#: (:meth:`CompiledNAP.swap_values`: same sparsity, new numbers) flows
#: into the SAME compiled executable with zero retraces, because the
#: replacement arrays have identical shapes/dtypes and hit the jit cache.
VALUE_ARRAY_NAMES = frozenset({
    "on_proc_vals", "on_node_vals", "off_node_vals",
    "ell_vals", "ell_t_vals", "fused_blocks", "A_vals",
    "abft_col", "abft_col_abs", "abft_row", "abft_row_abs"})


# ---------------------------------------------------------------------------
# In-graph integrity primitives (jnp twins of repro.core.integrity)
# ---------------------------------------------------------------------------

def _msg_checksums(buf: jnp.ndarray) -> jnp.ndarray:
    """Per-message position-weighted Fletcher fold, [n_slots] uint32.

    Bit-for-bit twin of :func:`repro.core.integrity.checksum_np`: the
    payload's raw bit pattern viewed as 32-bit words ``w_i``, with
    ``s1 = Σ w_i`` and ``s2 = Σ i·w_i`` (1-based) both wrapping mod 2^32,
    folded as ``s1 ^ rotl32(s2, 7)``.  uint32 arithmetic wraps, and
    reduction mod 2^32 is a ring homomorphism, so the jnp and numpy
    evaluations agree exactly.
    """
    n = buf.shape[0]
    flat = buf.reshape(n, -1)
    words = jax.lax.bitcast_convert_type(flat, jnp.uint32).reshape(n, -1)
    idx = jnp.arange(1, words.shape[1] + 1, dtype=jnp.uint32)
    s1 = jnp.sum(words, axis=1, dtype=jnp.uint32)
    s2 = jnp.sum(words * idx[None, :], axis=1, dtype=jnp.uint32)
    return s1 ^ (((s2 << 7) & jnp.uint32(0xFFFFFFFF)) | (s2 >> 25))


def _apply_fault(buf: jnp.ndarray, spec_row: jnp.ndarray) -> jnp.ndarray:
    """Pure in-graph message-fault transform at the pack boundary.

    ``spec_row`` is one int32 ``(kind_code, slot, element, bit)`` row of
    the fault-spec ARGUMENT (see integrity.build_fault_spec) — kind 0
    returns ``buf`` unchanged, so the armed/clean distinction is a data
    value, never a retrace.  Every variant is computed (cheap elementwise
    work) and selected by ``where``: bitflip XORs one bit of one 32-bit
    word; zero and drop blank the slot (a dropped message in a static
    SPMD program IS a zero payload); stale shifts the slot's elements by
    one (a plausibly-valid but stale buffer); duplicate delivers the
    NEXT slot's payload in place of this one.
    """
    kind, slot, elem, bit = (spec_row[0], spec_row[1], spec_row[2],
                             spec_row[3])
    n = buf.shape[0]
    flat = buf.reshape(n, -1)
    slot = jnp.mod(slot, n)
    is_slot = (jnp.arange(n, dtype=jnp.int32) == slot)[:, None]
    words = jax.lax.bitcast_convert_type(flat, jnp.uint32)
    w2 = words.reshape(n, -1)
    elem_w = jnp.mod(elem, w2.shape[1])
    hit = is_slot & (jnp.arange(w2.shape[1], dtype=jnp.int32)[None, :]
                     == elem_w)
    mask = jnp.where(
        hit, jnp.uint32(1) << jnp.clip(bit, 0, 31).astype(jnp.uint32),
        jnp.uint32(0))
    flipped = jax.lax.bitcast_convert_type(
        (w2 ^ mask).reshape(words.shape), flat.dtype).reshape(n, -1)
    zeroed = jnp.where(is_slot, jnp.zeros_like(flat), flat)
    stale = jnp.where(is_slot, jnp.roll(flat, 1, axis=1), flat)
    dup = jnp.where(is_slot, jnp.roll(flat, -1, axis=0), flat)
    out = flat
    for code, variant in ((1, flipped), (2, zeroed), (3, stale),
                          (4, zeroed), (5, dup)):
        out = jnp.where(kind == code, variant, out)
    return out.reshape(buf.shape)


def _stack_chk(pairs: List[Tuple[jnp.ndarray, jnp.ndarray]],
               max_slots: int) -> jnp.ndarray:
    """Stack per-phase (expected, actual) checksum vectors into the
    [n_phases, 2, max_slots] aux output (padded slots zero on BOTH rows,
    so padding can never read as a mismatch)."""
    rows = []
    for expect, actual in pairs:
        pad = max_slots - expect.shape[0]
        rows.append(jnp.stack([jnp.pad(expect, (0, pad)),
                               jnp.pad(actual, (0, pad))]))
    return jnp.stack(rows)


def _make_run(call4, fmt: str, val_fetch=None, fault_fetch=None, stage=None):
    """Wrap a 4-D shard program into the public run callable.

    ``run(v_shards, donate=False)`` accepts [n_nodes, ppn, rows_pad] or
    [..., nv] shards; ``donate=True`` dispatches to a separately-jitted
    entry with ``donate_argnums=(0,)`` (built lazily) so XLA may reuse the
    input shard buffer — the ``NapOperator.__call__(donate=...)`` path.

    ``val_fetch()`` returns the CURRENT matrix-value device arrays, passed
    as extra jit arguments each call (the hot-value-swap seam — see
    :data:`VALUE_ARRAY_NAMES`).  ``run.n_traces()`` counts program traces:
    it must not grow across a value swap with unchanged shapes.

    ``fault_fetch()`` (integrity-instrumented programs only) returns the
    armed fault-spec array — same shape/dtype every call, so arming or
    clearing scripted faults never retraces either.  With it set, ``run``
    returns the instrumented triple ``(w_shards, chk, abft)``.

    ``stage`` (multi-process jobs only — see
    :func:`repro.mesh.buffers.input_stager`) places the packed operand as
    a GLOBAL sharded array before the jit call; ``None`` keeps the
    single-process ``jnp.asarray`` path bit-for-bit.
    """
    counter = {"n": 0}

    def traced(*args):   # Python body runs only when jax (re)traces
        counter["n"] += 1
        return call4(*args)

    jits = {False: jax.jit(traced)}

    def run(v_shards, donate: bool = False):
        if stage is None:
            v_shards = jnp.asarray(v_shards, jnp.float32)
        else:
            v_shards = stage(v_shards)
        donate = bool(donate)
        if donate and donate not in jits:
            jits[True] = jax.jit(traced, donate_argnums=(0,))
        fn = jits[donate]
        vals = val_fetch() if val_fetch is not None else ()
        if fault_fetch is not None:
            spec_np = np.asarray(fault_fetch())
            spec_arg = (jnp.asarray(spec_np, jnp.int32) if stage is None
                        else stage(spec_np, np.int32))
            if v_shards.ndim == 3:
                w, chk, abft = fn(v_shards[..., None], spec_arg, *vals)
                return w[..., 0], chk, abft
            return fn(v_shards, spec_arg, *vals)
        if v_shards.ndim == 3:
            return fn(v_shards[..., None], *vals)[..., 0]
        return fn(v_shards, *vals)

    run.local_compute = fmt
    run.integrity = fault_fetch is not None
    # jitted 4-D entry, exposed for jaxpr/HLO checks — keeps the
    # single-argument contract by binding the current value arrays.
    if fault_fetch is not None:
        run.run4 = lambda v_shards: jits[False](
            v_shards, jnp.asarray(np.asarray(fault_fetch()), jnp.int32),
            *(val_fetch() if val_fetch is not None else ()))
    elif val_fetch is None:
        run.run4 = jits[False]
    else:
        run.run4 = lambda v_shards: jits[False](v_shards, *val_fetch())
    run.n_traces = lambda: counter["n"]
    return run


def _bind_shard_program(smapped, compiled, names: List[str],
                        with_fault: bool = False):
    """(call4, val_fetch) for a shard program applied as
    ``smapped(v_shards, *[arrays[k] for k in names])``.

    Structural arrays (gather/scatter maps, column indices) bind as
    closure constants — they are immutable for the life of the plan.
    :data:`VALUE_ARRAY_NAMES` entries instead arrive through ``val_fetch``
    as per-call jit arguments read off the LIVE compiled plan, so
    ``swap_values`` takes effect on the next call without retracing.
    ``with_fault`` inserts the integrity fault-spec as the second
    positional argument (the instrumented-program calling convention).

    Multi-process jobs pass EVERY named array as an argument instead:
    jax forbids closing over a ``jax.Array`` that spans non-addressable
    devices, and the plan's device buffers are global under a
    ``jax.distributed`` mesh.  The single-process split is unchanged.
    """
    from repro.mesh.buffers import is_multiprocess
    dev = compiled.device_arrays()
    if is_multiprocess():
        val_names = list(names)
    else:
        val_names = [k for k in names if k in VALUE_ARRAY_NAMES]
    struct = {k: dev[k] for k in names if k not in val_names}

    if with_fault:
        def call4(v_shards, fault_spec, *vals):
            by = dict(zip(val_names, vals))
            return smapped(v_shards, fault_spec,
                           *[by[k] if k in by else struct[k] for k in names])
    else:
        def call4(v_shards, *vals):
            by = dict(zip(val_names, vals))
            return smapped(v_shards, *[by[k] if k in by else struct[k]
                                       for k in names])

    def val_fetch():
        d = compiled.device_arrays()
        return tuple(d[k] for k in val_names)

    return call4, val_fetch


# ---------------------------------------------------------------------------
# NAP executor
# ---------------------------------------------------------------------------

def nap_forward_shardmap(compiled: CompiledNAP, mesh: Mesh,
                         local_compute: str = "auto", nv_block: int = 128,
                         interpret: bool = True, materialize_x: bool = False,
                         integrity: bool = False, fault_fetch=None):
    """Build the jitted shard_map NAPSpMV: f(v_shards) -> w_shards.

    ``v_shards`` is [n_nodes, ppn, cols_pad] or [n_nodes, ppn, cols_pad, nv]
    (multi-RHS SpMM) — COLUMN-partition packed; the output is ROW-partition
    packed [n_nodes, ppn, rows_pad(, nv)] (identical shapes in the square
    single-partition case).  ``local_compute`` selects the
    local kernel: ``"auto"`` (default) defers to the compile-time format
    autotuner, ``"bsr"`` / ``"ell"`` force the fused Pallas kernels and
    ``"coo"`` the scalar segment_sum reference.  The resolved format is
    exposed as ``run.local_compute``.  ``materialize_x=True`` re-enables
    the legacy HBM pad/concat of the packed x operand (bit-for-bit equal
    to the default zero-copy gather; kept as an A/B oracle).

    ``integrity=True`` builds the INSTRUMENTED program instead: every
    message payload is checksummed by the sender before the scripted
    fault boundary (the checksum words travel through a second tiny
    all_to_all over the same axis) and re-checksummed by the receiver,
    the armed fault-spec argument (``fault_fetch``) is applied as a pure
    transform at the pack boundary, and the ABFT triple
    ``(sum(y_p), c_p · x_packed, |c_p| · |x_packed|)`` is emitted per
    device — ``run`` then returns ``(w_shards, chk, abft)``.  With
    ``integrity=False`` the emitted program is bit-for-bit the
    uninstrumented one (no extra arguments, outputs, or ops).
    """
    fmt = compiled.resolve_local_compute(local_compute)
    if fmt == "bsr":
        compiled.ensure_fused()
    elif fmt == "ell":
        compiled.ensure_ell()
    topo = compiled.topo
    rows_pad = compiled.rows_pad
    bn = compiled.block_shape[1]
    cols_pad, bnode_pad = compiled.cols_pad, compiled.pads["bnode"]
    # multistep plans add the fifth "direct" exchange; with comm="nap"
    # every ms branch below is dead at trace time and the emitted program
    # is bit-for-bit the single-step one.
    ms = compiled.comm == "multistep"
    ph = phase_index("multistep" if ms else "nap")
    msg_phases = MULTISTEP_MESSAGE_PHASES if ms else NAP_MESSAGE_PHASES
    max_slots = topo.n_procs if ms else max(topo.ppn, topo.n_nodes)
    if integrity:
        compiled.ensure_abft()

    def per_device(v_loc, *args):
        squeeze = lambda x: x.reshape(x.shape[2:])
        if integrity:
            fault_spec = squeeze(args[0])                   # [n_phases, 4]
            args = args[1:]
        v_loc = squeeze(v_loc)                              # [rows_pad, nv]
        (full_send, init_send, final_send, inter_gather, bnode_gather,
         boff_gather) = map(squeeze, args[:6])
        direct_send = squeeze(args[6]) if ms else None
        tail = tuple(map(squeeze, args[7 if ms else 6:]))
        if integrity:
            abft_col, abft_abs = tail[-2:]
            tail = tail[:-2]
        nv = v_loc.shape[-1]

        chks = {}

        def exchange(buf, phase, axis):
            # Sender checksums the CLEAN payload, the scripted fault (if
            # armed for this device+phase) corrupts it at the pack
            # boundary, then payload and checksum words travel through
            # the same collective; the receiver recomputes.  Uninstrumented
            # (integrity=False) this is literally the bare all_to_all.
            if not integrity:
                return jax.lax.all_to_all(buf, axis, 0, 0, tiled=True)
            sent = _msg_checksums(buf)
            buf = _apply_fault(buf, fault_spec[ph[phase]])
            recv = jax.lax.all_to_all(buf, axis, 0, 0, tiled=True)
            expect = jax.lax.all_to_all(sent[:, None], axis, 0, 0,
                                        tiled=True)[:, 0]
            chks[phase] = (expect, _msg_checksums(recv))
            return recv

        # Phase A+B (overlap in Alg. 3): intra-node exchanges over "proc".
        full_out = v_loc[full_send]                       # [ppn, full_pad, nv]
        full_recv = exchange(full_out, "full", "proc")
        init_out = v_loc[init_send]
        init_recv = exchange(init_out, "init", "proc")

        # Phase C: ONE aggregated inter-node all-to-all over "node".
        staged = jnp.concatenate([v_loc, init_recv.reshape(-1, nv)])
        inter_out = staged[inter_gather]                  # [n_nodes, inter_pad, nv]
        inter_recv = exchange(inter_out, "inter", "node")

        # Phase D: intra-node scatter of received off-node data.
        inter_flat = inter_recv.reshape(-1, nv)
        final_out = inter_flat[final_send]
        final_recv = exchange(final_out, "final", "proc")

        # Buffers of Algorithm 3's three local_spmv calls.
        bnode = full_recv.reshape(-1, nv)[bnode_gather]   # [bnode_pad, nv]
        boff_parts = [inter_flat, final_recv.reshape(-1, nv)]
        if ms:
            # Phase E (multistep only): the low-duplication columns ship
            # owner -> requester in one flat exchange, bypassing the
            # aggregation; boff_gather resolves against all three buffers.
            direct_out = v_loc[direct_send]           # [n_procs, direct_pad, nv]
            direct_recv = exchange(direct_out, "direct", ("node", "proc"))
            boff_parts.append(direct_recv.reshape(-1, nv))
        boff = jnp.concatenate(boff_parts)[boff_gather]

        if fmt == "bsr":
            fused_cols, fused_blocks = tail
            # segment lengths are bn-aligned at compile time: the three
            # buffers ARE the packed x domain — no pad/concat round-trip.
            if materialize_x:
                x_cat = jnp.concatenate([v_loc, bnode, boff]).reshape(-1, bn, nv)
                w_tiles = fused_bsr_spmm(fused_cols, fused_blocks, x_cat,
                                         nv_block=nv_block, interpret=interpret)
            else:
                xs = tuple(seg.reshape(-1, bn, nv)
                           for seg in (v_loc, bnode, boff))
                w_tiles = fused_bsr_spmm_packed(fused_cols, fused_blocks, xs,
                                                nv_block=nv_block,
                                                interpret=interpret)
            w = w_tiles.reshape(-1, nv)[:rows_pad]
        elif fmt == "ell":
            ell_cols, ell_vals = tail
            xs = ((jnp.concatenate([v_loc, bnode, boff]),) if materialize_x
                  else (v_loc, bnode, boff))
            w = ell_spmm_packed(ell_cols, ell_vals, xs,
                                nv_block=nv_block, interpret=interpret)
        else:
            (on_proc_rows, on_proc_cols, on_proc_vals,
             on_node_rows, on_node_cols, on_node_vals,
             off_node_rows, off_node_cols, off_node_vals) = tail
            # local_spmv(A_on_process, v) — no communication needed (Alg. 3).
            w = segment_sum(on_proc_vals[:, None] * v_loc[on_proc_cols],
                            on_proc_rows, num_segments=rows_pad)
            # local_spmv(A_on_node, b_l->l)
            w = w + segment_sum(on_node_vals[:, None] * bnode[on_node_cols],
                                on_node_rows, num_segments=rows_pad)
            # local_spmv(A_off_node, b_nl->l)
            w = w + segment_sum(off_node_vals[:, None] * boff[off_node_cols],
                                off_node_rows, num_segments=rows_pad)
        if not integrity:
            return w.reshape(1, 1, rows_pad, -1)
        # Scripted compute-side corruption (what ABFT exists to catch) is
        # applied to the LOCAL result, after the wire but before the check.
        w = _apply_fault(w[None], fault_spec[ph["compute"]])[0]
        # ABFT: sum(y_p) vs c_p · x_packed over the SAME received buffers
        # the compute consumed, plus the |c_p|·|x| tolerance scale.
        d = (abft_col[:cols_pad] @ v_loc
             + abft_col[cols_pad: cols_pad + bnode_pad] @ bnode
             + abft_col[cols_pad + bnode_pad:] @ boff)
        s = (abft_abs[:cols_pad] @ jnp.abs(v_loc)
             + abft_abs[cols_pad: cols_pad + bnode_pad] @ jnp.abs(bnode)
             + abft_abs[cols_pad + bnode_pad:] @ jnp.abs(boff))
        abft = jnp.stack([jnp.sum(w, axis=0), d, s])
        chk = _stack_chk([chks[p] for p in msg_phases], max_slots)
        return (w.reshape(1, 1, rows_pad, -1),
                chk.reshape((1, 1) + chk.shape),
                abft.reshape((1, 1) + abft.shape))

    names = ["full_send", "init_send", "final_send", "inter_gather",
             "bnode_gather", "boff_gather"]
    if ms:
        names.insert(6, "direct_send")
    if fmt == "bsr":
        names += ["fused_cols", "fused_blocks"]
    elif fmt == "ell":
        names += ["ell_cols", "ell_vals"]
    else:
        names += ["on_proc_rows", "on_proc_cols", "on_proc_vals",
                  "on_node_rows", "on_node_cols", "on_node_vals",
                  "off_node_rows", "off_node_cols", "off_node_vals"]
    if integrity:
        names += ["abft_col", "abft_col_abs"]
    spec = P("node", "proc")
    n_in = 1 + len(names) + (1 if integrity else 0)
    smapped = shard_map(per_device, mesh=mesh,
                        in_specs=(spec,) * n_in,
                        out_specs=(spec, spec, spec) if integrity else spec,
                        check_vma=False)
    call4, val_fetch = _bind_shard_program(smapped, compiled, names,
                                           with_fault=integrity)
    from repro.mesh.buffers import input_stager
    return _make_run(call4, fmt, val_fetch,
                     fault_fetch=fault_fetch if integrity else None,
                     stage=input_stager(compiled.topo))


def nap_transpose_shardmap(compiled: CompiledNAP, mesh: Mesh,
                           local_compute: str = "auto", nv_block: int = 128,
                           interpret: bool = True,
                           integrity: bool = False, fault_fetch=None):
    """Build the jitted shard_map transpose NAPSpMV: f(u_shards) -> z_shards
    with ``z = A.T u`` — the exact adjoint of :func:`nap_forward_shardmap`.

    ``u_shards`` is ROW-partition packed ([.., rows_pad(, nv)]); the
    output is COLUMN-partition packed ([.., cols_pad(, nv)]) — for the
    square single-partition case the two coincide and this is invisible.

    The forward program is reversed operation by operation: the three
    local_spmv blocks run transposed first (producing per-buffer
    contribution vectors), then each communication phase runs backwards —
    final, inter, init, full — with every forward gather map reused as a
    scatter-add map and every ``all_to_all`` re-applied (a tiled
    all_to_all is an involution and its own adjoint).

    Transposed local compute runs through the adaptive engine like the
    forward direction: ``"auto"`` resolves against the transpose verdict
    recorded on ``compiled.autotune["transpose"]`` (argmin of ell/coo —
    there is no transposed Pallas BSR kernel, so a ``"bsr"`` request also
    defers to that verdict).  ``"ell"`` runs A_r^T as ONE Pallas ELL SpMM
    over the packed contribution domain ``[z | c_on_node | c_off_node]``;
    ``"coo"`` is the scalar segment_sum scatter reference.
    """
    fmt = compiled.resolve_transpose_local_compute(local_compute)
    if fmt == "ell":
        compiled.ensure_ell_t()
    topo = compiled.topo
    rows_pad, cols_pad = compiled.rows_pad, compiled.cols_pad
    pads = compiled.pads
    nn, ppn = topo.n_nodes, topo.ppn
    n_procs = topo.n_procs
    full_pad, init_pad = pads["full"], pads["init"]
    inter_pad, final_pad = pads["inter"], pads["final"]
    bnode_pad, boff_pad = pads["bnode"], pads["boff"]
    # see nap_forward_shardmap: with comm="nap" the ms branches are dead
    # at trace time and the program is bit-for-bit the single-step one.
    ms = compiled.comm == "multistep"
    direct_pad = pads.get("direct", 0)
    ph = phase_index("multistep" if ms else "nap")
    msg_phases = MULTISTEP_MESSAGE_PHASES if ms else NAP_MESSAGE_PHASES
    max_slots = n_procs if ms else max(ppn, nn)
    if integrity:
        compiled.ensure_abft()

    def per_device(u_loc, *args):
        squeeze = lambda x: x.reshape(x.shape[2:])
        if integrity:
            fault_spec = squeeze(args[0])                   # [n_phases, 4]
            args = args[1:]
        u_loc = squeeze(u_loc)                              # [rows_pad, nv]
        (full_send, init_send, final_send, inter_gather, bnode_gather,
         boff_gather) = map(squeeze, args[:6])
        direct_send = squeeze(args[6]) if ms else None
        tail = tuple(map(squeeze, args[7 if ms else 6:]))
        if integrity:
            abft_row, abft_abs = tail[-2:]
            tail = tail[:-2]
        nv = u_loc.shape[-1]

        chks = {}

        def exchange(buf, phase, axis):
            # Reverse-direction twin of the forward builder's exchange():
            # checksum the clean pre-exchange contribution buffer, apply
            # the armed fault at the pack boundary, verify post-delivery.
            if not integrity:
                return jax.lax.all_to_all(buf, axis, 0, 0, tiled=True)
            sent = _msg_checksums(buf)
            buf = _apply_fault(buf, fault_spec[ph[phase]])
            recv = jax.lax.all_to_all(buf, axis, 0, 0, tiled=True)
            expect = jax.lax.all_to_all(sent[:, None], axis, 0, 0,
                                        tiled=True)[:, 0]
            chks[phase] = (expect, _msg_checksums(recv))
            return recv

        # -- transposed local_spmv blocks: rows index u, cols index the
        #    output domain of each block (local x rows / buffer slots).
        if fmt == "ell":
            ell_t_cols, ell_t_vals = tail
            contrib = ell_spmm_packed(ell_t_cols, ell_t_vals, (u_loc,),
                                      nv_block=nv_block, interpret=interpret)
            z = contrib[:cols_pad]
            c_node = contrib[cols_pad: cols_pad + bnode_pad]
            c_off = contrib[cols_pad + bnode_pad:]
        else:
            (on_proc_rows, on_proc_cols, on_proc_vals,
             on_node_rows, on_node_cols, on_node_vals,
             off_node_rows, off_node_cols, off_node_vals) = tail
            z = segment_sum(on_proc_vals[:, None] * u_loc[on_proc_rows],
                            on_proc_cols, num_segments=cols_pad)
            c_node = segment_sum(on_node_vals[:, None] * u_loc[on_node_rows],
                                 on_node_cols, num_segments=bnode_pad)
            c_off = segment_sum(off_node_vals[:, None] * u_loc[off_node_rows],
                                off_node_cols, num_segments=boff_pad)

        if integrity:
            # Compute-side fault + transpose ABFT over the packed
            # contribution domain, BEFORE any communication: the sum of
            # every local contribution equals the row-sum vector (A_p 1)
            # dotted with u_loc.
            packed_c = jnp.concatenate([z, c_node, c_off])
            packed_c = _apply_fault(packed_c[None],
                                    fault_spec[ph["compute"]])[0]
            abft = jnp.stack([jnp.sum(packed_c, axis=0),
                              abft_row @ u_loc,
                              abft_abs @ jnp.abs(u_loc)])
            z = packed_c[:cols_pad]
            c_node = packed_c[cols_pad: cols_pad + bnode_pad]
            c_off = packed_c[cols_pad + bnode_pad:]

        # -- reverse of boff = concat(inter | final [| direct])[boff_gather]
        comb = segment_sum(
            c_off, boff_gather,
            num_segments=(nn * inter_pad + ppn * final_pad
                          + (n_procs * direct_pad if ms else 0)))
        inter_c = comb[: nn * inter_pad]
        final_recv_c = comb[nn * inter_pad: nn * inter_pad + ppn * final_pad
                            ].reshape(ppn, final_pad, nv)
        z_direct = None
        if ms:
            # -- reverse phase E: direct contributions ride the adjoint flat
            #    all_to_all straight back and scatter into the owners' rows.
            direct_recv_c = comb[nn * inter_pad + ppn * final_pad:
                                 ].reshape(n_procs, direct_pad, nv)
            direct_out_c = exchange(direct_recv_c, "direct", ("node", "proc"))
            z_direct = segment_sum(direct_out_c.reshape(-1, nv),
                                   direct_send.reshape(-1),
                                   num_segments=cols_pad)

        # -- reverse phase D: adjoint all_to_all + scatter over final_send
        final_out_c = exchange(final_recv_c, "final", "proc")
        inter_c = inter_c + segment_sum(final_out_c.reshape(-1, nv),
                                        final_send.reshape(-1),
                                        num_segments=nn * inter_pad)

        # -- reverse phase C: adjoint inter-node all_to_all + scatter over
        #    inter_gather into the staged domain concat(v_loc, init_recv)
        inter_out_c = exchange(inter_c.reshape(nn, inter_pad, nv),
                               "inter", "node")
        staged_c = segment_sum(inter_out_c.reshape(-1, nv),
                               inter_gather.reshape(-1),
                               num_segments=cols_pad + ppn * init_pad)
        z = z + staged_c[:cols_pad]

        # -- reverse phase B: init redistribution back to the owners
        init_recv_c = staged_c[cols_pad:].reshape(ppn, init_pad, nv)
        init_out_c = exchange(init_recv_c, "init", "proc")
        z = z + segment_sum(init_out_c.reshape(-1, nv),
                            init_send.reshape(-1), num_segments=cols_pad)

        # -- reverse phase A: on-node buffer contributions back to owners
        full_recv_c = segment_sum(c_node, bnode_gather,
                                  num_segments=ppn * full_pad)
        full_out_c = exchange(full_recv_c.reshape(ppn, full_pad, nv),
                              "full", "proc")
        z = z + segment_sum(full_out_c.reshape(-1, nv),
                            full_send.reshape(-1), num_segments=cols_pad)
        if ms:
            z = z + z_direct
        if not integrity:
            return z.reshape(1, 1, cols_pad, -1)
        chk = _stack_chk([chks[p] for p in msg_phases], max_slots)
        return (z.reshape(1, 1, cols_pad, -1),
                chk.reshape((1, 1) + chk.shape),
                abft.reshape((1, 1) + abft.shape))

    names = ["full_send", "init_send", "final_send", "inter_gather",
             "bnode_gather", "boff_gather"]
    if ms:
        names.insert(6, "direct_send")
    if fmt == "ell":
        names += ["ell_t_cols", "ell_t_vals"]
    else:
        names += ["on_proc_rows", "on_proc_cols", "on_proc_vals",
                  "on_node_rows", "on_node_cols", "on_node_vals",
                  "off_node_rows", "off_node_cols", "off_node_vals"]
    if integrity:
        names += ["abft_row", "abft_row_abs"]
    spec = P("node", "proc")
    n_in = 1 + len(names) + (1 if integrity else 0)
    smapped = shard_map(per_device, mesh=mesh,
                        in_specs=(spec,) * n_in,
                        out_specs=(spec, spec, spec) if integrity else spec,
                        check_vma=False)
    call4, val_fetch = _bind_shard_program(smapped, compiled, names,
                                           with_fault=integrity)
    from repro.mesh.buffers import input_stager
    return _make_run(call4, fmt, val_fetch,
                     fault_fetch=fault_fetch if integrity else None,
                     stage=input_stager(compiled.topo))


# ---------------------------------------------------------------------------
# Standard (Algorithm 1) compiled plan + executors
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompiledStandard:
    """Static arrays for the shard_map standard (Alg. 1) SpMV.

    The packed x domain is two-segment: ``[0, cols_pad) = v_loc`` (the
    COLUMN-partition shard) and ``[cols_pad, cols_pad + buf_pad)`` the
    single off-process recv buffer, both bn-aligned (zero-copy kernel
    domain); the output is ``rows_pad`` ROW-partition rows.  Format
    arrays (COO / ELL / fused BSR over that domain) emit lazily from
    ``per_rank_coo``, exactly like :class:`CompiledNAP`'s.
    """

    topo: Topology
    part: RowPartition
    rows_pad: int
    buf_pad: int
    pair_pad: int
    nnz_pad: int
    block_shape: Tuple[int, int]
    arrays: Dict[str, np.ndarray]          # send_idx, buf_gather + lazy fmts
    per_rank_coo: List[Tuple[np.ndarray, np.ndarray, np.ndarray]]
    col_part: Optional[RowPartition] = None  # None = square (col == row)
    cols_pad: int = 0                        # 0 = square (== rows_pad)
    plan: Optional[StandardPlan] = None
    autotune: Dict[str, object] = dataclasses.field(default_factory=dict)
    requested_local_compute: str = "auto"
    ell_t_kmax: int = 0
    _dev_cache: Dict[str, jnp.ndarray] = dataclasses.field(
        default_factory=_plan_namespace, repr=False, compare=False)
    # see the identically-named CompiledNAP fields (swap_values support)
    a_ref: Optional[CSR] = dataclasses.field(
        default=None, repr=False, compare=False)
    _cache_token: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False)

    def __post_init__(self) -> None:
        if self.col_part is None:
            self.col_part = self.part
        if not self.cols_pad:
            self.cols_pad = self.rows_pad

    @property
    def n_x(self) -> int:
        return self.cols_pad + self.buf_pad

    @property
    def packed_x_len(self) -> int:
        return self.n_x

    @property
    def chosen_local_compute(self) -> str:
        return str(self.autotune.get("chosen", "coo"))

    def resolve_local_compute(self, requested: str) -> str:
        return _resolve_local_compute(requested, self.requested_local_compute,
                                      self.chosen_local_compute)

    def resolve_transpose_local_compute(self, requested: str) -> str:
        """See :meth:`CompiledNAP.resolve_transpose_local_compute`."""
        return _resolve_transpose_local_compute(
            requested, self.requested_local_compute, self.autotune)

    def ensure_coo(self) -> None:
        if "A_rows" in self.arrays:
            return
        self.arrays["A_rows"] = _pad_to(
            [rr.astype(np.int32) for rr, _, _ in self.per_rank_coo],
            self.nnz_pad).astype(np.int32)
        self.arrays["A_cols"] = _pad_to(
            [cc.astype(np.int32) for _, cc, _ in self.per_rank_coo],
            self.nnz_pad).astype(np.int32)
        self.arrays["A_vals"] = _pad_to(
            [vv.astype(np.float32) for _, _, vv in self.per_rank_coo],
            self.nnz_pad, fill=0.0)

    def ensure_ell(self) -> None:
        if "ell_cols" in self.arrays:
            return
        e_cols, e_vals, _ = stack_ell([
            ELL.from_coo(rr, cc, vv, (self.rows_pad, self.n_x),
                         n_rows_pad=self.rows_pad)
            for rr, cc, vv in self.per_rank_coo])
        self.arrays["ell_cols"] = e_cols
        self.arrays["ell_vals"] = e_vals

    def ensure_ell_t(self) -> None:
        """Transposed ELL over the packed contribution domain
        ``[z(cols_pad) | buf]`` with x = u_loc (rows_pad)."""
        if "ell_t_cols" in self.arrays:
            return
        e_cols, e_vals, kmax = stack_ell([
            ELL.from_coo(cc, rr, vv, (self.n_x, self.rows_pad),
                         n_rows_pad=self.n_x)
            for rr, cc, vv in self.per_rank_coo])
        self.arrays["ell_t_cols"] = e_cols
        self.arrays["ell_t_vals"] = e_vals
        self.ell_t_kmax = kmax

    def ensure_fused(self) -> None:
        if "fused_cols" in self.arrays:
            return
        bm, bn = self.block_shape
        f_cols, f_blocks, _ = _stack_padded_bsr([
            BSR.from_coo(rr, cc, vv, (self.rows_pad, self.n_x), bm=bm, bn=bn)
            for rr, cc, vv in self.per_rank_coo])
        self.arrays["fused_cols"] = f_cols
        self.arrays["fused_blocks"] = f_blocks

    def ensure_abft(self) -> None:
        """ABFT checksum vectors over the two-segment packed domain —
        see :meth:`CompiledNAP.ensure_abft` (same contract)."""
        if "abft_col" in self.arrays:
            return
        n, n_x, rows_pad = self.topo.n_procs, self.n_x, self.rows_pad
        col = np.zeros((n, n_x), np.float64)
        cola = np.zeros((n, n_x), np.float64)
        row = np.zeros((n, rows_pad), np.float64)
        rowa = np.zeros((n, rows_pad), np.float64)
        for r, (rr, cc, vv) in enumerate(self.per_rank_coo):
            v32 = vv.astype(np.float32).astype(np.float64)
            np.add.at(col[r], cc, v32)
            np.add.at(cola[r], cc, np.abs(v32))
            np.add.at(row[r], rr, v32)
            np.add.at(rowa[r], rr, np.abs(v32))
        self.arrays["abft_col"] = col.astype(np.float32)
        self.arrays["abft_col_abs"] = cola.astype(np.float32)
        self.arrays["abft_row"] = row.astype(np.float32)
        self.arrays["abft_row_abs"] = rowa.astype(np.float32)

    def device_arrays(self) -> Dict[str, jnp.ndarray]:
        """Mesh-shaped (n_nodes, ppn, ...) device arrays, memoized per name."""
        return _memo_device_arrays(self.topo, self.arrays, self._dev_cache)

    def swap_values(self, a_new: CSR) -> List[str]:
        """Hot-swap matrix VALUES in place; sparsity must be identical.
        See :meth:`CompiledNAP.swap_values` — same contract, over the
        two-segment standard-plan domain (``per_rank_coo`` refreshes and
        every materialised format re-emits against the same pads)."""
        _swap_check_structure(self, a_new)
        blocks = split_all_blocks(a_new, self.part, self.topo,
                                  col_part=self.col_part)
        cols_pad = self.cols_pad
        per_rank_coo = []
        for blk in blocks:   # same packed-column layout as compile_standard
            rr0, cc0, vv0 = blk.on_proc.to_coo()
            rr1, cc1, vv1 = blk.on_node.to_coo()
            rr2, cc2, vv2 = blk.off_node.to_coo()
            rr = np.concatenate([rr0, rr1, rr2])
            cc = np.concatenate([cc0, cols_pad + cc1,
                                 cols_pad + blk.on_node_cols.size + cc2])
            vv = np.concatenate([vv0, vv1, vv2])
            per_rank_coo.append((rr, cc, vv))
        self.per_rank_coo = per_rank_coo
        changed = _swap_refresh_lazy(self, [
            ("A_rows", "A_vals", self.ensure_coo),
            ("ell_cols", "ell_vals", self.ensure_ell),
            ("ell_t_cols", "ell_t_vals", self.ensure_ell_t),
            ("fused_cols", "fused_blocks", self.ensure_fused)])
        changed += _swap_refresh_abft(self)
        _swap_finish(self, a_new, changed)
        return changed


def compile_standard(a: CSR, part: RowPartition, topo: Topology,
                     plan: Optional[StandardPlan] = None,
                     block_shape: Tuple[int, int] = (8, 128),
                     cache: bool = True, local_compute: str = "auto",
                     tuner: LocalComputeParams = TPU_V5E_LOCAL,
                     col_part: Optional[RowPartition] = None) -> CompiledStandard:
    """Compile Algorithm 1's flat plan into static shard_map arrays.

    ``part`` is the ROW partition, ``col_part`` the COLUMN/x partition
    (defaults to ``part`` — the square case; see :func:`compile_nap`).
    """
    if local_compute not in ("auto",) + LOCAL_FORMATS:
        raise ValueError(local_compute)
    cpart = part if col_part is None else col_part
    if part.n_rows != a.shape[0] or cpart.n_rows != a.shape[1]:
        raise ValueError(
            f"partition/matrix mismatch: a is {a.shape}, row partition has "
            f"{part.n_rows} rows, column partition {cpart.n_rows}")
    key = None
    if plan is None and cache:
        key = _cache_key(a, part, topo, block_shape, local_compute, tuner,
                         "standard", col_part=col_part)
        hit = _cache_get(key)
        if hit is not None:
            return hit
    if plan is None:
        plan = build_standard_plan(a.indptr, a.indices, part, topo,
                                   col_part=col_part)
    n_procs = topo.n_procs
    blocks = split_all_blocks(a, part, topo, col_part=cpart)
    local_index = cpart.local_index()
    bm, bn = block_shape
    if bn % 8 != 0:
        raise ValueError(f"bn must be a multiple of the 8-wide sublane "
                         f"tile, got {bn}")
    # bn-aligned segments: [0, cols_pad) = v_loc (column-partition shard),
    # [cols_pad, cols_pad+buf_pad) = the single off-process recv buffer
    # (zero-copy kernel domain); rows_pad is the row-partition output pad.
    rows_pad = _ceil_to(max(1, int(part.counts().max())), bn)
    cols_pad = _ceil_to(max(1, int(cpart.counts().max())), bn)
    buf_pad = _ceil_to(
        max(1, max(b.on_node_cols.size + b.off_node_cols.size for b in blocks)),
        bn)
    pair_pad = max(1, max((m.size for msgs in plan.sends for m in msgs), default=1))

    send_idx = np.zeros((n_procs, n_procs, pair_pad), dtype=np.int32)
    for r in range(n_procs):
        for m in plan.sends[r]:
            send_idx[r, m.dst, : m.size] = local_index[m.idx]

    nnz_pad = max(1, max(b.on_node.nnz + b.off_node.nnz + b.on_proc.nnz
                         for b in blocks))

    # --- packed two-segment domain [v_loc | buf] + format decision --------
    n_x = cols_pad + buf_pad
    per_rank_coo = []
    buf_gather = np.zeros((n_procs, buf_pad), dtype=np.int32)
    for r in range(n_procs):
        blk = blocks[r]
        cols_all = np.concatenate([blk.on_node_cols, blk.off_node_cols])
        buf_gather[r, : cols_all.size] = lookup_slots(
            plan.recv_slot_map(r, pair_pad), cols_all)
        rr0, cc0, vv0 = blk.on_proc.to_coo()
        rr1, cc1, vv1 = blk.on_node.to_coo()
        rr2, cc2, vv2 = blk.off_node.to_coo()
        rr = np.concatenate([rr0, rr1, rr2])
        cc = np.concatenate([cc0, cols_pad + cc1,
                             cols_pad + blk.on_node_cols.size + cc2])
        vv = np.concatenate([vv0, vv1, vv2])
        per_rank_coo.append((rr, cc, vv))
    autotune = _format_stats_from_coo(
        [(rr, cc) for rr, cc, _ in per_rank_coo], rows_pad, n_x,
        nnz_pad, (bm, bn), tuner)
    autotune["transpose"] = _transpose_format_stats(
        [(cc, rr) for rr, cc, _ in per_rank_coo], n_x, rows_pad,
        nnz_pad, (bm, bn), tuner)
    compiled = CompiledStandard(
        topo=topo, part=part, col_part=cpart, rows_pad=rows_pad,
        cols_pad=cols_pad, buf_pad=buf_pad,
        pair_pad=pair_pad, nnz_pad=nnz_pad, block_shape=tuple(block_shape),
        arrays=dict(send_idx=send_idx, buf_gather=buf_gather),
        per_rank_coo=per_rank_coo, plan=plan, autotune=autotune,
        requested_local_compute=local_compute, a_ref=a, _cache_token=key)
    if key is not None:
        _cache_put(key, compiled)
    return compiled


def standard_forward_shardmap(compiled: CompiledStandard, mesh: Mesh,
                              local_compute: str = "auto",
                              nv_block: int = 128, interpret: bool = True,
                              materialize_x: bool = False,
                              integrity: bool = False, fault_fetch=None):
    """Algorithm 1 as a flat padded all-to-all over ("node","proc").

    Local compute runs through the same adaptive engine as the NAP path —
    ``"auto"`` (default) picks bsr/ell/coo from the format cost model over
    the two-segment ``[v_loc | recv buffer]`` packed x domain; both Pallas
    paths read the segments zero-copy.  The resolved format is exposed as
    ``run.local_compute``.  ``integrity=True`` instruments the single
    ``pair`` exchange + ABFT exactly like :func:`nap_forward_shardmap`.
    """
    fmt = compiled.resolve_local_compute(local_compute)
    {"coo": compiled.ensure_coo, "ell": compiled.ensure_ell,
     "bsr": compiled.ensure_fused}[fmt]()
    topo = compiled.topo
    rows_pad, cols_pad = compiled.rows_pad, compiled.cols_pad
    bn = compiled.block_shape[1]
    ph = phase_index("standard")
    if integrity:
        compiled.ensure_abft()

    def per_device(v_loc, *args):
        squeeze = lambda x: x.reshape(x.shape[2:])
        if integrity:
            fault_spec = squeeze(args[0])                   # [n_phases, 4]
            args = args[1:]
        v_loc, send_idx, buf_gather = map(squeeze, (v_loc,) + args[:2])
        tail = tuple(map(squeeze, args[2:]))
        if integrity:
            abft_col, abft_abs = tail[-2:]
            tail = tail[:-2]
        nv = v_loc.shape[-1]
        out = v_loc[send_idx]                               # [n_procs, pair_pad, nv]
        if integrity:
            sent = _msg_checksums(out)
            out = _apply_fault(out, fault_spec[ph["pair"]])
        recv = jax.lax.all_to_all(out, ("node", "proc"), 0, 0, tiled=True)
        if integrity:
            expect = jax.lax.all_to_all(sent[:, None], ("node", "proc"),
                                        0, 0, tiled=True)[:, 0]
            chk_pair = (expect, _msg_checksums(recv))
        buf = recv.reshape(-1, nv)[buf_gather]              # [buf_pad, nv]
        if fmt == "bsr":
            fused_cols, fused_blocks = tail
            if materialize_x:
                x_cat = jnp.concatenate([v_loc, buf]).reshape(-1, bn, nv)
                w_tiles = fused_bsr_spmm(fused_cols, fused_blocks, x_cat,
                                         nv_block=nv_block, interpret=interpret)
            else:
                w_tiles = fused_bsr_spmm_packed(
                    fused_cols, fused_blocks,
                    (v_loc.reshape(-1, bn, nv), buf.reshape(-1, bn, nv)),
                    nv_block=nv_block, interpret=interpret)
            w = w_tiles.reshape(-1, nv)[:rows_pad]
        elif fmt == "ell":
            ell_cols, ell_vals = tail
            xs = ((jnp.concatenate([v_loc, buf]),) if materialize_x
                  else (v_loc, buf))
            w = ell_spmm_packed(ell_cols, ell_vals, xs,
                                nv_block=nv_block, interpret=interpret)
        else:
            A_rows, A_cols, A_vals = tail
            full = jnp.concatenate([v_loc, buf])
            w = segment_sum(A_vals[:, None] * full[A_cols], A_rows,
                            num_segments=rows_pad)
        if not integrity:
            return w.reshape(1, 1, rows_pad, -1)
        w = _apply_fault(w[None], fault_spec[ph["compute"]])[0]
        d = abft_col[:cols_pad] @ v_loc + abft_col[cols_pad:] @ buf
        s = (abft_abs[:cols_pad] @ jnp.abs(v_loc)
             + abft_abs[cols_pad:] @ jnp.abs(buf))
        abft = jnp.stack([jnp.sum(w, axis=0), d, s])
        chk = _stack_chk([chk_pair], topo.n_procs)
        return (w.reshape(1, 1, rows_pad, -1),
                chk.reshape((1, 1) + chk.shape),
                abft.reshape((1, 1) + abft.shape))

    names = ["send_idx", "buf_gather"]
    names += {"bsr": ["fused_cols", "fused_blocks"],
              "ell": ["ell_cols", "ell_vals"],
              "coo": ["A_rows", "A_cols", "A_vals"]}[fmt]
    if integrity:
        names += ["abft_col", "abft_col_abs"]
    spec = P("node", "proc")
    n_in = 1 + len(names) + (1 if integrity else 0)
    smapped = shard_map(per_device, mesh=mesh,
                        in_specs=(spec,) * n_in,
                        out_specs=(spec, spec, spec) if integrity else spec,
                        check_vma=False)
    call4, val_fetch = _bind_shard_program(smapped, compiled, names,
                                           with_fault=integrity)
    from repro.mesh.buffers import input_stager
    return _make_run(call4, fmt, val_fetch,
                     fault_fetch=fault_fetch if integrity else None,
                     stage=input_stager(compiled.topo))


def standard_transpose_shardmap(compiled: CompiledStandard, mesh: Mesh,
                                local_compute: str = "auto",
                                nv_block: int = 128, interpret: bool = True,
                                integrity: bool = False, fault_fetch=None):
    """Transpose of Algorithm 1 against the same compiled plan:
    f(u_shards) -> z_shards with ``z = A.T u``.

    ``u_shards`` is ROW-partition packed; the output COLUMN-partition
    packed ([.., cols_pad(, nv)]).  Reverse of
    :func:`standard_forward_shardmap`: the local SpMV runs transposed
    over the packed two-segment domain, buffer contributions scatter back
    through ``buf_gather`` into the recv layout, the flat all_to_all
    re-applies (its own adjoint), and ``send_idx`` scatters the returned
    contributions into the owners' rows.  Transposed local compute runs
    the adaptive engine restricted to ell/coo — ``"auto"`` resolves
    against ``compiled.autotune["transpose"]``, ``"ell"`` runs one Pallas
    ELL SpMM of A_r^T over the packed contribution domain.
    """
    fmt = compiled.resolve_transpose_local_compute(local_compute)
    if fmt == "ell":
        compiled.ensure_ell_t()
    else:
        compiled.ensure_coo()
    topo = compiled.topo
    rows_pad, cols_pad = compiled.rows_pad, compiled.cols_pad
    pair_pad, n_x = compiled.pair_pad, compiled.n_x
    n_procs = topo.n_procs
    ph = phase_index("standard")
    if integrity:
        compiled.ensure_abft()

    def per_device(u_loc, *args):
        squeeze = lambda x: x.reshape(x.shape[2:])
        if integrity:
            fault_spec = squeeze(args[0])                   # [n_phases, 4]
            args = args[1:]
        u_loc, send_idx, buf_gather = map(squeeze, (u_loc,) + args[:2])
        tail = tuple(map(squeeze, args[2:]))
        if integrity:
            abft_row, abft_abs = tail[-2:]
            tail = tail[:-2]
        nv = u_loc.shape[-1]
        # transposed local SpMV over the packed domain [v_loc | buf]
        if fmt == "ell":
            ell_t_cols, ell_t_vals = tail
            c = ell_spmm_packed(ell_t_cols, ell_t_vals, (u_loc,),
                                nv_block=nv_block, interpret=interpret)
        else:
            A_rows, A_cols, A_vals = tail
            c = segment_sum(A_vals[:, None] * u_loc[A_rows], A_cols,
                            num_segments=n_x)
        if integrity:
            # compute fault + transpose ABFT pre-communication (see the
            # NAP transpose builder — same contract)
            c = _apply_fault(c[None], fault_spec[ph["compute"]])[0]
            abft = jnp.stack([jnp.sum(c, axis=0), abft_row @ u_loc,
                              abft_abs @ jnp.abs(u_loc)])
        z = c[:cols_pad]
        # reverse of buf = recv.reshape(-1)[buf_gather]
        recv_c = segment_sum(c[cols_pad:], buf_gather,
                             num_segments=n_procs * pair_pad)
        out = recv_c.reshape(n_procs, pair_pad, nv)
        if integrity:
            sent = _msg_checksums(out)
            out = _apply_fault(out, fault_spec[ph["pair"]])
        out_c = jax.lax.all_to_all(out, ("node", "proc"), 0, 0, tiled=True)
        if integrity:
            expect = jax.lax.all_to_all(sent[:, None], ("node", "proc"),
                                        0, 0, tiled=True)[:, 0]
            chk_pair = (expect, _msg_checksums(out_c))
        # reverse of out = v_loc[send_idx]
        z = z + segment_sum(out_c.reshape(-1, nv), send_idx.reshape(-1),
                            num_segments=cols_pad)
        if not integrity:
            return z.reshape(1, 1, cols_pad, -1)
        chk = _stack_chk([chk_pair], n_procs)
        return (z.reshape(1, 1, cols_pad, -1),
                chk.reshape((1, 1) + chk.shape),
                abft.reshape((1, 1) + abft.shape))

    names = ["send_idx", "buf_gather"]
    names += (["ell_t_cols", "ell_t_vals"] if fmt == "ell"
              else ["A_rows", "A_cols", "A_vals"])
    if integrity:
        names += ["abft_row", "abft_row_abs"]
    spec = P("node", "proc")
    n_in = 1 + len(names) + (1 if integrity else 0)
    smapped = shard_map(per_device, mesh=mesh,
                        in_specs=(spec,) * n_in,
                        out_specs=(spec, spec, spec) if integrity else spec,
                        check_vma=False)
    call4, val_fetch = _bind_shard_program(smapped, compiled, names,
                                           with_fault=integrity)
    from repro.mesh.buffers import input_stager
    return _make_run(call4, fmt, val_fetch,
                     fault_fetch=fault_fetch if integrity else None,
                     stage=input_stager(compiled.topo))


# ---------------------------------------------------------------------------
# Traffic accounting
# ---------------------------------------------------------------------------

def _phase_lists(compiled) -> Dict[str, Tuple[int, List, List]]:
    """Per message phase: (n_slots per rank, send lists, recv lists).

    Dispatches on the compiled family: NAP phases for ``comm="nap"``,
    NAP + "direct" for ``comm="multistep"``, the single "pair" exchange
    for :class:`CompiledStandard`.  Phases whose plan was dropped (plans
    are optional on a compiled object) are omitted.
    """
    topo = compiled.topo
    if isinstance(compiled, CompiledStandard):
        if compiled.plan is None:
            return {}
        return {"pair": (topo.n_procs, compiled.plan.sends,
                         compiled.plan.recvs)}
    plan = compiled.plan
    if plan is None:
        return {}
    out = {
        "full": (topo.ppn, plan.local_full_sends, plan.local_full_recvs),
        "init": (topo.ppn, plan.local_init_sends, plan.local_init_recvs),
        "inter": (topo.n_nodes, plan.inter_sends, plan.inter_recvs),
        "final": (topo.ppn, plan.local_final_sends, plan.local_final_recvs),
    }
    if getattr(compiled, "comm", "nap") == "multistep" \
            and compiled.ms_plan is not None:
        direct = compiled.ms_plan.direct
        out["direct"] = (topo.n_procs, direct.sends, direct.recvs)
    return out


def padded_traffic(compiled, integrity: str = "off") -> Dict[str, object]:
    """Padded (SPMD-actual) vs effective bytes per phase, float32 payloads.

    Padded bytes are what the static all-to-alls actually move (every rank
    sends its full padded buffer every time); effective bytes are the plan's
    true message payloads — the gap is the padding the paper's T/U balancing
    minimises.  Effective ≤ padded always.

    Works for every compiled family: NAP (full/init/inter/final),
    multistep (+ the "direct" exchange), and standard (the single "pair"
    exchange).  Two per-direction extras ride along:

    * ``{phase}_max_rank_effective`` — the bottleneck rank's true payload
      for the FORWARD program (sender side), with the transpose twins
      (computed from the recv lists, since every message reverses) under
      ``out["transpose"]``.  Phase totals are direction-independent.
    * with ``integrity != "off"``, ``{phase}_checksum`` counts the
      side-channel all_to_all the instrumented program runs per phase
      (one u32 per slot per rank), and ``checksum_total`` sums them —
      the wires the integrity mode adds are not free.
    """
    topo = compiled.topo
    pads = getattr(compiled, "pads", None)
    n = topo.n_procs

    def pad_of(phase: str) -> int:
        if pads is not None:
            return pads[phase]
        return compiled.pair_pad  # CompiledStandard

    out: Dict[str, object] = {}
    transpose: Dict[str, int] = {}
    checksum_total = 0
    for name, (n_slots, sends, recvs) in _phase_lists(compiled).items():
        pad = pad_of(name)
        out[f"{name}_padded"] = n * n_slots * pad * 4
        out[f"{name}_effective"] = 4 * sum(
            m.size for msgs in sends for m in msgs)
        out[f"{name}_max_rank_effective"] = 4 * max(
            (sum(m.size for m in msgs) for msgs in sends), default=0)
        transpose[f"{name}_padded"] = out[f"{name}_padded"]
        transpose[f"{name}_effective"] = 4 * sum(
            m.size for msgs in recvs for m in msgs)
        transpose[f"{name}_max_rank_effective"] = 4 * max(
            (sum(m.size for m in msgs) for msgs in recvs), default=0)
        if integrity != "off":
            chk = n * n_slots * 4
            out[f"{name}_checksum"] = chk
            transpose[f"{name}_checksum"] = chk
            checksum_total += chk
    if integrity != "off":
        out["checksum_total"] = checksum_total
        transpose["checksum_total"] = checksum_total
    out["transpose"] = transpose
    return out
