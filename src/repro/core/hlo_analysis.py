"""HLO-text analyzer: trip-count-aware FLOPs / HBM bytes / collective bytes.

Why not ``compiled.cost_analysis()``: measured on this backend it (a) reports
per-device numbers (fine) but (b) counts a ``while`` body ONCE, ignoring the
trip count — and every model here drives its layers/microbatches through
``lax.scan``.  This parser walks the computation call graph (ENTRY -> while
bodies / calls / fusions / conditionals), multiplying by while trip counts
(recovered from the loop-condition constant), and accumulates:

* ``dot_flops``  — 2 * prod(result dims) * prod(contraction dims) per dot
* ``hbm_bytes``  — operand + result bytes at fusion/op boundaries (a proxy
  for HBM traffic; XLA:TPU fuses elementwise chains, so per-op results at
  computation scope approximate fusion-boundary traffic)
* ``collective_bytes`` by kind (all-reduce / all-gather / reduce-scatter /
  all-to-all / collective-permute), operand bytes, with replica-group sizes
  for ring-wire-byte refinement.

All numbers are PER DEVICE: the compiled module is the SPMD per-device
program.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    type_str: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, Op]
    order: List[str]


def _comp_header_name(stripped: str) -> Optional[str]:
    """'%region_0.2 (arg: (s32[], f32[...])) -> ... {' -> 'region_0.2'."""
    if not (stripped.endswith("{") and "->" in stripped):
        return None
    head = stripped.split("(", 1)[0].strip()
    if head.startswith("ENTRY"):
        head = head[len("ENTRY"):].strip()
    head = head.lstrip("%").strip()
    return head or None
def _parse_op_line(line: str):
    """Paren/comment-aware op parse: handles tuple types with /*index=N*/
    comments (which contain '=' and defeat naive regexes)."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    eq = s.find(" = ")
    if eq < 0 or not (s.startswith("%") or re.match(r"[\w.\-]+ =", s)):
        return None
    name = s[:eq].strip().lstrip("%")
    rest = s[eq + 3:]
    if rest.startswith("("):            # tuple type
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        type_str = rest[:end + 1]
        rest = rest[end + 1:].strip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str = rest[:sp]
        rest = rest[sp + 1:].strip()
    par = rest.find("(")
    if par < 0:
        return None
    kind = rest[:par].strip()
    if not re.fullmatch(r"[\w\-]+", kind):
        return None
    depth = 0
    end = len(rest) - 1
    for i in range(par, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operands = rest[par + 1:end]
    attrs = rest[end + 1:]
    return name, type_str, kind, operands, attrs


def parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            name = _comp_header_name(stripped)
            if name:
                cur = Computation(name, {}, [])
            continue
        if stripped.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        parsed = _parse_op_line(line)
        if parsed is None:
            continue
        name, type_str, kind, operands, attrs = parsed
        ops = [o.strip().lstrip("%").split(" ")[-1].lstrip("%")
               for o in _split_operands(operands)]
        cur.ops[name] = Op(name, kind, type_str.strip(), ops, attrs)
        cur.order.append(name)
    return comps


def _split_operands(s: str) -> List[str]:
    """Split top-level comma-separated operands (parens/braces aware)."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return [o.strip() for o in out if o.strip()]


def _operand_bytes(comp: Computation, op: Op) -> int:
    total = 0
    for o in op.operands:
        target = comp.ops.get(o)
        if target is not None:
            total += _shape_bytes(target.type_str)
        else:
            # parameter operands are written inline: "f32[8,16]{1,0} %param"
            total += _shape_bytes(o)
    return total


def _trip_count(cond: Computation) -> int:
    """Max integer constant in the loop condition (scan: lt(i, N))."""
    best = 1
    for op in cond.ops.values():
        if op.kind == "constant":
            m = re.search(r"constant\((\d+)\)", f"constant({op.operands[0]})"
                          if op.operands else op.attrs)
            if m:
                best = max(best, int(m.group(1)))
            else:
                m2 = re.search(r"(\d+)", op.attrs)
                if m2:
                    best = max(best, int(m2.group(1)))
    return best


def _dot_flops(comp: Computation, op: Op) -> int:
    out_elems = _shape_elems(op.type_str)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    if not m:
        return 2 * out_elems
    lhs_name = op.operands[0]
    lhs = comp.ops.get(lhs_name)
    lhs_type = lhs.type_str if lhs is not None else lhs_name
    sm = _SHAPE_RE.search(lhs_type)
    if not sm:
        return 2 * out_elems
    dims = [int(d) for d in sm.group(2).split(",") if d]
    k = 1
    for i in m.group(1).split(","):
        if i:
            k *= dims[int(i)]
    return 2 * out_elems * k


@dataclasses.dataclass
class HLOCost:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    group_sizes: Dict[str, List[int]] = dataclasses.field(default_factory=dict)
    dci_bytes: float = 0.0     # collectives whose groups cross the pod boundary

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def add(self, other: "HLOCost", mult: float) -> None:
        self.dot_flops += other.dot_flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.dci_bytes += other.dci_bytes * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = (self.collective_counts.get(k, 0.0)
                                         + v * mult)
        for k, v in other.group_sizes.items():
            self.group_sizes.setdefault(k, []).extend(v)


_CALLED = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_V2 = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=(\[[\d,]+\])(?:T\(([\d,]+)\))?")


def _group_size(attrs: str) -> int:
    m = _GROUPS.search(attrs)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_V2.search(attrs)  # [num_groups,group_size] iota form
    if m:
        return int(m.group(2))
    return 0


def _first_group(attrs: str):
    """Device ids of the first replica group (exactly reconstructs the iota
    form: transpose(reshape(iota, dims), perm).reshape(n_groups, size))."""
    m = _GROUPS.search(attrs)
    if m:
        return [int(x) for x in m.group(1).split(",")]
    m = _GROUPS_V2.search(attrs)
    if m:
        import numpy as _np
        ng, size = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).strip("[]").split(",")]
        arr = _np.arange(int(_np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            arr = arr.transpose(perm)
        return arr.reshape(ng, size)[0].tolist()
    return []


_PAIRS = re.compile(r"source_target_pairs=\{\{(\d+),(\d+)\}")


def _crosses_pod(attrs: str, pod_boundary: int) -> bool:
    """Does the first replica group span devices on both sides of the pod
    boundary (device ids are pod-major on the (pod, data, model) mesh)?
    collective-permute carries source_target_pairs instead (a 2-pod
    all-to-all lowers to a permute)."""
    m = _PAIRS.search(attrs)
    if m:
        a, b = int(m.group(1)), int(m.group(2))
        return (a < pod_boundary) != (b < pod_boundary)
    g = _first_group(attrs)
    if not g:
        return False
    return min(g) < pod_boundary <= max(g)


_SKIP_HBM = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "while", "conditional", "call", "after-all", "iota", "copy-start",
             "copy-done"}

# Fusion-boundary HBM model: on TPU, elementwise chains fuse into their
# producers/consumers, so only these op kinds move HBM bytes.  The unfused
# CPU module (which wraps every elementwise op in a kLoop fusion) would
# otherwise claim ~10x the traffic a TPU program performs.  A `fusion` op
# only counts if its computation contains a MAJOR op (dot/gather/scatter/...).
_HBM_KINDS = {"dot", "convolution", "scatter", "gather",
              "dynamic-slice", "dynamic-update-slice", "copy", "concatenate",
              "custom-call", "sort", "cholesky", "triangular-solve"}
_MAJOR_IN_FUSION = {"dot", "convolution", "scatter", "gather",
                    "dynamic-slice", "dynamic-update-slice", "concatenate",
                    "sort"}


def analyze_hlo(text: str, pod_boundary: int = 0) -> HLOCost:
    """pod_boundary: device-id threshold between pods (256 for the 2x16x16
    mesh); 0 disables DCI attribution."""
    comps = parse_computations(text)
    entry = None
    for line in text.splitlines():
        if line.strip().startswith("ENTRY"):
            entry = _comp_header_name(line.strip())
    if entry is None or entry not in comps:
        # fall back to the computation named main*
        entry = next((n for n in comps if n.startswith("main")), None)
        if entry is None:
            raise ValueError("no ENTRY computation found")

    has_major: Dict[str, bool] = {
        name: any(op.kind in _MAJOR_IN_FUSION for op in comp.ops.values())
        for name, comp in comps.items()}

    local: Dict[str, HLOCost] = {}
    edges: Dict[str, List[Tuple[str, float]]] = {}
    for name, comp in comps.items():
        cost = HLOCost()
        edge: List[Tuple[str, float]] = []
        for op in comp.ops.values():
            if op.kind in ("dot", "convolution"):
                cost.dot_flops += _dot_flops(comp, op)
            base_kind = op.kind.replace("-start", "")
            if op.kind.endswith("-done"):
                continue
            if base_kind in COLLECTIVE_KINDS:
                b = _operand_bytes(comp, op)
                cost.collective_bytes[base_kind] = (
                    cost.collective_bytes.get(base_kind, 0.0) + b)
                cost.collective_counts[base_kind] = (
                    cost.collective_counts.get(base_kind, 0.0) + 1)
                g = _group_size(op.attrs)
                if g:
                    cost.group_sizes.setdefault(base_kind, []).append(g)
                if pod_boundary and _crosses_pod(op.attrs, pod_boundary):
                    cost.dci_bytes += b
            # HBM model: count each counted op's RESULT bytes (the write; the
            # consumer's read of it is folded into a 2x at the end), plus dot
            # operand bytes explicitly (weight/activation reads at the MXU
            # boundary, incl. per-layer weight re-reads inside scans).
            count_hbm = op.kind in _HBM_KINDS
            if op.kind == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                count_hbm = bool(m and has_major.get(m.group(1), False))
            if count_hbm:
                cost.hbm_bytes += _shape_bytes(op.type_str)
                if op.kind in ("dot", "convolution"):
                    cost.hbm_bytes += _operand_bytes(comp, op)
            if op.kind == "while":
                bm = re.search(r"body=%?([\w.\-]+)", op.attrs)
                cm = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                if bm and cm and bm.group(1) in comps:
                    km = re.search(r'known_trip_count[":{\s]+n[":\s]+"?(\d+)',
                                   op.attrs)
                    trips = (int(km.group(1)) if km
                             else _trip_count(comps[cm.group(1)]))
                    edge.append((bm.group(1), float(trips)))
                    edge.append((cm.group(1), float(trips)))
            elif op.kind == "conditional":
                bm = _BRANCHES.search(op.attrs)
                if bm:
                    for b in bm.group(1).split(","):
                        edge.append((b.strip().lstrip("%"), 1.0))
                for key in ("true_computation", "false_computation"):
                    m = re.search(rf"{key}=%?([\w.\-]+)", op.attrs)
                    if m:
                        edge.append((m.group(1), 1.0))
            else:
                m = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", op.attrs)
                if m and m.group(1) in comps:
                    # fusions: dots inside count; bytes counted at call site
                    edge.append((m.group(1), 1.0))
        local[name] = cost
        edges[name] = edge

    total = HLOCost()
    _visited_guard = set()

    def visit(name: str, mult: float, stack: Tuple[str, ...]) -> None:
        if name in stack or name not in local:   # cycles impossible, be safe
            return
        total.add(_strip_fusion_bytes(local[name], name), mult)
        for child, m in edges[name]:
            child_mult = mult * m
            if _is_fusion_comp(child):
                # fused computations: count flops but not per-op bytes
                fcost = local.get(child)
                if fcost:
                    fc = HLOCost(dot_flops=fcost.dot_flops)
                    total.add(fc, child_mult)
            else:
                visit(child, child_mult, stack + (name,))

    def _is_fusion_comp(name: str) -> bool:
        return "fused_computation" in name or name.startswith("fused.")

    def _strip_fusion_bytes(cost: HLOCost, name: str) -> HLOCost:
        return cost

    visit(entry, 1.0, ())
    return total


def analyze_compiled(compiled) -> HLOCost:
    return analyze_hlo(compiled.as_text())
