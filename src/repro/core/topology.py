"""Process/node topology: the paper's rank <-> (p, n) machinery (Sec. 2).

A rank r in [0, n_p) is identified with the tuple (p, n) where
``p = r % ppn`` is the local process id and ``n = r // ppn`` the node id
(SMP-style ordering, as assumed in the paper).  On TPU the same object
describes a (pod, chip) hierarchy: ``node`` = pod, ``ppn`` = chips per pod.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np

Rank = int
ProcNode = Tuple[int, int]  # (p, n)


@dataclasses.dataclass(frozen=True)
class Topology:
    """An SMP-ordered machine of ``n_nodes`` nodes with ``ppn`` processes each."""

    n_nodes: int
    ppn: int

    def __post_init__(self) -> None:
        if self.n_nodes < 1 or self.ppn < 1:
            raise ValueError(f"bad topology ({self.n_nodes} nodes x {self.ppn} ppn)")

    @property
    def n_procs(self) -> int:
        return self.n_nodes * self.ppn

    # -- rank <-> (p, n), Sec. 2: r ~ (r mod ppn, floor(r / ppn)) ------------
    def proc_node(self, rank: Rank) -> ProcNode:
        if not 0 <= rank < self.n_procs:
            raise ValueError(f"rank {rank} out of range [0, {self.n_procs})")
        return rank % self.ppn, rank // self.ppn

    def rank(self, p: int, n: int) -> Rank:
        if not (0 <= p < self.ppn and 0 <= n < self.n_nodes):
            raise ValueError(f"({p},{n}) outside ({self.ppn} ppn, {self.n_nodes} nodes)")
        return n * self.ppn + p

    def node_of(self, rank: Rank) -> int:
        return rank // self.ppn

    def local_of(self, rank: Rank) -> int:
        return rank % self.ppn

    def ranks_on_node(self, n: int) -> range:
        return range(n * self.ppn, (n + 1) * self.ppn)

    def same_node(self, r: Rank, t: Rank) -> bool:
        return self.node_of(r) == self.node_of(t)

    def iter_ranks(self) -> Iterator[Rank]:
        return iter(range(self.n_procs))

    # -- vectorised helpers used by comm_graph ------------------------------
    def node_of_array(self, ranks: np.ndarray) -> np.ndarray:
        return np.asarray(ranks) // self.ppn

    def local_of_array(self, ranks: np.ndarray) -> np.ndarray:
        return np.asarray(ranks) % self.ppn


def paper_example_topology() -> Topology:
    """Example 2.1: six processes across three nodes (ppn = 2)."""
    return Topology(n_nodes=3, ppn=2)
