"""Row partitions of an N x N system across n_p ranks (Sec. 2, Eq. 2; Sec. 5).

Three partition kinds from the paper's experiments:

* ``contiguous`` — Eq. (2): rank r owns rows [floor(N/np)*r, floor(N/np)*(r+1))
  (the remainder rows are spread over the first ranks so every row is owned).
* ``strided``    — Sec. 5 SuiteSparse tests: row r lives on process r mod n_p.
* ``balanced``   — graph-partitioned surrogate for PT-Scotch: recursive
  min-degree-cut bisection over the matrix adjacency graph (offline stand-in;
  real deployments plug ParMETIS/PT-Scotch through the same interface).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class RowPartition:
    """Ownership map of N global rows over n_p ranks.

    ``owner[i]``  — rank owning global row i.
    ``perm``      — global rows sorted by (owner, row): the *local* storage
                    order. ``perm[first[r]:first[r+1]]`` are rank r's rows.
    ``first``     — CSR-style offsets into ``perm`` per rank (len n_p + 1).
    """

    n_rows: int
    n_procs: int
    owner: np.ndarray
    perm: np.ndarray
    first: np.ndarray
    kind: str = "contiguous"

    def rows_of(self, rank: int) -> np.ndarray:
        """R(r): global rows stored on ``rank`` (ascending)."""
        return self.perm[self.first[rank] : self.first[rank + 1]]

    def counts(self) -> np.ndarray:
        return np.diff(self.first)

    def local_index(self) -> np.ndarray:
        """global row -> index within its owner's local block."""
        loc = np.empty(self.n_rows, dtype=np.int64)
        for r in range(self.n_procs):
            rows = self.rows_of(r)
            loc[rows] = np.arange(rows.size)
        return loc

    def validate(self) -> None:
        assert self.owner.shape == (self.n_rows,)
        assert self.first.shape == (self.n_procs + 1,)
        assert self.first[0] == 0 and self.first[-1] == self.n_rows
        got = np.sort(self.perm)
        assert np.array_equal(got, np.arange(self.n_rows)), "perm must be a permutation"
        assert np.array_equal(self.owner[self.perm], np.repeat(np.arange(self.n_procs), self.counts()))


def _from_owner(owner: np.ndarray, n_procs: int, kind: str) -> RowPartition:
    n_rows = owner.shape[0]
    perm = np.argsort(owner, kind="stable").astype(np.int64)
    counts = np.bincount(owner, minlength=n_procs)
    first = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    part = RowPartition(n_rows=n_rows, n_procs=n_procs, owner=owner.astype(np.int64),
                        perm=perm, first=first, kind=kind)
    part.validate()
    return part


def contiguous_partition(n_rows: int, n_procs: int) -> RowPartition:
    """Eq. (2) with remainder rows distributed over the leading ranks."""
    base, extra = divmod(n_rows, n_procs)
    counts = np.full(n_procs, base, dtype=np.int64)
    counts[:extra] += 1
    owner = np.repeat(np.arange(n_procs), counts)
    return _from_owner(owner, n_procs, "contiguous")


def strided_partition(n_rows: int, n_procs: int) -> RowPartition:
    """Sec. 5: row r on process r mod n_p."""
    owner = np.arange(n_rows, dtype=np.int64) % n_procs
    return _from_owner(owner, n_procs, "strided")


def balanced_partition(indptr: np.ndarray, indices: np.ndarray, n_procs: int,
                       seed: int = 0, max_iters: int = 8) -> RowPartition:
    """Greedy KL-flavoured recursive bisection (PT-Scotch stand-in).

    Splits the row set in halves minimising cut edges, recursively, until
    n_procs parts exist (n_procs must be a power of two for the recursion;
    otherwise falls back to contiguous on the remainder split).
    """
    n_rows = len(indptr) - 1
    rng = np.random.default_rng(seed)
    owner = np.zeros(n_rows, dtype=np.int64)

    def bisect(rows: np.ndarray, lo: int, hi: int) -> None:
        nparts = hi - lo
        if nparts == 1 or rows.size == 0:
            owner[rows] = lo
            return
        half = nparts // 2
        target_left = rows.size * half // nparts
        # BFS growth from a peripheral seed gives a contiguous-ish half.
        in_set = np.zeros(n_rows, dtype=bool)
        in_set[rows] = True
        side = np.full(n_rows, -1, dtype=np.int8)  # 0 = left, 1 = right
        start = rows[rng.integers(rows.size)]
        frontier = [start]
        side[rows] = 1
        taken = 0
        seen = np.zeros(n_rows, dtype=bool)
        seen[start] = True
        while frontier and taken < target_left:
            nxt = []
            for u in frontier:
                if taken >= target_left:
                    break
                side[u] = 0
                taken += 1
                for v in indices[indptr[u] : indptr[u + 1]]:
                    if in_set[v] and not seen[v]:
                        seen[v] = True
                        nxt.append(v)
            frontier = nxt
        if taken < target_left:  # disconnected: top up arbitrarily
            rest = rows[side[rows] == 1]
            need = target_left - taken
            side[rest[:need]] = 0
        # one pass of boundary refinement (move vertices that reduce cut, keep balance)
        for _ in range(max_iters):
            moved = 0
            for u in rows:
                s = side[u]
                nbr = indices[indptr[u] : indptr[u + 1]]
                nbr = nbr[in_set[nbr]]
                if nbr.size == 0:
                    continue
                same = int(np.sum(side[nbr] == s))
                other = nbr.size - same
                if other > same:
                    cnt_left = int(np.sum(side[rows] == 0))
                    if s == 0 and cnt_left - 1 >= target_left - rows.size // (4 * nparts):
                        side[u] = 1
                        moved += 1
                    elif s == 1 and cnt_left + 1 <= target_left + rows.size // (4 * nparts):
                        side[u] = 0
                        moved += 1
            if moved == 0:
                break
        left = rows[side[rows] == 0]
        right = rows[side[rows] == 1]
        bisect(left, lo, lo + half)
        bisect(right, lo + half, hi)

    bisect(np.arange(n_rows, dtype=np.int64), 0, n_procs)
    return _from_owner(owner, n_procs, "balanced")


def survivor_partition(part: RowPartition, dead_ranks) -> RowPartition:
    """Repartition after rank loss (the serve layer's elastic rebuild).

    Surviving ranks KEEP every row they already own — their shards need no
    data motion, only the dead ranks' orphaned rows move.  Per-survivor
    intake counts come from a waterfill (repeatedly topping up the
    lightest survivor; ties break toward the lowest new rank), then the
    orphan rows are dealt out in ascending global order in runs of those
    counts — fully deterministic.  Ranks renumber compactly in surviving
    order, matching ``ElasticPolicy.survivor_topology``'s shrunken
    ``Topology``.
    """
    dead = sorted({int(r) for r in dead_ranks})
    for r in dead:
        if not 0 <= r < part.n_procs:
            raise ValueError(f"dead rank {r} outside [0, {part.n_procs})")
    survivors = [r for r in range(part.n_procs) if r not in set(dead)]
    if not survivors:
        raise ValueError("no surviving ranks to repartition onto")
    n_new = len(survivors)
    remap = np.full(part.n_procs, -1, dtype=np.int64)
    remap[survivors] = np.arange(n_new)
    mapped = remap[part.owner]
    alive = mapped >= 0
    owner = np.empty(part.n_rows, dtype=np.int64)
    owner[alive] = mapped[alive]
    orphans = np.flatnonzero(~alive)
    loads = np.bincount(mapped[alive], minlength=n_new).astype(np.int64)
    add = np.zeros(n_new, dtype=np.int64)
    for _ in range(orphans.size):
        i = int(np.argmin(loads + add))
        add[i] += 1
    owner[orphans] = np.repeat(np.arange(n_new), add)
    return _from_owner(owner, n_new, "elastic")


def make_partition(kind: str, n_rows: int, n_procs: int,
                   indptr: Optional[np.ndarray] = None,
                   indices: Optional[np.ndarray] = None, seed: int = 0) -> RowPartition:
    if kind == "contiguous":
        return contiguous_partition(n_rows, n_procs)
    if kind == "strided":
        return strided_partition(n_rows, n_procs)
    if kind == "balanced":
        if indptr is None or indices is None:
            raise ValueError("balanced partition needs the matrix structure")
        return balanced_partition(indptr, indices, n_procs, seed=seed)
    raise ValueError(f"unknown partition kind {kind!r}")
