"""Pluggable executor registry behind the :class:`repro.api.NapOperator`.

An *executor* binds one (backend, method) pair to a concrete matrix +
layout and exposes the four things the operator front-end needs:

* ``forward(v, donate=False)``  — global ``A @ v`` (1-RHS or multi-RHS)
* ``transpose(u, donate=False)``— global ``A.T @ u`` against the SAME plan
* ``stats()`` / ``cost(machine)`` / ``autotune_report()`` — plan-level
  message statistics, modeled comm time, and the local-format verdict
  (for BOTH directions — the transpose verdict rides along under
  ``"transpose"`` / ``"transpose_resolved"``).

Every executor is built over TWO partitions: ``row_part`` (output
ownership, ``a.shape[0]`` rows) and ``col_part`` (x ownership,
``a.shape[1]`` entries).  Square single-partition operators pass the same
object twice; rectangular AMG P / R operators separate them.  The forward
direction consumes a ``col_part``-owned operand and yields a
``row_part``-owned result; the transpose swaps the two.

Backends registered here:

* ``("shardmap", "nap" | "standard" | "multistep")`` — the jitted SPMD
  executors of :mod:`repro.core.spmv_jax`, sharing ONE packed-x path
  (:func:`pack_vector` / :func:`unpack_vector`) for forward and
  transpose, with lazy per-direction compilation (the transpose program
  is only built when ``op.T`` is first applied).
* ``("simulate", "nap" | "standard" | "multistep")`` — the exact numpy
  message-passing simulators (float64 correctness oracles).
* ``("moe", "flat" | "nap" | "auto")`` — MoE token->expert dispatch over
  a CSR routing matrix ``R [E, T]``: forward is the weighted
  dispatch-sum ``R @ X`` with every x payload quantized to
  ``spec.wire_dtype`` on the wire (f64 accumulation on receive),
  transpose the weighted combine; ``"auto"`` resolves flat-vs-nap PER
  DIRECTION from the modeled injected inter-pod bytes
  (:func:`repro.moe.plan.choose_dispatch`).  Built on the simulate
  mailboxes, so integrity checksums run over the QUANTIZED words.

The comm-strategy subsystem (:mod:`repro.comm`) treats the method as a
pluggable exchange strategy: ``repro.api.operator(comm=...)`` maps a
strategy name onto the method here, and ``comm="auto"`` resolves one per
operator (and per direction) from the modeled injected traffic.

Future backends — a true-TPU Mosaic lowering, the collective-permute
overlap executor of the roadmap's open item (d) — plug in with
``@register_executor("mosaic", "nap")`` and become reachable from every
call site through ``repro.api.operator(..., backend="mosaic")`` without
touching the operator or any ported caller.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.core.comm_graph import (build_nap_plan, build_standard_plan,
                                   nap_stats, standard_stats)
from repro.core.cost_model import (LocalComputeParams, MachineParams,
                                   TPU_V5E_LOCAL, multistep_cost, nap_cost,
                                   standard_cost)
from repro.core.integrity import (IntegrityError, IntegrityState, MessageFault,
                                  SimWire)
from repro.core.partition import RowPartition
from repro.core.spmv import (simulate_nap_spmv, simulate_nap_spmv_transpose,
                             simulate_standard_spmv,
                             simulate_standard_spmv_transpose)
from repro.core.topology import Topology

# NOTE: repro.core.spmv_jax (and thus jax) is imported lazily inside the
# shardmap executors — the simulate backend stays importable and usable on
# a jax-free numpy installation (repro.core.integrity is numpy-only).


@dataclasses.dataclass(frozen=True)
class OperatorSpec:
    """Everything an executor factory needs beyond (a, row/col parts, topo)."""

    method: str = "nap"
    backend: str = "shardmap"
    local_compute: str = "auto"
    pairing: str = "aligned"
    block_shape: Tuple[int, int] = (8, 128)
    nv_block: int = 128
    interpret: bool = True
    cache: bool = True
    tuner: LocalComputeParams = TPU_V5E_LOCAL
    integrity: str = "off"          # "off" | "detect" | "recover"
    # duplication threshold for method="multistep" ("auto" or int >= 1);
    # ignored by the single-strategy methods
    threshold: object = "auto"
    # wire payload encoding for the moe dispatch backend ("f32" | "bf16" |
    # "fp8_e4m3"); "f32" is the identity codec — bit-for-bit today's path
    wire_dtype: str = "f32"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[Tuple[str, str], Callable] = {}


def register_executor(backend: str, method: str):
    """Class/factory decorator: makes ``backend``/``method`` constructible
    through :func:`bind_executor` (and thus ``repro.api.operator``).  A
    factory signature is ``factory(a, row_part, col_part, topo, spec,
    mesh=None)``."""

    def deco(factory):
        _REGISTRY[(backend, method)] = factory
        return factory

    return deco


def available_executors() -> List[Tuple[str, str]]:
    return sorted(_REGISTRY)


def bind_executor(backend: str, method: str, a, row_part: RowPartition,
                  col_part: RowPartition, topo: Topology, spec: OperatorSpec,
                  mesh=None):
    """Instantiate the registered executor for (backend, method)."""
    try:
        factory = _REGISTRY[(backend, method)]
    except KeyError:
        avail = ", ".join(f"{b}/{m}" for b, m in available_executors())
        raise ValueError(
            f"no executor registered for backend={backend!r} "
            f"method={method!r}; available: {avail}") from None
    return factory(a, row_part, col_part, topo, spec, mesh=mesh)


def check_operand(n: int, v: np.ndarray) -> np.ndarray:
    """Shared operand validation: a global [n] vector or [n, nv] multivector."""
    v = np.asarray(v)
    if v.shape[:1] != (n,) or v.ndim > 2:
        raise ValueError(f"operand must be [{n}] or [{n}, nv], got {v.shape}")
    return v


# ---------------------------------------------------------------------------
# shard_map backend (shared packed-x path, lazy per-direction compile)
# ---------------------------------------------------------------------------

class _ShardmapExecutor:
    """Common shard_map plumbing: one pack/unpack path for every method
    and direction; the forward/transpose programs build lazily and are
    memoized per direction.  Forward packs the operand by ``col_part``
    (cols_pad) and unpacks by ``row_part``; transpose swaps both."""

    backend = "shardmap"

    def __init__(self, a, row_part: RowPartition, col_part: RowPartition,
                 topo: Topology, spec: OperatorSpec, mesh=None):
        self.a, self.topo, self.spec = a, topo, spec
        self.row_part, self.col_part = row_part, col_part
        self._mesh = mesh
        self._compiled = None
        self._runs: Dict[str, Callable] = {}
        self._integrity = (IntegrityState(spec.integrity, topo,
                                          type(self).method)
                           if spec.integrity != "off" else None)

    # -- lazy resources ----------------------------------------------------
    @property
    def mesh(self):
        if self._mesh is None:
            # memoized per (n_nodes, ppn) — every executor on the same
            # layout shares one mesh object (repro.mesh.buffers)
            from repro.mesh.buffers import mesh_for
            self._mesh = mesh_for(self.topo)
        return self._mesh

    @property
    def compiled(self):
        if self._compiled is None:
            self._compiled = self._compile()
        return self._compiled

    def _run(self, direction: str) -> Callable:
        if direction not in self._runs:
            self._runs[direction] = self._build(direction)
        return self._runs[direction]

    # -- the ONE packed-x path shared by all shard_map executors -----------
    def _apply(self, direction: str, v: np.ndarray, donate: bool) -> np.ndarray:
        from repro.core.spmv_jax import pack_vector, unpack_vector
        from repro.mesh.buffers import fetch_mesh_array

        c = self.compiled
        if direction == "forward":
            in_part, in_pad, out_part = self.col_part, c.cols_pad, self.row_part
            v = check_operand(self.a.shape[1], v)
        else:
            in_part, in_pad, out_part = self.row_part, c.rows_pad, self.col_part
            v = check_operand(self.a.shape[0], v)
        shards = pack_vector(v, in_part, self.topo, in_pad)
        if self._integrity is not None:
            w = self._apply_verified(direction, shards)
        else:
            w = self._run(direction)(shards, donate=donate)
        # fetch_mesh_array == np.asarray single-process; under a
        # multi-process mesh it gathers the global shards bitwise-exactly
        return unpack_vector(fetch_mesh_array(w), out_part, self.topo)

    def _apply_verified(self, direction: str, shards) -> np.ndarray:
        """Integrity path: arm any scripted faults, run the instrumented
        program (which also returns the wire-checksum and ABFT aux
        outputs), verify on the host, and — under ``"recover"`` — retry
        the apply from the RETAINED packed shards with the fault consumed
        (never donated), which reproduces the fault-free result
        bit-for-bit.  Persistent mismatches raise after the retry."""
        from repro.mesh.buffers import fetch_mesh_array
        st = self._integrity
        c = self.compiled
        n_terms = c.rows_pad + c.packed_x_len
        st.counters["applies"] += 1
        st.arm(direction)
        try:
            w, chk, abft = self._run(direction)(shards, donate=False)
            mism = st.verify(fetch_mesh_array(chk), fetch_mesh_array(abft),
                             direction, n_terms)
            if not mism:
                return w
            if st.mode == "detect":
                raise IntegrityError(
                    f"{len(mism)} integrity mismatch(es) on {direction} "
                    f"apply: " + "; ".join(str(m) for m in mism), mism)
            # recover: scripted faults were consumed at arm time, so the
            # retry runs the identical program on identical inputs clean.
            st.counters["retries"] += 1
            st.disarm()
            w, chk, abft = self._run(direction)(shards, donate=False)
            mism = st.verify(fetch_mesh_array(chk), fetch_mesh_array(abft),
                             direction, n_terms)
            if mism:
                raise IntegrityError(
                    f"integrity mismatch persisted through retry on "
                    f"{direction} apply: " + "; ".join(str(m) for m in mism),
                    mism)
            st.counters["recovered"] += 1
            return w
        finally:
            st.disarm()

    # -- integrity surface -------------------------------------------------
    def queue_fault(self, fault: MessageFault) -> None:
        """Script a deterministic message fault for the NEXT matching
        apply (fires once; requires ``integrity != "off"``)."""
        if self._integrity is None:
            raise ValueError("fault injection requires integrity='detect' "
                             "or 'recover' on the operator")
        self._integrity.queue_fault(fault)

    def integrity_report(self) -> Dict[str, object]:
        if self._integrity is None:
            return {"mode": "off"}
        return self._integrity.report()

    def forward(self, v: np.ndarray, donate: bool = False) -> np.ndarray:
        return self._apply("forward", v, donate)

    def transpose(self, u: np.ndarray, donate: bool = False) -> np.ndarray:
        return self._apply("transpose", u, donate)

    def swap_values(self, a_new) -> None:
        """Hot-swap matrix VALUES (sparsity must be identical): the
        compiled plan rebuilds its value arrays in place and every
        already-built direction program picks them up on the next call
        WITHOUT retracing — value arrays are per-call jit arguments
        (see :data:`repro.core.spmv_jax.VALUE_ARRAY_NAMES`)."""
        self.compiled.swap_values(a_new)
        self.a = a_new

    def trace_counts(self) -> Dict[str, int]:
        """Program (re)trace count per built direction; the serve plan
        cache asserts these stay flat across hot value swaps."""
        return {d: run.n_traces() for d, run in self._runs.items()}

    @property
    def local_compute(self) -> str:
        return self.compiled.resolve_local_compute(self.spec.local_compute)

    @property
    def transpose_local_compute(self) -> str:
        """Resolved transpose-direction format (the argmin of ell/coo from
        the compile-time transpose autotuner unless explicitly pinned —
        transposed Pallas BSR kernels remain a roadmap item)."""
        return self.compiled.resolve_transpose_local_compute(
            self.spec.local_compute)

    def autotune_report(self) -> Dict[str, object]:
        return dict(self.compiled.autotune,
                    resolved=self.local_compute,
                    transpose_resolved=self.transpose_local_compute,
                    requested=self.spec.local_compute)


@register_executor("shardmap", "nap")
class NapShardmapExecutor(_ShardmapExecutor):
    method = "nap"

    def _compile(self):
        from repro.core.spmv_jax import compile_nap
        return compile_nap(self.a, self.row_part, self.topo,
                           block_shape=self.spec.block_shape,
                           cache=self.spec.cache,
                           local_compute=self.spec.local_compute,
                           tuner=self.spec.tuner, col_part=self.col_part)

    def _build(self, direction: str):
        from repro.core.spmv_jax import (nap_forward_shardmap,
                                         nap_transpose_shardmap)
        kw = dict(local_compute=self.spec.local_compute,
                  nv_block=self.spec.nv_block, interpret=self.spec.interpret)
        if self._integrity is not None:
            kw.update(integrity=True, fault_fetch=self._integrity.fetch_spec)
        if direction == "forward":
            return nap_forward_shardmap(self.compiled, self.mesh, **kw)
        return nap_transpose_shardmap(self.compiled, self.mesh, **kw)

    def stats(self) -> Dict[str, object]:
        from repro.core.spmv_jax import padded_traffic
        out = {f"messages_{k}": v for k, v in
               nap_stats(self.compiled.plan).items()}
        out.update(padded_traffic(self.compiled,
                                  integrity=self.spec.integrity))
        return out

    def cost(self, machine: MachineParams) -> Dict[str, float]:
        return nap_cost(self.compiled.plan, machine)


@register_executor("shardmap", "multistep")
class MultistepShardmapExecutor(_ShardmapExecutor):
    """Multi-step plan on the SAME shard_map builders as the nap
    executor — :func:`nap_forward_shardmap` /
    :func:`nap_transpose_shardmap` add the fifth "direct" exchange when
    the compiled plan carries ``comm="multistep"``."""

    method = "multistep"

    def _compile(self):
        from repro.core.spmv_jax import compile_multistep
        return compile_multistep(self.a, self.row_part, self.topo,
                                 block_shape=self.spec.block_shape,
                                 cache=self.spec.cache,
                                 local_compute=self.spec.local_compute,
                                 tuner=self.spec.tuner,
                                 col_part=self.col_part,
                                 threshold=self.spec.threshold)

    def _build(self, direction: str):
        from repro.core.spmv_jax import (nap_forward_shardmap,
                                         nap_transpose_shardmap)
        kw = dict(local_compute=self.spec.local_compute,
                  nv_block=self.spec.nv_block, interpret=self.spec.interpret)
        if self._integrity is not None:
            kw.update(integrity=True, fault_fetch=self._integrity.fetch_spec)
        if direction == "forward":
            return nap_forward_shardmap(self.compiled, self.mesh, **kw)
        return nap_transpose_shardmap(self.compiled, self.mesh, **kw)

    def stats(self) -> Dict[str, object]:
        from repro.comm.multistep import multistep_stats
        from repro.core.spmv_jax import padded_traffic
        out = {f"messages_{k}": v for k, v in
               multistep_stats(self.compiled.ms_plan).items()}
        out.update(padded_traffic(self.compiled,
                                  integrity=self.spec.integrity))
        return out

    def cost(self, machine: MachineParams) -> Dict[str, float]:
        return multistep_cost(self.compiled.ms_plan, machine)


@register_executor("shardmap", "standard")
class StandardShardmapExecutor(_ShardmapExecutor):
    method = "standard"

    def _compile(self):
        from repro.core.spmv_jax import compile_standard
        return compile_standard(self.a, self.row_part, self.topo,
                                block_shape=self.spec.block_shape,
                                cache=self.spec.cache,
                                local_compute=self.spec.local_compute,
                                tuner=self.spec.tuner, col_part=self.col_part)

    def _build(self, direction: str):
        from repro.core.spmv_jax import (standard_forward_shardmap,
                                         standard_transpose_shardmap)
        kw = dict(local_compute=self.spec.local_compute,
                  nv_block=self.spec.nv_block, interpret=self.spec.interpret)
        if self._integrity is not None:
            kw.update(integrity=True, fault_fetch=self._integrity.fetch_spec)
        if direction == "forward":
            return standard_forward_shardmap(self.compiled, self.mesh, **kw)
        return standard_transpose_shardmap(self.compiled, self.mesh, **kw)

    def stats(self) -> Dict[str, object]:
        from repro.core.spmv_jax import padded_traffic
        out = {f"messages_{k}": v for k, v in
               standard_stats(self.compiled.plan).items()}
        out.update(padded_traffic(self.compiled,
                                  integrity=self.spec.integrity))
        return out

    def cost(self, machine: MachineParams) -> Dict[str, float]:
        return standard_cost(self.compiled.plan, machine)


# ---------------------------------------------------------------------------
# Simulator backend (exact message passing, float64 oracle)
# ---------------------------------------------------------------------------

class _SimulateExecutor:
    """Exact numpy message-passing backend; multi-RHS loops per column."""

    backend = "simulate"
    local_compute = "numpy"
    transpose_local_compute = "numpy"

    def __init__(self, a, row_part: RowPartition, col_part: RowPartition,
                 topo: Topology, spec: OperatorSpec, mesh=None):
        self.a, self.topo, self.spec = a, topo, spec
        self.row_part, self.col_part = row_part, col_part
        self._plan = None
        self._integrity = (IntegrityState(spec.integrity, topo,
                                          type(self).method)
                           if spec.integrity != "off" else None)

    @property
    def plan(self):
        if self._plan is None:
            self._plan = self._build_plan()
        return self._plan

    def _columnwise(self, fn, v: np.ndarray, n: int) -> np.ndarray:
        v = np.asarray(check_operand(n, v), dtype=np.float64)
        if v.ndim == 1:
            return fn(v)
        return np.stack([fn(v[:, i]) for i in range(v.shape[1])], axis=1)

    def forward(self, v: np.ndarray, donate: bool = False) -> np.ndarray:
        if self._integrity is None:
            return self._columnwise(lambda col: self._forward(col), v,
                                    self.a.shape[1])
        return self._forward_verified(v)

    def _forward_verified(self, v: np.ndarray) -> np.ndarray:
        """Integrity path over the numpy mailboxes: one :class:`SimWire`
        spans the whole (possibly multi-RHS) apply; a scripted fault
        fires on its first matching message.  Detect raises, recover
        re-runs clean (faults are consumed) — exact by construction."""
        st = self._integrity
        st.counters["applies"] += 1
        wire = SimWire(self.topo, st.take_pending("forward"))
        out = self._columnwise(lambda col: self._forward(col, wire=wire), v,
                               self.a.shape[1])
        mism = st.note_sim(wire)
        if not mism:
            return out
        if st.mode == "detect":
            raise IntegrityError(
                f"{len(mism)} integrity mismatch(es) on forward apply: "
                + "; ".join(str(m) for m in mism), mism)
        st.counters["retries"] += 1
        out = self._columnwise(lambda col: self._forward(col), v,
                               self.a.shape[1])
        st.counters["recovered"] += 1
        return out

    def transpose(self, u: np.ndarray, donate: bool = False) -> np.ndarray:
        st = self._integrity
        if st is not None:
            if any(f.direction in ("any", "transpose") for f in st.pending):
                raise NotImplementedError(
                    "message-fault injection on the transpose direction is "
                    "shardmap-only: the simulate transposes reverse the "
                    "exchange phases algebraically without mailboxes")
            st.counters["applies"] += 1
        return self._columnwise(lambda col: self._transpose(col), u,
                                self.a.shape[0])

    # -- integrity surface -------------------------------------------------
    def queue_fault(self, fault: MessageFault) -> None:
        if self._integrity is None:
            raise ValueError("fault injection requires integrity='detect' "
                             "or 'recover' on the operator")
        self._integrity.queue_fault(fault)

    def integrity_report(self) -> Dict[str, object]:
        if self._integrity is None:
            return {"mode": "off"}
        return self._integrity.report()

    def swap_values(self, a_new) -> None:
        """Hot-swap matrix VALUES; the comm plan is pure structure and is
        reused as-is.  Same structural contract as the shardmap backend."""
        old = self.a
        if (tuple(a_new.shape) != tuple(old.shape)
                or not np.array_equal(a_new.indptr, old.indptr)
                or not np.array_equal(a_new.indices, old.indices)):
            raise ValueError(
                "swap_values requires an identical sparsity structure "
                "(same shape, indptr, indices); rebuild the operator for "
                "a structural change")
        self.a = a_new

    def trace_counts(self) -> Dict[str, int]:
        return {}   # nothing is traced: exact numpy execution

    def autotune_report(self) -> Dict[str, object]:
        return {"resolved": self.local_compute,
                "transpose_resolved": self.transpose_local_compute,
                "note": "simulate backend runs exact numpy local compute in "
                        "both directions; the format autotuner applies to "
                        "shardmap only"}


@register_executor("simulate", "nap")
class NapSimulateExecutor(_SimulateExecutor):
    method = "nap"

    def _build_plan(self):
        return build_nap_plan(self.a.indptr, self.a.indices, self.row_part,
                              self.topo, pairing=self.spec.pairing,
                              col_part=self.col_part)

    def _forward(self, v, wire=None):
        return simulate_nap_spmv(self.a, v, self.plan, wire=wire)

    def _transpose(self, u):
        return simulate_nap_spmv_transpose(self.a, u, self.plan)

    def stats(self) -> Dict[str, object]:
        return {f"messages_{k}": v for k, v in nap_stats(self.plan).items()}

    def cost(self, machine: MachineParams) -> Dict[str, float]:
        return nap_cost(self.plan, machine)


@register_executor("simulate", "multistep")
class MultistepSimulateExecutor(_SimulateExecutor):
    method = "multistep"

    def _build_plan(self):
        from repro.comm.multistep import build_multistep_plan
        return build_multistep_plan(self.a.indptr, self.a.indices,
                                    self.row_part, self.topo,
                                    pairing=self.spec.pairing,
                                    col_part=self.col_part,
                                    threshold=self.spec.threshold)

    def _forward(self, v, wire=None):
        from repro.comm.simulate import simulate_multistep_spmv
        return simulate_multistep_spmv(self.a, v, self.plan, wire=wire)

    def _transpose(self, u):
        from repro.comm.simulate import simulate_multistep_spmv_transpose
        return simulate_multistep_spmv_transpose(self.a, u, self.plan)

    def stats(self) -> Dict[str, object]:
        from repro.comm.multistep import multistep_stats
        return {f"messages_{k}": v for k, v in
                multistep_stats(self.plan).items()}

    def cost(self, machine: MachineParams) -> Dict[str, float]:
        return multistep_cost(self.plan, machine)


@register_executor("simulate", "standard")
class StandardSimulateExecutor(_SimulateExecutor):
    method = "standard"

    def _build_plan(self):
        return build_standard_plan(self.a.indptr, self.a.indices,
                                   self.row_part, self.topo,
                                   col_part=self.col_part)

    def _forward(self, v, wire=None):
        return simulate_standard_spmv(self.a, v, self.plan, wire=wire)

    def _transpose(self, u):
        return simulate_standard_spmv_transpose(self.a, u, self.plan)

    def stats(self) -> Dict[str, object]:
        return {f"messages_{k}": v for k, v in
                standard_stats(self.plan).items()}

    def cost(self, machine: MachineParams) -> Dict[str, float]:
        return standard_cost(self.plan, machine)


# ---------------------------------------------------------------------------
# MoE dispatch backend (routing matrix over the simulate mailboxes,
# quantized wire payloads; see repro/moe/README.md)
# ---------------------------------------------------------------------------

class _MoeDispatchExecutor(_SimulateExecutor):
    """Shared moe-dispatch plumbing over the numpy mailboxes.

    Differences from the plain simulate backend:

    * every forward apply threads a wire from
      :func:`repro.moe.wire.make_wire` — narrow ``spec.wire_dtype``
      payloads are quantized at each send and f64-accumulated on
      receive; ``"f32"`` without integrity threads no wire at all
      (bit-identical to the plain simulators);
    * the transpose (weighted combine) quantizes the y operand once
      before the algebraic reverse route — one combine hop in the
      model; the in-graph nap path pays up to 2
      (:func:`repro.moe.wire.wire_error_bound` budgets both);
    * ``integrity="detect"|"recover"`` checksums the QUANTIZED words
      (idempotent re-encode on the receive side), so scripted faults on
      quantized messages attribute and retry exactly like f32 ones —
      and the recover retry re-runs with a CLEAN quantizing wire, so
      the retried result still reflects the wire encoding;
    * ``stats()`` adds the per-direction dispatch/combine injected
      byte accounting at the wire width.
    """

    backend = "moe"

    def _wire(self, faults=()):
        from repro.moe.wire import make_wire
        return make_wire(self.topo, self.spec.wire_dtype, faults,
                         force=self._integrity is not None)

    def forward(self, v: np.ndarray, donate: bool = False) -> np.ndarray:
        if self._integrity is None:
            wire = self._wire()
            return self._columnwise(lambda col: self._forward(col, wire=wire),
                                    v, self.a.shape[1])
        return self._forward_verified(v)

    def _forward_verified(self, v: np.ndarray) -> np.ndarray:
        st = self._integrity
        st.counters["applies"] += 1
        wire = self._wire(st.take_pending("forward"))
        out = self._columnwise(lambda col: self._forward(col, wire=wire), v,
                               self.a.shape[1])
        mism = st.note_sim(wire)
        if not mism:
            return out
        if st.mode == "detect":
            raise IntegrityError(
                f"{len(mism)} integrity mismatch(es) on forward apply: "
                + "; ".join(str(m) for m in mism), mism)
        st.counters["retries"] += 1
        clean = self._wire()
        out = self._columnwise(lambda col: self._forward(col, wire=clean), v,
                               self.a.shape[1])
        st.counters["recovered"] += 1
        return out

    def transpose(self, u: np.ndarray, donate: bool = False) -> np.ndarray:
        from repro.moe.wire import quantize_np
        u = np.asarray(check_operand(self.a.shape[0], u), dtype=np.float64)
        return super().transpose(quantize_np(u, self.spec.wire_dtype), donate)

    def stats(self) -> Dict[str, object]:
        from repro.moe.plan import dispatch_traffic
        out = {f"messages_{k}": v for k, v in self._plan_stats().items()}
        for direction, name in (("forward", "dispatch"),
                                ("transpose", "combine")):
            t = dispatch_traffic(self.plan, wire_dtype=self.spec.wire_dtype,
                                 nv=1, direction=direction,
                                 integrity=self.spec.integrity)
            out[f"{name}_injected_inter_bytes"] = t["injected_inter_bytes"]
            out[f"{name}_injected_intra_bytes"] = t["injected_intra_bytes"]
            out["bytes_per_val"] = t["bytes_per_val"]
        out["wire_dtype"] = self.spec.wire_dtype
        return out

    def autotune_report(self) -> Dict[str, object]:
        rep = super().autotune_report()
        rep.update(wire_dtype=self.spec.wire_dtype,
                   dispatch_resolved=type(self).method,
                   combine_resolved=type(self).method)
        return rep


@register_executor("moe", "flat")
class FlatMoeDispatchExecutor(_MoeDispatchExecutor):
    """Algorithm-1 analogue: every (token, owning-chip) payload crosses
    the flat pairwise exchange directly."""

    method = "flat"

    def _build_plan(self):
        return build_standard_plan(self.a.indptr, self.a.indices,
                                   self.row_part, self.topo,
                                   col_part=self.col_part)

    def _forward(self, v, wire=None):
        return simulate_standard_spmv(self.a, v, self.plan, wire=wire)

    def _transpose(self, u):
        return simulate_standard_spmv_transpose(self.a, u, self.plan)

    def _plan_stats(self):
        return standard_stats(self.plan)

    def cost(self, machine: MachineParams) -> Dict[str, float]:
        return standard_cost(self.plan, machine)


@register_executor("moe", "nap")
class NapMoeDispatchExecutor(_MoeDispatchExecutor):
    """NAPSpMV three-step dispatch: a token bound for several experts on
    one remote pod crosses the inter-pod boundary ONCE (the paper's
    E(n, m) dedup), via intra-gather -> one aggregated inter-pod
    exchange -> intra-scatter; the combine reverses every message."""

    method = "nap"

    def _build_plan(self):
        return build_nap_plan(self.a.indptr, self.a.indices, self.row_part,
                              self.topo, pairing=self.spec.pairing,
                              col_part=self.col_part)

    def _forward(self, v, wire=None):
        return simulate_nap_spmv(self.a, v, self.plan, wire=wire)

    def _transpose(self, u):
        return simulate_nap_spmv_transpose(self.a, u, self.plan)

    def _plan_stats(self):
        return nap_stats(self.plan)

    def cost(self, machine: MachineParams) -> Dict[str, float]:
        return nap_cost(self.plan, machine)


@register_executor("moe", "auto")
class AutoMoeDispatchExecutor:
    """Per-direction flat-vs-nap resolution for MoE dispatch.

    Binds :func:`repro.moe.plan.choose_dispatch` over the routing
    structure once, then delegates: ``forward`` runs the chosen dispatch
    executor, ``transpose`` the chosen combine executor (they may
    differ, mirroring ``comm="auto"``'s per-direction split).  The
    candidate plans are built once and shared with the sub-executors.
    """

    backend = "moe"
    method = "auto"
    local_compute = "numpy"
    transpose_local_compute = "numpy"

    def __init__(self, a, row_part: RowPartition, col_part: RowPartition,
                 topo: Topology, spec: OperatorSpec, mesh=None):
        from repro.moe.plan import build_dispatch_plans, choose_dispatch
        self.a, self.topo, self.spec = a, topo, spec
        self.row_part, self.col_part = row_part, col_part
        plans = build_dispatch_plans(a, row_part, col_part, topo,
                                     pairing=spec.pairing)
        verdict = choose_dispatch(a, row_part, col_part, topo,
                                  wire_dtype=spec.wire_dtype,
                                  integrity=spec.integrity, plans=plans)
        self.dispatch_report = {"dispatch": verdict["dispatch"],
                                "combine": verdict["combine"]}

        def sub(method: str):
            s = dataclasses.replace(spec, method=method)
            ex = _REGISTRY[("moe", method)](a, row_part, col_part, topo, s,
                                            mesh=mesh)
            ex._plan = plans[method]   # reuse the scored plan
            return ex

        fwd_m = verdict["dispatch"]["chosen"]
        bwd_m = verdict["combine"]["chosen"]
        self._fwd = sub(fwd_m)
        self._bwd = self._fwd if bwd_m == fwd_m else sub(bwd_m)

    def forward(self, v: np.ndarray, donate: bool = False) -> np.ndarray:
        return self._fwd.forward(v, donate=donate)

    def transpose(self, u: np.ndarray, donate: bool = False) -> np.ndarray:
        return self._bwd.transpose(u, donate=donate)

    def queue_fault(self, fault: MessageFault) -> None:
        target = self._bwd if fault.direction == "transpose" else self._fwd
        target.queue_fault(fault)

    def integrity_report(self) -> Dict[str, object]:
        rep = dict(self._fwd.integrity_report())
        if self._bwd is not self._fwd:
            rep["combine"] = self._bwd.integrity_report()
        return rep

    def swap_values(self, a_new) -> None:
        self._fwd.swap_values(a_new)
        if self._bwd is not self._fwd:
            self._bwd.swap_values(a_new)
        self.a = a_new

    def trace_counts(self) -> Dict[str, int]:
        return {}

    def stats(self) -> Dict[str, object]:
        out = dict(self._fwd.stats())
        if self._bwd is not self._fwd:
            b = self._bwd.stats()
            out["combine_injected_inter_bytes"] = \
                b["combine_injected_inter_bytes"]
            out["combine_injected_intra_bytes"] = \
                b["combine_injected_intra_bytes"]
        out["dispatch_resolved"] = type(self._fwd).method
        out["combine_resolved"] = type(self._bwd).method
        return out

    def cost(self, machine: MachineParams) -> Dict[str, float]:
        return self._fwd.cost(machine)

    def autotune_report(self) -> Dict[str, object]:
        return {"resolved": "numpy", "transpose_resolved": "numpy",
                "requested": "auto",
                "wire_dtype": self.spec.wire_dtype,
                "dispatch_resolved": type(self._fwd).method,
                "combine_resolved": type(self._bwd).method,
                "moe_dispatch": self.dispatch_report}
