"""Distributed SpMV executors: Algorithm 1 (standard) and Algorithms 2+3 (NAP).

Two executors share the comm plans of :mod:`repro.core.comm_graph`:

* a **numpy message-passing simulator** with exact MPI semantics — each rank
  touches only values it owns or that arrived in a message; the set of
  messages is the plan itself.  This is the correctness oracle and the
  source of the per-phase message statistics (Figs. 8–10).
* a **JAX SPMD executor** (:mod:`repro.core.spmv_jax`) that lowers the same
  plan to ``shard_map`` + ``all_to_all`` with static padded index maps.

The local compute mirrors Algorithm 3's three ``local_spmv`` calls: each
rank's rows are split into on-process / on-node / off-node *column* blocks
(Eqs. 4–7), and each block multiplies against its own buffer.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.comm_graph import (Message, NAPPlan, StandardPlan,
                                   build_nap_plan, build_standard_plan)
from repro.core.partition import RowPartition
from repro.core.topology import Topology
from repro.sparse.csr import CSR


# ---------------------------------------------------------------------------
# Local block splitting (Eqs. 4-7)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LocalBlocks:
    """Rank-local matrix split by column class, with buffer-slot column maps."""

    rank: int
    rows: np.ndarray                 # global rows R(r), ascending
    on_proc: CSR                     # cols -> local row index of owner (== this rank)
    on_node: CSR                     # cols -> slot in the on-node buffer
    off_node: CSR                    # cols -> slot in the off-node buffer
    on_node_cols: np.ndarray         # global col ids, buffer order (ascending)
    off_node_cols: np.ndarray


def split_local_blocks(a: CSR, part: RowPartition, topo: Topology, rank: int) -> LocalBlocks:
    rows = part.rows_of(rank)
    local = a.select_rows(rows)
    g_rows, g_cols, vals = local.to_coo()  # g_rows are positions within `rows`
    col_owner = part.owner[g_cols]
    col_node = topo.node_of_array(col_owner)
    me_node = topo.node_of(rank)

    on_proc_m = col_owner == rank
    on_node_m = (col_owner != rank) & (col_node == me_node)
    off_node_m = col_node != me_node

    # on-process: remap columns to local index within R(r).  ``rows`` is
    # ascending, so the remap is one bulk searchsorted.
    op_cols = np.searchsorted(rows, g_cols[on_proc_m])
    # masked subsets of a row-major COO stay row-major: skip the re-sort
    on_proc = CSR.from_coo(g_rows[on_proc_m], op_cols, vals[on_proc_m],
                           (rows.size, rows.size), sum_duplicates=False,
                           assume_sorted=True)

    def buffer_block(mask: np.ndarray) -> Tuple[CSR, np.ndarray]:
        cols = np.unique(g_cols[mask])
        bc = np.searchsorted(cols, g_cols[mask])  # slot in ascending buffer
        blk = CSR.from_coo(g_rows[mask], bc, vals[mask],
                           (rows.size, max(int(cols.size), 1)),
                           sum_duplicates=False, assume_sorted=True)
        return blk, cols

    on_node, on_node_cols = buffer_block(on_node_m)
    off_node, off_node_cols = buffer_block(off_node_m)
    return LocalBlocks(rank=rank, rows=rows, on_proc=on_proc, on_node=on_node,
                       off_node=off_node, on_node_cols=on_node_cols,
                       off_node_cols=off_node_cols)


def split_all_blocks(a: CSR, part: RowPartition, topo: Topology) -> List[LocalBlocks]:
    return [split_local_blocks(a, part, topo, r) for r in range(topo.n_procs)]


# ---------------------------------------------------------------------------
# Message-passing simulation
# ---------------------------------------------------------------------------

class _MailBox:
    """Delivers plan messages; each value fetched from the *sender's* state.

    Keyed by ``(src, dst)``: every plan phase emits at most one message per
    ordered rank pair (grouped phases by construction; inter chunks because a
    chunk index never repeats an (len_senders, len_receivers) residue pair).
    A duplicate post is a plan bug and fails loudly instead of silently
    overwriting the first payload.
    """

    def __init__(self) -> None:
        self.store: Dict[Tuple[int, int], np.ndarray] = {}

    def post(self, msg: Message, values: np.ndarray) -> None:
        assert values.shape == msg.idx.shape
        key = (msg.src, msg.dst)
        assert key not in self.store, \
            f"duplicate message for rank pair {key}: plan emitted two messages " \
            f"in one phase for the same (src, dst)"
        self.store[key] = values

    def fetch(self, msg: Message) -> np.ndarray:
        return self.store[(msg.src, msg.dst)]


def _gather_from(available: Dict[int, float], idx: np.ndarray) -> np.ndarray:
    missing = [int(j) for j in idx if int(j) not in available]
    if missing:
        raise AssertionError(f"rank accessed values it never received: {missing[:8]}")
    return np.array([available[int(j)] for j in idx], dtype=np.float64)


def simulate_standard_spmv(a: CSR, v: np.ndarray, plan: StandardPlan) -> np.ndarray:
    """Algorithm 1 with explicit message passing (numpy)."""
    part, topo = plan.partition, plan.topology
    blocks = split_all_blocks(a, part, topo)
    w = np.zeros(a.shape[0])
    # post all sends (Isend)
    box = _MailBox()
    for r in range(topo.n_procs):
        mine = {int(j): float(v[j]) for j in part.rows_of(r)}
        for msg in plan.sends[r]:
            box.post(msg, _gather_from(mine, msg.idx))
    # receive + compute
    for r in range(topo.n_procs):
        blk = blocks[r]
        mine = {int(j): float(v[j]) for j in blk.rows}
        w_local = blk.on_proc.matvec(np.array([mine[int(j)] for j in blk.rows]))
        recvd: Dict[int, float] = {}
        for msg in plan.recvs[r]:
            for jj, val in zip(msg.idx, box.fetch(msg)):
                recvd[int(jj)] = float(val)
        # standard algorithm has ONE off-process buffer (on-node ∪ off-node)
        b_node = _gather_from(recvd, blk.on_node_cols)
        b_off = _gather_from(recvd, blk.off_node_cols)
        if blk.on_node_cols.size:
            w_local = w_local + blk.on_node.matvec(b_node)
        if blk.off_node_cols.size:
            w_local = w_local + blk.off_node.matvec(b_off)
        w[blk.rows] = w_local
    return w


def simulate_nap_spmv(a: CSR, v: np.ndarray, plan: NAPPlan) -> np.ndarray:
    """Algorithms 2+3 with explicit per-phase message passing (numpy).

    Phase order follows Algorithm 3: local full + local init first, then
    inter-node Isend, local SpMVs overlap, then the final local scatter.
    """
    part, topo = plan.partition, plan.topology
    blocks = split_all_blocks(a, part, topo)
    w = np.zeros(a.shape[0])

    owned = [{int(j): float(v[j]) for j in part.rows_of(r)} for r in range(topo.n_procs)]

    # -- phase A: fully-local exchange (on_node -> on_node) ------------------
    box_full = _MailBox()
    for r in range(topo.n_procs):
        for msg in plan.local_full_sends[r]:
            assert topo.same_node(msg.src, msg.dst), "full-local must stay on node"
            box_full.post(msg, _gather_from(owned[r], msg.idx))

    # -- phase B: local init redistribution (on_node -> off_node) ------------
    box_init = _MailBox()
    for r in range(topo.n_procs):
        for msg in plan.local_init_sends[r]:
            assert topo.same_node(msg.src, msg.dst), "init redistribution stays on node"
            box_init.post(msg, _gather_from(owned[r], msg.idx))
    staged = [dict(owned[r]) for r in range(topo.n_procs)]
    for r in range(topo.n_procs):
        for msg in plan.local_init_recvs[r]:
            for jj, val in zip(msg.idx, box_init.fetch(msg)):
                staged[r][int(jj)] = float(val)

    # -- phase C: inter-node exchange (the only network injection) -----------
    box_inter = _MailBox()
    for r in range(topo.n_procs):
        for msg in plan.inter_sends[r]:
            assert not topo.same_node(msg.src, msg.dst), "inter phase crosses nodes"
            box_inter.post(msg, _gather_from(staged[r], msg.idx))
    arrived = [dict() for _ in range(topo.n_procs)]  # type: List[Dict[int, float]]
    for r in range(topo.n_procs):
        for msg in plan.inter_recvs[r]:
            for jj, val in zip(msg.idx, box_inter.fetch(msg)):
                arrived[r][int(jj)] = float(val)

    # -- phase D: local final scatter (off_node -> on_node) ------------------
    box_final = _MailBox()
    for r in range(topo.n_procs):
        for msg in plan.local_final_sends[r]:
            assert topo.same_node(msg.src, msg.dst)
            box_final.post(msg, _gather_from(arrived[r], msg.idx))
    for r in range(topo.n_procs):
        for msg in plan.local_final_recvs[r]:
            for jj, val in zip(msg.idx, box_final.fetch(msg)):
                arrived[r][int(jj)] = float(val)

    # -- compute: the three local_spmv calls of Algorithm 3 ------------------
    for r in range(topo.n_procs):
        blk = blocks[r]
        w_local = blk.on_proc.matvec(np.array([owned[r][int(j)] for j in blk.rows])
                                     if blk.rows.size else np.zeros(0))
        if blk.on_node_cols.size:
            b_ll: Dict[int, float] = {}
            for msg in plan.local_full_recvs[r]:
                for jj, val in zip(msg.idx, box_full.fetch(msg)):
                    b_ll[int(jj)] = float(val)
            w_local = w_local + blk.on_node.matvec(_gather_from(b_ll, blk.on_node_cols))
        if blk.off_node_cols.size:
            w_local = w_local + blk.off_node.matvec(_gather_from(arrived[r], blk.off_node_cols))
        w[blk.rows] = w_local
    return w


# ---------------------------------------------------------------------------
# Convenience wrapper
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DistSpMV:
    """A distributed SpMV problem: matrix + layout + both plans."""

    a: CSR
    partition: RowPartition
    topology: Topology
    standard: StandardPlan
    nap: NAPPlan

    @staticmethod
    def build(a: CSR, part: RowPartition, topo: Topology,
              pairing: str = "balanced") -> "DistSpMV":
        std = build_standard_plan(a.indptr, a.indices, part, topo)
        nap = build_nap_plan(a.indptr, a.indices, part, topo, pairing=pairing)
        return DistSpMV(a=a, partition=part, topology=topo, standard=std, nap=nap)

    def run(self, v: np.ndarray, algorithm: str = "nap") -> np.ndarray:
        if algorithm == "standard":
            return simulate_standard_spmv(self.a, v, self.standard)
        if algorithm == "nap":
            return simulate_nap_spmv(self.a, v, self.nap)
        raise ValueError(algorithm)
