"""Distributed SpMV executors: Algorithm 1 (standard) and Algorithms 2+3 (NAP).

Two executors share the comm plans of :mod:`repro.core.comm_graph`:

* a **numpy message-passing simulator** with exact MPI semantics — each rank
  touches only values it owns or that arrived in a message; the set of
  messages is the plan itself.  This is the correctness oracle and the
  source of the per-phase message statistics (Figs. 8–10).
* a **JAX SPMD executor** (:mod:`repro.core.spmv_jax`) that lowers the same
  plan to ``shard_map`` + ``all_to_all`` with static padded index maps.

The local compute mirrors Algorithm 3's three ``local_spmv`` calls: each
rank's rows are split into on-process / on-node / off-node *column* blocks
(Eqs. 4–7), and each block multiplies against its own buffer.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.comm_graph import (Message, NAPPlan, StandardPlan,
                                   build_nap_plan, build_standard_plan)
from repro.core.partition import RowPartition
from repro.core.topology import Topology
from repro.sparse.csr import CSR


# ---------------------------------------------------------------------------
# Local block splitting (Eqs. 4-7)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LocalBlocks:
    """Rank-local matrix split by column class, with buffer-slot column maps.

    ``rows`` come from the ROW partition (output ownership); ``x_rows``
    from the COLUMN partition (x ownership) — identical for the paper's
    square single-partition case, distinct for rectangular operators.
    """

    rank: int
    rows: np.ndarray                 # global rows R(r), ascending
    on_proc: CSR                     # cols -> local x index on this rank
    on_node: CSR                     # cols -> slot in the on-node buffer
    off_node: CSR                    # cols -> slot in the off-node buffer
    on_node_cols: np.ndarray         # global col ids, buffer order (ascending)
    off_node_cols: np.ndarray
    # global x/col indices owned here, ascending; defaults to ``rows``
    # (the square single-partition case — also keeps pre-rectangular
    # constructors like benchmarks/_legacy_plan.py valid verbatim)
    x_rows: Optional[np.ndarray] = None

    def __post_init__(self) -> None:
        if self.x_rows is None:
            self.x_rows = self.rows


def split_local_blocks(a: CSR, part: RowPartition, topo: Topology, rank: int,
                       col_part: Optional[RowPartition] = None) -> LocalBlocks:
    cpart = part if col_part is None else col_part
    rows = part.rows_of(rank)
    x_rows = cpart.rows_of(rank)
    local = a.select_rows(rows)
    g_rows, g_cols, vals = local.to_coo()  # g_rows are positions within `rows`
    col_owner = cpart.owner[g_cols]
    col_node = topo.node_of_array(col_owner)
    me_node = topo.node_of(rank)

    on_proc_m = col_owner == rank
    on_node_m = (col_owner != rank) & (col_node == me_node)
    off_node_m = col_node != me_node

    # on-process: remap columns to local index within the rank's x rows.
    # ``x_rows`` is ascending, so the remap is one bulk searchsorted.
    op_cols = np.searchsorted(x_rows, g_cols[on_proc_m])
    # masked subsets of a row-major COO stay row-major: skip the re-sort
    on_proc = CSR.from_coo(g_rows[on_proc_m], op_cols, vals[on_proc_m],
                           (rows.size, x_rows.size), sum_duplicates=False,
                           assume_sorted=True)

    def buffer_block(mask: np.ndarray) -> Tuple[CSR, np.ndarray]:
        cols = np.unique(g_cols[mask])
        bc = np.searchsorted(cols, g_cols[mask])  # slot in ascending buffer
        blk = CSR.from_coo(g_rows[mask], bc, vals[mask],
                           (rows.size, max(int(cols.size), 1)),
                           sum_duplicates=False, assume_sorted=True)
        return blk, cols

    on_node, on_node_cols = buffer_block(on_node_m)
    off_node, off_node_cols = buffer_block(off_node_m)
    return LocalBlocks(rank=rank, rows=rows, on_proc=on_proc, on_node=on_node,
                       off_node=off_node, on_node_cols=on_node_cols,
                       off_node_cols=off_node_cols, x_rows=x_rows)


def split_all_blocks(a: CSR, part: RowPartition, topo: Topology,
                     col_part: Optional[RowPartition] = None) -> List[LocalBlocks]:
    return [split_local_blocks(a, part, topo, r, col_part=col_part)
            for r in range(topo.n_procs)]


# ---------------------------------------------------------------------------
# Message-passing simulation
# ---------------------------------------------------------------------------

class _MailBox:
    """Delivers plan messages; each value fetched from the *sender's* state.

    Keyed by ``(src, dst)``: every plan phase emits at most one message per
    ordered rank pair (grouped phases by construction; inter chunks because a
    chunk index never repeats an (len_senders, len_receivers) residue pair).
    A duplicate post is a plan bug and fails loudly instead of silently
    overwriting the first payload.

    An optional :class:`repro.core.integrity.SimWire` sits at the post /
    fetch boundary: the sender checksums the clean payload (and a scripted
    fault may corrupt it in flight), the receiver re-checksums on fetch —
    the numpy twin of the instrumented shard_map exchange.
    """

    def __init__(self, wire=None, phase: str = "") -> None:
        self.store: Dict[Tuple[int, int], np.ndarray] = {}
        self.wire, self.phase = wire, phase

    def post(self, msg: Message, values: np.ndarray) -> None:
        assert values.shape == msg.idx.shape
        key = (msg.src, msg.dst)
        assert key not in self.store, \
            f"duplicate message for rank pair {key}: plan emitted two messages " \
            f"in one phase for the same (src, dst)"
        if self.wire is not None:
            values = self.wire.send(self.phase, msg, values)
        self.store[key] = values

    def fetch(self, msg: Message) -> np.ndarray:
        vals = self.store[(msg.src, msg.dst)]
        if self.wire is not None:
            self.wire.recv(self.phase, msg, vals)
        return vals


def _gather_from(available: Dict[int, float], idx: np.ndarray) -> np.ndarray:
    missing = [int(j) for j in idx if int(j) not in available]
    if missing:
        raise AssertionError(f"rank accessed values it never received: {missing[:8]}")
    return np.array([available[int(j)] for j in idx], dtype=np.float64)


def simulate_standard_spmv(a: CSR, v: np.ndarray, plan: StandardPlan,
                           wire=None) -> np.ndarray:
    """Algorithm 1 with explicit message passing (numpy).

    ``v`` has length ``a.shape[1]`` and is owned by the plan's column
    partition; the output has length ``a.shape[0]`` laid out by the row
    partition (the two coincide for square single-partition systems).
    ``wire`` optionally threads a :class:`repro.core.integrity.SimWire`
    through the mailbox (checksums + scripted faults).
    """
    part, topo = plan.partition, plan.topology
    cpart = plan.col_part
    blocks = split_all_blocks(a, part, topo, col_part=cpart)
    w = np.zeros(a.shape[0])
    # post all sends (Isend)
    box = _MailBox(wire, "pair")
    for r in range(topo.n_procs):
        mine = {int(j): float(v[j]) for j in cpart.rows_of(r)}
        for msg in plan.sends[r]:
            box.post(msg, _gather_from(mine, msg.idx))
    # receive + compute
    for r in range(topo.n_procs):
        blk = blocks[r]
        mine = {int(j): float(v[j]) for j in blk.x_rows}
        w_local = blk.on_proc.matvec(
            np.array([mine[int(j)] for j in blk.x_rows]))
        recvd: Dict[int, float] = {}
        for msg in plan.recvs[r]:
            for jj, val in zip(msg.idx, box.fetch(msg)):
                recvd[int(jj)] = float(val)
        # standard algorithm has ONE off-process buffer (on-node ∪ off-node)
        b_node = _gather_from(recvd, blk.on_node_cols)
        b_off = _gather_from(recvd, blk.off_node_cols)
        if blk.on_node_cols.size:
            w_local = w_local + blk.on_node.matvec(b_node)
        if blk.off_node_cols.size:
            w_local = w_local + blk.off_node.matvec(b_off)
        w[blk.rows] = w_local
    return w


def simulate_nap_spmv(a: CSR, v: np.ndarray, plan: NAPPlan,
                      wire=None) -> np.ndarray:
    """Algorithms 2+3 with explicit per-phase message passing (numpy).

    Phase order follows Algorithm 3: local full + local init first, then
    inter-node Isend, local SpMVs overlap, then the final local scatter.
    ``v`` is owned by the plan's column partition, the output by the row
    partition (identical for square single-partition systems).
    ``wire`` optionally threads a :class:`repro.core.integrity.SimWire`
    through all four phase mailboxes (checksums + scripted faults).
    """
    part, topo = plan.partition, plan.topology
    cpart = plan.col_part
    blocks = split_all_blocks(a, part, topo, col_part=cpart)
    w = np.zeros(a.shape[0])

    owned = [{int(j): float(v[j]) for j in cpart.rows_of(r)}
             for r in range(topo.n_procs)]

    # -- phase A: fully-local exchange (on_node -> on_node) ------------------
    box_full = _MailBox(wire, "full")
    for r in range(topo.n_procs):
        for msg in plan.local_full_sends[r]:
            assert topo.same_node(msg.src, msg.dst), "full-local must stay on node"
            box_full.post(msg, _gather_from(owned[r], msg.idx))

    # -- phase B: local init redistribution (on_node -> off_node) ------------
    box_init = _MailBox(wire, "init")
    for r in range(topo.n_procs):
        for msg in plan.local_init_sends[r]:
            assert topo.same_node(msg.src, msg.dst), "init redistribution stays on node"
            box_init.post(msg, _gather_from(owned[r], msg.idx))
    staged = [dict(owned[r]) for r in range(topo.n_procs)]
    for r in range(topo.n_procs):
        for msg in plan.local_init_recvs[r]:
            for jj, val in zip(msg.idx, box_init.fetch(msg)):
                staged[r][int(jj)] = float(val)

    # -- phase C: inter-node exchange (the only network injection) -----------
    box_inter = _MailBox(wire, "inter")
    for r in range(topo.n_procs):
        for msg in plan.inter_sends[r]:
            assert not topo.same_node(msg.src, msg.dst), "inter phase crosses nodes"
            box_inter.post(msg, _gather_from(staged[r], msg.idx))
    arrived = [dict() for _ in range(topo.n_procs)]  # type: List[Dict[int, float]]
    for r in range(topo.n_procs):
        for msg in plan.inter_recvs[r]:
            for jj, val in zip(msg.idx, box_inter.fetch(msg)):
                arrived[r][int(jj)] = float(val)

    # -- phase D: local final scatter (off_node -> on_node) ------------------
    box_final = _MailBox(wire, "final")
    for r in range(topo.n_procs):
        for msg in plan.local_final_sends[r]:
            assert topo.same_node(msg.src, msg.dst)
            box_final.post(msg, _gather_from(arrived[r], msg.idx))
    for r in range(topo.n_procs):
        for msg in plan.local_final_recvs[r]:
            for jj, val in zip(msg.idx, box_final.fetch(msg)):
                arrived[r][int(jj)] = float(val)

    # -- compute: the three local_spmv calls of Algorithm 3 ------------------
    for r in range(topo.n_procs):
        blk = blocks[r]
        w_local = blk.on_proc.matvec(
            np.array([owned[r][int(j)] for j in blk.x_rows])
            if blk.x_rows.size else np.zeros(0))
        if blk.on_node_cols.size:
            b_ll: Dict[int, float] = {}
            for msg in plan.local_full_recvs[r]:
                for jj, val in zip(msg.idx, box_full.fetch(msg)):
                    b_ll[int(jj)] = float(val)
            w_local = w_local + blk.on_node.matvec(_gather_from(b_ll, blk.on_node_cols))
        if blk.off_node_cols.size:
            w_local = w_local + blk.off_node.matvec(_gather_from(arrived[r], blk.off_node_cols))
        w[blk.rows] = w_local
    return w


# ---------------------------------------------------------------------------
# Transpose simulation (reversed send/recv roles)
# ---------------------------------------------------------------------------
#
# ``z = A.T u`` against the SAME plan: each rank multiplies its local rows
# through the transposed column blocks, producing per-index *contributions*
# instead of consuming buffer values; every forward message then runs
# backwards (forward receiver -> forward sender) carrying partial sums,
# which the forward sender accumulates — until contributions reach the
# owner of each vector index, who adds them into z.  This is the MPI-exact
# mirror of the adjoint shard_map program in :mod:`repro.core.spmv_jax`.

def _block_transpose_contrib(blk: LocalBlocks, u: np.ndarray):
    """Per-rank transposed local products: (z-contribution on the rank's
    own x rows, on-node buffer contributions, off-node buffer
    contributions).  ``u`` is row-partition laid out; z lives in the
    column/x space."""
    u_r = u[blk.rows] if blk.rows.size else np.zeros(0)
    z_own = blk.on_proc.transpose().matvec(u_r)
    c_node = blk.on_node.transpose().matvec(u_r) if blk.on_node_cols.size \
        else np.zeros(0)
    c_off = blk.off_node.transpose().matvec(u_r) if blk.off_node_cols.size \
        else np.zeros(0)
    return z_own, c_node, c_off


def _reverse_phase(fwd_sends: List[List[Message]],
                   pending: List[Dict[int, float]],
                   deliver) -> None:
    """Run one forward phase backwards: for every forward message
    (src -> dst, idx), the forward *receiver* pops its accumulated
    contributions for idx and the forward *sender* consumes them via
    ``deliver(src, j, value)``.  Two-phase (post all, then deliver), so a
    rank that both forwards and consumes a value never double-routes."""
    posted = []
    for msgs in fwd_sends:
        for m in msgs:
            vals = np.array([pending[m.dst].pop(int(j)) for j in m.idx])
            posted.append((m.src, m.idx, vals))
    for src, idx, vals in posted:
        for j, val in zip(idx, vals):
            deliver(src, int(j), float(val))


def simulate_standard_spmv_transpose(a: CSR, u: np.ndarray,
                                     plan: StandardPlan) -> np.ndarray:
    """Algorithm 1 reversed: z = A.T u with explicit message passing.

    ``u`` has length ``a.shape[0]`` (row partition); the output has
    length ``a.shape[1]`` and is owned by the column partition.
    """
    part, topo = plan.partition, plan.topology
    cpart = plan.col_part
    blocks = split_all_blocks(a, part, topo, col_part=cpart)
    z = np.zeros(a.shape[1])
    pending: List[Dict[int, float]] = [dict() for _ in range(topo.n_procs)]
    for r in range(topo.n_procs):
        blk = blocks[r]
        z_own, c_node, c_off = _block_transpose_contrib(blk, u)
        z[blk.x_rows] += z_own[: blk.x_rows.size]
        for j, val in zip(blk.on_node_cols, c_node[: blk.on_node_cols.size]):
            pending[r][int(j)] = float(val)
        for j, val in zip(blk.off_node_cols, c_off[: blk.off_node_cols.size]):
            pending[r][int(j)] = float(val)

    # the standard algorithm has ONE phase: reverse it straight to owners.
    def to_owner(rank: int, j: int, val: float) -> None:
        assert cpart.owner[j] == rank, "reversed message missed the owner"
        z[j] += val

    _reverse_phase(plan.sends, pending, to_owner)
    assert all(not p for p in pending), "unrouted transpose contributions"
    return z


def simulate_nap_spmv_transpose(a: CSR, u: np.ndarray,
                                plan: NAPPlan) -> np.ndarray:
    """Algorithms 2+3 reversed, phase by phase: z = A.T u.

    Reverse order of Algorithm 3: final scatter first (consumers -> home
    ranks), then the inter-node exchange (home -> staging rank), then the
    init redistribution (staging rank -> owner); the fully-local phase
    reverses independently (on-node consumers -> owners).  ``u`` is
    row-partition laid out; z is column-partition laid out.
    """
    part, topo = plan.partition, plan.topology
    cpart = plan.col_part
    blocks = split_all_blocks(a, part, topo, col_part=cpart)
    z = np.zeros(a.shape[1])
    # contributions awaiting reverse routing toward the owner (off-node
    # path) and via the fully-local path (on-node buffer).
    pending: List[Dict[int, float]] = [dict() for _ in range(topo.n_procs)]
    node_pending: List[Dict[int, float]] = [dict() for _ in range(topo.n_procs)]
    for r in range(topo.n_procs):
        blk = blocks[r]
        z_own, c_node, c_off = _block_transpose_contrib(blk, u)
        z[blk.x_rows] += z_own[: blk.x_rows.size]
        for j, val in zip(blk.on_node_cols, c_node[: blk.on_node_cols.size]):
            node_pending[r][int(j)] = float(val)
        for j, val in zip(blk.off_node_cols, c_off[: blk.off_node_cols.size]):
            pending[r][int(j)] = float(val)

    def accumulate(rank: int, j: int, val: float) -> None:
        pending[rank][j] = pending[rank].get(j, 0.0) + val

    # -- reverse phase D: consumers return contributions to the home rank --
    _reverse_phase(plan.local_final_sends, pending, accumulate)
    # -- reverse phase C: home ranks return aggregates across the network --
    _reverse_phase(plan.inter_sends, pending, accumulate)

    # -- reverse phase B: staging ranks return contributions to the owners --
    def to_owner(rank: int, j: int, val: float) -> None:
        assert cpart.owner[j] == rank, "reversed init message missed the owner"
        z[j] += val

    _reverse_phase(plan.local_init_sends, pending, to_owner)
    # whatever remains was staged from the rank's own values: fold into z.
    for r in range(topo.n_procs):
        for j, val in pending[r].items():
            assert cpart.owner[j] == r, "unrouted transpose contribution"
            z[j] += val

    # -- reverse phase A: on-node consumers return directly to the owners --
    _reverse_phase(plan.local_full_sends, node_pending, to_owner)
    assert all(not p for p in node_pending), "unrouted on-node contributions"
    return z


# ---------------------------------------------------------------------------
# Convenience wrapper
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DistSpMV:
    """A distributed SpMV problem: matrix + layout + both plans.

    (The historical ``.run`` shim is gone — apply through
    ``repro.api.operator(a, backend="simulate")`` or call the
    ``simulate_*`` oracles directly with ``.standard`` / ``.nap``.)
    """

    a: CSR
    partition: RowPartition
    topology: Topology
    standard: StandardPlan
    nap: NAPPlan
    col_partition: Optional[RowPartition] = None

    @staticmethod
    def build(a: CSR, part: RowPartition, topo: Topology,
              pairing: str = "balanced",
              col_part: Optional[RowPartition] = None) -> "DistSpMV":
        std = build_standard_plan(a.indptr, a.indices, part, topo,
                                  col_part=col_part)
        nap = build_nap_plan(a.indptr, a.indices, part, topo, pairing=pairing,
                             col_part=col_part)
        return DistSpMV(a=a, partition=part, topology=topo, standard=std,
                        nap=nap, col_partition=col_part)

